//! Characterize the full 12-kernel suite (paper Fig 3a/3b/3c) without
//! running the machine simulators' EDP comparison details — the
//! platform-independent half of the pipeline, rendered as the three
//! characterization figures.
//!
//! ```bash
//! cargo run --release --example characterize_suite -- [scale]
//! ```

use pisa_nmc::analysis::MetricSet;
use pisa_nmc::coordinator::{analyze_suite, figures, run_suite};
use pisa_nmc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.25);

    eprintln!("profiling suite at scale {scale} ...");
    let apps = run_suite(scale, 42, 8)?;

    // PJRT analytics when artifacts exist; native otherwise.
    let rt = Runtime::load_default().ok();
    if rt.is_some() {
        eprintln!("analytics engine: pjrt (AOT JAX/Pallas artifacts)");
    } else {
        eprintln!("analytics engine: native (run `make artifacts` for the pjrt path)");
    }
    let analytics = analyze_suite(&apps, rt.as_ref())?;

    let all = MetricSet::all();
    print!("{}", figures::fig3a(&apps, &analytics, all).0);
    println!();
    print!("{}", figures::fig3b(&apps, &analytics, all).0);
    println!();
    print!("{}", figures::fig3c(&apps, all).0);
    println!();
    print!("{}", figures::fig_mrc(&apps, all).0);

    // the paper's headline observation on this data
    let gs = apps.iter().position(|a| a.name == "gramschmidt").unwrap();
    let spat_gs = analytics.spatial[gs].iter().sum::<f64>() / analytics.spatial[gs].len() as f64;
    let mean_spat: f64 = analytics
        .spatial
        .iter()
        .map(|s| s.iter().sum::<f64>() / s.len() as f64)
        .sum::<f64>()
        / apps.len() as f64;
    println!(
        "\ngramschmidt mean spatial locality {spat_gs:.3} vs suite mean {mean_spat:.3} — \
         the paper's flagship cache-hostile kernel"
    );
    Ok(())
}
