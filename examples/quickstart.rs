//! Quickstart: profile one kernel end-to-end and print its PISA-NMC
//! metrics + host-vs-NMC EDP verdict.
//!
//! ```bash
//! cargo run --release --example quickstart            # defaults: atax
//! cargo run --release --example quickstart -- gramschmidt 96
//! ```

use pisa_nmc::coordinator::profile_app;
use pisa_nmc::workloads::by_name;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("atax");
    let kernel = by_name(name)?;
    let n = args
        .get(1)
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or_else(|| kernel.default_n() / 4);

    println!("profiling {name} (n={n}) ...");
    let r = profile_app(kernel.as_ref(), n, 42)?;

    println!("\n== platform-independent metrics (paper §II) ==");
    println!("dynamic instructions : {}", r.metrics.exec.dyn_instrs);
    println!(
        "profiling rate       : {:.2}M events/s (chunked pipeline)",
        r.events_per_sec() / 1e6
    );
    println!(
        "memory entropy       : {:.2} bits @1B → {:.2} bits @1KB",
        r.metrics.mem_entropy.entropies[0],
        r.metrics.mem_entropy.entropies[10]
    );
    println!("entropy_diff_mem     : {:.4}  (Fig 5 metric)", r.metrics.mem_entropy.entropy_diff);
    println!(
        "spat_8B_16B          : {:.4}  (Fig 3b / Fig 6 feature)",
        r.metrics.spatial.spat_8b_16b()
    );
    println!("DLP                  : {:.2}", r.metrics.dlp.dlp);
    println!(
        "BBLP_1..4            : {:?}",
        r.metrics
            .bblp
            .values
            .iter()
            .map(|v| (v * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    println!("PBBLP                : {:.1}", r.metrics.pbblp.pbblp);
    println!("ILP (inf window)     : {:.2}", r.metrics.ilp.inf);

    println!("\n== machine comparison (paper Fig 4) ==");
    println!(
        "host : {:.3} ms, {:.3} mJ  (DRAM lines {})",
        r.cmp.host.time_s * 1e3,
        r.cmp.host.energy_j * 1e3,
        r.cmp.host.dram_lines
    );
    println!(
        "NMC  : {:.3} ms, {:.3} mJ  (parallel fraction {:.0}%)",
        r.cmp.nmc.time_s * 1e3,
        r.cmp.nmc.energy_j * 1e3,
        r.cmp.nmc.parallel_fraction * 100.0
    );
    println!(
        "EDP improvement      : {:.2}x  → {}",
        r.cmp.edp_improvement(),
        if r.cmp.nmc_suitable() {
            "OFFLOAD to NMC"
        } else {
            "keep on host"
        }
    );
    Ok(())
}
