//! Author your own kernel against the public IR-builder API and run it
//! through the full PISA-NMC analysis — the downstream-user workflow.
//!
//! The kernel here is a 5-point stencil sweep (not in the paper's suite):
//! a classic NMC-debate workload with strong spatial locality but a large
//! streaming footprint.
//!
//! ```bash
//! cargo run --release --example custom_workload
//! ```

use pisa_nmc::coordinator::profile_app;
use pisa_nmc::interp::{run_program, NullInstrument};
use pisa_nmc::ir::{print::print_program, Program, ProgramBuilder};
use pisa_nmc::util::Rng;
use pisa_nmc::workloads::{Kernel, KernelInfo, Suite};

/// A user-defined workload only needs the `Kernel` trait.
struct Stencil5;

fn build_stencil(n: usize, seed: u64) -> Program {
    let mut rng = Rng::new(seed);
    let grid: Vec<f64> = (0..n * n).map(|_| rng.range_f64(0.0, 1.0)).collect();
    let ni = n as i64;
    let mut b = ProgramBuilder::new("stencil5");
    let src = b.alloc_f64_init("src", &grid);
    let dst = b.alloc_f64("dst", n * n);
    let inner = b.const_i(ni - 2);
    let one = b.const_i(1);
    let fifth = b.const_f(0.2);

    // for i in 1..n-1 { for j in 1..n-1 { dst[i][j] = 0.2*(c+n+s+e+w) } }
    b.counted_loop(inner, |b, i0| {
        let i = b.add(i0, one);
        b.counted_loop(inner, |b, j0| {
            let j = b.add(j0, one);
            let c = b.load_f64_2d(src, i, j, ni);
            let im1 = b.sub(i, one);
            let up = b.load_f64_2d(src, im1, j, ni);
            let ip1 = b.add(i, one);
            let down = b.load_f64_2d(src, ip1, j, ni);
            let jm1 = b.sub(j, one);
            let left = b.load_f64_2d(src, i, jm1, ni);
            let jp1 = b.add(j, one);
            let right = b.load_f64_2d(src, i, jp1, ni);
            let s1 = b.fadd(c, up);
            let s2 = b.fadd(s1, down);
            let s3 = b.fadd(s2, left);
            let s4 = b.fadd(s3, right);
            let avg = b.fmul(s4, fifth);
            b.store_f64_2d(dst, i, j, ni, avg);
        });
    });
    b.finish(None)
}

impl Kernel for Stencil5 {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "stencil5",
            suite: Suite::Polybench, // closest family for reporting
            param_name: "grid side",
            paper_value: "(custom)",
            summary: "5-point Jacobi stencil sweep",
        }
    }

    fn default_n(&self) -> usize {
        128
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        build_stencil(n, seed)
    }

    fn validate(&self, n: usize, seed: u64) -> anyhow::Result<f64> {
        // native oracle
        let mut rng = Rng::new(seed);
        let grid: Vec<f64> = (0..n * n).map(|_| rng.range_f64(0.0, 1.0)).collect();
        let prog = self.build(n, seed);
        let (_, machine) = run_program(&prog, &mut NullInstrument)?;
        let buf = prog.buffer("dst").unwrap();
        let got = machine.mem.read_f64_slice(buf.base, n * n)?;
        let mut err = 0.0f64;
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                let want = 0.2
                    * (grid[i * n + j]
                        + grid[(i - 1) * n + j]
                        + grid[(i + 1) * n + j]
                        + grid[i * n + j - 1]
                        + grid[i * n + j + 1]);
                err = err.max((got[i * n + j] - want).abs());
            }
        }
        Ok(err)
    }
}

fn main() -> anyhow::Result<()> {
    let k = Stencil5;

    // 1. show a snippet of the generated IR
    let tiny = k.build(4, 1);
    println!("== generated mini-IR (4x4 grid) ==");
    for line in print_program(&tiny).lines().take(18) {
        println!("{line}");
    }
    println!("  ...\n");

    // 2. oracle-validate like the built-in suite does
    let err = k.validate(24, 7)?;
    println!("oracle max abs err: {err:.2e}\n");
    assert!(err < 1e-12);

    // 3. full analysis + machine comparison
    let r = profile_app(&k, k.default_n(), 42)?;
    println!("== stencil5 (n={}) ==", r.n);
    println!(
        "spat_8B_16B     : {:.3} (stencils are spatially friendly)",
        r.metrics.spatial.spat_8b_16b()
    );
    println!("PBBLP           : {:.0} (rows are data-parallel)", r.metrics.pbblp.pbblp);
    println!("entropy_diff    : {:.3}", r.metrics.mem_entropy.entropy_diff);
    println!(
        "EDP improvement : {:.2}x → {}",
        r.cmp.edp_improvement(),
        if r.cmp.nmc_suitable() { "offload to NMC" } else { "keep on host" }
    );
    Ok(())
}
