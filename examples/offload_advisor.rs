//! **End-to-end driver** (EXPERIMENTS.md §End-to-end): the full PISA-NMC
//! workflow on a real workload suite —
//!
//!   1. profile all 12 Polybench/Rodinia kernels through the instrumented
//!      execution engine (one pass, all §II analyzers + task trace),
//!   2. run the numeric analytics (memory entropy, spatial locality, PCA)
//!      as AOT JAX/Pallas artifacts on PJRT,
//!   3. recommend offload candidates from the platform-independent metrics
//!      alone (the paper's thesis: metrics predict NMC suitability) — now
//!      including the `traffic` subsystem's data-movement signals: bytes
//!      per instruction, *post-hierarchy* DRAM bytes per instruction (what
//!      actually crosses the L1→L2→LLC replay — NMPO's offload model ranks
//!      by exactly this residual memory traffic) and the slope-based
//!      miss-ratio-curve knee,
//!   4. validate the recommendation by simulating each app on both the
//!      Power9-class host and the 32-PE HMC NMC system, reporting the
//!      paper's headline metric: EDP improvement, and the Spearman rank
//!      correlation of each suitability signal against it.
//!
//! ```bash
//! make artifacts && cargo run --release --example offload_advisor -- [scale]
//! ```

use pisa_nmc::coordinator::{analyze_suite, run_suite};
use pisa_nmc::report::Table;
use pisa_nmc::runtime::Runtime;
use pisa_nmc::util::stats::spearman;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.5);

    eprintln!("[1/4] profiling 12 kernels at scale {scale} ...");
    let t0 = std::time::Instant::now();
    let apps = run_suite(scale, 42, 8)?;
    eprintln!("      done in {:.1}s", t0.elapsed().as_secs_f64());

    eprintln!("[2/4] PJRT analytics (entropy / spatial / PCA artifacts) ...");
    let rt = Runtime::load_default().ok();
    let analytics = analyze_suite(&apps, rt.as_ref())?;
    eprintln!("      engine: {}", analytics.engine.name());

    // 3. metric-only recommendation: an app looks NMC-friendly when the
    // parallelism metrics say its loops can fan out across PEs (PBBLP —
    // the dominant EDP driver on a 32-PE system) or it sits in the
    // positive-PC1 (irregular/parallel) half of the PCA plane.
    eprintln!("[3/4] metric-only offload recommendation ...");
    let recommend: Vec<bool> = (0..apps.len())
        .map(|i| analytics.pca.scores[i][0] > 0.0 || apps[i].metrics.pbblp.pbblp > 10.0)
        .collect();

    eprintln!("[4/4] validating against machine simulations ...\n");
    let mut t = Table::new(&[
        "app",
        "PBBLP",
        "spat_8B_16B",
        "B/instr",
        "DRAM B/instr",
        "MRC knee",
        "PC1",
        "recommend",
        "EDP improvement",
        "verdict",
    ]);
    let mut agree = 0;
    for (i, a) in apps.iter().enumerate() {
        let edp = a.cmp.edp_improvement();
        let actual = edp > 1.0;
        if actual == recommend[i] {
            agree += 1;
        }
        let tr = &a.metrics.traffic;
        t.row(vec![
            a.name.clone(),
            format!("{:.0}", a.metrics.pbblp.pbblp),
            format!("{:.3}", a.metrics.spatial.spat_8b_16b()),
            format!("{:.2}", tr.bytes_per_instr()),
            format!("{:.3}", tr.dram_bytes_per_instr()),
            match tr.mrc_knee_bytes {
                Some(b) => pisa_nmc::traffic::capacity_label(b),
                None => "–".into(),
            },
            format!("{:+.2}", analytics.pca.scores[i][0]),
            if recommend[i] { "offload" } else { "host" }.into(),
            format!("{edp:.2}x"),
            if actual { "NMC wins" } else { "host wins" }.into(),
        ]);
    }
    print!("{}", t.render());

    let pc1: Vec<f64> = (0..apps.len()).map(|i| analytics.pca.scores[i][0]).collect();
    let edps: Vec<f64> = apps.iter().map(|a| a.cmp.edp_improvement()).collect();
    // the traffic subsystem's suitability signals, ranked against the
    // simulated outcome exactly like PC1: raw data movement per
    // instruction, the *post-hierarchy* DRAM bytes per instruction (the
    // traffic the L1→L2→LLC replay could not absorb — the residual an NMC
    // system would actually serve from its stacked DRAM) and the MRC knee
    // (a bigger knee capacity → cache-hostile working set; knee-less flat
    // curves rank below the family when the footprint fits the smallest
    // capacity and past it otherwise — see knee_or_sentinel)
    let bpi: Vec<f64> = apps.iter().map(|a| a.metrics.traffic.bytes_per_instr()).collect();
    let dram_bpi: Vec<f64> =
        apps.iter().map(|a| a.metrics.traffic.dram_bytes_per_instr()).collect();
    let knee: Vec<f64> = apps.iter().map(|a| a.metrics.traffic.knee_or_sentinel()).collect();
    println!(
        "\nmetric→EDP agreement: {agree}/{} apps;  Spearman(PC1, EDP improvement) = {:.2}",
        apps.len(),
        spearman(&pc1, &edps)
    );
    println!(
        "traffic signals:      Spearman(bytes/instr, EDP) = {:.2};  \
         Spearman(DRAM bytes/instr, EDP) = {:.2};  Spearman(MRC knee, EDP) = {:.2}",
        spearman(&bpi, &edps),
        spearman(&dram_bpi, &edps),
        spearman(&knee, &edps)
    );
    println!(
        "headline (paper Fig 4): best EDP improvement {:.2}x ({})",
        edps.iter().cloned().fold(f64::MIN, f64::max),
        apps[edps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0]
            .name
    );
    Ok(())
}
