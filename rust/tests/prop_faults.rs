//! Fault-matrix properties for the supervised pipeline: every injected
//! fault kind (`panic`, `stall:<ms>`, `interp-error`) at every site
//! (`interp`, `broadcaster`, `worker:<shard>`) under every delivery mode
//! (inline, offload, sharded with one worker, sharded auto) must
//! complete within a bounded wall clock — no hangs, no wedged channel
//! pools — and resolve to exactly one of the supervised contract's three
//! outcomes:
//!
//! * a **typed error** (`PanicError` / `InjectedFault` / `TimeoutError`)
//!   when the fault hits the interpreter thread — there is no partial
//!   event stream to salvage;
//! * a **degraded run**: analysis-side panics are isolated, the dead
//!   shard's families land in `AppMetrics::failed`, and every surviving
//!   family stays **bit-identical** to a clean run;
//! * a **clean run** for stalls without a watchdog: slower, same bits.
//!
//! The teardown edges ride along: a stalled sharded worker must trip the
//! `--app-timeout` watchdog (not block the producer forever), and an
//! offload analyzer panic must degrade while the interpreter still runs
//! the program to completion. With `FaultPlan::none()` the supervised
//! entry points must reproduce the unsupervised baseline bit for bit —
//! the same 4-way identity `prop_chunked.rs` gates.

use std::time::{Duration, Instant};

use pisa_nmc::analysis::{profile, profile_with_tasks_supervised, AppMetrics, MetricSet};
use pisa_nmc::fault::{FaultPlan, InjectedFault, PanicError, SuperviseOpts, TimeoutError};
use pisa_nmc::interp::{PipelineMode, Workers};
use pisa_nmc::ir::{Program, ProgramBuilder};
use pisa_nmc::traffic::TrafficOpts;

/// Every analyzer family `MetricSet::all()` enables, in canonical order.
const FAMILIES: &[&str] =
    &["mix", "branch", "mem_entropy", "reuse", "ilp", "dlp", "bblp", "pbblp", "traffic"];

fn modes() -> [(&'static str, PipelineMode); 4] {
    [
        ("inline", PipelineMode::Inline),
        ("offload", PipelineMode::Offload),
        ("sharded:1", PipelineMode::Sharded { workers: Workers::Fixed(1) }),
        ("sharded:auto", PipelineMode::Sharded { workers: Workers::Auto }),
    ]
}

/// A real suite kernel, sized to span several chunk flushes so chunk-0
/// faults fire mid-stream rather than at the final drain.
fn matrix_program() -> Program {
    pisa_nmc::workloads::by_name("gesummv").unwrap().build(24, 7)
}

/// The backpressure stress from `prop_chunked.rs`: ~100+ chunk flushes,
/// so a stalled worker exhausts the bounded buffer pool and the producer
/// actually blocks (the watchdog's recv_timeout path).
fn stress_program() -> Program {
    let mut b = ProgramBuilder::new("fault_stress");
    let a = b.alloc_f64("a", 256);
    let len = b.const_i(256);
    let n = b.const_i(40_000);
    b.counted_loop(n, |b, i| {
        let idx = b.rem(i, len);
        let v = b.load_f64(a, idx);
        let w = b.fadd(v, v);
        b.store_f64(a, idx, w);
    });
    b.finish(None)
}

fn run(
    p: &Program,
    mode: PipelineMode,
    sup: SuperviseOpts,
) -> anyhow::Result<(AppMetrics, bool)> {
    let (m, regions) =
        profile_with_tasks_supervised(p, MetricSet::all(), mode, TrafficOpts::default(), sup)?;
    Ok((m, regions.is_some()))
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bit-exact comparison of one analyzer family's metric surface
/// (the same surfaces `prop_chunked.rs` compares across deliveries).
fn assert_family_matches(combo: &str, fam: &str, a: &AppMetrics, b: &AppMetrics) {
    let ok = match fam {
        "mix" => {
            a.mix.per_op == b.mix.per_op
                && a.mix.branches == b.mix.branches
                && a.mix.blocks == b.mix.blocks
        }
        "branch" => {
            a.branch.weighted_entropy().to_bits() == b.branch.weighted_entropy().to_bits()
                && a.branch.dyn_branches() == b.branch.dyn_branches()
                && a.branch.static_sites() == b.branch.static_sites()
        }
        "mem_entropy" => {
            bits_eq(&a.mem_entropy.entropies, &b.mem_entropy.entropies)
                && a.mem_entropy.count_of_counts == b.mem_entropy.count_of_counts
                && a.mem_entropy.unique_addrs == b.mem_entropy.unique_addrs
                && a.mem_entropy.accesses == b.mem_entropy.accesses
        }
        "reuse" => {
            a.reuse.hist == b.reuse.hist
                && a.reuse.cold == b.reuse.cold
                && a.reuse.footprint == b.reuse.footprint
                && bits_eq(&a.reuse.avg_dtr, &b.reuse.avg_dtr)
                && bits_eq(&a.spatial.scores, &b.spatial.scores)
        }
        "ilp" => {
            a.ilp.inf.to_bits() == b.ilp.inf.to_bits()
                && a.ilp.critical_path == b.ilp.critical_path
        }
        "dlp" => a.dlp.dlp.to_bits() == b.dlp.dlp.to_bits(),
        "bblp" => bits_eq(&a.bblp.values, &b.bblp.values) && a.bblp.instances == b.bblp.instances,
        "pbblp" => {
            a.pbblp.pbblp.to_bits() == b.pbblp.pbblp.to_bits()
                && a.pbblp.iterations == b.pbblp.iterations
        }
        "traffic" => a.traffic == b.traffic,
        other => panic!("unknown family '{other}'"),
    };
    assert!(ok, "{combo}: surviving family '{fam}' is not bit-identical to the clean run");
}

#[test]
fn fault_matrix_is_bounded_and_classified() {
    let p = matrix_program();
    let clean = profile_with_tasks_supervised(
        &p,
        MetricSet::all(),
        PipelineMode::Inline,
        TrafficOpts::default(),
        SuperviseOpts::default(),
    )
    .unwrap()
    .0;
    let specs = [
        "panic@interp",
        "panic@broadcaster",
        "panic@worker:0",
        "panic@worker:1",
        "stall:25@interp",
        "stall:25@broadcaster",
        "stall:25@worker:0",
        "stall:25@worker:1",
        "interp-error@interp",
    ];
    for (mode_name, mode) in modes() {
        for spec in specs {
            let combo = format!("{mode_name} × {spec}");
            let sup = SuperviseOpts::default().with_fault(FaultPlan::from_spec(spec).unwrap());
            let t0 = Instant::now();
            let res = run(&p, mode, sup);
            let elapsed = t0.elapsed();
            assert!(elapsed < Duration::from_secs(60), "{combo}: took {elapsed:?} (hang?)");
            match res {
                Err(e) => {
                    assert!(
                        e.downcast_ref::<PanicError>().is_some()
                            || e.downcast_ref::<InjectedFault>().is_some(),
                        "{combo}: error is not typed: {e:#}"
                    );
                    assert!(
                        !spec.starts_with("stall"),
                        "{combo}: a stall without a watchdog must complete, not fail"
                    );
                    // only interpreter-thread faults fail the run:
                    // inline collapses every site onto it, other modes
                    // degrade their analysis-side faults instead
                    assert!(
                        matches!(mode, PipelineMode::Inline) || spec.ends_with("@interp"),
                        "{combo}: analysis-side fault must degrade, not fail"
                    );
                }
                Ok((m, has_regions)) => {
                    assert_eq!(
                        m.exec.dyn_instrs, clean.exec.dyn_instrs,
                        "{combo}: interpreter did not run to completion"
                    );
                    if m.failed.is_empty() {
                        assert!(
                            !spec.starts_with("panic"),
                            "{combo}: an injected panic cannot leave a fully clean run"
                        );
                        assert!(has_regions, "{combo}: clean run lost its task trace");
                        for fam in FAMILIES {
                            assert_family_matches(&combo, fam, &m, &clean);
                        }
                    } else {
                        assert!(
                            spec.starts_with("panic"),
                            "{combo}: only analysis-side panics degrade a run"
                        );
                        assert!(
                            !matches!(mode, PipelineMode::Inline),
                            "{combo}: inline delivery has no analysis side to lose"
                        );
                        for fam in &m.failed {
                            assert!(
                                FAMILIES.contains(&fam.as_str()),
                                "{combo}: unknown failed family '{fam}'"
                            );
                        }
                        for fam in FAMILIES {
                            if !m.failed.iter().any(|f| f == fam) {
                                assert_family_matches(&combo, fam, &m, &clean);
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn clean_supervised_runs_match_the_unsupervised_baseline() {
    // FaultPlan::none() plus a far-away watchdog must change nothing:
    // same bits as the plain `profile` path, in all four deliveries
    let p = matrix_program();
    let baseline = profile(&p).unwrap();
    let sup = SuperviseOpts::default().with_fault(FaultPlan::none()).with_timeout_s(Some(3600));
    for (mode_name, mode) in modes() {
        let combo = format!("{mode_name} × none");
        let (m, has_regions) = run(&p, mode, sup).unwrap();
        assert!(m.failed.is_empty(), "{combo}: clean run reported failed families");
        assert!(has_regions, "{combo}: clean run lost its task trace");
        assert_eq!(m.exec.dyn_instrs, baseline.exec.dyn_instrs, "{combo}: dyn instrs differ");
        for fam in FAMILIES {
            assert_family_matches(&combo, fam, &m, &baseline);
        }
    }
}

#[test]
fn stalled_sharded_worker_trips_the_watchdog_within_bounds() {
    // teardown edge: worker 0 sleeps 3s on its first chunk; the bounded
    // buffer pool backs the stall up to the producer, whose 1s watchdog
    // must fire through the recv_timeout waits — and teardown must still
    // drain every thread instead of wedging the pool
    let p = stress_program();
    let sup = SuperviseOpts::default()
        .with_fault(FaultPlan::from_spec("stall:3000@worker:0").unwrap())
        .with_timeout_s(Some(1));
    let t0 = Instant::now();
    let err = run(&p, PipelineMode::Sharded { workers: Workers::Auto }, sup).unwrap_err();
    let elapsed = t0.elapsed();
    assert!(
        err.downcast_ref::<TimeoutError>().is_some(),
        "want the typed watchdog expiry, got: {err:#}"
    );
    assert!(elapsed >= Duration::from_millis(900), "watchdog fired early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(20), "teardown took {elapsed:?} (wedged pool?)");
}

#[test]
fn offload_analyzer_panic_degrades_while_the_interpreter_completes() {
    // teardown edge: the single offloaded analysis thread dies mid-run
    // with the watchdog armed; the producer detaches, finishes the
    // program, and the run degrades — all families failed, trace forfeit
    let p = stress_program();
    let sup = SuperviseOpts::default()
        .with_fault(FaultPlan::from_spec("panic@worker:0").unwrap())
        .with_timeout_s(Some(600));
    let t0 = Instant::now();
    let (m, has_regions) = run(&p, PipelineMode::Offload, sup).unwrap();
    let elapsed = t0.elapsed();
    assert!(elapsed < Duration::from_secs(30), "degraded teardown took {elapsed:?}");
    let all: Vec<String> = FAMILIES.iter().map(|s| s.to_string()).collect();
    assert_eq!(m.failed, all, "offload death must take every family down together");
    assert!(!has_regions, "a degraded run must forfeit the task trace");
    assert!(m.exec.dyn_instrs > 0, "interpreter must still run to completion");
}
