//! Traffic-subsystem cross-validation properties.
//!
//! 1. **Hierarchy counters ≡ direct replay**: the per-level counters
//!    folded inside the chunked `AnalyzerStack` lane sweep must exactly
//!    match a fresh `HierarchyReplay` driven access-at-a-time over the
//!    captured stream — any drift introduced by chunk laning shows up
//!    here. (The deeper proof against an *independent* naive
//!    implementation lives in `prop_hierarchy.rs`.)
//! 2. **MRC ≡ fully-associative LRU replay**: the one-pass stack-distance
//!    MRC's exact miss counts must match a naive Mattson LRU stack
//!    simulated at each capacity directly.
//! 3. **Byte accounting ≡ event stream**: read/write byte totals must
//!    equal summing the captured access sizes.
//! 4. **Slope knee**: when present, the knee sits on the curve's steepest
//!    drop and clears `MIN_KNEE_DROP`.

use pisa_nmc::analysis::{profile, AppMetrics};
use pisa_nmc::interp::{Instrument, Machine, TraceEvent};
use pisa_nmc::ir::Program;
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{check_seeded, random_program};
use pisa_nmc::traffic::{
    HierarchyConfig, HierarchyPolicy, HierarchyReplay, MIN_KNEE_DROP, MRC_CAPACITIES_BYTES,
    MRC_LINE_BYTES,
};

/// Capture the run's memory-access stream in trace order.
#[derive(Default)]
struct AccessCapture(Vec<(u64, u8, bool)>);

impl Instrument for AccessCapture {
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            if let Some(m) = i.mem {
                self.0.push((m.addr, m.size, m.is_store));
            }
        }
    }
}

fn capture_accesses(prog: &Program) -> Vec<(u64, u8, bool)> {
    let mut cap = AccessCapture::default();
    Machine::new(prog).unwrap().run_per_event(&mut cap).unwrap();
    cap.0
}

/// The shared fully-associative LRU oracle (`testkit::naive_lru_misses`)
/// over this access stream's 64 B lines.
fn naive_lru_misses(accs: &[(u64, u8, bool)], cap_lines: usize) -> u64 {
    let lines = accs.iter().map(|&(addr, _, _)| addr / MRC_LINE_BYTES);
    pisa_nmc::testkit::naive_lru_misses(lines, cap_lines)
}

/// The property: the streaming `TrafficMetrics` from one chunked profile
/// pass must agree exactly with direct replays of the captured stream.
fn assert_traffic_matches_replay(
    m: &AppMetrics,
    accs: &[(u64, u8, bool)],
    check_mrc_points: usize,
) -> Result<(), String> {
    let tr = &m.traffic;
    prop_assert!(
        tr.accesses == accs.len() as u64,
        "access count: streaming {} vs captured {}",
        tr.accesses,
        accs.len()
    );

    // byte accounting vs the captured sizes
    let want_rb: u64 = accs.iter().filter(|a| !a.2).map(|a| a.1 as u64).sum();
    let want_wb: u64 = accs.iter().filter(|a| a.2).map(|a| a.1 as u64).sum();
    prop_assert!(
        (tr.read_bytes, tr.write_bytes) == (want_rb, want_wb),
        "byte totals: streaming ({}, {}) vs replay ({want_rb}, {want_wb})",
        tr.read_bytes,
        tr.write_bytes
    );

    // per-level hierarchy counters vs a direct access-at-a-time replay of
    // the same engine (chunk laning must not change the fold)
    let mut direct = HierarchyReplay::new(HierarchyConfig::host(tr.hierarchy_policy));
    for &(addr, _, is_store) in accs {
        direct.access(addr, is_store);
    }
    for (s, d) in tr.levels.iter().zip(direct.finalize()) {
        prop_assert!(
            (s.hits, s.misses, s.writebacks) == (d.hits, d.misses, d.writebacks),
            "level '{}': streaming ({}, {}, {}) vs direct replay ({}, {}, {})",
            s.name,
            s.hits,
            s.misses,
            s.writebacks,
            d.hits,
            d.misses,
            d.writebacks
        );
    }
    prop_assert!(
        (tr.dram_fills, tr.dram_writebacks) == (direct.dram_fills(), direct.dram_writebacks()),
        "DRAM counters: streaming ({}, {}) vs direct replay ({}, {})",
        tr.dram_fills,
        tr.dram_writebacks,
        direct.dram_fills(),
        direct.dram_writebacks()
    );

    // MRC vs the naive Mattson LRU stack at the smallest capacities (the
    // oracle is O(n·C), so only the cheap points are replayed)
    for (i, &cap) in MRC_CAPACITIES_BYTES.iter().enumerate().take(check_mrc_points) {
        let want = naive_lru_misses(accs, (cap / MRC_LINE_BYTES) as usize);
        prop_assert!(
            tr.mrc_misses[i] == want,
            "MRC misses at {cap} B: streaming {} vs naive LRU {want}",
            tr.mrc_misses[i]
        );
    }
    // Mattson inclusion: the curve is monotone non-increasing, floored by
    // the compulsory count
    for w in tr.mrc_misses.windows(2) {
        prop_assert!(w[1] <= w[0], "MRC not monotone: {:?}", tr.mrc_misses);
    }
    prop_assert!(
        *tr.mrc_misses.last().unwrap() >= tr.cold_misses,
        "largest-capacity misses below the compulsory floor"
    );
    Ok(())
}

#[test]
fn traffic_matches_direct_replay_on_random_programs() {
    check_seeded("traffic == direct replay", 0x7AFF1C, 24, |rng| {
        let p = random_program(rng);
        let m = profile(&p).map_err(|e| e.to_string())?;
        let accs = capture_accesses(&p);
        assert_traffic_matches_replay(&m, &accs, 2)
    });
}

#[test]
fn traffic_matches_direct_replay_on_real_kernels() {
    // ≥ 2 real kernels, sized to span several chunk flushes: one dense
    // regular Polybench kernel and one irregular Rodinia kernel
    for (name, n) in [("gesummv", 48), ("bfs", 96)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 7);
        let m = profile(&p).unwrap();
        let accs = capture_accesses(&p);
        assert!(accs.len() > 1000, "{name}: want a multi-chunk trace, got {} accesses", accs.len());
        if let Err(msg) = assert_traffic_matches_replay(&m, &accs, 2) {
            panic!("{name}: {msg}");
        }
    }
}

#[test]
fn default_profile_replays_the_inclusive_hierarchy() {
    let k = pisa_nmc::workloads::by_name("gesummv").unwrap();
    let m = profile(&k.build(24, 7)).unwrap();
    assert_eq!(m.traffic.hierarchy_policy, HierarchyPolicy::Inclusive);
    assert_eq!(m.traffic.levels.len(), 3);
    assert_eq!(m.traffic.dram_fills, m.traffic.llc().unwrap().misses);
}

#[test]
fn mrc_knee_sits_on_the_steepest_drop_when_present() {
    let k = pisa_nmc::workloads::by_name("atax").unwrap();
    let m = profile(&k.build(48, 7)).unwrap();
    let tr = &m.traffic;
    if let Some(knee) = tr.mrc_knee_bytes {
        assert!(MRC_CAPACITIES_BYTES.contains(&knee), "knee {knee} not in family");
        // slope definition: the knee's drop is the curve's maximum and
        // clears the flatness floor; earlier drops are strictly smaller
        // (ties resolve to the smallest capacity)
        let i = MRC_CAPACITIES_BYTES.iter().position(|&c| c == knee).unwrap();
        assert!(i >= 1, "knee cannot sit on the first point");
        let drop_at = |j: usize| tr.mrc_miss_ratio[j - 1] - tr.mrc_miss_ratio[j];
        let knee_drop = drop_at(i);
        assert!(knee_drop >= MIN_KNEE_DROP, "knee drop {knee_drop} under the floor");
        for j in 1..tr.mrc_miss_ratio.len() {
            if j < i {
                assert!(drop_at(j) < knee_drop, "earlier drop at {j} ties or beats the knee");
            } else {
                assert!(drop_at(j) <= knee_drop, "later drop at {j} beats the knee");
            }
        }
    }
    // the rank scalar is always positive and, when a knee exists, equals it
    assert!(tr.knee_or_sentinel() > 0.0);
    if let Some(knee) = tr.mrc_knee_bytes {
        assert_eq!(tr.knee_or_sentinel(), knee as f64);
    }
}
