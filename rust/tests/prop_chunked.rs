//! Pipeline equivalence properties: for seeded random programs from
//! `testkit`, profiling through the chunked `EventChunk` lane-swept hot
//! path, through the offloaded analysis thread **and** through the
//! family-sharded analyzer worker pool produces **bit-identical**
//! `AppMetrics` to the per-event reference path — pca8 feature vectors,
//! entropy histograms (count-of-counts), reuse-distance CDFs, instruction
//! mix, ILP windows, BBLP/PBBLP, the memory-traffic family (MRC miss
//! counts/ratios, slope knee, byte accounting, per-level hierarchy
//! counters and DRAM fills/writebacks — under both replay policies) and
//! the dynamic-count stats all compared exactly. This is the safety net under
//! every tuned `on_chunk`/`on_chunk_lanes` implementation, under the
//! offload channel protocol and under the sharded broadcast +
//! countdown-return recycling: any reordering or lost/duplicated event —
//! on any thread — shows up here as a bit mismatch.
//!
//! The backpressure stresses at the bottom deliberately make the analysis
//! side the slow one (the single offload thread, then one shard of the
//! sharded pool), so the bounded chunk pool must throttle the interpreter
//! without deadlocking or dropping events.

use std::time::Duration;

use pisa_nmc::analysis::{profile, profile_per_event, AppMetrics};
use pisa_nmc::coordinator::{ProfileRequest, RunCtx};
use pisa_nmc::interp::{
    run_offload, run_sharded, Counter, Instrument, Machine, PipelineMode, TraceEvent, Workers,
};
use pisa_nmc::ir::Program;
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{check_seeded, random_program};
use pisa_nmc::traffic::{HierarchyPolicy, TrafficOpts};

/// Profile through a non-default delivery/traffic combination via the
/// consolidated request builder (the positional variants are deprecated).
fn profile_req(
    p: &Program,
    mode: PipelineMode,
    traffic: TrafficOpts,
) -> Result<AppMetrics, String> {
    ProfileRequest::program(p)
        .mode(mode)
        .traffic(traffic)
        .run_metrics(&RunCtx::new())
        .map_err(|e| e.to_string())
}

/// Exact comparison of every metric surface. f64s are compared by bit
/// pattern: the two paths must execute the *same arithmetic in the same
/// order*, not merely land close.
fn assert_bit_identical(a: &AppMetrics, b: &AppMetrics) -> Result<(), String> {
    let (pa, pb) = (a.pca8_features(), b.pca8_features());
    for (i, (x, y)) in pa.iter().zip(&pb).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "pca8[{i}]: chunked {x} vs per-event {y}"
        );
    }

    // instruction mix
    prop_assert!(a.mix.per_op == b.mix.per_op, "per-op mix differs");
    prop_assert!(
        (a.mix.branches, a.mix.blocks) == (b.mix.branches, b.mix.blocks),
        "mix branch/block counts differ"
    );

    // memory entropy: per-granularity entropies and count-of-counts
    for (g, (x, y)) in a
        .mem_entropy
        .entropies
        .iter()
        .zip(&b.mem_entropy.entropies)
        .enumerate()
    {
        prop_assert!(x.to_bits() == y.to_bits(), "entropy[{g}] {x} vs {y}");
    }
    prop_assert!(
        a.mem_entropy.count_of_counts == b.mem_entropy.count_of_counts,
        "entropy count-of-counts differ"
    );
    prop_assert!(
        a.mem_entropy.unique_addrs == b.mem_entropy.unique_addrs
            && a.mem_entropy.accesses == b.mem_entropy.accesses,
        "entropy footprint/access counts differ"
    );

    // reuse: full distance histograms (the CDF data) + means + cold counts
    prop_assert!(a.reuse.hist == b.reuse.hist, "reuse histograms differ");
    prop_assert!(
        a.reuse.cold == b.reuse.cold && a.reuse.footprint == b.reuse.footprint,
        "reuse cold/footprint differ"
    );
    for (l, (x, y)) in a.reuse.avg_dtr.iter().zip(&b.reuse.avg_dtr).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "avg_dtr[{l}] {x} vs {y}");
    }
    for (l, (x, y)) in a.spatial.scores.iter().zip(&b.spatial.scores).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "spatial[{l}] {x} vs {y}");
    }

    // parallelism family
    for ((wa, va), (wb, vb)) in a.ilp.windowed.iter().zip(&b.ilp.windowed) {
        prop_assert!(
            wa == wb && va.to_bits() == vb.to_bits(),
            "ILP_{wa} {va} vs ILP_{wb} {vb}"
        );
    }
    prop_assert!(
        a.ilp.inf.to_bits() == b.ilp.inf.to_bits()
            && a.ilp.critical_path == b.ilp.critical_path,
        "ILP_inf / critical path differ"
    );
    prop_assert!(a.dlp.dlp.to_bits() == b.dlp.dlp.to_bits(), "DLP differs");
    prop_assert!(a.dlp.per_op.len() == b.dlp.per_op.len(), "DLP per-op len");
    for (x, y) in a.bblp.values.iter().zip(&b.bblp.values) {
        prop_assert!(x.to_bits() == y.to_bits(), "BBLP {x} vs {y}");
    }
    prop_assert!(a.bblp.instances == b.bblp.instances, "BB instances differ");
    prop_assert!(
        a.pbblp.pbblp.to_bits() == b.pbblp.pbblp.to_bits()
            && a.pbblp.iterations == b.pbblp.iterations,
        "PBBLP differs"
    );

    // memory traffic: MRC miss counts/ratios, byte accounting, the slope
    // knee and the per-level hierarchy counters — every field, exactly
    // (TrafficMetrics is integer folds + finalize-time ratios, so
    // PartialEq is bit-exact)
    prop_assert!(
        a.traffic.mrc_misses == b.traffic.mrc_misses,
        "MRC miss counts differ: {:?} vs {:?}",
        a.traffic.mrc_misses,
        b.traffic.mrc_misses
    );
    let (ra, rb) = (&a.traffic.mrc_miss_ratio, &b.traffic.mrc_miss_ratio);
    for (i, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "mrc_miss_ratio[{i}] {x} vs {y}");
    }
    prop_assert!(
        a.traffic.mrc_knee_bytes == b.traffic.mrc_knee_bytes,
        "MRC knee differs: {:?} vs {:?}",
        a.traffic.mrc_knee_bytes,
        b.traffic.mrc_knee_bytes
    );
    prop_assert!(
        a.traffic.hierarchy_policy == b.traffic.hierarchy_policy,
        "hierarchy policy differs"
    );
    for (la, lb) in a.traffic.levels.iter().zip(&b.traffic.levels) {
        prop_assert!(
            (la.hits, la.misses, la.writebacks) == (lb.hits, lb.misses, lb.writebacks),
            "hierarchy level '{}' counters differ: ({}, {}, {}) vs ({}, {}, {})",
            la.name,
            la.hits,
            la.misses,
            la.writebacks,
            lb.hits,
            lb.misses,
            lb.writebacks
        );
    }
    prop_assert!(
        (a.traffic.dram_fills, a.traffic.dram_writebacks)
            == (b.traffic.dram_fills, b.traffic.dram_writebacks),
        "DRAM fill/writeback counters differ"
    );
    prop_assert!(a.traffic == b.traffic, "traffic metrics differ");

    // branch entropy
    prop_assert!(
        a.branch.weighted_entropy().to_bits() == b.branch.weighted_entropy().to_bits()
            && a.branch.dyn_branches() == b.branch.dyn_branches()
            && a.branch.static_sites() == b.branch.static_sites(),
        "branch entropy differs"
    );

    // dynamic counts (wall_s legitimately differs)
    prop_assert!(
        a.exec.dyn_instrs == b.exec.dyn_instrs
            && a.exec.dyn_blocks == b.exec.dyn_blocks
            && a.exec.dyn_branches == b.exec.dyn_branches
            && a.exec.mem_reads == b.exec.mem_reads
            && a.exec.mem_writes == b.exec.mem_writes,
        "exec stats differ"
    );
    Ok(())
}

#[test]
fn chunked_profile_is_bit_identical_to_per_event() {
    check_seeded("chunked == per-event", 0xC41C, 32, |rng| {
        let p = random_program(rng);
        let chunked = profile(&p).map_err(|e| e.to_string())?;
        let reference = profile_per_event(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&chunked, &reference)
    });
}

#[test]
fn offload_profile_is_bit_identical_to_inline() {
    // the third delivery path: analyzers folding on a dedicated thread,
    // chunks crossing the bounded channel — same bits, every seed
    check_seeded("offload == inline", 0x0FF1, 24, |rng| {
        let p = random_program(rng);
        let offloaded = profile_req(&p, PipelineMode::Offload, TrafficOpts::default())?;
        let inline = profile(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&offloaded, &inline)?;
        // and transitively against the per-event reference
        let reference = profile_per_event(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&offloaded, &reference)
    });
}

#[test]
fn sharded_profile_is_bit_identical_to_inline() {
    // the fourth delivery path: analyzers sharded by family across a
    // worker pool, each chunk broadcast to every worker over the
    // countdown-return pool — same bits, every seed
    check_seeded("sharded == inline", 0x54A2, 24, |rng| {
        let p = random_program(rng);
        let sharded = profile_req(
            &p,
            PipelineMode::Sharded { workers: Workers::Auto },
            TrafficOpts::default(),
        )?;
        let inline = profile(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&sharded, &inline)?;
        // and transitively against the per-event reference
        let reference = profile_per_event(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&sharded, &reference)
    });
}

#[test]
fn all_four_paths_bit_identical_on_real_kernels() {
    // the suite kernels exercise nested loops, reductions and irregular
    // access patterns at sizes spanning several chunk flushes
    for (name, n) in [("gesummv", 24), ("atax", 24), ("bfs", 24), ("kmeans", 12)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 7);
        let chunked = profile(&p).unwrap();
        let reference = profile_per_event(&p).unwrap();
        let offloaded =
            profile_req(&p, PipelineMode::Offload, TrafficOpts::default()).unwrap();
        let sharded = profile_req(
            &p,
            PipelineMode::Sharded { workers: Workers::Auto },
            TrafficOpts::default(),
        )
        .unwrap();
        if let Err(msg) = assert_bit_identical(&chunked, &reference) {
            panic!("{name} (chunked vs per-event): {msg}");
        }
        if let Err(msg) = assert_bit_identical(&offloaded, &chunked) {
            panic!("{name} (offload vs chunked): {msg}");
        }
        if let Err(msg) = assert_bit_identical(&sharded, &chunked) {
            panic!("{name} (sharded vs chunked): {msg}");
        }
    }
}

#[test]
fn all_four_paths_bit_identical_under_exclusive_hierarchy() {
    // the new per-level counters must stay bit-identical across every
    // delivery for the *exclusive* replay too — its move-up/demote chains
    // are the most stateful fold in the stack, so any chunk-boundary or
    // cross-thread reordering would surface here first
    check_seeded("exclusive hierarchy 4-way", 0xE8C2, 12, |rng| {
        let p = random_program(rng);
        let excl = TrafficOpts::with_hierarchy(HierarchyPolicy::Exclusive);
        let reference = ProfileRequest::program(&p)
            .per_event(true)
            .traffic(excl)
            .run_metrics(&RunCtx::new())
            .map_err(|e| e.to_string())?;
        let chunked = profile_req(&p, PipelineMode::Inline, excl)?;
        let offloaded = profile_req(&p, PipelineMode::Offload, excl)?;
        let sharded = profile_req(&p, PipelineMode::Sharded { workers: Workers::Auto }, excl)?;
        prop_assert!(
            chunked.traffic.hierarchy_policy == HierarchyPolicy::Exclusive,
            "policy did not reach the analyzer"
        );
        assert_bit_identical(&chunked, &reference)?;
        assert_bit_identical(&offloaded, &chunked)?;
        assert_bit_identical(&sharded, &chunked)
    });
}

/// A deliberately slow analyzer: sleeps on every chunk so the analysis
/// thread falls behind the interpreter and the bounded chunk pool must
/// throttle the producer.
struct SlowCounter {
    inner: Counter,
    delay: Duration,
    chunks: u64,
}

impl Instrument for SlowCounter {
    fn on_event(&mut self, ev: &TraceEvent) {
        self.inner.on_event(ev);
    }

    fn on_chunk(&mut self, events: &[TraceEvent]) {
        std::thread::sleep(self.delay);
        self.chunks += 1;
        for ev in events {
            self.inner.on_event(ev);
        }
    }
}

#[test]
fn offload_backpressure_with_slow_analyzer_loses_nothing() {
    // ~100+ chunk flushes against an analyzer that sleeps per chunk: the
    // interpreter must block on the recycled-buffer channel (bounded
    // memory), never deadlock, and every event must still arrive in order
    use pisa_nmc::ir::ProgramBuilder;
    let mut b = ProgramBuilder::new("stress");
    let a = b.alloc_f64("a", 256);
    let len = b.const_i(256);
    let n = b.const_i(40_000);
    b.counted_loop(n, |b, i| {
        let idx = b.rem(i, len);
        let v = b.load_f64(a, idx);
        let w = b.fadd(v, v);
        b.store_f64(a, idx, w);
    });
    let p = b.finish(None);

    let mut fast = Counter::default();
    let inline = Machine::new(&p).unwrap().run(&mut fast).unwrap();

    let mut slow = SlowCounter {
        inner: Counter::default(),
        delay: Duration::from_millis(1),
        chunks: 0,
    };
    let offl = run_offload(&mut Machine::new(&p).unwrap(), &mut slow).unwrap();

    assert!(slow.chunks > 50, "expected many chunk flushes, got {}", slow.chunks);
    assert_eq!(inline.stats.dyn_instrs, offl.stats.dyn_instrs);
    assert_eq!(
        (fast.instrs, fast.blocks, fast.branches, fast.loads, fast.stores),
        (
            slow.inner.instrs,
            slow.inner.blocks,
            slow.inner.branches,
            slow.inner.loads,
            slow.inner.stores
        )
    );
    // the offload wall clock includes the analysis drain, so the slow
    // analyzer's sleeps are visible in the reported throughput
    assert!(offl.stats.wall_s >= slow.chunks as f64 * 0.001);
}

#[test]
fn sharded_backpressure_with_one_slow_worker_loses_nothing() {
    // same stress through the sharded topology: one deliberately slow
    // shard next to two fast ones. The slow worker's bounded input queue
    // must stall the broadcaster — and through the countdown-return pool,
    // the interpreter — without deadlocking, and every shard must still
    // fold every event in order.
    use pisa_nmc::ir::ProgramBuilder;
    let mut b = ProgramBuilder::new("stress_sharded");
    let a = b.alloc_f64("a", 256);
    let len = b.const_i(256);
    let n = b.const_i(40_000);
    b.counted_loop(n, |b, i| {
        let idx = b.rem(i, len);
        let v = b.load_f64(a, idx);
        let w = b.fadd(v, v);
        b.store_f64(a, idx, w);
    });
    let p = b.finish(None);

    let mut fast = Counter::default();
    let inline = Machine::new(&p).unwrap().run(&mut fast).unwrap();

    let mut slow = SlowCounter {
        inner: Counter::default(),
        delay: Duration::from_millis(1),
        chunks: 0,
    };
    let mut c1 = Counter::default();
    let mut c2 = Counter::default();
    let out = {
        let mut shards: Vec<&mut (dyn Instrument + Send)> = vec![&mut slow, &mut c1, &mut c2];
        run_sharded(&mut Machine::new(&p).unwrap(), &mut shards).unwrap()
    };

    assert!(slow.chunks > 50, "expected many chunk broadcasts, got {}", slow.chunks);
    assert_eq!(inline.stats.dyn_instrs, out.stats.dyn_instrs);
    let want = (fast.instrs, fast.blocks, fast.branches, fast.loads, fast.stores);
    for (who, c) in [("slow", &slow.inner), ("fast1", &c1), ("fast2", &c2)] {
        assert_eq!(
            want,
            (c.instrs, c.blocks, c.branches, c.loads, c.stores),
            "{who} shard dropped or duplicated events"
        );
    }
    // the sharded wall clock includes the slowest worker's drain
    assert!(out.stats.wall_s >= slow.chunks as f64 * 0.001);
}
