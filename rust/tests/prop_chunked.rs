//! Chunked-pipeline equivalence property: for seeded random programs from
//! `testkit`, profiling through the chunked `EventChunk`/`on_chunk` hot
//! path produces **bit-identical** `AppMetrics` to the per-event reference
//! path — pca8 feature vectors, entropy histograms (count-of-counts),
//! reuse-distance CDFs, instruction mix, ILP windows, BBLP/PBBLP and the
//! dynamic-count stats all compared exactly. This is the safety net under
//! every tuned `on_chunk` implementation: any reordering or lost/duplicated
//! event shows up here as a bit mismatch.

use pisa_nmc::analysis::{profile, profile_per_event, AppMetrics};
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{check_seeded, random_program};

/// Exact comparison of every metric surface. f64s are compared by bit
/// pattern: the two paths must execute the *same arithmetic in the same
/// order*, not merely land close.
fn assert_bit_identical(a: &AppMetrics, b: &AppMetrics) -> Result<(), String> {
    let (pa, pb) = (a.pca8_features(), b.pca8_features());
    for (i, (x, y)) in pa.iter().zip(&pb).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "pca8[{i}]: chunked {x} vs per-event {y}"
        );
    }

    // instruction mix
    prop_assert!(a.mix.per_op == b.mix.per_op, "per-op mix differs");
    prop_assert!(
        (a.mix.branches, a.mix.blocks) == (b.mix.branches, b.mix.blocks),
        "mix branch/block counts differ"
    );

    // memory entropy: per-granularity entropies and count-of-counts
    for (g, (x, y)) in a
        .mem_entropy
        .entropies
        .iter()
        .zip(&b.mem_entropy.entropies)
        .enumerate()
    {
        prop_assert!(x.to_bits() == y.to_bits(), "entropy[{g}] {x} vs {y}");
    }
    prop_assert!(
        a.mem_entropy.count_of_counts == b.mem_entropy.count_of_counts,
        "entropy count-of-counts differ"
    );
    prop_assert!(
        a.mem_entropy.unique_addrs == b.mem_entropy.unique_addrs
            && a.mem_entropy.accesses == b.mem_entropy.accesses,
        "entropy footprint/access counts differ"
    );

    // reuse: full distance histograms (the CDF data) + means + cold counts
    prop_assert!(a.reuse.hist == b.reuse.hist, "reuse histograms differ");
    prop_assert!(
        a.reuse.cold == b.reuse.cold && a.reuse.footprint == b.reuse.footprint,
        "reuse cold/footprint differ"
    );
    for (l, (x, y)) in a.reuse.avg_dtr.iter().zip(&b.reuse.avg_dtr).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "avg_dtr[{l}] {x} vs {y}");
    }
    for (l, (x, y)) in a.spatial.scores.iter().zip(&b.spatial.scores).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "spatial[{l}] {x} vs {y}");
    }

    // parallelism family
    for ((wa, va), (wb, vb)) in a.ilp.windowed.iter().zip(&b.ilp.windowed) {
        prop_assert!(
            wa == wb && va.to_bits() == vb.to_bits(),
            "ILP_{wa} {va} vs ILP_{wb} {vb}"
        );
    }
    prop_assert!(
        a.ilp.inf.to_bits() == b.ilp.inf.to_bits()
            && a.ilp.critical_path == b.ilp.critical_path,
        "ILP_inf / critical path differ"
    );
    prop_assert!(a.dlp.dlp.to_bits() == b.dlp.dlp.to_bits(), "DLP differs");
    prop_assert!(a.dlp.per_op.len() == b.dlp.per_op.len(), "DLP per-op len");
    for (x, y) in a.bblp.values.iter().zip(&b.bblp.values) {
        prop_assert!(x.to_bits() == y.to_bits(), "BBLP {x} vs {y}");
    }
    prop_assert!(a.bblp.instances == b.bblp.instances, "BB instances differ");
    prop_assert!(
        a.pbblp.pbblp.to_bits() == b.pbblp.pbblp.to_bits()
            && a.pbblp.iterations == b.pbblp.iterations,
        "PBBLP differs"
    );

    // branch entropy
    prop_assert!(
        a.branch.weighted_entropy().to_bits() == b.branch.weighted_entropy().to_bits()
            && a.branch.dyn_branches() == b.branch.dyn_branches()
            && a.branch.static_sites() == b.branch.static_sites(),
        "branch entropy differs"
    );

    // dynamic counts (wall_s legitimately differs)
    prop_assert!(
        a.exec.dyn_instrs == b.exec.dyn_instrs
            && a.exec.dyn_blocks == b.exec.dyn_blocks
            && a.exec.dyn_branches == b.exec.dyn_branches
            && a.exec.mem_reads == b.exec.mem_reads
            && a.exec.mem_writes == b.exec.mem_writes,
        "exec stats differ"
    );
    Ok(())
}

#[test]
fn chunked_profile_is_bit_identical_to_per_event() {
    check_seeded("chunked == per-event", 0xC41C, 32, |rng| {
        let p = random_program(rng);
        let chunked = profile(&p).map_err(|e| e.to_string())?;
        let reference = profile_per_event(&p).map_err(|e| e.to_string())?;
        assert_bit_identical(&chunked, &reference)
    });
}

#[test]
fn chunked_profile_is_bit_identical_on_real_kernels() {
    // the suite kernels exercise nested loops, reductions and irregular
    // access patterns at sizes spanning several chunk flushes
    for (name, n) in [("gesummv", 24), ("atax", 24), ("bfs", 24), ("kmeans", 12)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 7);
        let chunked = profile(&p).unwrap();
        let reference = profile_per_event(&p).unwrap();
        if let Err(msg) = assert_bit_identical(&chunked, &reference) {
            panic!("{name}: {msg}");
        }
    }
}
