//! Differential-oracle and invariant properties for the streaming
//! cache-hierarchy replay (`traffic::hierarchy`).
//!
//! 1. **Streaming ≡ naive replay**: the per-level hit/miss/writeback
//!    counters and DRAM fill/writeback counts folded inside the chunked
//!    `AnalyzerStack` pass must exactly match a *naive* event-at-a-time
//!    multi-level replay — an independent implementation below that keeps
//!    per-set recency as plain `Vec`s (move-to-back on touch, pop-front on
//!    evict) instead of the production LRU-stamp machinery — on seeded
//!    random programs *and* real suite kernels, under **both** the
//!    inclusive and the exclusive policy.
//! 2. **Inclusion invariant**: in inclusive mode an upper level never
//!    holds (and in particular never *hits*) a line absent from the levels
//!    below it.
//! 3. **Exclusive aggregate capacity**: with fully-associative levels a
//!    cyclic working set larger than the last level but no larger than the
//!    *sum* of the levels stops missing after the cold pass in exclusive
//!    mode, while inclusive mode (effective capacity = last level) keeps
//!    thrashing — pinned with exact counts.
//! 4. **MRC monotonicity**: miss ratios are non-increasing in capacity on
//!    random programs (Mattson inclusion, end to end through the profile
//!    pipeline).
//! 5. **DRAM accounting regression**: hierarchy DRAM bytes never exceed
//!    the old independent shadow bank's figure (`testkit::IndependentBank`)
//!    on any suite kernel, and are strictly lower on a crafted trace whose
//!    traffic is absorbed by upper levels — the double-counting the
//!    hierarchy replay was built to remove.
//! 6. **Spec defaulting ≡ host chain**: a `--hierarchy-spec` that spells
//!    out the host shape (round-tripped through `from_spec_json`, exactly
//!    the CLI path) produces bit-identical `TrafficMetrics` to the
//!    spec-less default on all four deliveries (per-event, inline-chunked,
//!    offload, sharded).
//! 7. **Sweep ≡ standalone replays, end to end**: every `--sweep` grid
//!    point folded through the full profile pipeline carries the same
//!    `SweepCounters` as a standalone [`HierarchyReplay`] at that config
//!    fed the captured access stream — the differential oracle behind the
//!    one-pass DSE mode.

use pisa_nmc::coordinator::{ProfileRequest, RunCtx};
use pisa_nmc::interp::{Instrument, Machine, PipelineMode, TraceEvent, Workers};
use pisa_nmc::sim::cache::ReplacementKind;
use pisa_nmc::ir::Program;
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{check_seeded, random_program};
use pisa_nmc::traffic::{
    HierarchyConfig, HierarchyPolicy, HierarchyReplay, LevelConfig, TrafficMetrics, TrafficOpts,
    HIERARCHY_LEVELS, MRC_LINE_BYTES,
};

// ---------------------------------------------------------------------------
// The naive oracle: same semantics, independent mechanics.

/// One naive level: per-set recency lists of `(line, dirty)`, oldest
/// first. Set/way derivation mirrors `sim::cache::Cache::new` so both
/// implementations shape identically.
#[derive(Clone)]
struct NaiveLevel {
    sets: Vec<Vec<(u64, bool)>>,
    ways: usize,
}

impl NaiveLevel {
    fn new(cfg: &LevelConfig, line_bytes: u64) -> NaiveLevel {
        let n_lines = ((cfg.capacity_bytes / line_bytes) as usize).max(1);
        let ways = (cfg.ways as usize).min(n_lines).max(1);
        let sets = (n_lines / ways).max(1);
        NaiveLevel { sets: vec![Vec::new(); sets], ways }
    }

    fn set_of(&self, line: u64) -> usize {
        (line as usize) % self.sets.len()
    }

    /// Hit: move to back (most recent), merge dirty.
    fn touch(&mut self, line: u64, dirty: bool) -> bool {
        let s = self.set_of(line);
        let set = &mut self.sets[s];
        if let Some(pos) = set.iter().position(|&(l, _)| l == line) {
            let (l, d) = set.remove(pos);
            set.push((l, d || dirty));
            true
        } else {
            false
        }
    }

    fn mark_dirty(&mut self, line: u64) -> bool {
        let s = self.set_of(line);
        if let Some(e) = self.sets[s].iter_mut().find(|e| e.0 == line) {
            e.1 = true;
            true
        } else {
            false
        }
    }

    /// Fill with fresh recency; evict the set's oldest entry when full.
    fn fill(&mut self, line: u64, dirty: bool) -> Option<(u64, bool)> {
        if self.touch(line, dirty) {
            return None;
        }
        let s = self.set_of(line);
        let ways = self.ways;
        let set = &mut self.sets[s];
        let evicted = (set.len() == ways).then(|| set.remove(0));
        set.push((line, dirty));
        evicted
    }

    fn take(&mut self, line: u64) -> Option<bool> {
        let s = self.set_of(line);
        let set = &mut self.sets[s];
        let pos = set.iter().position(|&(l, _)| l == line)?;
        Some(set.remove(pos).1)
    }
}

#[derive(Default, Clone, Copy, PartialEq, Eq, Debug)]
struct Counts {
    hits: u64,
    misses: u64,
    writebacks: u64,
}

/// The naive event-at-a-time multi-level replay, written directly from
/// the documented semantics in `traffic::hierarchy` (probe top-down; fill
/// missed levels deepest-first with back-invalidation under the inclusive
/// policy; move-up with victim demotion under the exclusive policy).
struct NaiveHierarchy {
    levels: Vec<NaiveLevel>,
    counts: Vec<Counts>,
    policy: HierarchyPolicy,
    dram_fills: u64,
    dram_writebacks: u64,
}

impl NaiveHierarchy {
    fn new(cfg: &HierarchyConfig) -> NaiveHierarchy {
        NaiveHierarchy {
            levels: cfg.levels.iter().map(|l| NaiveLevel::new(l, cfg.line_bytes)).collect(),
            counts: vec![Counts::default(); cfg.levels.len()],
            policy: cfg.policy,
            dram_fills: 0,
            dram_writebacks: 0,
        }
    }

    fn host(policy: HierarchyPolicy) -> NaiveHierarchy {
        Self::new(&HierarchyConfig::host(policy))
    }

    fn access(&mut self, addr: u64, is_store: bool) {
        let line = addr / MRC_LINE_BYTES;
        match self.policy {
            HierarchyPolicy::Inclusive => self.access_inclusive(line, is_store),
            HierarchyPolicy::Exclusive => self.access_exclusive(line, is_store),
        }
    }

    fn access_inclusive(&mut self, line: u64, is_store: bool) {
        let n = self.levels.len();
        let mut hit = n;
        for i in 0..n {
            if self.levels[i].touch(line, is_store && i == 0) {
                self.counts[i].hits += 1;
                hit = i;
                break;
            }
            self.counts[i].misses += 1;
        }
        if hit == n {
            self.dram_fills += 1;
        }
        for lvl in (0..hit).rev() {
            if let Some((vline, vdirty)) = self.levels[lvl].fill(line, is_store && lvl == 0) {
                // back-invalidate upper copies, merging their dirt
                let mut dirty = vdirty;
                for upper in (0..lvl).rev() {
                    if let Some(d) = self.levels[upper].take(vline) {
                        dirty |= d;
                    }
                }
                if dirty {
                    self.counts[lvl].writebacks += 1;
                    if lvl + 1 < n {
                        assert!(
                            self.levels[lvl + 1].mark_dirty(vline),
                            "oracle inclusion violated at level {lvl}"
                        );
                    } else {
                        self.dram_writebacks += 1;
                    }
                }
            }
        }
    }

    fn access_exclusive(&mut self, line: u64, is_store: bool) {
        let n = self.levels.len();
        if self.levels[0].touch(line, is_store) {
            self.counts[0].hits += 1;
            return;
        }
        self.counts[0].misses += 1;
        for i in 1..n {
            if let Some(dirty) = self.levels[i].take(line) {
                self.counts[i].hits += 1;
                self.promote(line, dirty || is_store);
                return;
            }
            self.counts[i].misses += 1;
        }
        self.dram_fills += 1;
        self.promote(line, is_store);
    }

    fn promote(&mut self, line: u64, dirty: bool) {
        let mut incoming = Some((line, dirty));
        for lvl in 0..self.levels.len() {
            let Some((l, d)) = incoming else { return };
            incoming = self.levels[lvl].fill(l, d);
            if incoming.is_some_and(|(_, d)| d) {
                self.counts[lvl].writebacks += 1;
            }
        }
        if incoming.is_some_and(|(_, d)| d) {
            self.dram_writebacks += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Shared capture + comparison plumbing.

/// Capture the run's memory-access stream in trace order.
#[derive(Default)]
struct AccessCapture(Vec<(u64, u8, bool)>);

impl Instrument for AccessCapture {
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            if let Some(m) = i.mem {
                self.0.push((m.addr, m.size, m.is_store));
            }
        }
    }
}

fn capture_accesses(prog: &Program) -> Vec<(u64, u8, bool)> {
    let mut cap = AccessCapture::default();
    Machine::new(prog).unwrap().run_per_event(&mut cap).unwrap();
    cap.0
}

/// The differential property: the streaming `TrafficMetrics` (folded
/// through chunk lanes inside the profile pipeline) must agree exactly
/// with the naive oracle replay of the captured stream.
fn assert_matches_naive(
    tr: &TrafficMetrics,
    accs: &[(u64, u8, bool)],
    policy: HierarchyPolicy,
) -> Result<(), String> {
    let mut oracle = NaiveHierarchy::host(policy);
    for &(addr, _, is_store) in accs {
        oracle.access(addr, is_store);
    }
    prop_assert!(tr.hierarchy_policy == policy, "policy label drifted");
    prop_assert!(
        tr.levels.len() == oracle.counts.len(),
        "level count: streaming {} vs oracle {}",
        tr.levels.len(),
        oracle.counts.len()
    );
    for (s, (i, o)) in tr.levels.iter().zip(oracle.counts.iter().enumerate()) {
        prop_assert!(
            (s.hits, s.misses, s.writebacks) == (o.hits, o.misses, o.writebacks),
            "{} level {i}: streaming ({}, {}, {}) vs naive ({}, {}, {})",
            policy.name(),
            s.hits,
            s.misses,
            s.writebacks,
            o.hits,
            o.misses,
            o.writebacks
        );
        prop_assert!(
            s.hits + s.misses <= accs.len() as u64,
            "level {i} saw more accesses than the trace has"
        );
    }
    prop_assert!(
        (tr.dram_fills, tr.dram_writebacks) == (oracle.dram_fills, oracle.dram_writebacks),
        "{} DRAM: streaming ({}, {}) vs naive ({}, {})",
        policy.name(),
        tr.dram_fills,
        tr.dram_writebacks,
        oracle.dram_fills,
        oracle.dram_writebacks
    );
    // the structural identities the counters must satisfy in both policies
    prop_assert!(
        tr.levels[0].hits + tr.levels[0].misses == accs.len() as u64,
        "L1 must see every access"
    );
    for w in tr.levels.windows(2) {
        prop_assert!(
            w[0].misses == w[1].hits + w[1].misses,
            "each level must see exactly the level above's misses"
        );
    }
    prop_assert!(
        tr.dram_fills == tr.levels.last().unwrap().misses,
        "DRAM fills must equal last-level misses"
    );
    Ok(())
}

fn profile_traffic(prog: &Program, policy: HierarchyPolicy) -> TrafficMetrics {
    ProfileRequest::program(prog)
        .traffic(TrafficOpts::with_hierarchy(policy))
        .run_metrics(&RunCtx::new())
        .unwrap()
        .traffic
}

// ---------------------------------------------------------------------------
// 1. Streaming ≡ naive replay.

#[test]
fn streaming_matches_naive_replay_on_random_programs_inclusive() {
    check_seeded("hierarchy == naive (inclusive)", 0x41C1, 16, |rng| {
        let p = random_program(rng);
        let tr = profile_traffic(&p, HierarchyPolicy::Inclusive);
        assert_matches_naive(&tr, &capture_accesses(&p), HierarchyPolicy::Inclusive)
    });
}

#[test]
fn streaming_matches_naive_replay_on_random_programs_exclusive() {
    check_seeded("hierarchy == naive (exclusive)", 0xE8C1, 16, |rng| {
        let p = random_program(rng);
        let tr = profile_traffic(&p, HierarchyPolicy::Exclusive);
        assert_matches_naive(&tr, &capture_accesses(&p), HierarchyPolicy::Exclusive)
    });
}

#[test]
fn streaming_matches_naive_replay_on_real_kernels() {
    // ≥ 2 real kernels spanning several chunk flushes: one dense regular
    // Polybench kernel, one irregular Rodinia kernel — both policies
    for (name, n) in [("gesummv", 48), ("bfs", 96)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 7);
        let accs = capture_accesses(&p);
        assert!(accs.len() > 1000, "{name}: want a multi-chunk trace, got {}", accs.len());
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let tr = profile_traffic(&p, policy);
            if let Err(msg) = assert_matches_naive(&tr, &accs, policy) {
                panic!("{name} ({}): {msg}", policy.name());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Inclusion invariant.

#[test]
fn inclusive_mode_never_hits_above_a_line_absent_below() {
    let mut rng = pisa_nmc::util::Rng::new(0x1C5);
    // footprint big enough to force evictions at every level of a scaled-
    // down chain, so back-invalidation actually fires
    let mut h = HierarchyReplay::new(HierarchyConfig::uniform(
        vec![
            LevelConfig::new("l1", 8 * 64, 2),
            LevelConfig::new("l2", 32 * 64, 4),
            LevelConfig::new("llc", 128 * 64, 8),
        ],
        64,
        HierarchyPolicy::Inclusive,
    ));
    // span ~512 lines of footprint: bigger than every level, so evictions
    // and back-invalidations fire at L1, L2 *and* the LLC
    let trace = pisa_nmc::testkit::address_trace(&mut rng, 20_000, 4096);
    for (i, &addr) in trace.iter().enumerate() {
        let hit = h.access(addr, i % 5 == 0);
        // an upper-level hit implies the line is present all the way down
        if hit < 2 {
            for lower in hit + 1..3 {
                assert!(
                    h.level_contains(lower, addr),
                    "hit at level {hit} but line absent from level {lower} (access {i})"
                );
            }
        }
        // periodically check full set inclusion (sorted subset walk)
        if i % 512 == 0 {
            for lvl in 0..2 {
                let upper = h.level_lines(lvl);
                let lower = h.level_lines(lvl + 1);
                for line in &upper {
                    assert!(
                        lower.binary_search(line).is_ok(),
                        "level {lvl} line {line} missing below (access {i})"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Exclusive aggregate capacity.

#[test]
fn exclusive_mode_reaches_aggregate_capacity_inclusive_does_not() {
    // fully-associative levels of 4 + 8 + 16 lines; a cyclic working set
    // of 24 lines: bigger than the 16-line last level, within the 28-line
    // aggregate. Exclusive never drops a line once resident (evictions
    // cascade down and only fall off when every level is full), so after
    // the cold pass every access hits somewhere. Inclusive's effective
    // capacity is the last level (upper levels are subsets), and a 24-line
    // cyclic walk over a 16-line LRU misses every time (stack distance 23).
    let shape = |policy| {
        HierarchyConfig::uniform(
            vec![
                LevelConfig::new("l1", 4 * 64, 4),
                LevelConfig::new("l2", 8 * 64, 8),
                LevelConfig::new("llc", 16 * 64, 16),
            ],
            64,
            policy,
        )
    };
    const LINES: u64 = 24;
    const PASSES: u64 = 8;

    let mut excl = HierarchyReplay::new(shape(HierarchyPolicy::Exclusive));
    let mut incl = HierarchyReplay::new(shape(HierarchyPolicy::Inclusive));
    for _ in 0..PASSES {
        for l in 0..LINES {
            excl.access(l * 64, false);
            incl.access(l * 64, false);
        }
    }
    assert_eq!(excl.dram_fills(), LINES, "exclusive: cold misses only");
    let e = excl.finalize();
    let total_hits: u64 = e.iter().map(|s| s.hits).sum();
    assert_eq!(total_hits, LINES * (PASSES - 1), "every warm access hits somewhere");
    assert_eq!(
        incl.dram_fills(),
        LINES * PASSES,
        "inclusive: every pass misses the 16-line last level"
    );
}

// ---------------------------------------------------------------------------
// 4. MRC monotonicity on random programs.

#[test]
fn mrc_miss_ratio_is_monotone_in_capacity_on_random_programs() {
    check_seeded("MRC monotone", 0x30_0307, 24, |rng| {
        let p = random_program(rng);
        let tr = profile_traffic(&p, HierarchyPolicy::Inclusive);
        for (i, w) in tr.mrc_miss_ratio.windows(2).enumerate() {
            prop_assert!(
                w[1] <= w[0] + 1e-15,
                "miss ratio increased with capacity at point {i}: {:?}",
                tr.mrc_miss_ratio
            );
        }
        prop_assert!(
            *tr.mrc_misses.last().unwrap() >= tr.cold_misses,
            "largest capacity dipped below the compulsory floor"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 5. DRAM accounting vs the old independent bank.

#[test]
fn hierarchy_dram_bytes_never_exceed_independent_bank_on_suite_kernels() {
    // the acceptance criterion: with the hierarchy enabled, reported DRAM
    // bytes are ≤ the old independent-bank figure on every suite kernel
    // (upper-level hits subtracted, never added)
    for k in pisa_nmc::workloads::registry() {
        let n = pisa_nmc::workloads::scaled_n(k.as_ref(), 0.1);
        let p = k.build(n, 42);
        let tr = profile_traffic(&p, HierarchyPolicy::Inclusive);
        let hier = tr.dram_fill_bytes() + tr.dram_writeback_bytes();
        let old = pisa_nmc::testkit::independent_bank_dram_bytes(&capture_accesses(&p));
        assert!(
            hier <= old,
            "{}: hierarchy DRAM {} B exceeds the old independent-bank figure {} B",
            k.info().name,
            hier,
            old
        );
    }
}

#[test]
fn hierarchy_is_strictly_below_the_bank_when_upper_levels_carry_the_traffic() {
    // crafted trace: one hot line h plus 16 filler lines all mapping to
    // h's LLC set (stride = 2048 lines; 64 L1 sets and 512 L2 sets divide
    // 2048, so they collide at every level). Pattern per cycle:
    // h f1 h f2 ... h f16. Replayed under the *exclusive* policy, h lives
    // only in L1 (in-set reuse distance 1 keeps it off the LRU), so the
    // LLC-side set circulates just the 16 fillers through the aggregate
    // 8+8+16 same-set ways and every warm access hits somewhere — DRAM
    // sees the 17 cold fills and nothing else. (Inclusive would pin h's
    // never-refreshed copy into the LLC by inclusion and thrash exactly
    // like the bank — which is why the policy knob matters.) The
    // independent bank's LLC-shaped cache sees h too: its refreshed copy
    // pins a way, 17 distinct lines cycle through 16 ways, and every
    // filler access misses, forever. The old accounting therefore keeps
    // charging DRAM for traffic a hierarchy absorbs.
    const STRIDE: u64 = 2048; // lines between same-LLC-set addresses
    let base = 0x40_0000u64 / MRC_LINE_BYTES;
    let mut accs: Vec<(u64, u8, bool)> = Vec::new();
    for _ in 0..50 {
        for f in 1..=16u64 {
            accs.push((base * MRC_LINE_BYTES, 8, false)); // h
            accs.push(((base + f * STRIDE) * MRC_LINE_BYTES, 8, false)); // f_i
        }
    }
    let mut h = HierarchyReplay::new(HierarchyConfig::host(HierarchyPolicy::Exclusive));
    for &(addr, _, is_store) in &accs {
        h.access(addr, is_store);
    }
    let hier = (h.dram_fills() + h.dram_writebacks()) * MRC_LINE_BYTES;
    let old = pisa_nmc::testkit::independent_bank_dram_bytes(&accs);
    assert!(
        hier < old / 10,
        "expected an order-of-magnitude gap: hierarchy {hier} B vs bank {old} B"
    );
    // sanity: the default shapes make the collision argument above real
    assert_eq!(HIERARCHY_LEVELS[2].capacity_bytes / MRC_LINE_BYTES / 16, STRIDE);
}

// ---------------------------------------------------------------------------
// 6. `--hierarchy-spec` defaulting ≡ the host chain, all four deliveries.

/// Profile under one of the four deliveries: `None` = per-event, else the
/// given chunked pipeline mode.
fn profile_delivery(
    p: &Program,
    mode: Option<PipelineMode>,
    traffic: TrafficOpts,
) -> Result<TrafficMetrics, String> {
    let req = ProfileRequest::program(p).traffic(traffic);
    let req = match mode {
        Some(m) => req.mode(m),
        None => req.per_event(true),
    };
    req.run_metrics(&RunCtx::new()).map(|m| m.traffic).map_err(|e| e.to_string())
}

const DELIVERIES: [(Option<PipelineMode>, &str); 4] = [
    (None, "per-event"),
    (Some(PipelineMode::Inline), "inline"),
    (Some(PipelineMode::Offload), "offload"),
    (Some(PipelineMode::Sharded { workers: Workers::Auto }), "sharded"),
];

fn assert_traffic_bits_equal(
    a: &TrafficMetrics,
    b: &TrafficMetrics,
    what: &str,
) -> Result<(), String> {
    prop_assert!(a.accesses == b.accesses, "{what}: accesses {} vs {}", a.accesses, b.accesses);
    prop_assert!(a.cold_misses == b.cold_misses, "{what}: cold misses");
    prop_assert!(a.footprint_lines == b.footprint_lines, "{what}: footprint");
    prop_assert!(a.mrc_misses == b.mrc_misses, "{what}: MRC miss counts");
    for (i, (x, y)) in a.mrc_miss_ratio.iter().zip(&b.mrc_miss_ratio).enumerate() {
        prop_assert!(x.to_bits() == y.to_bits(), "{what}: ratio[{i}] {x} vs {y}");
    }
    prop_assert!(a.mrc_knee_bytes == b.mrc_knee_bytes, "{what}: knee");
    prop_assert!(a.hierarchy_policy == b.hierarchy_policy, "{what}: policy label");
    prop_assert!(a.levels == b.levels, "{what}: per-level counters");
    prop_assert!(
        (a.dram_fills, a.dram_writebacks) == (b.dram_fills, b.dram_writebacks),
        "{what}: DRAM ({}, {}) vs ({}, {})",
        a.dram_fills,
        a.dram_writebacks,
        b.dram_fills,
        b.dram_writebacks
    );
    prop_assert!(
        a.read_bytes == b.read_bytes && a.write_bytes == b.write_bytes,
        "{what}: byte totals"
    );
    Ok(())
}

#[test]
fn host_shaped_spec_is_bit_identical_to_the_default_on_all_four_deliveries() {
    // the exact CLI path: serialize the host chain, re-parse the text as a
    // --hierarchy-spec, leak it into the opts. A spec that merely *spells
    // out* the defaults must not perturb a single bit of the metrics.
    let host = HierarchyConfig::host(HierarchyPolicy::default());
    let parsed = HierarchyConfig::from_spec_json(&host.to_json().to_string_compact())
        .expect("the host chain's own serialization must parse as a spec");
    assert_eq!(parsed, host, "spec round-trip must reproduce the host chain exactly");
    let spec: &'static HierarchyConfig = Box::leak(Box::new(parsed));
    check_seeded("host spec == default 4-way", 0x5EC5, 8, |rng| {
        let p = random_program(rng);
        for (mode, what) in DELIVERIES {
            let with_spec =
                profile_delivery(&p, mode, TrafficOpts::default().with_spec(Some(spec)))?;
            let plain = profile_delivery(&p, mode, TrafficOpts::default())?;
            assert_traffic_bits_equal(&with_spec, &plain, what)?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 7. Sweep grid points ≡ standalone replays through the full pipeline.

/// A deliberately heterogeneous DSE grid: a small inclusive chain, an
/// RRIP-fronted variant of the same shape (same aggregate capacity,
/// different replacement — pruning must never conflate them), an
/// exclusive two-level chain, and a no-write-allocate host chain.
fn dse_grid() -> &'static [HierarchyConfig] {
    let mut rrip_l1 = LevelConfig::new("l1", 8 * 64, 2);
    rrip_l1.replacement = ReplacementKind::Rrip;
    let mut no_alloc = HierarchyConfig::host(HierarchyPolicy::Inclusive);
    no_alloc.write_allocate = false;
    Box::leak(
        vec![
            HierarchyConfig::uniform(
                vec![LevelConfig::new("l1", 8 * 64, 2), LevelConfig::new("l2", 64 * 64, 4)],
                64,
                HierarchyPolicy::Inclusive,
            ),
            HierarchyConfig::uniform(
                vec![rrip_l1, LevelConfig::new("l2", 64 * 64, 4)],
                64,
                HierarchyPolicy::Inclusive,
            ),
            HierarchyConfig::uniform(
                vec![LevelConfig::new("l1", 4 * 64, 4), LevelConfig::new("l2", 32 * 64, 8)],
                64,
                HierarchyPolicy::Exclusive,
            ),
            no_alloc,
        ]
        .into_boxed_slice(),
    )
}

/// The sweep differential oracle: every grid point folded through the
/// profile pipeline must carry exactly the counters of a standalone
/// [`HierarchyReplay`] at that config fed the captured stream.
fn assert_sweep_matches_standalone(
    tr: &TrafficMetrics,
    accs: &[(u64, u8, bool)],
    grid: &[HierarchyConfig],
) -> Result<(), String> {
    prop_assert!(
        tr.sweep.len() == grid.len(),
        "sweep carried {} grid points, want {}",
        tr.sweep.len(),
        grid.len()
    );
    for (i, (cfg, got)) in grid.iter().zip(&tr.sweep).enumerate() {
        prop_assert!(got.config == *cfg, "grid point {i} labeled with the wrong config");
        let mut standalone = HierarchyReplay::new(cfg.clone());
        for &(addr, _, is_store) in accs {
            standalone.access(addr, is_store);
        }
        let want = standalone.sweep_counters();
        prop_assert!(
            *got == want,
            "grid point {i} diverged from its standalone replay:\n  swept {:?}\n  want  {:?}",
            got,
            want
        );
    }
    Ok(())
}

#[test]
fn sweep_grid_matches_standalone_replays_on_random_programs() {
    let grid = dse_grid();
    check_seeded("sweep == standalone replays", 0xD5E, 8, |rng| {
        let p = random_program(rng);
        let tr = profile_delivery(&p, None, TrafficOpts::default().with_sweep(Some(grid)))?;
        assert_sweep_matches_standalone(&tr, &capture_accesses(&p), grid)
    });
}

#[test]
fn sweep_grid_matches_standalone_replays_on_a_real_kernel_all_deliveries() {
    // one-pass DSE acceptance: on a multi-chunk real kernel, every grid
    // point is bit-identical to a standalone replay under *all four*
    // deliveries — including sharded, where the sweep rides the `hier`
    // shard group and merges back through the HIERARCHY adopt path
    let grid = dse_grid();
    let k = pisa_nmc::workloads::by_name("gesummv").unwrap();
    let p = k.build(48, 7);
    let accs = capture_accesses(&p);
    assert!(accs.len() > 1000, "want a multi-chunk trace, got {}", accs.len());
    for (mode, what) in DELIVERIES {
        let tr = profile_delivery(&p, mode, TrafficOpts::default().with_sweep(Some(grid)))
            .unwrap_or_else(|e| panic!("{what}: {e}"));
        if let Err(msg) = assert_sweep_matches_standalone(&tr, &accs, grid) {
            panic!("{what}: {msg}");
        }
    }
}
