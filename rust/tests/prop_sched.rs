//! Scheduler and serve-daemon properties (ISSUE 9).
//!
//! The suite scheduler's contract is that `--jobs K` is purely a
//! wall-clock knob: every per-app result must be bit-identical to the
//! sequential run, in the same deterministic registry order, for every
//! delivery mode. Fail-fast must cancel still-queued jobs instead of
//! letting them run, and the `serve` daemon must keep streaming after a
//! bad request, correlating results to submissions by `seq`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pisa_nmc::coordinator::{
    AppOutcome, AppResult, JobSpec, Jobs, OnError, ProfileRequest, RunCtx, Scheduler, ServeCfg,
    Server, SuitePolicy, WorkerBudget,
};
use pisa_nmc::fault::{FaultPlan, SuperviseOpts};
use pisa_nmc::interp::{PipelineMode, Workers};
use pisa_nmc::util::Json;

const SCALE: f64 = 0.05;
const SEED: u64 = 7;

fn fault(spec: &str) -> SuperviseOpts {
    SuperviseOpts::default().with_fault(FaultPlan::from_spec(spec).unwrap())
}

/// Canonical per-app result JSON: wall-clock zeroed, everything else
/// bit-compared (same convention as prop_trace).
fn canon(mut r: AppResult) -> String {
    r.metrics.exec.wall_s = 0.0;
    format!("{}:{}", r.name, r.to_json().to_string_compact())
}

fn suite_canon(mode: PipelineMode, per_event: bool, jobs: Jobs) -> Vec<String> {
    ProfileRequest::suite(SCALE, SEED)
        .mode(mode)
        .per_event(per_event)
        .jobs(jobs)
        .run_apps(&RunCtx::new())
        .unwrap()
        .into_iter()
        .map(canon)
        .collect()
}

#[test]
fn concurrent_suites_are_bit_identical_to_sequential_in_every_delivery() {
    let arms: [(PipelineMode, bool, &str); 4] = [
        (PipelineMode::Inline, true, "per-event"),
        (PipelineMode::Inline, false, "inline"),
        (PipelineMode::Offload, false, "offload"),
        (PipelineMode::Sharded { workers: Workers::Auto }, false, "sharded"),
    ];
    for (mode, per_event, label) in arms {
        let sequential = suite_canon(mode, per_event, Jobs::Fixed(1));
        assert!(!sequential.is_empty(), "{label}: the suite must profile something");
        for jobs in [Jobs::Fixed(2), Jobs::Auto] {
            let parallel = suite_canon(mode, per_event, jobs);
            assert_eq!(sequential, parallel, "{label} suite diverged under --jobs {jobs}");
        }
    }
}

#[test]
fn fail_fast_cancels_queued_jobs_without_running_them() {
    // Park the single job worker on an exhausted budget while all three
    // jobs queue, so the submission order is deterministic: job 0 fails
    // (injected interpreter fault), jobs 1–2 would each stall 5 s *if
    // they ever ran* — fail-fast must cancel them off the queue instead.
    let budget = WorkerBudget::new(1);
    let (sched, rx) = Scheduler::new(1, Arc::clone(&budget), 8, /* fail_fast */ true);
    let gate = budget.acquire(1);
    let mut faulty = JobSpec::kernel("gesummv", 16, 1);
    faulty.sup = fault("interp-error@interp");
    sched.submit(faulty).unwrap();
    for app in ["atax", "bicg"] {
        let mut slow = JobSpec::kernel(app, 16, 1);
        slow.sup = fault("stall:5000@interp");
        sched.submit(slow).unwrap();
    }
    sched.finish();
    let t0 = Instant::now();
    drop(gate);
    let mut kinds: Vec<(u64, String)> = rx
        .iter()
        .take(3)
        .map(|c| {
            let kind = match &c.outcome {
                AppOutcome::Ok(_) => "ok".to_string(),
                AppOutcome::Failed(f) => f.error.kind().to_string(),
            };
            (c.seq, kind)
        })
        .collect();
    let elapsed = t0.elapsed();
    kinds.sort();
    assert_eq!(kinds[0], (0, "interp-error".to_string()), "the faulty job reports its own error");
    assert_eq!(kinds[1], (1, "cancelled".to_string()));
    assert_eq!(kinds[2], (2, "cancelled".to_string()));
    // both stall jobs sleeping would take ≥ 10 s; cancellation is instant
    assert!(elapsed < Duration::from_secs(4), "queued jobs must not run ({elapsed:?})");
}

#[test]
fn suite_policy_failfast_aborts_and_continue_salvages() {
    let sup = fault("interp-error@interp");
    // fail-fast: the first interpreter fault aborts the whole request
    let err = ProfileRequest::suite(SCALE, SEED)
        .policy(SuitePolicy { sup, on_error: OnError::FailFast })
        .jobs(Jobs::Fixed(2))
        .run_apps(&RunCtx::new())
        .unwrap_err();
    assert!(err.to_string().contains("failed"), "{err}");
    // continue: every failure rides along structurally, nothing is lost
    let outcomes = ProfileRequest::suite(SCALE, SEED)
        .policy(SuitePolicy { sup, on_error: OnError::Continue })
        .jobs(Jobs::Auto)
        .outcomes(&RunCtx::new())
        .unwrap();
    assert!(!outcomes.is_empty());
    assert!(
        outcomes.iter().all(|o| matches!(o, AppOutcome::Failed(_))),
        "every app runs under the same injected fault"
    );
}

fn reply_field<'j>(j: &'j Json, key: &str) -> &'j str {
    j.get(key).and_then(|v| v.as_str()).unwrap_or_default()
}

fn reply_seq(j: &Json) -> u64 {
    j.get("seq").and_then(|v| v.as_f64()).expect("reply carries a seq") as u64
}

#[test]
fn serve_loopback_streams_results_and_survives_bad_requests() {
    let cfg = ServeCfg { jobs: Jobs::Fixed(2), ..ServeCfg::default() };
    let server = Server::bind("127.0.0.1:0", cfg, WorkerBudget::machine()).unwrap();
    let addr = server.local_addr().unwrap();
    let flag = server.shutdown_flag();
    let daemon = std::thread::spawn(move || server.run());

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, r#"{{"cmd":"profile","app":"gesummv","n":32,"seed":7}}"#).unwrap();
    writeln!(stream, r#"{{"cmd":"profile","app":"no-such-kernel"}}"#).unwrap();
    writeln!(stream, r#"{{"cmd":"profile","app":"atax","n":32,"seed":7}}"#).unwrap();

    // five replies: two accepted, one typed error (the connection keeps
    // serving), two results — acceptance and result lines interleave
    // freely, so classify by "type" and correlate on "seq"
    let mut accepted: Vec<(u64, String)> = Vec::new();
    let mut results: Vec<(u64, String)> = Vec::new();
    let mut errors = 0;
    for _ in 0..5 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        match reply_field(&j, "type") {
            "accepted" => accepted.push((reply_seq(&j), reply_field(&j, "app").to_string())),
            "result" => {
                let eps = j.get("events_per_sec").and_then(|v| v.as_f64()).unwrap();
                assert!(eps > 0.0, "results report profiler throughput");
                results.push((reply_seq(&j), reply_field(&j, "app").to_string()));
            }
            "error" => errors += 1,
            other => panic!("unexpected reply type '{other}': {line}"),
        }
    }
    assert_eq!(errors, 1, "the unknown kernel gets a typed error and queues nothing");
    accepted.sort();
    results.sort();
    assert_eq!(accepted, vec![(0, "gesummv".to_string()), (1, "atax".to_string())]);
    assert_eq!(results, accepted, "seq metadata must correlate results to submissions");

    // cancel of an already-finished seq is acknowledged, not fatal
    writeln!(stream, r#"{{"cmd":"cancel","seq":0}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(reply_field(&j, "type"), "cancel");
    assert!(line.contains("\"ok\":false"), "a finished job is past cancelling: {line}");

    flag.store(true, Ordering::SeqCst);
    drop(stream);
    drop(reader);
    daemon.join().unwrap().unwrap();
}
