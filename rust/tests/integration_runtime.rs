//! PJRT runtime integration: the AOT JAX/Pallas artifacts must agree with
//! the native Rust analyzers on *real application data* — the strongest
//! cross-layer correctness signal in the repo. Skipped gracefully when
//! `make artifacts` hasn't run.

use pisa_nmc::analysis::profile;
use pisa_nmc::coordinator::{analyze_suite, pca, run_suite, Engine};
use pisa_nmc::runtime::Runtime;
use pisa_nmc::workloads::by_name;

fn runtime() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e:#}");
            None
        }
    }
}

#[test]
fn entropy_artifact_matches_native_on_real_apps() {
    let Some(rt) = runtime() else { return };
    let g = rt.manifest().shape("G").unwrap();
    let b = rt.manifest().shape("B").unwrap();
    for name in ["atax", "bfs", "kmeans"] {
        let k = by_name(name).unwrap();
        let m = profile(&k.build(24, 3)).unwrap();
        let (counts, weights) = m.mem_entropy.to_artifact_inputs(g, b);
        let out = rt.execute("entropy", &[&counts, &weights]).unwrap();
        for (gi, native) in m.mem_entropy.entropies.iter().enumerate() {
            let pjrt = out[0][gi] as f64;
            assert!(
                (pjrt - native).abs() < 1e-3,
                "{name} g={gi}: pjrt {pjrt} vs native {native}"
            );
        }
    }
}

#[test]
fn pca_artifact_matches_native_power_iteration() {
    let Some(rt) = runtime() else { return };
    let n_cap = rt.manifest().shape("N").unwrap();
    // real feature matrix from a mini suite run
    let apps = run_suite(0.08, 5, 8).unwrap();
    let feats: Vec<Vec<f64>> = apps.iter().map(|a| a.metrics.pca4_features().to_vec()).collect();

    let mut x = vec![0f32; n_cap * 4];
    let mut mask = vec![0f32; n_cap];
    for (i, f) in feats.iter().enumerate() {
        mask[i] = 1.0;
        for (j, &v) in f.iter().enumerate() {
            x[i * 4 + j] = v as f32;
        }
    }
    let out = rt.execute("pca4", &[&x, &mask]).unwrap();
    let native = pca(&feats, &vec![true; feats.len()], 2);

    for i in 0..feats.len() {
        for c in 0..2 {
            let p = out[0][i * 2 + c] as f64;
            let nv = native.scores[i][c];
            assert!(
                (p - nv).abs() < 2e-2 * nv.abs().max(1.0),
                "score[{i}][{c}]: pjrt {p} vs native {nv}"
            );
        }
    }
    for (c, ev) in out[3].iter().enumerate() {
        let nv = native.explained_variance_ratio[c];
        assert!(
            (*ev as f64 - nv).abs() < 1e-2,
            "evr[{c}]: pjrt {ev} vs native {nv}"
        );
    }
}

#[test]
fn suite_analytics_pjrt_crosscheck_small() {
    let Some(rt) = runtime() else { return };
    let apps = run_suite(0.08, 9, 8).unwrap();
    let an = analyze_suite(&apps, Some(&rt)).unwrap();
    assert_eq!(an.engine, Engine::Pjrt);
    assert!(
        an.max_crosscheck_err < 1e-2,
        "pjrt/native drift {}",
        an.max_crosscheck_err
    );
    // spatial artifact values close to native exact (binned vs exact means)
    for (i, a) in apps.iter().enumerate() {
        for (s_pjrt, s_native) in an.spatial[i].iter().zip(&a.metrics.spatial.scores) {
            assert!(
                (s_pjrt - s_native).abs() < 0.12,
                "{}: spatial pjrt {s_pjrt} vs native {s_native}",
                a.name
            );
        }
    }
}

#[test]
fn model_artifact_runs_fused_suite() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().get("model").unwrap();
    let inputs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let len = s.iter().product::<usize>().max(1);
            match i {
                1 | 5 => vec![1.0; len], // weights, mask
                _ => vec![0.5; len],
            }
        })
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let out = rt.execute("model", &refs).unwrap();
    assert_eq!(out.len(), 8, "analysis_suite ABI is 8 outputs");
    for (i, o) in out.iter().enumerate() {
        assert!(o.iter().all(|v| v.is_finite()), "output {i} non-finite");
    }
}
