//! Property tests over the analyzers: every streaming metric checked
//! against an independent (naive) oracle on randomized traces, plus
//! determinism and bound invariants.

use std::collections::HashMap;

use pisa_nmc::analysis::{self, MemEntropyAnalyzer, ReuseAnalyzer};
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{address_trace, check, check_seeded, usize_in};
use pisa_nmc::util::stats::shannon_entropy_counts;
use pisa_nmc::util::Rng;

/// O(n²) exact stack-distance oracle with the analyzer's cold-miss
/// convention (distance = prior footprint).
fn naive_mean_dtr(addrs: &[u64], shift: u8) -> f64 {
    let mut stack: Vec<u64> = Vec::new();
    let mut sum = 0.0;
    for &a in addrs {
        let line = a >> shift;
        if let Some(pos) = stack.iter().position(|&l| l == line) {
            sum += (stack.len() - 1 - pos) as f64;
            stack.remove(pos);
        } else {
            sum += stack.len() as f64;
        }
        stack.push(line);
    }
    sum / addrs.len() as f64
}

#[test]
fn reuse_distance_matches_naive_oracle() {
    check_seeded("reuse vs naive", 0xBEEF, 24, |rng| {
        let len = usize_in(rng, 10, 600);
        let span = 1 + rng.below(512);
        let addrs = address_trace(rng, len, span);
        let mut a = ReuseAnalyzer::new();
        for &ad in &addrs {
            a.record(ad);
        }
        let r = a.finalize();
        for (li, &shift) in analysis::reuse::LINE_SHIFTS.iter().enumerate() {
            let want = naive_mean_dtr(&addrs, shift);
            prop_assert!(
                (r.avg_dtr[li] - want).abs() < 1e-9,
                "shift {shift}: got {} want {want}",
                r.avg_dtr[li]
            );
        }
        Ok(())
    });
}

#[test]
fn mem_entropy_fold_matches_naive_at_every_granularity() {
    check_seeded("entropy fold vs naive", 0xE27, 24, |rng| {
        let len = usize_in(rng, 5, 2000);
        let addrs = address_trace(rng, len, 1 << 12);
        let mut an = MemEntropyAnalyzer::new();
        for &a in &addrs {
            an.record(a);
        }
        let r = an.finalize(4096);
        for shift in 0u8..=10 {
            let mut h: HashMap<u64, u64> = HashMap::new();
            for &a in &addrs {
                *h.entry(a >> shift).or_insert(0) += 1;
            }
            let want = shannon_entropy_counts(h.values().copied());
            prop_assert!(
                (r.entropies[shift as usize] - want).abs() < 1e-9,
                "shift {shift}: got {} want {want}",
                r.entropies[shift as usize]
            );
        }
        Ok(())
    });
}

#[test]
fn count_of_counts_reconstructs_exact_entropy() {
    check("count-of-counts identity", |rng| {
        let len = usize_in(rng, 10, 3000);
        let addrs = address_trace(rng, len, 1 << 10);
        let mut an = MemEntropyAnalyzer::new();
        for &a in &addrs {
            an.record(a);
        }
        let r = an.finalize(4096);
        for (g, pairs) in r.count_of_counts.iter().enumerate() {
            let total: u64 = pairs.iter().map(|&(c, m)| c as u64 * m).sum();
            if total == 0 {
                continue;
            }
            let h: f64 = -pairs
                .iter()
                .map(|&(c, m)| {
                    let p = c as f64 / total as f64;
                    m as f64 * p * p.log2()
                })
                .sum::<f64>();
            prop_assert!(
                (h - r.entropies[g]).abs() < 1e-9,
                "g={g}: coc {h} vs exact {}",
                r.entropies[g]
            );
        }
        Ok(())
    });
}

#[test]
fn entropy_never_increases_with_coarser_granularity() {
    check("entropy monotone in granularity", |rng| {
        let len = usize_in(rng, 10, 2000);
        let addrs = address_trace(rng, len, 1 << 14);
        let mut an = MemEntropyAnalyzer::new();
        for &a in &addrs {
            an.record(a);
        }
        let r = an.finalize(4096);
        for w in r.entropies.windows(2) {
            prop_assert!(w[1] <= w[0] + 1e-9, "coarser granularity raised entropy: {w:?}");
        }
        Ok(())
    });
}

#[test]
fn spatial_scores_always_in_unit_interval() {
    check("spatial in [0,1]", |rng| {
        let len = usize_in(rng, 10, 1500);
        let addrs = address_trace(rng, len, 1 << 16);
        let mut a = ReuseAnalyzer::new();
        for &ad in &addrs {
            a.record(ad);
        }
        let s = pisa_nmc::analysis::spatial::from_reuse(&a.finalize());
        for v in &s.scores {
            prop_assert!((0.0..=1.0).contains(v), "score {v} out of range");
        }
        Ok(())
    });
}

#[test]
fn profile_is_deterministic_for_fixed_seed() {
    check_seeded("deterministic profiling", 0xD0, 6, |rng| {
        let names = ["atax", "mvt", "kmeans", "bfs"];
        let name = names[usize_in(rng, 0, names.len() - 1)];
        let n = usize_in(rng, 8, 24);
        let k = pisa_nmc::workloads::by_name(name).map_err(|e| e.to_string())?;
        let a = analysis::profile(&k.build(n, 7)).map_err(|e| e.to_string())?;
        let b = analysis::profile(&k.build(n, 7)).map_err(|e| e.to_string())?;
        prop_assert!(a.exec.dyn_instrs == b.exec.dyn_instrs, "instr counts differ");
        prop_assert!(
            a.mem_entropy.entropies == b.mem_entropy.entropies,
            "entropies differ"
        );
        prop_assert!(a.pca4_features() == b.pca4_features(), "features differ");
        Ok(())
    });
}

#[test]
fn parallelism_metrics_are_finite_and_at_least_one() {
    check_seeded("parallelism bounds", 0x1B, 8, |rng| {
        let names = ["gesummv", "trmm", "bp"];
        let name = names[usize_in(rng, 0, names.len() - 1)];
        let n = usize_in(rng, 6, 20);
        let k = pisa_nmc::workloads::by_name(name).map_err(|e| e.to_string())?;
        let m = analysis::profile(&k.build(n, rng.next_u64())).map_err(|e| e.to_string())?;
        prop_assert!(m.ilp.inf >= 1.0, "ILP {} < 1", m.ilp.inf);
        prop_assert!(m.dlp.dlp >= 0.99, "DLP {} < 1", m.dlp.dlp);
        prop_assert!(m.pbblp.pbblp >= 0.99, "PBBLP {}", m.pbblp.pbblp);
        for v in &m.bblp.values {
            prop_assert!(v.is_finite() && *v >= 0.99, "BBLP {v}");
        }
        Ok(())
    });
}

#[test]
fn windowed_ilp_never_exceeds_count() {
    check_seeded("ILP sanity", 0x11F, 8, |rng| {
        let n = usize_in(rng, 6, 24);
        let k = pisa_nmc::workloads::by_name("atax").map_err(|e| e.to_string())?;
        let m = analysis::profile(&k.build(n, rng.next_u64())).map_err(|e| e.to_string())?;
        for (w, v) in &m.ilp.windowed {
            prop_assert!(*v <= *w as f64 + 1e-9, "ILP_{w} = {v} exceeds window");
        }
        prop_assert!(
            m.ilp.inf <= m.exec.dyn_instrs as f64,
            "ILP_inf exceeds trace length"
        );
        Ok(())
    });
}
