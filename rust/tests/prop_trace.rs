//! Trace record/replay properties: interpret → serialize (`TraceWriter`) →
//! decode (`TraceReader`) → analyze must be **bit-identical** to analyzing
//! the live interpreter stream, for seeded random programs and real suite
//! kernels, under every delivery mode (per-event, chunked, offload,
//! sharded). Metrics are compared through the serialized `AppMetrics` JSON
//! with the wall clock zeroed, so every analyzer surface — pca8 features,
//! histograms, MRC/hierarchy counters, parallelism families — participates
//! in the equality.
//!
//! The corruption matrix then damages a recorded file byte-by-byte
//! (magic flip, version bump, mid-frame truncation, checksum flip) and
//! asserts each case surfaces the matching typed [`TraceError`] — never a
//! panic — while a recording killed by an injected interpreter fault must
//! leave a well-formed prefix: every complete frame replays, then the
//! missing footer reports as `Truncated`.

use std::fs;
use std::path::{Path, PathBuf};

use pisa_nmc::analysis::{
    profile_source_opts, profile_source_per_event, AppMetrics, MetricSet,
};
use pisa_nmc::coordinator::{ProfileRequest, RunCtx};
use pisa_nmc::fault::{FaultPlan, SuperviseOpts};
use pisa_nmc::interp::{EventChunk, Machine, PipelineMode, Workers};
use pisa_nmc::ir::Program;
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{check_seeded, random_program};
use pisa_nmc::trace::{
    required_lanes, ChunkStatus, TraceError, TraceLanes, TraceMeta, TraceReader, TraceSource,
    TraceWriter,
};
use pisa_nmc::traffic::TrafficOpts;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pisa-prop-trace-{}-{tag}.pallas-trace", std::process::id()))
}

/// Interpret `prog` once with the trace writer as the only sink, producing
/// a finished (footer-bearing) recording at a fresh temp path.
fn record(prog: &Program, app: &str, tag: &str, lanes: TraceLanes) -> PathBuf {
    let path = tmp_path(tag);
    let mut machine = Machine::new(prog).unwrap();
    let meta = TraceMeta { app: app.to_string(), n: 0, seed: 0 };
    let mut w = TraceWriter::create(&path, meta, machine.chunk_capacity(), lanes).unwrap();
    machine.run(&mut w).unwrap();
    w.finish().unwrap();
    path
}

/// Canonical form for exact comparison: the full `AppMetrics` JSON with the
/// only legitimately run-dependent field (wall clock) zeroed. String
/// equality here is bit equality of every metric surface.
fn canon(mut m: AppMetrics) -> String {
    m.exec.wall_s = 0.0;
    m.to_json().to_string_compact()
}

const REPLAY_MODES: [PipelineMode; 3] = [
    PipelineMode::Inline,
    PipelineMode::Offload,
    PipelineMode::Sharded { workers: Workers::Auto },
];

/// Decode every frame of `path`, returning the terminal result plus how
/// many chunks/events were successfully delivered before it.
fn drain(path: &Path) -> (anyhow::Result<()>, u64, u64) {
    let mut r = match TraceReader::open(path) {
        Ok(r) => r,
        Err(e) => return (Err(e), 0, 0),
    };
    let mut chunk = EventChunk::with_capacity(r.chunk_capacity());
    loop {
        match r.next_chunk(&mut chunk) {
            Ok(ChunkStatus::Delivered) => {}
            Ok(ChunkStatus::Done) => {
                let pv = r.provenance();
                return (Ok(()), pv.chunks, pv.events);
            }
            Err(e) => {
                let pv = r.provenance();
                return (Err(e), pv.chunks, pv.events);
            }
        }
    }
}

#[test]
fn round_trip_is_bit_identical_on_real_kernels() {
    for (name, n) in [("gesummv", 24), ("bfs", 24)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 7);
        let all = MetricSet::all();
        let opts = TrafficOpts::default();
        let direct = canon(
            ProfileRequest::program(&p)
                .metrics(all)
                .per_event(true)
                .traffic(opts)
                .run_metrics(&RunCtx::new())
                .unwrap(),
        );
        let path = record(&p, name, &format!("kern-{name}"), TraceLanes::ALL);
        for mode in REPLAY_MODES {
            let mut r = TraceReader::open(&path).unwrap();
            let replayed = profile_source_opts(&p, &mut r, all, mode, opts).unwrap();
            assert_eq!(
                canon(replayed),
                direct,
                "{name}: {} replay diverged from direct per-event analysis",
                mode.name()
            );
        }
        let mut r = TraceReader::open(&path).unwrap();
        let replayed = profile_source_per_event(&p, &mut r, all, opts).unwrap();
        assert_eq!(canon(replayed), direct, "{name}: per-event replay diverged");
        fs::remove_file(&path).unwrap();
    }
}

#[test]
fn round_trip_is_bit_identical_on_random_programs() {
    check_seeded("trace round-trip", 0x7AC3, 12, |rng| {
        let p = random_program(rng);
        let all = MetricSet::all();
        let opts = TrafficOpts::default();
        let direct = canon(
            ProfileRequest::program(&p)
                .metrics(all)
                .traffic(opts)
                .run_metrics(&RunCtx::new())
                .map_err(|e| e.to_string())?,
        );
        let path = record(&p, "random", "rand", TraceLanes::ALL);
        for mode in REPLAY_MODES {
            let mut r = TraceReader::open(&path).map_err(|e| e.to_string())?;
            let replayed =
                profile_source_opts(&p, &mut r, all, mode, opts).map_err(|e| e.to_string())?;
            prop_assert!(
                canon(replayed) == direct,
                "{} replay diverged from direct analysis",
                mode.name()
            );
        }
        fs::remove_file(&path).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn corruption_yields_typed_errors_never_panics() {
    let k = pisa_nmc::workloads::by_name("gesummv").unwrap();
    let p = k.build(16, 3);
    let path = record(&p, "gesummv", "corrupt", TraceLanes::ALL);
    let good = fs::read(&path).unwrap();
    fs::remove_file(&path).unwrap();
    // magic(8) version(2) lanes(2) cap(4) n(8) seed(8) name_len(4) name
    let header_len = 8 + 2 + 2 + 4 + 8 + 8 + 4 + "gesummv".len();
    assert!(good.len() > header_len + 16, "recording implausibly small");

    let check = |tag: &str, bytes: Vec<u8>, want: fn(&TraceError) -> bool, what: &str| {
        let cpath = tmp_path(tag);
        fs::write(&cpath, bytes).unwrap();
        let (res, _, _) = drain(&cpath);
        fs::remove_file(&cpath).unwrap();
        let err = res.expect_err("corrupted trace must not decode cleanly");
        match err.downcast_ref::<TraceError>() {
            Some(te) if want(te) => {}
            other => panic!("{tag}: expected {what}, got {other:?} ({err:#})"),
        }
    };

    let mut b = good.clone();
    b[0] ^= 0xFF;
    check("bad-magic", b, |e| matches!(e, TraceError::BadMagic), "BadMagic");

    let mut b = good.clone();
    b[8] = b[8].wrapping_add(1); // version u16 LE at offset 8
    check(
        "bad-version",
        b,
        |e| matches!(e, TraceError::VersionMismatch { found: 2, supported: 1 }),
        "VersionMismatch{found: 2}",
    );

    // cut mid-frame: complete frames before the cut still deliver
    let cut = good[..header_len + 6].to_vec();
    check("truncated", cut, |e| matches!(e, TraceError::Truncated { .. }), "Truncated");

    // flip the last byte of the footer's checksum block (slot 5 = blocks
    // lane); frames all decode, the footer check reports the lane
    let mut b = good.clone();
    let i = b.len() - 9; // …checksums(48) | end magic(8)
    b[i] ^= 0xFF;
    check(
        "bad-checksum",
        b,
        |e| matches!(e, TraceError::ChecksumMismatch { lane: "blocks", .. }),
        "ChecksumMismatch{lane: blocks}",
    );
}

#[test]
fn truncated_file_still_delivers_complete_frames() {
    // big enough for several chunk flushes, cut just before the footer
    let k = pisa_nmc::workloads::by_name("gesummv").unwrap();
    let p = k.build(24, 7);
    let path = record(&p, "gesummv", "trunc-tail", TraceLanes::ALL);
    let bytes = fs::read(&path).unwrap();
    fs::remove_file(&path).unwrap();
    let (ok, chunks, events) = {
        let cpath = tmp_path("trunc-tail-full");
        fs::write(&cpath, &bytes).unwrap();
        let out = drain(&cpath);
        fs::remove_file(&cpath).unwrap();
        out
    };
    ok.unwrap();
    assert!(chunks >= 1 && events > 0);

    // footer is 4 + 16 + 48 + 8 = 76 bytes; removing the last 80 leaves
    // every frame intact but the footer unreadable
    let cpath = tmp_path("trunc-tail-cut");
    fs::write(&cpath, &bytes[..bytes.len() - 80]).unwrap();
    let (res, got_chunks, got_events) = drain(&cpath);
    fs::remove_file(&cpath).unwrap();
    let err = res.expect_err("footer-less trace must not decode cleanly");
    assert!(
        matches!(err.downcast_ref::<TraceError>(), Some(TraceError::Truncated { .. })),
        "expected Truncated, got {err:#}"
    );
    assert_eq!(
        (got_chunks, got_events),
        (chunks, events),
        "every complete frame must be delivered before the truncation error"
    );
}

#[test]
fn crashed_recording_leaves_wellformed_prefix() {
    // a loop long enough for several chunk flushes before the injected
    // interpreter fault at chunk boundary 2 kills the run
    use pisa_nmc::ir::ProgramBuilder;
    let mut b = ProgramBuilder::new("stress");
    let a = b.alloc_f64("a", 256);
    let len = b.const_i(256);
    let n = b.const_i(40_000);
    b.counted_loop(n, |b, i| {
        let idx = b.rem(i, len);
        let v = b.load_f64(a, idx);
        let w = b.fadd(v, v);
        b.store_f64(a, idx, w);
    });
    let p = b.finish(None);

    let path = tmp_path("fault");
    let mut machine = Machine::new(&p).unwrap();
    let meta = TraceMeta { app: "stress".to_string(), n: 0, seed: 0 };
    let mut w =
        TraceWriter::create(&path, meta, machine.chunk_capacity(), TraceLanes::ALL).unwrap();
    let fault = FaultPlan::from_spec("interp-error@interp:2").unwrap();
    let res = machine.run_supervised(&mut w, SuperviseOpts::default().with_fault(fault));
    assert!(res.is_err(), "injected interpreter fault must surface");
    drop(w); // no finish(): the crashed-recording signature is a missing footer

    let (res, chunks, events) = drain(&path);
    fs::remove_file(&path).unwrap();
    assert!(
        chunks >= 2 && events > 0,
        "complete frames before the fault must replay (got {chunks} chunks, {events} events)"
    );
    let err = res.expect_err("missing footer must surface as an error");
    match err.downcast_ref::<TraceError>() {
        Some(TraceError::Truncated { what }) => {
            assert_eq!(*what, "missing footer", "clean EOF at a frame boundary");
        }
        other => panic!("expected Truncated, got {other:?} ({err:#})"),
    }
}

#[test]
fn replaying_lane_starved_trace_names_missing_families() {
    let k = pisa_nmc::workloads::by_name("gesummv").unwrap();
    let p = k.build(16, 3);
    let mix_only = MetricSet::from_names("mix").unwrap();
    let lanes = required_lanes(mix_only);
    assert_eq!(lanes, TraceLanes::TAGS, "mix needs only the op-tag lane");
    let path = record(&p, "gesummv", "mix-only", lanes);

    // replaying the full metric set against a tags-only recording must
    // fail at plan time, naming the starved families and the lanes
    let mut r = TraceReader::open(&path).unwrap();
    let err = profile_source_opts(
        &p,
        &mut r,
        MetricSet::all(),
        PipelineMode::Inline,
        TrafficOpts::default(),
    )
    .unwrap_err();
    match err.downcast_ref::<TraceError>() {
        Some(TraceError::MissingLanes { families, missing }) => {
            assert!(families.iter().any(|f| f == "traffic"), "families: {families:?}");
            assert!(missing.contains(TraceLanes::ADDRS));
            assert!(!missing.contains(TraceLanes::TAGS), "tags are recorded");
        }
        other => panic!("expected MissingLanes, got {other:?} ({err:#})"),
    }

    // the selection the recording was made for still replays bit-identically
    let direct = canon(
        ProfileRequest::program(&p)
            .metrics(mix_only)
            .per_event(true)
            .run_metrics(&RunCtx::new())
            .unwrap(),
    );
    let mut r = TraceReader::open(&path).unwrap();
    let replayed = profile_source_opts(
        &p,
        &mut r,
        mix_only,
        PipelineMode::Inline,
        TrafficOpts::default(),
    )
    .unwrap();
    assert_eq!(canon(replayed), direct, "mix-only replay diverged");
    fs::remove_file(&path).unwrap();
}
