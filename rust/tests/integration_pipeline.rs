//! End-to-end pipeline integration: suite profiling → native analytics →
//! figure data → JSON report, plus the paper-shape assertions that are
//! robust at reduced scale.

use pisa_nmc::coordinator::{analyze_suite, figures, run_pipeline, run_suite, Engine};
use pisa_nmc::util::Json;

fn app<'a>(
    apps: &'a [pisa_nmc::coordinator::AppResult],
    name: &str,
) -> &'a pisa_nmc::coordinator::AppResult {
    apps.iter().find(|a| a.name == name).unwrap()
}

#[test]
fn pipeline_native_end_to_end() {
    let report = run_pipeline(0.12, 42, 8, None).unwrap();
    assert_eq!(report.apps.len(), 12);
    assert_eq!(report.analytics.engine, Engine::Native);

    // every app produced finite, plausible metrics
    for a in &report.apps {
        assert!(a.metrics.exec.dyn_instrs > 1000, "{}", a.name);
        assert!(a.metrics.mem_entropy.entropies[0] > 1.0, "{}", a.name);
        assert!(a.cmp.edp_improvement() > 0.0, "{}", a.name);
        assert!(a.cmp.host.time_s > 0.0 && a.cmp.nmc.time_s > 0.0, "{}", a.name);
        for f in a.metrics.pca4_features() {
            assert!(f.is_finite(), "{}: non-finite feature", a.name);
        }
    }

    // the traffic family rode the same pass: bytes + a populated MRC +
    // the hierarchy replay's per-level counters
    for a in &report.apps {
        let tr = &a.metrics.traffic;
        assert!(tr.accesses > 0, "{}", a.name);
        assert_eq!(tr.reads + tr.writes, tr.accesses, "{}", a.name);
        assert!(tr.bytes_per_instr() > 0.0, "{}", a.name);
        assert_eq!(tr.mrc_misses.len(), tr.mrc_capacities.len(), "{}", a.name);
        assert!(tr.mrc_miss_ratio[0] > 0.0, "{}: cold misses imply a nonzero curve", a.name);
        // hierarchy filtering: L1 saw everything, each level below saw
        // exactly the level above's misses, DRAM only what crossed the LLC
        assert_eq!(tr.levels[0].hits + tr.levels[0].misses, tr.accesses, "{}", a.name);
        for w in tr.levels.windows(2) {
            assert_eq!(w[0].misses, w[1].hits + w[1].misses, "{}", a.name);
        }
        assert_eq!(tr.dram_fills, tr.llc().unwrap().misses, "{}", a.name);
        assert!(
            tr.dram_fill_bytes() + tr.dram_writeback_bytes()
                <= (tr.accesses + tr.levels.last().unwrap().writebacks)
                    * pisa_nmc::traffic::MRC_LINE_BYTES,
            "{}",
            a.name
        );
    }

    // figure renderers produce content for all 12 apps
    let (t3a, _) = figures::fig3a(&report.apps, &report.analytics, report.metrics);
    let (t6, _) = figures::fig6(&report.apps, &report.analytics, report.metrics);
    let (tmrc, _) = figures::fig_mrc(&report.apps, report.metrics);
    for a in &report.apps {
        assert!(t3a.contains(&a.name), "fig3a missing {}", a.name);
        assert!(t6.contains(&a.name), "fig6 missing {}", a.name);
        assert!(tmrc.contains(&a.name), "fig_mrc missing {}", a.name);
    }

    // JSON report is parseable and carries all figures + the hierarchy
    let j = report.to_json();
    let pretty = j.to_string_pretty();
    let reparsed = Json::parse(&pretty).expect("valid JSON");
    for key in ["fig3a", "fig3b", "fig3c", "fig4", "fig5", "fig6", "fig_mrc", "apps"] {
        assert!(reparsed.get(key).is_some(), "report missing {key}");
    }
    assert_eq!(
        reparsed.get("hierarchy_policy").and_then(|v| v.as_str()),
        Some("inclusive"),
        "report must carry the hierarchy policy"
    );
    assert_eq!(
        reparsed.get("mrc_mode").and_then(|v| v.as_str()),
        Some("exact"),
        "report must carry the MRC mode"
    );
    assert!(reparsed.get("mrc_rate").is_some(), "report must carry the MRC sample rate");
    for key in ["\"hierarchy\"", "\"levels\"", "\"writebacks\"", "\"fills\""] {
        assert!(pretty.contains(key), "per-level traffic JSON missing {key}");
    }
}

#[test]
fn characterization_shape_vs_paper() {
    // The platform-independent metric *shape* claims of §IV-A hold even at
    // reduced scale (they are properties of access patterns, not sizes).
    let apps = run_suite(0.25, 42, 8).unwrap();
    let an = analyze_suite(&apps, None).unwrap();

    let idx = |name: &str| apps.iter().position(|a| a.name == name).unwrap();

    // gramschmidt has the lowest mean spatial locality (paper Fig 3b)
    let mean_spat: Vec<f64> = an
        .spatial
        .iter()
        .map(|s| s.iter().sum::<f64>() / s.len() as f64)
        .collect();
    let gs = mean_spat[idx("gramschmidt")];
    let below: usize = mean_spat.iter().filter(|&&v| v < gs).count();
    assert!(
        below <= 2,
        "gramschmidt should be among the 3 least spatially-local: {mean_spat:?}"
    );

    // bfs has the lowest DLP (paper: "bfs ... has the lowest DLP values")
    let dlp: Vec<f64> = apps.iter().map(|a| a.metrics.dlp.dlp).collect();
    let bfs_dlp = dlp[idx("bfs")];
    let lower: usize = dlp.iter().filter(|&&v| v < bfs_dlp).count();
    assert!(lower <= 1, "bfs should have (nearly) the lowest DLP: {dlp:?}");

    // data-parallel kernels show larger PBBLP than factorization kernels.
    // (PBBLP is iteration-weighted, so kernels dominated by serial inner
    // reductions — mvt's dot products — sit near 2 even though their outer
    // loops are parallel; bp's parallel 16-wide inner update lifts it.)
    assert!(app(&apps, "bp").metrics.pbblp.pbblp > 5.0);
    assert!(app(&apps, "mvt").metrics.pbblp.pbblp > 1.5);
    assert!(app(&apps, "cholesky").metrics.pbblp.pbblp < 5.0);
    assert!(
        app(&apps, "bp").metrics.pbblp.pbblp > app(&apps, "cholesky").metrics.pbblp.pbblp,
        "bp must out-parallel cholesky"
    );

    // memory entropy is within [0, log2(footprint)] and nonzero everywhere
    for (i, a) in apps.iter().enumerate() {
        let h0 = an.entropies[i][0];
        let bound = (a.metrics.mem_entropy.unique_addrs as f64).log2() + 1e-9;
        assert!(h0 > 0.0 && h0 <= bound, "{}: H={h0} bound={bound}", a.name);
    }
}

#[test]
fn tables_render_paper_rows() {
    let t1 = figures::table1();
    for needle in ["Power9", "32 single-issue", "HMC", "8 stacked layers", "32 vaults"] {
        assert!(t1.contains(needle), "table1 missing {needle}");
    }
    let t2 = figures::table2(1.0);
    for needle in ["atax", "8000", "2000", "1.0m", "1.1m", "819k", "kmeans"] {
        assert!(t2.contains(needle), "table2 missing {needle}");
    }
}

#[test]
fn scale_changes_problem_size_not_structure() {
    let small = run_suite(0.08, 7, 8).unwrap();
    let larger = run_suite(0.16, 7, 8).unwrap();
    for (s, l) in small.iter().zip(&larger) {
        assert_eq!(s.name, l.name);
        assert!(
            l.metrics.exec.dyn_instrs > s.metrics.exec.dyn_instrs,
            "{}: scale did not grow work",
            s.name
        );
    }
}
