//! Property tests for the SHARDS-style sampled MRC (`--mrc sampled:<rate>`).
//!
//! 1. **Exact mode is unchanged**: `--mrc exact` (the default
//!    `TrafficOpts`) is bit-identical to the plain pre-sampling entry
//!    points on every delivery path — including sharded delivery, where
//!    the traffic family is now split into MRC and hierarchy halves.
//! 2. **Rate 1.0 is an exactness oracle**: `sampled:1.0` samples every
//!    line with weight exactly 1.0, so the estimator must reproduce the
//!    exact curve bit for bit end-to-end through the profile pipeline.
//!    This pins the plumbing on seeded random programs whose footprints
//!    are far too small for statistical bounds.
//! 3. **Error bound**: at rate 0.1 on traces with thousands of distinct
//!    lines (synthetic address traces, `gesummv`, `bfs`), the mean
//!    absolute miss-ratio error across all 8 capacity points stays ≤ 0.02.
//! 4. **Fixed-size variant**: never exceeds `S_max` resident lines and
//!    only ever lowers its rate.
//! 5. **Sampled mode is deterministic across deliveries**: the spatial
//!    hash makes the sample a pure function of the line address, so
//!    per-event / chunked / offload / sharded all agree bitwise.

use pisa_nmc::analysis::{profile, profile_per_event, AppMetrics, MetricSet};
use pisa_nmc::coordinator::{ProfileRequest, RunCtx};
use pisa_nmc::interp::{PipelineMode, Workers};
use pisa_nmc::ir::Program;
use pisa_nmc::prop_assert;
use pisa_nmc::testkit::{address_trace, check_seeded, random_program};
use pisa_nmc::traffic::{
    mrc::MRC_LINE_SHIFT, MrcBuilder, MrcMode, SampledMrc, TrafficMetrics, TrafficOpts,
    N_MRC_POINTS,
};
use pisa_nmc::util::Rng;

/// Opts-threaded profiling via the consolidated request builder (the
/// positional `profile_opts`/`profile_per_event_opts` are deprecated).
fn profile_req(
    p: &Program,
    metrics: MetricSet,
    mode: PipelineMode,
    traffic: TrafficOpts,
) -> anyhow::Result<AppMetrics> {
    ProfileRequest::program(p)
        .metrics(metrics)
        .mode(mode)
        .traffic(traffic)
        .run_metrics(&RunCtx::new())
}

fn profile_req_pe(
    p: &Program,
    metrics: MetricSet,
    traffic: TrafficOpts,
) -> anyhow::Result<AppMetrics> {
    ProfileRequest::program(p)
        .metrics(metrics)
        .per_event(true)
        .traffic(traffic)
        .run_metrics(&RunCtx::new())
}

fn assert_traffic_bits_equal(a: &TrafficMetrics, b: &TrafficMetrics, what: &str) {
    assert_eq!(a.accesses, b.accesses, "{what}: accesses");
    assert_eq!(a.cold_misses, b.cold_misses, "{what}: cold misses");
    assert_eq!(a.footprint_lines, b.footprint_lines, "{what}: footprint");
    assert_eq!(a.mrc_misses, b.mrc_misses, "{what}: miss counts");
    for (i, (x, y)) in a.mrc_miss_ratio.iter().zip(&b.mrc_miss_ratio).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: ratio[{i}] {x} vs {y}");
    }
    assert_eq!(a.mrc_knee_bytes, b.mrc_knee_bytes, "{what}: knee");
    assert_eq!(a.dram_fills, b.dram_fills, "{what}: dram fills");
    assert_eq!(a.dram_writebacks, b.dram_writebacks, "{what}: writebacks");
    assert_eq!(a.read_bytes, b.read_bytes, "{what}: read bytes");
    assert_eq!(a.write_bytes, b.write_bytes, "{what}: write bytes");
}

// ---------------------------------------------------------------------------
// 1. `--mrc exact` ≡ the pre-sampling kernel, on all four deliveries.

#[test]
fn exact_mode_is_bit_identical_to_the_pre_sampling_kernel() {
    check_seeded("exact == pre-sampling 4-way", 0x5A3D, 10, |rng| {
        let p = random_program(rng);
        let all = MetricSet::all();
        let exact = TrafficOpts::default();
        // the historical entry points (no TrafficOpts anywhere)
        let legacy = profile(&p).map_err(|e| e.to_string())?;
        let legacy_pe = profile_per_event(&p).map_err(|e| e.to_string())?;
        // the opts-threaded request builder, in explicit exact mode
        let inline =
            profile_req(&p, all, PipelineMode::Inline, exact).map_err(|e| e.to_string())?;
        let per_event = profile_req_pe(&p, all, exact).map_err(|e| e.to_string())?;
        let offload =
            profile_req(&p, all, PipelineMode::Offload, exact).map_err(|e| e.to_string())?;
        let sharded =
            profile_req(&p, all, PipelineMode::Sharded { workers: Workers::Auto }, exact)
                .map_err(|e| e.to_string())?;
        prop_assert!(inline.traffic.mrc_mode == MrcMode::Exact, "default mode must be exact");
        for (got, want, what) in [
            (&inline, &legacy, "inline"),
            (&per_event, &legacy_pe, "per-event"),
            (&offload, &legacy, "offload"),
            // the split-traffic sharded path against the unsplit inline
            (&sharded, &legacy, "sharded vs inline"),
        ] {
            assert_traffic_bits_equal(&got.traffic, &want.traffic, what);
            let (pa, pb) = (got.pca8_features(), want.pca8_features());
            for (i, (x, y)) in pa.iter().zip(&pb).enumerate() {
                prop_assert!(x.to_bits() == y.to_bits(), "{what}: pca8[{i}] {x} vs {y}");
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Rate 1.0 reproduces the exact curve bit for bit.

#[test]
fn sampled_rate_one_reproduces_exact_through_the_full_pipeline() {
    check_seeded("sampled:1.0 == exact", 0x10_F1, 10, |rng| {
        let p = random_program(rng);
        let all = MetricSet::all();
        let exact = profile_req(&p, all, PipelineMode::Inline, TrafficOpts::default())
            .map_err(|e| e.to_string())?;
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 1.0 });
        let sampled =
            profile_req(&p, all, PipelineMode::Inline, opts).map_err(|e| e.to_string())?;
        let (a, b) = (&exact.traffic, &sampled.traffic);
        prop_assert!(b.mrc_mode == MrcMode::Sampled { rate: 1.0 }, "mode must be recorded");
        prop_assert!(
            b.mrc_sampled_accesses == b.accesses,
            "rate 1.0 must sample every access"
        );
        prop_assert!(a.cold_misses == b.cold_misses, "cold misses diverge");
        prop_assert!(a.footprint_lines == b.footprint_lines, "footprints diverge");
        prop_assert!(a.mrc_misses == b.mrc_misses, "miss counts diverge");
        prop_assert!(a.mrc_knee_bytes == b.mrc_knee_bytes, "knees diverge");
        for (i, (x, y)) in a.mrc_miss_ratio.iter().zip(&b.mrc_miss_ratio).enumerate() {
            prop_assert!(x.to_bits() == y.to_bits(), "ratio[{i}] {x} vs {y}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. Error bound at rate 0.1 on statistically meaningful footprints.

fn mae(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

#[test]
fn sampled_rate_point_one_mae_on_synthetic_traces() {
    // ~8k-line footprints sampled at 0.1 → ~800 sampled lines per case:
    // every individual curve stays within a loose per-case band and the
    // mean across seeds meets the headline 0.02 bound
    let mut total = 0.0;
    const CASES: u64 = 12;
    for seed in 0..CASES {
        let mut rng = Rng::new(0x3A_E0 + seed);
        let addrs = address_trace(&mut rng, 100_000, 65_536);
        let mut exact = MrcBuilder::new();
        let mut sampled = SampledMrc::new(0.1);
        for &a in &addrs {
            exact.access(a);
            sampled.access(a);
        }
        let exact_ratios: Vec<f64> = exact
            .miss_counts()
            .iter()
            .map(|&m| m as f64 / exact.accesses() as f64)
            .collect();
        let e = mae(&sampled.miss_ratios(), &exact_ratios);
        assert!(e <= 0.04, "seed {seed}: per-case MAE {e:.4} out of band");
        total += e;
    }
    let mean = total / CASES as f64;
    assert!(mean <= 0.02, "mean MAE {mean:.4} > 0.02 across {CASES} traces");
}

#[test]
fn sampled_rate_point_one_mae_on_suite_kernels() {
    // gesummv (dense streaming, ~9k-line footprint at n=192) and bfs
    // (irregular pointer chasing, ~5k lines at n=4096): MAE ≤ 0.02 per
    // kernel, end-to-end through the profile pipeline
    let traffic_only = MetricSet::from_names("traffic").unwrap();
    let sampled_opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.1 });
    for (name, n) in [("gesummv", 192usize), ("bfs", 4096usize)] {
        let k = pisa_nmc::workloads::by_name(name).unwrap();
        let p = k.build(n, 42);
        let exact = profile_req(&p, traffic_only, PipelineMode::Inline, TrafficOpts::default())
            .unwrap()
            .traffic;
        let sampled =
            profile_req(&p, traffic_only, PipelineMode::Inline, sampled_opts).unwrap().traffic;
        assert!(
            sampled.mrc_sampled_accesses < exact.accesses / 2,
            "{name}: sampling barely reduced the substream \
             ({} of {})",
            sampled.mrc_sampled_accesses,
            exact.accesses
        );
        let e = mae(&sampled.mrc_miss_ratio, &exact.mrc_miss_ratio);
        assert!(e <= 0.02, "{name}: MAE {e:.4} > 0.02");
        // the footprint/cold estimator lands near the truth too
        let (est, truth) = (sampled.footprint_lines as f64, exact.footprint_lines as f64);
        assert!(
            (est - truth).abs() / truth < 0.2,
            "{name}: footprint estimate {est} vs {truth}"
        );
    }
}

// ---------------------------------------------------------------------------
// 4. Fixed-size variant: bounded residency, monotone threshold.

#[test]
fn fixed_size_variant_never_exceeds_its_bound() {
    for (seed, s_max) in [(1u64, 128usize), (2, 512), (3, 2048)] {
        let mut rng = Rng::new(0xF1_5E ^ seed);
        let addrs = address_trace(&mut rng, 60_000, 65_536);
        let mut s = SampledMrc::fixed_size(s_max);
        let mut last_rate = s.current_rate();
        for (i, &a) in addrs.iter().enumerate() {
            s.access(a);
            if i % 32 == 0 {
                assert!(
                    s.resident() <= s_max,
                    "resident {} > S_max {s_max} at access {i}",
                    s.resident()
                );
                let r = s.current_rate();
                assert!(r <= last_rate, "rate rose {last_rate} -> {r}");
                last_rate = r;
            }
        }
        assert!(s.resident() <= s_max);
        // an ~8k-line footprint must have forced adaptation at small S_max
        if s_max < 1024 {
            assert!(s.current_rate() < 1.0, "S_max {s_max} never adapted");
        }
        let r = s.miss_ratios();
        assert!(r.iter().all(|v| (0.0..=1.0).contains(v)), "{r:?}");
        for w in r.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "curve must be monotone: {r:?}");
        }
        assert_eq!(r.len(), N_MRC_POINTS);
    }
}

// ---------------------------------------------------------------------------
// 5. Sampled mode is bit-identical across all four delivery paths.

#[test]
fn sampled_mode_is_bit_identical_across_all_four_deliveries() {
    check_seeded("sampled 4-way identity", 0x54_4D, 10, |rng| {
        let p = random_program(rng);
        let all = MetricSet::all();
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.5 });
        let reference = profile_req_pe(&p, all, opts).map_err(|e| e.to_string())?;
        let inline =
            profile_req(&p, all, PipelineMode::Inline, opts).map_err(|e| e.to_string())?;
        let offload =
            profile_req(&p, all, PipelineMode::Offload, opts).map_err(|e| e.to_string())?;
        let sharded =
            profile_req(&p, all, PipelineMode::Sharded { workers: Workers::Auto }, opts)
                .map_err(|e| e.to_string())?;
        prop_assert!(
            inline.traffic.mrc_mode == MrcMode::Sampled { rate: 0.5 },
            "mode did not reach the analyzer"
        );
        for (got, what) in [(&inline, "inline"), (&offload, "offload"), (&sharded, "sharded")] {
            assert_traffic_bits_equal(&got.traffic, &reference.traffic, what);
            prop_assert!(
                got.traffic.mrc_sampled_accesses == reference.traffic.mrc_sampled_accesses,
                "{what}: sampled-substream size diverged"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Sanity: the line-granularity plumbing agrees between exact and sampled.

#[test]
fn exact_and_sampled_see_the_same_line_stream() {
    // same addresses, same line shift: the sampled kernel's raw access
    // count must equal the exact kernel's regardless of rate, and the
    // sample must be a strict subset
    let mut rng = Rng::new(0x11D);
    let addrs = address_trace(&mut rng, 5_000, 4096);
    let distinct_lines: std::collections::HashSet<u64> =
        addrs.iter().map(|a| a >> MRC_LINE_SHIFT).collect();
    let mut exact = MrcBuilder::new();
    let mut sampled = SampledMrc::new(0.25);
    for &a in &addrs {
        exact.access(a);
        sampled.access(a);
    }
    assert_eq!(exact.footprint_lines(), distinct_lines.len() as u64);
    assert_eq!(sampled.accesses(), exact.accesses());
    assert!(sampled.sampled_accesses() <= sampled.accesses());
    assert!(sampled.resident() <= distinct_lines.len());
}
