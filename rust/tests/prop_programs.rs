//! Property tests driven by a *random structured-program generator*: build
//! arbitrary (but well-formed) mini-IR programs, execute them, and check
//! the pipeline-wide invariants the coordinator depends on — verification,
//! bounded execution, work conservation between the analyzers and the
//! task-trace, and machine-model sanity.

use pisa_nmc::interp::{run_program, Counter, Machine, NullInstrument};
use pisa_nmc::ir::{verify::verify, Program, ProgramBuilder, Reg};
use pisa_nmc::prop_assert;
use pisa_nmc::sim::{simulate_host, simulate_nmc, Region, TaskTraceCollector};
use pisa_nmc::testkit::{check_seeded, usize_in};
use pisa_nmc::util::Rng;

/// Generate a random structured program: nested counted loops (bounded trip
/// counts), arithmetic over a register pool, loads/stores into a shared
/// buffer with in-bounds random indexing, and the occasional if/else.
fn random_program(rng: &mut Rng) -> Program {
    let mut b = ProgramBuilder::new("rand");
    let len = 64usize;
    let data: Vec<f64> = (0..len).map(|_| rng.range_f64(0.5, 2.0)).collect();
    let buf = b.alloc_f64_init("buf", &data);
    let len_reg = b.const_i(len as i64);

    let mut pool: Vec<Reg> = (0..4).map(|i| b.const_f(1.0 + i as f64)).collect();
    let depth = usize_in(rng, 1, 3);
    gen_block(&mut b, rng, &mut pool, buf, len_reg, depth);
    let ret = pool[0];
    b.finish(Some(ret))
}

fn gen_block(
    b: &mut ProgramBuilder,
    rng: &mut Rng,
    pool: &mut Vec<Reg>,
    buf: pisa_nmc::ir::BufRef,
    len_reg: Reg,
    depth: usize,
) {
    for _ in 0..usize_in(rng, 1, 5) {
        match rng.below(if depth > 0 { 5 } else { 3 }) {
            0 => {
                // arithmetic: fadd/fmul of two pool regs (stays finite:
                // magnitudes bounded by construction below)
                let x = pool[usize_in(rng, 0, pool.len() - 1)];
                let y = pool[usize_in(rng, 0, pool.len() - 1)];
                let z = if rng.below(2) == 0 { b.fadd(x, y) } else { b.fmul(x, y) };
                // clamp via fmin to keep values bounded across loops
                let cap = b.const_f(4.0);
                let z = b.fmin(z, cap);
                let slot = usize_in(rng, 0, pool.len() - 1);
                pool[slot] = z;
            }
            1 => {
                // load buf[idx % len]
                let idx_c = b.const_i(rng.below(64) as i64);
                let v = b.load_f64(buf, idx_c);
                let slot = usize_in(rng, 0, pool.len() - 1);
                pool[slot] = v;
            }
            2 => {
                // store pool reg to buf[idx]
                let idx_c = b.const_i(rng.below(64) as i64);
                let v = pool[usize_in(rng, 0, pool.len() - 1)];
                b.store_f64(buf, idx_c, v);
            }
            3 => {
                // bounded counted loop
                let trip = b.const_i(1 + rng.below(8) as i64);
                let mut inner_pool = pool.clone();
                // deterministic sub-rng so closure borrows don't fight
                let mut sub = Rng::new(rng.next_u64());
                b.counted_loop(trip, |b, i| {
                    let idx = b.rem(i, len_reg);
                    let v = b.load_f64(buf, idx);
                    inner_pool[0] = v;
                    gen_block(b, &mut sub, &mut inner_pool, buf, len_reg, depth - 1);
                });
            }
            _ => {
                // if/else on a data comparison
                let x = pool[usize_in(rng, 0, pool.len() - 1)];
                let y = pool[usize_in(rng, 0, pool.len() - 1)];
                let c = b.fcmp_lt(x, y);
                let mut sub1 = Rng::new(rng.next_u64());
                let mut sub2 = Rng::new(rng.next_u64());
                let mut p1 = pool.clone();
                let mut p2 = pool.clone();
                b.if_then_else(
                    c,
                    |b| gen_block(b, &mut sub1, &mut p1, buf, len_reg, 0),
                    |b| gen_block(b, &mut sub2, &mut p2, buf, len_reg, 0),
                );
            }
        }
    }
}

#[test]
fn random_programs_verify_and_terminate() {
    check_seeded("random programs run", 0xA11CE, 48, |rng| {
        let p = random_program(rng);
        let errs = verify(&p);
        prop_assert!(errs.is_empty(), "verify errors: {errs:?}");
        let mut m = Machine::new(&p).map_err(|e| e.to_string())?;
        m.instr_limit = 5_000_000;
        let out = m.run(&mut NullInstrument).map_err(|e| e.to_string())?;
        prop_assert!(out.stats.dyn_instrs > 0, "no instructions executed");
        Ok(())
    });
}

#[test]
fn task_trace_conserves_work_on_random_programs() {
    check_seeded("region work conservation", 0x7A5C, 32, |rng| {
        let p = random_program(rng);
        let mut c = TaskTraceCollector::new(&p);
        let (out, _) = run_program(&p, &mut c).map_err(|e| e.to_string())?;
        let regions = c.finalize();
        let total: u64 = regions.iter().map(|r| r.instrs()).sum();
        prop_assert!(
            total == out.stats.dyn_instrs,
            "regions carry {total} instrs, trace had {}",
            out.stats.dyn_instrs
        );
        // memory accesses conserved too
        let acc: usize = regions
            .iter()
            .map(|r| match r {
                Region::Serial(t) => t.accesses.len(),
                Region::Parallel(ts) => ts.iter().map(|t| t.accesses.len()).sum(),
            })
            .sum();
        prop_assert!(
            acc as u64 == out.stats.mem_reads + out.stats.mem_writes,
            "region accesses {acc} vs machine {}",
            out.stats.mem_reads + out.stats.mem_writes
        );
        Ok(())
    });
}

#[test]
fn both_machines_see_identical_work_and_positive_time() {
    check_seeded("machine model sanity", 0x51A1, 24, |rng| {
        let p = random_program(rng);
        let mut c = TaskTraceCollector::new(&p);
        run_program(&p, &mut c).map_err(|e| e.to_string())?;
        let regions = c.finalize();
        if regions.is_empty() {
            return Ok(());
        }
        let h = simulate_host(&regions, 2.0);
        let n = simulate_nmc(&regions);
        prop_assert!(h.dyn_instrs == n.dyn_instrs, "work mismatch");
        prop_assert!(h.time_s > 0.0 && h.energy_j > 0.0, "host non-positive");
        prop_assert!(n.time_s > 0.0 && n.energy_j > 0.0, "nmc non-positive");
        prop_assert!(h.time_s.is_finite() && n.time_s.is_finite(), "non-finite time");
        Ok(())
    });
}

#[test]
fn event_counts_match_machine_stats() {
    check_seeded("event stream vs stats", 0xC0DE, 32, |rng| {
        let p = random_program(rng);
        let mut c = Counter::default();
        let (out, _) = run_program(&p, &mut c).map_err(|e| e.to_string())?;
        prop_assert!(c.instrs == out.stats.dyn_instrs, "instr events");
        prop_assert!(c.blocks == out.stats.dyn_blocks, "block events");
        prop_assert!(c.branches == out.stats.dyn_branches, "branch events");
        prop_assert!(
            c.loads + c.stores == out.stats.mem_reads + out.stats.mem_writes,
            "mem events"
        );
        Ok(())
    });
}

#[test]
fn execution_is_bit_deterministic() {
    check_seeded("deterministic execution", 0xDE7, 24, |rng| {
        let seed = rng.next_u64();
        let p1 = random_program(&mut Rng::new(seed));
        let p2 = random_program(&mut Rng::new(seed));
        let (o1, m1) = run_program(&p1, &mut NullInstrument).map_err(|e| e.to_string())?;
        let (o2, m2) = run_program(&p2, &mut NullInstrument).map_err(|e| e.to_string())?;
        prop_assert!(o1.stats.dyn_instrs == o2.stats.dyn_instrs, "instrs differ");
        let b1 = p1.buffer("buf").unwrap();
        let b2 = p2.buffer("buf").unwrap();
        let d1 = m1.mem.read_f64_slice(b1.base, 64).map_err(|e| e.to_string())?;
        let d2 = m2.mem.read_f64_slice(b2.base, 64).map_err(|e| e.to_string())?;
        prop_assert!(d1 == d2, "memory images differ");
        Ok(())
    });
}
