//! Property tests driven by the random structured-program generator in
//! `testkit` (`random_program`): build arbitrary (but well-formed) mini-IR
//! programs, execute them, and check
//! the pipeline-wide invariants the coordinator depends on — verification,
//! bounded execution, work conservation between the analyzers and the
//! task-trace, and machine-model sanity.

use pisa_nmc::interp::{run_program, Counter, Machine, NullInstrument};
use pisa_nmc::ir::verify::verify;
use pisa_nmc::prop_assert;
use pisa_nmc::sim::{simulate_host, simulate_nmc, Region, TaskTraceCollector};
use pisa_nmc::testkit::{check_seeded, random_program};
use pisa_nmc::util::Rng;

#[test]
fn random_programs_verify_and_terminate() {
    check_seeded("random programs run", 0xA11CE, 48, |rng| {
        let p = random_program(rng);
        let errs = verify(&p);
        prop_assert!(errs.is_empty(), "verify errors: {errs:?}");
        let mut m = Machine::new(&p).map_err(|e| e.to_string())?;
        m.instr_limit = 5_000_000;
        let out = m.run(&mut NullInstrument).map_err(|e| e.to_string())?;
        prop_assert!(out.stats.dyn_instrs > 0, "no instructions executed");
        Ok(())
    });
}

#[test]
fn task_trace_conserves_work_on_random_programs() {
    check_seeded("region work conservation", 0x7A5C, 32, |rng| {
        let p = random_program(rng);
        let mut c = TaskTraceCollector::new(&p);
        let (out, _) = run_program(&p, &mut c).map_err(|e| e.to_string())?;
        let regions = c.finalize();
        let total: u64 = regions.iter().map(|r| r.instrs()).sum();
        prop_assert!(
            total == out.stats.dyn_instrs,
            "regions carry {total} instrs, trace had {}",
            out.stats.dyn_instrs
        );
        // memory accesses conserved too
        let acc: usize = regions
            .iter()
            .map(|r| match r {
                Region::Serial(t) => t.accesses.len(),
                Region::Parallel(ts) => ts.iter().map(|t| t.accesses.len()).sum(),
            })
            .sum();
        prop_assert!(
            acc as u64 == out.stats.mem_reads + out.stats.mem_writes,
            "region accesses {acc} vs machine {}",
            out.stats.mem_reads + out.stats.mem_writes
        );
        Ok(())
    });
}

#[test]
fn both_machines_see_identical_work_and_positive_time() {
    check_seeded("machine model sanity", 0x51A1, 24, |rng| {
        let p = random_program(rng);
        let mut c = TaskTraceCollector::new(&p);
        run_program(&p, &mut c).map_err(|e| e.to_string())?;
        let regions = c.finalize();
        if regions.is_empty() {
            return Ok(());
        }
        let h = simulate_host(&regions, 2.0);
        let n = simulate_nmc(&regions);
        prop_assert!(h.dyn_instrs == n.dyn_instrs, "work mismatch");
        prop_assert!(h.time_s > 0.0 && h.energy_j > 0.0, "host non-positive");
        prop_assert!(n.time_s > 0.0 && n.energy_j > 0.0, "nmc non-positive");
        prop_assert!(h.time_s.is_finite() && n.time_s.is_finite(), "non-finite time");
        Ok(())
    });
}

#[test]
fn event_counts_match_machine_stats() {
    check_seeded("event stream vs stats", 0xC0DE, 32, |rng| {
        let p = random_program(rng);
        let mut c = Counter::default();
        let (out, _) = run_program(&p, &mut c).map_err(|e| e.to_string())?;
        prop_assert!(c.instrs == out.stats.dyn_instrs, "instr events");
        prop_assert!(c.blocks == out.stats.dyn_blocks, "block events");
        prop_assert!(c.branches == out.stats.dyn_branches, "branch events");
        prop_assert!(
            c.loads + c.stores == out.stats.mem_reads + out.stats.mem_writes,
            "mem events"
        );
        Ok(())
    });
}

#[test]
fn execution_is_bit_deterministic() {
    check_seeded("deterministic execution", 0xDE7, 24, |rng| {
        let seed = rng.next_u64();
        let p1 = random_program(&mut Rng::new(seed));
        let p2 = random_program(&mut Rng::new(seed));
        let (o1, m1) = run_program(&p1, &mut NullInstrument).map_err(|e| e.to_string())?;
        let (o2, m2) = run_program(&p2, &mut NullInstrument).map_err(|e| e.to_string())?;
        prop_assert!(o1.stats.dyn_instrs == o2.stats.dyn_instrs, "instrs differ");
        let b1 = p1.buffer("buf").unwrap();
        let b2 = p2.buffer("buf").unwrap();
        let d1 = m1.mem.read_f64_slice(b1.base, 64).map_err(|e| e.to_string())?;
        let d2 = m2.mem.read_f64_slice(b2.base, 64).map_err(|e| e.to_string())?;
        prop_assert!(d1 == d2, "memory images differ");
        Ok(())
    });
}
