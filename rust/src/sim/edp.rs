//! Energy-delay product comparison (paper §IV-B, Fig 4).
//!
//! "We use EDP as our major metric of reference because both energy and
//! performance are critical criteria for evaluating NMC suitability.
//! Applications with EDP reduction less than 1 are not suitable for NMC."

use super::host_system::HostResult;
use super::nmc_system::NmcResult;
use crate::util::Json;

/// Host-vs-NMC outcome for one application.
#[derive(Debug, Clone)]
pub struct EdpComparison {
    pub app: String,
    pub host: HostResult,
    pub nmc: NmcResult,
}

impl EdpComparison {
    /// Fig 4's y-axis: EDP_host / EDP_nmc (> 1 ⇒ NMC suitable).
    pub fn edp_improvement(&self) -> f64 {
        let n = self.nmc.edp();
        if n <= 0.0 {
            return 0.0;
        }
        self.host.edp() / n
    }

    pub fn speedup(&self) -> f64 {
        if self.nmc.time_s <= 0.0 {
            return 0.0;
        }
        self.host.time_s / self.nmc.time_s
    }

    pub fn energy_reduction(&self) -> f64 {
        if self.nmc.energy_j <= 0.0 {
            return 0.0;
        }
        self.host.energy_j / self.nmc.energy_j
    }

    pub fn nmc_suitable(&self) -> bool {
        self.edp_improvement() > 1.0
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("app", self.app.as_str());
        j.set("edp_improvement", self.edp_improvement());
        j.set("speedup", self.speedup());
        j.set("energy_reduction", self.energy_reduction());
        j.set("nmc_suitable", self.nmc_suitable());
        let mut h = Json::obj();
        h.set("time_s", self.host.time_s);
        h.set("energy_j", self.host.energy_j);
        h.set("edp", self.host.edp());
        h.set("l3_misses", self.host.l3_misses);
        h.set("dram_lines", self.host.dram_lines);
        h.set("ipc", self.host.ipc);
        j.set("host", h);
        let mut n = Json::obj();
        n.set("time_s", self.nmc.time_s);
        n.set("energy_j", self.nmc.energy_j);
        n.set("edp", self.nmc.edp());
        n.set("parallel_fraction", self.nmc.parallel_fraction);
        n.set("dram_lines", self.nmc.dram_lines);
        n.set("remote_lines", self.nmc.remote_lines);
        j.set("nmc", n);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::host_system::simulate_host;
    use crate::sim::nmc_system::simulate_nmc;
    use crate::sim::task_trace::collect;
    use crate::ir::ProgramBuilder;

    #[test]
    fn edp_math() {
        let mut b = ProgramBuilder::new("t");
        let a = b.alloc_f64("a", 512);
        let n = b.const_i(512);
        let c = b.const_f(1.0);
        b.counted_loop(n, |b, i| {
            b.store_f64(a, i, c);
        });
        let regions = collect(&b.finish(None)).unwrap();
        let cmp = EdpComparison {
            app: "t".into(),
            host: simulate_host(&regions, 3.0),
            nmc: simulate_nmc(&regions),
        };
        let want = (cmp.host.energy_j * cmp.host.time_s) / (cmp.nmc.energy_j * cmp.nmc.time_s);
        assert!((cmp.edp_improvement() - want).abs() < 1e-12);
        assert_eq!(cmp.nmc_suitable(), want > 1.0);
        let s = cmp.to_json().to_string_compact();
        assert!(s.contains("edp_improvement"));
    }
}
