//! Set-associative write-allocate LRU caches and a small hierarchy.

/// Access outcome at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; carries whether a dirty line was evicted (writeback traffic).
    Miss { writeback: bool },
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp — larger = more recent.
    lru: u64,
}

/// One set-associative LRU cache level.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    lines: Vec<Line>, // sets × ways
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    /// `capacity_bytes` must be sets·ways·line; sets are derived.
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let n_lines = (capacity_bytes / line_bytes).max(1);
        let ways = ways.min(n_lines).max(1);
        let sets = (n_lines / ways).max(1);
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            lines: vec![Line::default(); sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    /// Tiny fully-specified cache (the NMC PE L1: `lines` total lines).
    pub fn tiny(lines: usize, ways: usize, line_bytes: usize) -> Cache {
        Cache::new(lines * line_bytes, ways, line_bytes)
    }

    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    /// Access one address; `is_store` marks the line dirty on hit/fill.
    /// One code path with the hierarchy replay: probe/fill are the shared
    /// line primitives below, `access` just layers the counters on top.
    pub fn access(&mut self, addr: u64, is_store: bool) -> Access {
        let line = addr >> self.line_shift;
        if self.touch_line(line, is_store) {
            self.hits += 1;
            return Access::Hit;
        }
        let writeback = self.fill_line_after_miss(line, is_store).is_some_and(|e| e.dirty);
        if writeback {
            self.writebacks += 1;
        }
        self.misses += 1;
        Access::Miss { writeback }
    }

    pub fn miss_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }

    // --- line-addressed primitives -------------------------------------
    //
    // The multi-level hierarchy replay (`traffic::hierarchy`) decomposes
    // an access into probe / fill / invalidate steps so it can route
    // misses, victim writebacks and back-invalidations between levels.
    // These primitives reuse the same set/way/LRU machinery as `access`
    // but are counter-neutral: the hierarchy owns its per-level counts.
    // They work in line units (`line = addr >> line_shift`) because the
    // victim of one level is filled into the next by line, not by byte.

    #[inline]
    fn set_and_tag(&self, line: u64) -> (usize, u64) {
        ((line as usize) % self.sets, line / self.sets as u64)
    }

    /// Probe for `line`; on hit refresh its LRU stamp and merge `dirty`.
    pub fn touch_line(&mut self, line: u64, dirty: bool) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        for l in &mut self.lines[base..base + self.ways] {
            if l.valid && l.tag == tag {
                self.clock += 1;
                l.lru = self.clock;
                l.dirty |= dirty;
                return true;
            }
        }
        false
    }

    /// Mark `line` dirty *without* refreshing its LRU stamp (a writeback
    /// landing from the level above must not promote a cooling line).
    pub fn mark_dirty_line(&mut self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        for l in &mut self.lines[base..base + self.ways] {
            if l.valid && l.tag == tag {
                l.dirty = true;
                return true;
            }
        }
        false
    }

    /// Insert `line` with a fresh LRU stamp, evicting the set's LRU victim
    /// when full; the victim comes back (line id + dirty) so the caller
    /// can write it back or demote it. If the line is already resident the
    /// fill degenerates to a touch (refresh + dirty merge), no eviction.
    pub fn fill_line(&mut self, line: u64, dirty: bool) -> Option<Evicted> {
        if self.touch_line(line, dirty) {
            return None;
        }
        self.fill_line_after_miss(line, dirty)
    }

    /// [`Cache::fill_line`] for callers that already know the line is
    /// absent — a probe just missed, or (in the exclusive hierarchy)
    /// disjointness guarantees it — skipping the redundant set scan on
    /// the replay's hottest path.
    pub fn fill_line_after_miss(&mut self, line: u64, dirty: bool) -> Option<Evicted> {
        debug_assert!(!self.contains_line(line), "fill_line_after_miss on a resident line");
        let (set, tag) = self.set_and_tag(line);
        let sets = self.sets as u64;
        let base = set * self.ways;
        self.clock += 1;
        let clock = self.clock;
        let victim = self.lines[base..base + self.ways]
            .iter_mut()
            .min_by_key(|l| if l.valid { l.lru } else { 0 })
            .expect("ways >= 1");
        let evicted = if victim.valid {
            Some(Evicted { line: victim.tag * sets + set as u64, dirty: victim.dirty })
        } else {
            None
        };
        *victim = Line { tag, valid: true, dirty, lru: clock };
        evicted
    }

    /// Remove `line` if resident, returning its dirty bit (exclusive-mode
    /// promotion and inclusive back-invalidation both take lines out).
    pub fn take_line(&mut self, line: u64) -> Option<bool> {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        for l in &mut self.lines[base..base + self.ways] {
            if l.valid && l.tag == tag {
                let dirty = l.dirty;
                *l = Line::default();
                return Some(dirty);
            }
        }
        None
    }

    /// Is `line` resident? (read-only probe; no LRU effect)
    pub fn contains_line(&self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        self.lines[base..base + self.ways]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    /// All resident line ids, sorted (inclusion-invariant checks in tests).
    pub fn resident_lines(&self) -> Vec<u64> {
        let sets = self.sets as u64;
        let mut out: Vec<u64> = self
            .lines
            .iter()
            .enumerate()
            .filter(|(_, l)| l.valid)
            .map(|(i, l)| l.tag * sets + (i / self.ways) as u64)
            .collect();
        out.sort_unstable();
        out
    }
}

/// A line evicted by [`Cache::fill_line`]: its line id and dirty bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Evicted {
    pub line: u64,
    pub dirty: bool,
}

/// Result of sending one access through a multi-level hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchyOutcome {
    /// Deepest level that *hit* (0 = L1); `levels` if it went to memory.
    pub hit_level: usize,
    /// A dirty line was written back to memory.
    pub dram_writeback: bool,
}

/// Inclusive-ish multi-level hierarchy (misses propagate downward).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub levels: Vec<Cache>,
}

impl Hierarchy {
    pub fn new(levels: Vec<Cache>) -> Hierarchy {
        Hierarchy { levels }
    }

    pub fn access(&mut self, addr: u64, is_store: bool) -> HierarchyOutcome {
        let mut dram_writeback = false;
        let n = self.levels.len();
        for (i, c) in self.levels.iter_mut().enumerate() {
            match c.access(addr, is_store) {
                Access::Hit => {
                    return HierarchyOutcome { hit_level: i, dram_writeback };
                }
                Access::Miss { writeback } => {
                    // victim writeback from the last level goes to memory
                    if writeback && i + 1 == n {
                        dram_writeback = true;
                    }
                }
            }
        }
        HierarchyOutcome { hit_level: n, dram_writeback }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 2, 64);
        assert!(matches!(c.access(0x100, false), Access::Miss { .. }));
        assert_eq!(c.access(0x100, false), Access::Hit);
        assert_eq!(c.access(0x13f, false), Access::Hit); // same 64B line
        assert!(matches!(c.access(0x140, false), Access::Miss { .. }));
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2 ways, 1 set of 2 lines (tiny 2-line cache like the NMC L1)
        let mut c = Cache::tiny(2, 2, 64);
        c.access(0x000, false);
        c.access(0x040, false);
        c.access(0x000, false); // refresh line 0
        c.access(0x080, false); // evicts 0x040
        assert_eq!(c.access(0x000, false), Access::Hit);
        assert!(matches!(c.access(0x040, false), Access::Miss { .. }));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::tiny(1, 1, 64);
        c.access(0x000, true); // dirty fill
        match c.access(0x040, false) {
            Access::Miss { writeback } => assert!(writeback),
            _ => panic!("expected miss"),
        }
    }

    #[test]
    fn working_set_behavior() {
        // working set smaller than capacity → near-zero steady-state misses
        let mut c = Cache::new(32 * 1024, 8, 64);
        let addrs: Vec<u64> = (0..256u64).map(|i| i * 64).collect();
        for &a in &addrs {
            c.access(a, false);
        }
        let misses_cold = c.misses;
        for _ in 0..10 {
            for &a in &addrs {
                c.access(a, false);
            }
        }
        assert_eq!(c.misses, misses_cold, "steady state must not miss");
    }

    #[test]
    fn line_primitives_match_access_semantics() {
        // the decomposed probe/fill path must agree with `access` on the
        // same stream (hit/miss outcomes and victim choice)
        let mut via_access = Cache::tiny(2, 2, 64);
        let mut via_prims = Cache::tiny(2, 2, 64);
        let stream = [0u64, 1, 0, 2, 0, 1, 3, 2];
        for &line in &stream {
            let hit = matches!(via_access.access(line * 64, false), Access::Hit);
            let phit = via_prims.touch_line(line, false);
            if !phit {
                via_prims.fill_line(line, false);
            }
            assert_eq!(hit, phit, "line {line}");
        }
        assert_eq!(via_access.resident_lines(), via_prims.resident_lines());
    }

    #[test]
    fn fill_line_reports_victims_and_take_removes() {
        let mut c = Cache::tiny(1, 1, 64); // one slot
        assert_eq!(c.fill_line(5, true), None);
        assert!(c.contains_line(5));
        // filling a second line evicts the dirty first one
        assert_eq!(c.fill_line(9, false), Some(Evicted { line: 5, dirty: true }));
        assert!(!c.contains_line(5) && c.contains_line(9));
        assert_eq!(c.take_line(9), Some(false));
        assert_eq!(c.take_line(9), None);
        assert_eq!(c.resident_lines(), Vec::<u64>::new());
    }

    #[test]
    fn mark_dirty_does_not_refresh_lru() {
        let mut c = Cache::tiny(2, 2, 64); // one set, two ways
        c.fill_line(1, false);
        c.fill_line(2, false);
        assert!(c.mark_dirty_line(1)); // dirty, but still the LRU victim
        let v = c.fill_line(3, false).expect("set is full");
        assert_eq!(v, Evicted { line: 1, dirty: true });
        assert!(!c.mark_dirty_line(7), "absent line cannot be dirtied");
    }

    #[test]
    fn refill_of_resident_line_merges_instead_of_evicting() {
        let mut c = Cache::tiny(2, 2, 64);
        c.fill_line(1, false);
        c.fill_line(2, false);
        assert_eq!(c.fill_line(1, true), None, "re-fill must not evict");
        assert_eq!(c.take_line(1), Some(true), "dirty bit merged");
    }

    #[test]
    fn hierarchy_propagates() {
        let mut h = Hierarchy::new(vec![Cache::tiny(2, 2, 64), Cache::new(4096, 4, 64)]);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 2); // cold: straight to memory
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 0);
        // knock 0x1000 out of the 2-line L1 but not out of L2
        h.access(0x2000, false);
        h.access(0x3000, false);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 1);
    }
}
