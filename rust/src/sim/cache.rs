//! Set-associative write-allocate LRU caches and a small hierarchy.

/// Access outcome at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; carries whether a dirty line was evicted (writeback traffic).
    Miss { writeback: bool },
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp — larger = more recent.
    lru: u64,
}

/// One set-associative LRU cache level.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    lines: Vec<Line>, // sets × ways
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    /// `capacity_bytes` must be sets·ways·line; sets are derived.
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let n_lines = (capacity_bytes / line_bytes).max(1);
        let ways = ways.min(n_lines).max(1);
        let sets = (n_lines / ways).max(1);
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            lines: vec![Line::default(); sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    /// Tiny fully-specified cache (the NMC PE L1: `lines` total lines).
    pub fn tiny(lines: usize, ways: usize, line_bytes: usize) -> Cache {
        Cache::new(lines * line_bytes, ways, line_bytes)
    }

    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    /// Access one address; `is_store` marks the line dirty on hit/fill.
    pub fn access(&mut self, addr: u64, is_store: bool) -> Access {
        self.clock += 1;
        let line_addr = addr >> self.line_shift;
        let set = (line_addr as usize) % self.sets;
        let tag = line_addr / self.sets as u64;
        let base = set * self.ways;
        let set_lines = &mut self.lines[base..base + self.ways];

        for l in set_lines.iter_mut() {
            if l.valid && l.tag == tag {
                l.lru = self.clock;
                l.dirty |= is_store;
                self.hits += 1;
                return Access::Hit;
            }
        }
        // miss: fill into LRU victim
        let victim = set_lines
            .iter_mut()
            .min_by_key(|l| if l.valid { l.lru } else { 0 })
            .expect("ways >= 1");
        let writeback = victim.valid && victim.dirty;
        if writeback {
            self.writebacks += 1;
        }
        *victim = Line { tag, valid: true, dirty: is_store, lru: self.clock };
        self.misses += 1;
        Access::Miss { writeback }
    }

    pub fn miss_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }
}

/// Result of sending one access through a multi-level hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchyOutcome {
    /// Deepest level that *hit* (0 = L1); `levels` if it went to memory.
    pub hit_level: usize,
    /// A dirty line was written back to memory.
    pub dram_writeback: bool,
}

/// Inclusive-ish multi-level hierarchy (misses propagate downward).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub levels: Vec<Cache>,
}

impl Hierarchy {
    pub fn new(levels: Vec<Cache>) -> Hierarchy {
        Hierarchy { levels }
    }

    pub fn access(&mut self, addr: u64, is_store: bool) -> HierarchyOutcome {
        let mut dram_writeback = false;
        let n = self.levels.len();
        for (i, c) in self.levels.iter_mut().enumerate() {
            match c.access(addr, is_store) {
                Access::Hit => {
                    return HierarchyOutcome { hit_level: i, dram_writeback };
                }
                Access::Miss { writeback } => {
                    // victim writeback from the last level goes to memory
                    if writeback && i + 1 == n {
                        dram_writeback = true;
                    }
                }
            }
        }
        HierarchyOutcome { hit_level: n, dram_writeback }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 2, 64);
        assert!(matches!(c.access(0x100, false), Access::Miss { .. }));
        assert_eq!(c.access(0x100, false), Access::Hit);
        assert_eq!(c.access(0x13f, false), Access::Hit); // same 64B line
        assert!(matches!(c.access(0x140, false), Access::Miss { .. }));
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2 ways, 1 set of 2 lines (tiny 2-line cache like the NMC L1)
        let mut c = Cache::tiny(2, 2, 64);
        c.access(0x000, false);
        c.access(0x040, false);
        c.access(0x000, false); // refresh line 0
        c.access(0x080, false); // evicts 0x040
        assert_eq!(c.access(0x000, false), Access::Hit);
        assert!(matches!(c.access(0x040, false), Access::Miss { .. }));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::tiny(1, 1, 64);
        c.access(0x000, true); // dirty fill
        match c.access(0x040, false) {
            Access::Miss { writeback } => assert!(writeback),
            _ => panic!("expected miss"),
        }
    }

    #[test]
    fn working_set_behavior() {
        // working set smaller than capacity → near-zero steady-state misses
        let mut c = Cache::new(32 * 1024, 8, 64);
        let addrs: Vec<u64> = (0..256u64).map(|i| i * 64).collect();
        for &a in &addrs {
            c.access(a, false);
        }
        let misses_cold = c.misses;
        for _ in 0..10 {
            for &a in &addrs {
                c.access(a, false);
            }
        }
        assert_eq!(c.misses, misses_cold, "steady state must not miss");
    }

    #[test]
    fn hierarchy_propagates() {
        let mut h = Hierarchy::new(vec![Cache::tiny(2, 2, 64), Cache::new(4096, 4, 64)]);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 2); // cold: straight to memory
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 0);
        // knock 0x1000 out of the 2-line L1 but not out of L2
        h.access(0x2000, false);
        h.access(0x3000, false);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 1);
    }
}
