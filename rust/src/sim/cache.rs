//! Set-associative write-allocate caches and a small hierarchy, with
//! selectable replacement ([`ReplacementKind`]: LRU, SRRIP, or DRRIP via
//! the [`ReplacementPolicy`] trait). Everything here is deterministic —
//! DRRIP's BRRIP throttle is a fill counter, not a random draw — because
//! bit-identical replays across pipeline deliveries are a repo invariant.

/// Access outcome at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; carries whether a dirty line was evicted (writeback traffic).
    Miss { writeback: bool },
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp — larger = more recent.
    lru: u64,
    /// Re-reference prediction value (RRIP policies only; 0 = imminent).
    rrpv: u8,
}

/// Which replacement policy a cache runs (`--hierarchy-spec` levels pick
/// one each). `Lru` is the historical default and stays bit-identical to
/// the pre-policy implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplacementKind {
    #[default]
    Lru,
    /// Static RRIP (2-bit SRRIP: insert long, promote to imminent on hit).
    Rrip,
    /// Dynamic RRIP: deterministic set-dueling between SRRIP and BRRIP.
    Drrip,
}

impl ReplacementKind {
    pub fn name(self) -> &'static str {
        match self {
            ReplacementKind::Lru => "lru",
            ReplacementKind::Rrip => "rrip",
            ReplacementKind::Drrip => "drrip",
        }
    }

    pub fn from_name(s: &str) -> Option<ReplacementKind> {
        match s {
            "lru" => Some(ReplacementKind::Lru),
            "rrip" => Some(ReplacementKind::Rrip),
            "drrip" => Some(ReplacementKind::Drrip),
            _ => None,
        }
    }
}

/// 2-bit RRPV range: 0 = re-reference imminent … 3 = distant.
const RRPV_MAX: u8 = 3;
/// SRRIP insertion point ("long" re-reference interval).
const RRPV_LONG: u8 = 2;
/// DRRIP policy-selector saturation and neutral point (10-bit PSEL).
const PSEL_MAX: u16 = 1023;
const PSEL_INIT: u16 = 512;
/// One SRRIP-leader and one BRRIP-leader set per this many sets.
const DUEL_MOD: usize = 32;
/// BRRIP inserts at `RRPV_LONG` once per this many fills (else distant).
const BRRIP_THROTTLE: u32 = 32;

/// Replacement decisions for the non-LRU policies, expressed over the
/// per-line RRPV stamps. The cache calls through this trait on every
/// hit/fill/eviction; the built-ins ([`Srrip`], [`Drrip`]) are wired in
/// via [`ReplacementKind`]. Implementations must be deterministic.
pub trait ReplacementPolicy {
    fn kind(&self) -> ReplacementKind;
    /// Restamp a line that just hit.
    fn on_hit(&mut self, set: usize, rrpv: &mut u8);
    /// Stamp a line just filled after a miss (the insertion policy).
    fn on_fill(&mut self, set: usize, rrpv: &mut u8);
    /// Choose the victim way of a full set, aging stamps in place.
    /// Ties break to the lowest way index so replays are deterministic.
    fn victim(&mut self, set: usize, rrpvs: &mut [u8]) -> usize;
}

/// Static RRIP (Jaleel et al.): scan-resistant 2-bit re-reference
/// prediction.
#[derive(Debug, Clone, Copy, Default)]
pub struct Srrip;

fn rrip_victim(rrpvs: &mut [u8]) -> usize {
    loop {
        if let Some(i) = rrpvs.iter().position(|&r| r >= RRPV_MAX) {
            return i;
        }
        for r in rrpvs.iter_mut() {
            *r += 1;
        }
    }
}

impl ReplacementPolicy for Srrip {
    fn kind(&self) -> ReplacementKind {
        ReplacementKind::Rrip
    }

    fn on_hit(&mut self, _set: usize, rrpv: &mut u8) {
        *rrpv = 0;
    }

    fn on_fill(&mut self, _set: usize, rrpv: &mut u8) {
        *rrpv = RRPV_LONG;
    }

    fn victim(&mut self, _set: usize, rrpvs: &mut [u8]) -> usize {
        rrip_victim(rrpvs)
    }
}

/// Dynamic RRIP: set-dueling between SRRIP and BRRIP insertion. Sets
/// `s % DUEL_MOD == 0` lead for SRRIP, `== 1` for BRRIP; a miss (= fill)
/// in a leader set moves the saturating PSEL counter against its policy,
/// and follower sets insert with whichever side is missing less. The
/// BRRIP arm inserts distant except every `BRRIP_THROTTLE`-th fill — a
/// counter, not a coin flip, so replays are exactly reproducible. Caches
/// with fewer than `DUEL_MOD` sets degenerate gracefully (a 1-set cache
/// has only the SRRIP leader and behaves as SRRIP).
#[derive(Debug, Clone, Copy)]
pub struct Drrip {
    psel: u16,
    brrip_fills: u32,
}

impl Default for Drrip {
    fn default() -> Self {
        Drrip { psel: PSEL_INIT, brrip_fills: 0 }
    }
}

impl Drrip {
    fn brrip_insert(&mut self, rrpv: &mut u8) {
        self.brrip_fills += 1;
        *rrpv = if self.brrip_fills % BRRIP_THROTTLE == 0 { RRPV_LONG } else { RRPV_MAX };
    }
}

impl ReplacementPolicy for Drrip {
    fn kind(&self) -> ReplacementKind {
        ReplacementKind::Drrip
    }

    fn on_hit(&mut self, _set: usize, rrpv: &mut u8) {
        *rrpv = 0;
    }

    fn on_fill(&mut self, set: usize, rrpv: &mut u8) {
        match set % DUEL_MOD {
            0 => {
                // SRRIP leader missed: evidence against SRRIP
                self.psel = (self.psel + 1).min(PSEL_MAX);
                *rrpv = RRPV_LONG;
            }
            1 => {
                self.psel = self.psel.saturating_sub(1);
                self.brrip_insert(rrpv);
            }
            _ => {
                if self.psel > PSEL_INIT {
                    self.brrip_insert(rrpv);
                } else {
                    *rrpv = RRPV_LONG;
                }
            }
        }
    }

    fn victim(&mut self, _set: usize, rrpvs: &mut [u8]) -> usize {
        rrip_victim(rrpvs)
    }
}

/// The cache's wired-in policy. LRU keeps its dedicated stamp path (and
/// its exact historical victim choice); the RRIP policies dispatch
/// through [`ReplacementPolicy`].
#[derive(Debug, Clone)]
enum Replacer {
    Lru,
    Rrip(Srrip),
    Drrip(Drrip),
}

impl Replacer {
    fn new(kind: ReplacementKind) -> Replacer {
        match kind {
            ReplacementKind::Lru => Replacer::Lru,
            ReplacementKind::Rrip => Replacer::Rrip(Srrip),
            ReplacementKind::Drrip => Replacer::Drrip(Drrip::default()),
        }
    }

    fn kind(&self) -> ReplacementKind {
        match self {
            Replacer::Lru => ReplacementKind::Lru,
            Replacer::Rrip(p) => p.kind(),
            Replacer::Drrip(p) => p.kind(),
        }
    }

    #[inline]
    fn on_hit(&mut self, set: usize, rrpv: &mut u8) {
        match self {
            Replacer::Lru => {}
            Replacer::Rrip(p) => p.on_hit(set, rrpv),
            Replacer::Drrip(p) => p.on_hit(set, rrpv),
        }
    }

    #[inline]
    fn on_fill(&mut self, set: usize, rrpv: &mut u8) {
        match self {
            Replacer::Lru => {}
            Replacer::Rrip(p) => p.on_fill(set, rrpv),
            Replacer::Drrip(p) => p.on_fill(set, rrpv),
        }
    }

    #[inline]
    fn victim(&mut self, set: usize, rrpvs: &mut [u8]) -> usize {
        match self {
            Replacer::Lru => unreachable!("LRU victims come from the stamp scan"),
            Replacer::Rrip(p) => p.victim(set, rrpvs),
            Replacer::Drrip(p) => p.victim(set, rrpvs),
        }
    }
}

/// One set-associative cache level with a selectable replacement policy
/// (LRU by default).
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    lines: Vec<Line>, // sets × ways
    clock: u64,
    repl: Replacer,
    /// Reusable victim-selection scratch (RRIP policies age a copy of the
    /// set's stamps; no per-miss allocation).
    rrpv_scratch: Vec<u8>,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    /// `capacity_bytes` must be sets·ways·line; sets are derived. LRU
    /// replacement — bit-identical to the historical constructor.
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        Cache::with_policy(capacity_bytes, ways, line_bytes, ReplacementKind::Lru)
    }

    /// [`Cache::new`] with an explicit replacement policy (the
    /// `--hierarchy-spec` per-level `replacement` knob lands here).
    pub fn with_policy(
        capacity_bytes: usize,
        ways: usize,
        line_bytes: usize,
        kind: ReplacementKind,
    ) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let n_lines = (capacity_bytes / line_bytes).max(1);
        let ways = ways.min(n_lines).max(1);
        let sets = (n_lines / ways).max(1);
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            lines: vec![Line::default(); sets * ways],
            clock: 0,
            repl: Replacer::new(kind),
            rrpv_scratch: Vec::new(),
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    /// The replacement policy this cache was built with.
    pub fn replacement(&self) -> ReplacementKind {
        self.repl.kind()
    }

    /// Tiny fully-specified cache (the NMC PE L1: `lines` total lines).
    pub fn tiny(lines: usize, ways: usize, line_bytes: usize) -> Cache {
        Cache::new(lines * line_bytes, ways, line_bytes)
    }

    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    /// Access one address; `is_store` marks the line dirty on hit/fill.
    /// One code path with the hierarchy replay: probe/fill are the shared
    /// line primitives below, `access` just layers the counters on top.
    pub fn access(&mut self, addr: u64, is_store: bool) -> Access {
        let line = addr >> self.line_shift;
        if self.touch_line(line, is_store) {
            self.hits += 1;
            return Access::Hit;
        }
        let writeback = self.fill_line_after_miss(line, is_store).is_some_and(|e| e.dirty);
        if writeback {
            self.writebacks += 1;
        }
        self.misses += 1;
        Access::Miss { writeback }
    }

    pub fn miss_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }

    // --- line-addressed primitives -------------------------------------
    //
    // The multi-level hierarchy replay (`traffic::hierarchy`) decomposes
    // an access into probe / fill / invalidate steps so it can route
    // misses, victim writebacks and back-invalidations between levels.
    // These primitives reuse the same set/way/LRU machinery as `access`
    // but are counter-neutral: the hierarchy owns its per-level counts.
    // They work in line units (`line = addr >> line_shift`) because the
    // victim of one level is filled into the next by line, not by byte.

    #[inline]
    fn set_and_tag(&self, line: u64) -> (usize, u64) {
        ((line as usize) % self.sets, line / self.sets as u64)
    }

    /// Probe for `line`; on hit refresh its recency stamp and merge
    /// `dirty` (RRIP policies restamp the RRPV through the policy).
    pub fn touch_line(&mut self, line: u64, dirty: bool) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        let ways = self.ways;
        let Cache { lines, repl, clock, .. } = self;
        for l in &mut lines[base..base + ways] {
            if l.valid && l.tag == tag {
                *clock += 1;
                l.lru = *clock;
                l.dirty |= dirty;
                repl.on_hit(set, &mut l.rrpv);
                return true;
            }
        }
        false
    }

    /// Mark `line` dirty *without* refreshing its LRU stamp (a writeback
    /// landing from the level above must not promote a cooling line).
    pub fn mark_dirty_line(&mut self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        for l in &mut self.lines[base..base + self.ways] {
            if l.valid && l.tag == tag {
                l.dirty = true;
                return true;
            }
        }
        false
    }

    /// Insert `line` with a fresh LRU stamp, evicting the set's LRU victim
    /// when full; the victim comes back (line id + dirty) so the caller
    /// can write it back or demote it. If the line is already resident the
    /// fill degenerates to a touch (refresh + dirty merge), no eviction.
    pub fn fill_line(&mut self, line: u64, dirty: bool) -> Option<Evicted> {
        if self.touch_line(line, dirty) {
            return None;
        }
        self.fill_line_after_miss(line, dirty)
    }

    /// [`Cache::fill_line`] for callers that already know the line is
    /// absent — a probe just missed, or (in the exclusive hierarchy)
    /// disjointness guarantees it — skipping the redundant set scan on
    /// the replay's hottest path.
    pub fn fill_line_after_miss(&mut self, line: u64, dirty: bool) -> Option<Evicted> {
        debug_assert!(!self.contains_line(line), "fill_line_after_miss on a resident line");
        let (set, tag) = self.set_and_tag(line);
        let sets = self.sets as u64;
        let base = set * self.ways;
        self.clock += 1;
        let clock = self.clock;
        let ways = self.ways;
        let Cache { lines, repl, rrpv_scratch, .. } = self;
        let set_lines = &mut lines[base..base + ways];
        let slot = match repl {
            // the historical LRU choice (first minimal; invalids key to 0)
            Replacer::Lru => {
                set_lines
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, l)| if l.valid { l.lru } else { 0 })
                    .expect("ways >= 1")
                    .0
            }
            _ => match set_lines.iter().position(|l| !l.valid) {
                Some(slot) => slot,
                None => {
                    rrpv_scratch.clear();
                    rrpv_scratch.extend(set_lines.iter().map(|l| l.rrpv));
                    let slot = repl.victim(set, rrpv_scratch);
                    // the aging a victim scan applies is part of the state
                    for (l, &r) in set_lines.iter_mut().zip(rrpv_scratch.iter()) {
                        l.rrpv = r;
                    }
                    slot
                }
            },
        };
        let victim = &mut set_lines[slot];
        let evicted = if victim.valid {
            Some(Evicted { line: victim.tag * sets + set as u64, dirty: victim.dirty })
        } else {
            None
        };
        *victim = Line { tag, valid: true, dirty, lru: clock, rrpv: 0 };
        repl.on_fill(set, &mut victim.rrpv);
        evicted
    }

    /// Remove `line` if resident, returning its dirty bit (exclusive-mode
    /// promotion and inclusive back-invalidation both take lines out).
    pub fn take_line(&mut self, line: u64) -> Option<bool> {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        for l in &mut self.lines[base..base + self.ways] {
            if l.valid && l.tag == tag {
                let dirty = l.dirty;
                *l = Line::default();
                return Some(dirty);
            }
        }
        None
    }

    /// Is `line` resident? (read-only probe; no LRU effect)
    pub fn contains_line(&self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.ways;
        self.lines[base..base + self.ways]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    /// All resident line ids, sorted (inclusion-invariant checks in tests).
    pub fn resident_lines(&self) -> Vec<u64> {
        let sets = self.sets as u64;
        let mut out: Vec<u64> = self
            .lines
            .iter()
            .enumerate()
            .filter(|(_, l)| l.valid)
            .map(|(i, l)| l.tag * sets + (i / self.ways) as u64)
            .collect();
        out.sort_unstable();
        out
    }
}

/// A line evicted by [`Cache::fill_line`]: its line id and dirty bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Evicted {
    pub line: u64,
    pub dirty: bool,
}

/// Result of sending one access through a multi-level hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchyOutcome {
    /// Deepest level that *hit* (0 = L1); `levels` if it went to memory.
    pub hit_level: usize,
    /// A dirty line was written back to memory.
    pub dram_writeback: bool,
}

/// Inclusive-ish multi-level hierarchy (misses propagate downward).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub levels: Vec<Cache>,
}

impl Hierarchy {
    pub fn new(levels: Vec<Cache>) -> Hierarchy {
        Hierarchy { levels }
    }

    pub fn access(&mut self, addr: u64, is_store: bool) -> HierarchyOutcome {
        let mut dram_writeback = false;
        let n = self.levels.len();
        for (i, c) in self.levels.iter_mut().enumerate() {
            match c.access(addr, is_store) {
                Access::Hit => {
                    return HierarchyOutcome { hit_level: i, dram_writeback };
                }
                Access::Miss { writeback } => {
                    // victim writeback from the last level goes to memory
                    if writeback && i + 1 == n {
                        dram_writeback = true;
                    }
                }
            }
        }
        HierarchyOutcome { hit_level: n, dram_writeback }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 2, 64);
        assert!(matches!(c.access(0x100, false), Access::Miss { .. }));
        assert_eq!(c.access(0x100, false), Access::Hit);
        assert_eq!(c.access(0x13f, false), Access::Hit); // same 64B line
        assert!(matches!(c.access(0x140, false), Access::Miss { .. }));
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2 ways, 1 set of 2 lines (tiny 2-line cache like the NMC L1)
        let mut c = Cache::tiny(2, 2, 64);
        c.access(0x000, false);
        c.access(0x040, false);
        c.access(0x000, false); // refresh line 0
        c.access(0x080, false); // evicts 0x040
        assert_eq!(c.access(0x000, false), Access::Hit);
        assert!(matches!(c.access(0x040, false), Access::Miss { .. }));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::tiny(1, 1, 64);
        c.access(0x000, true); // dirty fill
        match c.access(0x040, false) {
            Access::Miss { writeback } => assert!(writeback),
            _ => panic!("expected miss"),
        }
    }

    #[test]
    fn working_set_behavior() {
        // working set smaller than capacity → near-zero steady-state misses
        let mut c = Cache::new(32 * 1024, 8, 64);
        let addrs: Vec<u64> = (0..256u64).map(|i| i * 64).collect();
        for &a in &addrs {
            c.access(a, false);
        }
        let misses_cold = c.misses;
        for _ in 0..10 {
            for &a in &addrs {
                c.access(a, false);
            }
        }
        assert_eq!(c.misses, misses_cold, "steady state must not miss");
    }

    #[test]
    fn line_primitives_match_access_semantics() {
        // the decomposed probe/fill path must agree with `access` on the
        // same stream (hit/miss outcomes and victim choice)
        let mut via_access = Cache::tiny(2, 2, 64);
        let mut via_prims = Cache::tiny(2, 2, 64);
        let stream = [0u64, 1, 0, 2, 0, 1, 3, 2];
        for &line in &stream {
            let hit = matches!(via_access.access(line * 64, false), Access::Hit);
            let phit = via_prims.touch_line(line, false);
            if !phit {
                via_prims.fill_line(line, false);
            }
            assert_eq!(hit, phit, "line {line}");
        }
        assert_eq!(via_access.resident_lines(), via_prims.resident_lines());
    }

    #[test]
    fn fill_line_reports_victims_and_take_removes() {
        let mut c = Cache::tiny(1, 1, 64); // one slot
        assert_eq!(c.fill_line(5, true), None);
        assert!(c.contains_line(5));
        // filling a second line evicts the dirty first one
        assert_eq!(c.fill_line(9, false), Some(Evicted { line: 5, dirty: true }));
        assert!(!c.contains_line(5) && c.contains_line(9));
        assert_eq!(c.take_line(9), Some(false));
        assert_eq!(c.take_line(9), None);
        assert_eq!(c.resident_lines(), Vec::<u64>::new());
    }

    #[test]
    fn mark_dirty_does_not_refresh_lru() {
        let mut c = Cache::tiny(2, 2, 64); // one set, two ways
        c.fill_line(1, false);
        c.fill_line(2, false);
        assert!(c.mark_dirty_line(1)); // dirty, but still the LRU victim
        let v = c.fill_line(3, false).expect("set is full");
        assert_eq!(v, Evicted { line: 1, dirty: true });
        assert!(!c.mark_dirty_line(7), "absent line cannot be dirtied");
    }

    #[test]
    fn refill_of_resident_line_merges_instead_of_evicting() {
        let mut c = Cache::tiny(2, 2, 64);
        c.fill_line(1, false);
        c.fill_line(2, false);
        assert_eq!(c.fill_line(1, true), None, "re-fill must not evict");
        assert_eq!(c.take_line(1), Some(true), "dirty bit merged");
    }

    #[test]
    fn policy_constructor_with_lru_matches_the_default_cache() {
        // Cache::new and with_policy(Lru) must be the same machine
        let mut a = Cache::new(1024, 2, 64);
        let mut b = Cache::with_policy(1024, 2, 64, ReplacementKind::Lru);
        assert_eq!(b.replacement(), ReplacementKind::Lru);
        let stream: Vec<u64> = (0..200u64).map(|i| (i * 7) % 37 * 64).collect();
        for &addr in &stream {
            assert_eq!(a.access(addr, addr % 3 == 0), b.access(addr, addr % 3 == 0));
        }
        assert_eq!(a.resident_lines(), b.resident_lines());
        assert_eq!((a.hits, a.misses, a.writebacks), (b.hits, b.misses, b.writebacks));
    }

    #[test]
    fn srrip_protects_a_reused_line_from_a_scan() {
        // 1 set × 2 ways. Fill A (rrpv 2), fill B (rrpv 2), hit A
        // (rrpv 0). The next fill must age to (A=1, B=3) and evict B —
        // LRU would instead have evicted A's set-mate by recency alone.
        let mut c = Cache::with_policy(2 * 64, 2, 64, ReplacementKind::Rrip);
        assert_eq!(c.replacement(), ReplacementKind::Rrip);
        c.access(0x000, false); // A
        c.access(0x040, false); // B
        assert_eq!(c.access(0x000, false), Access::Hit);
        assert!(matches!(c.access(0x080, false), Access::Miss { .. })); // evicts B
        assert_eq!(c.access(0x000, false), Access::Hit, "reused line survived the scan");
        assert!(matches!(c.access(0x040, false), Access::Miss { .. }), "distant line evicted");
    }

    #[test]
    fn rrip_victim_scan_ages_and_breaks_ties_low() {
        let mut rrpvs = vec![1u8, 2, 2];
        assert_eq!(rrip_victim(&mut rrpvs), 1, "first distant way wins");
        assert_eq!(rrpvs, vec![2, 3, 3], "aging applied once");
        let mut tied = vec![RRPV_MAX, RRPV_MAX];
        assert_eq!(rrip_victim(&mut tied), 0, "ties break to the lowest way");
    }

    #[test]
    fn drrip_is_deterministic_and_degenerates_to_srrip_on_one_set() {
        // a 1-set cache has only the SRRIP leader set, so DRRIP must
        // reproduce SRRIP exactly; two DRRIP runs must agree bit-for-bit
        let stream: Vec<u64> = (0..500u64).map(|i| (i * 13) % 29 * 64).collect();
        let mut srrip = Cache::with_policy(4 * 64, 4, 64, ReplacementKind::Rrip);
        let mut d1 = Cache::with_policy(4 * 64, 4, 64, ReplacementKind::Drrip);
        let mut d2 = Cache::with_policy(4 * 64, 4, 64, ReplacementKind::Drrip);
        for &addr in &stream {
            let r = srrip.access(addr, false);
            assert_eq!(d1.access(addr, false), r);
            assert_eq!(d2.access(addr, false), r);
        }
        assert_eq!(d1.resident_lines(), d2.resident_lines());
        assert_eq!(d1.resident_lines(), srrip.resident_lines());
        assert_eq!((d1.hits, d1.misses), (srrip.hits, srrip.misses));
    }

    #[test]
    fn rrip_line_primitives_match_access_semantics() {
        // the hierarchy replay drives caches through the decomposed
        // probe/fill primitives; they must agree with `access` under the
        // RRIP policies too
        for kind in [ReplacementKind::Rrip, ReplacementKind::Drrip] {
            let mut via_access = Cache::with_policy(4 * 64, 2, 64, kind);
            let mut via_prims = Cache::with_policy(4 * 64, 2, 64, kind);
            let stream: Vec<u64> = (0..300u64).map(|i| (i * 11) % 23).collect();
            for &line in &stream {
                let hit = matches!(via_access.access(line * 64, false), Access::Hit);
                let phit = via_prims.touch_line(line, false);
                if !phit {
                    via_prims.fill_line_after_miss(line, false);
                }
                assert_eq!(hit, phit, "{kind:?} line {line}");
            }
            assert_eq!(via_access.resident_lines(), via_prims.resident_lines());
        }
    }

    #[test]
    fn replacement_kind_names_round_trip() {
        for kind in [ReplacementKind::Lru, ReplacementKind::Rrip, ReplacementKind::Drrip] {
            assert_eq!(ReplacementKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(ReplacementKind::from_name("plru"), None);
        assert_eq!(ReplacementKind::default(), ReplacementKind::Lru);
    }

    #[test]
    fn hierarchy_propagates() {
        let mut h = Hierarchy::new(vec![Cache::tiny(2, 2, 64), Cache::new(4096, 4, 64)]);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 2); // cold: straight to memory
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 0);
        // knock 0x1000 out of the 2-line L1 but not out of L2
        h.access(0x2000, false);
        h.access(0x3000, false);
        let o = h.access(0x1000, false);
        assert_eq!(o.hit_level, 1);
    }
}
