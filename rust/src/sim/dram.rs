//! Ramulator-lite: command-level DRAM timing with per-bank row-buffer
//! state and a shared data bus (the paper extends Ramulator [12] with
//! processing units; this is the timing core that extension drives).
//!
//! Model: per bank — open row, earliest next-activate time (tRAS/tRP
//! honored); per channel — data-bus busy window. A request's service is:
//! row hit → tCL; row closed → tRCD+tCL; row conflict → tRP+tRCD+tCL;
//! then tBL burst clocks on the data bus. Requests are issued in arrival
//! order (the in-order PEs and the host miss stream are both ordered), so
//! FR-FCFS reduces to FCFS with row-state awareness — the row-locality
//! effect the EDP comparison needs is fully retained.

use super::config::DramConfig;

#[derive(Debug, Clone, Copy, Default)]
struct Bank {
    open_row: Option<u64>,
    /// Earliest clock the bank may issue the next ACT.
    next_act: u64,
    /// Earliest clock the bank may issue PRE (tRAS after last ACT).
    next_pre: u64,
}

/// One DRAM channel/vault timing model. All times in DRAM clocks.
#[derive(Debug, Clone)]
pub struct Dram {
    cfg: DramConfig,
    banks: Vec<Bank>,
    bus_free: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub requests: u64,
}

/// Completed request timing.
#[derive(Debug, Clone, Copy)]
pub struct Served {
    /// Clock at which the full burst has transferred.
    pub done: u64,
    /// Pure service latency in clocks (done - issue).
    pub latency: u64,
    /// Whether the open row was hit (occupancy accounting).
    pub row_hit: bool,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Dram {
        let banks = vec![Bank::default(); cfg.n_banks];
        Dram {
            cfg,
            banks,
            bus_free: 0,
            row_hits: 0,
            row_misses: 0,
            row_conflicts: 0,
            requests: 0,
        }
    }

    pub fn cfg(&self) -> &DramConfig {
        &self.cfg
    }

    fn bank_and_row(&self, addr: u64) -> (usize, u64) {
        let row_addr = addr / self.cfg.row_bytes;
        // interleave rows across banks
        let bank = (row_addr as usize) % self.cfg.n_banks;
        (bank, row_addr / self.cfg.n_banks as u64)
    }

    /// Serve one line request arriving at `now` (DRAM clocks).
    pub fn request(&mut self, addr: u64, now: u64) -> Served {
        self.requests += 1;
        let (bi, row) = self.bank_and_row(addr);
        let c = &self.cfg;
        let bank = &mut self.banks[bi];

        let mut t = now.max(bank.next_act.min(u64::MAX));
        let mut row_hit = false;
        let cas_ready = match bank.open_row {
            Some(r) if r == row => {
                self.row_hits += 1;
                row_hit = true;
                t.max(bank.next_act) + c.t_cl
            }
            Some(_) => {
                self.row_conflicts += 1;
                // PRE (respect tRAS) then ACT then CAS
                let pre_at = t.max(bank.next_pre);
                let act_at = pre_at + c.t_rp;
                bank.next_pre = act_at + c.t_ras;
                bank.next_act = act_at + c.t_rcd;
                act_at + c.t_rcd + c.t_cl
            }
            None => {
                self.row_misses += 1;
                let act_at = t;
                bank.next_pre = act_at + c.t_ras;
                bank.next_act = act_at + c.t_rcd;
                act_at + c.t_rcd + c.t_cl
            }
        };
        bank.open_row = Some(row);

        let start = cas_ready.max(self.bus_free);
        let done = start + c.t_bl;
        self.bus_free = done;
        t = t.min(now); // silence unused-assign lint path
        let _ = t;
        Served { done, latency: done - now, row_hit }
    }

    /// Convert clocks to nanoseconds.
    pub fn clocks_to_ns(&self, clocks: u64) -> f64 {
        clocks as f64 * self.cfg.ns_per_clock()
    }

    /// Rebase the time origin to 0 (used at region barriers, whose local
    /// clocks restart): open-row contents persist — the row buffer is
    /// physical state — but all pending timing reservations are cleared.
    pub fn reset_time(&mut self) {
        for b in &mut self.banks {
            b.next_act = 0;
            b.next_pre = 0;
        }
        self.bus_free = 0;
    }

    pub fn row_hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.row_hits as f64 / self.requests as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vault() -> Dram {
        Dram::new(DramConfig::hmc_vault())
    }

    #[test]
    fn row_hit_is_faster_than_conflict() {
        let mut d = vault();
        let first = d.request(0, 0); // cold activate
        let hit = d.request(64, first.done); // same 256B row
        let c = d.cfg().clone();
        assert_eq!(hit.latency, c.t_cl + c.t_bl);
        // new row, same bank region → conflict path is strictly slower
        let conflict = d.request(c.row_bytes * c.n_banks as u64, hit.done + 100);
        assert!(conflict.latency > hit.latency);
        assert_eq!(d.row_hits, 1);
        assert!(d.row_conflicts >= 1);
    }

    #[test]
    fn sequential_stream_mostly_row_hits() {
        let mut d = vault();
        let mut now = 0;
        for i in 0..64u64 {
            let s = d.request(i * 64, now);
            now = s.done;
        }
        // 256B rows of 64B lines → 4 lines/row → 75% hit rate
        assert!((d.row_hit_rate() - 0.75).abs() < 0.05, "{}", d.row_hit_rate());
    }

    #[test]
    fn random_stream_mostly_misses_rows() {
        let mut d = vault();
        let mut rng = crate::util::Rng::new(3);
        let mut now = 0;
        for _ in 0..256 {
            let s = d.request(rng.below(1 << 22) * 64, now);
            now = s.done;
        }
        assert!(d.row_hit_rate() < 0.3, "{}", d.row_hit_rate());
    }

    #[test]
    fn bus_serializes_bursts() {
        let mut d = vault();
        // two same-row requests at the same instant: second waits for bus
        let a = d.request(0, 0);
        let b = d.request(64, 0);
        assert!(b.done >= a.done + d.cfg().t_bl);
    }

    #[test]
    fn completion_monotone_per_bank() {
        let mut d = vault();
        let mut rng = crate::util::Rng::new(9);
        let mut now = 0;
        let mut last_done = 0;
        for _ in 0..500 {
            let s = d.request(rng.below(1 << 20) * 64, now);
            assert!(s.done >= now, "completion before issue");
            last_done = s.done.max(last_done);
            now += 2;
        }
        assert!(last_done > 0);
    }
}
