//! Machine models (paper §III-A, Fig 2): the Power9-class host and the
//! HMC-based NMC system, both driven by the same region/task trace so the
//! EDP comparison (Fig 4) holds work constant across machines.
//!
//! * [`task_trace`] — segments the instrumentation stream into
//!   barrier-separated serial/parallel regions (the Pin-trace step).
//! * [`cache`] — set-associative LRU caches (host hierarchy, PE L1s).
//! * [`dram`] — Ramulator-lite command-level DRAM timing (DDR4 channel and
//!   HMC vaults share the model with different parameters).
//! * [`host_system`] / [`nmc_system`] — the two machines.
//! * [`edp`] — the energy-delay-product comparison.
//! * [`config`] — Table 1 parameters + the energy table.

pub mod cache;
pub mod config;
pub mod dram;
pub mod edp;
pub mod host_system;
pub mod nmc_system;
pub mod task_trace;

pub use config::{DramConfig, EnergyConfig, HostConfig, NmcConfig};
pub use edp::EdpComparison;
pub use host_system::{simulate_host, HostResult, HostSystem};
pub use nmc_system::{simulate_nmc, NmcResult, NmcSystem};
pub use task_trace::{collect, Region, Task, TaskTraceCollector};

use anyhow::Result;

/// Full host-vs-NMC comparison for one program (collect trace once, run
/// both machines). `ilp` is the measured ILP_256 from the analysis pass.
pub fn compare(prog: &crate::ir::Program, ilp: f64) -> Result<EdpComparison> {
    let regions = collect(prog)?;
    Ok(EdpComparison {
        app: prog.func.name.clone(),
        host: simulate_host(&regions, ilp),
        nmc: simulate_nmc(&regions),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn compare_runs_end_to_end_on_real_kernel() {
        let k = by_name("atax").unwrap();
        let prog = k.build(24, 1);
        let cmp = compare(&prog, 2.0).unwrap();
        assert!(cmp.host.time_s > 0.0);
        assert!(cmp.nmc.time_s > 0.0);
        assert!(cmp.edp_improvement() > 0.0);
    }

    #[test]
    fn same_work_on_both_machines() {
        let k = by_name("gesummv").unwrap();
        let prog = k.build(16, 2);
        let cmp = compare(&prog, 2.0).unwrap();
        assert_eq!(cmp.host.dyn_instrs, cmp.nmc.dyn_instrs);
    }
}
