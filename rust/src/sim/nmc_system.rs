//! The NMC machine model: 32 in-order single-issue PEs in the logic layer
//! of an HMC, one PE per vault, each with a small L1 (paper Table 1,
//! modeled after Ahn+15 / Gao+15 as the paper states).
//!
//! Execution semantics (see `task_trace`): parallel regions fan their
//! iteration tasks across PEs in contiguous blocks (OpenMP-static style)
//! with a barrier at region end; serial regions run on PE 0.
//!
//! Timing model: each PE sees a *private* command-level DRAM view per
//! vault (row-buffer locality of its own stream), and cross-PE vault
//! contention is applied at the region barrier: the region takes
//! max(slowest PE, hottest vault's total occupancy) — the two physical
//! bottlenecks of a vault-partitioned PIM. This avoids the time-travel
//! artifacts of replaying per-PE streams through one shared absolute-time
//! bus model while keeping both locality and bandwidth-saturation effects.

use super::cache::{Access, Cache};
use super::config::{EnergyConfig, NmcConfig};
use super::dram::Dram;
use super::task_trace::{Region, Task};

/// Simulation result for one application on the NMC system.
#[derive(Debug, Clone)]
pub struct NmcResult {
    pub time_s: f64,
    pub energy_j: f64,
    pub dyn_instrs: u64,
    pub l1_misses: u64,
    pub dram_lines: u64,
    pub remote_lines: u64,
    /// Fraction of instructions executed inside parallel regions.
    pub parallel_fraction: f64,
    pub row_hit_rate: f64,
    /// Fraction of total time attributable to hot-vault serialization
    /// (bandwidth-bound) rather than the slowest PE (latency-bound).
    pub vault_bound_fraction: f64,
}

impl NmcResult {
    pub fn edp(&self) -> f64 {
        self.energy_j * self.time_s
    }
}

/// The simulator.
pub struct NmcSystem {
    cfg: NmcConfig,
    energy: EnergyConfig,
    /// Persistent per-PE L1s (physical caches survive region barriers).
    caches: Vec<Cache>,
    /// Per-PE private DRAM timing views, one per vault.
    pe_vaults: Vec<Vec<Dram>>,
    /// Per-vault occupancy within the current region (ns).
    vault_busy_ns: Vec<f64>,
    now_ns: f64,
    // accounting
    instrs: u64,
    l1_misses: u64,
    dram_lines: u64,
    remote_lines: u64,
    par_instrs: u64,
    row_hits: u64,
    vault_bound_ns: f64,
    heavy_cost: u64,
}

impl NmcSystem {
    pub fn new(cfg: NmcConfig, energy: EnergyConfig) -> Self {
        let caches = (0..cfg.n_pes)
            .map(|_| Cache::tiny(cfg.l1_lines, cfg.l1_ways, cfg.line_bytes))
            .collect();
        let pe_vaults = (0..cfg.n_pes)
            .map(|_| {
                (0..cfg.n_vaults)
                    .map(|_| Dram::new(cfg.dram.clone()))
                    .collect()
            })
            .collect();
        let vault_busy_ns = vec![0.0; cfg.n_vaults];
        NmcSystem {
            cfg,
            energy,
            caches,
            pe_vaults,
            vault_busy_ns,
            now_ns: 0.0,
            instrs: 0,
            l1_misses: 0,
            dram_lines: 0,
            remote_lines: 0,
            par_instrs: 0,
            row_hits: 0,
            vault_bound_ns: 0.0,
            heavy_cost: 12,
        }
    }

    fn vault_of(&self, addr: u64) -> usize {
        ((addr / self.cfg.vault_block_bytes) as usize) % self.cfg.n_vaults
    }

    fn pe_cycles_to_ns(&self, c: u64) -> f64 {
        c as f64 / self.cfg.freq_ghz
    }

    /// Execute one task on PE `pe_id`; `cycles` is the PE's local clock
    /// relative to the region start. Returns the updated clock.
    fn run_task(&mut self, pe_id: usize, mut cycles: u64, task: &Task) -> u64 {
        cycles += task.simple_ops + task.heavy_ops * self.heavy_cost;
        self.instrs += task.instrs();
        for &(addr, is_store) in &task.accesses {
            cycles += self.cfg.l1_lat;
            match self.caches[pe_id].access(addr, is_store) {
                Access::Hit => {}
                Access::Miss { writeback } => {
                    self.l1_misses += 1;
                    let vault = self.vault_of(addr);
                    let remote = vault != pe_id % self.cfg.n_vaults;
                    let mut extra_ns = 0.0;
                    if remote {
                        self.remote_lines += 1;
                        extra_ns += self.cfg.remote_vault_ns;
                    }
                    let clk_ghz = self.cfg.dram.clock_ghz;
                    let (t_bl, t_act) = (
                        self.cfg.dram.t_bl,
                        self.cfg.dram.t_rcd + self.cfg.dram.t_rp,
                    );
                    let clocks = (self.pe_cycles_to_ns(cycles) * clk_ghz) as u64;
                    let served = self.pe_vaults[pe_id][vault].request(addr, clocks);
                    self.dram_lines += 1;
                    if served.row_hit {
                        self.row_hits += 1;
                    }
                    // vault occupancy: burst + (activate unless row hit)
                    let occ = t_bl + if served.row_hit { 0 } else { t_act };
                    self.vault_busy_ns[vault] += occ as f64 / clk_ghz;
                    if writeback {
                        let wb = self.pe_vaults[pe_id][vault].request(addr ^ 0x40, served.done);
                        self.dram_lines += 1;
                        self.vault_busy_ns[vault] += t_bl as f64 / clk_ghz;
                        let _ = wb;
                    }
                    let lat_ns = served.latency as f64 / clk_ghz + extra_ns;
                    // in-order PE stalls for the full line fill
                    cycles += (lat_ns * self.cfg.freq_ghz).ceil() as u64;
                }
            }
        }
        cycles
    }

    /// Close a region: advance global time by the bottleneck — the slowest
    /// PE or the hottest vault — and reset per-region occupancy.
    fn barrier(&mut self, span_cycles: u64) {
        let span_ns = self.pe_cycles_to_ns(span_cycles);
        let hot_ns = self.vault_busy_ns.iter().cloned().fold(0.0f64, f64::max);
        if hot_ns > span_ns {
            self.vault_bound_ns += hot_ns - span_ns;
        }
        self.now_ns += span_ns.max(hot_ns);
        self.vault_busy_ns.iter_mut().for_each(|v| *v = 0.0);
        // region-local clocks restart at the barrier: rebase every DRAM
        // view's timing reservations (row-buffer contents persist)
        for pv in &mut self.pe_vaults {
            for d in pv {
                d.reset_time();
            }
        }
    }

    /// Simulate one region stream; call once per application.
    pub fn run(&mut self, regions: &[Region]) -> NmcResult {
        for region in regions {
            match region {
                Region::Serial(task) => {
                    let c = self.run_task(0, 0, task);
                    self.barrier(c);
                }
                Region::Parallel(tasks) => {
                    let active = self.cfg.n_pes.min(tasks.len());
                    let mut clocks = vec![0u64; active];
                    for (t_idx, task) in tasks.iter().enumerate() {
                        self.par_instrs += task.instrs();
                        // blocked static scheduling (OpenMP-static style):
                        // PE p runs a contiguous chunk of iterations, which
                        // preserves each PE's line/row locality; the hop a
                        // PE pays for non-local data is the cheap intra-
                        // stack network (remote_vault_ns/nmc_remote_line_pj).
                        let pe_id = (t_idx * active) / tasks.len();
                        clocks[pe_id] = self.run_task(pe_id, clocks[pe_id], task);
                    }
                    let max_c = clocks.iter().copied().max().unwrap_or(0);
                    self.barrier(max_c);
                }
            }
        }

        let time_s = self.now_ns * 1e-9;
        let e = &self.energy;
        let energy_j = (self.instrs as f64 * e.nmc_instr_pj
            + self.dram_lines as f64 * e.nmc_dram_line_pj
            + self.remote_lines as f64 * e.nmc_remote_line_pj)
            * 1e-12
            + e.nmc_static_w * time_s;
        NmcResult {
            time_s,
            energy_j,
            dyn_instrs: self.instrs,
            l1_misses: self.l1_misses,
            dram_lines: self.dram_lines,
            remote_lines: self.remote_lines,
            parallel_fraction: if self.instrs == 0 {
                0.0
            } else {
                self.par_instrs as f64 / self.instrs as f64
            },
            row_hit_rate: if self.dram_lines == 0 {
                0.0
            } else {
                self.row_hits as f64 / self.dram_lines as f64
            },
            vault_bound_fraction: if self.now_ns == 0.0 {
                0.0
            } else {
                self.vault_bound_ns / self.now_ns
            },
        }
    }
}

/// One-shot convenience.
pub fn simulate_nmc(regions: &[Region]) -> NmcResult {
    NmcSystem::new(NmcConfig::default(), EnergyConfig::default()).run(regions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::sim::task_trace::collect;

    fn map_program(n: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("map");
        let a = b.alloc_f64("a", n);
        let nn = b.const_i(n as i64);
        let c = b.const_f(2.0);
        b.counted_loop(nn, |b, i| {
            b.store_f64(a, i, c);
        });
        b.finish(None)
    }

    fn serial_program(n: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("ser");
        let a = b.alloc_f64("a", n);
        let acc = b.const_f(0.0);
        let nn = b.const_i(n as i64);
        b.counted_loop(nn, |b, i| {
            let v = b.load_f64(a, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        b.finish(Some(acc))
    }

    #[test]
    fn produces_time_and_energy() {
        let regions = collect(&map_program(512)).unwrap();
        let r = simulate_nmc(&regions);
        assert!(r.time_s > 0.0);
        assert!(r.energy_j > 0.0);
        assert!(r.dyn_instrs > 512);
        assert!(r.parallel_fraction > 0.5, "{}", r.parallel_fraction);
    }

    #[test]
    fn parallel_compute_scales_with_pe_count() {
        // balanced pure-compute region: time ≈ total / n_pes
        let tasks: Vec<Task> = (0..64)
            .map(|_| Task { simple_ops: 50_000, heavy_ops: 0, accesses: vec![] })
            .collect();
        let r = simulate_nmc(&[Region::Parallel(tasks)]);
        let want = (64.0 * 50_000.0) / 32.0 / 1.25e9;
        assert!(
            (r.time_s - want).abs() / want < 0.05,
            "got {} want {want}",
            r.time_s
        );
    }

    #[test]
    fn imbalanced_region_bounded_by_slowest_pe() {
        let mut tasks: Vec<Task> = (0..31)
            .map(|_| Task { simple_ops: 1000, heavy_ops: 0, accesses: vec![] })
            .collect();
        tasks.push(Task { simple_ops: 500_000, heavy_ops: 0, accesses: vec![] });
        let r = simulate_nmc(&[Region::Parallel(tasks)]);
        let floor = 500_000.0 / 1.25e9;
        assert!(r.time_s >= floor, "barrier must wait for the straggler");
    }

    #[test]
    fn parallel_map_faster_than_serialized_map() {
        let regions = collect(&map_program(4096)).unwrap();
        let par = simulate_nmc(&regions);
        let serialized: Vec<Region> = regions
            .iter()
            .map(|r| match r {
                Region::Parallel(ts) => {
                    let mut merged = Task::default();
                    for t in ts {
                        merged.simple_ops += t.simple_ops;
                        merged.heavy_ops += t.heavy_ops;
                        merged.accesses.extend(t.accesses.iter().copied());
                    }
                    Region::Serial(merged)
                }
                Region::Serial(t) => Region::Serial(t.clone()),
            })
            .collect();
        let ser = simulate_nmc(&serialized);
        assert!(
            par.time_s < ser.time_s / 2.0,
            "parallel {} vs serial {}",
            par.time_s,
            ser.time_s
        );
    }

    #[test]
    fn hot_vault_serializes_bandwidth() {
        // 32 PEs × 512 cold lines each, ALL inside one vault block → the
        // vault's occupancy, not PE latency, bounds the region
        let tasks: Vec<Task> = (0..32u64)
            .map(|p| Task {
                simple_ops: 1,
                heavy_ops: 0,
                accesses: (0..512u64)
                    .map(|i| (((p * 512 + i) * 64) % 2048, false))
                    .collect(),
            })
            .collect();
        let r = simulate_nmc(&[Region::Parallel(tasks)]);
        assert!(r.time_s > 0.0);
        assert!(
            r.vault_bound_fraction > 0.5,
            "hot vault must dominate: {}",
            r.vault_bound_fraction
        );
    }

    #[test]
    fn serial_reduction_gets_no_parallel_speedup() {
        let regions = collect(&serial_program(1024)).unwrap();
        let r = simulate_nmc(&regions);
        assert!(r.parallel_fraction < 0.05);
    }

    #[test]
    fn energy_scales_with_work() {
        // sizes above the offload threshold so both fan out; 16x the work
        // must cost clearly more energy (dynamic + static·time both scale)
        let small = simulate_nmc(&collect(&map_program(2048)).unwrap());
        let large = simulate_nmc(&collect(&map_program(32768)).unwrap());
        assert!(
            large.energy_j > 4.0 * small.energy_j,
            "small {} large {}",
            small.energy_j,
            large.energy_j
        );
    }
}
