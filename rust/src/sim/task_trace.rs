//! Task-trace collection: turns the single-threaded instrumentation stream
//! into the *region/task* form both machine models consume (the "Pin trace
//! fed to Ramulator" of paper §III-A).
//!
//! Regions are barrier-separated phases:
//! * [`Region::Parallel`] — one loop invocation whose iterations have no
//!   cross-iteration register/memory dependences (induction registers
//!   excluded — the PBBLP criterion). Its tasks (= iterations, including
//!   everything nested inside them) may spread across the 32 NMC PEs.
//! * [`Region::Serial`] — everything else, in trace order.
//!
//! Dependences are tracked at **every** nesting level simultaneously, and
//! parallelism is harvested at the *outermost* level that qualifies: an
//! outer loop whose iterations are independent becomes one parallel region
//! of whole-iteration tasks (atax rows, kmeans points, bfs sweep nodes);
//! when an outer level is serial, the collector recurses and still
//! recovers inner parallel loops (gramschmidt's column updates inside the
//! serial k loop). Reads of data written before an invocation opened never
//! count as cross-iteration dependences, and write-after-write without an
//! intervening read is allowed (commutative flag/accumulator stores).
//!
//! This is how "each processing unit operates on the data assigned to that
//! vault" becomes concrete for a single-threaded source trace: only
//! provably data-parallel loops fan out; everything else runs on one PE.
//! The host model runs the same stream fully serialized, so both machines
//! execute identical dynamic work.

use std::collections::HashMap;
use crate::util::FastMap;

use crate::analysis::dataflow::MEM_GRANULE_SHIFT;
use crate::interp::{Instrument, TraceEvent};
use crate::ir::{BlockId, LoopInfo, Program, Reg};

/// One schedulable unit of work (a loop iteration or serial glue).
#[derive(Debug, Clone, Default)]
pub struct Task {
    pub simple_ops: u64,
    pub heavy_ops: u64,
    /// (address, is_store) in execution order.
    pub accesses: Vec<(u64, bool)>,
}

impl Task {
    pub fn instrs(&self) -> u64 {
        self.simple_ops + self.heavy_ops + self.accesses.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.instrs() == 0
    }

    pub fn merge(&mut self, other: Task) {
        self.simple_ops += other.simple_ops;
        self.heavy_ops += other.heavy_ops;
        self.accesses.extend(other.accesses);
    }
}

/// A barrier-separated execution phase.
#[derive(Debug, Clone)]
pub enum Region {
    Serial(Task),
    /// Iterations of one data-parallel loop invocation (tasks include all
    /// nested work).
    Parallel(Vec<Task>),
}

impl Region {
    pub fn instrs(&self) -> u64 {
        match self {
            Region::Serial(t) => t.instrs(),
            Region::Parallel(ts) => ts.iter().map(|t| t.instrs()).sum(),
        }
    }
}

// ---------------------------------------------------------------------------
// region tree (built during the run, flattened at finalize)

#[derive(Debug)]
enum TNode {
    Glue(Task),
    Loop {
        parallel: bool,
        /// iterations[i] = the nodes executed during iteration i.
        iterations: Vec<Vec<TNode>>,
    },
}

fn merge_into(task: &mut Task, nodes: Vec<TNode>) {
    for n in nodes {
        match n {
            TNode::Glue(t) => task.merge(t),
            TNode::Loop { iterations, .. } => {
                for it in iterations {
                    merge_into(task, it);
                }
            }
        }
    }
}

fn node_instrs(n: &TNode) -> u64 {
    match n {
        TNode::Glue(t) => t.instrs(),
        TNode::Loop { iterations, .. } => iterations
            .iter()
            .map(|it| it.iter().map(node_instrs).sum::<u64>())
            .sum(),
    }
}

fn flatten(nodes: Vec<TNode>, serial_acc: &mut Task, out: &mut Vec<Region>) {
    for n in nodes {
        match n {
            TNode::Glue(t) => serial_acc.merge(t),
            TNode::Loop { parallel, iterations } => {
                // offload threshold: fanning a loop across PEs costs a
                // barrier and cold caches; a real runtime keeps tiny loops
                // on one core. Loops below the threshold stay serial.
                let work: u64 = iterations
                    .iter()
                    .map(|it| it.iter().map(node_instrs).sum::<u64>())
                    .sum();
                if parallel && iterations.len() >= 4 && work >= 2048 {
                    if !serial_acc.is_empty() {
                        out.push(Region::Serial(std::mem::take(serial_acc)));
                    }
                    let tasks: Vec<Task> = iterations
                        .into_iter()
                        .map(|it| {
                            let mut t = Task::default();
                            merge_into(&mut t, it);
                            t
                        })
                        .filter(|t| !t.is_empty())
                        .collect();
                    if !tasks.is_empty() {
                        out.push(Region::Parallel(tasks));
                    }
                } else {
                    // serial loop: recurse — inner parallel loops re-emerge
                    for it in iterations {
                        flatten(it, serial_acc, out);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// collector

struct Frame {
    loop_idx: usize,
    /// Work before the first body entry (initial header evaluation) —
    /// emitted as serial glue ahead of the loop node.
    preamble: Task,
    /// Completed iterations (each a node list).
    iterations: Vec<Vec<TNode>>,
    /// Node list of the currently open iteration (None between iterations).
    open: Option<Vec<TNode>>,
    /// Glue accumulator inside the open iteration.
    glue: Task,
    dep_found: bool,
    reg_writer: FastMap<Reg, u64>,
    mem_writer: FastMap<u64, u64>,
}

impl Frame {
    fn new(loop_idx: usize) -> Frame {
        Frame {
            loop_idx,
            preamble: Task::default(),
            iterations: Vec::new(),
            open: None,
            glue: Task::default(),
            dep_found: false,
            reg_writer: FastMap::default(),
            mem_writer: FastMap::default(),
        }
    }

    fn iter_idx(&self) -> u64 {
        self.iterations.len() as u64
    }

    fn flush_glue(&mut self) {
        if !self.glue.is_empty() {
            let t = std::mem::take(&mut self.glue);
            if let Some(open) = self.open.as_mut() {
                open.push(TNode::Glue(t));
            } else if let Some(last) = self.iterations.last_mut() {
                // between-iterations header evaluation (~the loop cmp):
                // charge it to the previous iteration
                last.push(TNode::Glue(t));
            } else {
                // before the first body entry: serial preamble
                self.preamble.merge(t);
            }
        }
    }

    fn close_iteration(&mut self) {
        self.flush_glue();
        if let Some(nodes) = self.open.take() {
            self.iterations.push(nodes);
        }
    }
}

/// Streaming collector (an [`Instrument`]).
pub struct TaskTraceCollector {
    header_of: HashMap<BlockId, usize>,
    loops: Vec<LoopInfo>,
    stack: Vec<Frame>,
    /// Top-level nodes (no loop active).
    root: Vec<TNode>,
    root_glue: Task,
}

impl TaskTraceCollector {
    pub fn new(prog: &Program) -> Self {
        TaskTraceCollector {
            header_of: prog
                .loops
                .iter()
                .enumerate()
                .map(|(i, l)| (l.header, i))
                .collect(),
            loops: prog.loops.clone(),
            stack: Vec::new(),
            root: Vec::new(),
            root_glue: Task::default(),
        }
    }

    fn flush_root_glue(&mut self) {
        if !self.root_glue.is_empty() {
            let t = std::mem::take(&mut self.root_glue);
            self.root.push(TNode::Glue(t));
        }
    }

    fn pop_frame(&mut self) {
        let mut f = self.stack.pop().expect("loop stack underflow");
        f.close_iteration();
        let mut nodes = Vec::with_capacity(2);
        if !f.preamble.is_empty() {
            nodes.push(TNode::Glue(std::mem::take(&mut f.preamble)));
        }
        nodes.push(TNode::Loop {
            parallel: !f.dep_found,
            iterations: f.iterations,
        });
        match self.stack.last_mut() {
            Some(parent) => {
                parent.flush_glue();
                match parent.open.as_mut() {
                    Some(open) => open.extend(nodes),
                    None => {
                        // inner loop ran during parent header evaluation —
                        // cannot happen with the structured builder, but
                        // stay safe: attach to the last parent iteration
                        if let Some(last) = parent.iterations.last_mut() {
                            last.extend(nodes);
                        } else {
                            parent.preamble = {
                                let mut t = std::mem::take(&mut parent.preamble);
                                merge_into(&mut t, nodes);
                                t
                            };
                        }
                    }
                }
            }
            None => {
                self.flush_root_glue();
                self.root.extend(nodes);
            }
        }
    }

    /// Finish collection and flatten the tree into regions.
    pub fn finalize(mut self) -> Vec<Region> {
        while !self.stack.is_empty() {
            self.pop_frame();
        }
        self.flush_root_glue();
        let mut out = Vec::new();
        let mut acc = Task::default();
        flatten(std::mem::take(&mut self.root), &mut acc, &mut out);
        if !acc.is_empty() {
            out.push(Region::Serial(acc));
        }
        out
    }

    #[inline]
    fn cur_task(&mut self) -> &mut Task {
        match self.stack.last_mut() {
            Some(f) if f.open.is_some() => &mut f.glue,
            Some(f) => &mut f.glue, // header evaluation: flushed on close
            None => &mut self.root_glue,
        }
    }
}

// Chunk delivery uses the default `on_chunk` (a statically-dispatched loop
// over `on_event` — there is no per-chunk state worth hoisting here).
impl Instrument for TaskTraceCollector {
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { block } => {
                if let Some(top) = self.stack.last_mut() {
                    let li = self.loops[top.loop_idx];
                    if *block == li.header {
                        top.close_iteration();
                        return;
                    }
                    if *block == li.body {
                        top.flush_glue(); // header glue → previous iteration
                        top.open = Some(Vec::new());
                        return;
                    }
                    if *block == li.exit {
                        self.pop_frame();
                        return;
                    }
                }
                if let Some(&idx) = self.header_of.get(block) {
                    self.stack.push(Frame::new(idx));
                }
            }
            TraceEvent::Instr(i) => {
                let heavy = matches!(
                    i.op,
                    crate::ir::Op::Div
                        | crate::ir::Op::Rem
                        | crate::ir::Op::FDiv
                        | crate::ir::Op::FSqrt
                        | crate::ir::Op::FExp
                );
                let mem = i.mem;

                // dependence bookkeeping on EVERY active frame: iteration
                // index differs per level (an outer iteration spans many
                // inner ones)
                for (level, f) in self.stack.iter_mut().enumerate() {
                    let _ = level;
                    if f.open.is_none() {
                        // header evaluation of this frame: attribute to the
                        // frame's previous iteration for dep purposes (the
                        // cmp reads the counter only, which is excluded)
                    }
                    let counter = self.loops[f.loop_idx].counter;
                    let cur = f.iter_idx();
                    for &s in i.sources() {
                        if s != counter {
                            if let Some(&j) = f.reg_writer.get(&s) {
                                if j != cur {
                                    f.dep_found = true;
                                }
                            }
                        }
                    }
                    if let Some(m) = mem {
                        let g = m.addr >> MEM_GRANULE_SHIFT;
                        if m.is_store {
                            f.mem_writer.insert(g, cur);
                        } else if let Some(&j) = f.mem_writer.get(&g) {
                            if j != cur {
                                f.dep_found = true;
                            }
                        }
                    }
                    if let Some(d) = i.dst {
                        if d != counter {
                            f.reg_writer.insert(d, cur);
                        }
                    }
                }

                let task = self.cur_task();
                if let Some(m) = mem {
                    task.accesses.push((m.addr, m.is_store));
                } else if heavy {
                    task.heavy_ops += 1;
                } else {
                    task.simple_ops += 1;
                }
            }
            TraceEvent::Branch { .. } => {}
        }
    }
}

/// Convenience: run a program and collect its region trace.
pub fn collect(prog: &Program) -> anyhow::Result<Vec<Region>> {
    let mut c = TaskTraceCollector::new(prog);
    crate::interp::run_program(prog, &mut c)?;
    Ok(c.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;

    fn parallel_sizes(regions: &[Region]) -> Vec<usize> {
        regions
            .iter()
            .filter_map(|r| match r {
                Region::Parallel(ts) => Some(ts.len()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn parallel_map_yields_parallel_region() {
        let mut b = ProgramBuilder::new("map");
        let a = b.alloc_f64("a", 512);
        let n = b.const_i(512);
        let c = b.const_f(2.0);
        b.counted_loop(n, |b, i| {
            b.store_f64(a, i, c);
        });
        let regions = collect(&b.finish(None)).unwrap();
        assert_eq!(parallel_sizes(&regions), vec![512]);
    }

    #[test]
    fn tiny_parallel_loops_stay_serial() {
        // below the offload threshold a data-parallel loop is NOT fanned
        // out (barrier + cold caches would cost more than it saves)
        let mut b = ProgramBuilder::new("tiny");
        let a = b.alloc_f64("a", 8);
        let n = b.const_i(8);
        let c = b.const_f(2.0);
        b.counted_loop(n, |b, i| {
            b.store_f64(a, i, c);
        });
        let regions = collect(&b.finish(None)).unwrap();
        assert!(parallel_sizes(&regions).is_empty());
    }

    #[test]
    fn reduction_stays_serial() {
        let mut b = ProgramBuilder::new("red");
        let a = b.alloc_f64("a", 64);
        let acc = b.const_f(0.0);
        let n = b.const_i(64);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        let regions = collect(&b.finish(Some(acc))).unwrap();
        assert!(regions.iter().all(|r| matches!(r, Region::Serial(_))));
    }

    #[test]
    fn outer_parallel_loop_with_inner_reduction_fans_out_at_outer_level() {
        // tmp[i] = Σ_j A[i][j]·x[j] : the atax-phase-1 shape. The inner
        // reduction is serial, but outer iterations are independent — the
        // region must be ONE Parallel with n whole-row tasks.
        let n = 16usize;
        let mut b = ProgramBuilder::new("rows");
        let a = b.alloc_f64("A", n * n);
        let x = b.alloc_f64("x", n);
        let tmp = b.alloc_f64("tmp", n);
        let nn = b.const_i(n as i64);
        b.counted_loop(nn, |b, i| {
            let acc = b.const_f(0.0);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a, i, j, n as i64);
                let xj = b.load_f64(x, j);
                let p = b.fmul(aij, xj);
                let s = b.fadd(acc, p);
                b.assign(acc, s);
            });
            b.store_f64(tmp, i, acc);
        });
        let regions = collect(&b.finish(None)).unwrap();
        assert_eq!(parallel_sizes(&regions), vec![n]);
        // each task carries the whole inner loop (n loads of A + x + ...)
        if let Some(Region::Parallel(ts)) = regions
            .iter()
            .find(|r| matches!(r, Region::Parallel(_)))
        {
            assert!(ts[0].accesses.len() >= 2 * n);
        }
    }

    #[test]
    fn serial_outer_recovers_inner_parallel() {
        // for k { for i { b[i] = a[i] * k } ; s += b[0] } — the outer loop
        // chains through s, the inner map is parallel each time.
        let n = 256usize;
        let m = 5usize;
        let mut b = ProgramBuilder::new("nest");
        let aa = b.alloc_f64("a", n);
        let bb = b.alloc_f64("b", n);
        let s = b.const_f(0.0);
        let mm = b.const_i(m as i64);
        let nn = b.const_i(n as i64);
        let zero = b.const_i(0);
        b.counted_loop(mm, |b, k| {
            let kf = b.itof(k);
            b.counted_loop(nn, |b, i| {
                let v = b.load_f64(aa, i);
                let w = b.fmul(v, kf);
                b.store_f64(bb, i, w);
            });
            let b0 = b.load_f64(bb, zero);
            let t = b.fadd(s, b0);
            b.assign(s, t);
        });
        let regions = collect(&b.finish(Some(s))).unwrap();
        // outer is serial (s chain + b[0] read of inner writes), inner maps
        // re-emerge: m parallel regions of n tasks
        assert_eq!(parallel_sizes(&regions), vec![n; m]);
    }

    #[test]
    fn write_write_collisions_without_reads_stay_parallel() {
        // every iteration stores to flag[0] (bfs's `over` flag) but nobody
        // reads it inside the loop → still parallel
        let mut b = ProgramBuilder::new("flag");
        let a = b.alloc_f64("a", 512);
        let flag = b.alloc_i64("flag", 1);
        let n = b.const_i(512);
        let zero = b.const_i(0);
        let one = b.const_i(1);
        b.counted_loop(n, |b, i| {
            let c = b.const_f(1.0);
            b.store_f64(a, i, c);
            b.store_i64(flag, zero, one);
        });
        let regions = collect(&b.finish(None)).unwrap();
        assert_eq!(parallel_sizes(&regions), vec![512]);
    }

    #[test]
    fn read_of_other_iterations_write_serializes() {
        // a[i+1] read... written by next iter? make a[i] = a[i-1]+1 chain
        let mut b = ProgramBuilder::new("chain");
        let a = b.alloc_f64("a", 33);
        let n = b.const_i(32);
        let one = b.const_i(1);
        let f1 = b.const_f(1.0);
        b.counted_loop(n, |b, i| {
            let prev = b.load_f64(a, i);
            let v = b.fadd(prev, f1);
            let ip1 = b.add(i, one);
            b.store_f64(a, ip1, v);
        });
        let regions = collect(&b.finish(None)).unwrap();
        assert!(parallel_sizes(&regions).is_empty());
    }

    #[test]
    fn work_is_conserved() {
        let mut b = ProgramBuilder::new("w");
        let a = b.alloc_f64("a", 32);
        let n = b.const_i(32);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fadd(v, v);
            b.store_f64(a, i, w);
        });
        let p = b.finish(None);
        let mut c = TaskTraceCollector::new(&p);
        let (out, _) = crate::interp::run_program(&p, &mut c).unwrap();
        let regions = c.finalize();
        let total: u64 = regions.iter().map(|r| r.instrs()).sum();
        assert_eq!(total, out.stats.dyn_instrs);
    }
}
