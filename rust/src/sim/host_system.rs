//! The host machine model: one Power9-class out-of-order core with an
//! L1/L2/L3 hierarchy and a DDR4 channel (paper Table 1 row 1).
//!
//! Analytical-plus-cache-sim hybrid: compute cycles come from the
//! ILP-limited sustainable IPC (min(issue width, measured ILP_256) — the
//! platform-independent ILP metric doubling as the µarch throughput bound);
//! memory cycles come from driving every access through the simulated
//! hierarchy, with miss latencies overlapped by the configured MLP and DRAM
//! service through the same command-level model the vaults use (row
//! locality kept intact).

use super::cache::{Cache, Hierarchy};
use super::config::{DramConfig, EnergyConfig, HostConfig};
use super::dram::Dram;
use super::task_trace::{Region, Task};

/// Simulation result for one application on the host.
#[derive(Debug, Clone)]
pub struct HostResult {
    pub time_s: f64,
    pub energy_j: f64,
    pub dyn_instrs: u64,
    pub l1_misses: u64,
    pub l2_misses: u64,
    pub l3_misses: u64,
    pub dram_lines: u64,
    pub row_hit_rate: f64,
    pub ipc: f64,
}

impl HostResult {
    pub fn edp(&self) -> f64 {
        self.energy_j * self.time_s
    }
}

/// The simulator. `ilp` is the application's measured ILP (window 256) from
/// the platform-independent analysis; it bounds sustained IPC.
pub struct HostSystem {
    cfg: HostConfig,
    energy: EnergyConfig,
    ilp: f64,
}

impl HostSystem {
    pub fn new(cfg: HostConfig, energy: EnergyConfig, ilp: f64) -> Self {
        HostSystem { cfg, energy, ilp }
    }

    pub fn run(&self, regions: &[Region]) -> HostResult {
        let c = &self.cfg;
        let mut hier = Hierarchy::new(vec![
            Cache::new(c.l1_bytes(), c.l1_ways, c.line_bytes),
            Cache::new(c.l2_bytes(), c.l2_ways, c.line_bytes),
            Cache::new(c.l3_bytes(), c.l3_ways, c.line_bytes),
        ]);
        let mut dram = Dram::new(DramConfig::ddr4());

        let mut instrs = 0u64;
        let mut heavy = 0u64;
        let mut accesses = 0u64;
        let mut l2_hits = 0u64;
        let mut l3_hits = 0u64;
        let mut dram_lines = 0u64;
        let mut mem_stall_cycles = 0f64;
        let mut dram_now_clocks = 0u64;

        let mut visit = |task: &Task| {
            instrs += task.instrs();
            heavy += task.heavy_ops;
            for &(addr, is_store) in &task.accesses {
                accesses += 1;
                let o = hier.access(addr, is_store);
                match o.hit_level {
                    0 => {} // folded into base IPC
                    1 => {
                        l2_hits += 1;
                        mem_stall_cycles += c.l2_lat as f64 / c.mlp;
                    }
                    2 => {
                        l3_hits += 1;
                        mem_stall_cycles += c.l3_lat as f64 / c.mlp;
                    }
                    _ => {
                        // DRAM: command-level service, overlapped by MLP
                        let served = dram.request(addr, dram_now_clocks);
                        dram_now_clocks = served.done;
                        dram_lines += 1;
                        let ns = served.latency as f64 / dram.cfg().clock_ghz
                            + c.dram_lat_ns * 0.25; // controller/queueing adder
                        mem_stall_cycles += ns * c.freq_ghz / c.mlp;
                        if o.dram_writeback {
                            dram_lines += 1;
                        }
                    }
                }
            }
        };

        for region in regions {
            match region {
                Region::Serial(t) => visit(t),
                Region::Parallel(ts) => {
                    for t in ts {
                        visit(t);
                    }
                }
            }
        }

        let ipc = self.ilp.min(c.issue_width).max(0.25);
        let compute_cycles = instrs as f64 / ipc + heavy as f64 * 10.0;
        let cycles = compute_cycles + mem_stall_cycles;
        let time_s = cycles / (c.freq_ghz * 1e9);

        let lv = &hier.levels;
        let (l1m, l2m, l3m) = (lv[0].misses, lv[1].misses, lv[2].misses);
        let e = &self.energy;
        let energy_j = (instrs as f64 * e.host_instr_pj
            + l1m as f64 * e.host_l2_pj
            + l2m as f64 * e.host_l3_pj
            + dram_lines as f64 * e.host_dram_line_pj)
            * 1e-12
            + e.host_static_w * time_s;

        HostResult {
            time_s,
            energy_j,
            dyn_instrs: instrs,
            l1_misses: l1m,
            l2_misses: l2m,
            l3_misses: l3m,
            dram_lines,
            row_hit_rate: dram.row_hit_rate(),
            ipc,
        }
    }
}

/// One-shot convenience with the repro-scaled host (see
/// `HostConfig::scaled_for_repro`): the hierarchy shrinks by the same
/// factor the datasets were scaled so working-set/cache ratios match the
/// paper's Table-2 sizes.
pub fn simulate_host(regions: &[Region], ilp: f64) -> HostResult {
    HostSystem::new(HostConfig::scaled_for_repro(), EnergyConfig::default(), ilp).run(regions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::task_trace::collect;
    use crate::ir::ProgramBuilder;

    fn streaming_program(n: usize) -> crate::ir::Program {
        let mut b = ProgramBuilder::new("stream");
        let a = b.alloc_f64("a", n);
        let nn = b.const_i(n as i64);
        let c = b.const_f(1.0);
        b.counted_loop(nn, |b, i| {
            b.store_f64(a, i, c);
        });
        b.finish(None)
    }

    fn random_walk_program(n: usize) -> crate::ir::Program {
        // pseudo-random strided loads over a large array (cache hostile)
        let mut b = ProgramBuilder::new("rand");
        let a = b.alloc_f64("a", n);
        let nn = b.const_i((n / 2) as i64);
        let stride = b.const_i(7919); // prime stride mod n
        let nmod = b.const_i(n as i64);
        let acc = b.const_f(0.0);
        b.counted_loop(nn, |b, i| {
            let x = b.mul(i, stride);
            let idx = b.rem(x, nmod);
            let v = b.load_f64(a, idx);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        b.finish(Some(acc))
    }

    #[test]
    fn produces_time_and_energy() {
        let r = simulate_host(&collect(&streaming_program(4096)).unwrap(), 3.0);
        assert!(r.time_s > 0.0 && r.energy_j > 0.0);
        assert!(r.dyn_instrs > 4096);
    }

    #[test]
    fn cache_friendly_beats_cache_hostile_per_access() {
        let n = 256 * 1024; // 2 MB array: fits L3, not L2
        let seq = simulate_host(&collect(&streaming_program(n)).unwrap(), 3.0);
        let rnd = simulate_host(&collect(&random_walk_program(n)).unwrap(), 3.0);
        let seq_per = seq.time_s / seq.dyn_instrs as f64;
        let rnd_per = rnd.time_s / rnd.dyn_instrs as f64;
        assert!(
            rnd_per > 1.2 * seq_per,
            "random {rnd_per} vs sequential {seq_per}"
        );
    }

    #[test]
    fn higher_ilp_means_faster() {
        let regions = collect(&streaming_program(8192)).unwrap();
        let slow = simulate_host(&regions, 1.0);
        let fast = simulate_host(&regions, 4.0);
        assert!(fast.time_s < slow.time_s);
    }

    #[test]
    fn small_working_set_stays_in_cache() {
        let r = simulate_host(&collect(&streaming_program(64)).unwrap(), 3.0);
        assert_eq!(r.l3_misses as usize, 64 * 8 / 64); // cold lines only
    }
}
