//! Machine-model parameters — the paper's Table 1, plus the energy table
//! the paper gets from AMESTER measurements (substituted here with
//! literature-typical per-event energies; see DESIGN.md §Substitutions).

/// Host: IBM Power9-class big OoO core + 3-level cache + DDR4 (Table 1 row 1).
#[derive(Debug, Clone)]
pub struct HostConfig {
    pub freq_ghz: f64,
    /// Sustained issue width of the OoO core (SMT4 Power9 core ≈ 4/cycle
    /// per thread context; single-thread analysis per paper §IV-B).
    pub issue_width: f64,
    /// Memory-level parallelism: overlapped outstanding misses.
    pub mlp: f64,
    pub l1_kb: usize,
    pub l1_ways: usize,
    pub l2_kb: usize,
    pub l2_ways: usize,
    pub l3_kb: usize,
    pub l3_ways: usize,
    pub line_bytes: usize,
    /// Latencies in core cycles.
    pub l1_lat: u64,
    pub l2_lat: u64,
    pub l3_lat: u64,
    /// DDR4 average access latency (ns) on top of L3 miss.
    pub dram_lat_ns: f64,
    /// DDR4 peak bandwidth GB/s (RDIMM @ 2.7 GHz per Table 1).
    pub dram_bw_gbs: f64,
}

impl Default for HostConfig {
    fn default() -> Self {
        HostConfig {
            freq_ghz: 2.3,
            issue_width: 4.0,
            mlp: 4.0,
            l1_kb: 32,
            l1_ways: 8,
            l2_kb: 256,
            l2_ways: 8,
            l3_kb: 10 * 1024,
            l3_ways: 20,
            line_bytes: 64,
            l1_lat: 3,
            l2_lat: 12,
            l3_lat: 35,
            dram_lat_ns: 80.0,
            dram_bw_gbs: 21.3,
        }
    }
}

/// Dataset-scale factor between the paper's simulated sizes and this
/// repo's defaults (paper: dims 8000/2000, 1M nodes; here: see each
/// kernel's `default_n`). The experimentally relevant dimensionless
/// quantity is working-set ÷ cache capacity, so the repro host shrinks its
/// hierarchy by the same factor — standard scaled-simulation practice,
/// documented in DESIGN.md §Substitutions.
pub const CACHE_SCALE: usize = 128;

impl HostConfig {
    /// Table-1 host with the hierarchy scaled by [`CACHE_SCALE`] to match
    /// the repo's scaled datasets (L1 256 B, L2 2 KB, L3 80 KB).
    pub fn scaled_for_repro() -> Self {
        let mut c = HostConfig::default();
        c.l1_kb = 0; // replaced by bytes below through ways×line sizing
        let l1_bytes = 32 * 1024 / CACHE_SCALE;
        let l2_bytes = 256 * 1024 / CACHE_SCALE;
        let l3_bytes = 10 * 1024 * 1024 / CACHE_SCALE;
        c.l1_kb = l1_bytes / 1024; // 0 KB would divide to zero sets; Cache::new floors at 1 line
        c.l2_kb = l2_bytes / 1024;
        c.l3_kb = l3_bytes / 1024;
        c.l1_ways = 2;
        c.l2_ways = 4;
        c.l3_ways = 8;
        c
    }

    /// Cache capacities in bytes (l1_kb of 0 from scaling means 512 B).
    pub fn l1_bytes(&self) -> usize {
        if self.l1_kb == 0 {
            512
        } else {
            self.l1_kb * 1024
        }
    }
    pub fn l2_bytes(&self) -> usize {
        self.l2_kb.max(1) * 1024
    }
    pub fn l3_bytes(&self) -> usize {
        self.l3_kb.max(1) * 1024
    }
}

/// NMC: 32 in-order single-issue PEs in the HMC logic layer (Table 1 row 2).
#[derive(Debug, Clone)]
pub struct NmcConfig {
    pub n_pes: usize,
    pub freq_ghz: f64,
    /// Per-PE L1 size in 64 B lines. Table 1 reads "L1-I/D 2-way, 2 cache
    /// lines, 64B per cache line"; a literal 2-line (128 B) data cache
    /// cannot even hold one accumulator line plus one stream and would
    /// starve every serial phase, so we read it as a 2-way, 2 KB cache
    /// (32 lines) — the smallest configuration under which the paper's
    /// own winning kernels can win (DESIGN.md §Substitutions).
    pub l1_lines: usize,
    pub l1_ways: usize,
    pub line_bytes: usize,
    pub l1_lat: u64,
    pub dram: DramConfig,
    /// HMC organization.
    pub n_vaults: usize,
    pub stacked_layers: usize,
    /// Vault-interleave granule. HMC interleaves at small blocks for
    /// bandwidth, but NMC studies (Ahn+15, Gao+15) partition data at page
    /// granularity so a PE's working set is vault-local ("each processing
    /// unit operates on the data assigned to that vault").
    pub vault_block_bytes: u64,
    /// Extra latency (ns) for a PE touching a remote vault over the
    /// intra-stack network.
    pub remote_vault_ns: f64,
    /// SerDes link bandwidth per direction (GB/s): 16-bit @ 15 Gbps.
    pub link_gbs: f64,
}

impl Default for NmcConfig {
    fn default() -> Self {
        NmcConfig {
            n_pes: 32,
            freq_ghz: 1.25,
            l1_lines: 32,
            l1_ways: 2,
            line_bytes: 64,
            l1_lat: 1,
            dram: DramConfig::hmc_vault(),
            n_vaults: 32,
            stacked_layers: 8,
            vault_block_bytes: 2048,
            remote_vault_ns: 2.0,
            link_gbs: 30.0,
        }
    }
}

/// Command-level DRAM timing (per vault for HMC, per channel for DDR4),
/// in DRAM-clock cycles.
#[derive(Debug, Clone)]
pub struct DramConfig {
    pub clock_ghz: f64,
    pub n_banks: usize,
    pub row_bytes: u64,
    pub t_rcd: u64,
    pub t_rp: u64,
    pub t_cl: u64,
    pub t_ras: u64,
    /// Burst length in clocks for one 64B line.
    pub t_bl: u64,
}

impl DramConfig {
    /// One HMC vault: short TSV-connected arrays — low latency, narrow rows.
    pub fn hmc_vault() -> Self {
        DramConfig {
            clock_ghz: 1.25,
            n_banks: 8,
            row_bytes: 256,
            t_rcd: 14,
            t_rp: 14,
            t_cl: 14,
            t_ras: 33,
            t_bl: 4,
        }
    }

    /// DDR4-2666-class channel.
    pub fn ddr4() -> Self {
        DramConfig {
            clock_ghz: 1.333,
            n_banks: 16,
            row_bytes: 8192,
            t_rcd: 19,
            t_rp: 19,
            t_cl: 19,
            t_ras: 43,
            t_bl: 4,
        }
    }

    pub fn ns_per_clock(&self) -> f64 {
        1.0 / self.clock_ghz
    }
}

/// Per-event energies (pJ) and static power (W). The paper measures host
/// power with AMESTER; these are literature-typical substitutes chosen so
/// the *ratio* host/NMC matches published NMC studies (Ahn+15, Gao+15):
/// the NMC win comes from (a) no off-chip DDR PHY traversal per miss and
/// (b) simple in-order PEs vs a big OoO core.
#[derive(Debug, Clone)]
pub struct EnergyConfig {
    /// Host big-core energy per committed instruction (incl. L1).
    pub host_instr_pj: f64,
    pub host_l2_pj: f64,
    pub host_l3_pj: f64,
    /// Full off-chip DDR4 line fetch (activate+IO+PHY), per 64B line.
    pub host_dram_line_pj: f64,
    pub host_static_w: f64,
    /// NMC in-order PE energy per instruction (incl. its 2-line L1).
    pub nmc_instr_pj: f64,
    /// TSV-local vault line fetch, per 64B line.
    pub nmc_dram_line_pj: f64,
    /// Remote-vault hop adder, per 64B line.
    pub nmc_remote_line_pj: f64,
    pub nmc_static_w: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            // per-instr energies are AMORTIZED (core power / instruction
            // rate), so they carry the core's leakage+clock overhead: a big
            // OoO P9 core at ~2.3 GHz × ~2.5 IPC and ~15 W ≈ 2.5 nJ/instr;
            // a simple in-order PE is ~10× leaner per instruction.
            host_instr_pj: 2500.0,
            host_l2_pj: 25.0,
            host_l3_pj: 80.0,
            host_dram_line_pj: 8000.0, // ~125 pJ/B end-to-end off-chip (act+IO+PHY+term)
            host_static_w: 2.0,        // uncore remainder
            nmc_instr_pj: 250.0,
            nmc_dram_line_pj: 830.0, // ~13 pJ/B TSV-local
            nmc_remote_line_pj: 150.0,
            nmc_static_w: 0.5, // vault peripherals + stack logic
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shapes() {
        let h = HostConfig::default();
        assert_eq!(h.l1_kb, 32);
        assert_eq!(h.l2_kb, 256);
        assert_eq!(h.l3_kb, 10 * 1024);
        assert!((h.freq_ghz - 2.3).abs() < 1e-12);
        let n = NmcConfig::default();
        assert_eq!(n.n_pes, 32);
        assert_eq!(n.n_vaults, 32);
        assert_eq!(n.l1_lines, 32); // 2 KB PE L1 (see field docs)
        assert!((n.freq_ghz - 1.25).abs() < 1e-12);
        assert_eq!(n.stacked_layers, 8);
    }

    #[test]
    fn energy_ratios_favor_nmc_per_byte() {
        let e = EnergyConfig::default();
        assert!(e.host_dram_line_pj > 3.0 * e.nmc_dram_line_pj);
        assert!(e.host_instr_pj > 3.0 * e.nmc_instr_pj);
    }
}
