//! Hand-rolled CLI (no clap in the offline vendor set).
//!
//! ```text
//! pisa-nmc pipeline [--scale F] [--seed N] [--jobs N|auto] [--no-pjrt] [--out FILE]
//! pisa-nmc analyze --kernel NAME [--n N] [--seed N] [--json]
//! pisa-nmc serve --listen ADDR [--jobs N|auto] [--queue-cap N]
//! pisa-nmc figure {3a|3b|3c|4|5|6|mrc} [pipeline flags]
//! pisa-nmc table {1|2} [--scale F]
//! pisa-nmc validate [--n N]
//! pisa-nmc ir --kernel NAME [--n N]
//! ```

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

/// Flags that take a value; everything else is boolean.
const VALUE_FLAGS: &[&str] = &[
    "scale",
    "seed",
    "threads",
    "out",
    "kernel",
    "n",
    "metrics",
    "pipeline",
    "workers",
    "hierarchy",
    "hierarchy-spec",
    "sweep",
    "mrc",
    "mrc-smax",
    "inject-fault",
    "app-timeout",
    "on-error",
    "record-out",
    "trace",
    "jobs",
    "listen",
    "queue-cap",
];

pub fn parse(argv: &[String]) -> Result<Args> {
    let mut a = Args::default();
    let mut it = argv.iter().peekable();
    a.command = it
        .next()
        .cloned()
        .ok_or_else(|| anyhow!("no command; try `pisa-nmc help`"))?;
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            if VALUE_FLAGS.contains(&name) {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow!("--{name} requires a value"))?;
                a.flags.push((name.to_string(), Some(v.clone())));
            } else {
                a.flags.push((name.to_string(), None));
            }
        } else {
            a.positional.push(arg.clone());
        }
    }
    Ok(a)
}

impl Args {
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: '{v}' is not a number")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    /// One positional argument (e.g. the figure id).
    pub fn positional1(&self) -> Result<&str> {
        match self.positional.as_slice() {
            [one] => Ok(one),
            _ => bail!("expected exactly one argument, got {:?}", self.positional),
        }
    }
}

/// Record/replay flag validation, applied up front before any verb runs:
/// the legal combinations form a small closed set (`record` writes, never
/// replays; `--trace` replays on `pipeline`/`analyze` only and must name
/// an existing file), so misuse fails immediately in the same error style
/// as every other CLI mistake.
pub fn validate_trace_flags(a: &Args) -> Result<()> {
    if a.command == "record" {
        if a.get("record-out").is_none() {
            bail!("record requires --record-out <path>");
        }
        if a.has("trace") {
            bail!("record interprets a kernel and writes a trace; --trace replays one — pick one");
        }
    } else if a.has("record-out") {
        bail!("--record-out only applies to the record command");
    }
    if a.has("trace") {
        if !matches!(a.command.as_str(), "pipeline" | "analyze") {
            bail!("--trace only applies to the pipeline and analyze commands");
        }
        let path = a.require("trace")?;
        if !std::path::Path::new(path).exists() {
            bail!("--trace {path}: no such file");
        }
    }
    Ok(())
}

/// Traffic-family flag validation, applied up front like
/// [`validate_trace_flags`]: `--hierarchy`/`--hierarchy-spec`/`--mrc`/
/// `--mrc-smax`/`--sweep` configure the traffic analyzers, so they are
/// rejected on verbs that never run them (`table`, `validate`, `ir`, …)
/// and on runs whose `--metrics` selection excludes the traffic family —
/// previously these combinations silently no-opped while `--workers`
/// misuse errored, an inconsistency this closes. `--sweep` additionally
/// re-profiles the registry suite, so it is pipeline/figure-only and
/// cannot combine with `--trace` replay.
pub fn validate_traffic_flags(a: &Args) -> Result<()> {
    const TRAFFIC_FLAGS: &[&str] = &["hierarchy", "hierarchy-spec", "mrc", "mrc-smax", "sweep"];
    const TRAFFIC_VERBS: &[&str] = &["pipeline", "analyze", "serve", "record", "figure"];
    let Some(&flag) = TRAFFIC_FLAGS.iter().find(|f| a.has(f)) else {
        return Ok(());
    };
    if !TRAFFIC_VERBS.contains(&a.command.as_str()) {
        bail!(
            "--{flag} configures the traffic analyzers, which the {} command never runs \
             (traffic flags apply to: pipeline, analyze, serve, record, figure)",
            a.command
        );
    }
    if let Some(list) = a.get("metrics") {
        let runs_traffic = list
            .split(',')
            .map(str::trim)
            .any(|m| m == "traffic" || m == "all");
        if !runs_traffic {
            bail!(
                "--{flag} configures the traffic analyzers but --metrics {list} deselects \
                 the traffic family, so it would silently no-op; add `traffic` (or `all`)"
            );
        }
    }
    if a.has("hierarchy") && a.has("hierarchy-spec") {
        bail!(
            "--hierarchy and --hierarchy-spec both set the replay hierarchy; pick one \
             (a spec carries its own per-level policy fields)"
        );
    }
    if a.has("sweep") {
        if !matches!(a.command.as_str(), "pipeline" | "figure") {
            bail!("--sweep only applies to the pipeline and figure commands");
        }
        if a.has("trace") {
            bail!(
                "--sweep re-profiles the suite for its traffic-only grid pass and cannot \
                 combine with --trace replay"
            );
        }
    }
    Ok(())
}

pub const HELP: &str = "\
pisa-nmc — Platform-Independent Software Analysis for Near-Memory Computing
(reproduction of Corda et al., cs.PF 2019; see DESIGN.md)

USAGE:
  pisa-nmc pipeline [--scale F] [--seed N] [--jobs N|auto] [--metrics LIST]
                    [--pipeline MODE] [--workers N|auto]
                    [--hierarchy inclusive|exclusive]
                    [--hierarchy-spec FILE|JSON] [--sweep GRIDFILE]
                    [--mrc exact|sampled:<rate>] [--mrc-smax N]
                    [--inject-fault SPEC] [--app-timeout SECS]
                    [--on-error fail-fast|continue] [--no-pjrt]
                    [--trace FILE] [--out FILE]
        full suite: profile 12 kernels, run host+NMC sims, PJRT analytics,
        print every table and figure (writes JSON report with --out)
  pisa-nmc serve --listen ADDR [--jobs N|auto] [--queue-cap N]
                 [--metrics LIST] [--pipeline MODE]
                 [--hierarchy inclusive|exclusive]
                 [--mrc exact|sampled:<rate>] [--app-timeout SECS]
        profiling-as-a-service daemon: accept jobs as JSON lines over TCP
        and stream each result back as it completes (details below)
  pisa-nmc analyze --kernel NAME [--n N] [--seed N] [--metrics LIST]
                   [--pipeline MODE] [--workers N|auto]
                   [--hierarchy inclusive|exclusive]
                   [--hierarchy-spec FILE|JSON]
                   [--mrc exact|sampled:<rate>] [--mrc-smax N]
                   [--inject-fault SPEC] [--app-timeout SECS]
                   [--trace FILE] [--json]
        profile a single kernel and print its metrics (with --trace:
        replay a recording instead of interpreting; --kernel is ignored)
  pisa-nmc record --kernel NAME --record-out FILE [--n N] [--seed N]
                  [--metrics LIST] [--pipeline MODE] [--workers N|auto]
                  [--hierarchy inclusive|exclusive]
                  [--mrc exact|sampled:<rate>] [--mrc-smax N] [--json]
        profile one kernel while streaming its event trace to a versioned
        .pallas-trace file (replay it later with --trace)
  pisa-nmc figure {3a|3b|3c|4|5|6|mrc|sweep} [pipeline flags]
        regenerate one paper figure (mrc: the miss-ratio-curve extension;
        sweep: the offload-verdict grid, requires --sweep GRIDFILE)
  pisa-nmc table {1|2} [--scale F]
        print a paper table
  pisa-nmc validate [--n N]
        run every kernel against its native oracle
  pisa-nmc ir --kernel NAME [--n N]
        dump a kernel's mini-IR
  pisa-nmc help

--metrics LIST selects analyzer families (comma-separated:
mix,branch,mem_entropy,reuse,ilp,dlp,bblp,pbblp,traffic — or `all`, the
default); deselected families report empty results and grey out their
figure series (ilp stays on when the machine simulations run: the host
model needs it). `traffic` is the streaming memory-traffic subsystem:
one-pass miss-ratio curves (64B lines), an L1→L2→LLC hierarchy replay
with per-level counters, bytes/instr and post-hierarchy DRAM traffic.

--hierarchy POLICY selects the traffic family's cache-hierarchy content
management: `inclusive` (default — upper levels are subsets of lower
levels, maintained by back-invalidation) or `exclusive` (a line lives in
exactly one level; lower levels act as victim caches, so the aggregate
capacity approaches the sum of the levels). Each level only sees the
level above's misses; DRAM bytes count only what crosses the LLC.

--hierarchy-spec FILE|JSON replaces the built-in host shape entirely
with a user hierarchy (conflicts with --hierarchy, which only picks the
policy of the built-in shape). The value is a path to a JSON file, or
the JSON itself when it starts with `{`. Top-level keys: `levels` (1-8
entries, required), `line_bytes` (power of two 8-4096, default 64),
`policy` (`inclusive`|`exclusive` default for levels, default
inclusive), `write_allocate` (default true; false sends store misses
straight to DRAM without filling the hierarchy). Each level:
`name` (unique, required), `capacity_bytes` or `capacity_kb`
(required), `ways` (default 8), `policy` (per-level override),
`replacement` (`lru`|`rrip`|`drrip`, default lru). Unknown keys and
invalid shapes fail up front with a typed `hierarchy spec:` error, and
the spec round-trips into the report JSON as provenance:

  pisa-nmc analyze --kernel gesummv --metrics traffic --hierarchy-spec \\
    '{\"levels\":[{\"name\":\"l1\",\"capacity_kb\":1,\"ways\":4},
                {\"name\":\"llc\",\"capacity_kb\":16,\"replacement\":\"rrip\"}]}'

--sweep GRIDFILE (pipeline and figure only) runs the design-space
exploration advisor: after the normal profile pass, each app's address
stream is replayed ONCE more with every grid configuration attached to
the same chunk lanes — N small hierarchy replays sweeping one pass, no
re-interpretation per grid point, each point's counters bit-identical
to a standalone run at that config. Grid points whose aggregate
capacity lands on a flat segment of the app's miss-ratio curve are
pruned as dominated and inherit the nearest replayed neighbor's
verdict. Each point's DRAM-line delta is folded through the host
energy/latency model into a per-config EDP and compared against the
NMC simulation, yielding a per-app offload verdict per grid point
(figure `sweep`, plus a \"sweep\" section in --out JSON). The grid file
holds hierarchy specs and an optional replacement-policy cross
product:

  {\"configs\": [
     {\"levels\": [{\"name\": \"l1\", \"capacity_kb\": 1, \"ways\": 4}]},
     {\"levels\": [{\"name\": \"l1\", \"capacity_kb\": 1, \"ways\": 4},
                  {\"name\": \"llc\", \"capacity_kb\": 32, \"ways\": 8}]},
     {\"policy\": \"exclusive\", \"levels\": [
        {\"name\": \"l1\", \"capacity_kb\": 2},
        {\"name\": \"llc\", \"capacity_kb\": 64}]}],
   \"replacements\": [\"lru\", \"rrip\"]}

  # 3 shapes x 2 replacement policies = 6 grid points per app
  pisa-nmc pipeline --scale 0.1 --sweep grid.json --out report.json
  pisa-nmc figure sweep --sweep grid.json

--mrc MODE selects the stack-distance kernel behind the miss-ratio
curves: `exact` (default — Olken/Fenwick over every access, bit-identical
to previous releases) or `sampled:<rate>` (SHARDS spatial hash sampling:
a line participates iff hash(line) < rate*2^64, distances and cold misses
are rescaled by 1/rate, state shrinks from the full footprint to
~rate*footprint entries). `sampled` alone uses the default rate 0.01.
Sampled curves are estimates: the knee is trustworthy when
rate*footprint_lines is large (≥ ~1000 sampled lines keeps per-point
error around a percent); at tiny footprints or rates the curve gets
noisy and `exact` costs little anyway.

--mrc-smax N switches the SHARDS sampler to fixed-size mode: at most N
sampled lines stay resident (the internal adaptive-rate default is 8192),
starting from the mode's rate and adapting it down whenever the cap
fills — constant memory at any footprint, at the cost of a run-dependent
effective rate. Only valid with `--mrc sampled`; the exact kernel keeps
every line by construction.

--pipeline MODE selects event delivery: `inline` (default — analyzers fold
on the interpreter thread), `offload` (analyzers fold on a dedicated
analysis thread, overlapped with interpretation; each app then uses two
cores) or `sharded` (analyzers shard by metric family across a pool of
workers, every chunk broadcast to all of them; each app then uses
2 + workers cores). Metrics are bit-identical across all modes.

--jobs N|auto sets suite-level concurrency: how many apps profile at
once, each driving its own inline/offload/sharded pipeline (`auto`, the
default, matches the machine; `--threads N` is the deprecated spelling of
`--jobs N`). Every concurrent app draws its pipeline threads from one
process-global worker budget, so `--jobs 4 --pipeline sharded --workers
auto` admits apps only as budget frees up instead of oversubscribing the
machine. Results are streamed back into deterministic suite order, so any
`--jobs` value is bit-identical to a sequential run (wall-clock timings
aside). Under `--on-error fail-fast` the first failed app cancels every
still-queued job.

--workers N|auto sizes the sharded analyzer pool (`sharded` only).
`auto` (default) plans one worker per enabled family group — tags
(mix/branch), memory lanes (mem_entropy/reuse + the traffic MRC half),
the traffic hierarchy-replay half, dataflow (ilp/dlp), block structure
(bblp/pbblp) — so e.g. `--metrics mix` collapses to one worker while
`--metrics traffic` plans two; a fixed N is clamped to the non-empty
groups.

Failure handling: every app runs supervised. --app-timeout SECS arms a
per-app watchdog checked at chunk boundaries; a sharded worker that
panics is isolated (its shard's metric families report \"status\":
\"failed\" while the survivors stay bit-identical) and the run degrades
instead of crashing. --on-error picks the suite policy: `fail-fast`
(default) aborts on the first failed app; `continue` finishes the suite,
records failed apps under a `\"failures\"` JSON section, and exits
nonzero only for hard losses (interpreter error, panic, timeout) —
degraded apps with salvaged survivors exit zero. --inject-fault
KIND@SITE[:CHUNK] arms one deterministic fault for testing: KIND is
`panic`, `stall:<ms>` or `interp-error`; SITE is `interp`, `broadcaster`
or `worker:<shard>`; CHUNK is the chunk ordinal it fires on (default 0).

Record/replay: `record` composes the analyzer stack with a trace-writer
sink, so one instrumented run yields both the metrics and a compact
self-describing binary trace (`.pallas-trace`: versioned header, SoA
chunk frames with delta+varint-coded addresses, checksummed footer — the
full wire layout is documented in the `trace` module). --record-out FILE
names the output; the lanes written are exactly what the selected
--metrics families need, so narrow recordings stay small but can only
feed the families they carry — replaying a starved trace fails up front
naming the missing families. --trace FILE (pipeline and analyze only)
replays a recording through the full analyzer stack — every --pipeline
delivery mode, both --hierarchy policies, exact and sampled --mrc — with
metrics event-for-event identical to the recording run; the workload
identity (kernel, n, seed) comes from the trace header and the JSON
report gains a \"trace\" provenance section.

  # record gesummv once, then analyze the same stream two ways
  pisa-nmc record --kernel gesummv --n 64 --record-out g.pallas-trace
  pisa-nmc pipeline --trace g.pallas-trace --metrics all --out report.json
  pisa-nmc analyze --trace g.pallas-trace --pipeline sharded --json

Serve mode: `serve` turns the same scheduler into a long-running daemon.
Clients connect over TCP and exchange JSON lines: `{\"cmd\":\"profile\",
\"app\":NAME}` plus optional `\"n\"`/`\"scale\"`/`\"seed\"`/`\"metrics\"`/
`\"pipeline\"`/`\"workers\"`/`\"hierarchy\"`/`\"mrc\"` overrides (or
`\"trace\":PATH` to replay a recording) queues a job and is answered with
`{\"type\":\"accepted\",\"seq\":K}`; each result then streams back as
`{\"type\":\"result\",\"seq\":K,\"app\":...,\"events_per_sec\":...}` the
moment it completes. Invalid requests get `{\"type\":\"error\",...}`
without poisoning the stream, a full queue answers
`{\"type\":\"rejected\",...}` (backpressure — resubmit later), and
`{\"cmd\":\"cancel\",\"seq\":K}` revokes a still-queued job. --queue-cap N
bounds the per-connection queue (default 16); --jobs sizes the
concurrent-job pool; --app-timeout arms the same per-job watchdog as the
pipeline verb. SIGTERM drains in-flight jobs and exits cleanly.

  # serve on a local port, submit a job and stream the reply with netcat
  pisa-nmc serve --listen 127.0.0.1:7071 --jobs auto &
  printf '%s\\n' '{\"cmd\":\"profile\",\"app\":\"gesummv\",\"n\":48}' \\
    | nc 127.0.0.1 7071
  # ... or with bash alone:
  exec 3<>/dev/tcp/127.0.0.1/7071
  printf '%s\\n' '{\"cmd\":\"profile\",\"app\":\"gesummv\",\"n\":48}' >&3
  head -2 <&3   # accepted line, then the streamed result JSON

Artifacts are searched in ./artifacts (or $PISA_NMC_ARTIFACTS); build them
with `make artifacts`. --no-pjrt forces the native analytics fallback.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = args(&["figure", "3a", "--scale", "0.5", "--no-pjrt"]);
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional1().unwrap(), "3a");
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert!(a.has("no-pjrt"));
        assert!(!a.has("json"));
    }

    #[test]
    fn metrics_flag_takes_a_value() {
        let a = args(&["analyze", "--kernel", "atax", "--metrics", "mix,dlp"]);
        assert_eq!(a.get("metrics"), Some("mix,dlp"));
        assert!(parse(&["pipeline".into(), "--metrics".into()]).is_err());
    }

    #[test]
    fn pipeline_flag_takes_a_value() {
        let a = args(&["pipeline", "--pipeline", "offload"]);
        assert_eq!(a.get("pipeline"), Some("offload"));
        assert!(parse(&["pipeline".into(), "--pipeline".into()]).is_err());
    }

    #[test]
    fn workers_flag_takes_a_value() {
        let a = args(&["pipeline", "--pipeline", "sharded", "--workers", "3"]);
        assert_eq!(a.get("pipeline"), Some("sharded"));
        assert_eq!(a.get("workers"), Some("3"));
        assert!(parse(&["pipeline".into(), "--workers".into()]).is_err());
    }

    #[test]
    fn hierarchy_flag_takes_a_value() {
        let a = args(&["pipeline", "--metrics", "traffic", "--hierarchy", "exclusive"]);
        assert_eq!(a.get("hierarchy"), Some("exclusive"));
        assert!(parse(&["pipeline".into(), "--hierarchy".into()]).is_err());
    }

    #[test]
    fn mrc_flag_takes_a_value() {
        let a = args(&["pipeline", "--metrics", "traffic", "--mrc", "sampled:0.05"]);
        assert_eq!(a.get("mrc"), Some("sampled:0.05"));
        assert!(parse(&["pipeline".into(), "--mrc".into()]).is_err());
    }

    #[test]
    fn mrc_smax_flag_takes_a_value() {
        let a = args(&["pipeline", "--mrc", "sampled", "--mrc-smax", "4096"]);
        assert_eq!(a.get("mrc-smax"), Some("4096"));
        assert!(parse(&["pipeline".into(), "--mrc-smax".into()]).is_err());
    }

    #[test]
    fn inject_fault_flag_takes_a_value() {
        let a = args(&["pipeline", "--inject-fault", "panic@worker:1"]);
        assert_eq!(a.get("inject-fault"), Some("panic@worker:1"));
        assert!(parse(&["pipeline".into(), "--inject-fault".into()]).is_err());
    }

    #[test]
    fn app_timeout_flag_takes_a_value() {
        let a = args(&["pipeline", "--app-timeout", "30"]);
        assert_eq!(a.get_u64("app-timeout", 0).unwrap(), 30);
        assert!(parse(&["pipeline".into(), "--app-timeout".into()]).is_err());
    }

    #[test]
    fn on_error_flag_takes_a_value() {
        let a = args(&["pipeline", "--on-error", "continue"]);
        assert_eq!(a.get("on-error"), Some("continue"));
        assert!(parse(&["pipeline".into(), "--on-error".into()]).is_err());
    }

    #[test]
    fn jobs_flag_takes_a_value() {
        let a = args(&["pipeline", "--jobs", "auto"]);
        assert_eq!(a.get("jobs"), Some("auto"));
        assert!(parse(&["pipeline".into(), "--jobs".into()]).is_err());
    }

    #[test]
    fn listen_flag_takes_a_value() {
        let a = args(&["serve", "--listen", "127.0.0.1:7071"]);
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("listen"), Some("127.0.0.1:7071"));
        assert!(parse(&["serve".into(), "--listen".into()]).is_err());
    }

    #[test]
    fn queue_cap_flag_takes_a_value() {
        let a = args(&["serve", "--listen", "127.0.0.1:0", "--queue-cap", "4"]);
        assert_eq!(a.get_usize("queue-cap", 16).unwrap(), 4);
        assert!(parse(&["serve".into(), "--queue-cap".into()]).is_err());
    }

    #[test]
    fn value_flag_requires_value() {
        assert!(parse(&["analyze".into(), "--kernel".into()]).is_err());
    }

    #[test]
    fn record_and_trace_flags_take_values() {
        let a = args(&["record", "--kernel", "atax", "--record-out", "t.pallas-trace"]);
        assert_eq!(a.get("record-out"), Some("t.pallas-trace"));
        assert!(parse(&["record".into(), "--record-out".into()]).is_err());
        let a = args(&["pipeline", "--trace", "t.pallas-trace"]);
        assert_eq!(a.get("trace"), Some("t.pallas-trace"));
        assert!(parse(&["pipeline".into(), "--trace".into()]).is_err());
    }

    #[test]
    fn record_requires_record_out() {
        let a = args(&["record", "--kernel", "atax"]);
        let err = validate_trace_flags(&a).unwrap_err();
        assert!(err.to_string().contains("--record-out"), "{err}");
    }

    #[test]
    fn record_rejects_replay_flag() {
        let a = args(&["record", "--kernel", "atax", "--record-out", "o", "--trace", "i"]);
        assert!(validate_trace_flags(&a).is_err());
    }

    #[test]
    fn record_out_is_record_only() {
        for cmd in ["pipeline", "analyze", "validate"] {
            let a = args(&[cmd, "--record-out", "o"]);
            let err = validate_trace_flags(&a).unwrap_err();
            assert!(err.to_string().contains("record command"), "{cmd}: {err}");
        }
    }

    #[test]
    fn trace_flag_is_replay_only_and_must_name_an_existing_file() {
        // wrong verb
        let a = args(&["validate", "--trace", "whatever"]);
        assert!(validate_trace_flags(&a).is_err());
        // right verb, missing file
        let a = args(&["pipeline", "--trace", "/nonexistent/missing.pallas-trace"]);
        let err = validate_trace_flags(&a).unwrap_err();
        assert!(err.to_string().contains("no such file"), "{err}");
        // right verb, existing file
        let p = std::env::temp_dir()
            .join(format!("pisa-cli-trace-{}.pallas-trace", std::process::id()));
        std::fs::write(&p, b"x").unwrap();
        let argv = vec!["analyze".to_string(), "--trace".to_string(), p.display().to_string()];
        let a = parse(&argv).unwrap();
        assert!(validate_trace_flags(&a).is_ok());
        let _ = std::fs::remove_file(&p);
        // flag-free commands validate clean
        assert!(validate_trace_flags(&args(&["analyze", "--kernel", "atax"])).is_ok());
        assert!(validate_trace_flags(&args(&["pipeline"])).is_ok());
    }

    #[test]
    fn bad_number_is_error() {
        let a = args(&["pipeline", "--scale", "abc"]);
        assert!(a.get_f64("scale", 1.0).is_err());
    }

    #[test]
    fn hierarchy_spec_and_sweep_flags_take_values() {
        let a = args(&["pipeline", "--hierarchy-spec", "spec.json", "--sweep", "grid.json"]);
        assert_eq!(a.get("hierarchy-spec"), Some("spec.json"));
        assert_eq!(a.get("sweep"), Some("grid.json"));
        assert!(parse(&["pipeline".into(), "--hierarchy-spec".into()]).is_err());
        assert!(parse(&["pipeline".into(), "--sweep".into()]).is_err());
    }

    #[test]
    fn traffic_flags_rejected_on_non_traffic_verbs() {
        // previously these silently no-opped; now every traffic knob is
        // checked against the verbs that actually run the traffic family
        for flag in ["--hierarchy", "--hierarchy-spec", "--mrc", "--mrc-smax", "--sweep"] {
            for cmd in ["table", "validate", "ir"] {
                let a = args(&[cmd, flag, "x"]);
                let err = validate_traffic_flags(&a).unwrap_err();
                assert!(err.to_string().contains("traffic"), "{cmd} {flag}: {err}");
            }
        }
        // the honoring verbs accept them
        assert!(validate_traffic_flags(&args(&["pipeline", "--hierarchy", "exclusive"])).is_ok());
        assert!(validate_traffic_flags(&args(&["serve", "--mrc", "sampled"])).is_ok());
        assert!(validate_traffic_flags(&args(&["record", "--hierarchy", "inclusive"])).is_ok());
        // flag-free commands validate clean
        assert!(validate_traffic_flags(&args(&["table", "1"])).is_ok());
    }

    #[test]
    fn traffic_flags_require_traffic_in_metrics() {
        // e.g. `record --metrics mix --hierarchy ...` recorded a trace
        // that never ran the hierarchy: reject instead of no-opping
        let a = args(&["record", "--metrics", "mix", "--hierarchy", "exclusive"]);
        let err = validate_traffic_flags(&a).unwrap_err();
        assert!(err.to_string().contains("--metrics"), "{err}");
        let a = args(&["analyze", "--metrics", "mix,reuse", "--mrc", "sampled"]);
        assert!(validate_traffic_flags(&a).is_err());
        // traffic or all in the list is fine, as is no --metrics (= all)
        assert!(validate_traffic_flags(&args(&[
            "analyze", "--metrics", "mix,traffic", "--mrc", "exact"
        ]))
        .is_ok());
        assert!(validate_traffic_flags(&args(&["pipeline", "--metrics", "all", "--sweep", "g"]))
            .is_ok());
        assert!(validate_traffic_flags(&args(&["pipeline", "--hierarchy", "inclusive"])).is_ok());
    }

    #[test]
    fn hierarchy_conflicts_with_hierarchy_spec() {
        let a = args(&["pipeline", "--hierarchy", "exclusive", "--hierarchy-spec", "s.json"]);
        let err = validate_traffic_flags(&a).unwrap_err();
        assert!(err.to_string().contains("pick one"), "{err}");
    }

    #[test]
    fn sweep_is_pipeline_or_figure_only_and_excludes_trace() {
        assert!(validate_traffic_flags(&args(&["pipeline", "--sweep", "g.json"])).is_ok());
        assert!(validate_traffic_flags(&args(&["figure", "sweep", "--sweep", "g.json"])).is_ok());
        let a = args(&["analyze", "--sweep", "g.json"]);
        let err = validate_traffic_flags(&a).unwrap_err();
        assert!(err.to_string().contains("pipeline and figure"), "{err}");
        let a = args(&["pipeline", "--sweep", "g.json", "--trace", "t.pallas-trace"]);
        let err = validate_traffic_flags(&a).unwrap_err();
        assert!(err.to_string().contains("--trace"), "{err}");
    }
}
