//! The AOT artifact manifest (written by `python/compile/aot.py`): names,
//! files, and the input/output shape ABI the Rust side must honor.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// One artifact's ABI entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// Input shapes, in call order ([] = scalar).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in tuple order.
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactSpec {
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product::<usize>().max(1)
    }

    pub fn output_len(&self, i: usize) -> usize {
        self.outputs[i].iter().product::<usize>().max(1)
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub abi: u64,
    /// Named shape constants (G, B, L, D, N, K).
    pub shapes: BTreeMap<String, usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn shape_list(j: &Json) -> Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("expected shape array"))?
                .iter()
                .map(|d| {
                    d.as_f64()
                        .map(|v| v as usize)
                        .ok_or_else(|| anyhow!("bad dim"))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let abi = j
            .get("abi")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("manifest missing abi"))? as u64;
        if abi != 1 {
            bail!("unsupported artifact ABI {abi} (runtime speaks 1)");
        }

        let mut shapes = BTreeMap::new();
        if let Some(sh) = j.get("shapes").and_then(|s| s.as_obj()) {
            for (k, v) in sh {
                if let Some(n) = v.as_f64() {
                    shapes.insert(k.clone(), n as usize);
                }
            }
        }

        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(file),
                inputs: shape_list(meta.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: shape_list(meta.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
            };
            if !spec.file.exists() {
                bail!("artifact file missing: {:?}", spec.file);
            }
            artifacts.insert(name.clone(), spec);
        }

        Ok(Manifest { abi, shapes, artifacts, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact '{name}' in manifest"))
    }

    pub fn shape(&self, key: &str) -> Result<usize> {
        self.shapes
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("no shape constant '{key}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.abi, 1);
        for name in ["entropy", "spatial", "pca4", "pca8", "model"] {
            let a = m.get(name).unwrap();
            assert!(!a.inputs.is_empty(), "{name}");
            assert!(!a.outputs.is_empty(), "{name}");
        }
        let e = m.get("entropy").unwrap();
        assert_eq!(e.inputs[0], vec![m.shape("G").unwrap(), m.shape("B").unwrap()]);
        assert_eq!(e.input_len(0), 16 * 4096);
        assert_eq!(e.output_len(1), 1); // scalar diff
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
