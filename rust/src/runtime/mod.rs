//! PJRT runtime: loads the AOT-compiled JAX/Pallas analytics artifacts
//! (HLO text, see `python/compile/aot.py`) and executes them from the
//! analysis path. Python is build-time only; this module is the only
//! boundary between the Rust system and the XLA world.

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactSpec, Manifest};
