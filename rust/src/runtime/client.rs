//! PJRT execution of the AOT JAX/Pallas analytics artifacts.
//!
//! HLO *text* → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` once per artifact at startup; `execute` per call
//! on the analysis path. Python never runs here (the artifacts were lowered
//! by `make artifacts`). See /opt/xla-example/load_hlo/ for the pattern and
//! aot_recipe notes on why text (not serialized protos) is the interchange.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::manifest::{ArtifactSpec, Manifest};

/// Compiled-artifact registry + PJRT client. One per process; `execute` is
/// `&self` (PJRT executions are internally synchronized).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every artifact in `dir` and compile it on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut executables = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Runtime { client, manifest, executables })
    }

    /// Default artifact directory: `$PISA_NMC_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("PISA_NMC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name` with flat fp32 inputs (row-major, shapes per
    /// the manifest). Returns one flat fp32 vector per output.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(name)?;
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not compiled"))?;
        self.check_inputs(spec, inputs)?;

        let literals: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(shape, data)| {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // scalar: reshape to rank-0
                    lit.reshape(&[]).map_err(|e| anyhow!("scalar reshape: {e:?}"))
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: manifest declares {} outputs, module returned {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let v: Vec<f32> = lit
                    .to_vec()
                    .map_err(|e| anyhow!("reading output {i} of {name}: {e:?}"))?;
                if v.len() != spec.output_len(i) {
                    bail!(
                        "{name} output {i}: expected {} elements, got {}",
                        spec.output_len(i),
                        v.len()
                    );
                }
                Ok(v)
            })
            .collect()
    }

    fn check_inputs(&self, spec: &ArtifactSpec, inputs: &[&[f32]]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, data) in inputs.iter().enumerate() {
            let want = spec.input_len(i);
            if data.len() != want {
                bail!(
                    "{} input {i}: expected {} elements for shape {:?}, got {}",
                    spec.name,
                    want,
                    spec.inputs[i],
                    data.len()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime load"))
    }

    #[test]
    fn entropy_artifact_roundtrip() {
        let Some(rt) = runtime() else { return };
        let g = rt.manifest().shape("G").unwrap();
        let b = rt.manifest().shape("B").unwrap();
        // row 0: 256 addresses each counted once → entropy 8 bits
        let mut counts = vec![0f32; g * b];
        let mut weights = vec![0f32; g * b];
        counts[0] = 1.0;
        weights[0] = 256.0;
        // row 1: uniform over 2 values → 1 bit
        counts[b] = 5.0;
        weights[b] = 2.0;
        let out = rt.execute("entropy", &[&counts, &weights]).unwrap();
        assert_eq!(out[0].len(), g);
        assert!((out[0][0] - 8.0).abs() < 1e-4, "{}", out[0][0]);
        assert!((out[0][1] - 1.0).abs() < 1e-4, "{}", out[0][1]);
        assert_eq!(out[1].len(), 1); // scalar diff
    }

    #[test]
    fn spatial_artifact_roundtrip() {
        let Some(rt) = runtime() else { return };
        let l = rt.manifest().shape("L").unwrap();
        let d = rt.manifest().shape("D").unwrap();
        // point-mass histograms with halving means → scores 0.5
        let mut hist = vec![0f32; l * d];
        let binv: Vec<f32> = crate::analysis::reuse::bin_values().to_vec();
        for row in 0..l {
            // bin k has value ~2^k·0.7; put mass at descending bins
            hist[row * d + (10 - row)] = 7.0;
        }
        let out = rt.execute("spatial", &[&hist, &binv]).unwrap();
        assert_eq!(out[0].len(), l);
        assert_eq!(out[1].len(), l - 1);
        for s in &out[1] {
            assert!((0.0..=1.0).contains(s), "{s}");
        }
        // means strictly decreasing → strictly positive scores
        assert!(out[1].iter().all(|&s| s > 0.0), "{:?}", out[1]);
    }

    #[test]
    fn pca4_artifact_separates_clusters() {
        let Some(rt) = runtime() else { return };
        let n = rt.manifest().shape("N").unwrap();
        let mut x = vec![0f32; n * 4];
        let mut mask = vec![0f32; n];
        // two clusters in feature space
        for i in 0..12 {
            mask[i] = 1.0;
            let hi = if i < 6 { 10.0 } else { 1.0 };
            let lo = if i < 6 { 1.0 } else { 10.0 };
            x[i * 4] = hi + (i % 3) as f32 * 0.01;
            x[i * 4 + 1] = hi;
            x[i * 4 + 2] = lo;
            x[i * 4 + 3] = lo + (i % 2) as f32 * 0.01;
        }
        let out = rt.execute("pca4", &[&x, &mask]).unwrap();
        let scores = &out[0]; // [N, 2]
        let pc1: Vec<f32> = (0..12).map(|i| scores[i * 2]).collect();
        let s0 = pc1[0].signum();
        assert!(pc1[..6].iter().all(|v| v.signum() == s0), "{pc1:?}");
        assert!(pc1[6..].iter().all(|v| v.signum() == -s0), "{pc1:?}");
        // masked rows score 0
        for i in 12..n {
            assert!(scores[i * 2].abs() < 1e-5);
        }
        // explained variance sums to ~1 for a 2-cluster layout
        let evr = &out[3];
        assert!(evr[0] > 0.5);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let Some(rt) = runtime() else { return };
        let bad = vec![0f32; 7];
        assert!(rt.execute("entropy", &[&bad, &bad]).is_err());
    }
}
