//! Report rendering: aligned ASCII tables, horizontal bar charts (the
//! terminal stand-ins for the paper's figures) and JSON output files.

use crate::util::Json;

/// A simple aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}", c, w = widths[i]));
                if i + 1 < ncol {
                    out.push_str("  ");
                }
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

/// Horizontal bar chart with one bar per labelled value.
pub fn bar_chart(title: &str, items: &[(String, f64)], width: usize) -> String {
    let mut out = format!("{title}\n");
    let max = items.iter().map(|(_, v)| v.abs()).fold(0.0f64, f64::max).max(1e-12);
    let lw = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, v) in items {
        let n = ((v.abs() / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<lw$}  {:>10.4}  {}\n",
            label,
            v,
            "#".repeat(n),
            lw = lw
        ));
    }
    out
}

/// A tiny ASCII scatter plot (for the Fig-6 PCA plane): points in [-1,1]²
/// normalized space, one character label per point.
pub fn scatter(points: &[(String, f64, f64)], cols: usize, rows: usize) -> String {
    let (mut min_x, mut max_x, mut min_y, mut max_y) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(_, x, y) in points {
        min_x = min_x.min(x);
        max_x = max_x.max(x);
        min_y = min_y.min(y);
        max_y = max_y.max(y);
    }
    let pad_x = (max_x - min_x).max(1e-9) * 0.1;
    let pad_y = (max_y - min_y).max(1e-9) * 0.1;
    min_x -= pad_x;
    max_x += pad_x;
    min_y -= pad_y;
    max_y += pad_y;

    let mut grid = vec![vec![' '; cols]; rows];
    // axes through 0 if visible
    if min_x < 0.0 && max_x > 0.0 {
        let cx = ((0.0 - min_x) / (max_x - min_x) * (cols - 1) as f64) as usize;
        for r in grid.iter_mut() {
            r[cx] = '|';
        }
    }
    if min_y < 0.0 && max_y > 0.0 {
        let cy = rows - 1 - ((0.0 - min_y) / (max_y - min_y) * (rows - 1) as f64) as usize;
        for c in grid[cy].iter_mut() {
            if *c == ' ' {
                *c = '-';
            } else {
                *c = '+';
            }
        }
    }
    let mut legend = Vec::new();
    for (i, (label, x, y)) in points.iter().enumerate() {
        let cx = ((x - min_x) / (max_x - min_x) * (cols - 1) as f64) as usize;
        let cy = rows - 1 - ((y - min_y) / (max_y - min_y) * (rows - 1) as f64) as usize;
        let ch = (b'a' + (i % 26) as u8) as char;
        grid[cy][cx] = ch;
        legend.push(format!("{ch}={label}"));
    }
    let mut out = String::new();
    for row in grid {
        out.push_str(&row.into_iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&legend.join("  "));
    out.push('\n');
    out
}

/// Write pretty JSON to a file, creating parent dirs.
pub fn save_json(path: &std::path::Path, j: &Json) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, j.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let mut t = Table::new(&["app", "value"]);
        t.row(vec!["atax".into(), "1.5".into()]);
        t.row(vec!["gramschmidt".into(), "10".into()]);
        let s = t.render();
        assert!(s.contains("app          value"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn bars_scale() {
        let s = bar_chart(
            "t",
            &[("a".into(), 1.0), ("b".into(), 2.0)],
            10,
        );
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[2].matches('#').count() == 10);
        assert!(lines[1].matches('#').count() == 5);
    }

    #[test]
    fn scatter_places_points() {
        let s = scatter(
            &[("x".into(), -1.0, -1.0), ("y".into(), 1.0, 1.0)],
            21,
            11,
        );
        assert!(s.contains('a'));
        assert!(s.contains('b'));
        assert!(s.contains("a=x"));
    }
}
