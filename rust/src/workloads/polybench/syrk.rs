//! syrk: C = α·A·Aᵀ + β·C — symmetric rank-k update (dense triple loop).

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Syrk;

const ALPHA: f64 = 1.5;
const BETA: f64 = 1.2;

fn gen(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x5127);
    (gen_vec(&mut rng, n * n), gen_vec(&mut rng, n * n))
}

fn native(n: usize, a: &[f64], c0: &[f64]) -> Vec<f64> {
    let mut c = c0.to_vec();
    for i in 0..n {
        for j in 0..n {
            c[i * n + j] *= BETA;
        }
    }
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                c[i * n + j] += ALPHA * a[i * n + k] * a[j * n + k];
            }
        }
    }
    c
}

impl Kernel for Syrk {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "syrk",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "C = alpha A A^T + beta C",
        }
    }

    fn default_n(&self) -> usize {
        112
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let (a, c0) = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("syrk");
        let a_buf = b.alloc_f64_init("A", &a);
        let c_buf = b.alloc_f64_init("C", &c0);
        let nn = b.const_i(ni);
        let alpha = b.const_f(ALPHA);
        let beta = b.const_f(BETA);

        b.counted_loop(nn, |b, i| {
            b.counted_loop(nn, |b, j| {
                let cij = b.load_f64_2d(c_buf, i, j, ni);
                let s = b.fmul(cij, beta);
                b.store_f64_2d(c_buf, i, j, ni, s);
            });
        });
        b.counted_loop(nn, |b, i| {
            b.counted_loop(nn, |b, j| {
                let acc = b.load_f64_2d(c_buf, i, j, ni);
                b.counted_loop(nn, |b, k| {
                    let aik = b.load_f64_2d(a_buf, i, k, ni);
                    let ajk = b.load_f64_2d(a_buf, j, k, ni);
                    let p = b.fmul(aik, ajk);
                    let ap = b.fmul(alpha, p);
                    let s = b.fadd(acc, ap);
                    b.assign(acc, s);
                });
                b.store_f64_2d(c_buf, i, j, ni, acc);
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let (a, c0) = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "C")?;
        Ok(max_abs_err(&got, &native(n, &a, &c0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Syrk.validate(9, 11).unwrap() < 1e-12);
    }
}
