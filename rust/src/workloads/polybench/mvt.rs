//! mvt: x1 += A·y1;  x2 += Aᵀ·y2 — row-major and column-major walks over
//! the same matrix (the transposed half is the cache-hostile one).

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Mvt;

struct Data {
    a: Vec<f64>,
    x1: Vec<f64>,
    x2: Vec<f64>,
    y1: Vec<f64>,
    y2: Vec<f64>,
}

fn gen(n: usize, seed: u64) -> Data {
    let mut rng = Rng::new(seed ^ 0x3717);
    Data {
        a: gen_vec(&mut rng, n * n),
        x1: gen_vec(&mut rng, n),
        x2: gen_vec(&mut rng, n),
        y1: gen_vec(&mut rng, n),
        y2: gen_vec(&mut rng, n),
    }
}

fn native(n: usize, d: &Data) -> (Vec<f64>, Vec<f64>) {
    let mut x1 = d.x1.clone();
    let mut x2 = d.x2.clone();
    for i in 0..n {
        for j in 0..n {
            x1[i] += d.a[i * n + j] * d.y1[j];
        }
    }
    for i in 0..n {
        for j in 0..n {
            x2[i] += d.a[j * n + i] * d.y2[j];
        }
    }
    (x1, x2)
}

impl Kernel for Mvt {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "mvt",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "x1 += A y1; x2 += A^T y2",
        }
    }

    fn default_n(&self) -> usize {
        160
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let d = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("mvt");
        let a_buf = b.alloc_f64_init("A", &d.a);
        let x1_buf = b.alloc_f64_init("x1", &d.x1);
        let x2_buf = b.alloc_f64_init("x2", &d.x2);
        let y1_buf = b.alloc_f64_init("y1", &d.y1);
        let y2_buf = b.alloc_f64_init("y2", &d.y2);
        let nn = b.const_i(ni);

        b.counted_loop(nn, |b, i| {
            let acc = b.load_f64(x1_buf, i);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a_buf, i, j, ni);
                let yj = b.load_f64(y1_buf, j);
                let p = b.fmul(aij, yj);
                let s = b.fadd(acc, p);
                b.assign(acc, s);
            });
            b.store_f64(x1_buf, i, acc);
        });
        b.counted_loop(nn, |b, i| {
            let acc = b.load_f64(x2_buf, i);
            b.counted_loop(nn, |b, j| {
                let aji = b.load_f64_2d(a_buf, j, i, ni); // stride-n column walk
                let yj = b.load_f64(y2_buf, j);
                let p = b.fmul(aji, yj);
                let s = b.fadd(acc, p);
                b.assign(acc, s);
            });
            b.store_f64(x2_buf, i, acc);
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let d = gen(n, seed);
        let prog = self.build(n, seed);
        let got1 = run_and_read(&prog, "x1")?;
        let got2 = run_and_read(&prog, "x2")?;
        let (w1, w2) = native(n, &d);
        Ok(max_abs_err(&got1, &w1).max(max_abs_err(&got2, &w2)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Mvt.validate(12, 9).unwrap() < 1e-12);
    }
}
