//! gesummv: y = α·A·x + β·B·x — two dense MV products, summed.

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Gesummv;

const ALPHA: f64 = 1.5;
const BETA: f64 = 1.2;

fn gen(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x9E55);
    (
        gen_vec(&mut rng, n * n),
        gen_vec(&mut rng, n * n),
        gen_vec(&mut rng, n),
    )
}

fn native(n: usize, a: &[f64], bm: &[f64], x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut ta = 0.0;
        let mut tb = 0.0;
        for j in 0..n {
            ta += a[i * n + j] * x[j];
            tb += bm[i * n + j] * x[j];
        }
        y[i] = ALPHA * ta + BETA * tb;
    }
    y
}

impl Kernel for Gesummv {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "gesummv",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "8000",
            summary: "y = alpha A x + beta B x",
        }
    }

    fn default_n(&self) -> usize {
        448
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let (a, bm, x) = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("gesummv");
        let a_buf = b.alloc_f64_init("A", &a);
        let b_buf = b.alloc_f64_init("B", &bm);
        let x_buf = b.alloc_f64_init("x", &x);
        let y_buf = b.alloc_f64("y", n);
        let nn = b.const_i(ni);
        let alpha = b.const_f(ALPHA);
        let beta = b.const_f(BETA);

        b.counted_loop(nn, |b, i| {
            let ta = b.const_f(0.0);
            let tb = b.const_f(0.0);
            b.counted_loop(nn, |b, j| {
                let xj = b.load_f64(x_buf, j);
                let aij = b.load_f64_2d(a_buf, i, j, ni);
                let pa = b.fmul(aij, xj);
                let sa = b.fadd(ta, pa);
                b.assign(ta, sa);
                let bij = b.load_f64_2d(b_buf, i, j, ni);
                let pb = b.fmul(bij, xj);
                let sb = b.fadd(tb, pb);
                b.assign(tb, sb);
            });
            let at = b.fmul(alpha, ta);
            let bt = b.fmul(beta, tb);
            let yi = b.fadd(at, bt);
            b.store_f64(y_buf, i, yi);
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let (a, bm, x) = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "y")?;
        Ok(max_abs_err(&got, &native(n, &a, &bm, &x)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Gesummv.validate(14, 7).unwrap() < 1e-12);
    }
}
