//! gramschmidt: modified Gram-Schmidt QR factorization A = Q·R.
//!
//! Column-major walks through row-major storage on every inner loop — the
//! paper's flagship low-spatial-locality / high-entropy kernel (Fig 3a/3b)
//! and one of the largest EDP winners on the NMC system (Fig 4).

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Gramschmidt;

fn gen(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x6453);
    // shift away from zero so columns are never degenerate
    gen_vec(&mut rng, n * n)
        .into_iter()
        .map(|v| v + 2.0)
        .collect()
}

fn native(n: usize, a0: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut a = a0.to_vec();
    let mut q = vec![0.0; n * n];
    let mut r = vec![0.0; n * n];
    for k in 0..n {
        let mut nrm = 0.0;
        for i in 0..n {
            nrm += a[i * n + k] * a[i * n + k];
        }
        r[k * n + k] = nrm.sqrt();
        for i in 0..n {
            q[i * n + k] = a[i * n + k] / r[k * n + k];
        }
        for j in k + 1..n {
            let mut s = 0.0;
            for i in 0..n {
                s += q[i * n + k] * a[i * n + j];
            }
            r[k * n + j] = s;
            for i in 0..n {
                a[i * n + j] -= q[i * n + k] * r[k * n + j];
            }
        }
    }
    (a, q, r)
}

impl Kernel for Gramschmidt {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "gramschmidt",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "modified Gram-Schmidt QR",
        }
    }

    fn default_n(&self) -> usize {
        96
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let a0 = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("gramschmidt");
        let a_buf = b.alloc_f64_init("A", &a0);
        let q_buf = b.alloc_f64("Q", n * n);
        let r_buf = b.alloc_f64("R", n * n);
        let nn = b.const_i(ni);
        let one = b.const_i(1);

        b.counted_loop(nn, |b, k| {
            // nrm = Σ_i A[i][k]²  (column walk)
            let nrm = b.const_f(0.0);
            b.counted_loop(nn, |b, i| {
                let aik = b.load_f64_2d(a_buf, i, k, ni);
                let p = b.fmul(aik, aik);
                let s = b.fadd(nrm, p);
                b.assign(nrm, s);
            });
            let rkk = b.fsqrt(nrm);
            b.store_f64_2d(r_buf, k, k, ni, rkk);
            // Q[:,k] = A[:,k] / R[k][k]
            b.counted_loop(nn, |b, i| {
                let aik = b.load_f64_2d(a_buf, i, k, ni);
                let qik = b.fdiv(aik, rkk);
                b.store_f64_2d(q_buf, i, k, ni, qik);
            });
            // project out column k from the remaining columns
            let kp1 = b.add(k, one);
            b.loop_range(kp1, nn, |b, j| {
                let s = b.const_f(0.0);
                b.counted_loop(nn, |b, i| {
                    let qik = b.load_f64_2d(q_buf, i, k, ni);
                    let aij = b.load_f64_2d(a_buf, i, j, ni);
                    let p = b.fmul(qik, aij);
                    let t = b.fadd(s, p);
                    b.assign(s, t);
                });
                b.store_f64_2d(r_buf, k, j, ni, s);
                b.counted_loop(nn, |b, i| {
                    let qik = b.load_f64_2d(q_buf, i, k, ni);
                    let p = b.fmul(qik, s);
                    let aij = b.load_f64_2d(a_buf, i, j, ni);
                    let t = b.fsub(aij, p);
                    b.store_f64_2d(a_buf, i, j, ni, t);
                });
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let a0 = gen(n, seed);
        let prog = self.build(n, seed);
        let got_q = run_and_read(&prog, "Q")?;
        let got_r = run_and_read(&prog, "R")?;
        let (_, want_q, want_r) = native(n, &a0);
        Ok(max_abs_err(&got_q, &want_q).max(max_abs_err(&got_r, &want_r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Gramschmidt.validate(10, 19).unwrap() < 1e-9);
    }

    #[test]
    fn q_columns_orthonormal() {
        let n = 8;
        let (_, q, _) = native(n, &gen(n, 4));
        for c1 in 0..n {
            for c2 in 0..n {
                let dot: f64 = (0..n).map(|i| q[i * n + c1] * q[i * n + c2]).sum();
                let want = if c1 == c2 { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-8, "cols {c1},{c2}: {dot}");
            }
        }
    }
}
