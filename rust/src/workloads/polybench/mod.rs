//! Polybench kernels (Pouchet; paper Table 2 rows 1–2).
//!
//! Each module holds one kernel: the IR construction (`build`), the
//! native-Rust oracle, and kernel-specific tests. Data is generated
//! deterministically from the seed; numerically sensitive kernels
//! (cholesky, lu, gramschmidt) use well-conditioned inputs (SPD /
//! diagonally dominant), as Polybench's init functions do.

pub mod atax;
pub mod cholesky;
pub mod gemver;
pub mod gesummv;
pub mod gramschmidt;
pub mod lu;
pub mod mvt;
pub mod syrk;
pub mod trmm;

use crate::util::Rng;

/// Uniform values in [-1, 1) — generic matrix/vector payload.
pub(crate) fn gen_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.range_f64(-1.0, 1.0)).collect()
}

/// Symmetric positive-definite matrix: B·Bᵀ + n·I (cholesky input).
pub(crate) fn spd_matrix(rng: &mut Rng, n: usize) -> Vec<f64> {
    let b = gen_vec(rng, n * n);
    let mut a = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += b[i * n + k] * b[j * n + k];
            }
            a[i * n + j] = s;
        }
        a[i * n + i] += n as f64;
    }
    a
}

/// Row-diagonally-dominant matrix (stable LU without pivoting).
pub(crate) fn dd_matrix(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut a = gen_vec(rng, n * n);
    for i in 0..n {
        let row_sum: f64 = (0..n).map(|j| a[i * n + j].abs()).sum();
        a[i * n + i] = row_sum + 1.0;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spd_is_symmetric_with_large_diagonal() {
        let mut rng = Rng::new(1);
        let n = 8;
        let a = spd_matrix(&mut rng, n);
        for i in 0..n {
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-12);
            }
            assert!(a[i * n + i] >= n as f64);
        }
    }

    #[test]
    fn dd_matrix_is_dominant() {
        let mut rng = Rng::new(2);
        let n = 10;
        let a = dd_matrix(&mut rng, n);
        for i in 0..n {
            let off: f64 = (0..n)
                .filter(|&j| j != i)
                .map(|j| a[i * n + j].abs())
                .sum();
            assert!(a[i * n + i].abs() > off);
        }
    }
}
