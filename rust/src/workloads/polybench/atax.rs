//! atax: y = Aᵀ·(A·x) — matrix-transpose-vector product chain.
//!
//! The second phase walks A by columns through the row-major layout
//! (stride-n accesses), a classic mixed-locality pattern.

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Atax;

fn gen(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0xA7A8);
    (gen_vec(&mut rng, n * n), gen_vec(&mut rng, n))
}

fn native(n: usize, a: &[f64], x: &[f64]) -> Vec<f64> {
    let mut tmp = vec![0.0; n];
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            acc += a[i * n + j] * x[j];
        }
        tmp[i] = acc;
    }
    for i in 0..n {
        for j in 0..n {
            y[j] += a[i * n + j] * tmp[i];
        }
    }
    y
}

impl Kernel for Atax {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "atax",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "8000",
            summary: "y = A^T (A x)",
        }
    }

    fn default_n(&self) -> usize {
        640
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let (a, x) = gen(n, seed);
        let mut b = ProgramBuilder::new("atax");
        let a_buf = b.alloc_f64_init("A", &a);
        let x_buf = b.alloc_f64_init("x", &x);
        let tmp_buf = b.alloc_f64("tmp", n);
        let y_buf = b.alloc_f64("y", n);
        let nn = b.const_i(n as i64);

        // tmp[i] = Σ_j A[i][j]·x[j]
        b.counted_loop(nn, |b, i| {
            let acc = b.const_f(0.0);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a_buf, i, j, n as i64);
                let xj = b.load_f64(x_buf, j);
                let p = b.fmul(aij, xj);
                let s = b.fadd(acc, p);
                b.assign(acc, s);
            });
            b.store_f64(tmp_buf, i, acc);
        });
        // y[j] += A[i][j]·tmp[i]  (column updates: stride-n writes)
        b.counted_loop(nn, |b, i| {
            let ti = b.load_f64(tmp_buf, i);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a_buf, i, j, n as i64);
                let yj = b.load_f64(y_buf, j);
                let p = b.fmul(aij, ti);
                let s = b.fadd(yj, p);
                b.store_f64(y_buf, j, s);
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let (a, x) = gen(n, seed);
        let prog = self.build(n, seed);
        let got = run_and_read(&prog, "y")?;
        Ok(max_abs_err(&got, &native(n, &a, &x)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Atax.validate(17, 3).unwrap() < 1e-12);
    }

    #[test]
    fn known_small_case() {
        // n=2, A=[[1,2],[3,4]], x=[1,1] → Ax=[3,7], AᵀAx=[1·3+3·7, 2·3+4·7]=[24, 34]
        let y = native(2, &[1.0, 2.0, 3.0, 4.0], &[1.0, 1.0]);
        assert_eq!(y, vec![24.0, 34.0]);
    }
}
