//! gemver: rank-2 update + two transposed matrix-vector products:
//! Â = A + u1·v1ᵀ + u2·v2ᵀ;  x = β·Âᵀ·y + z;  w = α·Â·x.

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Gemver;

const ALPHA: f64 = 1.5;
const BETA: f64 = 1.2;

struct Data {
    a: Vec<f64>,
    u1: Vec<f64>,
    v1: Vec<f64>,
    u2: Vec<f64>,
    v2: Vec<f64>,
    y: Vec<f64>,
    z: Vec<f64>,
}

fn gen(n: usize, seed: u64) -> Data {
    let mut rng = Rng::new(seed ^ 0x6E37);
    Data {
        a: gen_vec(&mut rng, n * n),
        u1: gen_vec(&mut rng, n),
        v1: gen_vec(&mut rng, n),
        u2: gen_vec(&mut rng, n),
        v2: gen_vec(&mut rng, n),
        y: gen_vec(&mut rng, n),
        z: gen_vec(&mut rng, n),
    }
}

fn native(n: usize, d: &Data) -> Vec<f64> {
    let mut a = d.a.clone();
    for i in 0..n {
        for j in 0..n {
            a[i * n + j] += d.u1[i] * d.v1[j] + d.u2[i] * d.v2[j];
        }
    }
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            acc += BETA * a[j * n + i] * d.y[j];
        }
        x[i] = acc + d.z[i];
    }
    let mut w = vec![0.0; n];
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            acc += ALPHA * a[i * n + j] * x[j];
        }
        w[i] = acc;
    }
    w
}

impl Kernel for Gemver {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "gemver",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "8000",
            summary: "rank-2 update + transposed MV chain",
        }
    }

    fn default_n(&self) -> usize {
        576
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let d = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("gemver");
        let a_buf = b.alloc_f64_init("A", &d.a);
        let u1 = b.alloc_f64_init("u1", &d.u1);
        let v1 = b.alloc_f64_init("v1", &d.v1);
        let u2 = b.alloc_f64_init("u2", &d.u2);
        let v2 = b.alloc_f64_init("v2", &d.v2);
        let y = b.alloc_f64_init("y", &d.y);
        let z = b.alloc_f64_init("z", &d.z);
        let x = b.alloc_f64("x", n);
        let w = b.alloc_f64("w", n);
        let nn = b.const_i(ni);
        let alpha = b.const_f(ALPHA);
        let beta = b.const_f(BETA);

        // Â = A + u1 v1ᵀ + u2 v2ᵀ
        b.counted_loop(nn, |b, i| {
            let u1i = b.load_f64(u1, i);
            let u2i = b.load_f64(u2, i);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a_buf, i, j, ni);
                let v1j = b.load_f64(v1, j);
                let v2j = b.load_f64(v2, j);
                let p1 = b.fmul(u1i, v1j);
                let p2 = b.fmul(u2i, v2j);
                let s1 = b.fadd(aij, p1);
                let s2 = b.fadd(s1, p2);
                b.store_f64_2d(a_buf, i, j, ni, s2);
            });
        });
        // x[i] = β Σ_j Â[j][i] y[j] + z[i]  (column walk: stride-n loads)
        b.counted_loop(nn, |b, i| {
            let acc = b.const_f(0.0);
            b.counted_loop(nn, |b, j| {
                let aji = b.load_f64_2d(a_buf, j, i, ni);
                let yj = b.load_f64(y, j);
                let p = b.fmul(aji, yj);
                let bp = b.fmul(beta, p);
                let s = b.fadd(acc, bp);
                b.assign(acc, s);
            });
            let zi = b.load_f64(z, i);
            let xi = b.fadd(acc, zi);
            b.store_f64(x, i, xi);
        });
        // w[i] = α Σ_j Â[i][j] x[j]
        b.counted_loop(nn, |b, i| {
            let acc = b.const_f(0.0);
            b.counted_loop(nn, |b, j| {
                let aij = b.load_f64_2d(a_buf, i, j, ni);
                let xj = b.load_f64(x, j);
                let p = b.fmul(aij, xj);
                let ap = b.fmul(alpha, p);
                let s = b.fadd(acc, ap);
                b.assign(acc, s);
            });
            b.store_f64(w, i, acc);
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let d = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "w")?;
        Ok(max_abs_err(&got, &native(n, &d)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Gemver.validate(11, 5).unwrap() < 1e-12);
    }
}
