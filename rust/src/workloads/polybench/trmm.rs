//! trmm: B = α·Aᵀ·B with A unit lower triangular (Polybench 4.2 variant):
//! B[i][j] += Σ_{k>i} A[k][i]·B[k][j]; B[i][j] *= α.

use anyhow::Result;

use super::gen_vec;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Trmm;

const ALPHA: f64 = 1.5;

fn gen(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x7233);
    (gen_vec(&mut rng, n * n), gen_vec(&mut rng, n * n))
}

fn native(n: usize, a: &[f64], b0: &[f64]) -> Vec<f64> {
    let mut b = b0.to_vec();
    for i in 0..n {
        for j in 0..n {
            let mut acc = b[i * n + j];
            for k in i + 1..n {
                acc += a[k * n + i] * b[k * n + j];
            }
            b[i * n + j] = ALPHA * acc;
        }
    }
    b
}

impl Kernel for Trmm {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "trmm",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "B = alpha A^T B (A lower-triangular)",
        }
    }

    fn default_n(&self) -> usize {
        112
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let (a, b0) = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("trmm");
        let a_buf = b.alloc_f64_init("A", &a);
        let b_buf = b.alloc_f64_init("B", &b0);
        let nn = b.const_i(ni);
        let alpha = b.const_f(ALPHA);
        let one = b.const_i(1);

        b.counted_loop(nn, |b, i| {
            b.counted_loop(nn, |b, j| {
                let acc = b.load_f64_2d(b_buf, i, j, ni);
                let ip1 = b.add(i, one);
                b.loop_range(ip1, nn, |b, k| {
                    let aki = b.load_f64_2d(a_buf, k, i, ni); // column walk
                    let bkj = b.load_f64_2d(b_buf, k, j, ni);
                    let p = b.fmul(aki, bkj);
                    let s = b.fadd(acc, p);
                    b.assign(acc, s);
                });
                let scaled = b.fmul(alpha, acc);
                b.store_f64_2d(b_buf, i, j, ni, scaled);
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let (a, b0) = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "B")?;
        Ok(max_abs_err(&got, &native(n, &a, &b0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Trmm.validate(10, 13).unwrap() < 1e-12);
    }

    #[test]
    fn triangular_structure_respected() {
        // with upper part of A never read, zeroing it must not change output
        let n = 6;
        let (mut a, b0) = gen(n, 1);
        let want = native(n, &a, &b0);
        for i in 0..n {
            for j in i + 1..n {
                a[i * n + j] = 999.0; // A[i][j] with j>i is never read
            }
        }
        assert_eq!(native(n, &a, &b0), want);
    }
}
