//! lu: in-place LU factorization (no pivoting; diagonally dominant input).
//!
//! The paper singles lu out in the Fig-6 discussion: diagonal-matrix
//! accesses hurt traditional CPUs, making it a borderline NMC candidate.

use anyhow::Result;

use super::dd_matrix;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Lu;

fn gen(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x1001);
    dd_matrix(&mut rng, n)
}

fn native(n: usize, a0: &[f64]) -> Vec<f64> {
    let mut a = a0.to_vec();
    for i in 0..n {
        for j in 0..i {
            for k in 0..j {
                a[i * n + j] -= a[i * n + k] * a[k * n + j];
            }
            a[i * n + j] /= a[j * n + j];
        }
        for j in i..n {
            for k in 0..i {
                a[i * n + j] -= a[i * n + k] * a[k * n + j];
            }
        }
    }
    a
}

impl Kernel for Lu {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "lu",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "in-place LU factorization",
        }
    }

    fn default_n(&self) -> usize {
        144
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let a0 = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("lu");
        let a_buf = b.alloc_f64_init("A", &a0);
        let nn = b.const_i(ni);
        let zero = b.const_i(0);

        b.counted_loop(nn, |b, i| {
            b.loop_range(zero, i, |b, j| {
                let acc = b.load_f64_2d(a_buf, i, j, ni);
                b.loop_range(zero, j, |b, k| {
                    let aik = b.load_f64_2d(a_buf, i, k, ni);
                    let akj = b.load_f64_2d(a_buf, k, j, ni);
                    let p = b.fmul(aik, akj);
                    let s = b.fsub(acc, p);
                    b.assign(acc, s);
                });
                let ajj = b.load_f64_2d(a_buf, j, j, ni);
                let q = b.fdiv(acc, ajj);
                b.store_f64_2d(a_buf, i, j, ni, q);
            });
            b.loop_range(i, nn, |b, j| {
                let acc = b.load_f64_2d(a_buf, i, j, ni);
                b.loop_range(zero, i, |b, k| {
                    let aik = b.load_f64_2d(a_buf, i, k, ni);
                    let akj = b.load_f64_2d(a_buf, k, j, ni);
                    let p = b.fmul(aik, akj);
                    let s = b.fsub(acc, p);
                    b.assign(acc, s);
                });
                b.store_f64_2d(a_buf, i, j, ni, acc);
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let a0 = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "A")?;
        Ok(max_abs_err(&got, &native(n, &a0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Lu.validate(12, 17).unwrap() < 1e-9);
    }

    #[test]
    fn lu_reconstructs_input() {
        let n = 7;
        let a0 = gen(n, 3);
        let f = native(n, &a0);
        // (L with unit diagonal)·U ≈ A₀
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..=i.min(j) {
                    let l = if k == i { 1.0 } else { f[i * n + k] };
                    let u = f[k * n + j];
                    if k <= j && k <= i {
                        s += l * u;
                    }
                }
                assert!(
                    (s - a0[i * n + j]).abs() < 1e-8,
                    "({i},{j}): {s} vs {}",
                    a0[i * n + j]
                );
            }
        }
    }
}
