//! cholesky: in-place A = L·Lᵀ factorization of an SPD matrix.
//!
//! Strongly serial (each column depends on all previous) with triangular
//! loop bounds — the paper's example of a high-spatial-locality kernel that
//! *still* benefits from NMC.

use anyhow::Result;

use super::spd_matrix;
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{run_and_read, Kernel, KernelInfo, Suite};

pub struct Cholesky;

fn gen(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xC401);
    spd_matrix(&mut rng, n)
}

fn native(n: usize, a0: &[f64]) -> Vec<f64> {
    let mut a = a0.to_vec();
    for i in 0..n {
        for j in 0..i {
            for k in 0..j {
                a[i * n + j] -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] /= a[j * n + j];
        }
        for k in 0..i {
            a[i * n + i] -= a[i * n + k] * a[i * n + k];
        }
        a[i * n + i] = a[i * n + i].sqrt();
    }
    a
}

impl Kernel for Cholesky {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "cholesky",
            suite: Suite::Polybench,
            param_name: "dimensions",
            paper_value: "2000",
            summary: "in-place LL^T factorization",
        }
    }

    fn default_n(&self) -> usize {
        160
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let a0 = gen(n, seed);
        let ni = n as i64;
        let mut b = ProgramBuilder::new("cholesky");
        let a_buf = b.alloc_f64_init("A", &a0);
        let nn = b.const_i(ni);
        let zero = b.const_i(0);

        b.counted_loop(nn, |b, i| {
            // for j in 0..i
            b.loop_range(zero, i, |b, j| {
                let acc = b.load_f64_2d(a_buf, i, j, ni);
                b.loop_range(zero, j, |b, k| {
                    let aik = b.load_f64_2d(a_buf, i, k, ni);
                    let ajk = b.load_f64_2d(a_buf, j, k, ni);
                    let p = b.fmul(aik, ajk);
                    let s = b.fsub(acc, p);
                    b.assign(acc, s);
                });
                let ajj = b.load_f64_2d(a_buf, j, j, ni);
                let q = b.fdiv(acc, ajj);
                b.store_f64_2d(a_buf, i, j, ni, q);
            });
            // diagonal
            let acc = b.load_f64_2d(a_buf, i, i, ni);
            b.loop_range(zero, i, |b, k| {
                let aik = b.load_f64_2d(a_buf, i, k, ni);
                let p = b.fmul(aik, aik);
                let s = b.fsub(acc, p);
                b.assign(acc, s);
            });
            let r = b.fsqrt(acc);
            b.store_f64_2d(a_buf, i, i, ni, r);
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let a0 = gen(n, seed);
        let got = run_and_read(&self.build(n, seed), "A")?;
        // compare the lower triangle (upper is untouched input)
        let want = native(n, &a0);
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..=i {
                err = err.max((got[i * n + j] - want[i * n + j]).abs());
            }
        }
        Ok(err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Cholesky.validate(12, 15).unwrap() < 1e-9);
    }

    #[test]
    fn factor_reconstructs_input() {
        let n = 8;
        let a0 = gen(n, 2);
        let l = native(n, &a0);
        // L·Lᵀ ≈ A₀
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..=i.min(j) {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!(
                    (s - a0[i * n + j]).abs() < 1e-8,
                    "({i},{j}): {s} vs {}",
                    a0[i * n + j]
                );
            }
        }
    }
}
