//! kmeans (Rodinia): Lloyd iterations over `n` points in `DIM`-d space,
//! `K` clusters, fixed `ITERS` assignment/update rounds.
//!
//! Point scans are sequential; the per-point center scan revisits the small
//! centroid table constantly — a mixed-locality, compare-heavy pattern.

use anyhow::Result;

use crate::interp::{run_program, NullInstrument};
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Kmeans;

const DIM: usize = 4;
const K: usize = 5;
const ITERS: usize = 3;

struct Data {
    points: Vec<f64>,  // [n][DIM]
    centers: Vec<f64>, // [K][DIM] initial
}

fn gen(n: usize, seed: u64) -> Data {
    let mut rng = Rng::new(seed ^ 0x04EA);
    // K Gaussian blobs so assignments are non-degenerate
    let blob_centers: Vec<f64> = (0..K * DIM).map(|_| rng.range_f64(-8.0, 8.0)).collect();
    let mut points = Vec::with_capacity(n * DIM);
    for p in 0..n {
        let c = p % K;
        for d in 0..DIM {
            points.push(blob_centers[c * DIM + d] + rng.normal());
        }
    }
    // initial centers = first K points (Rodinia style)
    let centers = points[..K * DIM].to_vec();
    Data { points, centers }
}

struct NativeOut {
    centers: Vec<f64>,
    membership: Vec<i64>,
}

fn native(n: usize, d: &Data) -> NativeOut {
    let mut centers = d.centers.clone();
    let mut membership = vec![0i64; n];
    for _ in 0..ITERS {
        // assign
        for p in 0..n {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..K {
                let mut dist = 0.0;
                for q in 0..DIM {
                    let diff = d.points[p * DIM + q] - centers[c * DIM + q];
                    dist += diff * diff;
                }
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            membership[p] = best as i64;
        }
        // update
        let mut sums = vec![0.0; K * DIM];
        let mut counts = vec![0.0f64; K];
        for p in 0..n {
            let c = membership[p] as usize;
            counts[c] += 1.0;
            for q in 0..DIM {
                sums[c * DIM + q] += d.points[p * DIM + q];
            }
        }
        for c in 0..K {
            if counts[c] > 0.0 {
                for q in 0..DIM {
                    centers[c * DIM + q] = sums[c * DIM + q] / counts[c];
                }
            }
        }
    }
    NativeOut { centers, membership }
}

impl Kernel for Kmeans {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "kmeans",
            suite: Suite::Rodinia,
            param_name: "data size",
            paper_value: "819k",
            summary: "Lloyd k-means (K=5, 4-d, 3 iterations)",
        }
    }

    fn default_n(&self) -> usize {
        5120
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let d = gen(n, seed);
        let dim_i = DIM as i64;
        let mut b = ProgramBuilder::new("kmeans");
        let pts = b.alloc_f64_init("points", &d.points);
        let ctr = b.alloc_f64_init("centers", &d.centers);
        let mem = b.alloc_i64("membership", n);
        let sums = b.alloc_f64("sums", K * DIM);
        let counts = b.alloc_f64("counts", K);

        let nn = b.const_i(n as i64);
        let kk = b.const_i(K as i64);
        let dd = b.const_i(dim_i);
        let zero = b.const_i(0);
        let fzero = b.const_f(0.0);
        let fone = b.const_f(1.0);
        let iters = b.const_i(ITERS as i64);

        b.counted_loop(iters, |b, _t| {
            // assignment
            b.counted_loop(nn, |b, p| {
                let best = b.const_i(0);
                let best_d = b.const_f(f64::INFINITY);
                b.counted_loop(kk, |b, c| {
                    let dist = b.const_f(0.0);
                    b.counted_loop(dd, |b, q| {
                        let pv = {
                            let idx = b.idx2(p, q, dim_i);
                            b.load_f64(pts, idx)
                        };
                        let cv = {
                            let idx = b.idx2(c, q, dim_i);
                            b.load_f64(ctr, idx)
                        };
                        let diff = b.fsub(pv, cv);
                        let sq = b.fmul(diff, diff);
                        let s = b.fadd(dist, sq);
                        b.assign(dist, s);
                    });
                    let closer = b.fcmp_lt(dist, best_d);
                    b.if_then(closer, |b| {
                        b.assign(best_d, dist);
                        b.assign(best, c);
                    });
                });
                b.store_i64(mem, p, best);
            });
            // clear accumulators
            let kd = b.const_i((K * DIM) as i64);
            b.counted_loop(kd, |b, i| {
                b.store_f64(sums, i, fzero);
            });
            b.counted_loop(kk, |b, c| {
                b.store_f64(counts, c, fzero);
            });
            // accumulate
            b.counted_loop(nn, |b, p| {
                let c = b.load_i64(mem, p);
                let cnt = b.load_f64(counts, c);
                let cnt1 = b.fadd(cnt, fone);
                b.store_f64(counts, c, cnt1);
                b.counted_loop(dd, |b, q| {
                    let pidx = b.idx2(p, q, dim_i);
                    let pv = b.load_f64(pts, pidx);
                    let sidx = b.idx2(c, q, dim_i);
                    let sv = b.load_f64(sums, sidx);
                    let s = b.fadd(sv, pv);
                    b.store_f64(sums, sidx, s);
                });
            });
            // recenter
            b.counted_loop(kk, |b, c| {
                let cnt = b.load_f64(counts, c);
                let nonzero = b.fcmp_gt(cnt, fzero);
                b.if_then(nonzero, |b| {
                    b.counted_loop(dd, |b, q| {
                        let sidx = b.idx2(c, q, dim_i);
                        let sv = b.load_f64(sums, sidx);
                        let avg = b.fdiv(sv, cnt);
                        b.store_f64(ctr, sidx, avg);
                    });
                });
            });
        });
        let _ = zero;
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let n = n.max(K); // need at least K points for initial centers
        let d = gen(n, seed);
        let prog = self.build(n, seed);
        let want = native(n, &d);
        let got_c = run_and_read(&prog, "centers")?;
        let (_, machine) = run_program(&prog, &mut NullInstrument)?;
        let mbuf = prog.buffer("membership").unwrap();
        let got_m = machine.mem.read_i64_slice(mbuf.base, n)?;
        let mism = got_m
            .iter()
            .zip(&want.membership)
            .filter(|(a, b)| a != b)
            .count();
        Ok(max_abs_err(&got_c, &want.centers).max(mism as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Kmeans.validate(60, 25).unwrap() < 1e-12);
    }

    #[test]
    fn centers_move_toward_blobs() {
        // after 3 iterations the centers should separate (not all equal)
        let n = 100;
        let out = native(n, &gen(n, 8));
        let c = &out.centers;
        let mut distinct = 0;
        for a in 0..K {
            for b in a + 1..K {
                let d2: f64 = (0..DIM)
                    .map(|q| (c[a * DIM + q] - c[b * DIM + q]).powi(2))
                    .sum();
                if d2 > 1.0 {
                    distinct += 1;
                }
            }
        }
        assert!(distinct >= K, "centers collapsed: {distinct}");
    }
}
