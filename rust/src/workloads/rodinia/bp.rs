//! bp (Rodinia backprop): one epoch of a 2-layer MLP — forward pass,
//! output/hidden deltas, weight updates with momentum.
//!
//! `n` is the input-layer width (the paper's "layer size 1.1m"); the hidden
//! layer is fixed at 16 units as in Rodinia. The forward loop walks the
//! [input][hidden] weight matrix column-wise (stride 16·8 B = 2 cache
//! lines), giving bp its signature high memory entropy / low spatial
//! locality (paper Figs 3a/3b).

use anyhow::Result;

use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{max_abs_err, run_and_read, Kernel, KernelInfo, Suite};

pub struct Backprop;

const HID: usize = 16;
const ETA: f64 = 0.3;
const MOMENTUM: f64 = 0.3;
const TARGET: f64 = 0.1;

struct Data {
    input: Vec<f64>,
    w1: Vec<f64>, // [n][HID] input→hidden (+1 bias row would be n+1 in Rodinia; omitted)
    w2: Vec<f64>, // [HID] hidden→output
}

fn gen(n: usize, seed: u64) -> Data {
    let mut rng = Rng::new(seed ^ 0xB9);
    Data {
        input: (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect(),
        w1: (0..n * HID).map(|_| rng.range_f64(-0.5, 0.5)).collect(),
        w2: (0..HID).map(|_| rng.range_f64(-0.5, 0.5)).collect(),
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

struct NativeOut {
    w1: Vec<f64>,
    w2: Vec<f64>,
    hidden: Vec<f64>,
}

fn native(n: usize, d: &Data) -> NativeOut {
    // forward
    let mut hidden = vec![0.0; HID];
    for j in 0..HID {
        let mut s = 0.0;
        for i in 0..n {
            s += d.input[i] * d.w1[i * HID + j];
        }
        hidden[j] = sigmoid(s);
    }
    let mut o = 0.0;
    for j in 0..HID {
        o += hidden[j] * d.w2[j];
    }
    let out = sigmoid(o);
    // deltas
    let delta_out = out * (1.0 - out) * (TARGET - out);
    let mut delta_hid = vec![0.0; HID];
    for j in 0..HID {
        delta_hid[j] = hidden[j] * (1.0 - hidden[j]) * d.w2[j] * delta_out;
    }
    // updates (momentum against zero prev-weights, as in a first epoch)
    let mut w2 = d.w2.clone();
    for j in 0..HID {
        w2[j] += ETA * delta_out * hidden[j] + MOMENTUM * 0.0;
    }
    let mut w1 = d.w1.clone();
    for i in 0..n {
        for j in 0..HID {
            w1[i * HID + j] += ETA * delta_hid[j] * d.input[i];
        }
    }
    NativeOut { w1, w2, hidden }
}

impl Kernel for Backprop {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "bp",
            suite: Suite::Rodinia,
            param_name: "layer size",
            paper_value: "1.1m",
            summary: "backprop: 2-layer MLP epoch (16 hidden units)",
        }
    }

    fn default_n(&self) -> usize {
        3584
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let d = gen(n, seed);
        let hid_i = HID as i64;
        let mut b = ProgramBuilder::new("bp");
        let in_buf = b.alloc_f64_init("input", &d.input);
        let w1_buf = b.alloc_f64_init("w1", &d.w1);
        let w2_buf = b.alloc_f64_init("w2", &d.w2);
        let hid_buf = b.alloc_f64("hidden", HID);
        let dh_buf = b.alloc_f64("delta_hid", HID);
        let out_buf = b.alloc_f64("out", 1);

        let nn = b.const_i(n as i64);
        let hh = b.const_i(hid_i);
        let zero = b.const_i(0);
        let fone = b.const_f(1.0);
        let eta = b.const_f(ETA);
        let target = b.const_f(TARGET);

        // forward hidden: column walk of w1 (stride HID·8 bytes)
        b.counted_loop(hh, |b, j| {
            let acc = b.const_f(0.0);
            b.counted_loop(nn, |b, i| {
                let x = b.load_f64(in_buf, i);
                let w = b.load_f64_2d(w1_buf, i, j, hid_i);
                let p = b.fmul(x, w);
                let s = b.fadd(acc, p);
                b.assign(acc, s);
            });
            // sigmoid(acc) = 1/(1+exp(-acc))
            let neg = b.fneg(acc);
            let e = b.fexp(neg);
            let den = b.fadd(fone, e);
            let h = b.fdiv(fone, den);
            b.store_f64(hid_buf, j, h);
        });
        // forward output
        let oacc = b.const_f(0.0);
        b.counted_loop(hh, |b, j| {
            let h = b.load_f64(hid_buf, j);
            let w = b.load_f64(w2_buf, j);
            let p = b.fmul(h, w);
            let s = b.fadd(oacc, p);
            b.assign(oacc, s);
        });
        let noacc = b.fneg(oacc);
        let eo = b.fexp(noacc);
        let den = b.fadd(fone, eo);
        let out = b.fdiv(fone, den);
        b.store_f64(out_buf, zero, out);

        // delta_out = out(1-out)(target-out)
        let om = b.fsub(fone, out);
        let to = b.fsub(target, out);
        let d1 = b.fmul(out, om);
        let delta_out = b.fmul(d1, to);

        // hidden deltas + w2 update
        b.counted_loop(hh, |b, j| {
            let h = b.load_f64(hid_buf, j);
            let hm = b.fsub(fone, h);
            let w = b.load_f64(w2_buf, j);
            let t1 = b.fmul(h, hm);
            let t2 = b.fmul(t1, w);
            let dh = b.fmul(t2, delta_out);
            b.store_f64(dh_buf, j, dh);
            let up = b.fmul(eta, delta_out);
            let up2 = b.fmul(up, h);
            let w_new = b.fadd(w, up2);
            b.store_f64(w2_buf, j, w_new);
        });
        // w1 update: row-major walk (the "good" phase)
        b.counted_loop(nn, |b, i| {
            let x = b.load_f64(in_buf, i);
            b.counted_loop(hh, |b, j| {
                let dh = b.load_f64(dh_buf, j);
                let w = b.load_f64_2d(w1_buf, i, j, hid_i);
                let p1 = b.fmul(eta, dh);
                let p2 = b.fmul(p1, x);
                let w_new = b.fadd(w, p2);
                b.store_f64_2d(w1_buf, i, j, hid_i, w_new);
            });
        });
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let d = gen(n, seed);
        let prog = self.build(n, seed);
        let want = native(n, &d);
        let got_w1 = run_and_read(&prog, "w1")?;
        let got_w2 = run_and_read(&prog, "w2")?;
        let got_h = run_and_read(&prog, "hidden")?;
        Ok(max_abs_err(&got_w1, &want.w1)
            .max(max_abs_err(&got_w2, &want.w2))
            .max(max_abs_err(&got_h, &want.hidden)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert!(Backprop.validate(64, 23).unwrap() < 1e-12);
    }

    #[test]
    fn hidden_activations_in_unit_interval() {
        let n = 32;
        let out = native(n, &gen(n, 6));
        assert!(out.hidden.iter().all(|&h| h > 0.0 && h < 1.0));
    }
}
