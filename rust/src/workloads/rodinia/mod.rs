//! Rodinia kernels (Che et al.; paper Table 2 rows 3–5): bfs, bp
//! (backprop) and kmeans — the irregular / data-analytics side of the
//! evaluation, complementing Polybench's dense kernels.

pub mod bfs;
pub mod bp;
pub mod kmeans;
