//! bfs: level-synchronous breadth-first search over a random directed graph
//! in CSR form (Rodinia's mask/updating-mask formulation).
//!
//! Pointer-chasing through `col[]` gives data-dependent, high-entropy
//! addresses — the paper's irregular-access NMC winner despite its low DLP.

use anyhow::Result;

use crate::interp::{run_program, NullInstrument};
use crate::ir::{Program, ProgramBuilder};
use crate::util::Rng;
use crate::workloads::{Kernel, KernelInfo, Suite};

pub struct Bfs;

/// CSR graph: ~`DEG` out-edges per node plus a ring edge for reachability.
const DEG: usize = 4;

pub(crate) struct Graph {
    pub row_ptr: Vec<i64>,
    pub col: Vec<i64>,
}

fn gen(n: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed ^ 0xBF5);
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col = Vec::new();
    row_ptr.push(0);
    for u in 0..n {
        // ring edge keeps every node reachable from 0
        col.push(((u + 1) % n) as i64);
        for _ in 0..DEG {
            col.push(rng.below(n as u64) as i64);
        }
        row_ptr.push(col.len() as i64);
    }
    Graph { row_ptr, col }
}

fn native(n: usize, g: &Graph) -> Vec<i64> {
    let mut cost = vec![-1i64; n];
    let mut mask = vec![false; n];
    let mut visited = vec![false; n];
    let mut updating = vec![false; n];
    cost[0] = 0;
    mask[0] = true;
    visited[0] = true;
    loop {
        let mut over = false;
        for u in 0..n {
            if mask[u] {
                mask[u] = false;
                for e in g.row_ptr[u] as usize..g.row_ptr[u + 1] as usize {
                    let v = g.col[e] as usize;
                    if !visited[v] {
                        cost[v] = cost[u] + 1;
                        updating[v] = true;
                    }
                }
            }
        }
        for v in 0..n {
            if updating[v] {
                mask[v] = true;
                visited[v] = true;
                updating[v] = false;
                over = true;
            }
        }
        if !over {
            break;
        }
    }
    cost
}

impl Kernel for Bfs {
    fn info(&self) -> KernelInfo {
        KernelInfo {
            name: "bfs",
            suite: Suite::Rodinia,
            param_name: "nodes",
            paper_value: "1.0m",
            summary: "level-synchronous BFS (CSR, mask formulation)",
        }
    }

    fn default_n(&self) -> usize {
        2048
    }

    fn build(&self, n: usize, seed: u64) -> Program {
        let g = gen(n, seed);
        let mut b = ProgramBuilder::new("bfs");
        let row_buf = b.alloc_i64_init("row_ptr", &g.row_ptr);
        let col_buf = b.alloc_i64_init("col", &g.col);
        let mask_buf = b.alloc_i64("mask", n);
        let upd_buf = b.alloc_i64("updating", n);
        let vis_buf = b.alloc_i64("visited", n);
        let cost_init = {
            let mut c = vec![-1i64; n];
            c[0] = 0;
            c
        };
        let cost_buf = b.alloc_i64_init("cost", &cost_init);
        let over_buf = b.alloc_i64("over", 1);

        let nn = b.const_i(n as i64);
        let zero = b.const_i(0);
        let one = b.const_i(1);

        // mask[0] = visited[0] = 1; over = 1 to enter the loop
        b.store_i64(mask_buf, zero, one);
        b.store_i64(vis_buf, zero, one);
        b.store_i64(over_buf, zero, one);

        b.while_loop(
            |b| {
                let o = b.load_i64(over_buf, zero);
                b.cmp_ne(o, zero)
            },
            |b| {
                b.store_i64(over_buf, zero, zero);
                // phase 1: expand frontier
                b.counted_loop(nn, |b, u| {
                    let m = b.load_i64(mask_buf, u);
                    let active = b.cmp_ne(m, zero);
                    b.if_then(active, |b| {
                        b.store_i64(mask_buf, u, zero);
                        let cu = b.load_i64(cost_buf, u);
                        let cnew = b.add(cu, one);
                        let lo = b.load_i64(row_buf, u);
                        let up1 = b.add(u, one);
                        let hi = b.load_i64(row_buf, up1);
                        b.loop_range(lo, hi, |b, e| {
                            let v = b.load_i64(col_buf, e);
                            let vis = b.load_i64(vis_buf, v);
                            let unvis = b.cmp_eq(vis, zero);
                            b.if_then(unvis, |b| {
                                b.store_i64(cost_buf, v, cnew);
                                b.store_i64(upd_buf, v, one);
                            });
                        });
                    });
                });
                // phase 2: commit next frontier
                b.counted_loop(nn, |b, v| {
                    let upd = b.load_i64(upd_buf, v);
                    let hot = b.cmp_ne(upd, zero);
                    b.if_then(hot, |b| {
                        b.store_i64(mask_buf, v, one);
                        b.store_i64(vis_buf, v, one);
                        b.store_i64(upd_buf, v, zero);
                        b.store_i64(over_buf, zero, one);
                    });
                });
            },
        );
        b.finish(None)
    }

    fn validate(&self, n: usize, seed: u64) -> Result<f64> {
        let g = gen(n, seed);
        let prog = self.build(n, seed);
        let (_, machine) = run_program(&prog, &mut NullInstrument)?;
        let buf = prog.buffer("cost").unwrap();
        let got = machine.mem.read_i64_slice(buf.base, n)?;
        let want = native(n, &g);
        let errs = got
            .iter()
            .zip(&want)
            .filter(|(a, b)| a != b)
            .count();
        Ok(errs as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_match() {
        assert_eq!(Bfs.validate(64, 21).unwrap(), 0.0);
    }

    #[test]
    fn ring_makes_everything_reachable() {
        let n = 32;
        let cost = native(n, &gen(n, 5));
        assert!(cost.iter().all(|&c| c >= 0), "{cost:?}");
        assert_eq!(cost[0], 0);
    }

    #[test]
    fn costs_are_shortest_path_lengths() {
        // BFS property: every edge (u,v) satisfies cost[v] <= cost[u] + 1
        let n = 48;
        let g = gen(n, 7);
        let cost = native(n, &g);
        for u in 0..n {
            for e in g.row_ptr[u] as usize..g.row_ptr[u + 1] as usize {
                let v = g.col[e] as usize;
                assert!(cost[v] <= cost[u] + 1);
            }
        }
    }
}
