//! The evaluated benchmark kernels (paper §III-B, Table 2): nine Polybench
//! kernels (atax, gemver, gesummv, cholesky, gramschmidt, lu, mvt, syrk,
//! trmm) and three Rodinia kernels (bfs, bp/backprop, kmeans), authored
//! against the mini-IR [`crate::ir::ProgramBuilder`] (the clang+opt step of
//! the PISA flow) and each validated against a native-Rust oracle.
//!
//! Dataset scaling: the paper profiles smaller datasets than it simulates
//! ("the analysis trend is similar for different dataset sizes", §IV-B);
//! `default_n` values here are scaled to keep a full-suite profiling run
//! interactive while preserving each kernel's access-pattern signature. The
//! paper's Table 2 parameters are retained in [`KernelInfo::paper_value`]
//! and reproduced by `pisa-nmc table 2`.

pub mod polybench;
pub mod rodinia;

use anyhow::{bail, Result};

use crate::interp::{run_program, NullInstrument};
use crate::ir::Program;

/// Benchmark suite of origin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    Polybench,
    Rodinia,
}

impl Suite {
    pub fn name(self) -> &'static str {
        match self {
            Suite::Polybench => "polybench",
            Suite::Rodinia => "rodinia",
        }
    }
}

/// Static description of a kernel (Table 2 row).
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub name: &'static str,
    pub suite: Suite,
    /// Table 2 "Param." column.
    pub param_name: &'static str,
    /// Table 2 "Values" column (the paper's simulated size).
    pub paper_value: &'static str,
    /// One-line description for docs/reports.
    pub summary: &'static str,
}

/// A runnable, verifiable benchmark kernel.
pub trait Kernel: Send + Sync {
    fn info(&self) -> KernelInfo;

    /// Construct the IR program for problem size `n` with data generated
    /// deterministically from `seed`.
    fn build(&self, n: usize, seed: u64) -> Program;

    /// Default problem size at scale 1.0 (chosen for ~10⁵–10⁷ dynamic
    /// instructions; see module docs).
    fn default_n(&self) -> usize;

    /// Run the IR program and compare its output buffers against a
    /// native-Rust implementation on identical inputs. Returns the max
    /// absolute error (should be ~0: both paths execute identical f64 op
    /// sequences).
    fn validate(&self, n: usize, seed: u64) -> Result<f64>;
}

/// Problem size after applying the CLI scale factor.
pub fn scaled_n(k: &dyn Kernel, scale: f64) -> usize {
    ((k.default_n() as f64 * scale).round() as usize).max(4)
}

/// All 12 kernels in the paper's presentation order.
pub fn registry() -> Vec<Box<dyn Kernel>> {
    vec![
        Box::new(polybench::atax::Atax),
        Box::new(polybench::gemver::Gemver),
        Box::new(polybench::gesummv::Gesummv),
        Box::new(polybench::cholesky::Cholesky),
        Box::new(polybench::gramschmidt::Gramschmidt),
        Box::new(polybench::lu::Lu),
        Box::new(polybench::mvt::Mvt),
        Box::new(polybench::syrk::Syrk),
        Box::new(polybench::trmm::Trmm),
        Box::new(rodinia::bfs::Bfs),
        Box::new(rodinia::bp::Backprop),
        Box::new(rodinia::kmeans::Kmeans),
    ]
}

/// Look a kernel up by name.
pub fn by_name(name: &str) -> Result<Box<dyn Kernel>> {
    for k in registry() {
        if k.info().name == name {
            return Ok(k);
        }
    }
    bail!(
        "unknown kernel '{name}' (available: {})",
        registry()
            .iter()
            .map(|k| k.info().name)
            .collect::<Vec<_>>()
            .join(", ")
    )
}

/// Helper shared by the kernels' `validate` implementations: run `prog`
/// uninstrumented and read back the named f64 buffer.
pub(crate) fn run_and_read(prog: &Program, buffer: &str) -> Result<Vec<f64>> {
    let (_, machine) = run_program(prog, &mut NullInstrument)?;
    let buf = prog
        .buffer(buffer)
        .ok_or_else(|| anyhow::anyhow!("no buffer {buffer}"))?;
    machine
        .mem
        .read_f64_slice(buf.base, (buf.len_bytes / 8) as usize)
}

/// Max |a - b| over two slices (oracle comparisons).
pub(crate) fn max_abs_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "oracle length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_twelve() {
        let names: Vec<_> = registry().iter().map(|k| k.info().name).collect();
        assert_eq!(
            names,
            vec![
                "atax",
                "gemver",
                "gesummv",
                "cholesky",
                "gramschmidt",
                "lu",
                "mvt",
                "syrk",
                "trmm",
                "bfs",
                "bp",
                "kmeans"
            ]
        );
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("atax").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn every_kernel_program_verifies() {
        for k in registry() {
            let p = k.build(8, 1);
            crate::ir::verify::verify_ok(&p);
        }
    }

    /// The core oracle gate: every kernel's IR execution must match its
    /// native implementation exactly-ish at two sizes and seeds.
    #[test]
    fn every_kernel_validates_small() {
        for k in registry() {
            for (n, seed) in [(6, 1u64), (13, 99u64)] {
                let err = k
                    .validate(n, seed)
                    .unwrap_or_else(|e| panic!("{} failed: {e}", k.info().name));
                assert!(
                    err < 1e-9,
                    "{} n={n} seed={seed}: max err {err}",
                    k.info().name
                );
            }
        }
    }

    #[test]
    fn scaled_n_floors() {
        let k = by_name("atax").unwrap();
        assert!(scaled_n(k.as_ref(), 1e-9) >= 4);
    }
}
