//! Fast non-cryptographic hasher for the hot analyzer maps (§Perf).
//!
//! std's default SipHash-1-3 is DoS-resistant but ~4× slower than needed
//! for the per-access HashMap updates in the reuse/entropy/dataflow
//! analyzers, whose keys are addresses and register ids we generate
//! ourselves. This is the Firefox/rustc "FxHash" multiply-fold, which is
//! the standard choice for compiler-internal maps.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: wrapping multiply + rotate fold per word.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// Drop-in `HashMap` with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FastSet<K> = HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_sequential_keys() {
        // sequential addresses must not collide into few buckets
        let mut m: FastMap<u64, u64> = FastMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 8, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u64 {
            assert_eq!(m[&(i * 8)], i);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let h = |v: u64| {
            let mut h = FxHasher::default();
            h.write_u64(v);
            h.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(42), h(43));
    }
}
