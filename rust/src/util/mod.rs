//! Shared utilities: deterministic RNG, statistics, JSON output.

pub mod fenwick;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod stats;

pub use fenwick::Fenwick;
pub use fxhash::{FastMap, FastSet};
pub use json::Json;
pub use rng::Rng;
