//! Growable Fenwick (binary-indexed) tree — the O(log n) core of the exact
//! reuse-distance analyzer (Olken-style stack distances over access
//! timestamps).

/// Fenwick tree over i64 counts, indices 0-based, grows on demand.
///
/// Growth note: a Fenwick node at (1-based) index i covers the range
/// `(i - lowbit(i), i]`, so simply zero-extending the array would leave new
/// high nodes missing the mass of already-inserted low indices. A shadow
/// vector of raw values is kept and the tree is rebuilt in O(n) on each
/// doubling — amortized O(1) per insert.
#[derive(Debug, Clone, Default)]
pub struct Fenwick {
    tree: Vec<i64>, // 1-based
    vals: Vec<i64>, // raw per-index values (rebuild source)
}

impl Fenwick {
    pub fn new() -> Fenwick {
        Fenwick { tree: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Fenwick {
        let mut f = Fenwick::new();
        f.grow_to(n);
        f
    }

    fn grow_to(&mut self, len: usize) {
        if self.vals.len() >= len {
            return;
        }
        let new_len = len.next_power_of_two().max(64);
        self.vals.resize(new_len, 0);
        // O(n) rebuild: tree[i] = sum over the range i covers.
        self.tree = vec![0; new_len + 1];
        for i in 1..=new_len {
            self.tree[i] += self.vals[i - 1];
            let parent = i + (i & i.wrapping_neg());
            if parent <= new_len {
                let add = self.tree[i];
                self.tree[parent] += add;
            }
        }
    }

    /// Add `delta` at index `i` (0-based).
    pub fn add(&mut self, i: usize, delta: i64) {
        self.grow_to(i + 1);
        self.vals[i] += delta;
        let mut idx = i + 1;
        while idx < self.tree.len() {
            self.tree[idx] += delta;
            idx += idx & idx.wrapping_neg();
        }
    }

    /// Sum of [0, i] (inclusive, 0-based). i >= len is allowed (clamped).
    pub fn prefix_sum(&self, i: usize) -> u64 {
        let mut idx = (i + 1).min(self.vals.len());
        let mut s = 0i64;
        while idx > 0 {
            s += self.tree[idx];
            idx -= idx & idx.wrapping_neg();
        }
        debug_assert!(s >= 0, "negative prefix sum");
        s as u64
    }

    /// Sum of the half-open range [lo, hi).
    pub fn range_sum(&self, lo: usize, hi: usize) -> u64 {
        if hi <= lo {
            return 0;
        }
        let upper = self.prefix_sum(hi - 1);
        if lo == 0 {
            upper
        } else {
            upper - self.prefix_sum(lo - 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn point_updates_and_sums() {
        let mut f = Fenwick::new();
        f.add(0, 1);
        f.add(5, 2);
        f.add(9, 3);
        assert_eq!(f.prefix_sum(0), 1);
        assert_eq!(f.prefix_sum(4), 1);
        assert_eq!(f.prefix_sum(5), 3);
        assert_eq!(f.prefix_sum(100), 6);
        assert_eq!(f.range_sum(1, 6), 2);
        assert_eq!(f.range_sum(6, 6), 0);
    }

    #[test]
    fn matches_naive_randomized() {
        let mut rng = Rng::new(3);
        let mut f = Fenwick::new();
        let mut naive = vec![0i64; 2000];
        for _ in 0..5000 {
            let i = rng.below(2000) as usize;
            if rng.below(2) == 0 && naive[i] > 0 {
                f.add(i, -1);
                naive[i] -= 1;
            } else {
                f.add(i, 1);
                naive[i] += 1;
            }
        }
        for probe in [0usize, 1, 7, 512, 1999] {
            let want: i64 = naive[..=probe].iter().sum();
            assert_eq!(f.prefix_sum(probe), want as u64);
        }
    }

    #[test]
    fn growth_preserves_existing_mass() {
        let mut f = Fenwick::new();
        for i in 0..50 {
            f.add(i, 1);
        }
        // force several doublings
        f.add(10_000, 5);
        assert_eq!(f.prefix_sum(49), 50);
        assert_eq!(f.prefix_sum(9_999), 50);
        assert_eq!(f.prefix_sum(10_000), 55);
        f.add(1_000_000, 7);
        assert_eq!(f.prefix_sum(1_000_000), 62);
        assert_eq!(f.range_sum(50, 10_000), 0);
    }

    #[test]
    fn incremental_growth_matches_naive() {
        let mut rng = Rng::new(17);
        let mut f = Fenwick::new();
        let mut naive: Vec<i64> = Vec::new();
        for step in 0..3000usize {
            // monotonically growing index domain, like reuse timestamps
            let i = step;
            naive.resize(i + 1, 0);
            naive[i] += 1;
            f.add(i, 1);
            if step % 97 == 0 && step > 10 {
                let probe = rng.below(step as u64) as usize;
                let want: i64 = naive[..=probe].iter().sum();
                assert_eq!(f.prefix_sum(probe), want as u64, "probe {probe}");
            }
        }
    }
}
