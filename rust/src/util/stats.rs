//! Small statistics helpers shared by analyzers, simulators and the bench
//! harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0 for len < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (sorts a copy); 0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread for the bench harness).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Shannon entropy in bits of a count distribution (native Rust oracle for
/// the Pallas entropy artifact; also used directly by analyzers).
///
/// Counts are sorted before the float reduction so the result is
/// bit-identical regardless of the caller's (HashMap) iteration order —
/// profiling reports must be reproducible run-to-run.
pub fn shannon_entropy_counts<I: IntoIterator<Item = u64>>(counts: I) -> f64 {
    let mut counts: Vec<u64> = counts.into_iter().filter(|&c| c > 0).collect();
    counts.sort_unstable();
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    -counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p.log2()
        })
        .sum::<f64>()
}

/// Pearson correlation coefficient; 0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Spearman rank correlation (used by EXPERIMENTS.md shape checks: "who wins
/// and in what order" is a rank statement).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Geometric mean of positive values; 0 if any non-positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118_033_988_749_895).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_and_point() {
        assert!((shannon_entropy_counts([1, 1, 1, 1]) - 2.0).abs() < 1e-12);
        assert_eq!(shannon_entropy_counts([5]), 0.0);
        assert_eq!(shannon_entropy_counts([]), 0.0);
        assert_eq!(shannon_entropy_counts([0, 0]), 0.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0];
        assert!((pearson(&x, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&x, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.0, -1.0]), 0.0);
    }

    #[test]
    fn mad_robust() {
        assert_eq!(mad(&[1.0, 1.0, 1.0, 100.0]), 0.0);
    }
}
