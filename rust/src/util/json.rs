//! Minimal JSON document builder + parser (PISA emits its analysis results
//! as JSON; the runtime reads the AOT manifest). No serde in the offline
//! vendor set.

use std::collections::BTreeMap;
use std::fmt::Write;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable for
//  goldens and diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — builder misuse).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like most encoders
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parsing (manifest.json)

impl Json {
    /// Parse a JSON document. Supports the full value grammar minus exotic
    /// escapes (\uXXXX surrogate pairs are passed through unpaired).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = *self.b.get(self.i).ok_or("bad escape")?;
                    out.push(match c {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b'r' => '\r',
                        b't' => '\t',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            char::from_u32(code).unwrap_or('\u{fffd}')
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    });
                    self.i += 1;
                }
                _ => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().ok_or("bad utf8")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
        Err("unterminated string".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::from).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_roundtrip_shape() {
        let mut j = Json::obj();
        j.set("name", "atax").set("n", 42u64).set("ok", true);
        j.set("vals", vec![1.0, 2.5]);
        let s = j.to_string_compact();
        assert_eq!(s, r#"{"n":42,"name":"atax","ok":true,"vals":[1,2.5]}"#);
    }

    #[test]
    fn escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_compact(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn pretty_has_indentation() {
        let mut j = Json::obj();
        j.set("a", 1u64);
        let s = j.to_string_pretty();
        assert!(s.contains("\n  \"a\": 1\n"));
    }
}

#[cfg(test)]
mod parse_tests {
    use super::*;

    #[test]
    fn roundtrip_parse() {
        let src = r#"{"abi":1,"shapes":{"G":16,"B":4096},"arr":[1,2.5,-3e2],"s":"a\nb","t":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("abi").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("shapes").unwrap().get("B").unwrap().as_f64(),
            Some(4096.0)
        );
        let arr = j.get("arr").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64(), Some(-300.0));
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\nb"));
        // reparse our own pretty output
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(again, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }
}
