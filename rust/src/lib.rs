//! # PISA-NMC — Platform-Independent Software Analysis for Near-Memory Computing
//!
//! Reproduction of Corda et al., *Platform Independent Software Analysis for
//! Near Memory Computing* (cs.PF 2019), as a three-layer Rust + JAX/Pallas
//! system (see DESIGN.md):
//!
//! * [`ir`] + [`interp`] — the hardware-agnostic mini-IR and instrumented
//!   execution engine (PISA's LLVM front half, substituted per DESIGN.md).
//! * [`analysis`] — streaming trace analyzers: instruction mix, branch
//!   entropy, memory entropy, data-temporal-reuse / spatial locality, ILP,
//!   DLP, BBLP, PBBLP (the paper's §II metrics).
//! * [`trace`] — trace ingestion: the `TraceSource` abstraction, the
//!   versioned `.pallas-trace` binary chunk format, and the record/replay
//!   writer/reader pair.
//! * [`traffic`] — streaming memory-traffic subsystem: one-pass miss-ratio
//!   curves, an inclusive/exclusive L1→L2→LLC hierarchy replay and
//!   post-hierarchy DRAM byte accounting from the chunk lanes (the
//!   NMPO-style data-movement signals).
//! * [`workloads`] — the 12 evaluated Polybench/Rodinia kernels authored on
//!   the IR builder, each validated against a native oracle.
//! * [`sim`] — the host (Power9-class) and NMC (HMC + in-order PEs) machine
//!   models that produce the paper's EDP comparison (Fig 4).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   analytics artifacts (entropy, spatial locality, PCA).
//! * [`coordinator`] — the profiling pipeline: fan-out across workloads,
//!   streaming analyzers, feature assembly, PCA, figure/table regeneration.
//!
//! Quickstart: see `examples/quickstart.rs`; full pipeline:
//! `examples/offload_advisor.rs` or `pisa-nmc pipeline`.

pub mod analysis;
pub mod cli;
pub mod coordinator;
pub mod fault;
pub mod interp;
pub mod ir;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod trace;
pub mod traffic;
pub mod util;
pub mod workloads;
