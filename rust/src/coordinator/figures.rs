//! Figure/table regeneration (paper §IV): packs pipeline results into each
//! figure's data series, routing the numeric analytics through the PJRT
//! artifacts when available (the system path) with the native analyzers as
//! fallback and cross-check.
//!
//! Every figure renderer takes the run's [`MetricSet`]: series whose
//! analyzer family was deselected via `--metrics` are greyed out (marked
//! `deselected` in the JSON, "–" or an omission note in the text) instead
//! of silently rendering all-zero data as if it were measured.

use anyhow::Result;

use super::pca::{pca, Pca};
use super::pipeline::AppResult;
use crate::analysis::reuse::{bin_values, N_DIST_BINS, N_LINE_SIZES};
use crate::analysis::spatial::score_label;
use crate::analysis::{Metric, MetricSet};
use crate::report::{bar_chart, scatter, Table};
use crate::runtime::Runtime;
use crate::traffic::capacity_label;
use crate::util::Json;
use crate::workloads::registry;

/// Which engine produced the analytics numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Pjrt,
    Native,
}

impl Engine {
    pub fn name(self) -> &'static str {
        match self {
            Engine::Pjrt => "pjrt",
            Engine::Native => "native",
        }
    }
}

/// Suite-level analytics: per-app entropy/spatial series + the PCA plane.
pub struct SuiteAnalytics {
    pub engine: Engine,
    /// [app][granularity 0..=10] memory entropy (bits).
    pub entropies: Vec<Vec<f64>>,
    /// [app] Fig-5 metric.
    pub entropy_diff: Vec<f64>,
    /// [app][line-size doubling 0..7] spatial score.
    pub spatial: Vec<Vec<f64>>,
    /// PCA over the paper's 4 features.
    pub pca: Pca,
    /// Max |pjrt - native| seen across cross-checked quantities (0 when
    /// engine == Native).
    pub max_crosscheck_err: f64,
}

/// Run the L2/L1 analytics for the suite. With a runtime, every app's
/// entropy + spatial reduction and the suite PCA execute as AOT artifacts;
/// native values are computed anyway and compared.
pub fn analyze_suite(apps: &[AppResult], rt: Option<&Runtime>) -> Result<SuiteAnalytics> {
    let native_entropies: Vec<Vec<f64>> = apps
        .iter()
        .map(|a| a.metrics.mem_entropy.entropies.clone())
        .collect();
    let native_diff: Vec<f64> = apps
        .iter()
        .map(|a| a.metrics.mem_entropy.entropy_diff)
        .collect();
    let native_spatial: Vec<Vec<f64>> =
        apps.iter().map(|a| a.metrics.spatial.scores.clone()).collect();
    let features: Vec<Vec<f64>> = apps
        .iter()
        .map(|a| a.metrics.pca4_features().to_vec())
        .collect();

    let Some(rt) = rt else {
        let mask = vec![true; apps.len()];
        return Ok(SuiteAnalytics {
            engine: Engine::Native,
            entropies: native_entropies,
            entropy_diff: native_diff,
            spatial: native_spatial,
            pca: pca(&features, &mask, 2),
            max_crosscheck_err: 0.0,
        });
    };

    let g = rt.manifest().shape("G")?;
    let b = rt.manifest().shape("B")?;
    let n_cap = rt.manifest().shape("N")?;
    let mut err = 0.0f64;

    let mut entropies = Vec::with_capacity(apps.len());
    let mut entropy_diff = Vec::with_capacity(apps.len());
    let mut spatial = Vec::with_capacity(apps.len());
    for (ai, a) in apps.iter().enumerate() {
        // entropy artifact
        let (counts, weights) = a.metrics.mem_entropy.to_artifact_inputs(g, b);
        let out = rt.execute("entropy", &[&counts, &weights])?;
        let h: Vec<f64> = out[0][..native_entropies[ai].len()]
            .iter()
            .map(|&v| v as f64)
            .collect();
        // diff over the REAL granularity rows (the artifact's padded rows
        // would drag zeros in, so recompute the O(G) mean from h)
        let d: f64 = h.windows(2).map(|w| w[0] - w[1]).sum::<f64>() / (h.len() - 1) as f64;
        for (x, y) in h.iter().zip(&native_entropies[ai]) {
            err = err.max((x - y).abs());
        }
        entropies.push(h);
        entropy_diff.push(d);

        // spatial artifact (binned — compared loosely against exact native)
        let hist = a.metrics.reuse.to_artifact_hist();
        let binv: Vec<f32> = bin_values().to_vec();
        debug_assert_eq!(hist.len(), N_LINE_SIZES * N_DIST_BINS);
        let out = rt.execute("spatial", &[&hist, &binv])?;
        spatial.push(out[1].iter().map(|&v| v as f64).collect());
    }

    // PCA artifact over the paper's 4 features, padded to N rows
    anyhow::ensure!(apps.len() <= n_cap, "suite larger than pca artifact N");
    let mut x = vec![0f32; n_cap * 4];
    let mut mask = vec![0f32; n_cap];
    for (i, f) in features.iter().enumerate() {
        mask[i] = 1.0;
        for (j, &v) in f.iter().enumerate() {
            x[i * 4 + j] = v as f32;
        }
    }
    let out = rt.execute("pca4", &[&x, &mask])?;
    let scores: Vec<Vec<f64>> = (0..apps.len())
        .map(|i| vec![out[0][i * 2] as f64, out[0][i * 2 + 1] as f64])
        .collect();
    let loadings: Vec<Vec<f64>> = (0..4)
        .map(|j| vec![out[1][j * 2] as f64, out[1][j * 2 + 1] as f64])
        .collect();
    let eigenvalues: Vec<f64> = out[2].iter().map(|&v| v as f64).collect();
    let evr: Vec<f64> = out[3].iter().map(|&v| v as f64).collect();

    // cross-check against native PCA (subspace-level: compare |scores|)
    let native_pca = pca(&features, &vec![true; apps.len()], 2);
    for (s_pjrt, s_nat) in scores.iter().zip(&native_pca.scores) {
        err = err.max((s_pjrt[0].abs() - s_nat[0].abs()).abs());
    }

    Ok(SuiteAnalytics {
        engine: Engine::Pjrt,
        entropies,
        entropy_diff,
        spatial,
        pca: Pca {
            scores,
            loadings,
            eigenvalues,
            explained_variance_ratio: evr,
        },
        max_crosscheck_err: err,
    })
}

// ---------------------------------------------------------------------------
// renderers

fn app_names(apps: &[AppResult]) -> Vec<String> {
    apps.iter().map(|a| a.name.clone()).collect()
}

/// Grey-out stub for a figure whose analyzer families were all deselected
/// via `--metrics`: an omission note instead of all-zero series posing as
/// measurements, and a `deselected` marker in the JSON naming every
/// missing family.
fn deselected_figure(figure: &str, metric_desc: &str, families: &[Metric]) -> (String, Json) {
    let names: Vec<&str> = families.iter().map(|m| m.name()).collect();
    let mut out = Json::obj();
    out.set("figure", figure);
    out.set("metric", metric_desc);
    out.set("deselected", true);
    out.set(
        "families",
        names.iter().map(|&n| Json::Str(n.to_string())).collect::<Vec<Json>>(),
    );
    (
        format!(
            "Fig {figure} — {metric_desc}\n  [series omitted: family '{}' deselected via --metrics]\n",
            names.join("', '")
        ),
        out,
    )
}

/// Fig 3a: memory entropy per app × granularity.
pub fn fig3a(apps: &[AppResult], an: &SuiteAnalytics, metrics: MetricSet) -> (String, Json) {
    if !metrics.contains(Metric::MemEntropy) {
        return deselected_figure(
            "3a",
            "memory entropy (bits) by granularity shift",
            &[Metric::MemEntropy],
        );
    }
    let mut t = Table::new(&["app", "g=1B", "g=4B", "g=16B", "g=64B", "g=256B", "g=1KB"]);
    let picks = [0usize, 2, 4, 6, 8, 10];
    let mut j = Json::obj();
    for (i, name) in app_names(apps).iter().enumerate() {
        let h = &an.entropies[i];
        t.row(
            std::iter::once(name.clone())
                .chain(picks.iter().map(|&p| format!("{:.2}", h[p])))
                .collect(),
        );
        j.set(name, h.clone());
    }
    let mut out = Json::obj();
    out.set("figure", "3a");
    out.set("metric", "memory entropy (bits) by granularity shift");
    out.set("engine", an.engine.name());
    out.set("series", j);
    (format!("Fig 3a — memory entropy [{}]\n{}", an.engine.name(), t.render()), out)
}

/// Fig 3b: spatial locality per app × line doubling.
pub fn fig3b(apps: &[AppResult], an: &SuiteAnalytics, metrics: MetricSet) -> (String, Json) {
    if !metrics.contains(Metric::Reuse) {
        return deselected_figure(
            "3b",
            "spatial locality score per line-size doubling",
            &[Metric::Reuse],
        );
    }
    let labels: Vec<String> = (0..N_LINE_SIZES - 1).map(score_label).collect();
    let mut headers = vec!["app".to_string()];
    headers.extend(labels.clone());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let mut j = Json::obj();
    for (i, name) in app_names(apps).iter().enumerate() {
        let s = &an.spatial[i];
        t.row(
            std::iter::once(name.clone())
                .chain(s.iter().map(|v| format!("{v:.3}")))
                .collect(),
        );
        j.set(name, s.clone());
    }
    let mut out = Json::obj();
    out.set("figure", "3b");
    out.set("metric", "spatial locality score per line-size doubling");
    out.set("engine", an.engine.name());
    out.set("series", j);
    (format!("Fig 3b — spatial locality [{}]\n{}", an.engine.name(), t.render()), out)
}

/// Fig 3c: parallelism characterization (DLP, BBLP_1..4, PBBLP).
/// Spans three families; deselected ones are greyed out per column.
pub fn fig3c(apps: &[AppResult], metrics: MetricSet) -> (String, Json) {
    let (dlp_on, bblp_on, pbblp_on) = (
        metrics.contains(Metric::Dlp),
        metrics.contains(Metric::Bblp),
        metrics.contains(Metric::Pbblp),
    );
    if !dlp_on && !bblp_on && !pbblp_on {
        return deselected_figure(
            "3c",
            "parallelism characterization",
            &[Metric::Dlp, Metric::Bblp, Metric::Pbblp],
        );
    }
    let grey = "–".to_string();
    let mut t = Table::new(&["app", "DLP", "BBLP_1", "BBLP_2", "BBLP_3", "BBLP_4", "PBBLP"]);
    let mut j = Json::obj();
    for a in apps {
        let b = &a.metrics.bblp.values;
        let bb = |i: usize| {
            if bblp_on {
                format!("{:.2}", b[i])
            } else {
                grey.clone()
            }
        };
        t.row(vec![
            a.name.clone(),
            if dlp_on { format!("{:.2}", a.metrics.dlp.dlp) } else { grey.clone() },
            bb(0),
            bb(1),
            bb(2),
            bb(3),
            if pbblp_on {
                format!("{:.1}", a.metrics.pbblp.pbblp)
            } else {
                grey.clone()
            },
        ]);
        let mut o = Json::obj();
        if dlp_on {
            o.set("dlp", a.metrics.dlp.dlp);
        }
        if bblp_on {
            o.set("bblp", b.clone());
        }
        if pbblp_on {
            o.set("pbblp", a.metrics.pbblp.pbblp);
        }
        j.set(&a.name, o);
    }
    let mut out = Json::obj();
    out.set("figure", "3c");
    out.set("metric", "parallelism characterization");
    let deselected: Vec<Json> = [
        (dlp_on, Metric::Dlp),
        (bblp_on, Metric::Bblp),
        (pbblp_on, Metric::Pbblp),
    ]
    .into_iter()
    .filter(|&(on, _)| !on)
    .map(|(_, m)| Json::Str(m.name().to_string()))
    .collect();
    if !deselected.is_empty() {
        out.set("deselected_families", deselected);
    }
    out.set("series", j);
    (format!("Fig 3c — parallelism\n{}", t.render()), out)
}

/// Fig 4: EDP improvement host→NMC.
pub fn fig4(apps: &[AppResult]) -> (String, Json) {
    let items: Vec<(String, f64)> = apps
        .iter()
        .map(|a| (a.name.clone(), a.cmp.edp_improvement()))
        .collect();
    let mut j = Json::obj();
    for a in apps {
        j.set(&a.name, a.cmp.to_json());
    }
    let mut out = Json::obj();
    out.set("figure", "4");
    out.set("metric", "EDP_host / EDP_nmc (>1 means NMC suitable)");
    out.set("series", j);
    let chart = bar_chart("Fig 4 — EDP improvement (host/NMC)", &items, 48);
    (chart, out)
}

/// Fig 5: the entropy-difference metric.
pub fn fig5(apps: &[AppResult], an: &SuiteAnalytics, metrics: MetricSet) -> (String, Json) {
    if !metrics.contains(Metric::MemEntropy) {
        return deselected_figure(
            "5",
            "entropy_diff_mem (mean entropy drop per granularity doubling)",
            &[Metric::MemEntropy],
        );
    }
    let items: Vec<(String, f64)> = app_names(apps)
        .into_iter()
        .zip(an.entropy_diff.iter().copied())
        .collect();
    let mut j = Json::obj();
    for (name, v) in &items {
        j.set(name, *v);
    }
    let mut out = Json::obj();
    out.set("figure", "5");
    out.set("metric", "entropy_diff_mem (mean entropy drop per granularity doubling)");
    out.set("engine", an.engine.name());
    out.set("series", j);
    let chart = bar_chart(
        &format!("Fig 5 — entropy_diff_mem [{}]", an.engine.name()),
        &items,
        48,
    );
    (chart, out)
}

/// Fig 6: the PCA biplot (scores + loadings + quadrants). The four input
/// features span four families; any deselected one is flagged (its feature
/// column enters the PCA as zeros).
pub fn fig6(apps: &[AppResult], an: &SuiteAnalytics, metrics: MetricSet) -> (String, Json) {
    let feature_families = [
        (Metric::Bblp, "BBLP_1"),
        (Metric::Pbblp, "PBBLP"),
        (Metric::MemEntropy, "entropy_diff_mem"),
        (Metric::Reuse, "spat_8B_16B"),
    ];
    let missing: Vec<&str> = feature_families
        .iter()
        .filter(|(m, _)| !metrics.contains(*m))
        .map(|(_, n)| *n)
        .collect();
    let pts: Vec<(String, f64, f64)> = app_names(apps)
        .into_iter()
        .enumerate()
        .map(|(i, n)| (n, an.pca.scores[i][0], an.pca.scores[i][1]))
        .collect();
    let plot = scatter(&pts, 64, 21);

    let feature_names = ["BBLP_1", "PBBLP", "entropy_diff_mem", "spat_8B_16B"];
    let mut lt = Table::new(&["feature", "PC1", "PC2"]);
    for (j, name) in feature_names.iter().enumerate() {
        lt.row(vec![
            name.to_string(),
            format!("{:+.3}", an.pca.loadings[j][0]),
            format!("{:+.3}", an.pca.loadings[j][1]),
        ]);
    }

    let mut qt = Table::new(&["app", "PC1", "PC2", "quadrant", "EDP>1"]);
    let mut j = Json::obj();
    for (i, a) in apps.iter().enumerate() {
        let (x, y) = (an.pca.scores[i][0], an.pca.scores[i][1]);
        let quad = match (x >= 0.0, y >= 0.0) {
            (true, true) => "I",
            (false, true) => "II",
            (false, false) => "III",
            (true, false) => "IV",
        };
        qt.row(vec![
            a.name.clone(),
            format!("{x:+.3}"),
            format!("{y:+.3}"),
            quad.to_string(),
            a.cmp.nmc_suitable().to_string(),
        ]);
        let mut o = Json::obj();
        o.set("pc1", x);
        o.set("pc2", y);
        o.set("quadrant", quad);
        o.set("nmc_suitable", a.cmp.nmc_suitable());
        j.set(&a.name, o);
    }

    let mut out = Json::obj();
    out.set("figure", "6");
    out.set("engine", an.engine.name());
    out.set("apps", j);
    let mut loads = Json::obj();
    for (jj, name) in feature_names.iter().enumerate() {
        loads.set(name, an.pca.loadings[jj].clone());
    }
    out.set("loadings", loads);
    out.set("explained_variance_ratio", an.pca.explained_variance_ratio.clone());
    if !missing.is_empty() {
        out.set(
            "deselected_features",
            missing.iter().map(|&n| Json::Str(n.to_string())).collect::<Vec<Json>>(),
        );
    }

    let grey_note = if missing.is_empty() {
        String::new()
    } else {
        format!(
            "NOTE: feature(s) {} zeroed — their families are deselected via --metrics\n",
            missing.join(", ")
        )
    };
    let text = format!(
        "Fig 6 — PCA of [BBLP_1, PBBLP, entropy_diff_mem, spat_8B_16B] [{}]\n\
         explained variance: PC1 {:.1}%  PC2 {:.1}%\n{}\n{}\n{}\n{}",
        an.engine.name(),
        an.pca.explained_variance_ratio[0] * 100.0,
        an.pca.explained_variance_ratio[1] * 100.0,
        grey_note,
        plot,
        lt.render(),
        qt.render()
    );
    (text, out)
}

/// Format a ratio for a table cell. A non-finite value (e.g. 0/0 from an
/// app the traffic family saw zero accesses for) renders as the grey
/// dash instead of leaking "NaN" into the report.
fn fmt_ratio(r: f64) -> String {
    if r.is_finite() {
        format!("{r:.3}")
    } else {
        "–".into()
    }
}

/// The MRC figure (extension): miss-ratio curve per app across the
/// geometric capacity family, the slope-based knee, byte-traffic rates
/// and the per-level hierarchy series (each level's miss ratio over the
/// accesses that actually reached it) — the `traffic` subsystem's report
/// surface.
pub fn fig_mrc(apps: &[AppResult], metrics: MetricSet) -> (String, Json) {
    if !metrics.contains(Metric::Traffic) {
        return deselected_figure(
            "mrc",
            "miss-ratio curve + byte traffic (64B lines)",
            &[Metric::Traffic],
        );
    }
    let caps = apps
        .first()
        .map(|a| a.metrics.traffic.mrc_capacities.clone())
        .unwrap_or_default();
    let level_names: Vec<&'static str> = apps
        .first()
        .map(|a| a.metrics.traffic.levels.iter().map(|l| l.name).collect())
        .unwrap_or_default();
    let policy = apps
        .first()
        .map(|a| a.metrics.traffic.hierarchy_policy)
        .unwrap_or_default();
    let mrc_mode = apps
        .first()
        .map(|a| a.metrics.traffic.mrc_mode)
        .unwrap_or_default();
    let mut headers = vec!["app".to_string()];
    headers.extend(caps.iter().map(|&c| capacity_label(c)));
    headers.push("knee".into());
    headers.push("B/instr".into());
    headers.extend(level_names.iter().map(|n| format!("{n} MR")));
    headers.push("DRAM B/instr".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let mut j = Json::obj();
    for a in apps {
        let tr = &a.metrics.traffic;
        let mut row = vec![a.name.clone()];
        row.extend(tr.mrc_miss_ratio.iter().map(|&r| fmt_ratio(r)));
        row.push(match tr.mrc_knee_bytes {
            Some(b) => capacity_label(b),
            None => "–".into(),
        });
        row.push(format!("{:.2}", tr.bytes_per_instr()));
        row.extend(tr.levels.iter().map(|l| fmt_ratio(l.miss_ratio())));
        row.push(format!("{:.2}", tr.dram_bytes_per_instr()));
        t.row(row);
        j.set(&a.name, tr.to_json());
    }
    let caps_f: Vec<f64> = caps.iter().map(|&c| c as f64).collect();
    let mut out = Json::obj();
    out.set("figure", "mrc");
    out.set("metric", "miss-ratio curve + byte traffic (64B lines)");
    out.set("capacities_bytes", caps_f);
    out.set("hierarchy_policy", policy.name());
    out.set("mrc_mode", mrc_mode.name());
    out.set("mrc_sample_rate", mrc_mode.rate());
    out.set(
        "hierarchy_levels",
        level_names
            .iter()
            .map(|n| Json::Str(n.to_string()))
            .collect::<Vec<Json>>(),
    );
    out.set("series", j);
    (
        format!(
            "Fig MRC — miss-ratio curves ({} MRC), {} hierarchy and byte traffic (64B lines)\n{}",
            mrc_mode.describe(),
            policy.name(),
            t.render()
        ),
        out,
    )
}

/// The sweep figure (DSE advisor, `--sweep`): one row per app, one
/// column per grid point, each cell the per-config
/// `EDP_host(config)/EDP_nmc` ratio with the offload verdict — `✓` when
/// NMC still wins at that hierarchy, `·` when the host does, `*` when
/// the point was MRC-pruned and inherited its neighbor's verdict.
pub fn fig_sweep(sw: &super::sweep::SweepReport) -> (String, Json) {
    let mut headers = vec!["app".to_string()];
    headers.extend(sw.labels.iter().cloned());
    headers.push("offload@".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let mut series = Json::obj();
    for a in &sw.apps {
        let mut row = vec![a.app.clone()];
        let mut wins = 0usize;
        let mut points = Vec::with_capacity(a.points.len());
        for p in &a.points {
            if p.offload {
                wins += 1;
            }
            row.push(format!(
                "{}{}{}",
                if p.edp_vs_nmc.is_finite() { format!("{:.2}", p.edp_vs_nmc) } else { "–".into() },
                if p.offload { "✓" } else { "·" },
                if p.pruned { "*" } else { "" },
            ));
            let mut pj = Json::obj();
            pj.set("edp_vs_nmc", p.edp_vs_nmc);
            pj.set("offload", p.offload);
            pj.set("pruned", p.pruned);
            points.push(pj);
        }
        row.push(format!("{wins}/{}", a.points.len()));
        t.row(row);
        series.set(&a.app, points);
    }
    let mut out = Json::obj();
    out.set("figure", "sweep");
    out.set("metric", "EDP_host(config)/EDP_nmc per grid point (>1: offload wins)");
    out.set(
        "grid_labels",
        sw.labels.iter().map(|l| Json::Str(l.clone())).collect::<Vec<Json>>(),
    );
    out.set("series", series);
    (
        format!(
            "Fig SWEEP — per-app offload verdict across {} hierarchy configs\n\
             (cell: EDP_host(cfg)/EDP_nmc; ✓ NMC wins, · host wins, * MRC-pruned/inherited)\n{}",
            sw.labels.len(),
            t.render()
        ),
        out,
    )
}

/// Table 1: host + NMC system characteristics.
pub fn table1() -> String {
    let h = crate::sim::HostConfig::default();
    let n = crate::sim::NmcConfig::default();
    let mut t = Table::new(&["Architecture", "CPU", "Cache per core", "Memory"]);
    t.row(vec![
        "IBM Power9 (Host)".into(),
        format!("4 cores (SMT4) @ {} GHz, {}-wide", h.freq_ghz, h.issue_width),
        format!("L1 {} KB / L2 {} KB / L3 {} MB", h.l1_kb, h.l2_kb, h.l3_kb / 1024),
        format!("DDR4 RDIMM, {} GB/s", h.dram_bw_gbs),
    ]);
    t.row(vec![
        "NMC".into(),
        format!(
            "{} single-issue in-order cores @ {} GHz",
            n.n_pes, n.freq_ghz
        ),
        format!(
            "L1-I/D {}-way, {} lines x {} B ({} KB)",
            n.l1_ways, n.l1_lines, n.line_bytes,
            n.l1_lines * n.line_bytes / 1024
        ),
        format!(
            "HMC, {} stacked layers, {} vaults, SerDes {} GB/s",
            n.stacked_layers, n.n_vaults, n.link_gbs
        ),
    ]);
    format!("Table 1 — system characteristics\n{}", t.render())
}

/// Table 2: benchmark parameters (paper values + this repo's scaled sizes).
pub fn table2(scale: f64) -> String {
    let mut t = Table::new(&["suite", "kernel", "param", "paper value", "this run (scaled)"]);
    for k in registry() {
        let info = k.info();
        t.row(vec![
            info.suite.name().into(),
            info.name.into(),
            info.param_name.into(),
            info.paper_value.into(),
            crate::workloads::scaled_n(k.as_ref(), scale).to_string(),
        ]);
    }
    format!("Table 2 — benchmark parameters\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::run_suite;

    fn tiny_apps() -> Vec<AppResult> {
        run_suite(0.08, 3, 4).unwrap()
    }

    #[test]
    fn native_analytics_and_all_figures_render() {
        let apps = tiny_apps();
        let all = MetricSet::all();
        let an = analyze_suite(&apps, None).unwrap();
        assert_eq!(an.engine, Engine::Native);
        assert_eq!(an.entropies.len(), 12);
        assert_eq!(an.spatial[0].len(), 7);

        let (s3a, j3a) = fig3a(&apps, &an, all);
        assert!(s3a.contains("gramschmidt"));
        assert!(j3a.get("series").is_some());
        let (s3b, _) = fig3b(&apps, &an, all);
        assert!(s3b.contains("spat_8B_16B"));
        let (s3c, _) = fig3c(&apps, all);
        assert!(s3c.contains("PBBLP"));
        let (s4, _) = fig4(&apps);
        assert!(s4.contains("EDP"));
        let (s5, _) = fig5(&apps, &an, all);
        assert!(s5.contains("entropy_diff"));
        let (s6, j6) = fig6(&apps, &an, all);
        assert!(s6.contains("quadrant"));
        assert!(!s6.contains("zeroed"));
        assert!(j6.get("deselected_features").is_none());
        let (smrc, jmrc) = fig_mrc(&apps, all);
        assert!(smrc.contains("miss-ratio"));
        assert!(smrc.contains("4K"));
        assert!(smrc.contains("B/instr"));
        assert!(smrc.contains("inclusive"));
        assert!(smrc.contains("llc MR"), "per-level series missing from the traffic figure");
        assert!(smrc.contains("exact MRC"), "the figure title names the MRC mode");
        assert!(jmrc.get("series").is_some());
        assert!(jmrc.get("hierarchy_policy").is_some());
        assert!(jmrc.get("mrc_mode").is_some());
        assert!(jmrc.get("mrc_sample_rate").is_some());
        assert!(table1().contains("Power9"));
        assert!(table2(1.0).contains("8000"));
    }

    #[test]
    fn deselected_families_grey_out_figures() {
        let apps = tiny_apps();
        let an = analyze_suite(&apps, None).unwrap();
        // mix+dlp only: entropy/reuse/traffic figures must announce the
        // omission instead of rendering zeros as data
        let sel = MetricSet::from_names("mix,dlp").unwrap();
        let (s3a, j3a) = fig3a(&apps, &an, sel);
        assert!(s3a.contains("deselected"));
        assert_eq!(j3a.get("deselected"), Some(&crate::util::Json::Bool(true)));
        assert!(j3a.get("series").is_none());
        let (s3b, _) = fig3b(&apps, &an, sel);
        assert!(s3b.contains("deselected"));
        let (smrc, jmrc) = fig_mrc(&apps, sel);
        assert!(smrc.contains("deselected"));
        assert!(jmrc.get("series").is_none());
        let (s5, _) = fig5(&apps, &an, sel);
        assert!(s5.contains("deselected"));
        // 3c greys only the missing columns: DLP is live, BBLP/PBBLP greyed
        let (s3c, j3c) = fig3c(&apps, sel);
        assert!(s3c.contains('–'));
        assert!(j3c.get("deselected_families").is_some());
        // 6 renders, flagging the zeroed features
        let (s6, j6) = fig6(&apps, &an, sel);
        assert!(s6.contains("zeroed"));
        assert!(j6.get("deselected_features").is_some());
    }

    #[test]
    fn non_finite_ratios_render_as_dash() {
        assert_eq!(fmt_ratio(0.25), "0.250");
        assert_eq!(fmt_ratio(f64::NAN), "–");
        assert_eq!(fmt_ratio(f64::INFINITY), "–");
    }

    #[test]
    fn sweep_figure_renders_offload_verdicts() {
        use crate::coordinator::sweep::{run_sweep, SweepGrid};
        use crate::coordinator::PipelineCfg;
        let apps = tiny_apps();
        let apps = &apps[..2]; // two apps keep the second replay pass cheap
        let grid = SweepGrid::from_json_str(
            r#"{"configs": [
                 {"levels": [{"name": "l1", "capacity_kb": 1, "ways": 4}]},
                 {"levels": [{"name": "l1", "capacity_kb": 1, "ways": 4},
                             {"name": "llc", "capacity_kb": 16, "ways": 8}]},
                 {"policy": "exclusive",
                  "levels": [{"name": "l1", "capacity_kb": 2},
                             {"name": "llc", "capacity_kb": 32}]}]}"#,
        )
        .unwrap();
        // tiny_apps profiles at scale 0.08, seed 3 — the sweep pass must
        // re-profile at the same seed for an identical address stream
        let cfg = PipelineCfg { scale: 0.08, seed: 3, ..PipelineCfg::default() };
        let sw = run_sweep(&cfg, apps, &grid).unwrap();
        assert_eq!(sw.labels.len(), 3);
        assert_eq!(sw.apps.len(), 2);
        for a in &sw.apps {
            assert_eq!(a.points.len(), 3);
            assert!(a.replayed >= 1 && a.replayed <= 3);
            for p in &a.points {
                assert!(p.edp.is_finite() && p.edp > 0.0);
                assert_eq!(p.pruned, p.counters.is_none());
                assert_eq!(p.pruned, p.inherited_from.is_some());
            }
        }
        let (text, json) = fig_sweep(&sw);
        assert!(text.contains("offload verdict"), "{text}");
        // the acceptance bar: >= 3 hierarchy columns in the rendered grid
        assert!(text.contains("1K/incl·lru"), "{text}");
        assert!(text.contains("1K+16K/incl·lru"), "{text}");
        assert!(text.contains("2K+32K/excl·lru"), "{text}");
        assert!(json.get("grid_labels").is_some());
        let sj = sw.to_json();
        assert!(sj.get("grid").is_some());
        assert!(sj.to_string_compact().contains("\"offload\""));
    }
}
