//! L3 coordination: the PISA-NMC profiling pipeline.
//!
//! [`pipeline`] fans the workload suite across worker threads (one
//! instrumented execution per app feeding all analyzers + the task trace,
//! then both machine models); [`figures`] routes the numeric analytics
//! through the AOT PJRT artifacts and regenerates every paper figure and
//! table; [`pca`] is the native mirror of the PCA artifact used for
//! fallback and cross-checking.

pub mod figures;
pub mod pca;
pub mod pipeline;

pub use figures::{analyze_suite, Engine, SuiteAnalytics};
pub use pca::{pca, Pca};
pub use pipeline::{
    profile_app, profile_app_mode, profile_app_opts, profile_app_select, profile_app_supervised,
    replay_app, run_suite, run_suite_opts, run_suite_select, run_suite_supervised, AppFailure,
    AppOutcome, AppResult, OnError, ProfileError, SuitePolicy,
};

use std::path::Path;

use anyhow::Result;

use crate::analysis::MetricSet;
use crate::interp::PipelineMode;
use crate::runtime::Runtime;
use crate::trace::TraceProvenance;
use crate::traffic::TrafficOpts;
use crate::util::Json;

/// Everything one `pisa-nmc pipeline` run produces.
pub struct PipelineReport {
    /// Successfully profiled apps, registry order (failed apps are
    /// absent here and present in [`PipelineReport::failures`]).
    pub apps: Vec<AppResult>,
    /// Apps that failed or degraded under `--on-error continue` (always
    /// empty under the default fail-fast policy, which aborts instead).
    pub failures: Vec<AppFailure>,
    pub analytics: SuiteAnalytics,
    pub scale: f64,
    pub seed: u64,
    /// Analyzer families that were enabled for this run.
    pub metrics: MetricSet,
    /// Event-delivery mode the apps were profiled with.
    pub mode: PipelineMode,
    /// Traffic-family options (hierarchy replay policy + MRC mode) the
    /// run profiled under.
    pub traffic: TrafficOpts,
    /// Provenance of the replayed `.pallas-trace` when the events came
    /// from a recorded file (`--trace`) rather than live interpretation;
    /// `None` for every interpreting run. Rendered as the report's
    /// `"trace"` section.
    pub trace: Option<TraceProvenance>,
}

/// Every knob one pipeline run takes — bundled so the supervised entry
/// point stays one call with one config, the same shape the CLI parses
/// into.
#[derive(Debug, Clone, Copy)]
pub struct PipelineCfg {
    pub scale: f64,
    pub seed: u64,
    pub threads: usize,
    pub metrics: MetricSet,
    pub mode: PipelineMode,
    pub traffic: TrafficOpts,
    /// Supervision plan + failure policy (`--inject-fault`,
    /// `--app-timeout`, `--on-error`).
    pub policy: SuitePolicy,
}

/// Run the full pipeline with every metric enabled, inline delivery.
pub fn run_pipeline(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
) -> Result<PipelineReport> {
    run_pipeline_select(scale, seed, threads, rt, MetricSet::all(), PipelineMode::Inline)
}

/// [`run_pipeline_opts`] with the default traffic options (inclusive
/// hierarchy replay, exact MRC).
pub fn run_pipeline_select(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<PipelineReport> {
    run_pipeline_opts(scale, seed, threads, rt, metrics, mode, TrafficOpts::default())
}

/// Run the full pipeline: profile suite (selected analyzer families,
/// selected delivery mode, selected traffic options) → artifacts
/// analytics → report. `metrics` is the CLI `--metrics` flag, `mode` the
/// CLI `--pipeline` flag and `traffic` bundles the CLI `--hierarchy` and
/// `--mrc` flags, all threaded into every worker's run.
pub fn run_pipeline_opts(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
    metrics: MetricSet,
    mode: PipelineMode,
    traffic: TrafficOpts,
) -> Result<PipelineReport> {
    let cfg = PipelineCfg {
        scale,
        seed,
        threads,
        metrics,
        mode,
        traffic,
        policy: SuitePolicy::default(),
    };
    run_pipeline_cfg(&cfg, rt)
}

/// The fully-parameterized pipeline: profile the suite under `cfg`'s
/// supervision plan and failure policy, then run the analytics over the
/// apps that survived. Under fail-fast (the default policy) this is
/// exactly [`run_pipeline_opts`]; under `--on-error continue`, failed
/// apps land in [`PipelineReport::failures`] and the analytics cover the
/// successes only.
pub fn run_pipeline_cfg(cfg: &PipelineCfg, rt: Option<&Runtime>) -> Result<PipelineReport> {
    // same effective set the workers profile with, so the report's
    // "metrics" list describes the families that actually ran
    let metrics = cfg.metrics.with_simulation_requirements();
    let outcomes = run_suite_supervised(
        cfg.scale,
        cfg.seed,
        cfg.threads,
        metrics,
        cfg.mode,
        cfg.traffic,
        cfg.policy,
    )?;
    let mut apps = Vec::new();
    let mut failures = Vec::new();
    for out in outcomes {
        match out {
            AppOutcome::Ok(r) => apps.push(*r),
            AppOutcome::Failed(f) => failures.push(*f),
        }
    }
    let analytics = if apps.is_empty() {
        // every app failed: synthesize an empty analytics block so the
        // report still renders (fig6 indexes loadings/eigenvalues by
        // feature and component, so those keep their static shapes)
        SuiteAnalytics {
            engine: Engine::Native,
            entropies: Vec::new(),
            entropy_diff: Vec::new(),
            spatial: Vec::new(),
            pca: Pca {
                scores: Vec::new(),
                loadings: vec![vec![0.0; 2]; 4],
                eigenvalues: vec![0.0; 2],
                explained_variance_ratio: vec![0.0; 2],
            },
            max_crosscheck_err: 0.0,
        }
    } else {
        analyze_suite(&apps, rt)?
    };
    Ok(PipelineReport {
        apps,
        failures,
        analytics,
        scale: cfg.scale,
        seed: cfg.seed,
        metrics,
        mode: cfg.mode,
        traffic: cfg.traffic,
        trace: None,
    })
}

/// Replay one recorded `.pallas-trace` through the pipeline report shape:
/// the full analyzer stack and both machine models run on the decoded
/// stream (any delivery mode, any traffic knobs), producing a single-app
/// [`PipelineReport`] whose `"trace"` section records the file's
/// provenance. The per-app analytics rows (entropy/spatial series) are
/// real — figures index them per app — but the cross-app PCA plane is
/// zeroed, since PCA over a single app is meaningless. Every per-app
/// metric is event-for-event identical to profiling the recording's
/// workload directly. `cfg.seed`/`cfg.scale` describe the *report*; the
/// workload identity (app, n, seed) comes from the trace header.
pub fn run_replay_cfg(cfg: &PipelineCfg, trace_path: &Path) -> Result<PipelineReport> {
    let metrics = cfg.metrics.with_simulation_requirements();
    let (app, provenance) = replay_app(trace_path, cfg.metrics, cfg.mode, cfg.traffic)?;
    let apps = vec![app];
    let analytics = SuiteAnalytics {
        engine: Engine::Native,
        entropies: apps.iter().map(|a| a.metrics.mem_entropy.entropies.clone()).collect(),
        entropy_diff: apps.iter().map(|a| a.metrics.mem_entropy.entropy_diff).collect(),
        spatial: apps.iter().map(|a| a.metrics.spatial.scores.clone()).collect(),
        pca: Pca {
            // one zeroed score row per app: to_json indexes scores[i]
            scores: vec![vec![0.0; 2]; apps.len()],
            loadings: vec![vec![0.0; 2]; 4],
            eigenvalues: vec![0.0; 2],
            explained_variance_ratio: vec![0.0; 2],
        },
        max_crosscheck_err: 0.0,
    };
    Ok(PipelineReport {
        apps,
        failures: Vec::new(),
        analytics,
        scale: cfg.scale,
        seed: provenance.seed,
        metrics,
        mode: cfg.mode,
        traffic: cfg.traffic,
        trace: Some(provenance),
    })
}

impl PipelineReport {
    /// Suite-level profiler throughput: total trace events over summed
    /// per-app wall time (workers overlap, so this is a conservative
    /// aggregate — per-app numbers live under each app's `exec`).
    pub fn suite_events_per_sec(&self) -> f64 {
        let total_events: u64 = self.apps.iter().map(|a| a.metrics.exec.events()).sum();
        let total_wall: f64 = self.apps.iter().map(|a| a.metrics.exec.wall_s).sum();
        if total_wall > 0.0 {
            total_events as f64 / total_wall
        } else {
            0.0
        }
    }

    /// True when any app was lost outright (interpreter error, panic,
    /// watchdog). Degraded apps — salvaged survivors with their failed
    /// families marked — do not count: `--on-error continue` exits zero
    /// for those, nonzero for hard losses.
    pub fn has_hard_failures(&self) -> bool {
        self.failures.iter().any(|f| f.error.is_hard())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scale", self.scale);
        j.set("seed", self.seed);
        j.set("pipeline_mode", self.mode.name());
        j.set("hierarchy_policy", self.traffic.hierarchy.name());
        j.set("mrc_mode", self.traffic.mrc.name());
        j.set("mrc_rate", self.traffic.mrc.rate());
        if let PipelineMode::Sharded { workers } = self.mode {
            // resolved pool size, not the raw flag: `auto` (and oversized
            // fixed counts) depend on the enabled families
            let resolved = crate::analysis::ShardPlan::new(self.metrics, workers).workers();
            j.set("pipeline_workers", resolved);
        }
        j.set("engine", self.analytics.engine.name());
        j.set("crosscheck_err", self.analytics.max_crosscheck_err);
        j.set(
            "metrics",
            self.metrics
                .names()
                .iter()
                .map(|n| Json::Str(n.to_string()))
                .collect::<Vec<Json>>(),
        );
        let total_events: u64 = self.apps.iter().map(|a| a.metrics.exec.events()).sum();
        j.set("profile_events", total_events);
        j.set("profile_events_per_sec", self.suite_events_per_sec());
        if let Some(t) = &self.trace {
            j.set("trace", t.to_json());
        }
        let mut apps = Json::obj();
        for (i, a) in self.apps.iter().enumerate() {
            let mut o = a.metrics.to_json();
            o.set("n", a.n);
            o.set("edp", a.cmp.to_json());
            o.set("pca_scores", self.analytics.pca.scores[i].clone());
            apps.set(&a.name, o);
        }
        j.set("apps", apps);
        if !self.failures.is_empty() {
            // clean runs keep their JSON shape unchanged; any failure
            // adds this section (the continue-mode smoke greps for it)
            let mut fj = Json::obj();
            for f in &self.failures {
                let mut o = Json::obj();
                o.set("error", f.error.kind());
                o.set("message", f.error.to_string());
                o.set("wall_s", f.wall_s);
                if let Some(m) = &f.partial {
                    // salvaged metrics, failed families stamped
                    // "status": "failed" by AppMetrics::to_json
                    o.set("metrics", m.to_json());
                }
                fj.set(&f.name, o);
            }
            j.set("failures", fj);
        }
        for (name, (_, fig)) in [
            ("fig3a", figures::fig3a(&self.apps, &self.analytics, self.metrics)),
            ("fig3b", figures::fig3b(&self.apps, &self.analytics, self.metrics)),
            ("fig5", figures::fig5(&self.apps, &self.analytics, self.metrics)),
            ("fig6", figures::fig6(&self.apps, &self.analytics, self.metrics)),
        ] {
            j.set(name, fig);
        }
        j.set("fig3c", figures::fig3c(&self.apps, self.metrics).1);
        j.set("fig4", figures::fig4(&self.apps).1);
        j.set("fig_mrc", figures::fig_mrc(&self.apps, self.metrics).1);
        j
    }

    /// Render every figure/table as one text report.
    pub fn render_all(&self) -> String {
        let mut s = String::new();
        s.push_str(&figures::table1());
        s.push('\n');
        s.push_str(&figures::table2(self.scale));
        s.push('\n');
        for text in [
            figures::fig3a(&self.apps, &self.analytics, self.metrics).0,
            figures::fig3b(&self.apps, &self.analytics, self.metrics).0,
            figures::fig3c(&self.apps, self.metrics).0,
            figures::fig4(&self.apps).0,
            figures::fig5(&self.apps, &self.analytics, self.metrics).0,
            figures::fig6(&self.apps, &self.analytics, self.metrics).0,
            figures::fig_mrc(&self.apps, self.metrics).0,
        ] {
            s.push_str(&text);
            s.push('\n');
        }
        s
    }
}
