//! L3 coordination: the PISA-NMC profiling pipeline.
//!
//! The front door is [`request::ProfileRequest`] — one builder naming a
//! target (`::app`, `::program`, `::source`, `::suite`, `::trace`) with
//! every knob optional (`.metrics()`, `.mode()`, `.traffic()`,
//! `.policy()`, `.jobs()`, `.budget()`) — executed against a
//! [`request::RunCtx`] that carries process-global state: the
//! [`sched::WorkerBudget`] every concurrent job draws shard workers from,
//! the optional PJRT runtime, and a default supervision plan.
//!
//! Under it: [`pipeline`] runs *one app's* pipeline (instrumented
//! execution feeding all analyzers + the task trace, then both machine
//! models); [`sched`] fans K such apps out concurrently (`--jobs`) while
//! the shared budget keeps `--jobs 4 --workers auto` from oversubscribing
//! the machine, streaming completions back into deterministic suite
//! order; [`serve`] exposes the same scheduler as a long-running daemon
//! speaking JSON-lines over TCP (`pisa-nmc serve --listen ...`);
//! [`figures`] routes the numeric analytics through the AOT PJRT
//! artifacts and regenerates every paper figure and table; [`pca`] is the
//! native mirror of the PCA artifact used for fallback and
//! cross-checking.
//!
//! The pre-redesign positional entry points (`run_pipeline_select`,
//! `run_suite_opts`, `profile_app_mode`, ...) survive as thin deprecated
//! shims over the builder; new options flow only through
//! [`ProfileRequest`]/[`PipelineCfg`], never new positional parameters.

pub mod figures;
pub mod pca;
pub mod pipeline;
pub mod request;
pub mod sched;
pub mod serve;
pub mod sweep;

pub use figures::{analyze_suite, Engine, SuiteAnalytics};
pub use pca::{pca, Pca};
#[allow(deprecated)] // the deprecated shims stay re-exported for one release
pub use pipeline::{
    profile_app, profile_app_mode, profile_app_opts, profile_app_select, profile_app_supervised,
    replay_app, run_suite, run_suite_opts, run_suite_select, run_suite_supervised, AppFailure,
    AppOutcome, AppResult, OnError, ProfileError, SuitePolicy,
};
pub use request::{ProfileRequest, RunCtx};
pub use sched::{Completion, JobKind, JobSpec, Jobs, Scheduler, SubmitError, WorkerBudget};
pub use serve::{install_sigterm_handler, ServeCfg, Server};
pub use sweep::{run_sweep, SweepGrid, SweepReport};

use std::path::Path;

use anyhow::Result;

use crate::analysis::MetricSet;
use crate::interp::PipelineMode;
use crate::runtime::Runtime;
use crate::trace::TraceProvenance;
use crate::traffic::TrafficOpts;
use crate::util::Json;

/// Everything one `pisa-nmc pipeline` run produces.
pub struct PipelineReport {
    /// Successfully profiled apps, registry order (failed apps are
    /// absent here and present in [`PipelineReport::failures`]).
    pub apps: Vec<AppResult>,
    /// Apps that failed or degraded under `--on-error continue` (always
    /// empty under the default fail-fast policy, which aborts instead).
    pub failures: Vec<AppFailure>,
    pub analytics: SuiteAnalytics,
    pub scale: f64,
    pub seed: u64,
    /// Analyzer families that were enabled for this run.
    pub metrics: MetricSet,
    /// Event-delivery mode the apps were profiled with.
    pub mode: PipelineMode,
    /// Traffic-family options (hierarchy replay policy + MRC mode) the
    /// run profiled under.
    pub traffic: TrafficOpts,
    /// Provenance of the replayed `.pallas-trace` when the events came
    /// from a recorded file (`--trace`) rather than live interpretation;
    /// `None` for every interpreting run. Rendered as the report's
    /// `"trace"` section.
    pub trace: Option<TraceProvenance>,
    /// The design-space exploration result when the run carried a
    /// `--sweep` grid: per-app, per-grid-point offload verdicts.
    /// Attached by the CLI after the profile pass (see
    /// [`sweep::run_sweep`]); rendered as the `"sweep"` section and the
    /// sweep figure.
    pub sweep: Option<SweepReport>,
}

/// Every knob one pipeline run takes — bundled so the supervised entry
/// point stays one call with one config, the same shape the CLI parses
/// into. Future flags land here (or on [`ProfileRequest`]), never as new
/// positional parameters; `PipelineCfg::default()` is a full-suite,
/// all-metrics, inline, auto-jobs run.
#[derive(Debug, Clone, Copy)]
pub struct PipelineCfg {
    pub scale: f64,
    pub seed: u64,
    /// Suite-level concurrency (`--jobs`): how many apps profile at once.
    pub jobs: Jobs,
    pub metrics: MetricSet,
    pub mode: PipelineMode,
    pub traffic: TrafficOpts,
    /// Supervision plan + failure policy (`--inject-fault`,
    /// `--app-timeout`, `--on-error`).
    pub policy: SuitePolicy,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            scale: 1.0,
            seed: 42,
            jobs: Jobs::Auto,
            metrics: MetricSet::all(),
            mode: PipelineMode::Inline,
            traffic: TrafficOpts::default(),
            policy: SuitePolicy::default(),
        }
    }
}

/// Run the full pipeline with every metric enabled, inline delivery.
/// `threads` is the legacy name for the job concurrency; it maps to
/// [`Jobs::Fixed`].
pub fn run_pipeline(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
) -> Result<PipelineReport> {
    let cfg = PipelineCfg { scale, seed, jobs: Jobs::Fixed(threads), ..PipelineCfg::default() };
    run_pipeline_cfg(&cfg, rt)
}

/// [`run_pipeline_cfg`] with the default traffic options.
#[deprecated(note = "build a PipelineCfg and call run_pipeline_cfg instead")]
pub fn run_pipeline_select(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<PipelineReport> {
    let cfg = PipelineCfg {
        scale,
        seed,
        jobs: Jobs::Fixed(threads),
        metrics,
        mode,
        ..PipelineCfg::default()
    };
    run_pipeline_cfg(&cfg, rt)
}

/// [`run_pipeline_cfg`] with the default supervision policy.
#[deprecated(note = "build a PipelineCfg and call run_pipeline_cfg instead")]
pub fn run_pipeline_opts(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
    metrics: MetricSet,
    mode: PipelineMode,
    traffic: TrafficOpts,
) -> Result<PipelineReport> {
    let cfg = PipelineCfg {
        scale,
        seed,
        jobs: Jobs::Fixed(threads),
        metrics,
        mode,
        traffic,
        ..PipelineCfg::default()
    };
    run_pipeline_cfg(&cfg, rt)
}

/// The fully-parameterized pipeline: profile the suite under `cfg`'s
/// supervision plan, failure policy and job concurrency, then run the
/// analytics over the apps that survived. Under fail-fast (the default
/// policy) any app failure aborts the run; under `--on-error continue`,
/// failed apps land in [`PipelineReport::failures`] and the analytics
/// cover the successes only. This is sugar for [`ProfileRequest::suite`]
/// + [`ProfileRequest::run`].
pub fn run_pipeline_cfg(cfg: &PipelineCfg, rt: Option<&Runtime>) -> Result<PipelineReport> {
    ProfileRequest::suite(cfg.scale, cfg.seed)
        .metrics(cfg.metrics)
        .mode(cfg.mode)
        .traffic(cfg.traffic)
        .policy(cfg.policy)
        .jobs(cfg.jobs)
        .run(&RunCtx::with_runtime(rt))
}

/// Replay one recorded `.pallas-trace` through the pipeline report shape:
/// the full analyzer stack and both machine models run on the decoded
/// stream (any delivery mode, any traffic knobs), producing a single-app
/// [`PipelineReport`] whose `"trace"` section records the file's
/// provenance. The per-app analytics rows (entropy/spatial series) are
/// real — figures index them per app — but the cross-app PCA plane is
/// zeroed, since PCA over a single app is meaningless. Every per-app
/// metric is event-for-event identical to profiling the recording's
/// workload directly. `cfg.seed`/`cfg.scale` describe the *report*; the
/// workload identity (app, n, seed) comes from the trace header.
pub fn run_replay_cfg(cfg: &PipelineCfg, trace_path: &Path) -> Result<PipelineReport> {
    let metrics = cfg.metrics.with_simulation_requirements();
    let (app, provenance) = replay_app(trace_path, cfg.metrics, cfg.mode, cfg.traffic)?;
    let apps = vec![app];
    let analytics = SuiteAnalytics {
        engine: Engine::Native,
        entropies: apps.iter().map(|a| a.metrics.mem_entropy.entropies.clone()).collect(),
        entropy_diff: apps.iter().map(|a| a.metrics.mem_entropy.entropy_diff).collect(),
        spatial: apps.iter().map(|a| a.metrics.spatial.scores.clone()).collect(),
        pca: Pca {
            // one zeroed score row per app: to_json indexes scores[i]
            scores: vec![vec![0.0; 2]; apps.len()],
            loadings: vec![vec![0.0; 2]; 4],
            eigenvalues: vec![0.0; 2],
            explained_variance_ratio: vec![0.0; 2],
        },
        max_crosscheck_err: 0.0,
    };
    Ok(PipelineReport {
        apps,
        failures: Vec::new(),
        analytics,
        scale: cfg.scale,
        seed: provenance.seed,
        metrics,
        mode: cfg.mode,
        traffic: cfg.traffic,
        trace: Some(provenance),
        sweep: None,
    })
}

impl PipelineReport {
    /// Suite-level profiler throughput: total trace events over summed
    /// per-app wall time (workers overlap, so this is a conservative
    /// aggregate — per-app numbers live under each app's `exec`).
    pub fn suite_events_per_sec(&self) -> f64 {
        let total_events: u64 = self.apps.iter().map(|a| a.metrics.exec.events()).sum();
        let total_wall: f64 = self.apps.iter().map(|a| a.metrics.exec.wall_s).sum();
        if total_wall > 0.0 {
            total_events as f64 / total_wall
        } else {
            0.0
        }
    }

    /// True when any app was lost outright (interpreter error, panic,
    /// watchdog). Degraded apps — salvaged survivors with their failed
    /// families marked — do not count: `--on-error continue` exits zero
    /// for those, nonzero for hard losses.
    pub fn has_hard_failures(&self) -> bool {
        self.failures.iter().any(|f| f.error.is_hard())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scale", self.scale);
        j.set("seed", self.seed);
        j.set("pipeline_mode", self.mode.name());
        j.set("hierarchy_policy", self.traffic.hierarchy.name());
        if self.traffic.spec.is_some() {
            // --hierarchy-spec provenance: the effective replay config in
            // the exact shape from_spec_json accepts, so a reader can
            // re-run the report's hierarchy verbatim
            j.set("hierarchy_spec", self.traffic.main_config().to_json());
        }
        j.set("mrc_mode", self.traffic.mrc.name());
        j.set("mrc_rate", self.traffic.mrc.rate());
        if let PipelineMode::Sharded { workers } = self.mode {
            // resolved pool size, not the raw flag: `auto` (and oversized
            // fixed counts) depend on the enabled families
            let resolved = crate::analysis::ShardPlan::new(self.metrics, workers).workers();
            j.set("pipeline_workers", resolved);
        }
        j.set("engine", self.analytics.engine.name());
        j.set("crosscheck_err", self.analytics.max_crosscheck_err);
        j.set(
            "metrics",
            self.metrics
                .names()
                .iter()
                .map(|n| Json::Str(n.to_string()))
                .collect::<Vec<Json>>(),
        );
        let total_events: u64 = self.apps.iter().map(|a| a.metrics.exec.events()).sum();
        j.set("profile_events", total_events);
        j.set("profile_events_per_sec", self.suite_events_per_sec());
        if let Some(t) = &self.trace {
            j.set("trace", t.to_json());
        }
        let mut apps = Json::obj();
        for (i, a) in self.apps.iter().enumerate() {
            let mut o = a.metrics.to_json();
            o.set("n", a.n);
            o.set("edp", a.cmp.to_json());
            o.set("pca_scores", self.analytics.pca.scores[i].clone());
            apps.set(&a.name, o);
        }
        j.set("apps", apps);
        if !self.failures.is_empty() {
            // clean runs keep their JSON shape unchanged; any failure
            // adds this section (the continue-mode smoke greps for it)
            let mut fj = Json::obj();
            for f in &self.failures {
                let mut o = Json::obj();
                o.set("error", f.error.kind());
                o.set("message", f.error.to_string());
                o.set("wall_s", f.wall_s);
                if let Some(m) = &f.partial {
                    // salvaged metrics, failed families stamped
                    // "status": "failed" by AppMetrics::to_json
                    o.set("metrics", m.to_json());
                }
                fj.set(&f.name, o);
            }
            j.set("failures", fj);
        }
        for (name, (_, fig)) in [
            ("fig3a", figures::fig3a(&self.apps, &self.analytics, self.metrics)),
            ("fig3b", figures::fig3b(&self.apps, &self.analytics, self.metrics)),
            ("fig5", figures::fig5(&self.apps, &self.analytics, self.metrics)),
            ("fig6", figures::fig6(&self.apps, &self.analytics, self.metrics)),
        ] {
            j.set(name, fig);
        }
        j.set("fig3c", figures::fig3c(&self.apps, self.metrics).1);
        j.set("fig4", figures::fig4(&self.apps).1);
        j.set("fig_mrc", figures::fig_mrc(&self.apps, self.metrics).1);
        if let Some(s) = &self.sweep {
            j.set("sweep", s.to_json());
            j.set("fig_sweep", figures::fig_sweep(s).1);
        }
        j
    }

    /// Render every figure/table as one text report.
    pub fn render_all(&self) -> String {
        let mut s = String::new();
        s.push_str(&figures::table1());
        s.push('\n');
        s.push_str(&figures::table2(self.scale));
        s.push('\n');
        for text in [
            figures::fig3a(&self.apps, &self.analytics, self.metrics).0,
            figures::fig3b(&self.apps, &self.analytics, self.metrics).0,
            figures::fig3c(&self.apps, self.metrics).0,
            figures::fig4(&self.apps).0,
            figures::fig5(&self.apps, &self.analytics, self.metrics).0,
            figures::fig6(&self.apps, &self.analytics, self.metrics).0,
            figures::fig_mrc(&self.apps, self.metrics).0,
        ] {
            s.push_str(&text);
            s.push('\n');
        }
        if let Some(sw) = &self.sweep {
            s.push_str(&figures::fig_sweep(sw).0);
            s.push('\n');
        }
        s
    }
}
