//! L3 coordination: the PISA-NMC profiling pipeline.
//!
//! [`pipeline`] fans the workload suite across worker threads (one
//! instrumented execution per app feeding all analyzers + the task trace,
//! then both machine models); [`figures`] routes the numeric analytics
//! through the AOT PJRT artifacts and regenerates every paper figure and
//! table; [`pca`] is the native mirror of the PCA artifact used for
//! fallback and cross-checking.

pub mod figures;
pub mod pca;
pub mod pipeline;

pub use figures::{analyze_suite, Engine, SuiteAnalytics};
pub use pca::{pca, Pca};
pub use pipeline::{profile_app, run_suite, AppResult};

use anyhow::Result;

use crate::runtime::Runtime;
use crate::util::Json;

/// Everything one `pisa-nmc pipeline` run produces.
pub struct PipelineReport {
    pub apps: Vec<AppResult>,
    pub analytics: SuiteAnalytics,
    pub scale: f64,
    pub seed: u64,
}

/// Run the full pipeline: profile suite → artifacts analytics → report.
pub fn run_pipeline(
    scale: f64,
    seed: u64,
    threads: usize,
    rt: Option<&Runtime>,
) -> Result<PipelineReport> {
    let apps = run_suite(scale, seed, threads)?;
    let analytics = analyze_suite(&apps, rt)?;
    Ok(PipelineReport { apps, analytics, scale, seed })
}

impl PipelineReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scale", self.scale);
        j.set("seed", self.seed);
        j.set("engine", self.analytics.engine.name());
        j.set("crosscheck_err", self.analytics.max_crosscheck_err);
        let mut apps = Json::obj();
        for (i, a) in self.apps.iter().enumerate() {
            let mut o = a.metrics.to_json();
            o.set("n", a.n);
            o.set("edp", a.cmp.to_json());
            o.set("pca_scores", self.analytics.pca.scores[i].clone());
            apps.set(&a.name, o);
        }
        j.set("apps", apps);
        for (name, (_, fig)) in [
            ("fig3a", figures::fig3a(&self.apps, &self.analytics)),
            ("fig3b", figures::fig3b(&self.apps, &self.analytics)),
            ("fig5", figures::fig5(&self.apps, &self.analytics)),
            ("fig6", figures::fig6(&self.apps, &self.analytics)),
        ] {
            j.set(name, fig);
        }
        j.set("fig3c", figures::fig3c(&self.apps).1);
        j.set("fig4", figures::fig4(&self.apps).1);
        j
    }

    /// Render every figure/table as one text report.
    pub fn render_all(&self) -> String {
        let mut s = String::new();
        s.push_str(&figures::table1());
        s.push('\n');
        s.push_str(&figures::table2(self.scale));
        s.push('\n');
        for text in [
            figures::fig3a(&self.apps, &self.analytics).0,
            figures::fig3b(&self.apps, &self.analytics).0,
            figures::fig3c(&self.apps).0,
            figures::fig4(&self.apps).0,
            figures::fig5(&self.apps, &self.analytics).0,
            figures::fig6(&self.apps, &self.analytics).0,
        ] {
            s.push_str(&text);
            s.push('\n');
        }
        s
    }
}
