//! Design-space exploration advisor (`--sweep`): replay every app across
//! a hierarchy-config × replacement-policy grid and report, per app and
//! per grid point, whether the NMC side still wins on EDP.
//!
//! Two-phase by construction. The normal pipeline pass produces each
//! app's miss-ratio curve plus its host/NMC simulations; this module then
//! re-runs **only the traffic family once per app** with every kept grid
//! config attached to the same chunk lanes ([`TrafficOpts::sweep`]), so a
//! K-point grid costs one replay pass, not K — and each kept point's
//! per-level counters are bit-identical to a standalone
//! [`HierarchyReplay`](crate::traffic::HierarchyReplay) at that config
//! (the differential oracle in `prop_hierarchy.rs` pins this).
//!
//! Between the phases the grid is pruned on the MRC: two configs of the
//! same shape (same level count, ways, policies, replacement, line size)
//! whose aggregate capacities land on the same flat segment of the app's
//! miss-ratio curve cannot produce meaningfully different DRAM traffic,
//! so the dominated point inherits its replayed neighbor's verdict
//! instead of burning a replay slot.
//!
//! The verdict model charges each grid point's DRAM-line *delta* against
//! the pass-1 host simulation: `ΔL` extra (or saved) 64 B-equivalent DRAM
//! lines cost `ΔL × host_dram_line_pj` energy and `ΔL × dram_lat_ns /
//! mlp` time on top of the simulated host, and the resulting per-config
//! EDP is compared against the (hierarchy-independent) NMC EDP — the same
//! `host EDP / NMC EDP > 1` offload rule the advisor already uses.

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::MetricSet;
use crate::sim::cache::ReplacementKind;
use crate::sim::{EnergyConfig, HostConfig};
use crate::traffic::{
    capacity_label, HierarchyConfig, SweepCounters, MRC_CAPACITIES_BYTES, MRC_LINE_BYTES,
};
use crate::util::Json;
use crate::workloads;

use super::pipeline::AppResult;
use super::request::{ProfileRequest, RunCtx};
use super::PipelineCfg;

/// Miss-ratio difference under which two grid capacities count as lying
/// on the same flat MRC segment (the larger point is dominated and
/// inherits the smaller's verdict). Half of
/// [`MIN_KNEE_DROP`](crate::traffic::MIN_KNEE_DROP)'s noise floor.
pub const SWEEP_FLAT_EPS: f64 = 0.01;

/// Hard cap on grid points after the replacement cross product: the
/// sweep is meant for tens of configs per pass, not a combinatorial
/// explosion riding one address stream.
pub const MAX_GRID_POINTS: usize = 64;

/// A parsed `--sweep` grid: the config list after applying the optional
/// replacement-policy cross product.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepGrid {
    pub configs: Vec<HierarchyConfig>,
}

impl SweepGrid {
    /// Parse a grid JSON document:
    ///
    /// ```json
    /// {"configs": [<hierarchy spec>, ...],
    ///  "replacements": ["lru", "rrip"]}
    /// ```
    ///
    /// Each entry of `configs` is a full `--hierarchy-spec` object and is
    /// validated by the same typed parser
    /// ([`HierarchyConfig::from_spec_json`]). The optional `replacements`
    /// list cross-products the grid: every config is duplicated per
    /// policy with *all* its levels stamped to that replacement
    /// (overriding any per-level `replacement` fields).
    pub fn from_json_str(s: &str) -> Result<SweepGrid> {
        let root = Json::parse(s).map_err(|e| anyhow!("sweep grid: {e}"))?;
        let obj = root
            .as_obj()
            .ok_or_else(|| anyhow!("sweep grid: top level must be an object"))?;
        for key in obj.keys() {
            if key != "configs" && key != "replacements" {
                bail!("sweep grid: unknown key '{key}' (expected configs, replacements)");
            }
        }
        let configs_json = root
            .get("configs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sweep grid: requires a \"configs\" array"))?;
        if configs_json.is_empty() {
            bail!("sweep grid: \"configs\" must not be empty");
        }
        let mut base = Vec::with_capacity(configs_json.len());
        for (i, c) in configs_json.iter().enumerate() {
            // route through the spec parser so grid entries fail with the
            // same typed `hierarchy spec:` errors as --hierarchy-spec
            let cfg = HierarchyConfig::from_spec_json(&c.to_string_compact())
                .map_err(|e| anyhow!("sweep grid: configs[{i}]: {e}"))?;
            base.push(cfg);
        }
        let replacements = match root.get("replacements") {
            None => Vec::new(),
            Some(r) => {
                let arr = r
                    .as_arr()
                    .ok_or_else(|| anyhow!("sweep grid: \"replacements\" must be an array"))?;
                arr.iter()
                    .map(|v| {
                        v.as_str()
                            .and_then(ReplacementKind::from_name)
                            .ok_or_else(|| {
                                anyhow!(
                                    "sweep grid: replacement '{}' is not lru|rrip|drrip",
                                    v.to_string_compact()
                                )
                            })
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        let configs: Vec<HierarchyConfig> = if replacements.is_empty() {
            base
        } else {
            base.iter()
                .flat_map(|c| {
                    replacements.iter().map(|&r| {
                        let mut cc = c.clone();
                        for l in &mut cc.levels {
                            l.replacement = r;
                        }
                        cc
                    })
                })
                .collect()
        };
        if configs.len() > MAX_GRID_POINTS {
            bail!(
                "sweep grid: {} grid points exceed the cap of {MAX_GRID_POINTS}",
                configs.len()
            );
        }
        Ok(SweepGrid { configs })
    }

    /// Load a grid from a file path, or parse it inline when the argument
    /// itself starts with `{` (mirrors `--hierarchy-spec`).
    pub fn load(arg: &str) -> Result<SweepGrid> {
        let text = if arg.trim_start().starts_with('{') {
            arg.to_string()
        } else {
            std::fs::read_to_string(arg).with_context(|| format!("sweep grid: reading {arg}"))?
        };
        Self::from_json_str(&text)
    }
}

/// Compact column label for one grid point, e.g. `4K+32K/incl·rrip`
/// (capacities per level, policy, replacement — `·nwa` marks
/// no-write-allocate, `·mixed` a per-level mixture).
pub fn config_label(c: &HierarchyConfig) -> String {
    let caps: Vec<String> = c.levels.iter().map(|l| capacity_label(l.capacity_bytes)).collect();
    let pol = if c.levels.iter().all(|l| l.policy == c.levels[0].policy) {
        &c.levels[0].policy.name()[..4]
    } else {
        "mixd"
    };
    let repl = if c.levels.iter().all(|l| l.replacement == c.levels[0].replacement) {
        c.levels[0].replacement.name().to_string()
    } else {
        "mixed".to_string()
    };
    let mut s = format!("{}/{}·{}", caps.join("+"), pol, repl);
    if !c.write_allocate {
        s.push_str("·nwa");
    }
    s
}

/// Per-grid-point plan for one app: replay it (consuming the next
/// [`TrafficOpts::sweep`] slot) or inherit a replayed neighbor's result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PointPlan {
    Replay { slot: usize },
    Inherit { from: usize },
}

/// The app's miss ratio at an arbitrary capacity: log2-linear
/// interpolation over the geometric MRC family, clamped at the ends.
/// `None` when the curve is unusable (wrong length or non-finite — e.g.
/// an app the traffic family never saw).
fn mrc_at(mrc: &[f64], bytes: u64) -> Option<f64> {
    if mrc.len() != MRC_CAPACITIES_BYTES.len() || mrc.iter().any(|r| !r.is_finite()) {
        return None;
    }
    let caps = &MRC_CAPACITIES_BYTES;
    if bytes <= caps[0] {
        return Some(mrc[0]);
    }
    if bytes >= caps[caps.len() - 1] {
        return Some(mrc[mrc.len() - 1]);
    }
    let x = (bytes as f64).log2();
    for i in 1..caps.len() {
        if bytes <= caps[i] {
            let x0 = (caps[i - 1] as f64).log2();
            let x1 = (caps[i] as f64).log2();
            let t = (x - x0) / (x1 - x0);
            return Some(mrc[i - 1] + t * (mrc[i] - mrc[i - 1]));
        }
    }
    unreachable!("bytes bounded by the clamp above");
}

/// Everything about a config except its capacities: two grid points may
/// only inherit from each other when their shapes match, since the MRC
/// flatness argument speaks about capacity alone.
fn shape_signature(c: &HierarchyConfig) -> String {
    let levels: Vec<String> = c
        .levels
        .iter()
        .map(|l| format!("{}:{}:{}", l.ways, l.policy.name(), l.replacement.name()))
        .collect();
    format!("{}|{}|{}", c.line_bytes, c.write_allocate, levels.join(","))
}

/// Decide, per grid point, replay vs inherit for one app. Within each
/// shape group (sorted by aggregate capacity) a point whose interpolated
/// miss ratio sits within [`SWEEP_FLAT_EPS`] of the previously kept
/// point's is dominated: same curve segment, same DRAM traffic, same
/// verdict. Unusable curves disable pruning entirely.
fn plan_grid(configs: &[HierarchyConfig], mrc: &[f64]) -> Vec<PointPlan> {
    let mut inherit_from: Vec<Option<usize>> = vec![None; configs.len()];
    let mut order: Vec<usize> = (0..configs.len()).collect();
    order.sort_by_key(|&i| (shape_signature(&configs[i]), configs[i].aggregate_capacity_bytes()));
    let mut prev: Option<(String, usize, f64)> = None; // (signature, kept idx, kept mr)
    for i in order {
        let sig = shape_signature(&configs[i]);
        let mr = mrc_at(mrc, configs[i].aggregate_capacity_bytes());
        let dominated = matches!((&prev, mr),
            (Some((psig, _, pmr)), Some(mr)) if *psig == sig && (mr - pmr).abs() < SWEEP_FLAT_EPS);
        if dominated {
            inherit_from[i] = prev.as_ref().map(|(_, kept, _)| *kept);
        } else {
            prev = Some((sig, i, mr.unwrap_or(f64::NAN)));
        }
    }
    // Replay slots number the kept points in *grid* order — the same
    // order `TrafficOpts::sweep` (and so `TrafficMetrics::sweep`) uses.
    let mut slot = 0usize;
    inherit_from
        .into_iter()
        .map(|inh| match inh {
            Some(from) => PointPlan::Inherit { from },
            None => {
                let p = PointPlan::Replay { slot };
                slot += 1;
                p
            }
        })
        .collect()
}

/// One app × one grid point: the replayed (or inherited) DRAM traffic
/// and the EDP verdict derived from it.
#[derive(Debug, Clone)]
pub struct GridPoint {
    /// `true` when this point was MRC-pruned and inherited
    /// [`inherited_from`](Self::inherited_from)'s numbers.
    pub pruned: bool,
    pub inherited_from: Option<usize>,
    /// Per-level counters — `None` for pruned points (they were never
    /// replayed; that is the point).
    pub counters: Option<SweepCounters>,
    /// Post-hierarchy DRAM traffic in 64 B-equivalent lines
    /// (fills + writebacks, scaled by the config's line size).
    pub dram_lines64: f64,
    /// Host EDP under this hierarchy (delta model over the simulated
    /// host).
    pub edp: f64,
    /// `edp / nmc_edp` — the per-config analog of
    /// [`EdpComparison::edp_improvement`](crate::sim::EdpComparison::edp_improvement).
    pub edp_vs_nmc: f64,
    /// The offload verdict: NMC still wins at this hierarchy.
    pub offload: bool,
}

/// One app's row of the sweep.
#[derive(Debug, Clone)]
pub struct AppSweep {
    pub app: String,
    pub points: Vec<GridPoint>,
    /// Grid points actually replayed (the rest were MRC-pruned).
    pub replayed: usize,
}

/// The full `--sweep` result: grid provenance plus one row per app.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub labels: Vec<String>,
    pub configs: Vec<HierarchyConfig>,
    pub apps: Vec<AppSweep>,
}

impl SweepReport {
    /// The `"sweep"` section of the pipeline JSON: the grid (full spec
    /// provenance per point) and per-app per-point verdicts.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let grid: Vec<Json> = self
            .configs
            .iter()
            .zip(&self.labels)
            .map(|(c, l)| {
                let mut g = Json::obj();
                g.set("label", l.as_str());
                g.set("config", c.to_json());
                g.set("aggregate_capacity_bytes", c.aggregate_capacity_bytes());
                g
            })
            .collect();
        j.set("grid", grid);
        let mut apps = Json::obj();
        for a in &self.apps {
            let mut o = Json::obj();
            o.set("replayed", a.replayed as u64);
            o.set("pruned", (a.points.len() - a.replayed) as u64);
            let points: Vec<Json> = a
                .points
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut pj = Json::obj();
                    pj.set("label", self.labels[i].as_str());
                    pj.set("pruned", p.pruned);
                    if let Some(from) = p.inherited_from {
                        pj.set("inherited_from", from as u64);
                    }
                    pj.set("dram_lines64", p.dram_lines64);
                    pj.set("edp", p.edp);
                    pj.set("edp_vs_nmc", p.edp_vs_nmc);
                    pj.set("offload", p.offload);
                    if let Some(c) = &p.counters {
                        pj.set("counters", c.to_json());
                    }
                    pj
                })
                .collect();
            o.set("points", points);
            apps.set(&a.app, o);
        }
        j.set("apps", apps);
        j
    }
}

/// Run the sweep's second phase over an already-profiled suite: one
/// traffic-only replay per app carrying every kept grid config, then the
/// EDP verdict per grid point. `apps` must come from a live (non-trace)
/// pipeline pass — the replay re-interprets each kernel by name at the
/// same `n` and seed, so the address stream is identical to pass 1.
pub fn run_sweep(cfg: &PipelineCfg, apps: &[AppResult], grid: &SweepGrid) -> Result<SweepReport> {
    let labels: Vec<String> = grid.configs.iter().map(config_label).collect();
    let hostc = HostConfig::default(); // latency knobs; caches live in the grid
    let energy = EnergyConfig::default();
    let mut out = Vec::with_capacity(apps.len());
    for app in apps {
        let plan = plan_grid(&grid.configs, &app.metrics.traffic.mrc_miss_ratio);
        let kept: Vec<HierarchyConfig> = plan
            .iter()
            .zip(&grid.configs)
            .filter(|(p, _)| matches!(p, PointPlan::Replay { .. }))
            .map(|(_, c)| c.clone())
            .collect();
        let n_kept = kept.len();
        // Leaked once per app per run: TrafficOpts stays Copy by carrying
        // a 'static slice, and a CLI sweep leaks a handful of tiny
        // configs exactly once.
        let kept: &'static [HierarchyConfig] = Box::leak(kept.into_boxed_slice());
        let k = workloads::by_name(&app.name)
            .with_context(|| format!("sweep: app {} is not a registry kernel", app.name))?;
        let m = ProfileRequest::app(k.as_ref(), app.n, cfg.seed)
            .metrics(MetricSet::from_names("traffic")?)
            .mode(cfg.mode)
            .traffic(cfg.traffic.with_sweep(Some(kept)))
            .run_metrics(&RunCtx::new())?;
        let counters = &m.traffic.sweep;
        if counters.len() != n_kept {
            bail!(
                "sweep: {} returned {} grid counters for {} kept configs",
                app.name,
                counters.len(),
                n_kept
            );
        }
        let host = &app.cmp.host;
        let nmc_edp = app.cmp.nmc.edp();
        let base_lines = host.dram_lines as f64;
        let verdict = |lines64: f64| -> (f64, f64, bool) {
            let delta = lines64 - base_lines;
            let e = (host.energy_j + delta * energy.host_dram_line_pj * 1e-12)
                .max(f64::MIN_POSITIVE);
            let t = (host.time_s + delta * hostc.dram_lat_ns * 1e-9 / hostc.mlp)
                .max(f64::MIN_POSITIVE);
            let edp = e * t;
            let vs = if nmc_edp > 0.0 { edp / nmc_edp } else { 0.0 };
            (edp, vs, vs > 1.0)
        };
        let mut points: Vec<Option<GridPoint>> = vec![None; grid.configs.len()];
        for (i, p) in plan.iter().enumerate() {
            if let PointPlan::Replay { slot } = p {
                let c = counters[*slot].clone();
                let lines64 = (c.dram_fills + c.dram_writebacks) as f64
                    * (c.config.line_bytes as f64 / MRC_LINE_BYTES as f64);
                let (edp, vs, offload) = verdict(lines64);
                points[i] = Some(GridPoint {
                    pruned: false,
                    inherited_from: None,
                    counters: Some(c),
                    dram_lines64: lines64,
                    edp,
                    edp_vs_nmc: vs,
                    offload,
                });
            }
        }
        for (i, p) in plan.iter().enumerate() {
            if let PointPlan::Inherit { from } = p {
                let donor = points[*from]
                    .as_ref()
                    .expect("inherit targets are always replayed points");
                points[i] = Some(GridPoint {
                    pruned: true,
                    inherited_from: Some(*from),
                    counters: None,
                    dram_lines64: donor.dram_lines64,
                    edp: donor.edp,
                    edp_vs_nmc: donor.edp_vs_nmc,
                    offload: donor.offload,
                });
            }
        }
        out.push(AppSweep {
            app: app.name.clone(),
            points: points.into_iter().map(|p| p.expect("every point resolved")).collect(),
            replayed: n_kept,
        });
    }
    Ok(SweepReport { labels, configs: grid.configs.clone(), apps: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::HierarchyPolicy;

    fn grid3() -> SweepGrid {
        SweepGrid::from_json_str(
            r#"{"configs": [
                 {"levels": [{"name": "l1", "capacity_kb": 1, "ways": 4}]},
                 {"levels": [{"name": "l1", "capacity_kb": 1, "ways": 4},
                             {"name": "llc", "capacity_kb": 32, "ways": 8}]},
                 {"policy": "exclusive",
                  "levels": [{"name": "l1", "capacity_kb": 2},
                             {"name": "llc", "capacity_kb": 64}]}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn grid_parses_and_cross_products() {
        let g = grid3();
        assert_eq!(g.configs.len(), 3);
        assert_eq!(g.configs[0].levels.len(), 1);
        assert_eq!(g.configs[2].policy, HierarchyPolicy::Exclusive);
        // replacement cross product doubles the grid and stamps levels
        let g2 = SweepGrid::from_json_str(
            r#"{"configs": [{"levels": [{"name": "l1", "capacity_kb": 1}]},
                            {"levels": [{"name": "l1", "capacity_kb": 4}]}],
                "replacements": ["lru", "rrip"]}"#,
        )
        .unwrap();
        assert_eq!(g2.configs.len(), 4);
        assert_eq!(g2.configs[0].levels[0].replacement, ReplacementKind::Lru);
        assert_eq!(g2.configs[1].levels[0].replacement, ReplacementKind::Rrip);
        assert_eq!(g2.configs[3].levels[0].replacement, ReplacementKind::Rrip);
    }

    #[test]
    fn grid_errors_are_typed() {
        let e = SweepGrid::from_json_str("[]").unwrap_err();
        assert!(e.to_string().contains("sweep grid"), "{e}");
        let e = SweepGrid::from_json_str(r#"{"configs": []}"#).unwrap_err();
        assert!(e.to_string().contains("must not be empty"), "{e}");
        let e = SweepGrid::from_json_str(r#"{"configs": [{"levels": []}]}"#).unwrap_err();
        // config entries fail with the spec parser's typed prefix
        assert!(e.to_string().contains("hierarchy spec"), "{e}");
        let e = SweepGrid::from_json_str(r#"{"grids": [1]}"#).unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
        let e = SweepGrid::from_json_str(
            r#"{"configs": [{"levels": [{"name": "l1", "capacity_kb": 1}]}],
                "replacements": ["plru"]}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("lru|rrip|drrip"), "{e}");
        // a path that is not inline JSON and does not exist
        assert!(SweepGrid::load("/nonexistent/grid.json").is_err());
    }

    #[test]
    fn labels_are_compact_and_distinct() {
        let g = grid3();
        let labels: Vec<String> = g.configs.iter().map(config_label).collect();
        assert_eq!(labels[0], "1K/incl·lru");
        assert_eq!(labels[1], "1K+32K/incl·lru");
        assert_eq!(labels[2], "2K+64K/excl·lru");
    }

    #[test]
    fn mrc_interpolation_clamps_and_interpolates() {
        let mrc = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3];
        assert_eq!(mrc_at(&mrc, 1), Some(1.0)); // below the family
        assert_eq!(mrc_at(&mrc, 1 << 30), Some(0.3)); // above it
        assert_eq!(mrc_at(&mrc, 4 << 10), Some(1.0)); // exact point
        // halfway in log2 between 4K and 16K
        let mid = mrc_at(&mrc, 8 << 10).unwrap();
        assert!((mid - 0.95).abs() < 1e-12, "{mid}");
        // unusable curves: wrong length or NaN
        assert_eq!(mrc_at(&[0.5; 3], 4 << 10), None);
        assert_eq!(mrc_at(&[f64::NAN; 8], 4 << 10), None);
    }

    #[test]
    fn flat_segments_are_pruned_within_a_shape_group() {
        // same shape, capacities 4K / 8K / 4M: the curve is flat between
        // 4K and 8K, cliffs by 4M
        let mk = |kb: u64| {
            HierarchyConfig::from_spec_json(&format!(
                r#"{{"levels": [{{"name": "l1", "capacity_kb": {kb}, "ways": 4}}]}}"#
            ))
            .unwrap()
        };
        let configs = vec![mk(4), mk(8), mk(4096)];
        let mrc = [0.9, 0.9, 0.9, 0.9, 0.9, 0.2, 0.2, 0.2];
        let plan = plan_grid(&configs, &mrc);
        assert_eq!(plan[0], PointPlan::Replay { slot: 0 });
        assert_eq!(plan[1], PointPlan::Inherit { from: 0 });
        assert_eq!(plan[2], PointPlan::Replay { slot: 1 });
        // different shape (ways) never inherits, even at equal capacity
        let mut other = mk(8);
        other.levels[0].ways = 2;
        let plan = plan_grid(&[mk(4), other], &mrc);
        assert!(plan.iter().all(|p| matches!(p, PointPlan::Replay { .. })));
        // NaN curve disables pruning
        let plan = plan_grid(&[mk(4), mk(8)], &[f64::NAN; 8]);
        assert!(plan.iter().all(|p| matches!(p, PointPlan::Replay { .. })));
    }
}
