//! Profiling-as-a-service: the `pisa-nmc serve` daemon.
//!
//! A [`Server`] listens on a TCP address and speaks a JSON-lines
//! protocol: every request is one JSON object on one line, every reply
//! one JSON object on one line. Each connection gets its own
//! [`Scheduler`], but every connection draws shard workers from the one
//! process-global [`WorkerBudget`], so a busy daemon cannot oversubscribe
//! the machine any more than a single `--jobs N` pipeline run can.
//!
//! Requests:
//!
//! ```text
//! {"cmd":"profile","app":"gesummv","n":48,"seed":7}
//! {"cmd":"profile","app":"atax","scale":0.1,"metrics":"mix,traffic","pipeline":"sharded","workers":"auto"}
//! {"cmd":"profile","trace":"runs/gesummv.pallas-trace"}
//! {"cmd":"cancel","seq":3}
//! ```
//!
//! Replies (one line each; results stream as jobs complete, which under
//! `--jobs > 1` need not be submission order — correlate on `"seq"`):
//!
//! ```text
//! {"type":"accepted","seq":3,"app":"gesummv"}
//! {"type":"result","seq":3,"app":"gesummv","events_per_sec":...,...}
//! {"type":"error","error":"unknown kernel 'gesumvm' ..."}       // bad request, nothing queued
//! {"type":"rejected","error":"job queue full (capacity 16)"}    // backpressure, resubmit later
//! {"type":"job-error","seq":4,"app":"atax","error":"timeout","message":"..."}
//! {"type":"cancel","seq":3,"ok":true}                           // queued jobs only
//! ```
//!
//! Malformed or unknown-app requests get a typed `"error"` reply and the
//! connection keeps serving — one bad job never poisons the stream. The
//! daemon drains in-flight jobs and exits cleanly on SIGTERM (see
//! [`install_sigterm_handler`]) or when [`Server::shutdown_flag`] is set.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::analysis::MetricSet;
use crate::fault::SuperviseOpts;
use crate::interp::{PipelineMode, Workers};
use crate::traffic::{HierarchyPolicy, MrcMode, TrafficOpts};
use crate::util::Json;
use crate::workloads::{by_name, scaled_n};

use super::pipeline::AppOutcome;
use super::sched::{Completion, JobSpec, Jobs, Scheduler, SubmitError, WorkerBudget};

/// Set by the SIGTERM handler; every [`Server::run`] loop polls it.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Install a SIGTERM handler that asks every running [`Server`] to drain
/// and exit. Direct `signal(2)` registration — storing one flag from a
/// handler is exactly the async-signal-safe case it supports, and it
/// keeps the daemon dependency-free.
#[cfg(unix)]
pub fn install_sigterm_handler() {
    const SIGTERM: i32 = 15;
    extern "C" fn on_term(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
    }
}

/// Non-unix builds have no SIGTERM; shutdown comes from
/// [`Server::shutdown_flag`] alone.
#[cfg(not(unix))]
pub fn install_sigterm_handler() {}

/// Server-level defaults: per-request JSON fields override `metrics`,
/// `mode` and the traffic knobs job by job; `jobs`, `queue_cap` and the
/// supervision plan are fixed at startup.
#[derive(Clone, Copy)]
pub struct ServeCfg {
    /// Concurrent jobs per connection (`--jobs`, capped at machine size).
    pub jobs: Jobs,
    /// Bounded queue depth per connection (`--queue-cap`); submissions
    /// beyond it get a `"rejected"` backpressure reply.
    pub queue_cap: usize,
    pub metrics: MetricSet,
    pub mode: PipelineMode,
    pub traffic: TrafficOpts,
    /// Supervision every served job runs under (`--app-timeout`).
    pub sup: SuperviseOpts,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            jobs: Jobs::Auto,
            queue_cap: 16,
            metrics: MetricSet::all(),
            mode: PipelineMode::Inline,
            traffic: TrafficOpts::default(),
            sup: SuperviseOpts::default(),
        }
    }
}

/// The profiling daemon: accepts connections, runs each one's jobs
/// through a [`Scheduler`] against the shared [`WorkerBudget`], and
/// streams result JSON back as each job completes.
pub struct Server {
    listener: TcpListener,
    cfg: ServeCfg,
    budget: Arc<WorkerBudget>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind the listen address (e.g. `127.0.0.1:7071`, or port `0` to
    /// let the OS pick — read it back with [`Server::local_addr`]).
    pub fn bind(addr: &str, cfg: ServeCfg, budget: Arc<WorkerBudget>) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address {addr}"))?;
        listener.set_nonblocking(true).context("setting the listener non-blocking")?;
        Ok(Server { listener, cfg, budget, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Per-instance shutdown flag (the test-friendly twin of SIGTERM):
    /// set it to true and `run()` drains and returns.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst)
    }

    /// Serve until SIGTERM or the shutdown flag; drains every accepted
    /// connection's in-flight jobs before returning.
    pub fn run(self) -> Result<()> {
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.stopping() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let cfg = self.cfg;
                    let budget = Arc::clone(&self.budget);
                    let shutdown = Arc::clone(&self.shutdown);
                    conns.push(std::thread::spawn(move || {
                        // a torn-down peer is not a server error
                        let _ = handle_conn(stream, cfg, budget, shutdown);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => return Err(e).context("accepting a serve connection"),
            }
            conns.retain(|h| !h.is_finished());
        }
        for h in conns {
            let _ = h.join();
        }
        Ok(())
    }
}

/// How often blocked reads/receives wake up to poll shutdown flags.
const POLL: Duration = Duration::from_millis(200);

fn handle_conn(
    stream: TcpStream,
    cfg: ServeCfg,
    budget: Arc<WorkerBudget>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(Some(POLL)).context("setting the connection read timeout")?;
    let out = Arc::new(Mutex::new(stream.try_clone().context("cloning the reply stream")?));
    // the queue cap is the backpressure bound; Jobs::resolve caps the
    // worker pool at machine size
    let (sched, rx) = Scheduler::new(
        cfg.jobs.resolve(usize::MAX),
        budget,
        cfg.queue_cap,
        /* fail_fast: one client's bad job must not cancel its others */ false,
    );
    let pending = Arc::new(AtomicUsize::new(0));
    let closing = Arc::new(AtomicBool::new(false));
    let writer = {
        let out = Arc::clone(&out);
        let pending = Arc::clone(&pending);
        let closing = Arc::clone(&closing);
        std::thread::spawn(move || result_writer(rx, &out, &pending, &closing))
    };

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client is done submitting
            Ok(_) => {
                let reply = dispatch(line.trim(), &cfg, &sched, &pending);
                line.clear();
                if let Some(reply) = reply {
                    if write_line(&out, &reply).is_err() {
                        break;
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // timeout tick: `line` keeps any partial read; loop to
                // re-poll the shutdown flags and continue the same line
            }
            Err(_) => break,
        }
    }

    if shutdown.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst) {
        sched.abort(); // queued jobs complete as cancelled; writer drains them
    } else {
        sched.finish();
    }
    closing.store(true, Ordering::SeqCst);
    let _ = writer.join();
    Ok(())
}

/// Handle one request line; `None` means an empty line (ignored).
fn dispatch(
    line: &str,
    cfg: &ServeCfg,
    sched: &Scheduler,
    pending: &Arc<AtomicUsize>,
) -> Option<Json> {
    if line.is_empty() {
        return None;
    }
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return Some(error_reply(&format!("malformed request: {e}"))),
    };
    match get_str(&req, "cmd") {
        Some("profile") => {
            let spec = match job_from_request(&req, cfg) {
                Ok(spec) => spec,
                Err(e) => return Some(error_reply(&format!("{e:#}"))),
            };
            let name = spec.name.clone();
            // count before submit: the job may complete before this
            // thread gets back from submit()
            pending.fetch_add(1, Ordering::SeqCst);
            match sched.submit(spec) {
                Ok(seq) => {
                    let mut j = Json::obj();
                    j.set("type", "accepted");
                    j.set("seq", seq);
                    j.set("app", name);
                    Some(j)
                }
                Err(e @ SubmitError::QueueFull { .. }) => {
                    pending.fetch_sub(1, Ordering::SeqCst);
                    let mut j = Json::obj();
                    j.set("type", "rejected");
                    j.set("error", e.to_string());
                    Some(j)
                }
                Err(e @ SubmitError::ShuttingDown) => {
                    pending.fetch_sub(1, Ordering::SeqCst);
                    Some(error_reply(&e.to_string()))
                }
            }
        }
        Some("cancel") => {
            let Some(seq) = get_num(&req, "seq") else {
                return Some(error_reply("cancel needs a numeric \"seq\""));
            };
            let seq = seq as u64;
            let mut j = Json::obj();
            j.set("type", "cancel");
            j.set("seq", seq);
            // true only for still-queued jobs; the cancelled completion
            // streams back as a "cancelled" job-error
            j.set("ok", sched.cancel(seq));
            Some(j)
        }
        Some(other) => Some(error_reply(&format!("unknown cmd '{other}' (profile|cancel)"))),
        None => Some(error_reply("request needs a \"cmd\" field (profile|cancel)")),
    }
}

/// Stream completions back to the client until the reader side says it is
/// closing and every accepted job has been answered.
fn result_writer(
    rx: Receiver<Completion>,
    out: &Arc<Mutex<TcpStream>>,
    pending: &Arc<AtomicUsize>,
    closing: &Arc<AtomicBool>,
) {
    loop {
        match rx.recv_timeout(POLL) {
            Ok(c) => {
                let done = write_line(out, &completion_reply(&c)).is_err();
                pending.fetch_sub(1, Ordering::SeqCst);
                if done {
                    return; // peer gone; completions drain into the void
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if closing.load(Ordering::SeqCst) && pending.load(Ordering::SeqCst) == 0 {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

fn completion_reply(c: &Completion) -> Json {
    match &c.outcome {
        AppOutcome::Ok(r) => {
            let mut j = r.to_json();
            j.set("type", "result");
            j.set("seq", c.seq);
            j.set("app", r.name.clone());
            j.set("events_per_sec", r.events_per_sec());
            j
        }
        AppOutcome::Failed(f) => {
            let mut j = Json::obj();
            j.set("type", "job-error");
            j.set("seq", c.seq);
            j.set("app", f.name.clone());
            j.set("error", f.error.kind());
            j.set("message", f.error.to_string());
            j
        }
    }
}

fn error_reply(msg: &str) -> Json {
    let mut j = Json::obj();
    j.set("type", "error");
    j.set("error", msg);
    j
}

fn write_line(out: &Arc<Mutex<TcpStream>>, j: &Json) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    writeln!(s, "{}", j.to_string_compact())?;
    s.flush()
}

fn get_str<'j>(j: &'j Json, key: &str) -> Option<&'j str> {
    j.get(key).and_then(|v| v.as_str())
}

fn get_num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

/// Turn a `{"cmd":"profile",...}` request into a [`JobSpec`], validating
/// everything up front so bad requests are refused before queueing.
fn job_from_request(req: &Json, cfg: &ServeCfg) -> Result<JobSpec> {
    let mut spec = if let Some(path) = get_str(req, "trace") {
        JobSpec::trace(path)
    } else {
        let Some(app) = get_str(req, "app") else {
            bail!("profile needs an \"app\" name or a \"trace\" path");
        };
        let k = by_name(app)?; // unknown app: typed error, nothing queued
        let n = match get_num(req, "n") {
            Some(n) if n >= 1.0 => n as usize,
            Some(_) => bail!("\"n\" must be a positive integer"),
            None => scaled_n(k.as_ref(), get_num(req, "scale").unwrap_or(1.0)),
        };
        let seed = get_num(req, "seed").map_or(42, |s| s as u64);
        JobSpec::kernel(app, n, seed)
    };
    spec.metrics = match get_str(req, "metrics") {
        Some(s) => MetricSet::from_names(s)?,
        None => cfg.metrics,
    };
    spec.mode = match get_str(req, "pipeline") {
        Some(s) => PipelineMode::from_name(s)?,
        None => cfg.mode,
    };
    if let Some(w) = get_str(req, "workers") {
        if !matches!(spec.mode, PipelineMode::Sharded { .. }) {
            bail!("\"workers\" applies only to the sharded pipeline (got '{}')", spec.mode.name());
        }
        spec.mode = PipelineMode::Sharded { workers: Workers::from_name(w)? };
    }
    spec.traffic = cfg.traffic;
    if let Some(h) = get_str(req, "hierarchy") {
        spec.traffic.hierarchy = HierarchyPolicy::from_name(h)?;
    }
    if let Some(m) = get_str(req, "mrc") {
        spec.traffic.mrc = MrcMode::from_name(m)?;
    }
    spec.sup = cfg.sup;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::JobKind;

    fn parse(line: &str) -> Result<JobSpec> {
        job_from_request(&Json::parse(line).unwrap(), &ServeCfg::default())
    }

    #[test]
    fn profile_request_parses_to_a_job() {
        let spec = parse(r#"{"cmd":"profile","app":"gesummv","n":48,"seed":7}"#).unwrap();
        assert_eq!(spec.name, "gesummv");
        let JobKind::Kernel { n, seed, .. } = spec.kind else { panic!("expected a kernel job") };
        assert_eq!((n, seed), (48, 7));
    }

    #[test]
    fn scale_resolves_against_the_kernel_default() {
        let spec = parse(r#"{"cmd":"profile","app":"gesummv","scale":0.1}"#).unwrap();
        let k = by_name("gesummv").unwrap();
        let JobKind::Kernel { n, seed, .. } = spec.kind else { panic!("expected a kernel job") };
        assert_eq!(n, scaled_n(k.as_ref(), 0.1));
        assert_eq!(seed, 42);
    }

    #[test]
    fn knobs_override_the_server_defaults() {
        let spec = parse(
            r#"{"cmd":"profile","app":"atax","n":8,"metrics":"mix,traffic","pipeline":"sharded","workers":"3","hierarchy":"exclusive"}"#,
        )
        .unwrap();
        assert!(matches!(spec.mode, PipelineMode::Sharded { workers: Workers::Fixed(3) }));
        assert_eq!(spec.traffic.hierarchy, HierarchyPolicy::Exclusive);
        assert!(spec.metrics.contains(crate::analysis::Metric::Traffic));
    }

    #[test]
    fn bad_requests_are_refused_before_queueing() {
        assert!(parse(r#"{"cmd":"profile","app":"no-such-kernel"}"#).is_err());
        assert!(parse(r#"{"cmd":"profile"}"#).is_err());
        assert!(parse(r#"{"cmd":"profile","app":"atax","n":0}"#).is_err());
        assert!(parse(r#"{"cmd":"profile","app":"atax","n":4,"workers":"2"}"#).is_err());
    }

    #[test]
    fn trace_requests_become_replay_jobs() {
        let spec = parse(r#"{"cmd":"profile","trace":"runs/gesummv.pallas-trace"}"#).unwrap();
        assert!(matches!(spec.kind, JobKind::Trace { .. }));
        assert_eq!(spec.name, "gesummv");
    }
}
