//! The consolidated profiling API: one [`ProfileRequest`] builder plus a
//! [`RunCtx`] of process-global state, replacing the positional
//! `profile_*`/`run_suite_*`/`run_pipeline_*` ladder that grew one public
//! signature per knob for eight PRs.
//!
//! A request names a *target* — one kernel ([`ProfileRequest::app`]), a
//! raw program ([`ProfileRequest::program`]), an externally-produced
//! event stream ([`ProfileRequest::source`]), the whole workload suite
//! ([`ProfileRequest::suite`]) or a recorded `.pallas-trace`
//! ([`ProfileRequest::trace`]) — and layers knobs on top with builder
//! methods, every one of them optional:
//!
//! ```ignore
//! let ctx = RunCtx::new();
//! let report = ProfileRequest::suite(0.5, 42)
//!     .metrics(MetricSet::from_names("traffic,mix")?)
//!     .mode(PipelineMode::Sharded { workers: Workers::Auto })
//!     .jobs(Jobs::Auto)
//!     .run(&ctx)?;
//! ```
//!
//! The context carries what outlives any one request: the process-global
//! [`WorkerBudget`] every scheduled job draws on, the optional PJRT
//! [`Runtime`] for the suite analytics, and a default supervision plan.
//! Requests run through the [`super::sched::Scheduler`] when they fan out
//! (suite targets) and hit the per-app engines in [`super::pipeline`]
//! directly otherwise; either way the metrics are bit-identical to the
//! legacy positional entry points, which are now thin deprecated shims
//! over this builder.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::{profile_run, profile_source_run, AppMetrics, MetricSet};
use crate::fault::SuperviseOpts;
use crate::interp::PipelineMode;
use crate::ir::Program;
use crate::runtime::Runtime;
use crate::trace::TraceSource;
use crate::traffic::TrafficOpts;
use crate::workloads::{registry, scaled_n, Kernel};

use super::figures::{analyze_suite, Engine, SuiteAnalytics};
use super::pca::Pca;
use super::pipeline::{
    job_delivery, replay_app, run_kernel, run_kernel_supervised, AppFailure, AppOutcome,
    AppResult, OnError, ProfileError, SuitePolicy,
};
use super::sched::{JobKind, JobSpec, Jobs, Scheduler, WorkerBudget};
use super::{PipelineCfg, PipelineReport};

/// Process-global run state shared across profiling requests: the worker
/// budget the scheduler accounts jobs against, the optional PJRT runtime
/// the suite analytics use, and the default supervision plan a request
/// inherits unless it sets its own.
pub struct RunCtx<'rt> {
    pub(crate) budget: Arc<WorkerBudget>,
    rt: Option<&'rt Runtime>,
    sup: SuperviseOpts,
}

impl Default for RunCtx<'static> {
    fn default() -> Self {
        Self::new()
    }
}

impl RunCtx<'static> {
    /// A fresh context: machine-sized worker budget, native analytics
    /// (no PJRT runtime), no supervision.
    pub fn new() -> Self {
        RunCtx {
            budget: WorkerBudget::machine(),
            rt: None,
            sup: SuperviseOpts::default(),
        }
    }
}

impl<'rt> RunCtx<'rt> {
    /// A context wired to the PJRT runtime (the suite analytics prefer
    /// the AOT artifacts when one is loaded).
    pub fn with_runtime(rt: Option<&'rt Runtime>) -> RunCtx<'rt> {
        RunCtx { budget: WorkerBudget::machine(), rt, sup: SuperviseOpts::default() }
    }

    /// Replace the worker budget (e.g. one shared budget across a daemon
    /// and a foreground pipeline in the same process).
    pub fn budget(mut self, budget: Arc<WorkerBudget>) -> Self {
        self.budget = budget;
        self
    }

    /// Default supervision plan for requests that don't carry their own.
    pub fn supervise(mut self, sup: SuperviseOpts) -> Self {
        self.sup = sup;
        self
    }

    /// The process-global worker budget scheduled jobs draw on.
    pub fn worker_budget(&self) -> &Arc<WorkerBudget> {
        &self.budget
    }

    /// The PJRT runtime, when one is loaded.
    pub fn runtime(&self) -> Option<&'rt Runtime> {
        self.rt
    }
}

/// What a [`ProfileRequest`] profiles.
enum Target<'p> {
    /// One registry (or user-supplied) kernel at an explicit size/seed.
    App { k: &'p dyn Kernel, n: usize, seed: u64 },
    /// A raw program — metrics only, no simulation layer.
    Program { prog: &'p Program },
    /// A program analyzed against an external event stream.
    Source { prog: &'p Program, source: &'p mut dyn TraceSource },
    /// The whole workload suite at a size scale.
    Suite { scale: f64, seed: u64 },
    /// A recorded `.pallas-trace` replay.
    Trace { path: PathBuf },
}

/// One profiling request: a target plus every optional knob, finished by
/// an exec method matching the target's shape (see the module doc).
///
/// | exec method | targets | returns |
/// |---|---|---|
/// | [`run`](Self::run) | suite, trace | [`PipelineReport`] |
/// | [`outcomes`](Self::outcomes) | suite | `Vec<AppOutcome>` |
/// | [`run_apps`](Self::run_apps) | suite | `Vec<AppResult>` (strict) |
/// | [`run_app`](Self::run_app) | app, trace | [`AppOutcome`] |
/// | [`run_strict`](Self::run_strict) | app, trace | [`AppResult`] |
/// | [`run_metrics`](Self::run_metrics) | app, program, source | [`AppMetrics`] |
pub struct ProfileRequest<'p> {
    target: Target<'p>,
    metrics: MetricSet,
    mode: PipelineMode,
    traffic: TrafficOpts,
    /// `None` inherits the context's supervision plan.
    sup: Option<SuperviseOpts>,
    on_error: OnError,
    jobs: Jobs,
    per_event: bool,
    /// `None` inherits the context's budget.
    budget: Option<Arc<WorkerBudget>>,
}

impl<'p> ProfileRequest<'p> {
    fn with_target(target: Target<'p>) -> Self {
        ProfileRequest {
            target,
            metrics: MetricSet::all(),
            mode: PipelineMode::Inline,
            traffic: TrafficOpts::default(),
            sup: None,
            on_error: OnError::default(),
            jobs: Jobs::Auto,
            per_event: false,
            budget: None,
        }
    }

    /// Profile one kernel (any [`Kernel`], registry or user-built) at an
    /// explicit size and seed.
    pub fn app(k: &'p dyn Kernel, n: usize, seed: u64) -> Self {
        Self::with_target(Target::App { k, n, seed })
    }

    /// Analyze a raw program: metrics only, no task trace or simulation
    /// layer (finish with [`run_metrics`](Self::run_metrics)).
    pub fn program(prog: &'p Program) -> Self {
        Self::with_target(Target::Program { prog })
    }

    /// Analyze `prog` against an externally-produced event stream (any
    /// [`TraceSource`]); finish with [`run_metrics`](Self::run_metrics).
    pub fn source(prog: &'p Program, source: &'p mut dyn TraceSource) -> Self {
        Self::with_target(Target::Source { prog, source })
    }

    /// Profile the whole workload suite, `scale` applied to every
    /// kernel's default size.
    pub fn suite(scale: f64, seed: u64) -> Self {
        Self::with_target(Target::Suite { scale, seed })
    }

    /// Replay a recorded `.pallas-trace` (workload identity comes from
    /// the trace header).
    pub fn trace(path: impl Into<PathBuf>) -> Self {
        Self::with_target(Target::Trace { path: path.into() })
    }

    /// Select the analyzer families (CLI `--metrics`); defaults to all.
    pub fn metrics(mut self, metrics: MetricSet) -> Self {
        self.metrics = metrics;
        self
    }

    /// Select the event delivery (CLI `--pipeline`); defaults to inline.
    pub fn mode(mut self, mode: PipelineMode) -> Self {
        self.mode = mode;
        self
    }

    /// Traffic-family knobs: hierarchy replay policy + MRC kernel (CLI
    /// `--hierarchy`, `--mrc`, `--mrc-smax`).
    pub fn traffic(mut self, traffic: TrafficOpts) -> Self {
        self.traffic = traffic;
        self
    }

    /// Supervision plan plus suite failure policy in one bundle (CLI
    /// `--inject-fault`, `--app-timeout`, `--on-error`).
    pub fn policy(mut self, policy: SuitePolicy) -> Self {
        self.sup = Some(policy.sup);
        self.on_error = policy.on_error;
        self
    }

    /// Per-request supervision plan, overriding the context default.
    pub fn supervise(mut self, sup: SuperviseOpts) -> Self {
        self.sup = Some(sup);
        self
    }

    /// Suite failure policy alone (defaults to fail-fast).
    pub fn on_error(mut self, on_error: OnError) -> Self {
        self.on_error = on_error;
        self
    }

    /// Suite-level concurrency (CLI `--jobs`); defaults to auto.
    pub fn jobs(mut self, jobs: Jobs) -> Self {
        self.jobs = jobs;
        self
    }

    /// Deliver events un-batched (the reference semantics the chunked
    /// pipeline is proven bit-identical to). Ignored for trace replays,
    /// which select delivery by `mode` alone.
    pub fn per_event(mut self, per_event: bool) -> Self {
        self.per_event = per_event;
        self
    }

    /// Per-request worker budget, overriding the context's.
    pub fn budget(mut self, budget: Arc<WorkerBudget>) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Run a suite or trace request to a full [`PipelineReport`]: profile
    /// (through the scheduler for suites), run the analytics over the
    /// surviving apps, and assemble the report the CLI renders. Errors on
    /// app/program/source targets — those finish with
    /// [`run_strict`](Self::run_strict)/[`run_metrics`](Self::run_metrics).
    pub fn run(self, ctx: &RunCtx<'_>) -> Result<PipelineReport> {
        let ProfileRequest {
            target,
            metrics,
            mode,
            traffic,
            sup,
            on_error,
            jobs,
            per_event,
            budget,
        } = self;
        match target {
            Target::Suite { scale, seed } => {
                // same effective set the jobs profile with, so the
                // report's "metrics" list names the families that ran
                let metrics = metrics.with_simulation_requirements();
                let req = ProfileRequest {
                    target: Target::Suite { scale, seed },
                    metrics,
                    mode,
                    traffic,
                    sup,
                    on_error,
                    jobs,
                    per_event,
                    budget,
                };
                let outcomes = req.outcomes(ctx)?;
                let mut apps = Vec::new();
                let mut failures = Vec::new();
                for out in outcomes {
                    match out {
                        AppOutcome::Ok(r) => apps.push(*r),
                        AppOutcome::Failed(f) => failures.push(*f),
                    }
                }
                let analytics = if apps.is_empty() {
                    empty_analytics(0)
                } else {
                    analyze_suite(&apps, ctx.rt)?
                };
                Ok(PipelineReport {
                    apps,
                    failures,
                    analytics,
                    scale,
                    seed,
                    metrics,
                    mode,
                    traffic,
                    trace: None,
                    sweep: None,
                })
            }
            Target::Trace { path } => {
                let cfg = PipelineCfg {
                    scale: 1.0,
                    seed: 0, // the replay report takes its seed from the trace header
                    jobs,
                    metrics,
                    mode,
                    traffic,
                    policy: SuitePolicy { sup: sup.unwrap_or(ctx.sup), on_error },
                };
                super::run_replay_cfg(&cfg, &path)
            }
            _ => bail!(
                "run() produces a pipeline report and requires a suite or trace target; \
                 finish app/program/source requests with run_strict()/run_app()/run_metrics()"
            ),
        }
    }

    /// Run a suite request to per-app [`AppOutcome`]s in registry order.
    /// Under [`OnError::FailFast`] the first failed app aborts the suite
    /// (queued jobs are cancelled); under [`OnError::Continue`] failures
    /// ride along structurally.
    pub fn outcomes(self, ctx: &RunCtx<'_>) -> Result<Vec<AppOutcome>> {
        let ProfileRequest {
            target,
            metrics,
            mode,
            traffic,
            sup,
            on_error,
            jobs,
            per_event,
            budget,
        } = self;
        let Target::Suite { scale, seed } = target else {
            bail!("outcomes() requires a suite target (ProfileRequest::suite)");
        };
        let sup = sup.unwrap_or(ctx.sup);
        let specs: Vec<JobSpec> = registry()
            .iter()
            .map(|k| {
                let name = k.info().name.to_string();
                JobSpec {
                    name: name.clone(),
                    kind: JobKind::Kernel { app: name, n: scaled_n(k.as_ref(), scale), seed },
                    metrics,
                    mode,
                    traffic,
                    sup,
                    per_event,
                }
            })
            .collect();
        let workers = jobs.resolve(specs.len());
        let budget = budget.unwrap_or_else(|| Arc::clone(&ctx.budget));
        run_batch(specs, workers, on_error == OnError::FailFast, budget)
    }

    /// [`outcomes`](Self::outcomes) with every app required to succeed:
    /// any failure aborts with that app's error.
    pub fn run_apps(self, ctx: &RunCtx<'_>) -> Result<Vec<AppResult>> {
        self.outcomes(ctx)?
            .into_iter()
            .map(|o| match o {
                AppOutcome::Ok(r) => Ok(*r),
                AppOutcome::Failed(f) => bail!("{} failed: {}", f.name, f.error),
            })
            .collect()
    }

    /// Run an app or trace request under supervision: never panics out
    /// and never returns `Err` — every failure mode folds into a
    /// structured [`AppOutcome::Failed`] (including a wrong target kind).
    pub fn run_app(self, ctx: &RunCtx<'_>) -> AppOutcome {
        let ProfileRequest { target, metrics, mode, traffic, sup, per_event, .. } = self;
        let sup = sup.unwrap_or(ctx.sup);
        match target {
            Target::App { k, n, seed } => {
                let delivery = job_delivery(mode, per_event);
                run_kernel_supervised(k, n, seed, metrics, delivery, traffic, sup)
            }
            Target::Trace { path } => {
                let start = Instant::now();
                match replay_app(&path, metrics, mode, traffic) {
                    Ok((r, _prov)) => AppOutcome::Ok(Box::new(r)),
                    Err(e) => AppOutcome::Failed(Box::new(AppFailure {
                        name: path.display().to_string(),
                        error: ProfileError::classify(&e),
                        wall_s: start.elapsed().as_secs_f64(),
                        partial: None,
                    })),
                }
            }
            _ => AppOutcome::Failed(Box::new(AppFailure {
                name: "<request>".to_string(),
                error: ProfileError::InterpError {
                    message: "run_app() requires an app or trace target".to_string(),
                },
                wall_s: 0.0,
                partial: None,
            })),
        }
    }

    /// Run an app or trace request strictly: full pipeline (analyzers,
    /// task trace, both machine models), any failure an `Err`.
    pub fn run_strict(self, ctx: &RunCtx<'_>) -> Result<AppResult> {
        let _ = ctx; // single-app runs don't draw on the budget
        let ProfileRequest { target, metrics, mode, traffic, per_event, .. } = self;
        match target {
            Target::App { k, n, seed } => {
                run_kernel(k, n, seed, metrics, job_delivery(mode, per_event), traffic)
            }
            Target::Trace { path } => replay_app(&path, metrics, mode, traffic).map(|(r, _)| r),
            _ => bail!("run_strict() requires an app or trace target"),
        }
    }

    /// Run an app, program or source request to bare [`AppMetrics`] — no
    /// task trace, no simulation layer. This is what the deprecated
    /// `analysis::profile_*` variants collapse onto.
    pub fn run_metrics(self, ctx: &RunCtx<'_>) -> Result<AppMetrics> {
        let ProfileRequest { target, metrics, mode, traffic, sup, per_event, .. } = self;
        let sup = sup.unwrap_or(ctx.sup);
        let delivery = job_delivery(mode, per_event);
        match target {
            Target::Program { prog } => {
                Ok(profile_run(prog, metrics, delivery, traffic, sup, false)?.0)
            }
            Target::Source { prog, source } => {
                Ok(profile_source_run(prog, source, metrics, delivery, traffic, false)?.0)
            }
            Target::App { k, n, seed } => {
                let prog = k.build(n, seed);
                Ok(profile_run(&prog, metrics, delivery, traffic, sup, false)?.0)
            }
            _ => bail!("run_metrics() requires an app, program or source target"),
        }
    }
}

/// Shape-stable empty analytics for reports with zero surviving apps
/// (fig6 indexes loadings/eigenvalues by feature and component, so those
/// keep their static shapes).
pub(crate) fn empty_analytics(n_apps: usize) -> SuiteAnalytics {
    SuiteAnalytics {
        engine: Engine::Native,
        entropies: Vec::new(),
        entropy_diff: Vec::new(),
        spatial: Vec::new(),
        pca: Pca {
            scores: vec![vec![0.0; 2]; n_apps],
            loadings: vec![vec![0.0; 2]; 4],
            eigenvalues: vec![0.0; 2],
            explained_variance_ratio: vec![0.0; 2],
        },
        max_crosscheck_err: 0.0,
    }
}

/// Drive one batch of jobs through a [`Scheduler`] and reorder the
/// completion stream into submission (= registry) order, so concurrent
/// suites are deterministic regardless of which app finishes first.
fn run_batch(
    specs: Vec<JobSpec>,
    workers: usize,
    fail_fast: bool,
    budget: Arc<WorkerBudget>,
) -> Result<Vec<AppOutcome>> {
    let n = specs.len();
    let (sched, rx) = Scheduler::new(workers, budget, n.max(1), fail_fast);
    for spec in specs {
        let name = spec.name.clone();
        sched.submit(spec).map_err(|e| anyhow!("submitting {name}: {e}"))?;
    }
    sched.finish();
    let mut slots: Vec<Option<AppOutcome>> = (0..n).map(|_| None).collect();
    let mut first_failure: Option<String> = None;
    for _ in 0..n {
        let c = rx.recv().context("a scheduled job produced no completion")?;
        if fail_fast && first_failure.is_none() {
            if let AppOutcome::Failed(f) = &c.outcome {
                // the cancellations are fallout from the real failure;
                // report the cause, not the casualties
                if !matches!(f.error, ProfileError::Cancelled) {
                    first_failure = Some(format!("{} failed: {}", f.name, f.error));
                }
            }
        }
        slots[c.seq as usize] = Some(c.outcome);
    }
    if let Some(msg) = first_failure {
        bail!("{msg}");
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.with_context(|| format!("job {i} produced no result")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn program_request_matches_profile() {
        let k = by_name("gesummv").unwrap();
        let prog = k.build(16, 1);
        let a = crate::analysis::profile(&prog).unwrap();
        let b = ProfileRequest::program(&prog).run_metrics(&RunCtx::new()).unwrap();
        assert_eq!(
            a.pca8_features().map(f64::to_bits),
            b.pca8_features().map(f64::to_bits)
        );
        assert_eq!(a.exec.dyn_instrs, b.exec.dyn_instrs);
    }

    #[test]
    fn per_event_request_matches_chunked() {
        let k = by_name("gesummv").unwrap();
        let prog = k.build(16, 1);
        let chunked = ProfileRequest::program(&prog).run_metrics(&RunCtx::new()).unwrap();
        let pe = ProfileRequest::program(&prog)
            .per_event(true)
            .run_metrics(&RunCtx::new())
            .unwrap();
        assert_eq!(
            chunked.pca8_features().map(f64::to_bits),
            pe.pca8_features().map(f64::to_bits)
        );
        assert_eq!(chunked.mix.per_op, pe.mix.per_op);
    }

    #[test]
    fn mismatched_targets_error_cleanly() {
        let ctx = RunCtx::new();
        let k = by_name("gesummv").unwrap();
        let prog = k.build(8, 1);
        assert!(ProfileRequest::program(&prog).run(&ctx).is_err());
        assert!(ProfileRequest::suite(0.05, 7).run_strict(&ctx).is_err());
        assert!(ProfileRequest::suite(0.05, 7).run_metrics(&ctx).is_err());
        assert!(ProfileRequest::program(&prog).outcomes(&ctx).is_err());
        let out = ProfileRequest::suite(0.05, 7).run_app(&ctx);
        let AppOutcome::Failed(f) = out else { panic!("expected a structured failure") };
        assert_eq!(f.error.kind(), "interp-error");
    }

    #[test]
    fn suite_request_produces_a_report() {
        let report = ProfileRequest::suite(0.05, 7)
            .jobs(Jobs::Fixed(2))
            .run(&RunCtx::new())
            .unwrap();
        assert_eq!(report.apps.len(), 12);
        assert!(report.failures.is_empty());
        assert_eq!(report.scale, 0.05);
        assert_eq!(report.seed, 7);
        assert!(report.suite_events_per_sec() > 0.0);
    }
}
