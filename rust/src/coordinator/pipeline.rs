//! The profiling pipeline: fan the 12 workloads out over worker threads,
//! run each through one instrumented execution (all analyzers + the task
//! trace in a single pass) and both machine models, then post-process the
//! numeric analytics through the PJRT artifacts on the main thread.
//!
//! Rust owns the event loop and process topology (L3 of the architecture);
//! the PJRT artifacts own the batched numeric analytics (L2/L1). Worker
//! count is bounded by `available_parallelism`; jobs stream through a
//! bounded channel so a slow workload cannot pile up unbounded memory.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::analysis::{self, AppMetrics};
use crate::interp::{run_program, Fanout};
use crate::sim::{self, EdpComparison, Region, TaskTraceCollector};
use crate::workloads::{registry, scaled_n, Kernel};

/// Per-application pipeline output.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub name: String,
    pub n: usize,
    pub metrics: AppMetrics,
    pub cmp: EdpComparison,
}

/// Profile one kernel: single instrumented execution feeding every analyzer
/// *and* the task-trace collector, then both machine simulations.
pub fn profile_app(k: &dyn Kernel, n: usize, seed: u64) -> Result<AppResult> {
    let prog = k.build(n, seed);
    crate::ir::verify::verify_ok(&prog);
    let n_regs = prog.func.n_regs;

    let mut mix = analysis::MixAnalyzer::new();
    let mut branch = analysis::BranchAnalyzer::new();
    let mut ment = analysis::MemEntropyAnalyzer::new();
    let mut reuse = analysis::ReuseAnalyzer::new();
    let mut ilp = analysis::IlpAnalyzer::new(n_regs);
    let mut dlp = analysis::DlpAnalyzer::for_program(&prog);
    let mut bblp = analysis::BblpAnalyzer::new(n_regs);
    let mut pbblp = analysis::PbblpAnalyzer::new(&prog);
    let mut tasks = TaskTraceCollector::new(&prog);

    let (out, _machine) = {
        let mut fan = Fanout::new(vec![
            &mut mix,
            &mut branch,
            &mut ment,
            &mut reuse,
            &mut ilp,
            &mut dlp,
            &mut bblp,
            &mut pbblp,
            &mut tasks,
        ]);
        run_program(&prog, &mut fan).with_context(|| format!("running {}", k.info().name))?
    };

    let mem_entropy = ment.finalize(analysis::ENTROPY_SLOTS);
    let reuse_res = reuse.finalize();
    let spatial = analysis::spatial::from_reuse(&reuse_res);
    let ilp_res = ilp.finalize();
    let metrics = AppMetrics {
        name: prog.func.name.clone(),
        mix,
        branch,
        mem_entropy,
        reuse: reuse_res,
        spatial,
        ilp: ilp_res,
        dlp: dlp.finalize(),
        bblp: bblp.finalize(),
        pbblp: pbblp.finalize(),
        exec: out.stats,
    };

    // both machine models consume the same region trace
    let regions: Vec<Region> = tasks.finalize();
    let ilp256 = metrics
        .ilp
        .windowed
        .iter()
        .find(|(w, _)| *w == 256)
        .map(|(_, v)| *v)
        .unwrap_or(metrics.ilp.inf);
    let cmp = EdpComparison {
        app: metrics.name.clone(),
        host: sim::simulate_host(&regions, ilp256),
        nmc: sim::simulate_nmc(&regions),
    };

    Ok(AppResult { name: metrics.name.clone(), n, metrics, cmp })
}

/// Run the whole suite, `scale` applied to every kernel's default size.
/// Results come back in registry order regardless of completion order.
pub fn run_suite(scale: f64, seed: u64, threads: usize) -> Result<Vec<AppResult>> {
    let kernels = registry();
    let n_jobs = kernels.len();
    let threads = threads
        .max(1)
        .min(n_jobs)
        .min(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4));

    // job queue: indices into the registry, pulled by workers
    let jobs: Mutex<Vec<usize>> = Mutex::new((0..n_jobs).rev().collect());
    let (tx, rx) = mpsc::channel::<(usize, Result<AppResult>)>();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let jobs = &jobs;
            scope.spawn(move || loop {
                let Some(idx) = jobs.lock().unwrap().pop() else {
                    break;
                };
                // fresh registry per thread: Kernel is stateless
                let k = &registry()[idx];
                let n = scaled_n(k.as_ref(), scale);
                let res = profile_app(k.as_ref(), n, seed);
                if tx.send((idx, res)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut slots: Vec<Option<AppResult>> = (0..n_jobs).map(|_| None).collect();
        for (idx, res) in rx {
            slots[idx] = Some(res?);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.with_context(|| format!("job {i} produced no result")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn profile_app_end_to_end() {
        let k = by_name("gesummv").unwrap();
        let r = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(r.name, "gesummv");
        assert!(r.metrics.exec.dyn_instrs > 1000);
        assert!(r.cmp.host.time_s > 0.0 && r.cmp.nmc.time_s > 0.0);
        assert_eq!(r.cmp.host.dyn_instrs, r.cmp.nmc.dyn_instrs);
    }

    #[test]
    fn tiny_suite_runs_in_order() {
        let rs = run_suite(0.08, 7, 4).unwrap();
        assert_eq!(rs.len(), 12);
        let names: Vec<_> = rs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names[0], "atax");
        assert_eq!(names[11], "kmeans");
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.cmp.edp_improvement() > 0.0, "{}", r.name);
        }
    }
}
