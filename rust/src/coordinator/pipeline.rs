//! The profiling pipeline: fan the 12 workloads out over worker threads,
//! run each through one instrumented execution (the full `AnalyzerStack`
//! plus the task trace in a single chunked pass) and both machine models,
//! then post-process the numeric analytics through the PJRT artifacts on
//! the main thread.
//!
//! Rust owns the event loop and process topology (L3 of the architecture);
//! the PJRT artifacts own the batched numeric analytics (L2/L1). Worker
//! count is bounded by `available_parallelism`; jobs stream through a
//! bounded channel so a slow workload cannot pile up unbounded memory.
//!
//! With [`PipelineMode::Offload`] each worker additionally pairs its
//! interpreter with a dedicated analysis thread (see
//! [`crate::interp::offload`]), so one app occupies two cores while it
//! runs; with [`PipelineMode::Sharded`] each app adds a broadcaster plus
//! one analyzer worker per planned shard (up to 5 with every family
//! enabled, now that the traffic family's MRC and hierarchy halves land
//! on separate workers) — size `--threads` accordingly on small machines.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::analysis::{profile_with_tasks, AppMetrics, MetricSet};
use crate::interp::PipelineMode;
use crate::sim::{self, EdpComparison, Region};
use crate::traffic::TrafficOpts;
use crate::workloads::{registry, scaled_n, Kernel};

/// Per-application pipeline output.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub name: String,
    pub n: usize,
    pub metrics: AppMetrics,
    pub cmp: EdpComparison,
}

impl AppResult {
    /// Profiler throughput for this app (trace events per wall second) —
    /// surfaced in the pipeline JSON so perf regressions show up in
    /// reports, not just in benches.
    pub fn events_per_sec(&self) -> f64 {
        self.metrics.exec.events_per_sec()
    }
}

/// Profile one kernel with every metric enabled (inline delivery).
pub fn profile_app(k: &dyn Kernel, n: usize, seed: u64) -> Result<AppResult> {
    profile_app_select(k, n, seed, MetricSet::all())
}

/// [`profile_app_mode`] with inline delivery.
pub fn profile_app_select(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
) -> Result<AppResult> {
    profile_app_mode(k, n, seed, metrics, PipelineMode::Inline)
}

/// [`profile_app_opts`] with the default traffic options (inclusive
/// hierarchy replay, exact MRC).
pub fn profile_app_mode(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<AppResult> {
    profile_app_opts(k, n, seed, metrics, mode, TrafficOpts::default())
}

/// Profile one kernel: single instrumented execution feeding the selected
/// analyzers *and* the task-trace collector, then both machine
/// simulations. This is `analysis::profile_with_tasks` plus the
/// simulation layer. `mode` selects whether the analyzers fold inline on
/// the interpreter thread, on one dedicated analysis thread, or sharded
/// by metric family across a worker pool (see [`crate::interp::offload`]);
/// `opts` selects the traffic subsystem's replay policy and MRC mode (CLI
/// `--hierarchy` / `--mrc`); exact-mode metrics are bit-identical on every
/// path.
///
/// Sim-required families (ILP — see
/// [`MetricSet::with_simulation_requirements`]) are force-enabled
/// regardless of `metrics`.
pub fn profile_app_opts(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<AppResult> {
    let metrics = metrics.with_simulation_requirements();
    let prog = k.build(n, seed);
    let (metrics, regions): (AppMetrics, Vec<Region>) =
        profile_with_tasks(&prog, metrics, mode, opts)
            .with_context(|| format!("running {}", k.info().name))?;

    // both machine models consume the same region trace
    let ilp256 = metrics
        .ilp
        .windowed
        .iter()
        .find(|(w, _)| *w == 256)
        .map(|(_, v)| *v)
        .unwrap_or(metrics.ilp.inf);
    let cmp = EdpComparison {
        app: metrics.name.clone(),
        host: sim::simulate_host(&regions, ilp256),
        nmc: sim::simulate_nmc(&regions),
    };

    Ok(AppResult { name: metrics.name.clone(), n, metrics, cmp })
}

/// Run the whole suite with every metric enabled, inline delivery.
pub fn run_suite(scale: f64, seed: u64, threads: usize) -> Result<Vec<AppResult>> {
    run_suite_select(scale, seed, threads, MetricSet::all(), PipelineMode::Inline)
}

/// [`run_suite_opts`] with the default traffic options (inclusive
/// hierarchy replay, exact MRC).
pub fn run_suite_select(
    scale: f64,
    seed: u64,
    threads: usize,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<Vec<AppResult>> {
    run_suite_opts(scale, seed, threads, metrics, mode, TrafficOpts::default())
}

/// Run the whole suite, `scale` applied to every kernel's default size,
/// `metrics` selecting the analyzer families, `mode` the event delivery
/// (inline, or overlapped on per-app analysis threads) and `opts` the
/// traffic subsystem's replay policy and MRC mode. Results come back in
/// registry order regardless of completion order.
pub fn run_suite_opts(
    scale: f64,
    seed: u64,
    threads: usize,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<Vec<AppResult>> {
    let kernels = registry();
    let n_jobs = kernels.len();
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let threads = threads.clamp(1, n_jobs.min(hw).max(1));

    // job queue: indices into the registry, pulled by workers
    let jobs: Mutex<Vec<usize>> = Mutex::new((0..n_jobs).rev().collect());
    let (tx, rx) = mpsc::channel::<(usize, Result<AppResult>)>();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let jobs = &jobs;
            scope.spawn(move || loop {
                let Some(idx) = jobs.lock().unwrap().pop() else {
                    break;
                };
                // fresh registry per thread: Kernel is stateless
                let k = &registry()[idx];
                let n = scaled_n(k.as_ref(), scale);
                let res = profile_app_opts(k.as_ref(), n, seed, metrics, mode, opts);
                if tx.send((idx, res)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut slots: Vec<Option<AppResult>> = (0..n_jobs).map(|_| None).collect();
        for (idx, res) in rx {
            slots[idx] = Some(res?);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.with_context(|| format!("job {i} produced no result")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn profile_app_end_to_end() {
        let k = by_name("gesummv").unwrap();
        let r = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(r.name, "gesummv");
        assert!(r.metrics.exec.dyn_instrs > 1000);
        assert!(r.cmp.host.time_s > 0.0 && r.cmp.nmc.time_s > 0.0);
        assert_eq!(r.cmp.host.dyn_instrs, r.cmp.nmc.dyn_instrs);
        assert!(r.events_per_sec() > 0.0, "throughput must be recorded");
    }

    #[test]
    fn profile_app_matches_analysis_profile() {
        // both entry points build the same AnalyzerStack: metrics agree
        let k = by_name("gesummv").unwrap();
        let r = profile_app(k.as_ref(), 16, 1).unwrap();
        let m = crate::analysis::profile(&k.build(16, 1)).unwrap();
        assert_eq!(r.metrics.pca8_features(), m.pca8_features());
        assert_eq!(r.metrics.exec.dyn_instrs, m.exec.dyn_instrs);
    }

    #[test]
    fn offload_app_matches_inline_bit_identically() {
        let k = by_name("gesummv").unwrap();
        let inline = profile_app(k.as_ref(), 20, 1).unwrap();
        let offl =
            profile_app_mode(k.as_ref(), 20, 1, MetricSet::all(), PipelineMode::Offload).unwrap();
        assert_eq!(
            inline.metrics.pca8_features().map(f64::to_bits),
            offl.metrics.pca8_features().map(f64::to_bits)
        );
        assert_eq!(inline.metrics.exec.dyn_instrs, offl.metrics.exec.dyn_instrs);
        // the same region trace feeds the machine models on both paths
        assert_eq!(inline.cmp.host.dyn_instrs, offl.cmp.host.dyn_instrs);
        assert_eq!(inline.cmp.edp_improvement(), offl.cmp.edp_improvement());
        assert!(offl.events_per_sec() > 0.0);
    }

    #[test]
    fn tiny_suite_runs_offloaded() {
        let rs = run_suite_select(0.05, 7, 2, MetricSet::all(), PipelineMode::Offload).unwrap();
        assert_eq!(rs.len(), 12);
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.events_per_sec() > 0.0, "{}", r.name);
        }
    }

    #[test]
    fn sharded_app_matches_inline_bit_identically() {
        use crate::interp::Workers;
        let k = by_name("gesummv").unwrap();
        let inline = profile_app(k.as_ref(), 20, 1).unwrap();
        let sharded = profile_app_mode(
            k.as_ref(),
            20,
            1,
            MetricSet::all(),
            PipelineMode::Sharded { workers: Workers::Fixed(3) },
        )
        .unwrap();
        assert_eq!(
            inline.metrics.pca8_features().map(f64::to_bits),
            sharded.metrics.pca8_features().map(f64::to_bits)
        );
        assert_eq!(inline.metrics.traffic, sharded.metrics.traffic);
        assert_eq!(inline.metrics.exec.dyn_instrs, sharded.metrics.exec.dyn_instrs);
        // the same region trace feeds the machine models on both paths
        assert_eq!(inline.cmp.host.dyn_instrs, sharded.cmp.host.dyn_instrs);
        assert_eq!(inline.cmp.edp_improvement(), sharded.cmp.edp_improvement());
        assert!(sharded.events_per_sec() > 0.0);
    }

    #[test]
    fn tiny_suite_runs_sharded() {
        use crate::interp::Workers;
        let mode = PipelineMode::Sharded { workers: Workers::Auto };
        let rs = run_suite_select(0.05, 7, 2, MetricSet::all(), mode).unwrap();
        assert_eq!(rs.len(), 12);
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.events_per_sec() > 0.0, "{}", r.name);
        }
    }

    #[test]
    fn hierarchy_policy_threads_through_the_app_pipeline() {
        use crate::traffic::HierarchyPolicy;
        let k = by_name("gesummv").unwrap();
        let excl = profile_app_opts(
            k.as_ref(),
            20,
            1,
            MetricSet::all(),
            PipelineMode::Inline,
            TrafficOpts::with_hierarchy(HierarchyPolicy::Exclusive),
        )
        .unwrap();
        assert_eq!(excl.metrics.traffic.hierarchy_policy, HierarchyPolicy::Exclusive);
        // the default wrapper stays inclusive
        let incl = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(incl.metrics.traffic.hierarchy_policy, HierarchyPolicy::Inclusive);
        // both policies filter the DRAM side: traffic crossing the last
        // level can never exceed the raw per-access line traffic
        for r in [&excl, &incl] {
            let tr = &r.metrics.traffic;
            assert!(tr.dram_fills <= tr.accesses, "fills exceed accesses");
            assert_eq!(tr.dram_fills, tr.llc().unwrap().misses);
        }
    }

    #[test]
    fn mrc_mode_threads_through_the_app_pipeline() {
        use crate::traffic::MrcMode;
        let k = by_name("gesummv").unwrap();
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.5 });
        let sampled =
            profile_app_opts(k.as_ref(), 20, 1, MetricSet::all(), PipelineMode::Inline, opts)
                .unwrap();
        assert_eq!(sampled.metrics.traffic.mrc_mode, MrcMode::Sampled { rate: 0.5 });
        assert!(
            sampled.metrics.traffic.mrc_sampled_accesses < sampled.metrics.traffic.accesses,
            "a 0.5-rate sampler must skip some accesses"
        );
        // the default wrapper stays exact — and exact means every access
        // participates in the stack-distance curve
        let exact = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(exact.metrics.traffic.mrc_mode, MrcMode::Exact);
        assert_eq!(exact.metrics.traffic.mrc_sampled_accesses, exact.metrics.traffic.accesses);
    }

    #[test]
    fn metric_subset_still_simulates() {
        // ilp deliberately NOT selected: profile_app must force it on so
        // the host model simulates with measured ILP, not a zeroed one
        let k = by_name("gesummv").unwrap();
        let sel = MetricSet::from_names("mix").unwrap();
        let r = profile_app_select(k.as_ref(), 16, 1, sel).unwrap();
        assert!(r.metrics.mix.total() > 0);
        assert!(r.metrics.ilp.inf >= 1.0, "ILP must be force-enabled for sims");
        assert!(r.cmp.host.time_s > 0.0 && r.cmp.nmc.time_s > 0.0);
        assert_eq!(r.metrics.mem_entropy.accesses, 0);
    }

    #[test]
    fn tiny_suite_runs_in_order() {
        let rs = run_suite(0.08, 7, 4).unwrap();
        assert_eq!(rs.len(), 12);
        let names: Vec<_> = rs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names[0], "atax");
        assert_eq!(names[11], "kmeans");
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.cmp.edp_improvement() > 0.0, "{}", r.name);
        }
    }
}
