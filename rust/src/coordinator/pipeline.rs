//! The per-app profiling pipeline: run one kernel (or one recorded
//! trace) through a single instrumented execution — the full
//! `AnalyzerStack` plus the task trace in one chunked pass — and both
//! machine models, folding every failure mode into a structured
//! [`AppOutcome`].
//!
//! Rust owns the event loop and process topology (L3 of the
//! architecture); the PJRT artifacts own the batched numeric analytics
//! (L2/L1). Suite-level fan-out lives in [`super::sched`]: the
//! [`Scheduler`](super::sched::Scheduler) runs K apps concurrently
//! (`--jobs`), each driving the per-app pipeline defined here, drawing
//! analysis threads from one process-global
//! [`WorkerBudget`](super::sched::WorkerBudget).
//!
//! With [`PipelineMode::Offload`] an app pairs its interpreter with a
//! dedicated analysis thread (see [`crate::interp::offload`]), so it
//! occupies two cores while it runs; with [`PipelineMode::Sharded`] it
//! adds a broadcaster plus one analyzer worker per planned shard (up to 5
//! with every family enabled, now that the traffic family's MRC and
//! hierarchy halves land on separate workers). The worker budget accounts
//! for exactly that appetite per running job.

use std::fmt;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::{
    delivery_for, profile_run, profile_source_with_tasks, AppMetrics, Delivery, MetricSet,
};
use crate::fault::{PanicError, SuperviseOpts, TimeoutError};
use crate::interp::PipelineMode;
use crate::sim::{self, EdpComparison, Region};
use crate::trace::{TraceProvenance, TraceReader};
use crate::traffic::TrafficOpts;
use crate::util::Json;
use crate::workloads::{by_name, Kernel};

use super::request::{ProfileRequest, RunCtx};
use super::sched::Jobs;

/// Per-application pipeline output.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub name: String,
    pub n: usize,
    pub metrics: AppMetrics,
    pub cmp: EdpComparison,
}

impl AppResult {
    /// Profiler throughput for this app (trace events per wall second) —
    /// surfaced in the pipeline JSON so perf regressions show up in
    /// reports, not just in benches.
    pub fn events_per_sec(&self) -> f64 {
        self.metrics.exec.events_per_sec()
    }

    /// The single-app result object: the full metric JSON plus the
    /// workload size and the host-vs-NMC EDP comparison — what the CLI
    /// `analyze` verb prints and the `serve` daemon streams per job.
    pub fn to_json(&self) -> Json {
        let mut j = self.metrics.to_json();
        j.set("n", self.n);
        j.set("edp", self.cmp.to_json());
        j
    }
}

/// Why one app failed under the supervised pipeline — the structured
/// taxonomy the report's `"failures"` section and the CLI exit code key
/// off, replacing stringly-typed anyhow at the coordinator boundary.
#[derive(Debug, Clone)]
pub enum ProfileError {
    /// The interpreter itself errored (including injected `interp-error`
    /// faults): there is no event stream, nothing is salvageable.
    InterpError { message: String },
    /// A pipeline thread panicked out from under the run before any
    /// degradation could salvage it.
    WorkerPanic { site: &'static str, message: String },
    /// The `--app-timeout` watchdog expired at a chunk boundary.
    Timeout { secs: u64 },
    /// Analyzer shards died but the broadcaster kept the survivors fed:
    /// the listed families are lost, the rest stay bit-identical to a
    /// clean run. The salvaged metrics ride in [`AppFailure::partial`].
    Degraded { failed_families: Vec<String> },
    /// The job never ran: it was still queued when the scheduler aborted
    /// (fail-fast), shut down, or honored an explicit cancellation.
    Cancelled,
}

impl ProfileError {
    /// Stable kind tag for JSON/report consumers.
    pub fn kind(&self) -> &'static str {
        match self {
            ProfileError::InterpError { .. } => "interp-error",
            ProfileError::WorkerPanic { .. } => "worker-panic",
            ProfileError::Timeout { .. } => "timeout",
            ProfileError::Degraded { .. } => "degraded",
            ProfileError::Cancelled => "cancelled",
        }
    }

    /// Degraded apps salvaged their surviving families; every other kind
    /// lost the app entirely. `--on-error continue` exits nonzero only
    /// for the latter.
    pub fn is_hard(&self) -> bool {
        !matches!(self, ProfileError::Degraded { .. })
    }

    /// Classify a profiling error by the typed faults the supervised
    /// pipeline embeds (see [`crate::fault`]).
    pub(crate) fn classify(e: &anyhow::Error) -> ProfileError {
        if let Some(t) = e.downcast_ref::<TimeoutError>() {
            ProfileError::Timeout { secs: t.secs }
        } else if let Some(p) = e.downcast_ref::<PanicError>() {
            ProfileError::WorkerPanic { site: p.site, message: p.message.clone() }
        } else {
            ProfileError::InterpError { message: format!("{e:#}") }
        }
    }
}

impl fmt::Display for ProfileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProfileError::InterpError { message } => write!(f, "interpreter error: {message}"),
            ProfileError::WorkerPanic { site, message } => {
                write!(f, "{site} thread panicked: {message}")
            }
            ProfileError::Timeout { secs } => write!(f, "exceeded --app-timeout {secs}s"),
            ProfileError::Degraded { failed_families } => {
                write!(f, "degraded; failed families: {}", failed_families.join(", "))
            }
            ProfileError::Cancelled => write!(f, "cancelled before running"),
        }
    }
}

/// One failed app under the supervised suite.
#[derive(Debug, Clone)]
pub struct AppFailure {
    pub name: String,
    pub error: ProfileError,
    /// Wall time burned before the failure surfaced.
    pub wall_s: f64,
    /// Salvaged metrics when the run degraded instead of dying outright:
    /// surviving families intact, dead ones listed in
    /// [`AppMetrics::failed`] and stamped `"status": "failed"` in JSON.
    pub partial: Option<AppMetrics>,
}

/// Per-app result of a supervised suite run.
#[derive(Debug, Clone)]
pub enum AppOutcome {
    Ok(Box<AppResult>),
    Failed(Box<AppFailure>),
}

impl AppOutcome {
    pub fn name(&self) -> &str {
        match self {
            AppOutcome::Ok(r) => &r.name,
            AppOutcome::Failed(f) => &f.name,
        }
    }
}

/// Suite failure policy — the CLI `--on-error` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnError {
    /// Abort the whole suite on the first failed app (the legacy
    /// behavior and the default). Jobs still queued when the failure
    /// surfaces are cancelled.
    #[default]
    FailFast,
    /// Profile every app regardless; failures land in the report's
    /// `"failures"` section.
    Continue,
}

impl OnError {
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "fail-fast" => Ok(OnError::FailFast),
            "continue" => Ok(OnError::Continue),
            _ => bail!("unknown --on-error policy '{s}' (expected fail-fast or continue)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OnError::FailFast => "fail-fast",
            OnError::Continue => "continue",
        }
    }
}

/// Suite-level supervision bundle: the per-app fault/watchdog plan plus
/// the failure policy. Defaults reproduce the unsupervised pipeline
/// exactly (no fault armed, no watchdog, fail-fast).
#[derive(Debug, Clone, Copy, Default)]
pub struct SuitePolicy {
    pub sup: SuperviseOpts,
    pub on_error: OnError,
}

/// The delivery one scheduled job drives: `per_event` selects the
/// un-batched reference path, otherwise the job's [`PipelineMode`] maps
/// onto the chunked deliveries.
pub(crate) fn job_delivery(mode: PipelineMode, per_event: bool) -> Delivery {
    if per_event {
        Delivery::PerEvent
    } else {
        delivery_for(mode)
    }
}

/// Profile one kernel with every metric enabled (inline delivery) — the
/// blessed shorthand; every other knob flows through
/// [`ProfileRequest`](super::ProfileRequest).
pub fn profile_app(k: &dyn Kernel, n: usize, seed: u64) -> Result<AppResult> {
    ProfileRequest::app(k, n, seed).run_strict(&RunCtx::new())
}

/// [`profile_app`] restricted to a metric subset.
#[deprecated(note = "build a coordinator::ProfileRequest::app(..).metrics(..) instead")]
pub fn profile_app_select(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
) -> Result<AppResult> {
    ProfileRequest::app(k, n, seed).metrics(metrics).run_strict(&RunCtx::new())
}

/// [`profile_app`] with metric subset and delivery mode knobs.
#[deprecated(note = "build a coordinator::ProfileRequest::app(..).mode(..) instead")]
pub fn profile_app_mode(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<AppResult> {
    ProfileRequest::app(k, n, seed).metrics(metrics).mode(mode).run_strict(&RunCtx::new())
}

/// The fully-parameterized positional single-app entry point. Superseded
/// by [`ProfileRequest`](super::ProfileRequest), which reaches the same
/// engine without growing a positional signature per knob.
#[deprecated(note = "build a coordinator::ProfileRequest::app(..) instead")]
pub fn profile_app_opts(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<AppResult> {
    ProfileRequest::app(k, n, seed)
        .metrics(metrics)
        .mode(mode)
        .traffic(opts)
        .run_strict(&RunCtx::new())
}

/// The strict per-app engine: single instrumented execution feeding the
/// selected analyzers *and* the task-trace collector, then both machine
/// simulations. Any failure (including a degraded run — the machine
/// models need the full task trace) is an `Err`. Sim-required families
/// (ILP — see [`MetricSet::with_simulation_requirements`]) are
/// force-enabled regardless of `metrics`.
pub(crate) fn run_kernel(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
) -> Result<AppResult> {
    let metrics = metrics.with_simulation_requirements();
    let prog = k.build(n, seed);
    let (m, regions) = (|| -> Result<(AppMetrics, Vec<Region>)> {
        let (m, regions) =
            profile_run(&prog, metrics, delivery, opts, SuperviseOpts::default(), true)?;
        if !m.failed.is_empty() {
            bail!("analysis degraded; failed families: {}", m.failed.join(", "));
        }
        Ok((m, regions.expect("task trace enabled")))
    })()
    .with_context(|| format!("running {}", k.info().name))?;
    Ok(simulate(m, n, &regions))
}

/// Run both machine models over the region trace and assemble the final
/// per-app result (shared by the strict and supervised entry points).
fn simulate(metrics: AppMetrics, n: usize, regions: &[Region]) -> AppResult {
    // both machine models consume the same region trace
    let ilp256 = metrics
        .ilp
        .windowed
        .iter()
        .find(|(w, _)| *w == 256)
        .map(|(_, v)| *v)
        .unwrap_or(metrics.ilp.inf);
    let cmp = EdpComparison {
        app: metrics.name.clone(),
        host: sim::simulate_host(regions, ilp256),
        nmc: sim::simulate_nmc(regions),
    };
    AppResult { name: metrics.name.clone(), n, metrics, cmp }
}

/// Replay a recorded `.pallas-trace` through the full per-app pipeline:
/// decode the stream, run the selected analyzers plus the task trace, and
/// both machine models — exactly what a live interpretation of the same
/// workload would produce, event for event. The program is rebuilt from
/// the header's workload identity (app name, `n`, seed) so the task-trace
/// collector and simulators see the recording's loop structure; the event
/// stream itself comes from the file, never the interpreter. Sim-required
/// families are force-enabled like every other pipeline entry point, so a
/// trace recorded with too few lanes fails up front with
/// [`TraceError::MissingLanes`](crate::trace::TraceError) naming the
/// starved families.
pub fn replay_app(
    path: &Path,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<(AppResult, TraceProvenance)> {
    let mut reader = TraceReader::open(path)?;
    let meta = reader.header().meta.clone();
    let n = usize::try_from(meta.n)
        .map_err(|_| anyhow!("trace workload size {} exceeds this platform", meta.n))?;
    let k = by_name(&meta.app).map_err(|_| {
        anyhow!("trace records app '{}' which is not in the workload registry", meta.app)
    })?;
    let metrics = metrics.with_simulation_requirements();
    let prog = k.build(n, meta.seed);
    let (m, regions) = profile_source_with_tasks(&prog, &mut reader, metrics, mode, opts)
        .with_context(|| format!("replaying {}", path.display()))?;
    Ok((simulate(m, n, &regions), reader.provenance()))
}

/// Profile one kernel under a supervision plan (`--inject-fault`,
/// `--app-timeout`): never returns `Err` — every failure mode is folded
/// into a structured [`AppOutcome::Failed`]. Analyzer-shard deaths come
/// back as [`ProfileError::Degraded`] with the salvaged metrics attached;
/// interpreter faults, watchdog expiry and producer panics lose the app.
pub fn profile_app_supervised(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
    sup: SuperviseOpts,
) -> AppOutcome {
    run_kernel_supervised(k, n, seed, metrics, delivery_for(mode), opts, sup)
}

/// The supervised per-app engine every scheduled job lands on (the
/// delivery is already resolved, so the per-event reference arm rides the
/// same path as the chunked modes).
pub(crate) fn run_kernel_supervised(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
    sup: SuperviseOpts,
) -> AppOutcome {
    let start = Instant::now();
    match try_run_kernel_supervised(k, n, seed, metrics, delivery, opts, sup) {
        Ok(outcome) => outcome,
        Err(e) => AppOutcome::Failed(Box::new(AppFailure {
            name: k.info().name.to_string(),
            error: ProfileError::classify(&e),
            wall_s: start.elapsed().as_secs_f64(),
            partial: None,
        })),
    }
}

fn try_run_kernel_supervised(
    k: &dyn Kernel,
    n: usize,
    seed: u64,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
    sup: SuperviseOpts,
) -> Result<AppOutcome> {
    let metrics = metrics.with_simulation_requirements();
    let prog = k.build(n, seed);
    let (m, regions) = profile_run(&prog, metrics, delivery, opts, sup, true)
        .with_context(|| format!("running {}", k.info().name))?;
    let Some(regions) = regions.filter(|_| m.failed.is_empty()) else {
        // degraded: the surviving families are intact, but the machine
        // models need the task trace and the full sim-required set
        let wall_s = m.exec.wall_s;
        return Ok(AppOutcome::Failed(Box::new(AppFailure {
            name: m.name.clone(),
            error: ProfileError::Degraded { failed_families: m.failed.clone() },
            wall_s,
            partial: Some(m),
        })));
    };
    Ok(AppOutcome::Ok(Box::new(simulate(m, n, &regions))))
}

/// Run the whole suite with every metric enabled, inline delivery,
/// `threads` concurrent apps — the blessed shorthand; every other knob
/// flows through [`ProfileRequest`](super::ProfileRequest) or
/// [`PipelineCfg`](super::PipelineCfg).
pub fn run_suite(scale: f64, seed: u64, threads: usize) -> Result<Vec<AppResult>> {
    ProfileRequest::suite(scale, seed).jobs(Jobs::Fixed(threads)).run_apps(&RunCtx::new())
}

/// [`run_suite`] with metric subset and delivery mode knobs.
#[deprecated(note = "build a coordinator::ProfileRequest::suite(..) instead")]
pub fn run_suite_select(
    scale: f64,
    seed: u64,
    threads: usize,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<Vec<AppResult>> {
    ProfileRequest::suite(scale, seed)
        .jobs(Jobs::Fixed(threads))
        .metrics(metrics)
        .mode(mode)
        .run_apps(&RunCtx::new())
}

/// The fully-parameterized positional suite entry point.
#[deprecated(note = "build a coordinator::ProfileRequest::suite(..) instead")]
pub fn run_suite_opts(
    scale: f64,
    seed: u64,
    threads: usize,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<Vec<AppResult>> {
    ProfileRequest::suite(scale, seed)
        .jobs(Jobs::Fixed(threads))
        .metrics(metrics)
        .mode(mode)
        .traffic(opts)
        .run_apps(&RunCtx::new())
}

/// The positional supervised-suite entry point: each app comes back as an
/// [`AppOutcome`] instead of aborting the suite.
#[deprecated(note = "build a coordinator::ProfileRequest::suite(..).policy(..) instead")]
pub fn run_suite_supervised(
    scale: f64,
    seed: u64,
    threads: usize,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
    policy: SuitePolicy,
) -> Result<Vec<AppOutcome>> {
    ProfileRequest::suite(scale, seed)
        .jobs(Jobs::Fixed(threads))
        .metrics(metrics)
        .mode(mode)
        .traffic(opts)
        .policy(policy)
        .outcomes(&RunCtx::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn profile_app_end_to_end() {
        let k = by_name("gesummv").unwrap();
        let r = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(r.name, "gesummv");
        assert!(r.metrics.exec.dyn_instrs > 1000);
        assert!(r.cmp.host.time_s > 0.0 && r.cmp.nmc.time_s > 0.0);
        assert_eq!(r.cmp.host.dyn_instrs, r.cmp.nmc.dyn_instrs);
        assert!(r.events_per_sec() > 0.0, "throughput must be recorded");
        // the result JSON carries the metric sections plus n and EDP
        let s = r.to_json().to_string_compact();
        for key in ["instruction_mix", "\"n\"", "\"edp\"", "events_per_sec"] {
            assert!(s.contains(key), "missing {key}");
        }
    }

    #[test]
    fn profile_app_matches_analysis_profile() {
        // both entry points build the same AnalyzerStack: metrics agree
        let k = by_name("gesummv").unwrap();
        let r = profile_app(k.as_ref(), 16, 1).unwrap();
        let m = crate::analysis::profile(&k.build(16, 1)).unwrap();
        assert_eq!(r.metrics.pca8_features(), m.pca8_features());
        assert_eq!(r.metrics.exec.dyn_instrs, m.exec.dyn_instrs);
    }

    #[test]
    fn offload_app_matches_inline_bit_identically() {
        let k = by_name("gesummv").unwrap();
        let inline = profile_app(k.as_ref(), 20, 1).unwrap();
        let offl = ProfileRequest::app(k.as_ref(), 20, 1)
            .mode(PipelineMode::Offload)
            .run_strict(&RunCtx::new())
            .unwrap();
        assert_eq!(
            inline.metrics.pca8_features().map(f64::to_bits),
            offl.metrics.pca8_features().map(f64::to_bits)
        );
        assert_eq!(inline.metrics.exec.dyn_instrs, offl.metrics.exec.dyn_instrs);
        // the same region trace feeds the machine models on both paths
        assert_eq!(inline.cmp.host.dyn_instrs, offl.cmp.host.dyn_instrs);
        assert_eq!(inline.cmp.edp_improvement(), offl.cmp.edp_improvement());
        assert!(offl.events_per_sec() > 0.0);
    }

    #[test]
    fn tiny_suite_runs_offloaded() {
        let rs = ProfileRequest::suite(0.05, 7)
            .mode(PipelineMode::Offload)
            .jobs(Jobs::Fixed(2))
            .run_apps(&RunCtx::new())
            .unwrap();
        assert_eq!(rs.len(), 12);
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.events_per_sec() > 0.0, "{}", r.name);
        }
    }

    #[test]
    fn sharded_app_matches_inline_bit_identically() {
        use crate::interp::Workers;
        let k = by_name("gesummv").unwrap();
        let inline = profile_app(k.as_ref(), 20, 1).unwrap();
        let sharded = ProfileRequest::app(k.as_ref(), 20, 1)
            .mode(PipelineMode::Sharded { workers: Workers::Fixed(3) })
            .run_strict(&RunCtx::new())
            .unwrap();
        assert_eq!(
            inline.metrics.pca8_features().map(f64::to_bits),
            sharded.metrics.pca8_features().map(f64::to_bits)
        );
        assert_eq!(inline.metrics.traffic, sharded.metrics.traffic);
        assert_eq!(inline.metrics.exec.dyn_instrs, sharded.metrics.exec.dyn_instrs);
        // the same region trace feeds the machine models on both paths
        assert_eq!(inline.cmp.host.dyn_instrs, sharded.cmp.host.dyn_instrs);
        assert_eq!(inline.cmp.edp_improvement(), sharded.cmp.edp_improvement());
        assert!(sharded.events_per_sec() > 0.0);
    }

    #[test]
    fn tiny_suite_runs_sharded() {
        use crate::interp::Workers;
        let rs = ProfileRequest::suite(0.05, 7)
            .mode(PipelineMode::Sharded { workers: Workers::Auto })
            .jobs(Jobs::Fixed(2))
            .run_apps(&RunCtx::new())
            .unwrap();
        assert_eq!(rs.len(), 12);
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.events_per_sec() > 0.0, "{}", r.name);
        }
    }

    #[test]
    fn hierarchy_policy_threads_through_the_app_pipeline() {
        use crate::traffic::HierarchyPolicy;
        let k = by_name("gesummv").unwrap();
        let excl = ProfileRequest::app(k.as_ref(), 20, 1)
            .traffic(TrafficOpts::with_hierarchy(HierarchyPolicy::Exclusive))
            .run_strict(&RunCtx::new())
            .unwrap();
        assert_eq!(excl.metrics.traffic.hierarchy_policy, HierarchyPolicy::Exclusive);
        // the default wrapper stays inclusive
        let incl = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(incl.metrics.traffic.hierarchy_policy, HierarchyPolicy::Inclusive);
        // both policies filter the DRAM side: traffic crossing the last
        // level can never exceed the raw per-access line traffic
        for r in [&excl, &incl] {
            let tr = &r.metrics.traffic;
            assert!(tr.dram_fills <= tr.accesses, "fills exceed accesses");
            assert_eq!(tr.dram_fills, tr.llc().unwrap().misses);
        }
    }

    #[test]
    fn mrc_mode_threads_through_the_app_pipeline() {
        use crate::traffic::MrcMode;
        let k = by_name("gesummv").unwrap();
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.5 });
        let sampled = ProfileRequest::app(k.as_ref(), 20, 1)
            .traffic(opts)
            .run_strict(&RunCtx::new())
            .unwrap();
        assert_eq!(sampled.metrics.traffic.mrc_mode, MrcMode::Sampled { rate: 0.5 });
        assert!(
            sampled.metrics.traffic.mrc_sampled_accesses < sampled.metrics.traffic.accesses,
            "a 0.5-rate sampler must skip some accesses"
        );
        // the default wrapper stays exact — and exact means every access
        // participates in the stack-distance curve
        let exact = profile_app(k.as_ref(), 20, 1).unwrap();
        assert_eq!(exact.metrics.traffic.mrc_mode, MrcMode::Exact);
        assert_eq!(exact.metrics.traffic.mrc_sampled_accesses, exact.metrics.traffic.accesses);
    }

    #[test]
    fn metric_subset_still_simulates() {
        // ilp deliberately NOT selected: the pipeline must force it on so
        // the host model simulates with measured ILP, not a zeroed one
        let k = by_name("gesummv").unwrap();
        let sel = MetricSet::from_names("mix").unwrap();
        let r = ProfileRequest::app(k.as_ref(), 16, 1)
            .metrics(sel)
            .run_strict(&RunCtx::new())
            .unwrap();
        assert!(r.metrics.mix.total() > 0);
        assert!(r.metrics.ilp.inf >= 1.0, "ILP must be force-enabled for sims");
        assert!(r.cmp.host.time_s > 0.0 && r.cmp.nmc.time_s > 0.0);
        assert_eq!(r.metrics.mem_entropy.accesses, 0);
    }

    #[test]
    fn replayed_trace_matches_direct_pipeline() {
        use crate::interp::Machine;
        use crate::trace::{TraceLanes, TraceMeta, TraceWriter};
        let k = by_name("gesummv").unwrap();
        let direct = profile_app(k.as_ref(), 16, 3).unwrap();
        let prog = k.build(16, 3);
        let path = std::env::temp_dir()
            .join(format!("pisa-replay-app-{}.pallas-trace", std::process::id()));
        let mut machine = Machine::new(&prog).unwrap();
        let meta = TraceMeta { app: "gesummv".into(), n: 16, seed: 3 };
        let cap = machine.chunk_capacity();
        let mut w = TraceWriter::create(&path, meta, cap, TraceLanes::ALL).unwrap();
        machine.run(&mut w).unwrap();
        w.finish().unwrap();
        let replayed =
            replay_app(&path, MetricSet::all(), PipelineMode::Inline, TrafficOpts::default());
        let _ = std::fs::remove_file(&path);
        let (r, prov) = replayed.unwrap();
        assert_eq!(prov.app, "gesummv");
        assert_eq!((prov.n, prov.seed), (16, 3));
        assert!(prov.chunks > 0 && prov.events > 0);
        // event-for-event equality: the whole metric JSON matches once the
        // wall clock (the one legitimately run-dependent field) is zeroed
        let mut a = r.metrics.clone();
        let mut b = direct.metrics.clone();
        a.exec.wall_s = 0.0;
        b.exec.wall_s = 0.0;
        assert_eq!(a.to_json().to_string_compact(), b.to_json().to_string_compact());
        // the machine models consumed an identical region trace
        assert_eq!(r.cmp.edp_improvement(), direct.cmp.edp_improvement());
    }

    #[test]
    fn replay_of_unknown_app_names_the_registry() {
        use crate::trace::{TraceLanes, TraceMeta, TraceWriter};
        let path = std::env::temp_dir()
            .join(format!("pisa-replay-unknown-{}.pallas-trace", std::process::id()));
        let meta = TraceMeta { app: "not-a-kernel".into(), n: 8, seed: 1 };
        let mut w = TraceWriter::create(&path, meta, 64, TraceLanes::ALL).unwrap();
        w.finish().unwrap();
        let err = replay_app(&path, MetricSet::all(), PipelineMode::Inline, TrafficOpts::default())
            .unwrap_err();
        let _ = std::fs::remove_file(&path);
        assert!(
            err.to_string().contains("not in the workload registry"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn on_error_policy_parses() {
        assert_eq!(OnError::from_name("fail-fast").unwrap(), OnError::FailFast);
        assert_eq!(OnError::from_name("continue").unwrap(), OnError::Continue);
        assert!(OnError::from_name("ignore").is_err());
        assert_eq!(OnError::default().name(), "fail-fast");
    }

    #[test]
    fn supervised_suite_continues_past_injected_failures() {
        use crate::fault::FaultPlan;
        let policy = SuitePolicy {
            sup: SuperviseOpts::default()
                .with_fault(FaultPlan::from_spec("interp-error@interp").unwrap()),
            on_error: OnError::Continue,
        };
        let outs = ProfileRequest::suite(0.05, 7)
            .jobs(Jobs::Fixed(2))
            .policy(policy)
            .outcomes(&RunCtx::new())
            .unwrap();
        assert_eq!(outs.len(), 12, "continue must still yield every slot");
        for o in &outs {
            match o {
                AppOutcome::Failed(f) => {
                    assert_eq!(f.error.kind(), "interp-error");
                    assert!(f.error.is_hard());
                    assert!(f.partial.is_none());
                }
                AppOutcome::Ok(r) => panic!("{} should have failed", r.name),
            }
        }
        // the same plan under fail-fast aborts the whole suite
        let ff = SuitePolicy { on_error: OnError::FailFast, ..policy };
        let res = ProfileRequest::suite(0.05, 7)
            .jobs(Jobs::Fixed(2))
            .policy(ff)
            .outcomes(&RunCtx::new());
        assert!(res.is_err());
    }

    #[test]
    fn degraded_sharded_app_salvages_surviving_families() {
        use crate::fault::FaultPlan;
        use crate::interp::Workers;
        let k = by_name("gesummv").unwrap();
        let clean = profile_app(k.as_ref(), 20, 1).unwrap();
        let sup =
            SuperviseOpts::default().with_fault(FaultPlan::from_spec("panic@worker:1").unwrap());
        let out = profile_app_supervised(
            k.as_ref(),
            20,
            1,
            MetricSet::all(),
            PipelineMode::Sharded { workers: Workers::Auto },
            TrafficOpts::default(),
            sup,
        );
        assert_eq!(out.name(), "gesummv");
        let AppOutcome::Failed(f) = out else { panic!("expected a degraded failure") };
        assert_eq!(f.error.kind(), "degraded");
        assert!(!f.error.is_hard(), "degraded apps must not hard-fail the process");
        let m = f.partial.as_ref().expect("degraded failure keeps salvaged metrics");
        assert_eq!(m.failed, vec!["mem_entropy", "reuse", "traffic"]);
        // the surviving families are bit-identical to the clean run
        assert_eq!(m.mix.per_op, clean.metrics.mix.per_op);
        assert_eq!(m.bblp.values, clean.metrics.bblp.values);
        assert!(m.to_json().to_string_compact().contains("failed_families"));
    }

    #[test]
    fn tiny_suite_runs_in_order() {
        let rs = run_suite(0.08, 7, 4).unwrap();
        assert_eq!(rs.len(), 12);
        let names: Vec<_> = rs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names[0], "atax");
        assert_eq!(names[11], "kmeans");
        for r in &rs {
            assert!(r.metrics.exec.dyn_instrs > 0, "{}", r.name);
            assert!(r.cmp.edp_improvement() > 0.0, "{}", r.name);
        }
    }
}
