//! Native PCA (standardize → covariance → power iteration with deflation).
//!
//! Mirrors `python/compile/model.py::pca_graph` exactly — same masking, the
//! same sign convention, the same deflation — so the coordinator can (a)
//! run without artifacts and (b) cross-check the PJRT path bit-for-bit-ish
//! (fp32 vs f64 differences only). The AOT artifact remains the primary
//! path in the pipeline.

/// PCA output: scores [n][k], loadings [f][k], eigenvalues [k], evr [k].
#[derive(Debug, Clone)]
pub struct Pca {
    pub scores: Vec<Vec<f64>>,
    pub loadings: Vec<Vec<f64>>,
    pub eigenvalues: Vec<f64>,
    pub explained_variance_ratio: Vec<f64>,
}

const POWER_ITERS: usize = 96;

/// Standardize columns over masked rows; masked-off rows become zero.
fn standardize(x: &[Vec<f64>], mask: &[bool]) -> (Vec<Vec<f64>>, f64) {
    let n = x.len();
    let f = x[0].len();
    let n_eff = mask.iter().filter(|&&m| m).count().max(1) as f64;
    let mut mu = vec![0.0; f];
    for (row, &m) in x.iter().zip(mask) {
        if m {
            for j in 0..f {
                mu[j] += row[j];
            }
        }
    }
    for v in &mut mu {
        *v /= n_eff;
    }
    let mut var = vec![0.0; f];
    for (row, &m) in x.iter().zip(mask) {
        if m {
            for j in 0..f {
                var[j] += (row[j] - mu[j]) * (row[j] - mu[j]);
            }
        }
    }
    let sd: Vec<f64> = var.iter().map(|v| (v / n_eff).sqrt()).collect();
    let mut z = vec![vec![0.0; f]; n];
    for i in 0..n {
        if mask[i] {
            for j in 0..f {
                // near-constant columns standardize to exact zero (see
                // kernels/ref.py for why not an epsilon divisor)
                z[i][j] = if sd[j] > 1e-6 {
                    (x[i][j] - mu[j]) / sd[j]
                } else {
                    0.0
                };
            }
        }
    }
    (z, n_eff)
}

fn matvec(c: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
    c.iter().map(|row| row.iter().zip(v).map(|(a, b)| a * b).sum()).collect()
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Masked PCA with k components.
pub fn pca(x: &[Vec<f64>], mask: &[bool], k: usize) -> Pca {
    assert!(!x.is_empty());
    let n = x.len();
    let f = x[0].len();
    assert_eq!(mask.len(), n);

    let (z, n_eff) = standardize(x, mask);
    // covariance C = Zᵀ Z / (n_eff - 1)
    let denom = (n_eff - 1.0).max(1.0);
    let mut c = vec![vec![0.0; f]; f];
    for row in &z {
        for a in 0..f {
            for b in 0..f {
                c[a][b] += row[a] * row[b];
            }
        }
    }
    for row in &mut c {
        for v in row.iter_mut() {
            *v /= denom;
        }
    }

    let mut eigenvalues = Vec::with_capacity(k);
    let mut loadings = vec![vec![0.0; k]; f];
    for comp in 0..k {
        // deterministic start: ones with a tilt toward axis `comp`
        let mut v: Vec<f64> = (0..f)
            .map(|j| 1.0 + if j == comp { 2.0 } else { 0.0 })
            .collect();
        let nv = norm(&v);
        v.iter_mut().for_each(|x| *x /= nv);
        for _ in 0..POWER_ITERS {
            let w = matvec(&c, &v);
            let nw = norm(&w).max(1e-30);
            v = w.into_iter().map(|x| x / nw).collect();
        }
        let cv = matvec(&c, &v);
        let lam: f64 = v.iter().zip(&cv).map(|(a, b)| a * b).sum();
        // sign convention: max-|.| element positive
        let mut imax = 0;
        for j in 1..f {
            if v[j].abs() > v[imax].abs() {
                imax = j;
            }
        }
        if v[imax] < 0.0 {
            v.iter_mut().for_each(|x| *x = -*x);
        }
        for j in 0..f {
            loadings[j][comp] = v[j];
        }
        eigenvalues.push(lam);
        // Hotelling deflation
        for a in 0..f {
            for b in 0..f {
                c[a][b] -= lam * v[a] * v[b];
            }
        }
    }

    let scores: Vec<Vec<f64>> = z
        .iter()
        .map(|row| {
            (0..k)
                .map(|comp| row.iter().enumerate().map(|(j, &v)| v * loadings[j][comp]).sum())
                .collect()
        })
        .collect();
    let pos_sum: f64 = eigenvalues.iter().map(|&l| l.max(0.0)).sum::<f64>().max(1e-12);
    let evr = eigenvalues.iter().map(|&l| l.max(0.0) / pos_sum).collect();

    Pca { scores, loadings, eigenvalues, explained_variance_ratio: evr }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cluster_data() -> (Vec<Vec<f64>>, Vec<bool>) {
        let mut x = Vec::new();
        for i in 0..12 {
            let (hi, lo) = if i < 6 { (10.0, 1.0) } else { (1.0, 10.0) };
            x.push(vec![
                hi + 0.01 * (i % 3) as f64,
                hi,
                lo,
                lo + 0.01 * (i % 2) as f64,
            ]);
        }
        (x, vec![true; 12])
    }

    #[test]
    fn separates_clusters_on_pc1() {
        let (x, mask) = cluster_data();
        let p = pca(&x, &mask, 2);
        let s0 = p.scores[0][0].signum();
        assert!(p.scores[..6].iter().all(|s| s[0].signum() == s0));
        assert!(p.scores[6..].iter().all(|s| s[0].signum() == -s0));
        assert!(p.explained_variance_ratio[0] > 0.5);
    }

    #[test]
    fn loadings_orthonormal() {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> = (0..20)
            .map(|_| (0..5).map(|_| rng.normal()).collect())
            .collect();
        let p = pca(&x, &vec![true; 20], 2);
        let dot = |a: usize, b: usize| -> f64 {
            (0..5).map(|j| p.loadings[j][a] * p.loadings[j][b]).sum()
        };
        assert!((dot(0, 0) - 1.0).abs() < 1e-6);
        assert!((dot(1, 1) - 1.0).abs() < 1e-6);
        assert!(dot(0, 1).abs() < 1e-4);
    }

    #[test]
    fn eigenvalues_descending_and_scores_variance_matches() {
        let mut rng = Rng::new(5);
        let x: Vec<Vec<f64>> = (0..40)
            .map(|i| {
                let t = i as f64 / 4.0;
                vec![t + 0.1 * rng.normal(), 2.0 * t + 0.1 * rng.normal(), rng.normal()]
            })
            .collect();
        let p = pca(&x, &vec![true; 40], 2);
        assert!(p.eigenvalues[0] >= p.eigenvalues[1]);
        // PC1 score variance ≈ λ1 (up to n vs n-1 normalization)
        let mean: f64 = p.scores.iter().map(|s| s[0]).sum::<f64>() / 40.0;
        let var: f64 = p.scores.iter().map(|s| (s[0] - mean).powi(2)).sum::<f64>() / 39.0;
        assert!((var - p.eigenvalues[0]).abs() / p.eigenvalues[0] < 0.05);
    }

    #[test]
    fn masked_rows_are_inert() {
        let (mut x, _) = cluster_data();
        x.push(vec![1e6, -1e6, 0.0, 42.0]);
        let mut mask = vec![true; 12];
        mask.push(false);
        let p_pad = pca(&x, &mask, 2);
        let p_ref = pca(&x[..12].to_vec(), &vec![true; 12], 2);
        for j in 0..4 {
            for c in 0..2 {
                assert!((p_pad.loadings[j][c] - p_ref.loadings[j][c]).abs() < 1e-9);
            }
        }
        assert!(p_pad.scores[12].iter().all(|&s| s.abs() < 1e-12));
    }
}
