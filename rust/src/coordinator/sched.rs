//! Suite scheduler: run K profiling jobs concurrently under one
//! process-global worker budget.
//!
//! Three pieces, layered:
//!
//! * [`WorkerBudget`] — a counting semaphore sized to the machine (or an
//!   explicit cap). Every job *accounts* its thread appetite against the
//!   budget before running, so `--jobs 4 --workers auto` throttles to the
//!   hardware instead of oversubscribing it. A job whose appetite exceeds
//!   the whole budget (one sharded app on a small machine) accounts the
//!   full budget and still runs with its planned thread set — the budget
//!   bounds *aggregate* concurrency, it never reshapes a single app's
//!   pipeline (which keeps every delivery bit-identical to a solo run).
//! * [`JobSpec`] — one fully-owned profiling job: a registry kernel (name
//!   + size + seed) or a recorded `.pallas-trace`, plus the per-job knobs
//!   (metric families, delivery, traffic options, supervision plan).
//!   Owned and `'static` so jobs can outlive the request that queued them.
//! * [`Scheduler`] — a fixed pool of job workers pulling from a bounded
//!   queue, streaming [`Completion`]s (submission ordinal + outcome) over
//!   a channel in completion order. Batch callers reorder by ordinal into
//!   deterministic suite order; the `serve` daemon forwards them as they
//!   arrive. Every submitted job yields exactly one completion: jobs
//!   cancelled (explicitly, or by a fail-fast abort) complete with
//!   [`ProfileError::Cancelled`].

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::analysis::{MetricSet, ShardPlan};
use crate::fault::{panic_message, SuperviseOpts};
use crate::interp::PipelineMode;
use crate::traffic::TrafficOpts;
use crate::workloads::by_name;

use super::pipeline::{replay_app, AppFailure, AppOutcome, ProfileError};

/// Process-global analysis-thread budget: a counting semaphore every
/// scheduled job draws from before spinning up its pipeline threads.
pub struct WorkerBudget {
    total: usize,
    free: Mutex<usize>,
    cv: Condvar,
}

impl WorkerBudget {
    /// A budget of exactly `total` threads (clamped to at least 1).
    pub fn new(total: usize) -> Arc<Self> {
        let total = total.max(1);
        Arc::new(WorkerBudget { total, free: Mutex::new(total), cv: Condvar::new() })
    }

    /// The default budget: one permit per hardware thread.
    pub fn machine() -> Arc<Self> {
        Self::new(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4))
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Currently unaccounted permits (diagnostic; racy by nature).
    pub fn available(&self) -> usize {
        *self.free.lock().unwrap()
    }

    /// Block until `want` permits (clamped to the budget's total — see the
    /// module doc on overdraft) can be accounted, and take them. The
    /// returned grant releases on drop.
    pub fn acquire(self: &Arc<Self>, want: usize) -> BudgetGrant {
        let accounted = want.clamp(1, self.total);
        let mut free = self.free.lock().unwrap();
        while *free < accounted {
            free = self.cv.wait(free).unwrap();
        }
        *free -= accounted;
        drop(free);
        BudgetGrant { budget: Arc::clone(self), accounted }
    }

    fn release(&self, n: usize) {
        *self.free.lock().unwrap() += n;
        self.cv.notify_all();
    }
}

/// RAII permit bundle from [`WorkerBudget::acquire`]; releases on drop.
pub struct BudgetGrant {
    budget: Arc<WorkerBudget>,
    accounted: usize,
}

impl BudgetGrant {
    /// Permits this grant accounts against the budget.
    pub fn accounted(&self) -> usize {
        self.accounted
    }
}

impl Drop for BudgetGrant {
    fn drop(&mut self) {
        self.budget.release(self.accounted);
    }
}

/// Suite-level concurrency — the CLI `--jobs` flag: how many apps profile
/// at once (each app's own pipeline threads come on top, bounded by the
/// [`WorkerBudget`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Jobs {
    /// One job worker per hardware thread, capped at the job count.
    #[default]
    Auto,
    /// Exactly this many concurrent jobs (clamped to `[1, hw]`).
    Fixed(usize),
}

impl Jobs {
    /// Parse the CLI `--jobs` value: `auto` or a positive integer.
    pub fn from_name(s: &str) -> Result<Self> {
        let s = s.trim();
        if s == "auto" {
            return Ok(Jobs::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Jobs::Fixed(n)),
            _ => bail!("--jobs expects 'auto' or a positive integer, got '{s}'"),
        }
    }

    /// Concrete worker count for a queue of `n_jobs` jobs.
    pub fn resolve(self, n_jobs: usize) -> usize {
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let cap = n_jobs.min(hw).max(1);
        match self {
            Jobs::Auto => cap,
            Jobs::Fixed(n) => n.clamp(1, cap),
        }
    }
}

impl std::fmt::Display for Jobs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Jobs::Auto => write!(f, "auto"),
            Jobs::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// What one scheduled job profiles.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// A registry kernel, by name (the scheduler rebuilds the program —
    /// kernels are stateless, so this is exactly a direct profile).
    Kernel { app: String, n: usize, seed: u64 },
    /// Replay a recorded `.pallas-trace`; the workload identity comes
    /// from the trace header.
    Trace { path: PathBuf },
}

/// One fully-owned profiling job: target plus every per-job knob. The
/// per-request knobs a [`super::ProfileRequest`] carries map 1:1 onto
/// these fields.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Display name for failure reports (successful results carry the
    /// workload's own name).
    pub name: String,
    pub kind: JobKind,
    pub metrics: MetricSet,
    pub mode: PipelineMode,
    pub traffic: TrafficOpts,
    pub sup: SuperviseOpts,
    /// Deliver per-event instead of `mode`'s chunked path — the reference
    /// arm the bit-identity property tests sweep.
    pub per_event: bool,
}

impl JobSpec {
    /// A kernel job with default knobs (all metrics, inline delivery).
    pub fn kernel(app: &str, n: usize, seed: u64) -> Self {
        JobSpec {
            name: app.to_string(),
            kind: JobKind::Kernel { app: app.to_string(), n, seed },
            metrics: MetricSet::all(),
            mode: PipelineMode::Inline,
            traffic: TrafficOpts::default(),
            sup: SuperviseOpts::default(),
            per_event: false,
        }
    }

    /// A trace-replay job with default knobs.
    pub fn trace(path: impl Into<PathBuf>) -> Self {
        let path = path.into();
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        JobSpec {
            name,
            kind: JobKind::Trace { path },
            metrics: MetricSet::all(),
            mode: PipelineMode::Inline,
            traffic: TrafficOpts::default(),
            sup: SuperviseOpts::default(),
            per_event: false,
        }
    }

    /// Threads this job's pipeline occupies while running: the
    /// interpreter, plus the delivery topology's analysis threads.
    fn threads_wanted(&self) -> usize {
        if self.per_event {
            return 1;
        }
        match self.mode {
            PipelineMode::Inline => 1,
            PipelineMode::Offload => 2,
            PipelineMode::Sharded { workers } => {
                // interpreter + broadcaster + one thread per planned shard
                2 + ShardPlan::new(self.metrics.with_simulation_requirements(), workers).workers()
            }
        }
    }
}

/// Run one job against the budget: account its thread appetite, profile,
/// release. Never panics out and never returns `Err` — every failure mode
/// folds into a structured [`AppOutcome::Failed`].
pub(crate) fn run_job(spec: &JobSpec, budget: &Arc<WorkerBudget>) -> AppOutcome {
    let grant = budget.acquire(spec.threads_wanted());
    let start = Instant::now();
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job_inner(spec)));
    drop(grant);
    match out {
        Ok(outcome) => outcome,
        Err(payload) => AppOutcome::Failed(Box::new(AppFailure {
            name: spec.name.clone(),
            error: ProfileError::WorkerPanic {
                site: "scheduler",
                message: panic_message(payload),
            },
            wall_s: start.elapsed().as_secs_f64(),
            partial: None,
        })),
    }
}

fn run_job_inner(spec: &JobSpec) -> AppOutcome {
    match &spec.kind {
        JobKind::Kernel { app, n, seed } => {
            let k = match by_name(app) {
                Ok(k) => k,
                Err(e) => {
                    return AppOutcome::Failed(Box::new(AppFailure {
                        name: spec.name.clone(),
                        error: ProfileError::InterpError { message: format!("{e:#}") },
                        wall_s: 0.0,
                        partial: None,
                    }))
                }
            };
            super::pipeline::run_kernel_supervised(
                k.as_ref(),
                *n,
                *seed,
                spec.metrics,
                super::pipeline::job_delivery(spec.mode, spec.per_event),
                spec.traffic,
                spec.sup,
            )
        }
        JobKind::Trace { path } => {
            let start = Instant::now();
            match replay_app(path, spec.metrics, spec.mode, spec.traffic) {
                Ok((r, _prov)) => AppOutcome::Ok(Box::new(r)),
                Err(e) => AppOutcome::Failed(Box::new(AppFailure {
                    name: spec.name.clone(),
                    error: ProfileError::classify(&e),
                    wall_s: start.elapsed().as_secs_f64(),
                    partial: None,
                })),
            }
        }
    }
}

/// One finished (or cancelled) job: the submission ordinal plus its
/// outcome. Ordinals are assigned by [`Scheduler::submit`] in order, so
/// batch callers can reorder completions deterministically.
pub struct Completion {
    pub seq: u64,
    pub outcome: AppOutcome,
}

/// Why [`Scheduler::submit`] refused a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full — the daemon's backpressure signal.
    QueueFull { cap: usize },
    /// The scheduler is shutting down (aborted or draining).
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => write!(f, "job queue full (capacity {cap})"),
            SubmitError::ShuttingDown => write!(f, "scheduler is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct SchedState {
    queue: VecDeque<(u64, JobSpec)>,
    next_seq: u64,
    /// No more submissions: workers exit once the queue drains.
    draining: bool,
    /// Hard stop: queued jobs are cancelled, workers exit immediately.
    aborted: bool,
}

struct SchedInner {
    state: Mutex<SchedState>,
    cv: Condvar,
    budget: Arc<WorkerBudget>,
    /// Completion sender for out-of-band completions (cancellations);
    /// each worker thread owns its own clone for job results.
    tx: Mutex<Sender<Completion>>,
    queue_cap: usize,
    /// Fail-fast: the first failed job aborts the scheduler, cancelling
    /// everything still queued.
    fail_fast: bool,
}

impl SchedInner {
    /// Cancel every queued job, emitting a [`ProfileError::Cancelled`]
    /// completion for each so submitted == completed always holds.
    fn cancel_queued(&self) {
        let drained: Vec<(u64, JobSpec)> = {
            let mut st = self.state.lock().unwrap();
            st.queue.drain(..).collect()
        };
        let tx = self.tx.lock().unwrap();
        for (seq, spec) in drained {
            let _ = tx.send(Completion {
                seq,
                outcome: AppOutcome::Failed(Box::new(AppFailure {
                    name: spec.name,
                    error: ProfileError::Cancelled,
                    wall_s: 0.0,
                    partial: None,
                })),
            });
        }
    }

    fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
        self.cancel_queued();
    }
}

/// A fixed pool of job workers over a bounded queue. Construction spawns
/// the workers; they stream every job's [`Completion`] into the paired
/// receiver and exit when the scheduler drains (after [`finish`]) or
/// aborts (fail-fast failure, [`abort`], or drop).
///
/// [`finish`]: Scheduler::finish
/// [`abort`]: Scheduler::abort
pub struct Scheduler {
    inner: Arc<SchedInner>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn `workers` job threads drawing on `budget`. `queue_cap` bounds
    /// the submission queue (backpressure); `fail_fast` makes the first
    /// failed job cancel everything still queued.
    pub fn new(
        workers: usize,
        budget: Arc<WorkerBudget>,
        queue_cap: usize,
        fail_fast: bool,
    ) -> (Self, Receiver<Completion>) {
        let (tx, rx) = mpsc::channel::<Completion>();
        let inner = Arc::new(SchedInner {
            state: Mutex::new(SchedState {
                queue: VecDeque::new(),
                next_seq: 0,
                draining: false,
                aborted: false,
            }),
            cv: Condvar::new(),
            budget,
            tx: Mutex::new(tx),
            queue_cap: queue_cap.max(1),
            fail_fast,
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                let tx = inner.tx.lock().unwrap().clone();
                std::thread::spawn(move || worker_loop(&inner, &tx))
            })
            .collect();
        (Scheduler { inner, workers: handles }, rx)
    }

    /// Queue one job; returns its submission ordinal. Fails with
    /// [`SubmitError::QueueFull`] instead of blocking — the caller owns
    /// the backpressure policy (the daemon turns it into a typed reply).
    pub fn submit(&self, spec: JobSpec) -> std::result::Result<u64, SubmitError> {
        let mut st = self.inner.state.lock().unwrap();
        if st.aborted || st.draining {
            return Err(SubmitError::ShuttingDown);
        }
        if st.queue.len() >= self.inner.queue_cap {
            return Err(SubmitError::QueueFull { cap: self.inner.queue_cap });
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.queue.push_back((seq, spec));
        drop(st);
        self.inner.cv.notify_one();
        Ok(seq)
    }

    /// Cancel a still-queued job. Returns `true` (and emits its
    /// [`ProfileError::Cancelled`] completion) when the job had not
    /// started; `false` when it is already running or finished — a
    /// running pipeline is never interrupted mid-app (the watchdog owns
    /// runaway apps).
    pub fn cancel(&self, seq: u64) -> bool {
        let spec = {
            let mut st = self.inner.state.lock().unwrap();
            match st.queue.iter().position(|(s, _)| *s == seq) {
                Some(i) => st.queue.remove(i).map(|(_, spec)| spec),
                None => None,
            }
        };
        match spec {
            Some(spec) => {
                let _ = self.inner.tx.lock().unwrap().send(Completion {
                    seq,
                    outcome: AppOutcome::Failed(Box::new(AppFailure {
                        name: spec.name,
                        error: ProfileError::Cancelled,
                        wall_s: 0.0,
                        partial: None,
                    })),
                });
                true
            }
            None => false,
        }
    }

    /// No further submissions: workers exit once the queue drains.
    pub fn finish(&self) {
        self.inner.state.lock().unwrap().draining = true;
        self.inner.cv.notify_all();
    }

    /// Hard stop: cancel every queued job (each completes with
    /// [`ProfileError::Cancelled`]); running jobs finish normally.
    pub fn abort(&self) {
        self.inner.abort();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.inner.abort();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &SchedInner, tx: &Sender<Completion>) {
    loop {
        let (seq, spec) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.aborted {
                    return;
                }
                if let Some(job) = st.queue.pop_front() {
                    break job;
                }
                if st.draining {
                    return;
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        let outcome = run_job(&spec, &inner.budget);
        if inner.fail_fast && matches!(outcome, AppOutcome::Failed(_)) {
            // cancel the queue *before* reporting the failure, so by the
            // time the batch collector sees it nothing new can start
            inner.abort();
        }
        if tx.send(Completion { seq, outcome }).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_parse_and_resolve() {
        assert_eq!(Jobs::from_name("auto").unwrap(), Jobs::Auto);
        assert_eq!(Jobs::from_name("3").unwrap(), Jobs::Fixed(3));
        assert!(Jobs::from_name("0").is_err());
        assert!(Jobs::from_name("lots").is_err());
        // fixed counts clamp to the job count; everything is at least 1
        assert_eq!(Jobs::Fixed(64).resolve(2), 2);
        assert_eq!(Jobs::Fixed(1).resolve(100), 1);
        assert!(Jobs::Auto.resolve(100) >= 1);
        assert_eq!(Jobs::Auto.resolve(1), 1);
        assert_eq!(Jobs::default(), Jobs::Auto);
        assert_eq!(Jobs::Auto.to_string(), "auto");
        assert_eq!(Jobs::Fixed(4).to_string(), "4");
    }

    #[test]
    fn budget_accounts_and_releases() {
        let b = WorkerBudget::new(4);
        assert_eq!(b.total(), 4);
        let g1 = b.acquire(3);
        assert_eq!(g1.accounted(), 3);
        assert_eq!(b.available(), 1);
        // overdraft: a 10-thread appetite accounts the whole budget
        drop(g1);
        let g2 = b.acquire(10);
        assert_eq!(g2.accounted(), 4);
        assert_eq!(b.available(), 0);
        drop(g2);
        assert_eq!(b.available(), 4);
    }

    #[test]
    fn budget_blocks_until_released() {
        let b = WorkerBudget::new(2);
        let g = b.acquire(2);
        let b2 = Arc::clone(&b);
        let t = std::thread::spawn(move || {
            let g = b2.acquire(1);
            g.accounted()
        });
        // the second acquire must be parked until the grant releases
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!t.is_finished(), "acquire must block while the budget is exhausted");
        drop(g);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn scheduler_runs_jobs_and_orders_by_seq() {
        let (sched, rx) = Scheduler::new(2, WorkerBudget::machine(), 8, false);
        for (app, n) in [("gesummv", 16), ("atax", 16)] {
            sched.submit(JobSpec::kernel(app, n, 1)).unwrap();
        }
        sched.finish();
        let mut done: Vec<(u64, String)> = rx
            .iter()
            .take(2)
            .map(|c| (c.seq, c.outcome.name().to_string()))
            .collect();
        done.sort();
        assert_eq!(done[0], (0, "gesummv".to_string()));
        assert_eq!(done[1], (1, "atax".to_string()));
    }

    #[test]
    fn queue_cap_rejects_with_backpressure() {
        // a 1-worker scheduler with a tiny queue: fill it without letting
        // anything drain by never finishing submit before checking
        let (sched, _rx) = Scheduler::new(1, WorkerBudget::new(1), 1, false);
        // first job may be picked up immediately; flood until one sticks
        // in the queue, then the next must bounce
        let mut rejected = false;
        for _ in 0..64 {
            match sched.submit(JobSpec::kernel("gesummv", 8, 1)) {
                Ok(_) => {}
                Err(SubmitError::QueueFull { cap }) => {
                    assert_eq!(cap, 1);
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(rejected, "a capacity-1 queue must eventually reject");
    }

    #[test]
    fn cancel_removes_queued_jobs_only() {
        let (sched, rx) = Scheduler::new(1, WorkerBudget::new(1), 8, false);
        let a = sched.submit(JobSpec::kernel("gesummv", 16, 1)).unwrap();
        let b = sched.submit(JobSpec::kernel("atax", 16, 1)).unwrap();
        let c = sched.submit(JobSpec::kernel("bicg", 16, 1)).unwrap();
        // cancel the tail jobs while the head (likely) runs
        assert!(sched.cancel(c), "queued job must cancel");
        assert!(!sched.cancel(c), "double-cancel must report false");
        let _ = b;
        sched.finish();
        let mut outcomes: Vec<(u64, &'static str)> = rx
            .iter()
            .take(3)
            .map(|cmp| {
                let kind = match &cmp.outcome {
                    AppOutcome::Ok(_) => "ok",
                    AppOutcome::Failed(f) => f.error.kind(),
                };
                (cmp.seq, kind)
            })
            .collect();
        outcomes.sort();
        assert_eq!(outcomes.iter().find(|(s, _)| *s == c).unwrap().1, "cancelled");
        assert_eq!(outcomes.iter().find(|(s, _)| *s == a).unwrap().1, "ok");
    }

    #[test]
    fn submit_after_finish_is_refused() {
        let (sched, rx) = Scheduler::new(1, WorkerBudget::new(1), 8, false);
        sched.finish();
        assert_eq!(
            sched.submit(JobSpec::kernel("gesummv", 8, 1)),
            Err(SubmitError::ShuttingDown)
        );
        drop(rx);
    }

    #[test]
    fn unknown_kernel_job_fails_structurally() {
        let budget = WorkerBudget::new(1);
        let out = run_job(&JobSpec::kernel("no-such-kernel", 8, 1), &budget);
        let AppOutcome::Failed(f) = out else { panic!("expected failure") };
        assert_eq!(f.error.kind(), "interp-error");
        assert_eq!(f.name, "no-such-kernel");
        assert_eq!(budget.available(), 1, "grant must release on failure");
    }
}
