//! `pisa-nmc` — the leader binary: CLI over the profiling pipeline,
//! figure/table regeneration, single-kernel analysis and oracle validation.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use pisa_nmc::analysis::{AnalyzerStack, MetricSet};
use pisa_nmc::cli::{self, Args};
use pisa_nmc::coordinator::{
    self, figures, AppOutcome, Jobs, OnError, PipelineCfg, ServeCfg, SuitePolicy, WorkerBudget,
};
use pisa_nmc::fault::{FaultPlan, SuperviseOpts};
use pisa_nmc::interp::{
    run_offload, run_sharded, ChunkLanes, Instrument, LaneMask, Machine, PipelineMode, TraceEvent,
    Workers,
};
use pisa_nmc::report::save_json;
use pisa_nmc::runtime::Runtime;
use pisa_nmc::trace::{required_lanes, TraceMeta, TraceWriter};
use pisa_nmc::traffic::{HierarchyConfig, HierarchyPolicy, MrcMode, TrafficOpts};
use pisa_nmc::workloads;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" {
        print!("{}", cli::HELP);
        return;
    }
    match cli::parse(&argv).and_then(run) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn load_runtime(args: &Args) -> Option<Runtime> {
    if args.has("no-pjrt") {
        return None;
    }
    match Runtime::load_default() {
        Ok(rt) => {
            eprintln!("[pjrt] artifacts loaded on {}", rt.platform());
            Some(rt)
        }
        Err(e) => {
            eprintln!("[pjrt] unavailable ({e:#}); using native analytics");
            None
        }
    }
}

/// Parse the `--metrics` analyzer-family selection (default: all).
fn metric_set(args: &Args) -> Result<MetricSet> {
    match args.get("metrics") {
        Some(spec) => MetricSet::from_names(spec),
        None => Ok(MetricSet::all()),
    }
}

/// Parse the `--hierarchy` traffic-replay policy (default: inclusive).
fn hierarchy_policy(args: &Args) -> Result<HierarchyPolicy> {
    match args.get("hierarchy") {
        Some(name) => HierarchyPolicy::from_name(name),
        None => Ok(HierarchyPolicy::default()),
    }
}

/// Parse the `--mrc` stack-distance mode (default: exact).
fn mrc_mode(args: &Args) -> Result<MrcMode> {
    match args.get("mrc") {
        Some(spec) => MrcMode::from_name(spec),
        None => Ok(MrcMode::default()),
    }
}

/// Parse `--hierarchy-spec`: a file path, or the spec JSON itself when
/// the value starts with `{`. The validated config is leaked to
/// `'static` — [`TrafficOpts`] stays `Copy` by carrying a reference, and
/// a CLI run parses exactly one spec for its whole lifetime.
fn hierarchy_spec(args: &Args) -> Result<Option<&'static HierarchyConfig>> {
    let Some(arg) = args.get("hierarchy-spec") else {
        return Ok(None);
    };
    let text = if arg.trim_start().starts_with('{') {
        arg.to_string()
    } else {
        std::fs::read_to_string(arg)
            .with_context(|| format!("--hierarchy-spec: reading {arg}"))?
    };
    let cfg = HierarchyConfig::from_spec_json(&text).map_err(|e| anyhow!("{e}"))?;
    Ok(Some(&*Box::leak(Box::new(cfg))))
}

/// Bundle the traffic-family flags (`--hierarchy`, `--hierarchy-spec`,
/// `--mrc`, `--mrc-smax`).
fn traffic_opts(args: &Args) -> Result<TrafficOpts> {
    let mrc = mrc_mode(args)?;
    let smax = match args.get("mrc-smax") {
        None => None,
        Some(_) => {
            let s = args.get_usize("mrc-smax", 0)?;
            if s == 0 {
                bail!("--mrc-smax must be at least 1");
            }
            if !matches!(mrc, MrcMode::Sampled { .. }) {
                bail!("--mrc-smax applies only to --mrc sampled (got '{}')", mrc.name());
            }
            Some(s)
        }
    };
    Ok(TrafficOpts::with_hierarchy(hierarchy_policy(args)?)
        .with_mrc(mrc)
        .with_mrc_smax(smax)
        .with_spec(hierarchy_spec(args)?))
}

/// Parse the supervision flags (`--inject-fault`, `--app-timeout`).
fn supervise_opts(args: &Args) -> Result<SuperviseOpts> {
    let fault = match args.get("inject-fault") {
        Some(spec) => FaultPlan::from_spec(spec)?,
        None => FaultPlan::none(),
    };
    let timeout = match args.get("app-timeout") {
        Some(_) => Some(args.get_u64("app-timeout", 0)?),
        None => None,
    };
    Ok(SuperviseOpts::default().with_fault(fault).with_timeout_s(timeout))
}

/// Parse the `--on-error` suite policy (default: fail-fast) together
/// with the supervision flags.
fn suite_policy(args: &Args) -> Result<SuitePolicy> {
    let on_error = match args.get("on-error") {
        Some(name) => OnError::from_name(name)?,
        None => OnError::default(),
    };
    Ok(SuitePolicy { sup: supervise_opts(args)?, on_error })
}

/// Parse the `--jobs` suite concurrency (default: auto). `--threads N`
/// is the deprecated spelling of `--jobs N` and keeps working.
fn jobs_flag(args: &Args) -> Result<Jobs> {
    match (args.get("jobs"), args.get("threads")) {
        (Some(s), _) => Jobs::from_name(s),
        (None, Some(_)) => Ok(Jobs::Fixed(args.get_usize("threads", 8)?)),
        (None, None) => Ok(Jobs::Auto),
    }
}

/// Parse the `--pipeline` event-delivery mode (default: inline) and, for
/// the sharded mode, the `--workers` pool size (default: auto).
fn pipeline_mode(args: &Args) -> Result<PipelineMode> {
    let mode = match args.get("pipeline") {
        Some(name) => PipelineMode::from_name(name)?,
        None => PipelineMode::Inline,
    };
    match (args.get("workers"), mode) {
        (None, mode) => Ok(mode),
        (Some(w), PipelineMode::Sharded { .. }) => {
            Ok(PipelineMode::Sharded { workers: Workers::from_name(w)? })
        }
        (Some(_), mode) => {
            bail!("--workers applies only to --pipeline sharded (got '{}')", mode.name())
        }
    }
}

/// Record-mode sink: fans one event stream into the analyzer stack and the
/// trace writer. Unlike [`Fanout`](pisa_nmc::interp::Fanout), which erases its
/// sinks to `&mut dyn Instrument` and so cannot cross threads, this pair of
/// concrete `Send` sinks is itself `Send` — which the offload pipeline's
/// analysis thread requires.
struct RecordSink<'a> {
    stack: &'a mut AnalyzerStack,
    writer: &'a mut TraceWriter,
}

impl Instrument for RecordSink<'_> {
    fn on_event(&mut self, ev: &TraceEvent) {
        self.stack.on_event(ev);
        self.writer.on_event(ev);
    }

    fn on_chunk(&mut self, events: &[TraceEvent]) {
        self.stack.on_chunk(events);
        self.writer.on_chunk(events);
    }

    fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
        self.stack.on_chunk_lanes(events, lanes);
        self.writer.on_chunk(events);
    }

    fn wants_lanes(&self) -> bool {
        self.stack.wants_lanes()
    }

    fn lane_needs(&self) -> LaneMask {
        self.stack.lane_needs()
    }
}

fn run(args: Args) -> Result<()> {
    cli::validate_trace_flags(&args)?;
    cli::validate_traffic_flags(&args)?;
    match args.command.as_str() {
        "pipeline" => {
            let cfg = PipelineCfg {
                scale: args.get_f64("scale", 1.0)?,
                seed: args.get_u64("seed", 42)?,
                jobs: jobs_flag(&args)?,
                metrics: metric_set(&args)?,
                mode: pipeline_mode(&args)?,
                traffic: traffic_opts(&args)?,
                policy: suite_policy(&args)?,
            };
            let mut report = match args.get("trace") {
                Some(tp) => coordinator::run_replay_cfg(&cfg, Path::new(tp))?,
                None => {
                    let rt = load_runtime(&args);
                    coordinator::run_pipeline_cfg(&cfg, rt.as_ref())?
                }
            };
            if let Some(gridarg) = args.get("sweep") {
                // phase 2 of the DSE advisor: one traffic-only replay per
                // app with the (MRC-pruned) grid riding the chunk lanes
                let grid = coordinator::SweepGrid::load(gridarg)?;
                report.sweep = Some(coordinator::run_sweep(&cfg, &report.apps, &grid)?);
            }
            print!("{}", report.render_all());
            // perf trend line for CI logs: suite-level profiler throughput
            eprintln!(
                "[perf] suite profile rate: {:.2}M events/s ({} pipeline)",
                report.suite_events_per_sec() / 1e6,
                report.mode.name()
            );
            if report.analytics.engine == coordinator::Engine::Pjrt {
                eprintln!(
                    "[pjrt] native cross-check max err: {:.2e}",
                    report.analytics.max_crosscheck_err
                );
            }
            // report (and --out JSON) first, exit status last: under
            // `continue` the salvaged results must land even when the
            // process then signals hard losses with a nonzero exit
            if let Some(out) = args.get("out") {
                save_json(Path::new(out), &report.to_json())?;
                eprintln!("wrote {out}");
            }
            if !report.failures.is_empty() {
                for f in &report.failures {
                    eprintln!("[failure] {}: {} ({:.2}s)", f.name, f.error, f.wall_s);
                }
                if report.has_hard_failures() {
                    bail!(
                        "{} of {} apps failed under --on-error continue",
                        report.failures.iter().filter(|f| f.error.is_hard()).count(),
                        report.apps.len() + report.failures.len()
                    );
                }
                eprintln!(
                    "[degraded] {} app(s) salvaged with failed families marked",
                    report.failures.len()
                );
            }
            Ok(())
        }
        "analyze" => {
            let metrics = metric_set(&args)?;
            let mode = pipeline_mode(&args)?;
            let traffic = traffic_opts(&args)?;
            let (r, prov) = match args.get("trace") {
                Some(tp) => {
                    let (r, prov) = coordinator::replay_app(Path::new(tp), metrics, mode, traffic)?;
                    (r, Some(prov))
                }
                None => {
                    let name = args.require("kernel")?;
                    let k = workloads::by_name(name)?;
                    let n = args.get_usize("n", k.default_n())?;
                    let seed = args.get_u64("seed", 42)?;
                    let sup = supervise_opts(&args)?;
                    let r = match coordinator::profile_app_supervised(
                        k.as_ref(),
                        n,
                        seed,
                        metrics,
                        mode,
                        traffic,
                        sup,
                    ) {
                        AppOutcome::Ok(r) => *r,
                        AppOutcome::Failed(f) => bail!("{}: {}", f.name, f.error),
                    };
                    (r, None)
                }
            };
            if args.has("json") {
                let mut j = r.to_json();
                if let Some(p) = &prov {
                    j.set("trace", p.to_json());
                }
                println!("{}", j.to_string_pretty());
            } else {
                println!("{} (n={})", r.name, r.n);
                if let Some(p) = &prov {
                    println!(
                        "  replayed trace    {} ({} chunks, {} events)",
                        p.path, p.chunks, p.events
                    );
                }
                println!("  dyn instrs        {}", r.metrics.exec.dyn_instrs);
                println!(
                    "  profile rate      {:.2}M events/s ({} pipeline)",
                    r.events_per_sec() / 1e6,
                    mode.name()
                );
                println!(
                    "  mem entropy(1B)   {:.3} bits",
                    r.metrics.mem_entropy.entropies[0]
                );
                println!("  entropy_diff      {:.4}", r.metrics.mem_entropy.entropy_diff);
                println!("  spat_8B_16B       {:.4}", r.metrics.spatial.spat_8b_16b());
                println!("  DLP               {:.2}", r.metrics.dlp.dlp);
                println!("  BBLP_1            {:.2}", r.metrics.bblp.values[0]);
                println!("  PBBLP             {:.1}", r.metrics.pbblp.pbblp);
                println!("  ILP inf           {:.2}", r.metrics.ilp.inf);
                println!("  branch entropy    {:.3}", r.metrics.branch.weighted_entropy());
                if metrics.contains(pisa_nmc::analysis::Metric::Traffic) {
                    let tr = &r.metrics.traffic;
                    println!(
                        "  bytes/instr       {:.3} (read {:.3} / write {:.3})",
                        tr.bytes_per_instr(),
                        tr.read_bytes_per_instr(),
                        tr.write_bytes_per_instr()
                    );
                    println!("  DRAM bytes/instr  {:.3}", tr.dram_bytes_per_instr());
                    let per_level: Vec<String> = tr
                        .levels
                        .iter()
                        .map(|l| format!("{} MR {:.3}", l.name, l.miss_ratio()))
                        .collect();
                    println!(
                        "  hierarchy         {} ({})",
                        tr.hierarchy_policy.name(),
                        per_level.join(", ")
                    );
                    println!(
                        "  MRC mode          {} ({} of {} accesses sampled)",
                        tr.mrc_mode.describe(),
                        tr.mrc_sampled_accesses,
                        tr.accesses
                    );
                    println!(
                        "  MRC knee          {}",
                        match tr.mrc_knee_bytes {
                            Some(b) => pisa_nmc::traffic::capacity_label(b),
                            None => "– (flat curve)".into(),
                        }
                    );
                }
                println!("  EDP improvement   {:.3}x", r.cmp.edp_improvement());
                println!("  speedup           {:.3}x", r.cmp.speedup());
                println!("  NMC suitable      {}", r.cmp.nmc_suitable());
            }
            Ok(())
        }
        "serve" => {
            let addr = args.require("listen")?;
            let cfg = ServeCfg {
                jobs: jobs_flag(&args)?,
                queue_cap: args.get_usize("queue-cap", 16)?,
                metrics: metric_set(&args)?,
                mode: pipeline_mode(&args)?,
                traffic: traffic_opts(&args)?,
                sup: supervise_opts(&args)?,
            };
            coordinator::install_sigterm_handler();
            let server = coordinator::Server::bind(addr, cfg, WorkerBudget::machine())?;
            eprintln!(
                "[serve] listening on {} ({} jobs, queue cap {})",
                server.local_addr()?,
                cfg.jobs,
                cfg.queue_cap
            );
            server.run()?;
            eprintln!("[serve] drained and shut down");
            Ok(())
        }
        "record" => {
            let out_path = args.require("record-out")?;
            let name = args.require("kernel")?;
            let k = workloads::by_name(name)?;
            let n = args.get_usize("n", k.default_n())?;
            let seed = args.get_u64("seed", 42)?;
            let metrics = metric_set(&args)?;
            let mode = pipeline_mode(&args)?;
            let traffic = traffic_opts(&args)?;
            let prog = k.build(n, seed);
            let mut machine = Machine::new(&prog)?;
            // Lanes follow the *selected* metric families: a mix-only
            // recording is smaller but only replays mix-only analyses —
            // the replay planner rejects anything wider with MissingLanes.
            let lanes = required_lanes(metrics);
            let meta = TraceMeta { app: name.to_string(), n: n as u64, seed };
            let mut writer =
                TraceWriter::create(Path::new(out_path), meta, machine.chunk_capacity(), lanes)?;
            let mut stack = AnalyzerStack::new_opts(&prog, metrics, traffic);
            let t0 = std::time::Instant::now();
            let outcome = match mode {
                PipelineMode::Sharded { .. } => {
                    // analyzer and writer each ride the broadcast as a shard
                    let mut shards: [&mut (dyn Instrument + Send); 2] = [&mut stack, &mut writer];
                    run_sharded(&mut machine, &mut shards)?
                }
                PipelineMode::Offload => {
                    let mut sink = RecordSink { stack: &mut stack, writer: &mut writer };
                    run_offload(&mut machine, &mut sink)?
                }
                PipelineMode::Inline => {
                    let mut sink = RecordSink { stack: &mut stack, writer: &mut writer };
                    machine.run(&mut sink)?
                }
            };
            writer.finish()?;
            let prov = writer.provenance(Path::new(out_path));
            let mut stats = outcome.stats;
            stats.wall_s = t0.elapsed().as_secs_f64();
            let (m, _) = stack.finalize(stats);
            if args.has("json") {
                let mut j = m.to_json();
                j.set("trace", prov.to_json());
                println!("{}", j.to_string_pretty());
            } else {
                println!("recorded {name} (n={n}, seed={seed}) -> {out_path}");
                println!("  events     {}", prov.events);
                println!("  chunks     {} (capacity {})", prov.chunks, prov.chunk_capacity);
                println!("  lanes      {}", prov.lanes);
                println!("  dyn instrs {}", m.exec.dyn_instrs);
            }
            eprintln!("wrote {out_path}");
            Ok(())
        }
        "figure" => {
            let which = args.positional1()?.to_string();
            let cfg = PipelineCfg {
                scale: args.get_f64("scale", 1.0)?,
                seed: args.get_u64("seed", 42)?,
                jobs: jobs_flag(&args)?,
                metrics: metric_set(&args)?,
                mode: pipeline_mode(&args)?,
                traffic: traffic_opts(&args)?,
                policy: SuitePolicy::default(),
            };
            let rt = load_runtime(&args);
            let report = coordinator::run_pipeline_cfg(&cfg, rt.as_ref())?;
            let (text, _json) = match which.as_str() {
                "3a" => figures::fig3a(&report.apps, &report.analytics, report.metrics),
                "3b" => figures::fig3b(&report.apps, &report.analytics, report.metrics),
                "3c" => figures::fig3c(&report.apps, report.metrics),
                "4" => figures::fig4(&report.apps),
                "5" => figures::fig5(&report.apps, &report.analytics, report.metrics),
                "6" => figures::fig6(&report.apps, &report.analytics, report.metrics),
                "mrc" => figures::fig_mrc(&report.apps, report.metrics),
                "sweep" => {
                    let gridarg = args
                        .get("sweep")
                        .ok_or_else(|| anyhow!("figure sweep requires --sweep GRIDFILE"))?;
                    let grid = coordinator::SweepGrid::load(gridarg)?;
                    let sw = coordinator::run_sweep(&cfg, &report.apps, &grid)?;
                    figures::fig_sweep(&sw)
                }
                other => bail!("unknown figure '{other}' (3a|3b|3c|4|5|6|mrc|sweep)"),
            };
            print!("{text}");
            Ok(())
        }
        "table" => {
            match args.positional1()? {
                "1" => print!("{}", figures::table1()),
                "2" => print!("{}", figures::table2(args.get_f64("scale", 1.0)?)),
                other => bail!("unknown table '{other}' (1|2)"),
            }
            Ok(())
        }
        "validate" => {
            let n = args.get_usize("n", 16)?;
            let mut failed = 0;
            for k in workloads::registry() {
                let info = k.info();
                match k.validate(n, 42) {
                    Ok(err) if err < 1e-9 => {
                        println!("  ok    {:<12} max err {err:.2e}", info.name)
                    }
                    Ok(err) => {
                        println!("  FAIL  {:<12} max err {err:.2e}", info.name);
                        failed += 1;
                    }
                    Err(e) => {
                        println!("  FAIL  {:<12} {e:#}", info.name);
                        failed += 1;
                    }
                }
            }
            if failed > 0 {
                bail!("{failed} kernels failed validation");
            }
            Ok(())
        }
        "ir" => {
            let name = args.require("kernel")?;
            let k = workloads::by_name(name)?;
            let n = args.get_usize("n", 8)?;
            let prog = k.build(n, args.get_u64("seed", 42)?);
            print!("{}", pisa_nmc::ir::print::print_program(&prog));
            Ok(())
        }
        other => bail!("unknown command '{other}'; try `pisa-nmc help`"),
    }
}
