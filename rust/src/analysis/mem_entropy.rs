//! Memory-entropy analyzer (paper §II-A, Fig 3a and Fig 5).
//!
//! Shannon entropy of the accessed-address distribution at granularities
//! `addr >> g` for g = 0..=10 (byte up to 1 KiB granules). The stream is
//! counted once at byte granularity; coarser granularities are *folded*
//! exactly at finalization (counts at g+1 are sums of child buckets at g),
//! so the per-access hot path is a single hash update.
//!
//! For the AOT entropy artifact the exact per-address count multiset is
//! compressed to count-of-counts form — see `python/compile/kernels/
//! entropy.py`: entropy depends only on the multiset of counts, so (count
//! value, multiplicity) pairs reproduce the exact entropy with a fixed
//! [G, B] shape. If an application has more than B distinct count values
//! (rare: counts are heavily repeated), adjacent values are merged
//! weight-proportionally and the introduced error is bounded and recorded.

use crate::util::FastMap;

use crate::interp::{ChunkLanes, Instrument, LaneMask, TraceEvent};
use crate::util::stats::shannon_entropy_counts;
use crate::util::Json;

/// Granularity shifts analyzed (2^0 .. 2^10 bytes).
pub const SHIFTS: std::ops::RangeInclusive<u8> = 0..=10;
pub const N_GRANULARITIES: usize = 11;

/// Streaming byte-granularity address counter.
#[derive(Debug, Clone, Default)]
pub struct MemEntropyAnalyzer {
    counts: FastMap<u64, u32>,
    accesses: u64,
}

/// Finalized entropy results.
#[derive(Debug, Clone)]
pub struct MemEntropyResult {
    /// Shannon entropy in bits per granularity (index = shift, fine→coarse).
    pub entropies: Vec<f64>,
    /// Paper Fig-5 metric: mean consecutive entropy drop.
    pub entropy_diff: f64,
    /// Count-of-counts per granularity: (count value, multiplicity) pairs.
    pub count_of_counts: Vec<Vec<(u32, u64)>>,
    /// Total dynamic accesses.
    pub accesses: u64,
    /// Distinct byte addresses touched (memory footprint proxy).
    pub unique_addrs: u64,
    /// True if any granularity needed lossy merging to fit `max_slots`.
    pub merged: bool,
}

impl MemEntropyAnalyzer {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&mut self, addr: u64) {
        *self.counts.entry(addr).or_insert(0) += 1;
        self.accesses += 1;
    }

    /// Fold byte-granularity counts to all granularities and compute exact
    /// entropies + the count-of-counts compression (`max_slots` = the AOT
    /// artifact's B dimension).
    pub fn finalize(&self, max_slots: usize) -> MemEntropyResult {
        let mut entropies = Vec::with_capacity(N_GRANULARITIES);
        let mut coc = Vec::with_capacity(N_GRANULARITIES);
        let mut merged = false;

        let mut cur: FastMap<u64, u64> =
            self.counts.iter().map(|(&a, &c)| (a, c as u64)).collect();
        for shift in SHIFTS {
            if shift > 0 {
                let mut next: FastMap<u64, u64> =
                    FastMap::with_capacity_and_hasher(cur.len() / 2 + 1, Default::default());
                for (&a, &c) in &cur {
                    *next.entry(a >> 1).or_insert(0) += c;
                }
                cur = next;
            }
            entropies.push(shannon_entropy_counts(cur.values().copied()));

            // count-of-counts
            let mut multiset: FastMap<u64, u64> = FastMap::default();
            for &c in cur.values() {
                *multiset.entry(c).or_insert(0) += 1;
            }
            let mut pairs: Vec<(u32, u64)> = multiset
                .into_iter()
                .map(|(c, m)| (c.min(u32::MAX as u64) as u32, m))
                .collect();
            pairs.sort_by_key(|&(c, _)| c);
            if pairs.len() > max_slots {
                merged = true;
                pairs = merge_pairs(pairs, max_slots);
            }
            coc.push(pairs);
        }

        let diffs: Vec<f64> = entropies.windows(2).map(|w| w[0] - w[1]).collect();
        let entropy_diff = if diffs.is_empty() {
            0.0
        } else {
            diffs.iter().sum::<f64>() / diffs.len() as f64
        };

        MemEntropyResult {
            entropies,
            entropy_diff,
            count_of_counts: coc,
            accesses: self.accesses,
            unique_addrs: self.counts.len() as u64,
            merged,
        }
    }

    pub fn accesses(&self) -> u64 {
        self.accesses
    }
}

/// Merge sorted (count, mult) pairs down to `target` slots by combining
/// adjacent count values into their weighted mean. Total mass (Σ c·m) and
/// total multiplicity are preserved; the entropy error is O(Δc/c) per merge
/// and merges only happen between adjacent (≈equal) counts.
fn merge_pairs(mut pairs: Vec<(u32, u64)>, target: usize) -> Vec<(u32, u64)> {
    while pairs.len() > target {
        // halve by merging adjacent pairs
        let mut out = Vec::with_capacity(pairs.len() / 2 + 1);
        let mut it = pairs.chunks_exact(2);
        for ch in &mut it {
            let (c0, m0) = ch[0];
            let (c1, m1) = ch[1];
            let mass = c0 as u64 * m0 + c1 as u64 * m1;
            let m = m0 + m1;
            out.push((((mass + m / 2) / m).max(1) as u32, m));
        }
        if let [last] = it.remainder() {
            out.push(*last);
        }
        pairs = out;
    }
    pairs
}

impl Instrument for MemEntropyAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            if let Some(m) = i.mem {
                self.record(m.addr);
            }
        }
    }

    /// Lane path (the hot path): sweep the chunk's dense packed-address
    /// lane — no enum unpacking per event. Consecutive accesses to the same
    /// byte address (scalar accumulators, repeated flag stores) are
    /// run-length folded so the hash map sees one probe per run, and the
    /// access counter accumulates once per chunk.
    fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
        let addrs = lanes.addrs();
        self.accesses += addrs.len() as u64;
        let mut i = 0;
        while i < addrs.len() {
            let a = addrs[i];
            let mut j = i + 1;
            while j < addrs.len() && addrs[j] == a {
                j += 1;
            }
            *self.counts.entry(a).or_insert(0) += (j - i) as u32;
            i = j;
        }
    }

    fn wants_lanes(&self) -> bool {
        true
    }

    fn lane_needs(&self) -> LaneMask {
        LaneMask::ADDRS
    }
}

impl MemEntropyResult {
    /// Pack count-of-counts into the fixed [G, B] fp32 matrices the entropy
    /// artifact expects (rows beyond `N_GRANULARITIES` stay zero).
    pub fn to_artifact_inputs(&self, g_rows: usize, b_slots: usize) -> (Vec<f32>, Vec<f32>) {
        let mut counts = vec![0f32; g_rows * b_slots];
        let mut weights = vec![0f32; g_rows * b_slots];
        for (g, pairs) in self.count_of_counts.iter().enumerate().take(g_rows) {
            for (s, &(c, m)) in pairs.iter().enumerate().take(b_slots) {
                counts[g * b_slots + s] = c as f32;
                weights[g * b_slots + s] = m as f32;
            }
        }
        (counts, weights)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("entropies", self.entropies.clone());
        j.set("entropy_diff", self.entropy_diff);
        j.set("accesses", self.accesses);
        j.set("unique_addrs", self.unique_addrs);
        j.set("merged", self.merged);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::HashMap;

    fn analyze(addrs: &[u64]) -> MemEntropyResult {
        let mut a = MemEntropyAnalyzer::new();
        for &ad in addrs {
            a.record(ad);
        }
        a.finalize(4096)
    }

    /// O(n) oracle: entropy computed from a plain histogram at granularity g.
    fn naive_entropy(addrs: &[u64], shift: u8) -> f64 {
        let mut h: HashMap<u64, u64> = HashMap::new();
        for &a in addrs {
            *h.entry(a >> shift).or_insert(0) += 1;
        }
        shannon_entropy_counts(h.values().copied())
    }

    #[test]
    fn uniform_64_addresses() {
        let addrs: Vec<u64> = (0..64u64).collect();
        let r = analyze(&addrs);
        assert!((r.entropies[0] - 6.0).abs() < 1e-9); // 64 distinct bytes
        assert!((r.entropies[1] - 5.0).abs() < 1e-9); // 32 2B granules
        assert!((r.entropies[6] - 0.0).abs() < 1e-9); // one 64B line
        assert!(r.entropy_diff > 0.0);
    }

    #[test]
    fn matches_naive_fold_random() {
        let mut rng = Rng::new(21);
        let addrs: Vec<u64> = (0..5000).map(|_| 0x1_0000 + rng.below(1 << 14) * 8).collect();
        let r = analyze(&addrs);
        for shift in SHIFTS {
            let want = naive_entropy(&addrs, shift);
            let got = r.entropies[shift as usize];
            assert!(
                (got - want).abs() < 1e-9,
                "shift {shift}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn count_of_counts_preserves_entropy() {
        let mut rng = Rng::new(5);
        let addrs: Vec<u64> = (0..20_000).map(|_| rng.below(3000) * 4).collect();
        let r = analyze(&addrs);
        for (g, pairs) in r.count_of_counts.iter().enumerate() {
            // recompute entropy from the compressed form
            let total: u64 = pairs.iter().map(|&(c, m)| c as u64 * m).sum();
            let h: f64 = -pairs
                .iter()
                .map(|&(c, m)| {
                    let p = c as f64 / total as f64;
                    m as f64 * p * p.log2()
                })
                .sum::<f64>();
            assert!(
                (h - r.entropies[g]).abs() < 1e-9,
                "granularity {g}: {h} vs {}",
                r.entropies[g]
            );
        }
    }

    #[test]
    fn lane_sweep_matches_per_event_records() {
        // mixture of runs and jumps exercises the run-length fold
        let mut rng = Rng::new(11);
        let addrs = crate::testkit::address_trace(&mut rng, 4000, 1 << 12);
        let mut per_event = MemEntropyAnalyzer::new();
        for &a in &addrs {
            per_event.record(a);
        }
        // feed the same trace through the lane path in chunks
        let mut lane = MemEntropyAnalyzer::new();
        let mut lanes = ChunkLanes::default();
        for chunk in addrs.chunks(512) {
            let events: Vec<TraceEvent> = chunk
                .iter()
                .map(|&addr| {
                    TraceEvent::Instr(crate::interp::InstrEvent {
                        op: crate::ir::Op::Load,
                        dst: Some(0),
                        srcs: [0; 3],
                        n_srcs: 1,
                        mem: Some(crate::interp::MemAccess { addr, size: 8, is_store: false }),
                        block: 0,
                    })
                })
                .collect();
            lanes.rebuild(&events);
            lane.on_chunk_lanes(&events, &lanes);
        }
        let (a, b) = (per_event.finalize(4096), lane.finalize(4096));
        assert_eq!(a.accesses, b.accesses);
        assert_eq!(a.unique_addrs, b.unique_addrs);
        for (x, y) in a.entropies.iter().zip(&b.entropies) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.count_of_counts, b.count_of_counts);
    }

    #[test]
    fn single_address_stream_zero_entropy() {
        let r = analyze(&vec![0x4000; 1000]);
        assert!(r.entropies.iter().all(|&h| h == 0.0));
        assert_eq!(r.unique_addrs, 1);
    }

    #[test]
    fn merging_caps_slots_and_stays_close() {
        // force > max_slots distinct count values
        let mut a = MemEntropyAnalyzer::new();
        let mut t = 0u64;
        for addr in 0..300u64 {
            for _ in 0..=addr {
                a.record(addr * 8);
                t += 1;
            }
        }
        assert!(t > 0);
        let r = a.finalize(64);
        assert!(r.merged);
        for pairs in &r.count_of_counts {
            assert!(pairs.len() <= 64);
        }
        // merged entropy from compressed form still close to exact
        let pairs = &r.count_of_counts[0];
        let total: u64 = pairs.iter().map(|&(c, m)| c as u64 * m).sum();
        let h: f64 = -pairs
            .iter()
            .map(|&(c, m)| {
                let p = c as f64 / total as f64;
                m as f64 * p * p.log2()
            })
            .sum::<f64>();
        assert!((h - r.entropies[0]).abs() < 0.05, "{h} vs {}", r.entropies[0]);
    }

    #[test]
    fn artifact_packing_shapes() {
        let addrs: Vec<u64> = (0..256u64).map(|i| i * 8).collect();
        let r = analyze(&addrs);
        let (c, w) = r.to_artifact_inputs(16, 4096);
        assert_eq!(c.len(), 16 * 4096);
        assert_eq!(w.len(), 16 * 4096);
        // row 0: single count value (1) with multiplicity 256
        assert_eq!(c[0], 1.0);
        assert_eq!(w[0], 256.0);
        assert_eq!(c[1], 0.0);
        // rows >= 11 all zero
        assert!(c[11 * 4096..].iter().all(|&v| v == 0.0));
    }
}
