//! The PISA-NMC metric analyzers (paper §II).
//!
//! Every analyzer implements [`crate::interp::Instrument`] and folds the
//! dynamic event stream exactly once (the paper's single-pass instrumented
//! run). Since the chunked-pipeline refactor, the canonical way to compose
//! them is the [`AnalyzerStack`]: one registry owning the full analyzer set
//! (plus, optionally, the `sim::TaskTraceCollector`), receiving events as
//! [`EventChunk`](crate::interp::EventChunk) flushes — one virtual call per
//! chunk, statically-dispatched per-analyzer sweeps inside — and finalizing
//! into one [`AppMetrics`]. The memory-side analyzers (`mix`,
//! `mem_entropy`, `reuse`, `spatial` through `reuse`, and the
//! [`crate::traffic`] subsystem) sweep the chunk's dense SoA
//! [`ChunkLanes`](crate::interp::ChunkLanes) view, built once per chunk —
//! restricted by the stack's per-lane needs-mask to the lanes the enabled
//! families actually read — and shared across them. `analysis::profile`,
//! `coordinator::profile_app` and the examples/benches all drive this one
//! code path; [`MetricSet`] selects a subset by name (the CLI `--metrics`
//! flag ends up here).
//!
//! The stack can fold on the interpreter thread, on one dedicated
//! analysis thread overlapped with interpretation (see
//! [`crate::interp::offload`]), or sharded by metric family across a pool
//! of analyzer workers with every chunk broadcast to all of them (plan
//! and merge in [`shard`], mechanism in
//! [`crate::interp::offload::sharded`]). The delivery, metric subset and
//! traffic knobs are selected on a `coordinator::ProfileRequest`
//! (`ProfileRequest::program(&prog).mode(...).run_metrics(&ctx)`), which
//! lands on the one crate-internal `profile_run` engine; [`profile`] is
//! the all-defaults shorthand and [`profile_per_event`] keeps the
//! un-batched delivery as the reference semantics.
//! `rust/tests/prop_chunked.rs` proves all paths produce bit-identical
//! metrics on seeded random programs.
//!
//! | metric | module | paper figure |
//! |---|---|---|
//! | instruction mix        | [`mix`]         | (baseline) |
//! | branch entropy         | [`branch`]      | (baseline) |
//! | memory entropy         | [`mem_entropy`] | Fig 3a, Fig 5 |
//! | DTR / spatial locality | [`reuse`], [`spatial`] | Fig 3b |
//! | ILP (windowed)         | [`ilp`]         | (baseline) |
//! | DLP                    | [`dlp`]         | Fig 3c |
//! | BBLP (windowed)        | [`bblp`]        | Fig 3c |
//! | PBBLP                  | [`pbblp`]       | Fig 3c |
//! | memory traffic / MRC   | [`crate::traffic`] | (extension: MRC figure) |

pub mod bblp;
pub mod branch;
pub mod dataflow;
pub mod dlp;
pub mod ilp;
pub mod mem_entropy;
pub mod mix;
pub mod pbblp;
pub mod reuse;
pub mod shard;
pub mod spatial;

use anyhow::{bail, Result};

pub use bblp::{BblpAnalyzer, BblpResult};
pub use branch::BranchAnalyzer;
pub use dlp::{DlpAnalyzer, DlpResult};
pub use ilp::{IlpAnalyzer, IlpResult};
pub use mem_entropy::{MemEntropyAnalyzer, MemEntropyResult};
pub use mix::MixAnalyzer;
pub use pbblp::{PbblpAnalyzer, PbblpResult};
pub use reuse::{LineDist, ReuseAnalyzer, ReuseResult, StackDistance};
pub use shard::ShardPlan;
pub use spatial::SpatialResult;

use crate::fault::SuperviseOpts;
use crate::interp::{
    offload, ChunkLanes, ExecStats, Instrument, LaneMask, Machine, PipelineMode, TraceEvent,
    Workers,
};
use crate::ir::Program;
use crate::sim::{Region, TaskTraceCollector};
use crate::trace::{check_lanes, replay_chunked, replay_offload, replay_per_event, TraceSource};
use crate::traffic::{HierarchyPolicy, TrafficAnalyzer, TrafficMetrics, TrafficOpts, TrafficParts};
use crate::util::Json;

/// All §II metrics for one application run (PISA's JSON result object),
/// plus the memory-traffic extension family.
#[derive(Debug, Clone)]
pub struct AppMetrics {
    pub name: String,
    pub mix: MixAnalyzer,
    pub branch: BranchAnalyzer,
    pub mem_entropy: MemEntropyResult,
    pub reuse: ReuseResult,
    pub spatial: SpatialResult,
    pub ilp: IlpResult,
    pub dlp: DlpResult,
    pub bblp: BblpResult,
    pub pbblp: PbblpResult,
    pub traffic: TrafficMetrics,
    pub exec: ExecStats,
    /// Metric families whose analyzer shard died mid-run (supervised
    /// pipelines only — see [`crate::fault`]). Empty on a clean run. A
    /// listed family's result fields hold whatever had been folded before
    /// the failure and must not be trusted; `to_json` marks the matching
    /// sections `"status": "failed"`.
    pub failed: Vec<String>,
}

/// Count-of-counts slots the entropy artifact accepts (see aot.py `B`).
pub const ENTROPY_SLOTS: usize = 4096;

/// One selectable analyzer family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Mix = 0,
    Branch = 1,
    MemEntropy = 2,
    Reuse = 3,
    Ilp = 4,
    Dlp = 5,
    Bblp = 6,
    Pbblp = 7,
    /// The memory-traffic subsystem ([`crate::traffic`]): miss-ratio
    /// curves, the cache-hierarchy replay, byte-traffic accounting.
    Traffic = 8,
}

impl Metric {
    pub const ALL: [Metric; 9] = [
        Metric::Mix,
        Metric::Branch,
        Metric::MemEntropy,
        Metric::Reuse,
        Metric::Ilp,
        Metric::Dlp,
        Metric::Bblp,
        Metric::Pbblp,
        Metric::Traffic,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Metric::Mix => "mix",
            Metric::Branch => "branch",
            Metric::MemEntropy => "mem_entropy",
            Metric::Reuse => "reuse",
            Metric::Ilp => "ilp",
            Metric::Dlp => "dlp",
            Metric::Bblp => "bblp",
            Metric::Pbblp => "pbblp",
            Metric::Traffic => "traffic",
        }
    }
}

/// A subset of the metric families, selectable by name — the value of the
/// CLI `--metrics` flag, threaded through `coordinator::pipeline` into the
/// [`AnalyzerStack`]. Disabled families still appear in [`AppMetrics`] with
/// shape-stable empty results so reports and figures never change layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricSet {
    bits: u16,
}

/// Bit mask with every [`Metric::ALL`] family set.
const ALL_BITS: u16 = (1 << Metric::ALL.len()) - 1;

impl Default for MetricSet {
    fn default() -> Self {
        Self::all()
    }
}

impl MetricSet {
    pub fn all() -> Self {
        MetricSet { bits: ALL_BITS }
    }

    pub fn none() -> Self {
        MetricSet { bits: 0 }
    }

    pub fn with(mut self, m: Metric) -> Self {
        self.bits |= 1 << (m as u16);
        self
    }

    /// The set with family `m` removed (e.g. the bench's
    /// traffic-disabled arm).
    pub fn without(mut self, m: Metric) -> Self {
        self.bits &= !(1 << (m as u16));
        self
    }

    #[inline]
    pub fn contains(&self, m: Metric) -> bool {
        self.bits & (1 << (m as u16)) != 0
    }

    pub fn is_all(&self) -> bool {
        self.bits == ALL_BITS
    }

    /// No family enabled at all.
    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// Every family in either set (shard planning composes group subsets).
    pub fn union(self, other: MetricSet) -> Self {
        MetricSet { bits: self.bits | other.bits }
    }

    /// Number of enabled families.
    pub fn len(&self) -> usize {
        self.bits.count_ones() as usize
    }

    /// Parse a comma-separated selection, e.g. `"mix,dlp,bblp"`. Accepts
    /// `"all"` and the alias `"spatial"` (spatial locality is derived from
    /// `reuse`). Unknown names are an error listing the valid set.
    pub fn from_names(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "all" {
            return Ok(Self::all());
        }
        let mut set = Self::none();
        for raw in spec.split(',') {
            let name = raw.trim();
            let m = match name {
                "spatial" => Metric::Reuse, // spatial scores derive from DTR
                _ => match Metric::ALL.iter().find(|m| m.name() == name) {
                    Some(&m) => m,
                    None => bail!(
                        "unknown metric '{name}'; valid: all, spatial, {}",
                        Metric::ALL.map(|m| m.name()).join(", ")
                    ),
                },
            };
            set = set.with(m);
        }
        Ok(set)
    }

    /// The effective set when the machine simulations will run: forces on
    /// every family the simulators consume (the host model's IPC comes
    /// from measured ILP_256 — simulating with a zeroed ILP would clamp
    /// the host to its floor IPC and distort every EDP number). Both the
    /// coordinator's app pipeline and the pipeline report derive from
    /// this one place so they cannot desync.
    pub fn with_simulation_requirements(self) -> Self {
        self.with(Metric::Ilp)
    }

    /// Names of the enabled families, in canonical order.
    pub fn names(&self) -> Vec<&'static str> {
        Metric::ALL
            .iter()
            .filter(|&&m| self.contains(m))
            .map(|&m| m.name())
            .collect()
    }
}

/// The unified analyzer registry: owns every §II analyzer (and, for the
/// coordinator, the task-trace collector), fans each event chunk out to
/// the enabled subset with static per-analyzer dispatch, and finalizes
/// into an [`AppMetrics`]. This replaces the hand-assembled `Fanout`
/// stacks that used to be duplicated across `analysis::profile` and
/// `coordinator::profile_app`.
pub struct AnalyzerStack {
    name: String,
    metrics: MetricSet,
    mix: MixAnalyzer,
    branch: BranchAnalyzer,
    ment: MemEntropyAnalyzer,
    reuse: ReuseAnalyzer,
    ilp: IlpAnalyzer,
    dlp: DlpAnalyzer,
    bblp: BblpAnalyzer,
    pbblp: PbblpAnalyzer,
    /// Allocated only when the family is enabled — the hierarchy replay
    /// is the one analyzer with a non-trivial construction cost (~37k
    /// cache-line slots), so subset runs must not pay for it.
    traffic: Option<TrafficAnalyzer>,
    tasks: Option<TaskTraceCollector>,
    /// Fallback lane scratch for sinks that call `on_chunk` directly (the
    /// `EventChunk` flush path hands pre-built lanes to `on_chunk_lanes`
    /// instead, so this stays empty on the pipeline hot path).
    lanes: ChunkLanes,
}

impl AnalyzerStack {
    /// Build the stack for `prog`, feeding only the selected metric
    /// families (default inclusive hierarchy for the `traffic` family).
    /// Construction is cheap; disabled analyzers simply never receive
    /// events and finalize to empty results.
    pub fn new(prog: &Program, metrics: MetricSet) -> Self {
        Self::new_opts(prog, metrics, TrafficOpts::default())
    }

    /// [`AnalyzerStack::new`] with the traffic hierarchy's replay policy
    /// (default MRC mode) — kept for callers that predate `--mrc`.
    pub fn new_with(prog: &Program, metrics: MetricSet, hierarchy: HierarchyPolicy) -> Self {
        Self::new_opts(prog, metrics, TrafficOpts::with_hierarchy(hierarchy))
    }

    /// [`AnalyzerStack::new`] with the full traffic knob set — the CLI
    /// `--hierarchy` and `--mrc` flags end up here on every delivery path
    /// (including each sharded worker's per-shard stack).
    pub fn new_opts(prog: &Program, metrics: MetricSet, opts: TrafficOpts) -> Self {
        Self::new_parts(prog, metrics, opts, TrafficParts::ALL)
    }

    /// [`AnalyzerStack::new_opts`] restricted to the given traffic halves
    /// — how a shard plan hands one worker only the MRC fold and another
    /// only the hierarchy replay (see [`shard`]). No-op unless the
    /// `traffic` family is enabled.
    pub(crate) fn new_parts(
        prog: &Program,
        metrics: MetricSet,
        opts: TrafficOpts,
        parts: TrafficParts,
    ) -> Self {
        let n_regs = prog.func.n_regs;
        AnalyzerStack {
            name: prog.func.name.clone(),
            metrics,
            mix: MixAnalyzer::new(),
            branch: BranchAnalyzer::new(),
            ment: MemEntropyAnalyzer::new(),
            reuse: ReuseAnalyzer::new(),
            ilp: IlpAnalyzer::new(n_regs),
            dlp: DlpAnalyzer::for_program(prog),
            bblp: BblpAnalyzer::new(n_regs),
            pbblp: PbblpAnalyzer::new(prog),
            traffic: (metrics.contains(Metric::Traffic) && !parts.is_empty())
                .then(|| TrafficAnalyzer::with_opts_parts(opts, parts)),
            tasks: None,
            lanes: ChunkLanes::default(),
        }
    }

    /// Full stack, every metric enabled.
    pub fn full(prog: &Program) -> Self {
        Self::new(prog, MetricSet::all())
    }

    /// Additionally collect the region/task trace both machine models
    /// consume (used by `coordinator::profile_app`).
    ///
    /// Invariant: `prog` must be the same program this stack was built
    /// from — the collector's loop/region structure comes from
    /// `prog.loops`, and a mismatched program would silently produce a
    /// task trace for the wrong control structure.
    pub fn with_task_trace(mut self, prog: &Program) -> Self {
        self.tasks = Some(TaskTraceCollector::new(prog));
        self
    }

    /// Consume the stack: finalize every analyzer into one [`AppMetrics`]
    /// and, when task tracing was enabled, the region trace.
    pub fn finalize(self, exec: ExecStats) -> (AppMetrics, Option<Vec<Region>>) {
        let mem_entropy = self.ment.finalize(ENTROPY_SLOTS);
        let reuse = self.reuse.finalize();
        let spatial = spatial::from_reuse(&reuse);
        let traffic = match self.traffic {
            Some(t) => t.finalize(exec.dyn_instrs),
            None => TrafficMetrics::default(),
        };
        let mut bblp = self.bblp;
        let mut pbblp = self.pbblp;
        let metrics = AppMetrics {
            name: self.name,
            mix: self.mix,
            branch: self.branch,
            mem_entropy,
            reuse,
            spatial,
            ilp: self.ilp.finalize(),
            dlp: self.dlp.finalize(),
            bblp: bblp.finalize(),
            pbblp: pbblp.finalize(),
            traffic,
            exec,
            failed: Vec::new(),
        };
        let regions = self.tasks.map(|t| t.finalize());
        (metrics, regions)
    }
}

impl Instrument for AnalyzerStack {
    fn on_event(&mut self, ev: &TraceEvent) {
        let m = self.metrics;
        if m.contains(Metric::Mix) {
            self.mix.on_event(ev);
        }
        if m.contains(Metric::Branch) {
            self.branch.on_event(ev);
        }
        if m.contains(Metric::MemEntropy) {
            self.ment.on_event(ev);
        }
        if m.contains(Metric::Reuse) {
            self.reuse.on_event(ev);
        }
        if m.contains(Metric::Ilp) {
            self.ilp.on_event(ev);
        }
        if m.contains(Metric::Dlp) {
            self.dlp.on_event(ev);
        }
        if m.contains(Metric::Bblp) {
            self.bblp.on_event(ev);
        }
        if m.contains(Metric::Pbblp) {
            self.pbblp.on_event(ev);
        }
        if let Some(t) = self.traffic.as_mut() {
            t.on_event(ev);
        }
        if let Some(t) = self.tasks.as_mut() {
            t.on_event(ev);
        }
    }

    /// The hot path: the lane-capable analyzers (`mix`, `mem_entropy`,
    /// `reuse` — and `spatial` through `reuse` — plus the `traffic`
    /// subsystem) sweep the shared SoA [`ChunkLanes`] view, built once per
    /// chunk by the `EventChunk` flush; the dependency analyzers sweep the
    /// event slice with their tuned `on_chunk`s. All dispatch here is
    /// static.
    fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
        let m = self.metrics;
        if m.contains(Metric::Mix) {
            self.mix.on_chunk_lanes(events, lanes);
        }
        if m.contains(Metric::Branch) {
            self.branch.on_chunk(events);
        }
        if m.contains(Metric::MemEntropy) {
            self.ment.on_chunk_lanes(events, lanes);
        }
        if m.contains(Metric::Reuse) {
            self.reuse.on_chunk_lanes(events, lanes);
        }
        if m.contains(Metric::Ilp) {
            self.ilp.on_chunk(events);
        }
        if m.contains(Metric::Dlp) {
            self.dlp.on_chunk(events);
        }
        if m.contains(Metric::Bblp) {
            self.bblp.on_chunk(events);
        }
        if m.contains(Metric::Pbblp) {
            self.pbblp.on_chunk(events);
        }
        if let Some(t) = self.traffic.as_mut() {
            t.on_chunk_lanes(events, lanes);
        }
        if let Some(t) = self.tasks.as_mut() {
            t.on_chunk(events);
        }
    }

    /// The stack consumes lanes whenever a lane-capable family is enabled;
    /// `EventChunk::flush_into` skips the lane build otherwise.
    fn wants_lanes(&self) -> bool {
        !self.lane_needs().is_empty()
    }

    /// Per-lane needs-mask derived from the enabled families, so
    /// `ChunkLanes::rebuild_masked` skips unread lanes on subset runs:
    /// tags only for `mix`, addrs for `mem_entropy`/`reuse`/`traffic`.
    /// The traffic mask comes from the analyzer itself — a shard carrying
    /// only the hierarchy replay skips the sizes lane its MRC half would
    /// have needed.
    fn lane_needs(&self) -> LaneMask {
        let m = self.metrics;
        let mut needs = LaneMask::NONE;
        if m.contains(Metric::Mix) {
            needs |= LaneMask::TAGS;
        }
        if m.contains(Metric::MemEntropy) || m.contains(Metric::Reuse) {
            needs |= LaneMask::ADDRS;
        }
        if let Some(t) = self.traffic.as_ref() {
            needs |= t.lane_needs();
        }
        needs
    }

    /// Chunk delivery without caller-built lanes (ad-hoc sinks, benches):
    /// build the lanes into the stack's own scratch and take the same lane
    /// path, so behavior is identical to the pipeline flush.
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        let needs = self.lane_needs();
        if !needs.is_empty() {
            let mut lanes = std::mem::take(&mut self.lanes);
            lanes.rebuild_masked(events, needs);
            self.on_chunk_lanes(events, &lanes);
            self.lanes = lanes;
        } else {
            self.on_chunk_lanes(events, &ChunkLanes::default());
        }
    }
}

/// How `profile_run` delivers events to the analyzers. Crate-internal:
/// public callers pick a delivery through `coordinator::ProfileRequest`
/// (or its [`PipelineMode`] + per-event knobs), never positionally.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Delivery {
    PerEvent,
    Chunked,
    Offload,
    /// Family-sharded across a worker pool (see [`shard`]).
    Sharded(Workers),
}

fn profile_impl(
    prog: &Program,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
) -> Result<AppMetrics> {
    Ok(profile_run(prog, metrics, delivery, opts, SuperviseOpts::default(), false)?.0)
}

/// The one implementation every profiling entry point lands on: run
/// `prog` once with the selected delivery, optionally collecting the
/// region/task trace the machine models consume, and finalize into one
/// [`AppMetrics`]. The sharded delivery builds one stack per planned
/// shard and merges deterministically ([`shard::ShardPlan`]); every other
/// delivery drives a single stack. `opts` selects the traffic family's
/// replay policy and MRC kernel and must reach every path identically —
/// bit-identity across deliveries includes the per-level counters and,
/// in sampled mode, the SHARDS estimates (the sampling hash is
/// deterministic).
pub(crate) fn profile_run(
    prog: &Program,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
    sup: SuperviseOpts,
    with_tasks: bool,
) -> Result<(AppMetrics, Option<Vec<Region>>)> {
    crate::ir::verify::verify_ok(prog);
    if let Delivery::Sharded(workers) = delivery {
        return shard::profile_sharded_run(prog, metrics, workers, opts, sup, with_tasks);
    }
    let mut stack = AnalyzerStack::new_opts(prog, metrics, opts);
    if with_tasks {
        stack = stack.with_task_trace(prog);
    }
    let mut machine = Machine::new(prog)?;
    let mut failed: Vec<String> = Vec::new();
    let out = match delivery {
        Delivery::Chunked => machine.run_supervised(&mut stack, sup)?,
        Delivery::PerEvent => machine.run_per_event(&mut stack)?,
        Delivery::Offload => {
            let run = offload::run_offload_supervised(&mut machine, &mut stack, sup)?;
            if !run.failures.is_empty() {
                // the single offloaded stack owned every enabled family,
                // so its death takes them all down together
                failed = metrics.names().iter().map(|s| s.to_string()).collect();
            }
            run.outcome
        }
        Delivery::Sharded(_) => unreachable!("handled above"),
    };
    let (mut m, regions) = stack.finalize(out.stats);
    let degraded = !failed.is_empty();
    m.failed = failed;
    // A degraded run's task trace lived on the dead analysis thread; a
    // truncated region list would silently mis-shape the simulations, so
    // degradation forfeits the trace entirely.
    Ok((m, if degraded { None } else { regions }))
}

/// Map the CLI-facing [`PipelineMode`] onto the internal delivery enum.
pub(crate) fn delivery_for(mode: PipelineMode) -> Delivery {
    match mode {
        PipelineMode::Inline => Delivery::Chunked,
        PipelineMode::Offload => Delivery::Offload,
        PipelineMode::Sharded { workers } => Delivery::Sharded(workers),
    }
}

/// [`profile_opts`] plus the region/task trace both machine models
/// consume — the `coordinator` entry point, identical metrics on every
/// delivery path.
pub fn profile_with_tasks(
    prog: &Program,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<(AppMetrics, Vec<Region>)> {
    let (m, regions) =
        profile_with_tasks_supervised(prog, metrics, mode, opts, SuperviseOpts::default())?;
    if !m.failed.is_empty() {
        bail!("analysis degraded; failed families: {}", m.failed.join(", "));
    }
    Ok((m, regions.expect("task trace enabled")))
}

/// [`profile_with_tasks`] under a supervision plan (`--inject-fault`,
/// `--app-timeout`): analyzer-thread deaths degrade the run instead of
/// failing it. The returned metrics list the dead families in
/// [`AppMetrics::failed`]; the region trace comes back `None` whenever
/// the run degraded (the collector lived on a dead thread). Interpreter
/// faults and watchdog expiry still return `Err` — there is no partial
/// event stream to salvage.
pub fn profile_with_tasks_supervised(
    prog: &Program,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
    sup: SuperviseOpts,
) -> Result<(AppMetrics, Option<Vec<Region>>)> {
    profile_run(prog, metrics, delivery_for(mode), opts, sup, true)
}

/// Run `prog` once, streaming the trace through every analyzer (chunked
/// delivery — the default fast path).
pub fn profile(prog: &Program) -> Result<AppMetrics> {
    profile_impl(prog, MetricSet::all(), Delivery::Chunked, TrafficOpts::default())
}

/// [`profile`] restricted to a metric subset. Disabled families come back
/// as shape-stable empty results.
#[deprecated(note = "build a coordinator::ProfileRequest::program(..).metrics(..) instead")]
pub fn profile_select(prog: &Program, metrics: MetricSet) -> Result<AppMetrics> {
    profile_impl(prog, metrics, Delivery::Chunked, TrafficOpts::default())
}

/// [`profile`] with the analyzers folding on a dedicated analysis thread,
/// overlapped with interpretation (see [`crate::interp::offload`]).
/// Metrics are bit-identical to [`profile`] and [`profile_per_event`].
#[deprecated(note = "build a coordinator::ProfileRequest::program(..).mode(Offload) instead")]
pub fn profile_offload(prog: &Program) -> Result<AppMetrics> {
    profile_impl(prog, MetricSet::all(), Delivery::Offload, TrafficOpts::default())
}

/// [`profile`] with the analyzers sharded by metric family across an
/// auto-sized worker pool, every chunk broadcast to all of them (see
/// [`shard`] and [`crate::interp::offload::sharded`]). Metrics are
/// bit-identical to every other delivery path.
#[deprecated(note = "build a coordinator::ProfileRequest::program(..).mode(Sharded) instead")]
pub fn profile_sharded(prog: &Program) -> Result<AppMetrics> {
    let delivery = Delivery::Sharded(Workers::Auto);
    profile_impl(prog, MetricSet::all(), delivery, TrafficOpts::default())
}

/// [`profile_select`] with the delivery mode as a knob.
#[deprecated(note = "build a coordinator::ProfileRequest::program(..).mode(..) instead")]
pub fn profile_select_mode(
    prog: &Program,
    metrics: MetricSet,
    mode: PipelineMode,
) -> Result<AppMetrics> {
    profile_impl(prog, metrics, delivery_for(mode), TrafficOpts::default())
}

/// The fully-parameterized positional entry point: metric subset, delivery
/// mode *and* the traffic knobs. Superseded by the builder
/// (`coordinator::ProfileRequest::program(&prog).metrics(..).mode(..)
/// .traffic(..).run_metrics(&ctx)`), which reaches the same one
/// `profile_run` engine without growing a positional signature per knob.
#[deprecated(note = "build a coordinator::ProfileRequest::program(..) instead")]
pub fn profile_opts(
    prog: &Program,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<AppMetrics> {
    profile_impl(prog, metrics, delivery_for(mode), opts)
}

/// Reference path: identical to [`profile`] but with one `on_event` call
/// per trace event instead of chunked delivery. Exists so the
/// chunked-equivalence property test and the dispatch microbenchmarks have
/// an unbatched baseline; not used by the pipeline.
pub fn profile_per_event(prog: &Program) -> Result<AppMetrics> {
    profile_impl(prog, MetricSet::all(), Delivery::PerEvent, TrafficOpts::default())
}

/// [`profile_per_event`] under explicit traffic knobs — the un-batched
/// reference arm for the parameterized equivalence tests (per-event ≡
/// chunked ≡ offload ≡ sharded must hold for both replay policies and
/// both MRC kernels).
#[deprecated(
    note = "build a coordinator::ProfileRequest::program(..).per_event(true) instead"
)]
pub fn profile_per_event_opts(
    prog: &Program,
    metrics: MetricSet,
    opts: TrafficOpts,
) -> Result<AppMetrics> {
    profile_impl(prog, metrics, Delivery::PerEvent, opts)
}

/// Profile a pre-produced event stream instead of interpreting directly —
/// the ingestion inversion. `source` is any [`TraceSource`]: the
/// interpreter behind [`crate::trace::InterpSource`], or a recorded
/// `.pallas-trace` file behind [`crate::trace::TraceReader`]. The full
/// analyzer stack runs unchanged on either origin, under any delivery
/// mode. Fails at plan time with
/// [`TraceError::MissingLanes`](crate::trace::TraceError) when the source
/// does not carry the lanes the selected families read (a
/// narrowly-recorded trace replayed against a wider metric set).
pub fn profile_source_opts(
    prog: &Program,
    source: &mut dyn TraceSource,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<AppMetrics> {
    Ok(profile_source_run(prog, source, metrics, delivery_for(mode), opts, false)?.0)
}

/// [`profile_source_opts`] with per-event delivery — the un-batched
/// reference arm for the replay bit-identity tests.
pub fn profile_source_per_event(
    prog: &Program,
    source: &mut dyn TraceSource,
    metrics: MetricSet,
    opts: TrafficOpts,
) -> Result<AppMetrics> {
    Ok(profile_source_run(prog, source, metrics, Delivery::PerEvent, opts, false)?.0)
}

/// [`profile_source_opts`] plus the region/task trace both machine models
/// consume — the coordinator's replay entry point.
pub fn profile_source_with_tasks(
    prog: &Program,
    source: &mut dyn TraceSource,
    metrics: MetricSet,
    mode: PipelineMode,
    opts: TrafficOpts,
) -> Result<(AppMetrics, Vec<Region>)> {
    let (m, regions) = profile_source_run(prog, source, metrics, delivery_for(mode), opts, true)?;
    Ok((m, regions.expect("task trace enabled")))
}

/// The source-driven sibling of [`profile_run`]: same stack construction
/// and delivery shapes, but events come from `source` and the execution
/// statistics are the source's (wall time stamped here — the driver owns
/// the clock). Replay is strict: a source error or a dead analyzer thread
/// fails the run; there is no fault-supervision arm on this path.
pub(crate) fn profile_source_run(
    prog: &Program,
    source: &mut dyn TraceSource,
    metrics: MetricSet,
    delivery: Delivery,
    opts: TrafficOpts,
    with_tasks: bool,
) -> Result<(AppMetrics, Option<Vec<Region>>)> {
    crate::ir::verify::verify_ok(prog);
    // plan-time lane gate: a starved replay must fail before any decoding,
    // naming the families it cannot feed
    check_lanes(source.lanes(), metrics)?;
    let t0 = std::time::Instant::now();
    if let Delivery::Sharded(workers) = delivery {
        return shard::profile_sharded_source(prog, source, metrics, workers, opts, with_tasks, t0);
    }
    let mut stack = AnalyzerStack::new_opts(prog, metrics, opts);
    if with_tasks {
        stack = stack.with_task_trace(prog);
    }
    match delivery {
        Delivery::Chunked => replay_chunked(source, &mut stack)?,
        Delivery::PerEvent => replay_per_event(source, &mut stack)?,
        Delivery::Offload => replay_offload(source, &mut stack)?,
        Delivery::Sharded(_) => unreachable!("handled above"),
    }
    let mut exec = source.stats();
    exec.wall_s = t0.elapsed().as_secs_f64();
    Ok(stack.finalize(exec))
}

impl AppMetrics {
    /// The paper's four Fig-6 PCA features, in artifact column order:
    /// [BBLP_1, PBBLP, entropy_diff_mem, spat_8B_16B].
    pub fn pca4_features(&self) -> [f64; 4] {
        [
            self.bblp.bblp_1(),
            self.pbblp.pbblp,
            self.mem_entropy.entropy_diff,
            self.spatial.spat_8b_16b(),
        ]
    }

    /// Extended 8-feature vector for the pca8 artifact:
    /// pca4 + [DLP, ILP_inf, memory entropy @64B, branch entropy].
    pub fn pca8_features(&self) -> [f64; 8] {
        let p4 = self.pca4_features();
        [
            p4[0],
            p4[1],
            p4[2],
            p4[3],
            self.dlp.dlp,
            self.ilp.inf,
            self.mem_entropy.entropies[6],
            self.branch.weighted_entropy(),
        ]
    }

    /// True when `family` (a [`Metric::name`]) died mid-run on a
    /// supervised pipeline.
    pub fn family_failed(&self, family: &str) -> bool {
        self.failed.iter().any(|f| f == family)
    }

    pub fn to_json(&self) -> Json {
        // Degraded families keep their (shape-stable, untrustworthy)
        // numbers but get stamped so no downstream reader mistakes them
        // for measurements. Spatial locality derives from reuse, so it
        // inherits that family's failure.
        let section = |mut sec: Json, family: &str| -> Json {
            if self.family_failed(family) {
                sec.set("status", "failed");
            }
            sec
        };
        let mut j = Json::obj();
        j.set("name", self.name.as_str());
        j.set("instruction_mix", section(self.mix.to_json(), "mix"));
        j.set("branch", section(self.branch.to_json(), "branch"));
        j.set("memory_entropy", section(self.mem_entropy.to_json(), "mem_entropy"));
        j.set("reuse", section(self.reuse.to_json(), "reuse"));
        j.set("spatial_locality", section(self.spatial.to_json(), "reuse"));
        j.set("ilp", section(self.ilp.to_json(), "ilp"));
        j.set("dlp", section(self.dlp.to_json(), "dlp"));
        j.set("bblp", section(self.bblp.to_json(), "bblp"));
        j.set("pbblp", section(self.pbblp.to_json(), "pbblp"));
        j.set("traffic", section(self.traffic.to_json(), "traffic"));
        j.set("dyn_instrs", self.exec.dyn_instrs);
        let mut exec = Json::obj();
        exec.set("events", self.exec.events());
        exec.set("wall_s", self.exec.wall_s);
        exec.set("events_per_sec", self.exec.events_per_sec());
        j.set("exec", exec);
        if !self.failed.is_empty() {
            j.set("status", "degraded");
            let fams: Vec<Json> = self.failed.iter().map(|f| Json::from(f.as_str())).collect();
            j.set("failed_families", fams);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;

    fn tiny_program() -> Program {
        let mut b = ProgramBuilder::new("tiny");
        let data: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        let a = b.alloc_f64_init("a", &data);
        let o = b.alloc_f64("o", 64);
        let n = b.const_i(64);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, v);
            b.store_f64(o, i, w);
        });
        b.finish(None)
    }

    #[test]
    fn profile_produces_all_metrics() {
        let m = profile(&tiny_program()).unwrap();
        assert_eq!(m.name, "tiny");
        assert!(m.exec.dyn_instrs > 0);
        assert_eq!(m.mem_entropy.entropies.len(), 11);
        assert_eq!(m.reuse.avg_dtr.len(), 8);
        assert_eq!(m.spatial.scores.len(), 7);
        assert_eq!(m.bblp.values.len(), 4);
        assert!(m.pbblp.pbblp > 32.0, "map loop should be data-parallel");
        assert!(m.dlp.dlp > 1.0);
        assert!(m.ilp.inf >= 1.0);
        // the traffic family rides the same single pass
        assert_eq!(m.traffic.accesses, m.exec.mem_reads + m.exec.mem_writes);
        assert_eq!(m.traffic.reads, m.exec.mem_reads);
        assert_eq!(m.traffic.writes, m.exec.mem_writes);
        assert_eq!(m.traffic.read_bytes, 64 * 8);
        assert_eq!(m.traffic.write_bytes, 64 * 8);
        assert!(m.traffic.bytes_per_instr() > 0.0);
        assert!(m.traffic.mrc_miss_ratio.len() >= 6);
    }

    #[test]
    fn chunked_profile_matches_per_event_reference() {
        let p = tiny_program();
        let a = profile(&p).unwrap();
        let b = profile_per_event(&p).unwrap();
        let pa = a.pca8_features();
        let pb = b.pca8_features();
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{pa:?} vs {pb:?}");
        }
        assert_eq!(a.mix.per_op, b.mix.per_op);
        assert_eq!(a.mem_entropy.count_of_counts, b.mem_entropy.count_of_counts);
        assert_eq!(a.reuse.hist, b.reuse.hist);
        assert_eq!(a.exec.dyn_instrs, b.exec.dyn_instrs);
    }

    fn profile_delivery(prog: &Program, delivery: Delivery) -> AppMetrics {
        profile_impl(prog, MetricSet::all(), delivery, TrafficOpts::default()).unwrap()
    }

    #[test]
    fn offload_profile_matches_inline() {
        let p = tiny_program();
        let a = profile(&p).unwrap();
        let b = profile_delivery(&p, Delivery::Offload);
        assert_eq!(a.pca8_features().map(f64::to_bits), b.pca8_features().map(f64::to_bits));
        assert_eq!(a.mix.per_op, b.mix.per_op);
        assert_eq!(a.reuse.hist, b.reuse.hist);
        assert_eq!(a.mem_entropy.count_of_counts, b.mem_entropy.count_of_counts);
        assert_eq!(a.exec.dyn_instrs, b.exec.dyn_instrs);
    }

    #[test]
    fn sharded_profile_matches_inline() {
        let p = tiny_program();
        let a = profile(&p).unwrap();
        let b = profile_delivery(&p, Delivery::Sharded(Workers::Auto));
        assert_eq!(a.pca8_features().map(f64::to_bits), b.pca8_features().map(f64::to_bits));
        assert_eq!(a.mix.per_op, b.mix.per_op);
        assert_eq!(a.reuse.hist, b.reuse.hist);
        assert_eq!(a.mem_entropy.count_of_counts, b.mem_entropy.count_of_counts);
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.exec.dyn_instrs, b.exec.dyn_instrs);
    }

    #[test]
    fn source_profile_matches_direct_on_every_delivery() {
        use crate::trace::InterpSource;
        let p = tiny_program();
        let reference = profile(&p).unwrap();
        for mode in [
            PipelineMode::Inline,
            PipelineMode::Offload,
            PipelineMode::Sharded { workers: Workers::Auto },
        ] {
            let mut src = InterpSource::new(&p).unwrap();
            let m =
                profile_source_opts(&p, &mut src, MetricSet::all(), mode, TrafficOpts::default())
                    .unwrap();
            assert_eq!(
                m.pca8_features().map(f64::to_bits),
                reference.pca8_features().map(f64::to_bits),
                "{mode:?}"
            );
            assert_eq!(m.mix.per_op, reference.mix.per_op);
            assert_eq!(m.reuse.hist, reference.reuse.hist);
            assert_eq!(m.traffic, reference.traffic);
            assert_eq!(m.exec.dyn_instrs, reference.exec.dyn_instrs);
        }
        let mut src = InterpSource::new(&p).unwrap();
        let m =
            profile_source_per_event(&p, &mut src, MetricSet::all(), TrafficOpts::default())
                .unwrap();
        assert_eq!(
            m.pca8_features().map(f64::to_bits),
            reference.pca8_features().map(f64::to_bits)
        );
    }

    #[test]
    fn source_profile_with_tasks_yields_regions() {
        use crate::trace::InterpSource;
        let p = tiny_program();
        let mut src = InterpSource::new(&p).unwrap();
        let (m, regions) = profile_source_with_tasks(
            &p,
            &mut src,
            MetricSet::all(),
            PipelineMode::Inline,
            TrafficOpts::default(),
        )
        .unwrap();
        assert!(m.exec.dyn_instrs > 0);
        assert!(!regions.is_empty());
    }

    #[test]
    fn lane_starved_source_fails_at_plan_time() {
        use crate::interp::EventChunk;
        use crate::trace::{ChunkStatus, TraceError, TraceLanes};
        struct Stub;
        impl TraceSource for Stub {
            fn next_chunk(&mut self, _chunk: &mut EventChunk) -> Result<ChunkStatus> {
                bail!("decode reached")
            }
            fn chunk_capacity(&self) -> usize {
                8
            }
            fn lanes(&self) -> TraceLanes {
                TraceLanes::TAGS
            }
            fn stats(&self) -> ExecStats {
                ExecStats::default()
            }
        }
        let p = tiny_program();
        let err = profile_source_opts(
            &p,
            &mut Stub,
            MetricSet::all(),
            PipelineMode::Inline,
            TrafficOpts::default(),
        )
        .unwrap_err();
        match err.downcast_ref::<TraceError>() {
            Some(TraceError::MissingLanes { families, missing }) => {
                assert!(families.iter().any(|f| f == "traffic"), "{families:?}");
                assert!(families.iter().any(|f| f == "ilp"), "{families:?}");
                assert!(!families.iter().any(|f| f == "mix"), "{families:?}");
                assert!(missing.contains(TraceLanes::ADDRS));
                assert!(!missing.contains(TraceLanes::TAGS));
            }
            other => panic!("expected MissingLanes, got {other:?}"),
        }
        // a tags-only selection is satisfied by a tags-only source: the
        // gate passes and the stub's own decode error surfaces instead
        let sel = MetricSet::from_names("mix").unwrap();
        let err =
            profile_source_opts(&p, &mut Stub, sel, PipelineMode::Inline, TrafficOpts::default())
                .unwrap_err();
        assert_eq!(err.to_string(), "decode reached");
    }

    #[test]
    fn analyzer_stack_is_send() {
        // the offload path moves the stack (by mutable borrow) to the
        // analysis thread; keep this a compile-visible guarantee
        fn assert_send<T: Send>() {}
        assert_send::<AnalyzerStack>();
    }

    #[test]
    fn stack_direct_chunk_call_matches_lane_flush() {
        // sinks that call on_chunk without pre-built lanes (ad-hoc
        // composition) must land on the same lane path
        let p = tiny_program();
        let reference = profile(&p).unwrap();
        let mut stack = AnalyzerStack::full(&p);
        let mut machine = Machine::new(&p).unwrap();
        // capture the whole trace, then hand it to the stack via on_chunk
        struct Capture(Vec<TraceEvent>);
        impl Instrument for Capture {
            fn on_event(&mut self, ev: &TraceEvent) {
                self.0.push(*ev);
            }
        }
        let mut cap = Capture(Vec::new());
        let out = machine.run_per_event(&mut cap).unwrap();
        for slice in cap.0.chunks(700) {
            stack.on_chunk(slice);
        }
        let (m, _) = stack.finalize(out.stats);
        assert_eq!(
            m.pca8_features().map(f64::to_bits),
            reference.pca8_features().map(f64::to_bits)
        );
    }

    #[test]
    fn metric_selection_feeds_only_chosen_families() {
        let p = tiny_program();
        let sel = MetricSet::from_names("mix,dlp").unwrap();
        assert_eq!(sel.names(), vec!["mix", "dlp"]);
        let m = profile_impl(&p, sel, Delivery::Chunked, TrafficOpts::default()).unwrap();
        assert!(m.mix.total() > 0);
        assert!(m.dlp.dlp > 1.0);
        // disabled families are shape-stable but empty
        assert_eq!(m.mem_entropy.accesses, 0);
        assert_eq!(m.mem_entropy.entropies.len(), 11);
        assert_eq!(m.reuse.accesses, 0);
        assert_eq!(m.bblp.values.len(), 4);
        assert_eq!(m.branch.dyn_branches(), 0);
        assert_eq!(m.traffic.accesses, 0);
        assert!(m.traffic.mrc_miss_ratio.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn traffic_family_selectable_alone() {
        let p = tiny_program();
        let sel = MetricSet::from_names("traffic").unwrap();
        assert_eq!(sel.names(), vec!["traffic"]);
        let m = profile_impl(&p, sel, Delivery::Chunked, TrafficOpts::default()).unwrap();
        assert_eq!(m.traffic.accesses, 128);
        assert_eq!(m.traffic.read_bytes, 512);
        assert_eq!(m.traffic.write_bytes, 512);
        // other lane families stayed off
        assert_eq!(m.reuse.accesses, 0);
        assert_eq!(m.mem_entropy.accesses, 0);
        assert_eq!(m.mix.total(), 0);
    }

    #[test]
    fn metric_set_parsing() {
        assert!(MetricSet::from_names("all").unwrap().is_all());
        assert!(MetricSet::from_names("").unwrap().is_all());
        let s = MetricSet::from_names("spatial").unwrap();
        assert!(s.contains(Metric::Reuse));
        assert!(!s.contains(Metric::Mix));
        let t = MetricSet::from_names("traffic,mix").unwrap();
        assert!(t.contains(Metric::Traffic) && t.contains(Metric::Mix));
        assert!(!t.is_all());
        assert!(!MetricSet::all().without(Metric::Traffic).is_all());
        assert!(!MetricSet::all().without(Metric::Traffic).contains(Metric::Traffic));
        assert!(MetricSet::from_names("mix,bogus").is_err());
    }

    #[test]
    fn degraded_metrics_mark_failed_families_in_json() {
        let mut m = profile(&tiny_program()).unwrap();
        let clean = m.to_json().to_string_pretty();
        assert!(!clean.contains("failed_families"));
        assert!(!clean.contains("\"status\""));
        m.failed = vec!["reuse".into(), "traffic".into()];
        assert!(m.family_failed("reuse") && !m.family_failed("mix"));
        let j = m.to_json();
        let s = j.to_string_pretty();
        assert!(s.contains("failed_families"));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("degraded"));
        for sec in ["reuse", "spatial_locality", "traffic"] {
            let status = j.get(sec).and_then(|v| v.get("status")).and_then(Json::as_str);
            assert_eq!(status, Some("failed"), "section {sec}");
        }
        assert!(j.get("instruction_mix").unwrap().get("status").is_none());
    }

    #[test]
    fn feature_vectors_consistent() {
        let m = profile(&tiny_program()).unwrap();
        let p4 = m.pca4_features();
        let p8 = m.pca8_features();
        assert_eq!(&p4[..], &p8[..4]);
        assert!(p4.iter().all(|v| v.is_finite()));
        assert!(p8.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn json_report_has_sections() {
        let m = profile(&tiny_program()).unwrap();
        let s = m.to_json().to_string_pretty();
        for key in [
            "instruction_mix",
            "memory_entropy",
            "spatial_locality",
            "dlp",
            "bblp",
            "pbblp",
            "traffic",
            "miss_ratio",
            "events_per_sec",
        ] {
            assert!(s.contains(key), "missing {key}");
        }
    }
}
