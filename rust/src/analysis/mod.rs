//! The PISA-NMC metric analyzers (paper §II).
//!
//! Every analyzer implements [`crate::interp::Instrument`] and consumes the
//! dynamic event stream exactly once; [`profile`] fans a single execution
//! out to all of them (the paper's single-pass instrumented run) and
//! produces an [`AppMetrics`] with every §II metric:
//!
//! | metric | module | paper figure |
//! |---|---|---|
//! | instruction mix        | [`mix`]         | (baseline) |
//! | branch entropy         | [`branch`]      | (baseline) |
//! | memory entropy         | [`mem_entropy`] | Fig 3a, Fig 5 |
//! | DTR / spatial locality | [`reuse`], [`spatial`] | Fig 3b |
//! | ILP (windowed)         | [`ilp`]         | (baseline) |
//! | DLP                    | [`dlp`]         | Fig 3c |
//! | BBLP (windowed)        | [`bblp`]        | Fig 3c |
//! | PBBLP                  | [`pbblp`]       | Fig 3c |

pub mod bblp;
pub mod branch;
pub mod dataflow;
pub mod dlp;
pub mod ilp;
pub mod mem_entropy;
pub mod mix;
pub mod pbblp;
pub mod reuse;
pub mod spatial;

use anyhow::Result;

pub use bblp::{BblpAnalyzer, BblpResult};
pub use branch::BranchAnalyzer;
pub use dlp::{DlpAnalyzer, DlpResult};
pub use ilp::{IlpAnalyzer, IlpResult};
pub use mem_entropy::{MemEntropyAnalyzer, MemEntropyResult};
pub use mix::MixAnalyzer;
pub use pbblp::{PbblpAnalyzer, PbblpResult};
pub use reuse::{ReuseAnalyzer, ReuseResult};
pub use spatial::SpatialResult;

use crate::interp::{run_program, ExecStats, Fanout};
use crate::ir::Program;
use crate::util::Json;

/// All §II metrics for one application run (PISA's JSON result object).
#[derive(Debug, Clone)]
pub struct AppMetrics {
    pub name: String,
    pub mix: MixAnalyzer,
    pub branch: BranchAnalyzer,
    pub mem_entropy: MemEntropyResult,
    pub reuse: ReuseResult,
    pub spatial: SpatialResult,
    pub ilp: IlpResult,
    pub dlp: DlpResult,
    pub bblp: BblpResult,
    pub pbblp: PbblpResult,
    pub exec: ExecStats,
}

/// Count-of-counts slots the entropy artifact accepts (see aot.py `B`).
pub const ENTROPY_SLOTS: usize = 4096;

/// Run `prog` once, streaming the trace through every analyzer.
pub fn profile(prog: &Program) -> Result<AppMetrics> {
    crate::ir::verify::verify_ok(prog);
    let n_regs = prog.func.n_regs;
    let mut mix = MixAnalyzer::new();
    let mut branch = BranchAnalyzer::new();
    let mut ment = MemEntropyAnalyzer::new();
    let mut reuse = ReuseAnalyzer::new();
    let mut ilp = IlpAnalyzer::new(n_regs);
    let mut dlp = DlpAnalyzer::for_program(prog);
    let mut bblp = BblpAnalyzer::new(n_regs);
    let mut pbblp = PbblpAnalyzer::new(prog);

    let (out, _machine) = {
        let mut fan = Fanout::new(vec![
            &mut mix,
            &mut branch,
            &mut ment,
            &mut reuse,
            &mut ilp,
            &mut dlp,
            &mut bblp,
            &mut pbblp,
        ]);
        run_program(prog, &mut fan)?
    };

    let mem_entropy = ment.finalize(ENTROPY_SLOTS);
    let reuse_res = reuse.finalize();
    let spatial = spatial::from_reuse(&reuse_res);
    Ok(AppMetrics {
        name: prog.func.name.clone(),
        mix,
        branch,
        mem_entropy,
        reuse: reuse_res,
        spatial,
        ilp: ilp.finalize(),
        dlp: dlp.finalize(),
        bblp: bblp.finalize(),
        pbblp: pbblp.finalize(),
        exec: out.stats,
    })
}

impl AppMetrics {
    /// The paper's four Fig-6 PCA features, in artifact column order:
    /// [BBLP_1, PBBLP, entropy_diff_mem, spat_8B_16B].
    pub fn pca4_features(&self) -> [f64; 4] {
        [
            self.bblp.bblp_1(),
            self.pbblp.pbblp,
            self.mem_entropy.entropy_diff,
            self.spatial.spat_8b_16b(),
        ]
    }

    /// Extended 8-feature vector for the pca8 artifact:
    /// pca4 + [DLP, ILP_inf, memory entropy @64B, branch entropy].
    pub fn pca8_features(&self) -> [f64; 8] {
        let p4 = self.pca4_features();
        [
            p4[0],
            p4[1],
            p4[2],
            p4[3],
            self.dlp.dlp,
            self.ilp.inf,
            self.mem_entropy.entropies[6],
            self.branch.weighted_entropy(),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str());
        j.set("instruction_mix", self.mix.to_json());
        j.set("branch", self.branch.to_json());
        j.set("memory_entropy", self.mem_entropy.to_json());
        j.set("reuse", self.reuse.to_json());
        j.set("spatial_locality", self.spatial.to_json());
        j.set("ilp", self.ilp.to_json());
        j.set("dlp", self.dlp.to_json());
        j.set("bblp", self.bblp.to_json());
        j.set("pbblp", self.pbblp.to_json());
        j.set("dyn_instrs", self.exec.dyn_instrs);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;

    fn tiny_program() -> Program {
        let mut b = ProgramBuilder::new("tiny");
        let data: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        let a = b.alloc_f64_init("a", &data);
        let o = b.alloc_f64("o", 64);
        let n = b.const_i(64);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, v);
            b.store_f64(o, i, w);
        });
        b.finish(None)
    }

    #[test]
    fn profile_produces_all_metrics() {
        let m = profile(&tiny_program()).unwrap();
        assert_eq!(m.name, "tiny");
        assert!(m.exec.dyn_instrs > 0);
        assert_eq!(m.mem_entropy.entropies.len(), 11);
        assert_eq!(m.reuse.avg_dtr.len(), 8);
        assert_eq!(m.spatial.scores.len(), 7);
        assert_eq!(m.bblp.values.len(), 4);
        assert!(m.pbblp.pbblp > 32.0, "map loop should be data-parallel");
        assert!(m.dlp.dlp > 1.0);
        assert!(m.ilp.inf >= 1.0);
    }

    #[test]
    fn feature_vectors_consistent() {
        let m = profile(&tiny_program()).unwrap();
        let p4 = m.pca4_features();
        let p8 = m.pca8_features();
        assert_eq!(&p4[..], &p8[..4]);
        assert!(p4.iter().all(|v| v.is_finite()));
        assert!(p8.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn json_report_has_sections() {
        let m = profile(&tiny_program()).unwrap();
        let s = m.to_json().to_string_pretty();
        for key in [
            "instruction_mix",
            "memory_entropy",
            "spatial_locality",
            "dlp",
            "bblp",
            "pbblp",
        ] {
            assert!(s.contains(key), "missing {key}");
        }
    }
}
