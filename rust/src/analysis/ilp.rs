//! Instruction-level parallelism (PISA baseline; feeds the host model and
//! the DLP/BBLP family).
//!
//! ILP_w: the trace is partitioned into consecutive windows of w dynamic
//! instructions; within each window the dataflow-critical-path parallelism
//! `count / depth` is computed (register + memory dependences, idealized
//! machine); ILP_w is the instruction-weighted mean over windows. ILP_∞
//! treats the whole trace as one window. Window sizes follow PISA's
//! convention of scheduling-scope-limited ILP.

use super::dataflow::DepthTracker;
use crate::interp::{Instrument, TraceEvent};
use crate::util::Json;

/// Finite scheduling windows analyzed (instructions).
pub const ILP_WINDOWS: [usize; 4] = [32, 64, 128, 256];

#[derive(Debug, Clone)]
struct WindowedIlp {
    window: usize,
    tracker: DepthTracker,
    in_window: usize,
    weighted_sum: f64, // Σ window_count · window_parallelism
    weight: u64,       // Σ window_count
}

impl WindowedIlp {
    fn flush(&mut self) {
        if self.tracker.count > 0 {
            self.weighted_sum += self.tracker.parallelism() * self.tracker.count as f64;
            self.weight += self.tracker.count;
        }
        self.tracker.reset();
        self.in_window = 0;
    }

    fn value(&self) -> f64 {
        // include the trailing partial window
        let mut sum = self.weighted_sum;
        let mut w = self.weight;
        if self.tracker.count > 0 {
            sum += self.tracker.parallelism() * self.tracker.count as f64;
            w += self.tracker.count;
        }
        if w == 0 {
            0.0
        } else {
            sum / w as f64
        }
    }
}

/// Streaming ILP analyzer (all window sizes + ∞ in one pass).
#[derive(Debug, Clone)]
pub struct IlpAnalyzer {
    windows: Vec<WindowedIlp>,
    inf: DepthTracker,
}

/// Finalized ILP numbers.
#[derive(Debug, Clone)]
pub struct IlpResult {
    /// (window size, ILP_w), ascending; plus `inf`.
    pub windowed: Vec<(usize, f64)>,
    pub inf: f64,
    pub instrs: u64,
    pub critical_path: u32,
}

impl IlpAnalyzer {
    pub fn new(n_regs: u16) -> Self {
        IlpAnalyzer {
            windows: ILP_WINDOWS
                .iter()
                .map(|&w| WindowedIlp {
                    window: w,
                    tracker: DepthTracker::new(n_regs),
                    in_window: 0,
                    weighted_sum: 0.0,
                    weight: 0,
                })
                .collect(),
            inf: DepthTracker::new(n_regs),
        }
    }

    pub fn finalize(&self) -> IlpResult {
        IlpResult {
            windowed: self.windows.iter().map(|w| (w.window, w.value())).collect(),
            inf: self.inf.parallelism(),
            instrs: self.inf.count,
            critical_path: self.inf.max_depth,
        }
    }
}

// Chunk delivery uses the default `on_chunk` (a statically-dispatched loop
// over `on_event` — there is no per-chunk state worth hoisting here).
impl Instrument for IlpAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            self.inf.observe(i);
            for w in &mut self.windows {
                w.tracker.observe(i);
                w.in_window += 1;
                if w.in_window >= w.window {
                    w.flush();
                }
            }
        }
    }
}

impl IlpResult {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (w, v) in &self.windowed {
            j.set(&format!("ilp_{w}"), *v);
        }
        j.set("ilp_inf", self.inf);
        j.set("instrs", self.instrs);
        j.set("critical_path", self.critical_path as u64);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    fn ilp_of(p: &crate::ir::Program) -> IlpResult {
        let mut a = IlpAnalyzer::new(p.func.n_regs);
        run_program(p, &mut a).unwrap();
        a.finalize()
    }

    #[test]
    fn serial_chain_has_ilp_near_one() {
        // x = x + x repeated: a pure serial dependence chain (plus the loop
        // bookkeeping, which is itself serial on the counter).
        let mut b = ProgramBuilder::new("serial");
        let x = b.const_f(1.000001);
        let n = b.const_i(2000);
        b.counted_loop(n, |b, _i| {
            let y = b.fmul(x, x);
            b.assign(x, y);
        });
        let p = b.finish(Some(x));
        let r = ilp_of(&p);
        assert!(r.inf < 3.0, "serial ILP_inf {}", r.inf);
    }

    #[test]
    fn independent_stores_have_high_ilp() {
        // a[i] = c : iterations independent except the counter chain →
        // dataflow ILP well above the serial case.
        let mut b = ProgramBuilder::new("par");
        let a = b.alloc_f64("a", 2048);
        let n = b.const_i(2048);
        b.counted_loop(n, |b, i| {
            let v = b.const_f(3.0);
            b.store_f64(a, i, v);
        });
        let p = b.finish(None);
        let r = ilp_of(&p);
        assert!(r.inf > 2.5, "parallel ILP_inf {}", r.inf);
    }

    #[test]
    fn windowed_ilp_not_above_longer_windows_for_uniform_code() {
        let mut b = ProgramBuilder::new("w");
        let a = b.alloc_f64("a", 1024);
        let n = b.const_i(1024);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fadd(v, v);
            b.store_f64(a, i, w);
        });
        let p = b.finish(None);
        let r = ilp_of(&p);
        assert_eq!(r.windowed.len(), ILP_WINDOWS.len());
        for (w, v) in &r.windowed {
            assert!(*v >= 1.0, "ILP_{w} = {v} must be >= 1");
            assert!(*v <= r.inf * 1.5 + 1.0);
        }
    }

    #[test]
    fn counts_match_trace() {
        let mut b = ProgramBuilder::new("c");
        let x = b.const_i(1);
        let y = b.const_i(2);
        b.add(x, y);
        let p = b.finish(None);
        let r = ilp_of(&p);
        assert_eq!(r.instrs, 3);
        assert_eq!(r.critical_path, 2); // consts at depth 1, add at 2
    }
}
