//! Data-level parallelism (paper §II-B, Fig 3c).
//!
//! The paper "specializes ILP per opcode in order to estimate DLP": if
//! several dynamic instances of the *same* opcode sit at the *same* global
//! dataflow level, an idealized SIMD unit could execute them as one vector
//! instruction. So per opcode o:
//!
//! ```text
//! ILP_o = count_o / (#distinct dataflow levels where o occurs)
//! ```
//!
//! which is exactly the mean vector length a level-synchronous vectorizer
//! would achieve. The program-level DLP is the count-weighted mean of ILP_o
//! over *vectorizable* opcodes (arithmetic + memory; control/moves excluded,
//! see [`Op::vectorizable`]).
//!
//! Dependences through loop-induction registers are excluded from the depth
//! recurrence (a vectorizer strength-reduces the counter); without this,
//! the i → i+1 chain would place every iteration of even a perfectly
//! data-parallel loop at a distinct level and DLP would degenerate to 1.

use super::dataflow::{DepthTracker, LevelSet};
use crate::interp::{Instrument, TraceEvent};
use crate::ir::Op;
use crate::util::Json;

/// Streaming DLP analyzer.
pub struct DlpAnalyzer {
    depth: DepthTracker,
    levels: Vec<LevelSet>,       // per opcode
    counts: [u64; Op::COUNT],    // per opcode
}

/// Finalized DLP numbers.
#[derive(Debug, Clone)]
pub struct DlpResult {
    /// Count-weighted mean vector length over vectorizable opcodes.
    pub dlp: f64,
    /// Per-opcode (mnemonic, count, ILP_o) for ops that occurred.
    pub per_op: Vec<(&'static str, u64, f64)>,
}

impl DlpAnalyzer {
    /// `counters`: the program's loop-induction registers (from
    /// `Program::loops`), excluded from the dependence recurrence.
    pub fn new(n_regs: u16, counters: &[u16]) -> Self {
        DlpAnalyzer {
            depth: DepthTracker::with_ignored(n_regs, counters),
            levels: (0..Op::COUNT).map(|_| LevelSet::default()).collect(),
            counts: [0; Op::COUNT],
        }
    }

    pub fn for_program(prog: &crate::ir::Program) -> Self {
        let counters: Vec<u16> = prog
            .loops
            .iter()
            .map(|l| l.counter)
            .filter(|&c| c != u16::MAX)
            .collect();
        Self::new(prog.func.n_regs, &counters)
    }

    pub fn finalize(&self) -> DlpResult {
        let mut per_op = Vec::new();
        let mut weighted = 0.0;
        let mut weight = 0u64;
        for i in 0..Op::COUNT {
            let c = self.counts[i];
            if c == 0 {
                continue;
            }
            let op = Op::from_index(i).unwrap();
            let lv = self.levels[i].len().max(1);
            let ilp_o = c as f64 / lv as f64;
            per_op.push((op.mnemonic(), c, ilp_o));
            if op.vectorizable() {
                weighted += ilp_o * c as f64;
                weight += c;
            }
        }
        DlpResult {
            dlp: if weight == 0 { 0.0 } else { weighted / weight as f64 },
            per_op,
        }
    }
}

// Chunk delivery uses the default `on_chunk` (a statically-dispatched loop
// over `on_event` — there is no per-chunk state worth hoisting here).
impl Instrument for DlpAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            let d = self.depth.observe(i);
            let idx = i.op.index();
            self.counts[idx] += 1;
            self.levels[idx].insert(d);
        }
    }
}

impl DlpResult {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("dlp", self.dlp);
        let mut ops = Json::obj();
        for (name, count, ilp_o) in &self.per_op {
            let mut o = Json::obj();
            o.set("count", *count);
            o.set("ilp", *ilp_o);
            ops.set(name, o);
        }
        j.set("per_op", ops);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    fn dlp_of(p: &crate::ir::Program) -> DlpResult {
        let mut a = DlpAnalyzer::for_program(p);
        run_program(p, &mut a).unwrap();
        a.finalize()
    }

    #[test]
    fn elementwise_map_has_high_dlp() {
        // a[i] = a[i] * 2 — every fmul is independent; dataflow levels are
        // shared across iterations (same loop-body structure), so ILP_fmul
        // is high.
        let mut b = ProgramBuilder::new("map");
        let data: Vec<f64> = (0..512).map(|i| i as f64).collect();
        let a = b.alloc_f64_init("a", &data);
        let n = b.const_i(512);
        let two = b.const_f(2.0);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, two);
            b.store_f64(a, i, w);
        });
        let r = dlp_of(&b.finish(None));
        let fmul = r.per_op.iter().find(|(n, _, _)| *n == "fmul").unwrap();
        assert!(fmul.2 > 4.0, "fmul vector length {}", fmul.2);
        assert!(r.dlp > 2.0, "dlp {}", r.dlp);
    }

    #[test]
    fn reduction_has_low_dlp() {
        // acc += a[i] — every fadd is chained: one new dataflow level per
        // iteration ⇒ ILP_fadd ≈ 1.
        let mut b = ProgramBuilder::new("red");
        let data: Vec<f64> = (0..512).map(|i| i as f64).collect();
        let a = b.alloc_f64_init("a", &data);
        let acc = b.const_f(0.0);
        let n = b.const_i(512);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        let r = dlp_of(&b.finish(Some(acc)));
        let fadd = r.per_op.iter().find(|(n, _, _)| *n == "fadd").unwrap();
        assert!(fadd.2 < 1.5, "fadd vector length {}", fadd.2);
    }

    #[test]
    fn map_beats_reduction() {
        let build_map = || {
            let mut b = ProgramBuilder::new("m");
            let a = b.alloc_f64("a", 256);
            let n = b.const_i(256);
            let c = b.const_f(1.5);
            b.counted_loop(n, |b, i| {
                let v = b.load_f64(a, i);
                let w = b.fmul(v, c);
                b.store_f64(a, i, w);
            });
            b.finish(None)
        };
        let build_red = || {
            let mut b = ProgramBuilder::new("r");
            let a = b.alloc_f64("a", 256);
            let acc = b.const_f(0.0);
            let n = b.const_i(256);
            b.counted_loop(n, |b, i| {
                let v = b.load_f64(a, i);
                let s = b.fadd(acc, v);
                b.assign(acc, s);
            });
            b.finish(Some(acc))
        };
        assert!(dlp_of(&build_map()).dlp > dlp_of(&build_red()).dlp);
    }
}
