//! Branch-entropy analyzer (PISA baseline metric).
//!
//! Per static branch site, the entropy of its taken/not-taken outcome
//! distribution; the program-level metric is the execution-weighted average.
//! High branch entropy ≈ unpredictable control flow (hurts wide OoO hosts,
//! matters less for the simple in-order NMC PEs).

use std::collections::HashMap;

use crate::interp::{Instrument, TraceEvent};
use crate::ir::BlockId;
use crate::util::Json;

#[derive(Debug, Clone, Copy, Default)]
struct SiteCounts {
    taken: u64,
    not_taken: u64,
}

impl SiteCounts {
    fn total(&self) -> u64 {
        self.taken + self.not_taken
    }

    fn entropy(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        let mut h = 0.0;
        for c in [self.taken, self.not_taken] {
            if c > 0 {
                let p = c as f64 / t as f64;
                h -= p * p.log2();
            }
        }
        h
    }
}

/// Streaming per-site branch outcome counters.
#[derive(Debug, Clone, Default)]
pub struct BranchAnalyzer {
    sites: HashMap<BlockId, SiteCounts>,
    total: u64,
}

impl BranchAnalyzer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Execution-weighted average per-site entropy, in [0, 1] bits.
    pub fn weighted_entropy(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sites
            .values()
            .map(|s| s.entropy() * s.total() as f64)
            .sum::<f64>()
            / self.total as f64
    }

    /// Global taken rate.
    pub fn taken_rate(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sites.values().map(|s| s.taken).sum::<u64>() as f64 / self.total as f64
    }

    pub fn dyn_branches(&self) -> u64 {
        self.total
    }

    pub fn static_sites(&self) -> usize {
        self.sites.len()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("weighted_entropy", self.weighted_entropy());
        j.set("taken_rate", self.taken_rate());
        j.set("dyn_branches", self.total);
        j.set("static_sites", self.static_sites());
        j
    }
}

impl BranchAnalyzer {
    #[inline]
    fn bump_site(&mut self, block: BlockId, taken: u64, not_taken: u64) {
        let s = self.sites.entry(block).or_default();
        s.taken += taken;
        s.not_taken += not_taken;
    }
}

impl Instrument for BranchAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Branch { block, taken } = ev {
            let s = self.sites.entry(*block).or_default();
            if *taken {
                s.taken += 1;
            } else {
                s.not_taken += 1;
            }
            self.total += 1;
        }
    }

    /// Chunk path: consecutive branch events overwhelmingly come from the
    /// same static site (a hot loop header), so outcomes are run-length
    /// accumulated and the site map is probed once per run instead of once
    /// per dynamic branch.
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        let mut cur: Option<BlockId> = None;
        let (mut taken_acc, mut nt_acc) = (0u64, 0u64);
        let mut total = 0u64;
        for ev in events {
            if let TraceEvent::Branch { block, taken } = ev {
                total += 1;
                if cur != Some(*block) {
                    if let Some(b) = cur {
                        self.bump_site(b, taken_acc, nt_acc);
                    }
                    cur = Some(*block);
                    taken_acc = 0;
                    nt_acc = 0;
                }
                if *taken {
                    taken_acc += 1;
                } else {
                    nt_acc += 1;
                }
            }
        }
        if let Some(b) = cur {
            self.bump_site(b, taken_acc, nt_acc);
        }
        self.total += total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    #[test]
    fn loop_branch_is_predictable() {
        // A 1000-iteration loop's header branch is taken 1000/1001 times —
        // entropy near 0.
        let mut b = ProgramBuilder::new("t");
        let n = b.const_i(1000);
        b.counted_loop(n, |b, i| {
            b.add_i(i, 0);
        });
        let p = b.finish(None);
        let mut br = BranchAnalyzer::new();
        run_program(&p, &mut br).unwrap();
        assert!(br.weighted_entropy() < 0.02, "{}", br.weighted_entropy());
        assert_eq!(br.dyn_branches(), 1001);
    }

    #[test]
    fn alternating_branch_is_one_bit() {
        // if (i % 2) inside a loop → that site's outcomes alternate →
        // entropy 1 bit at the if-site.
        let mut b = ProgramBuilder::new("t");
        let out = b.alloc_f64("o", 1);
        let n = b.const_i(512);
        let two = b.const_i(2);
        b.counted_loop(n, |b, i| {
            let r = b.rem(i, two);
            let zero = b.const_i(0);
            let c = b.cmp_ne(r, zero);
            b.if_then(c, |b| {
                let z = b.const_i(0);
                let v = b.const_f(1.0);
                b.store_f64(out, z, v);
            });
        });
        let p = b.finish(None);
        let mut br = BranchAnalyzer::new();
        run_program(&p, &mut br).unwrap();
        // two hot sites: loop header (low entropy) + the if (1 bit)
        assert_eq!(br.static_sites(), 2);
        let h = br.weighted_entropy();
        assert!(h > 0.4 && h < 0.6, "weighted entropy {h}");
    }

    #[test]
    fn chunk_run_length_matches_per_event() {
        use crate::interp::InstrEvent;
        use crate::ir::Op;
        let mut evs = Vec::new();
        // alternating sites with mixed outcomes, plus non-branch noise
        for i in 0..200u32 {
            evs.push(TraceEvent::Branch { block: i % 3, taken: i % 2 == 0 });
            if i % 5 == 0 {
                evs.push(TraceEvent::Instr(InstrEvent {
                    op: Op::Add,
                    dst: Some(0),
                    srcs: [0; 3],
                    n_srcs: 0,
                    mem: None,
                    block: 0,
                }));
            }
        }
        let mut a = BranchAnalyzer::new();
        let mut b = BranchAnalyzer::new();
        for ev in &evs {
            a.on_event(ev);
        }
        b.on_chunk(&evs);
        assert_eq!(a.total, b.total);
        assert_eq!(a.static_sites(), b.static_sites());
        assert_eq!(a.weighted_entropy().to_bits(), b.weighted_entropy().to_bits());
        assert_eq!(a.taken_rate().to_bits(), b.taken_rate().to_bits());
    }

    #[test]
    fn empty_trace_is_zero() {
        let br = BranchAnalyzer::new();
        assert_eq!(br.weighted_entropy(), 0.0);
        assert_eq!(br.taken_rate(), 0.0);
    }
}
