//! Potential basic-block-level parallelism (paper §II-B, Fig 3c).
//!
//! PBBLP "tries in a fast and straightforward manner to estimate the
//! basic-block level parallelism in data-parallel loops": loop iterations
//! are the tasks. Using the builder's structured `LoopInfo` (the stand-in
//! for LLVM's LoopInfo pass), each loop *invocation* is tracked on a stack;
//! within an invocation, iteration i depends on iteration j < i when i reads
//! a register or memory granule last written by j — **excluding the
//! induction register**, which every iteration trivially chains through.
//!
//! Per invocation: ratio = iterations / critical-iteration-chain-length.
//! A data-parallel loop scores ratio = trip count (all iterations could run
//! at once); a reduction scores ≈ 1. PBBLP is the iteration-weighted mean
//! of the ratios. Instructions inside nested loops are attributed to the
//! innermost active invocation (the paper's "fast and straightforward"
//! approximation).

use std::collections::HashMap;
use crate::util::FastMap;

use super::dataflow::MEM_GRANULE_SHIFT;
use crate::interp::{Instrument, TraceEvent};
use crate::ir::{BlockId, LoopInfo, Program, Reg};
use crate::util::Json;

#[derive(Debug)]
struct Invocation {
    loop_idx: usize,
    /// Index of the currently open iteration (None between iterations —
    /// during header evaluation — and before the first body entry).
    open_iter: Option<u64>,
    reg_writer: FastMap<Reg, u64>,
    mem_writer: FastMap<u64, u64>,
    iter_depths: Vec<u32>,
    cur_dep: u32,
    max_depth: u32,
}

impl Invocation {
    fn new(loop_idx: usize) -> Self {
        Invocation {
            loop_idx,
            open_iter: None,
            reg_writer: FastMap::default(),
            mem_writer: FastMap::default(),
            iter_depths: Vec::new(),
            cur_dep: 0,
            max_depth: 0,
        }
    }

    fn open_iteration(&mut self) {
        debug_assert!(self.open_iter.is_none());
        self.open_iter = Some(self.iter_depths.len() as u64);
        self.cur_dep = 0;
    }

    fn close_iteration(&mut self) {
        if self.open_iter.take().is_some() {
            let d = self.cur_dep + 1;
            self.iter_depths.push(d);
            self.max_depth = self.max_depth.max(d);
        }
    }
}

/// Streaming PBBLP analyzer (constructed per program: needs its LoopInfo).
pub struct PbblpAnalyzer {
    header_of: HashMap<BlockId, usize>,
    loops: Vec<LoopInfo>,
    stack: Vec<Invocation>,
    weighted_sum: f64,
    weight: u64,
    invocations: u64,
}

/// Finalized PBBLP numbers.
#[derive(Debug, Clone)]
pub struct PbblpResult {
    /// Iteration-weighted mean of per-invocation (iters / critical chain).
    pub pbblp: f64,
    pub invocations: u64,
    pub iterations: u64,
}

impl PbblpAnalyzer {
    pub fn new(prog: &Program) -> Self {
        PbblpAnalyzer {
            header_of: prog
                .loops
                .iter()
                .enumerate()
                .map(|(i, l)| (l.header, i))
                .collect(),
            loops: prog.loops.clone(),
            stack: Vec::new(),
            weighted_sum: 0.0,
            weight: 0,
            invocations: 0,
        }
    }

    fn pop_invocation(&mut self) {
        let mut inv = self.stack.pop().expect("pop on empty loop stack");
        inv.close_iteration(); // no-op if already closed at header
        let iters = inv.iter_depths.len() as u64;
        if iters > 0 {
            let ratio = iters as f64 / inv.max_depth.max(1) as f64;
            self.weighted_sum += ratio * iters as f64;
            self.weight += iters;
        }
        self.invocations += 1;
    }

    pub fn finalize(&mut self) -> PbblpResult {
        while !self.stack.is_empty() {
            self.pop_invocation();
        }
        PbblpResult {
            pbblp: if self.weight == 0 {
                1.0 // no loops executed: trivially serial
            } else {
                self.weighted_sum / self.weight as f64
            },
            invocations: self.invocations,
            iterations: self.weight,
        }
    }
}

// Chunk delivery uses the default `on_chunk` (a statically-dispatched loop
// over `on_event` — there is no per-chunk state worth hoisting here).
impl Instrument for PbblpAnalyzer {
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { block } => {
                // 1) re-entering the active loop's header closes an iteration
                if let Some(top) = self.stack.last_mut() {
                    let li = self.loops[top.loop_idx];
                    if *block == li.header {
                        top.close_iteration();
                        return;
                    }
                    if *block == li.body {
                        top.open_iteration();
                        return;
                    }
                    if *block == li.exit {
                        self.pop_invocation();
                        return;
                    }
                }
                // 2) entering some loop's header for the first time
                if let Some(&idx) = self.header_of.get(block) {
                    self.stack.push(Invocation::new(idx));
                }
            }
            TraceEvent::Instr(i) => {
                let Some(top) = self.stack.last_mut() else {
                    return;
                };
                let Some(cur) = top.open_iter else {
                    return; // header evaluation, not an iteration body
                };
                let counter = self.loops[top.loop_idx].counter;
                let mut dep = top.cur_dep;
                for &s in i.sources() {
                    if s == counter {
                        continue;
                    }
                    if let Some(&j) = top.reg_writer.get(&s) {
                        if j != cur {
                            dep = dep.max(top.iter_depths[j as usize]);
                        }
                    }
                }
                if let Some(m) = i.mem {
                    let granule = m.addr >> MEM_GRANULE_SHIFT;
                    if m.is_store {
                        top.mem_writer.insert(granule, cur);
                    } else if let Some(&j) = top.mem_writer.get(&granule) {
                        if j != cur {
                            dep = dep.max(top.iter_depths[j as usize]);
                        }
                    }
                }
                if let Some(d) = i.dst {
                    if d != counter {
                        top.reg_writer.insert(d, cur);
                    }
                }
                top.cur_dep = dep;
            }
            TraceEvent::Branch { .. } => {}
        }
    }
}

impl PbblpResult {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pbblp", self.pbblp);
        j.set("invocations", self.invocations);
        j.set("iterations", self.iterations);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    fn pbblp_of(p: &crate::ir::Program) -> PbblpResult {
        let mut a = PbblpAnalyzer::new(p);
        run_program(p, &mut a).unwrap();
        a.finalize()
    }

    #[test]
    fn data_parallel_loop_scores_trip_count() {
        // a[i] = 2·b[i]: no cross-iteration deps → ratio = N.
        let mut b = ProgramBuilder::new("par");
        let src: Vec<f64> = (0..128).map(|i| i as f64).collect();
        let bb = b.alloc_f64_init("b", &src);
        let aa = b.alloc_f64("a", 128);
        let n = b.const_i(128);
        let two = b.const_f(2.0);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(bb, i);
            let w = b.fmul(v, two);
            b.store_f64(aa, i, w);
        });
        let r = pbblp_of(&b.finish(None));
        assert_eq!(r.iterations, 128);
        assert!((r.pbblp - 128.0).abs() < 1e-9, "pbblp {}", r.pbblp);
    }

    #[test]
    fn reduction_scores_near_one() {
        let mut b = ProgramBuilder::new("red");
        let src: Vec<f64> = (0..128).map(|i| i as f64).collect();
        let aa = b.alloc_f64_init("a", &src);
        let acc = b.const_f(0.0);
        let n = b.const_i(128);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(aa, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        let r = pbblp_of(&b.finish(Some(acc)));
        assert!((r.pbblp - 1.0).abs() < 1e-9, "pbblp {}", r.pbblp);
    }

    #[test]
    fn recurrence_through_memory_is_serial() {
        // a[i] = a[i-1] + 1 : loop-carried memory dep.
        let mut b = ProgramBuilder::new("rec");
        let aa = b.alloc_f64("a", 129);
        let one = b.const_i(1);
        let n = b.const_i(128);
        let fone = b.const_f(1.0);
        b.counted_loop(n, |b, i| {
            let prev = b.load_f64(aa, i);
            let v = b.fadd(prev, fone);
            let ip1 = b.add(i, one);
            b.store_f64(aa, ip1, v);
        });
        let r = pbblp_of(&b.finish(None));
        assert!(r.pbblp < 1.5, "pbblp {}", r.pbblp);
    }

    #[test]
    fn nested_loop_attributes_to_innermost() {
        // outer 4 × inner 32, inner is data-parallel → inner invocations
        // dominate the weight: PBBLP close to 32.
        let mut b = ProgramBuilder::new("nest");
        let aa = b.alloc_f64("a", 4 * 32);
        let n = b.const_i(4);
        let m = b.const_i(32);
        b.counted_loop(n, |b, i| {
            b.counted_loop(m, |b, j| {
                let idx = b.idx2(i, j, 32);
                let c = b.const_f(1.0);
                b.store_f64(aa, idx, c);
            });
        });
        let r = pbblp_of(&b.finish(None));
        assert_eq!(r.invocations, 5);
        assert_eq!(r.iterations, 4 + 4 * 32);
        assert!(r.pbblp > 25.0, "pbblp {}", r.pbblp);
    }

    #[test]
    fn no_loops_defaults_to_one() {
        let mut b = ProgramBuilder::new("flat");
        let x = b.const_f(1.0);
        b.fadd(x, x);
        let r = pbblp_of(&b.finish(None));
        assert_eq!(r.pbblp, 1.0);
        assert_eq!(r.invocations, 0);
    }
}
