//! Basic-block-level parallelism (paper §II-B, Fig 3c).
//!
//! A basic block is "the smallest component that can be considered as a
//! potential parallelizable task"; each *dynamic BB instance* is treated as
//! an atomic sequential task, and BBLP is the dataflow parallelism over the
//! task DAG: instance depth = 1 + max(depth of instances that produced its
//! register or memory inputs). Intra-instance dependences don't count (the
//! task is sequential anyway).
//!
//! Like ILP, BBLP is computed for bounded scheduling scopes: windows of
//! W ∈ {16, 64, 256} consecutive BB instances plus the unbounded case.
//! The paper's Fig 3c series BBLP_1..BBLP_4 map to W = 16, 64, 256, ∞ in
//! that order (BBLP_1 = the most restrictive scheduler — the one the paper
//! singles out as lowest for NMC-friendly applications).

use super::dataflow::MEM_GRANULE_SHIFT;
use crate::util::FastMap;
use crate::interp::{Instrument, TraceEvent};
use crate::util::Json;

/// BB-instance window sizes; `None` = unbounded.
pub const BBLP_WINDOWS: [Option<usize>; 4] = [Some(16), Some(64), Some(256), None];

#[derive(Debug, Clone)]
struct BbTracker {
    window: Option<usize>,
    gen: u32,
    reg_writer: Vec<(u32, u64)>,          // reg -> (gen, instance)
    mem_writer: FastMap<u64, (u32, u64)>, // granule -> (gen, instance)
    depths: Vec<u32>,                     // depth per instance since window start
    base: u64,                            // first instance id of current window
    max_depth: u32,
    in_window: u64,
    weighted_sum: f64,
    weight: u64,
}

impl BbTracker {
    fn new(window: Option<usize>, n_regs: u16) -> Self {
        BbTracker {
            window,
            gen: 1,
            reg_writer: vec![(0, 0); n_regs as usize],
            mem_writer: FastMap::default(),
            depths: Vec::new(),
            base: 0,
            max_depth: 0,
            in_window: 0,
            weighted_sum: 0.0,
            weight: 0,
        }
    }

    #[inline]
    fn producer_depth(&self, inst: u64) -> u32 {
        self.depths
            .get((inst - self.base) as usize)
            .copied()
            .unwrap_or(0)
    }

    fn close_instance(&mut self, inst: u64, dep_max: u32) {
        debug_assert_eq!(inst - self.base, self.depths.len() as u64);
        let d = dep_max + 1;
        self.depths.push(d);
        self.max_depth = self.max_depth.max(d);
        self.in_window += 1;
        if let Some(w) = self.window {
            if self.in_window >= w as u64 {
                self.flush(inst + 1);
            }
        }
    }

    fn flush(&mut self, next_base: u64) {
        if self.in_window > 0 && self.max_depth > 0 {
            let par = self.in_window as f64 / self.max_depth as f64;
            self.weighted_sum += par * self.in_window as f64;
            self.weight += self.in_window;
        }
        self.gen += 1;
        self.depths.clear();
        self.base = next_base;
        self.max_depth = 0;
        self.in_window = 0;
    }

    fn value(&self) -> f64 {
        let mut sum = self.weighted_sum;
        let mut w = self.weight;
        if self.in_window > 0 && self.max_depth > 0 {
            sum += (self.in_window as f64 / self.max_depth as f64) * self.in_window as f64;
            w += self.in_window;
        }
        if w == 0 {
            0.0
        } else {
            sum / w as f64
        }
    }
}

/// Streaming BBLP analyzer (all windows in one pass).
pub struct BblpAnalyzer {
    trackers: Vec<BbTracker>,
    cur_instance: u64,
    started: bool,
    /// Max producer depth seen by the current instance, per tracker.
    cur_dep: Vec<u32>,
}

/// Finalized BBLP numbers.
#[derive(Debug, Clone)]
pub struct BblpResult {
    /// Parallel to [`BBLP_WINDOWS`]: BBLP_1..BBLP_4.
    pub values: Vec<f64>,
    pub instances: u64,
}

impl BblpAnalyzer {
    pub fn new(n_regs: u16) -> Self {
        BblpAnalyzer {
            trackers: BBLP_WINDOWS
                .iter()
                .map(|&w| BbTracker::new(w, n_regs))
                .collect(),
            cur_instance: 0,
            started: false,
            cur_dep: vec![0; BBLP_WINDOWS.len()],
        }
    }

    fn begin_instance(&mut self) {
        if self.started {
            let inst = self.cur_instance;
            for (t, &dep) in self.trackers.iter_mut().zip(&self.cur_dep) {
                t.close_instance(inst, dep);
            }
            self.cur_instance += 1;
        }
        self.started = true;
        self.cur_dep.iter_mut().for_each(|d| *d = 0);
    }

    /// Close the final open instance. Must be called after the run; `values`
    /// are meaningless otherwise.
    pub fn finalize(&mut self) -> BblpResult {
        if self.started {
            let inst = self.cur_instance;
            for (t, &dep) in self.trackers.iter_mut().zip(&self.cur_dep) {
                t.close_instance(inst, dep);
            }
            self.cur_instance += 1;
            self.started = false;
        }
        BblpResult {
            values: self.trackers.iter().map(|t| t.value()).collect(),
            instances: self.cur_instance,
        }
    }
}

// Chunk delivery uses the default `on_chunk` (a statically-dispatched loop
// over `on_event` — there is no per-chunk state worth hoisting here).
impl Instrument for BblpAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { .. } => self.begin_instance(),
            TraceEvent::Instr(i) => {
                let cur = self.cur_instance;
                for (ti, t) in self.trackers.iter_mut().enumerate() {
                    let mut dep = self.cur_dep[ti];
                    for &s in i.sources() {
                        let (g, w) = t.reg_writer[s as usize];
                        if g == t.gen && w != cur && w >= t.base {
                            dep = dep.max(t.producer_depth(w));
                        }
                    }
                    if let Some(m) = i.mem {
                        let granule = m.addr >> MEM_GRANULE_SHIFT;
                        if m.is_store {
                            t.mem_writer.insert(granule, (t.gen, cur));
                        } else if let Some(&(g, w)) = t.mem_writer.get(&granule) {
                            if g == t.gen && w != cur && w >= t.base {
                                dep = dep.max(t.producer_depth(w));
                            }
                        }
                    }
                    if let Some(d) = i.dst {
                        t.reg_writer[d as usize] = (t.gen, cur);
                    }
                    self.cur_dep[ti] = dep;
                }
            }
            TraceEvent::Branch { .. } => {}
        }
    }
}

impl BblpResult {
    pub fn bblp_1(&self) -> f64 {
        self.values[0]
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (i, v) in self.values.iter().enumerate() {
            j.set(&format!("bblp_{}", i + 1), *v);
        }
        j.set("instances", self.instances);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    fn bblp_of(p: &crate::ir::Program) -> BblpResult {
        let mut a = BblpAnalyzer::new(p.func.n_regs);
        run_program(p, &mut a).unwrap();
        a.finalize()
    }

    #[test]
    fn serial_accumulator_low_bblp() {
        // every body instance reads+writes acc ⇒ body instances chain.
        let mut b = ProgramBuilder::new("ser");
        let a = b.alloc_f64("a", 512);
        let acc = b.const_f(0.0);
        let n = b.const_i(512);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        let r = bblp_of(&b.finish(Some(acc)));
        // headers + bodies chain via acc and i: parallelism stays near 1..2
        assert!(r.bblp_1() < 2.0, "bblp_1 {}", r.bblp_1());
    }

    #[test]
    fn instance_count_matches_dyn_blocks() {
        let mut b = ProgramBuilder::new("c");
        let n = b.const_i(10);
        b.counted_loop(n, |b, i| {
            b.add_i(i, 0);
        });
        let p = b.finish(None);
        let mut a = BblpAnalyzer::new(p.func.n_regs);
        let (out, _) = run_program(&p, &mut a).unwrap();
        let r = a.finalize();
        assert_eq!(r.instances, out.stats.dyn_blocks);
    }

    #[test]
    fn windows_all_reported() {
        let mut b = ProgramBuilder::new("w");
        let n = b.const_i(100);
        b.counted_loop(n, |b, i| {
            b.add_i(i, 1);
        });
        let r = bblp_of(&b.finish(None));
        assert_eq!(r.values.len(), 4);
        for v in &r.values {
            assert!(*v >= 0.99, "{:?}", r.values);
        }
    }

    #[test]
    fn independent_block_stream_has_higher_bblp_than_chained() {
        // chained: each iteration stores then loads the same cell.
        let chained = {
            let mut b = ProgramBuilder::new("ch");
            let a = b.alloc_f64("a", 1);
            let n = b.const_i(256);
            let z = b.const_i(0);
            b.counted_loop(n, |b, _i| {
                let v = b.load_f64(a, z);
                let w = b.fadd(v, v);
                b.store_f64(a, z, w);
            });
            bblp_of(&b.finish(None))
        };
        // independent: disjoint cells.
        let indep = {
            let mut b = ProgramBuilder::new("ind");
            let a = b.alloc_f64("a", 256);
            let n = b.const_i(256);
            b.counted_loop(n, |b, i| {
                let c = b.const_f(2.0);
                b.store_f64(a, i, c);
            });
            bblp_of(&b.finish(None))
        };
        // the loop-counter chain still serializes headers, but the memory
        // chain in `chained` must not make it *more* parallel
        assert!(indep.values[3] >= chained.values[3] - 1e-9);
    }
}
