//! Spatial-locality score (paper §II-A, Fig 3b), derived from the exact DTR
//! results of [`super::reuse`].
//!
//! score(l→l+1) = clamp((d_l − d_{l+1}) / d_l, 0, 1): the relative reduction
//! in mean reuse distance when the line size doubles. Near 1 ⇒ strong
//! spatial reuse (doubling the line halves the stack distance); near 0 ⇒
//! the extra bytes fetched with each line are never used — the paper's
//! signature of an NMC-friendly (cache-hostile) access pattern.
//!
//! The native implementation here is the reference; the coordinator also
//! routes the binned histograms through the AOT `spatial.hlo.txt` Pallas
//! artifact and cross-checks the two (they differ only by log2-binning of
//! the distance distribution).
//!
//! Spatial locality has no event-consuming analyzer of its own: its entire
//! input is the DTR distribution `reuse` folds by sweeping the dense
//! [`crate::interp::ChunkLanes`] address lane — so the whole
//! reuse→spatial family runs off the SoA chunk view, never matching
//! `TraceEvent` per event on the hot path.

use super::reuse::{ReuseResult, LINE_SHIFTS, N_LINE_SIZES};
use crate::util::Json;

/// Finalized spatial-locality scores.
#[derive(Debug, Clone)]
pub struct SpatialResult {
    /// score[l] for doubling LINE_SHIFTS[l] → LINE_SHIFTS[l+1]; length L-1.
    pub scores: Vec<f64>,
    /// Mean DTR per line size (copied from the reuse result for reporting).
    pub avg_dtr: Vec<f64>,
}

/// Compute scores from mean DTR distances.
pub fn spatial_scores(avg_dtr: &[f64]) -> Vec<f64> {
    avg_dtr
        .windows(2)
        .map(|w| {
            if w[0] <= 1e-12 {
                0.0
            } else {
                ((w[0] - w[1]) / w[0]).clamp(0.0, 1.0)
            }
        })
        .collect()
}

pub fn from_reuse(r: &ReuseResult) -> SpatialResult {
    SpatialResult {
        scores: spatial_scores(&r.avg_dtr),
        avg_dtr: r.avg_dtr.clone(),
    }
}

impl SpatialResult {
    /// The paper's Fig-6 PCA feature: score for the 8B→16B doubling.
    pub fn spat_8b_16b(&self) -> f64 {
        self.scores.first().copied().unwrap_or(0.0)
    }

    /// Mean score across all doublings (overall spatial-locality summary,
    /// used in the Fig 3b characterization).
    pub fn mean_score(&self) -> f64 {
        if self.scores.is_empty() {
            0.0
        } else {
            self.scores.iter().sum::<f64>() / self.scores.len() as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let labels: Vec<Json> = LINE_SHIFTS
            .windows(2)
            .map(|w| Json::Str(format!("spat_{}B_{}B", 1u64 << w[0], 1u64 << w[1])))
            .collect();
        j.set("labels", labels);
        j.set("scores", self.scores.clone());
        j.set("avg_dtr", self.avg_dtr.clone());
        j.set("spat_8B_16B", self.spat_8b_16b());
        j.set("mean_score", self.mean_score());
        j
    }
}

/// Label helper for figures: e.g. index 0 → "spat_8B_16B".
pub fn score_label(idx: usize) -> String {
    assert!(idx + 1 < N_LINE_SIZES);
    format!(
        "spat_{}B_{}B",
        1u64 << LINE_SHIFTS[idx],
        1u64 << LINE_SHIFTS[idx + 1]
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::reuse::ReuseAnalyzer;

    #[test]
    fn halving_distances_scores_half() {
        let scores = spatial_scores(&[64.0, 32.0, 16.0, 8.0]);
        assert_eq!(scores, vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn growth_clamps_to_zero() {
        let scores = spatial_scores(&[10.0, 20.0, 5.0]);
        assert_eq!(scores[0], 0.0);
        assert!((scores[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn zero_distance_guard() {
        assert_eq!(spatial_scores(&[0.0, 0.0]), vec![0.0]);
    }

    #[test]
    fn sequential_stream_scores_high_random_scores_low() {
        // sequential 8B walk → strong score at small line sizes
        let mut seq = ReuseAnalyzer::new();
        for i in 0..8192u64 {
            seq.record(0x1_0000 + i * 8);
        }
        let s_seq = from_reuse(&seq.finalize());

        // random large-stride walk → no spatial reuse below the stride
        let mut rng = crate::util::Rng::new(4);
        let mut rnd = ReuseAnalyzer::new();
        for _ in 0..8192 {
            rnd.record(0x1_0000 + rng.below(4096) * 1024);
        }
        let s_rnd = from_reuse(&rnd.finalize());

        assert!(
            s_seq.spat_8b_16b() > 0.4,
            "sequential 8B→16B score {}",
            s_seq.spat_8b_16b()
        );
        assert!(
            s_rnd.spat_8b_16b() < 0.05,
            "random 8B→16B score {}",
            s_rnd.spat_8b_16b()
        );
        assert!(s_seq.mean_score() > s_rnd.mean_score());
    }

    #[test]
    fn labels() {
        assert_eq!(score_label(0), "spat_8B_16B");
        assert_eq!(score_label(6), "spat_512B_1024B");
    }
}
