//! Instruction-mix analyzer (PISA baseline metric).
//!
//! Counts dynamic instructions per opcode and per class; the mix fractions
//! feed the machine models' cost estimates and the report's
//! characterization table.

use crate::interp::{
    ChunkLanes, Instrument, LaneMask, TraceEvent, TAG_BLOCK, TAG_BR_NOT, TAG_BR_TAKEN,
};
use crate::ir::{Op, OpClass};
use crate::util::Json;

/// Dynamic instruction mix.
#[derive(Debug, Clone)]
pub struct MixAnalyzer {
    pub per_op: [u64; Op::COUNT],
    pub branches: u64,
    pub blocks: u64,
}

impl Default for MixAnalyzer {
    fn default() -> Self {
        MixAnalyzer {
            per_op: [0; Op::COUNT],
            branches: 0,
            blocks: 0,
        }
    }
}

impl MixAnalyzer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total(&self) -> u64 {
        self.per_op.iter().sum::<u64>() + self.branches
    }

    pub fn count_class(&self, class: OpClass) -> u64 {
        (0..Op::COUNT)
            .filter(|&i| Op::from_index(i).unwrap().class() == class)
            .map(|i| self.per_op[i])
            .sum()
    }

    /// Fraction of dynamic instructions in `class` (branches included in the
    /// denominator as control instructions).
    pub fn fraction(&self, class: OpClass) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.count_class(class) as f64 / t as f64
    }

    pub fn control_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.branches as f64 / t as f64
    }

    /// Loads+stores per instruction — the "memory intensity" the paper's
    /// intro argues drives NMC benefit.
    pub fn memory_fraction(&self) -> f64 {
        self.fraction(OpClass::Load) + self.fraction(OpClass::Store)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("total", self.total());
        j.set("blocks", self.blocks);
        j.set("branches", self.branches);
        let mut ops = Json::obj();
        for i in 0..Op::COUNT {
            if self.per_op[i] > 0 {
                ops.set(Op::from_index(i).unwrap().mnemonic(), self.per_op[i]);
            }
        }
        j.set("per_op", ops);
        let mut cls = Json::obj();
        for (name, c) in [
            ("int_arith", OpClass::IntArith),
            ("float_arith", OpClass::FloatArith),
            ("compare", OpClass::Compare),
            ("convert", OpClass::Convert),
            ("data_move", OpClass::DataMove),
            ("load", OpClass::Load),
            ("store", OpClass::Store),
        ] {
            cls.set(name, self.fraction(c));
        }
        cls.set("control", self.control_fraction());
        j.set("class_fractions", cls);
        j
    }
}

impl Instrument for MixAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::Instr(i) => self.per_op[i.op.index()] += 1,
            TraceEvent::Branch { .. } => self.branches += 1,
            TraceEvent::BlockEnter { .. } => self.blocks += 1,
        }
    }

    /// Lane path (the hot path): sweep the dense one-byte op-tag lane — no
    /// enum unpacking per event. Branch/block tallies accumulate in
    /// registers and hit the struct once per chunk; only the per-op
    /// histogram is touched per instruction.
    fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
        let (mut branches, mut blocks) = (0u64, 0u64);
        for &tag in lanes.tags() {
            match tag {
                TAG_BLOCK => blocks += 1,
                TAG_BR_TAKEN | TAG_BR_NOT => branches += 1,
                op => self.per_op[op as usize] += 1,
            }
        }
        self.branches += branches;
        self.blocks += blocks;
    }

    fn wants_lanes(&self) -> bool {
        true
    }

    fn lane_needs(&self) -> LaneMask {
        LaneMask::TAGS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::run_program;
    use crate::ir::ProgramBuilder;

    #[test]
    fn lane_sweep_matches_per_event() {
        let mut b = ProgramBuilder::new("t");
        let a = b.alloc_f64_init("a", &[1.0, 2.0, 3.0, 4.0]);
        let n = b.const_i(4);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, v);
            b.store_f64(a, i, w);
        });
        let p = b.finish(None);
        // chunked run goes through the lane sweep (wants_lanes), per-event
        // through on_event — identical tallies
        let mut lane = MixAnalyzer::new();
        let mut per_event = MixAnalyzer::new();
        crate::interp::Machine::new(&p).unwrap().run(&mut lane).unwrap();
        crate::interp::Machine::new(&p).unwrap().run_per_event(&mut per_event).unwrap();
        assert_eq!(lane.per_op, per_event.per_op);
        assert_eq!(lane.branches, per_event.branches);
        assert_eq!(lane.blocks, per_event.blocks);
    }

    #[test]
    fn counts_loop_mix() {
        let mut b = ProgramBuilder::new("t");
        let a = b.alloc_f64_init("a", &[1.0, 2.0, 3.0, 4.0]);
        let n = b.const_i(4);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, v);
            b.store_f64(a, i, w);
        });
        let p = b.finish(None);
        let mut mix = MixAnalyzer::new();
        run_program(&p, &mut mix).unwrap();
        assert_eq!(mix.per_op[Op::Load.index()], 4);
        assert_eq!(mix.per_op[Op::Store.index()], 4);
        assert_eq!(mix.per_op[Op::FMul.index()], 4);
        assert_eq!(mix.branches, 5);
        assert!(mix.memory_fraction() > 0.0);
        let total_fracs: f64 = [
            OpClass::IntArith,
            OpClass::FloatArith,
            OpClass::Compare,
            OpClass::Convert,
            OpClass::DataMove,
            OpClass::Load,
            OpClass::Store,
        ]
        .iter()
        .map(|&c| mix.fraction(c))
        .sum::<f64>()
            + mix.control_fraction();
        assert!((total_fracs - 1.0).abs() < 1e-12);
    }
}
