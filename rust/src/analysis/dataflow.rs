//! Shared dynamic-dataflow machinery for the parallelism metrics (ILP, DLP,
//! BBLP): last-writer tracking over registers and memory granules, and the
//! classic "depth = 1 + max(producer depths)" recurrence under an idealized
//! machine (infinite resources, perfect renaming — dependencies only).

use crate::interp::InstrEvent;
use crate::util::FastMap;

/// Memory dependences are tracked at 8-byte granularity — every value in
/// the mini-IR is at most 8 bytes and buffers are 64B-aligned, so this is
/// exact for the workloads here.
pub const MEM_GRANULE_SHIFT: u8 = 3;

/// Dataflow-depth tracker with O(1) generation-based reset (used by the
/// windowed ILP variants: resetting per window must not reallocate).
#[derive(Debug, Clone)]
pub struct DepthTracker {
    reg_depth: Vec<(u32, u32)>, // (gen, depth)
    mem_depth: FastMap<u64, (u32, u32)>,
    /// Registers whose dependences are ignored (per-reg mask). Used by the
    /// DLP analyzer to exclude induction-variable chains: a vectorizer
    /// strength-reduces the counter, so the i → i+1 chain must not serialize
    /// otherwise-independent iterations.
    ignore: Vec<bool>,
    gen: u32,
    pub max_depth: u32,
    pub count: u64,
}

impl DepthTracker {
    pub fn new(n_regs: u16) -> Self {
        DepthTracker {
            reg_depth: vec![(0, 0); n_regs as usize],
            mem_depth: FastMap::default(),
            ignore: vec![false; n_regs as usize],
            gen: 1,
            max_depth: 0,
            count: 0,
        }
    }

    /// Ignore dependences through `regs` (loop counters).
    pub fn with_ignored(n_regs: u16, regs: &[u16]) -> Self {
        let mut t = Self::new(n_regs);
        for &r in regs {
            if (r as usize) < t.ignore.len() {
                t.ignore[r as usize] = true;
            }
        }
        t
    }

    /// Forget all dependences (window boundary). O(1).
    pub fn reset(&mut self) {
        self.gen += 1;
        self.max_depth = 0;
        self.count = 0;
    }

    /// Record one executed instruction; returns its dataflow depth.
    #[inline]
    pub fn observe(&mut self, ev: &InstrEvent) -> u32 {
        let mut prod = 0u32;
        for &s in ev.sources() {
            if self.ignore[s as usize] {
                continue;
            }
            let (g, d) = self.reg_depth[s as usize];
            if g == self.gen {
                prod = prod.max(d);
            }
        }
        if let Some(m) = ev.mem {
            let granule = m.addr >> MEM_GRANULE_SHIFT;
            if m.is_store {
                // store depends on its sources only (handled above); it
                // *defines* the granule below.
                let d = prod + 1;
                self.mem_depth.insert(granule, (self.gen, d));
                self.count += 1;
                self.max_depth = self.max_depth.max(d);
                return d;
            } else if let Some(&(g, d)) = self.mem_depth.get(&granule) {
                if g == self.gen {
                    prod = prod.max(d);
                }
            }
        }
        let d = prod + 1;
        if let Some(dst) = ev.dst {
            self.reg_depth[dst as usize] = (self.gen, d);
        }
        self.count += 1;
        self.max_depth = self.max_depth.max(d);
        d
    }

    /// Parallelism of everything seen since the last reset.
    pub fn parallelism(&self) -> f64 {
        if self.max_depth == 0 {
            return 0.0;
        }
        self.count as f64 / self.max_depth as f64
    }
}

/// Growable bitset over u32 keys with insertion counting — tracks the
/// distinct dataflow levels each opcode occupies (DLP) without a HashSet's
/// per-entry overhead.
#[derive(Debug, Clone, Default)]
pub struct LevelSet {
    words: Vec<u64>,
    distinct: u64,
}

impl LevelSet {
    /// Insert `level`; returns true if newly inserted.
    #[inline]
    pub fn insert(&mut self, level: u32) -> bool {
        let w = (level >> 6) as usize;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let bit = 1u64 << (level & 63);
        if self.words[w] & bit == 0 {
            self.words[w] |= bit;
            self.distinct += 1;
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> u64 {
        self.distinct
    }

    pub fn is_empty(&self) -> bool {
        self.distinct == 0
    }
}

/// Helper constructing an InstrEvent for unit tests of the trackers.
#[cfg(test)]
pub fn test_event(op: crate::ir::Op, dst: Option<u16>, srcs: &[u16]) -> InstrEvent {
    let mut s = [0u16; 3];
    s[..srcs.len()].copy_from_slice(srcs);
    InstrEvent {
        op,
        dst,
        srcs: s,
        n_srcs: srcs.len() as u8,
        mem: None,
        block: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::MemAccess;
    use crate::ir::Op;

    #[test]
    fn independent_chain_depths() {
        let mut t = DepthTracker::new(8);
        // two independent adds: both depth 1
        assert_eq!(t.observe(&test_event(Op::Add, Some(0), &[4, 5])), 1);
        assert_eq!(t.observe(&test_event(Op::Add, Some(1), &[6, 7])), 1);
        // dependent on both: depth 2
        assert_eq!(t.observe(&test_event(Op::Add, Some(2), &[0, 1])), 2);
        assert!((t.parallelism() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn memory_carried_dependence() {
        let mut t = DepthTracker::new(4);
        let mut store = test_event(Op::Store, None, &[0, 1]);
        store.mem = Some(MemAccess { addr: 0x100, size: 8, is_store: true });
        let d_store = t.observe(&store);
        let mut load = test_event(Op::Load, Some(2), &[3]);
        load.mem = Some(MemAccess { addr: 0x100, size: 8, is_store: false });
        let d_load = t.observe(&load);
        assert_eq!(d_load, d_store + 1, "load must depend on prior store");
    }

    #[test]
    fn reset_clears_dependences() {
        let mut t = DepthTracker::new(4);
        t.observe(&test_event(Op::Add, Some(0), &[1, 2]));
        t.observe(&test_event(Op::Add, Some(0), &[0, 0])); // depth 2
        assert_eq!(t.max_depth, 2);
        t.reset();
        assert_eq!(t.observe(&test_event(Op::Add, Some(3), &[0, 0])), 1);
    }

    #[test]
    fn levelset_counts_distinct() {
        let mut s = LevelSet::default();
        assert!(s.insert(3));
        assert!(!s.insert(3));
        assert!(s.insert(1000));
        assert_eq!(s.len(), 2);
    }
}
