//! Exact data-temporal-reuse (DTR) distance analyzer (paper §II-A).
//!
//! The DTR of an access is the number of *distinct* cache lines touched
//! since the previous access to the same line (Mattson stack distance).
//! Computed exactly with the Olken/Bennett–Kruskal algorithm: a Fenwick tree
//! over access timestamps holds a mark at each line's most recent access;
//! the distance is the mark count strictly between the previous access and
//! now — O(log n) per access instead of the O(n) naive stack.
//!
//! Tracked simultaneously for line sizes 8 B..1 KiB (shifts 3..=10), which
//! is exactly what the spatial-locality score needs (reduction in DTR when
//! doubling the line, Fig 3b).
//!
//! Cold-miss convention: a first-touch access is assigned a distance equal
//! to the line footprint at that moment (the number of distinct lines seen
//! before it) — "you would have missed however large the stack was". This
//! keeps streaming workloads comparable across line sizes; the convention is
//! applied uniformly and documented in DESIGN.md.


use crate::interp::{ChunkLanes, Instrument, LaneMask, TraceEvent};
use crate::util::{FastMap, Fenwick, Json};

/// Line-size shifts analyzed: 2^3 .. 2^10 bytes.
pub const LINE_SHIFTS: [u8; 8] = [3, 4, 5, 6, 7, 8, 9, 10];
pub const N_LINE_SIZES: usize = LINE_SHIFTS.len();
/// Log2 distance bins for the AOT spatial artifact.
pub const N_DIST_BINS: usize = 64;

/// Outcome of one [`StackDistance`] access, from the tracked stack's point
/// of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineDist {
    /// Same line as the immediately-previous access: distance 0, stack
    /// order unchanged (the fast path — nothing was updated).
    Repeat,
    /// A reuse: exactly this many *distinct* lines were touched since the
    /// previous access to this line (Mattson stack distance).
    Reuse(u64),
    /// First touch (compulsory/cold); carries the line footprint *before*
    /// this access — the repo's documented cold-miss convention ("you
    /// would have missed however large the stack was").
    Cold(u64),
}

/// The exact Olken/Bennett–Kruskal stack-distance kernel: a Fenwick tree
/// over access timestamps holds a mark at each line's most recent access;
/// the distance of a reuse is the mark count strictly between the previous
/// access and now — O(log n) per access instead of the O(n) naive stack.
///
/// Shared by the multi-line-size DTR trackers below and by the
/// `traffic` subsystem's one-pass miss-ratio curve (an access to a
/// fully-associative LRU cache of capacity `C` lines hits iff its stack
/// distance is `< C`), so both fold the trace exactly once.
#[derive(Debug, Clone)]
pub struct StackDistance {
    last: FastMap<u64, u64>,
    fen: Fenwick,
    time: u64,
    /// The immediately-previous line (fast path: an immediate repeat has
    /// distance 0 and moves nothing in the stack, so it needs neither the
    /// map nor the Fenwick — §Perf optimization; coarse-line trackers see
    /// long same-line runs on sequential code).
    last_line: u64,
}

impl Default for StackDistance {
    fn default() -> Self {
        Self::new()
    }
}

impl StackDistance {
    pub fn new() -> StackDistance {
        StackDistance {
            last: FastMap::default(),
            fen: Fenwick::new(),
            time: 0,
            last_line: u64::MAX,
        }
    }

    /// Record one access to `line` (an address already shifted to line
    /// granularity) and return its exact stack distance class.
    #[inline]
    pub fn access_line(&mut self, line: u64) -> LineDist {
        if line == self.last_line {
            return LineDist::Repeat;
        }
        self.last_line = line;
        let t = self.time;
        let out = match self.last.insert(line, t) {
            Some(prev) => {
                // distinct lines strictly between prev and t
                let d = self.fen.range_sum(prev as usize + 1, t as usize);
                self.fen.add(prev as usize, -1);
                LineDist::Reuse(d)
            }
            None => LineDist::Cold(self.last.len() as u64 - 1),
        };
        self.fen.add(t as usize, 1);
        self.time += 1;
        out
    }

    /// Distinct lines seen so far.
    pub fn footprint(&self) -> u64 {
        self.last.len() as u64
    }
}

#[derive(Debug, Clone)]
struct Tracker {
    shift: u8,
    sd: StackDistance,
    hist: [u64; N_DIST_BINS],
    sum_dist: f64,
    count: u64,
    cold: u64,
}

impl Tracker {
    fn new(shift: u8) -> Tracker {
        Tracker {
            shift,
            sd: StackDistance::new(),
            hist: [0; N_DIST_BINS],
            sum_dist: 0.0,
            count: 0,
            cold: 0,
        }
    }

    #[inline]
    fn access(&mut self, addr: u64) {
        let line = addr >> self.shift;
        let dist = match self.sd.access_line(line) {
            LineDist::Repeat => {
                // immediate repeat: distance 0, stack order unchanged — exact
                self.hist[0] += 1;
                self.count += 1;
                return;
            }
            LineDist::Reuse(d) => d,
            LineDist::Cold(footprint) => {
                self.cold += 1;
                footprint // footprint before this line
            }
        };
        self.sum_dist += dist as f64;
        self.count += 1;
        self.hist[dist_bin(dist)] += 1;
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_dist / self.count as f64
        }
    }
}

/// Sub-bins per octave: third-octave binning keeps the binned-mean error
/// within ~±12% of the exact mean (the spatial artifact's only
/// approximation vs the native exact path); 64 bins cover distances up to
/// 2^21 lines, saturating above.
const SUBS: usize = 3;

/// Third-octave log bin: 0 → bin 0; d ≥ 1 → 1 + 3·⌊log2 d⌋ + sub.
#[inline]
pub fn dist_bin(d: u64) -> usize {
    if d == 0 {
        return 0;
    }
    let k = 63 - d.leading_zeros() as usize;
    let frac = d as f64 / (1u64 << k) as f64; // [1, 2)
    let sub = ((frac - 1.0) * SUBS as f64) as usize;
    (1 + k * SUBS + sub.min(SUBS - 1)).min(N_DIST_BINS - 1)
}

/// Representative distance value per bin (geometric center of the bin
/// range) — must match the `binv` input the runtime feeds the spatial
/// artifact.
pub fn bin_values() -> [f32; N_DIST_BINS] {
    let mut v = [0f32; N_DIST_BINS];
    for (bin, slot) in v.iter_mut().enumerate().skip(1) {
        let k = (bin - 1) / SUBS;
        let sub = (bin - 1) % SUBS;
        let lo = (1u64 << k) as f64 * (1.0 + sub as f64 / SUBS as f64);
        let hi = (1u64 << k) as f64 * (1.0 + (sub + 1) as f64 / SUBS as f64);
        *slot = (lo * hi).sqrt() as f32;
    }
    v
}

/// Streaming multi-line-size exact reuse-distance analyzer. The chunk hot
/// path sweeps the dense packed-address lane of [`ChunkLanes`] (built once
/// per chunk and shared with `mem_entropy`/`mix`), so it keeps no private
/// address scratch of its own.
#[derive(Debug, Clone)]
pub struct ReuseAnalyzer {
    trackers: Vec<Tracker>,
}

/// Finalized DTR results.
#[derive(Debug, Clone)]
pub struct ReuseResult {
    /// Mean DTR (in lines) per line size, fine→coarse.
    pub avg_dtr: Vec<f64>,
    /// Log2-binned distance histograms per line size ([L][D]).
    pub hist: Vec<[u64; N_DIST_BINS]>,
    /// Cold (first-touch) accesses per line size.
    pub cold: Vec<u64>,
    /// Distinct lines per line size (footprint).
    pub footprint: Vec<u64>,
    pub accesses: u64,
}

impl Default for ReuseAnalyzer {
    fn default() -> Self {
        Self::new()
    }
}

impl ReuseAnalyzer {
    pub fn new() -> Self {
        ReuseAnalyzer { trackers: LINE_SHIFTS.iter().map(|&s| Tracker::new(s)).collect() }
    }

    #[inline]
    pub fn record(&mut self, addr: u64) {
        for t in &mut self.trackers {
            t.access(addr);
        }
    }

    pub fn finalize(&self) -> ReuseResult {
        ReuseResult {
            avg_dtr: self.trackers.iter().map(|t| t.mean()).collect(),
            hist: self.trackers.iter().map(|t| t.hist).collect(),
            cold: self.trackers.iter().map(|t| t.cold).collect(),
            footprint: self.trackers.iter().map(|t| t.sd.footprint()).collect(),
            accesses: self.trackers.first().map(|t| t.count).unwrap_or(0),
        }
    }
}

impl Instrument for ReuseAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            if let Some(m) = i.mem {
                self.record(m.addr);
            }
        }
    }

    /// Lane path (the hot path): the per-event loop over the 8 trackers is
    /// inverted. The chunk's addresses arrive already densely packed in the
    /// shared [`ChunkLanes`] view, and each tracker sweeps the whole slice —
    /// so one tracker's map/Fenwick state stays hot for thousands of
    /// accesses instead of being evicted 8 ways per event. Per-tracker
    /// order is unchanged, so distances are exact.
    fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
        let addrs = lanes.addrs();
        if addrs.is_empty() {
            return;
        }
        for t in &mut self.trackers {
            for &addr in addrs {
                t.access(addr);
            }
        }
    }

    fn wants_lanes(&self) -> bool {
        true
    }

    fn lane_needs(&self) -> LaneMask {
        LaneMask::ADDRS
    }
}

impl ReuseResult {
    /// Pack histograms into the fixed [L, D] fp32 matrix for the spatial
    /// artifact.
    pub fn to_artifact_hist(&self) -> Vec<f32> {
        let mut out = vec![0f32; N_LINE_SIZES * N_DIST_BINS];
        for (l, h) in self.hist.iter().enumerate() {
            for (d, &c) in h.iter().enumerate() {
                out[l * N_DIST_BINS + d] = c as f32;
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("avg_dtr", self.avg_dtr.clone());
        j.set(
            "cold",
            self.cold.iter().map(|&c| c as f64).collect::<Vec<f64>>(),
        );
        j.set(
            "footprint",
            self.footprint.iter().map(|&c| c as f64).collect::<Vec<f64>>(),
        );
        j.set("accesses", self.accesses);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// O(n²) oracle: exact stack distances with the same cold-miss
    /// convention.
    fn naive_distances(addrs: &[u64], shift: u8) -> Vec<u64> {
        let mut stack: Vec<u64> = Vec::new(); // most recent last
        let mut out = Vec::with_capacity(addrs.len());
        for &a in addrs {
            let line = a >> shift;
            if let Some(pos) = stack.iter().position(|&l| l == line) {
                out.push((stack.len() - 1 - pos) as u64);
                stack.remove(pos);
            } else {
                out.push(stack.len() as u64);
            }
            stack.push(line);
        }
        out
    }

    fn run_analyzer(addrs: &[u64]) -> ReuseResult {
        let mut r = ReuseAnalyzer::new();
        for &a in addrs {
            r.record(a);
        }
        r.finalize()
    }

    #[test]
    fn stack_distance_kernel_classes() {
        // a b c a : the 2nd 'a' reuses at distance 2 (b, c in between)
        let mut sd = StackDistance::new();
        assert_eq!(sd.access_line(10), LineDist::Cold(0));
        assert_eq!(sd.access_line(10), LineDist::Repeat);
        assert_eq!(sd.access_line(11), LineDist::Cold(1));
        assert_eq!(sd.access_line(12), LineDist::Cold(2));
        assert_eq!(sd.access_line(10), LineDist::Reuse(2));
        assert_eq!(sd.footprint(), 3);
        // a repeat after a reuse still short-circuits
        assert_eq!(sd.access_line(10), LineDist::Repeat);
        // LRU order after the reuse: [11, 12, 10] — touching 11 skips 12, 10
        assert_eq!(sd.access_line(11), LineDist::Reuse(2));
    }

    #[test]
    fn simple_reuse_pattern() {
        // a b c a : distance of 2nd 'a' is 2 (b, c touched in between)
        let addrs = [0u64, 64, 128, 0].map(|a| a + 0x1000);
        let r = run_analyzer(&addrs);
        // 64B lines (shift 6 = index 3): distances 0,1,2 cold + 2
        let want_mean = (0.0 + 1.0 + 2.0 + 2.0) / 4.0;
        assert!((r.avg_dtr[3] - want_mean).abs() < 1e-12, "{:?}", r.avg_dtr);
    }

    #[test]
    fn matches_naive_oracle_randomized() {
        let mut rng = Rng::new(77);
        let addrs: Vec<u64> = (0..2000)
            .map(|_| 0x1_0000 + rng.below(256) * 8)
            .collect();
        let r = run_analyzer(&addrs);
        for (li, &shift) in LINE_SHIFTS.iter().enumerate() {
            let naive = naive_distances(&addrs, shift);
            let want = naive.iter().map(|&d| d as f64).sum::<f64>() / naive.len() as f64;
            assert!(
                (r.avg_dtr[li] - want).abs() < 1e-9,
                "shift {shift}: got {} want {want}",
                r.avg_dtr[li]
            );
        }
    }

    #[test]
    fn sequential_stream_has_strong_spatial_signal() {
        // touching consecutive f64s: coarser lines see near-zero DTR
        let addrs: Vec<u64> = (0..4096u64).map(|i| 0x1_0000 + i * 8).collect();
        let r = run_analyzer(&addrs);
        // at 8B lines every access is cold → mean grows with footprint
        assert!(r.avg_dtr[0] > 100.0);
        // at 1KB lines, 127 of 128 accesses hit the open line → tiny mean
        assert!(r.avg_dtr[7] < r.avg_dtr[0] / 4.0, "{:?}", r.avg_dtr);
        // monotone non-increasing across line sizes for a sequential stream
        for w in r.avg_dtr.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn random_stream_entropy_insensitive_to_line_until_stride() {
        // random 8B-aligned accesses over 1024 lines of 8B: at shifts <= 3
        // distances are the same
        let mut rng = Rng::new(9);
        let addrs: Vec<u64> = (0..5000).map(|_| rng.below(1024) * 1024).collect();
        // stride 1KB ⇒ every line size below 1KB sees identical line ids
        let r = run_analyzer(&addrs);
        for li in 0..N_LINE_SIZES - 1 {
            assert!(
                (r.avg_dtr[li] - r.avg_dtr[li + 1]).abs() < 1e-9,
                "{:?}",
                r.avg_dtr
            );
        }
    }

    #[test]
    fn histogram_mass_equals_accesses() {
        let mut rng = Rng::new(13);
        let addrs: Vec<u64> = (0..3000).map(|_| rng.below(500) * 8).collect();
        let r = run_analyzer(&addrs);
        for h in &r.hist {
            assert_eq!(h.iter().sum::<u64>(), addrs.len() as u64);
        }
    }

    #[test]
    fn dist_bin_boundaries() {
        assert_eq!(dist_bin(0), 0);
        assert_eq!(dist_bin(1), 1);
        assert_eq!(dist_bin(2), 4); // octave 1, sub 0
        assert_eq!(dist_bin(3), 5); // octave 1, sub 1 (frac 1.5)
        assert_eq!(dist_bin(4), 7); // octave 2, sub 0
        assert_eq!(dist_bin(u64::MAX), N_DIST_BINS - 1);
        // bins are monotone in distance
        let mut prev = 0;
        for d in 0..10_000u64 {
            let b = dist_bin(d);
            assert!(b >= prev, "bin decreased at d={d}");
            prev = b;
        }
    }

    #[test]
    fn bin_values_monotone_and_representative() {
        let v = bin_values();
        assert_eq!(v[0], 0.0);
        for w in v.windows(2).skip(1) {
            assert!(w[1] > w[0]);
        }
        // every d maps to a bin whose representative is within ~±20%
        for d in [1u64, 2, 3, 7, 100, 12345, 1 << 18] {
            let rep = v[dist_bin(d)] as f64;
            assert!(
                (rep / d as f64) < 1.25 && (rep / d as f64) > 0.8,
                "d={d} rep={rep}"
            );
        }
    }
}
