//! Family-level shard planning and deterministic result merging for the
//! sharded pipeline ([`crate::interp::offload::sharded`]).
//!
//! The mechanism (chunk broadcast, countdown-return recycling) lives in
//! `interp`; this module owns the **policy**: which metric families fold
//! together on one worker, how many workers a [`MetricSet`] warrants, and
//! how the per-shard [`AnalyzerStack`]s merge back into one
//! [`AppMetrics`].
//!
//! Families group along the lane boundaries the SoA
//! [`ChunkLanes`](crate::interp::ChunkLanes) view already draws, so each
//! worker streams mostly its own lane. The `traffic` family is itself
//! **splittable** ([`TrafficParts`]): its MRC + byte-accounting half and
//! its hierarchy-replay half are independent folds over the address lane,
//! so they get separate groups — the two heaviest memory-side folds no
//! longer serialize on one worker:
//!
//! | group | families | traffic half | sweeps |
//! |---|---|---|---|
//! | tags  | `mix`, `branch`        | —         | op-tag lane / event slice |
//! | mem   | `mem_entropy`, `reuse` | MRC       | addrs / sizes / store lanes |
//! | hier  | —                      | hierarchy | addrs / store lanes |
//! | dep   | `ilp`, `dlp`           | —         | event slices (dataflow) |
//! | block | `bblp`, `pbblp`        | —         | event slices (block structure) |
//!
//! The `--sweep` grid replays ([`TrafficOpts::sweep`]) ride the `hier`
//! group: they are built exactly when the hierarchy half is enabled
//! (`TrafficAnalyzer::with_opts_parts`), fold the same address/store
//! lanes, and merge back through the same `HIERARCHY` adopt path — so a
//! K-point grid sweeps one broadcast chunk stream on one worker instead
//! of re-interpreting the app K times.
//!
//! `Workers::Auto` sizes the pool as one worker per non-empty group;
//! `Workers::Fixed(n)` packs the groups contiguously into at most `n`
//! shards (clamped so no shard is ever empty — `--metrics mix` collapses
//! to a single worker no matter what `--workers` asks for). The plan is a
//! pure function of the metric set, and the merge reads shards in plan
//! order, so sharded results are deterministic regardless of worker
//! scheduling.

use std::time::Instant;

use anyhow::Result;

use crate::fault::SuperviseOpts;
use crate::interp::{run_sharded_supervised, Instrument, Machine, Workers};
use crate::ir::Program;
use crate::sim::Region;
use crate::trace::{replay_sharded, TraceSource};
use crate::traffic::{TrafficOpts, TrafficParts};

use super::{AnalyzerStack, AppMetrics, ExecStats, Metric, MetricSet};

/// One canonical shard group: the families that fold together, plus the
/// half of the `traffic` family (if any) that rides with them.
#[derive(Debug, Clone, Copy)]
pub struct ShardGroup {
    pub name: &'static str,
    pub families: &'static [Metric],
    pub traffic: TrafficParts,
}

/// The canonical shard groups, in plan order. Every non-traffic family
/// appears in exactly one group and each [`TrafficParts`] half in exactly
/// one (pinned by a unit test), so any plan's shards are pairwise
/// disjoint and cover the enabled set.
pub const SHARD_GROUPS: [ShardGroup; 5] = [
    ShardGroup {
        name: "tags",
        families: &[Metric::Mix, Metric::Branch],
        traffic: TrafficParts::NONE,
    },
    ShardGroup {
        name: "mem",
        families: &[Metric::MemEntropy, Metric::Reuse],
        traffic: TrafficParts::MRC,
    },
    ShardGroup { name: "hier", families: &[], traffic: TrafficParts::HIERARCHY },
    ShardGroup {
        name: "dep",
        families: &[Metric::Ilp, Metric::Dlp],
        traffic: TrafficParts::NONE,
    },
    ShardGroup {
        name: "block",
        families: &[Metric::Bblp, Metric::Pbblp],
        traffic: TrafficParts::NONE,
    },
];

/// What one worker folds: a family subset plus the traffic halves it
/// owns. `metrics` includes [`Metric::Traffic`] exactly when `traffic` is
/// non-empty, so the per-shard stack allocates its traffic analyzer with
/// just those halves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub metrics: MetricSet,
    pub traffic: TrafficParts,
}

impl ShardSpec {
    fn none() -> ShardSpec {
        ShardSpec { metrics: MetricSet::none(), traffic: TrafficParts::NONE }
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty() && self.traffic.is_empty()
    }

    fn union(self, other: ShardSpec) -> ShardSpec {
        ShardSpec {
            metrics: self.metrics.union(other.metrics),
            traffic: self.traffic.union(other.traffic),
        }
    }
}

/// How the enabled metric families split across analyzer workers: one
/// [`ShardSpec`] per worker, pairwise disjoint (families *and* traffic
/// halves), union equal to the enabled set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    shards: Vec<ShardSpec>,
}

impl ShardPlan {
    /// Plan the worker pool for `metrics`. Never returns an empty plan:
    /// with no lane-aware family enabled the plan is one (possibly empty)
    /// shard, which keeps the topology total for metric-less runs.
    pub fn new(metrics: MetricSet, workers: Workers) -> Self {
        let groups: Vec<ShardSpec> = SHARD_GROUPS
            .iter()
            .map(|group| {
                let fams = group
                    .families
                    .iter()
                    .filter(|m| metrics.contains(**m))
                    .fold(MetricSet::none(), |set, &m| set.with(m));
                let traffic = if metrics.contains(Metric::Traffic) {
                    group.traffic
                } else {
                    TrafficParts::NONE
                };
                let fams = if traffic.is_empty() { fams } else { fams.with(Metric::Traffic) };
                ShardSpec { metrics: fams, traffic }
            })
            .filter(|spec| !spec.is_empty())
            .collect();
        if groups.is_empty() {
            return ShardPlan { shards: vec![ShardSpec::none()] };
        }
        let n = match workers {
            Workers::Auto => groups.len(),
            Workers::Fixed(n) => n.clamp(1, groups.len()),
        };
        // contiguous partition of the canonical group order into n shards;
        // the index map is monotone and surjective for n <= len, so every
        // shard receives at least one group
        let mut shards = vec![ShardSpec::none(); n];
        for (i, g) in groups.iter().enumerate() {
            let slot = i * n / groups.len();
            shards[slot] = shards[slot].union(*g);
        }
        ShardPlan { shards }
    }

    /// The resolved worker count.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Per-worker shard specs, in plan (= merge) order.
    pub fn shards(&self) -> &[ShardSpec] {
        &self.shards
    }
}

/// Run `prog` through the sharded pipeline: build one [`AnalyzerStack`]
/// per planned shard, broadcast every chunk to all of them, then merge
/// the per-shard results — in plan order, so the outcome is independent
/// of worker timing. With `with_tasks`, the task-trace collector rides
/// the last shard (the block-structure side of the canonical plan).
///
/// Under supervision (`sup`), a dead worker degrades the run instead of
/// failing it: the broadcaster keeps feeding the survivors the complete
/// stream, so their families merge bit-identically to a clean run, while
/// the dead shard's families are listed in [`AppMetrics::failed`] and
/// kept out of the merge (a mid-fold panic leaves analyzer state
/// half-applied). The region trace is forfeited if its carrier shard —
/// the last one — died.
pub(super) fn profile_sharded_run(
    prog: &Program,
    metrics: MetricSet,
    workers: Workers,
    opts: TrafficOpts,
    sup: SuperviseOpts,
    with_tasks: bool,
) -> Result<(AppMetrics, Option<Vec<Region>>)> {
    let plan = ShardPlan::new(metrics, workers);
    let mut stacks: Vec<AnalyzerStack> = plan
        .shards()
        .iter()
        .map(|spec| AnalyzerStack::new_parts(prog, spec.metrics, opts, spec.traffic))
        .collect();
    if with_tasks {
        let last = stacks.pop().expect("plan is never empty");
        stacks.push(last.with_task_trace(prog));
    }
    let mut machine = Machine::new(prog)?;
    let run = {
        let mut refs: Vec<&mut (dyn Instrument + Send)> = stacks
            .iter_mut()
            .map(|s| s as &mut (dyn Instrument + Send))
            .collect();
        run_sharded_supervised(&mut machine, &mut refs, sup)?
    };
    let mut dead = vec![false; plan.workers()];
    let mut dead_families = MetricSet::none();
    for f in &run.failures {
        if let Some(slot) = dead.get_mut(f.shard) {
            *slot = true;
            dead_families = dead_families.union(plan.shards()[f.shard].metrics);
        }
    }
    let (mut m, mut regions) = merge_shards(&plan, stacks, &dead, run.outcome.stats);
    m.failed = dead_families.names().iter().map(|s| s.to_string()).collect();
    if dead.last().copied().unwrap_or(false) {
        // the task collector rode the dead last shard; a truncated trace
        // would silently mis-shape the simulations
        regions = None;
    }
    Ok((m, regions))
}

/// The sharded delivery driven by a [`TraceSource`] instead of a live
/// [`Machine`]: same plan, same per-shard stacks, same deterministic
/// merge, but chunks come from the source (a recorded trace, or the
/// interpreter behind its adapter) via
/// [`replay_sharded`](crate::trace::replay_sharded). Replay is strict —
/// a dead shard fails the run rather than degrading it, so `dead` is
/// all-false and the merge is always total. `t0` is the driver's clock
/// start; the merged exec stats are the source's with wall time stamped
/// here.
pub(super) fn profile_sharded_source(
    prog: &Program,
    source: &mut dyn TraceSource,
    metrics: MetricSet,
    workers: Workers,
    opts: TrafficOpts,
    with_tasks: bool,
    t0: Instant,
) -> Result<(AppMetrics, Option<Vec<Region>>)> {
    let plan = ShardPlan::new(metrics, workers);
    let mut stacks: Vec<AnalyzerStack> = plan
        .shards()
        .iter()
        .map(|spec| AnalyzerStack::new_parts(prog, spec.metrics, opts, spec.traffic))
        .collect();
    if with_tasks {
        let last = stacks.pop().expect("plan is never empty");
        stacks.push(last.with_task_trace(prog));
    }
    {
        let mut refs: Vec<&mut (dyn Instrument + Send)> = stacks
            .iter_mut()
            .map(|s| s as &mut (dyn Instrument + Send))
            .collect();
        replay_sharded(source, &mut refs)?;
    }
    let mut exec = source.stats();
    exec.wall_s = t0.elapsed().as_secs_f64();
    let dead = vec![false; plan.workers()];
    Ok(merge_shards(&plan, stacks, &dead, exec))
}

/// Fold the per-shard stacks into one [`AppMetrics`]: each family's
/// result is adopted from the one shard that owned it (plan order — the
/// shards are disjoint, so this is a disjoint union, not a reduction).
/// The `traffic` family may be split across two shards; its halves stitch
/// back through [`crate::traffic::TrafficMetrics::adopt_parts`].
/// `dead[i]` marks shard `i` as having died mid-run: its stack is
/// dropped un-finalized (a panic mid-chunk can leave analyzer state
/// half-applied) and its families keep shard 0's shape-stable empties.
fn merge_shards(
    plan: &ShardPlan,
    stacks: Vec<AnalyzerStack>,
    dead: &[bool],
    exec: ExecStats,
) -> (AppMetrics, Option<Vec<Region>>) {
    debug_assert!(
        {
            let mut seen = MetricSet::none();
            let mut parts = TrafficParts::NONE;
            let mut disjoint = true;
            for spec in plan.shards() {
                for m in Metric::ALL {
                    if m != Metric::Traffic && spec.metrics.contains(m) {
                        disjoint &= !seen.contains(m);
                        seen = seen.with(m);
                    }
                }
                disjoint &= spec.traffic.intersect(parts).is_empty();
                parts = parts.union(spec.traffic);
            }
            disjoint
        },
        "shard plan families overlap"
    );
    let mut parts = plan.shards().iter().zip(stacks).enumerate();
    let (_, (_, first_stack)) = parts.next().expect("plan is never empty");
    let (mut merged, mut regions) = first_stack.finalize(exec.clone());
    // shard 0's disabled families finalized shape-stable empty; overwrite
    // exactly the families (and traffic halves) later *surviving* shards
    // own
    for (i, (spec, stack)) in parts {
        if dead.get(i).copied().unwrap_or(false) {
            continue;
        }
        let (m, r) = stack.finalize(exec.clone());
        adopt(&mut merged, m, spec);
        if r.is_some() {
            regions = r;
        }
    }
    merged.exec = exec;
    (merged, regions)
}

/// Move the families `spec` owns from `src` into `dst`. `spatial` derives
/// from `reuse`, so it travels with the `Reuse` family; the traffic
/// halves move as blocks via `adopt_parts`.
fn adopt(dst: &mut AppMetrics, src: AppMetrics, spec: &ShardSpec) {
    let owned = spec.metrics;
    let AppMetrics {
        mix,
        branch,
        mem_entropy,
        reuse,
        spatial,
        ilp,
        dlp,
        bblp,
        pbblp,
        traffic,
        ..
    } = src;
    if owned.contains(Metric::Mix) {
        dst.mix = mix;
    }
    if owned.contains(Metric::Branch) {
        dst.branch = branch;
    }
    if owned.contains(Metric::MemEntropy) {
        dst.mem_entropy = mem_entropy;
    }
    if owned.contains(Metric::Reuse) {
        dst.reuse = reuse;
        dst.spatial = spatial;
    }
    if owned.contains(Metric::Ilp) {
        dst.ilp = ilp;
    }
    if owned.contains(Metric::Dlp) {
        dst.dlp = dlp;
    }
    if owned.contains(Metric::Bblp) {
        dst.bblp = bblp;
    }
    if owned.contains(Metric::Pbblp) {
        dst.pbblp = pbblp;
    }
    if !spec.traffic.is_empty() {
        dst.traffic.adopt_parts(traffic, spec.traffic);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{profile, profile_impl, Delivery};
    use crate::fault::FaultPlan;
    use crate::ir::ProgramBuilder;
    use crate::traffic::{HierarchyPolicy, MrcMode};

    /// Unsupervised defaults — the clean-run arm of every merge test.
    fn clean() -> SuperviseOpts {
        SuperviseOpts::default()
    }

    #[test]
    fn shard_groups_cover_every_family_and_traffic_half_exactly_once() {
        let mut seen = MetricSet::none();
        let mut parts = TrafficParts::NONE;
        let mut count = 0;
        for group in SHARD_GROUPS {
            for &m in group.families {
                assert_ne!(m, Metric::Traffic, "traffic splits by parts, not by family");
                assert!(!seen.contains(m), "{} in two groups", m.name());
                seen = seen.with(m);
                count += 1;
            }
            assert!(
                group.traffic.intersect(parts).is_empty(),
                "{} re-owns a traffic half",
                group.name
            );
            parts = parts.union(group.traffic);
        }
        assert!(seen.with(Metric::Traffic).is_all(), "a family is missing from SHARD_GROUPS");
        assert_eq!(count, Metric::ALL.len() - 1);
        assert!(parts.is_all(), "a traffic half is missing from SHARD_GROUPS");
    }

    #[test]
    fn auto_sizing_follows_the_enabled_families() {
        // all nine families: one worker per canonical group
        let all = ShardPlan::new(MetricSet::all(), Workers::Auto);
        assert_eq!(all.workers(), 5);
        // a single family collapses to one worker
        let mix = ShardPlan::new(MetricSet::from_names("mix").unwrap(), Workers::Auto);
        assert_eq!(mix.workers(), 1);
        assert_eq!(mix.shards()[0].metrics.names(), vec!["mix"]);
        // two families in the same group still collapse to one worker
        let tags = ShardPlan::new(MetricSet::from_names("mix,branch").unwrap(), Workers::Auto);
        assert_eq!(tags.workers(), 1);
        // families straddling two groups: two workers
        let two = ShardPlan::new(MetricSet::from_names("mix,ilp").unwrap(), Workers::Auto);
        assert_eq!(two.workers(), 2);
        assert_eq!(two.shards()[0].metrics.names(), vec!["mix"]);
        assert_eq!(two.shards()[1].metrics.names(), vec!["ilp"]);
        // the traffic family alone spans two groups: its MRC half and its
        // hierarchy half land on different workers
        let traffic = ShardPlan::new(MetricSet::from_names("traffic").unwrap(), Workers::Auto);
        assert_eq!(traffic.workers(), 2);
        assert_eq!(traffic.shards()[0].traffic, TrafficParts::MRC);
        assert_eq!(traffic.shards()[1].traffic, TrafficParts::HIERARCHY);
        for shard in traffic.shards() {
            assert!(shard.metrics.contains(Metric::Traffic));
        }
    }

    #[test]
    fn fixed_sizing_clamps_and_never_leaves_a_shard_empty() {
        for n in 1..=8 {
            let plan = ShardPlan::new(MetricSet::all(), Workers::Fixed(n));
            assert_eq!(plan.workers(), n.min(5), "requested {n}");
            let mut union = MetricSet::none();
            let mut parts = TrafficParts::NONE;
            let mut non_traffic = 0;
            for shard in plan.shards() {
                assert!(!shard.is_empty(), "empty shard in a {n}-worker plan");
                for m in Metric::ALL {
                    if m != Metric::Traffic && shard.metrics.contains(m) {
                        non_traffic += 1;
                    }
                }
                union = union.union(shard.metrics);
                assert!(shard.traffic.intersect(parts).is_empty(), "traffic half owned twice");
                parts = parts.union(shard.traffic);
            }
            // disjoint cover of the enabled set, both halves owned once
            assert!(union.is_all());
            assert!(parts.is_all());
            assert_eq!(non_traffic, Metric::ALL.len() - 1);
        }
        // more workers than enabled groups: clamp to the group count
        let mix = ShardPlan::new(MetricSet::from_names("mix").unwrap(), Workers::Fixed(8));
        assert_eq!(mix.workers(), 1);
        // zero is nonsense but must not underflow the clamp
        let zero = ShardPlan::new(MetricSet::all(), Workers::Fixed(0));
        assert_eq!(zero.workers(), 1);
    }

    #[test]
    fn empty_metric_set_plans_one_empty_shard() {
        let plan = ShardPlan::new(MetricSet::none(), Workers::Auto);
        assert_eq!(plan.workers(), 1);
        assert!(plan.shards()[0].is_empty());
    }

    #[test]
    fn plan_is_deterministic() {
        let a = ShardPlan::new(MetricSet::all(), Workers::Fixed(3));
        let b = ShardPlan::new(MetricSet::all(), Workers::Fixed(3));
        assert_eq!(a, b);
    }

    fn tiny_program() -> Program {
        let mut b = ProgramBuilder::new("tiny");
        let data: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        let a = b.alloc_f64_init("a", &data);
        let o = b.alloc_f64("o", 64);
        let n = b.const_i(64);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fmul(v, v);
            b.store_f64(o, i, w);
        });
        b.finish(None)
    }

    #[test]
    fn merged_sharded_metrics_match_inline_at_every_worker_count() {
        let p = tiny_program();
        let reference = profile(&p).unwrap();
        for workers in
            [Workers::Auto, Workers::Fixed(1), Workers::Fixed(2), Workers::Fixed(3), Workers::Fixed(4)]
        {
            let opts = TrafficOpts::default();
            let (m, regions) =
                profile_sharded_run(&p, MetricSet::all(), workers, opts, clean(), false).unwrap();
            assert!(regions.is_none());
            assert!(m.failed.is_empty());
            assert_eq!(
                m.pca8_features().map(f64::to_bits),
                reference.pca8_features().map(f64::to_bits),
                "{workers:?}"
            );
            assert_eq!(m.mix.per_op, reference.mix.per_op);
            assert_eq!(m.reuse.hist, reference.reuse.hist);
            assert_eq!(m.traffic, reference.traffic);
            assert_eq!(m.exec.dyn_instrs, reference.exec.dyn_instrs);
        }
    }

    #[test]
    fn merge_is_deterministic_across_runs() {
        // worker scheduling varies run to run; the merged result must not
        let p = tiny_program();
        let opts = TrafficOpts::default();
        let (a, _) = profile_sharded_run(&p, MetricSet::all(), Workers::Fixed(4), opts, clean(), false)
            .unwrap();
        let (b, _) = profile_sharded_run(&p, MetricSet::all(), Workers::Fixed(4), opts, clean(), false)
            .unwrap();
        assert_eq!(a.pca8_features().map(f64::to_bits), b.pca8_features().map(f64::to_bits));
        assert_eq!(a.mix.per_op, b.mix.per_op);
        assert_eq!(a.mem_entropy.count_of_counts, b.mem_entropy.count_of_counts);
        assert_eq!(a.traffic, b.traffic);
    }

    #[test]
    fn subset_run_keeps_disabled_families_empty() {
        let p = tiny_program();
        let sel = MetricSet::from_names("mix,traffic").unwrap();
        let inline = profile_impl(&p, sel, Delivery::Chunked, TrafficOpts::default()).unwrap();
        let (m, _) =
            profile_sharded_run(&p, sel, Workers::Auto, TrafficOpts::default(), clean(), false)
                .unwrap();
        assert_eq!(m.mix.per_op, inline.mix.per_op);
        assert_eq!(m.traffic, inline.traffic);
        assert_eq!(m.reuse.accesses, 0);
        assert_eq!(m.ilp.critical_path, inline.ilp.critical_path);
    }

    #[test]
    fn split_traffic_family_reassembles_bit_identically() {
        // traffic alone: the MRC half and the hierarchy half run on two
        // different workers and the merge must still equal inline exactly
        let p = tiny_program();
        let sel = MetricSet::from_names("traffic").unwrap();
        let inline = profile_impl(&p, sel, Delivery::Chunked, TrafficOpts::default()).unwrap();
        let plan = ShardPlan::new(sel, Workers::Auto);
        assert_eq!(plan.workers(), 2, "traffic must split across two workers");
        let (m, _) =
            profile_sharded_run(&p, sel, Workers::Auto, TrafficOpts::default(), clean(), false)
                .unwrap();
        assert_eq!(m.traffic, inline.traffic);
    }

    #[test]
    fn hierarchy_policy_reaches_the_traffic_shard() {
        // the exclusive replay must produce the same per-level counters
        // sharded as it does inline — the policy travels into every
        // per-shard stack, not just the single-stack deliveries
        let p = tiny_program();
        let opts = TrafficOpts::with_hierarchy(HierarchyPolicy::Exclusive);
        let inline = profile_impl(&p, MetricSet::all(), Delivery::Chunked, opts).unwrap();
        let (m, _) =
            profile_sharded_run(&p, MetricSet::all(), Workers::Auto, opts, clean(), false).unwrap();
        assert_eq!(m.traffic.hierarchy_policy, HierarchyPolicy::Exclusive);
        assert_eq!(m.traffic, inline.traffic);
    }

    #[test]
    fn sampled_mrc_mode_reaches_the_mem_shard() {
        // --mrc sampled must reach the (split) MRC half and merge back
        // bit-identically to the inline sampled run
        let p = tiny_program();
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.5 });
        let inline = profile_impl(&p, MetricSet::all(), Delivery::Chunked, opts).unwrap();
        let (m, _) =
            profile_sharded_run(&p, MetricSet::all(), Workers::Auto, opts, clean(), false).unwrap();
        assert_eq!(m.traffic.mrc_mode, MrcMode::Sampled { rate: 0.5 });
        assert_eq!(m.traffic, inline.traffic);
    }

    #[test]
    fn task_trace_rides_the_last_shard() {
        let p = tiny_program();
        let opts = TrafficOpts::default();
        let (_, regions) =
            profile_sharded_run(&p, MetricSet::all(), Workers::Auto, opts, clean(), true).unwrap();
        let regions = regions.expect("task trace requested");
        assert!(!regions.is_empty());
    }

    #[test]
    fn dead_worker_degrades_its_families_and_survivors_stay_bit_identical() {
        // kill the mem shard (worker 1 of the 5-group auto plan) on its
        // first chunk: its families come back failed, every surviving
        // family merges bit-identically to a clean inline run
        let p = tiny_program();
        let reference = profile(&p).unwrap();
        let sup = SuperviseOpts::default()
            .with_fault(FaultPlan::from_spec("panic@worker:1").unwrap());
        let (m, _) = profile_sharded_run(
            &p,
            MetricSet::all(),
            Workers::Auto,
            TrafficOpts::default(),
            sup,
            false,
        )
        .unwrap();
        assert_eq!(m.failed, vec!["mem_entropy", "reuse", "traffic"]);
        // survivors: bit-identical to the clean run
        assert_eq!(m.mix.per_op, reference.mix.per_op);
        assert_eq!(m.ilp.inf.to_bits(), reference.ilp.inf.to_bits());
        assert_eq!(m.dlp.dlp.to_bits(), reference.dlp.dlp.to_bits());
        assert_eq!(m.bblp.values, reference.bblp.values);
        assert_eq!(m.exec.dyn_instrs, reference.exec.dyn_instrs);
        // the dead shard's families kept shard 0's shape-stable empties
        assert_eq!(m.mem_entropy.accesses, 0);
        assert_eq!(m.reuse.accesses, 0);
    }

    #[test]
    fn dead_task_carrier_shard_forfeits_the_region_trace() {
        // the task trace rides the last shard (worker 4 of the auto
        // plan); killing it must degrade to regions=None, not a
        // truncated trace
        let p = tiny_program();
        let sup = SuperviseOpts::default()
            .with_fault(FaultPlan::from_spec("panic@worker:4").unwrap());
        let (m, regions) = profile_sharded_run(
            &p,
            MetricSet::all(),
            Workers::Auto,
            TrafficOpts::default(),
            sup,
            true,
        )
        .unwrap();
        assert_eq!(m.failed, vec!["bblp", "pbblp"]);
        assert!(regions.is_none(), "dead collector must not yield a partial trace");
    }
}
