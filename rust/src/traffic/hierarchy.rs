//! Streaming multi-level cache-hierarchy replay (L1 → L2 → LLC → DRAM).
//!
//! Unlike the independent shadow bank this subsystem used to carry (three
//! caches each seeing every access — kept as a test-only oracle in
//! [`crate::testkit`]), the [`HierarchyReplay`] is a real hierarchy: each
//! level only sees its upper level's **misses**, dirty lines write back
//! *downward* level by level, and DRAM fill/writeback traffic is computed
//! from what actually crosses the last level — so upper-level hits are
//! subtracted from the DRAM byte accounting instead of double-counted.
//! That post-hierarchy DRAM traffic is the signal NMPO-style offload
//! models rank candidates by.
//!
//! Two content-management policies, selected chain-wide by
//! [`HierarchyPolicy`] (CLI: `--hierarchy inclusive|exclusive`) or per
//! level by a `--hierarchy-spec` file:
//!
//! * **Inclusive** — every upper level's contents are a subset of the
//!   levels below (strict inclusion, maintained by back-invalidation).
//!   A miss at level *i* fills the line into *every* level above the hit
//!   level, deepest first. Evicting a line from level *i* back-invalidates
//!   it from the levels above (merging their dirty bits); if the merged
//!   line is dirty it is written back to level *i+1* — which holds the
//!   line by inclusion — or to DRAM from the last level. Writebacks mark
//!   the lower copy dirty **without** refreshing its LRU stamp.
//! * **Exclusive** — a line lives in exactly one level at a time (victim
//!   hierarchy). A hit at L2/LLC *moves* the line up to L1; every L1 fill
//!   demotes the L1 victim to L2, whose victim demotes to LLC, whose
//!   victim leaves the hierarchy (to DRAM if dirty, dropped if clean).
//!   The aggregate capacity therefore approaches the *sum* of the levels,
//!   which `rust/tests/prop_hierarchy.rs` pins as a property.
//!
//! Since the DSE-advisor work the whole shape is **user-constructible**:
//! [`HierarchyConfig::from_spec_json`] parses a spec like
//!
//! ```json
//! { "line_bytes": 64, "policy": "inclusive", "write_allocate": true,
//!   "levels": [
//!     { "name": "l1",  "capacity_kb": 32,   "ways": 8 },
//!     { "capacity_kb": 256, "ways": 8, "policy": "exclusive",
//!       "replacement": "rrip" },
//!     { "name": "llc", "capacity_kb": 2048, "ways": 16,
//!       "replacement": "drrip" } ] }
//! ```
//!
//! with typed [`SpecError`]s, and [`HierarchyConfig::to_json`] round-trips
//! the accepted config into report provenance. Each level's `policy`
//! describes how *that* level manages content relative to the levels
//! above it (L1's flag only participates in chain classification); its
//! `replacement` picks the within-set policy
//! ([`ReplacementKind`]: `lru|rrip|drrip`). Uniform chains dispatch to the
//! original inclusive/exclusive paths — bit-identical to the fixed-shape
//! implementation — while mixed per-level policies run a unified path
//! that provably reduces to either pure policy (pinned by tests below).
//! The `write_allocate: false` knob changes stores only: a store probes
//! top-down and dirties the highest resident copy in place (no take, no
//! move), and a store that misses every level counts one DRAM writeback
//! and allocates nothing — which is the one configuration where the
//! "last-level misses == DRAM fills" identity intentionally breaks.
//!
//! Per-level counters follow one convention in all policies:
//! `hits`/`misses` count the accesses that *reached* the level (so
//! `misses` at the last level are exactly the DRAM fills under
//! write-allocate), and `writebacks` counts dirty lines evicted from the
//! level (inclusive: merged-dirty victims written downward; exclusive:
//! dirty demotions).
//!
//! The replay is streaming — one [`access`](HierarchyReplay::access) per
//! memory event, folded inside the `TrafficAnalyzer`'s single chunk-lane
//! pass — and is proven equivalent to a naive event-at-a-time multi-level
//! replay for both policies in `rust/tests/prop_hierarchy.rs`. The
//! `--sweep` grid mode rides the same pass: N small replays each
//! [`sweep`](HierarchyReplay::sweep) the same chunk lanes and finalize
//! into [`SweepCounters`] per grid point.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

use crate::sim::cache::{Cache, Evicted, ReplacementKind};
use crate::util::Json;

use super::mrc::MRC_LINE_BYTES;

/// Content-management policy of a replayed hierarchy (or of one level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HierarchyPolicy {
    /// Upper levels are subsets of lower levels (back-invalidation).
    #[default]
    Inclusive,
    /// A line lives in exactly one level (victim hierarchy).
    Exclusive,
}

impl HierarchyPolicy {
    pub fn name(self) -> &'static str {
        match self {
            HierarchyPolicy::Inclusive => "inclusive",
            HierarchyPolicy::Exclusive => "exclusive",
        }
    }

    /// Parse the CLI `--hierarchy` value.
    pub fn from_name(s: &str) -> Result<Self> {
        match s.trim() {
            "inclusive" => Ok(HierarchyPolicy::Inclusive),
            "exclusive" => Ok(HierarchyPolicy::Exclusive),
            other => bail!("unknown hierarchy policy '{other}' (inclusive|exclusive)"),
        }
    }
}

/// Shape of one hierarchy level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelConfig {
    /// Short label used in reports/JSON ("l1", "l2", "llc").
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
    /// How this level manages content relative to the levels above it.
    pub policy: HierarchyPolicy,
    /// Within-set replacement (LRU unless a spec says otherwise).
    pub replacement: ReplacementKind,
}

impl LevelConfig {
    /// A level with the historical defaults: inclusive, LRU.
    pub const fn new(name: &'static str, capacity_bytes: u64, ways: u32) -> LevelConfig {
        LevelConfig {
            name,
            capacity_bytes,
            ways,
            policy: HierarchyPolicy::Inclusive,
            replacement: ReplacementKind::Lru,
        }
    }
}

/// The default host-class chain at 64 B lines (Table 1's cache-per-core
/// column shapes — the same shapes the old independent bank used, so the
/// before/after DRAM comparison in `prop_hierarchy.rs` is level-for-level).
pub const HIERARCHY_LEVELS: [LevelConfig; 3] = [
    LevelConfig::new("l1", 32 << 10, 8),
    LevelConfig::new("l2", 256 << 10, 8),
    LevelConfig::new("llc", 2 << 20, 16),
];

/// Full hierarchy shape: ordered levels (upper first), line size, policy,
/// allocation behavior. Plays the `sim::config` role for the traffic
/// subsystem: one struct the CLI/coordinator hand down, defaults matching
/// the host model, and — since the DSE advisor — constructible from a
/// user spec ([`from_spec_json`](HierarchyConfig::from_spec_json)).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierarchyConfig {
    pub levels: Vec<LevelConfig>,
    pub line_bytes: u64,
    /// Chain-wide label; per-level overrides live in [`LevelConfig`].
    pub policy: HierarchyPolicy,
    /// `false` = stores never allocate: they dirty a resident copy in
    /// place or count one DRAM writeback on a full miss.
    pub write_allocate: bool,
}

impl HierarchyConfig {
    /// A chain with every level stamped to `policy` (write-allocate).
    pub fn uniform(
        mut levels: Vec<LevelConfig>,
        line_bytes: u64,
        policy: HierarchyPolicy,
    ) -> Self {
        for l in &mut levels {
            l.policy = policy;
        }
        HierarchyConfig { levels, line_bytes, policy, write_allocate: true }
    }

    /// The host-shaped L1→L2→LLC chain under `policy`.
    pub fn host(policy: HierarchyPolicy) -> Self {
        Self::uniform(HIERARCHY_LEVELS.to_vec(), MRC_LINE_BYTES, policy)
    }

    /// Capacity the chain effectively holds — the deepest (largest) level
    /// for all-inclusive chains, the level sum otherwise. The MRC-based
    /// sweep pruning places grid points on the miss-ratio curve by this
    /// number.
    pub fn aggregate_capacity_bytes(&self) -> u64 {
        if self.levels.iter().all(|l| l.policy == HierarchyPolicy::Inclusive) {
            self.levels.iter().map(|l| l.capacity_bytes).max().unwrap_or(0)
        } else {
            self.levels.iter().map(|l| l.capacity_bytes).sum()
        }
    }

    /// Serialize into the exact shape [`from_spec_json`] accepts, so
    /// reports carry provenance a reader can re-run
    /// (`from_spec_json(cfg.to_json().to_string_compact()) == cfg`).
    ///
    /// [`from_spec_json`]: HierarchyConfig::from_spec_json
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("line_bytes", self.line_bytes);
        j.set("policy", self.policy.name());
        j.set("write_allocate", self.write_allocate);
        let levels: Vec<Json> = self
            .levels
            .iter()
            .map(|l| {
                let mut lj = Json::obj();
                lj.set("name", l.name);
                lj.set("capacity_bytes", l.capacity_bytes);
                lj.set("ways", u64::from(l.ways));
                lj.set("policy", l.policy.name());
                lj.set("replacement", l.replacement.name());
                lj
            })
            .collect();
        j.set("levels", levels);
        j
    }

    /// Parse a user hierarchy spec (the `--hierarchy-spec` payload; see
    /// the module docs for the format). Every field is validated with a
    /// typed [`SpecError`] — unknown keys are rejected so a typo'd knob
    /// can't silently fall back to a default.
    pub fn from_spec_json(spec: &str) -> std::result::Result<HierarchyConfig, SpecError> {
        let root = Json::parse(spec).map_err(SpecError::Parse)?;
        let obj = root
            .as_obj()
            .ok_or_else(|| invalid("spec", "top level must be a JSON object"))?;
        for key in obj.keys() {
            if !TOP_KEYS.contains(&key.as_str()) {
                return Err(invalid(
                    key.clone(),
                    "unknown key (levels|line_bytes|policy|write_allocate)",
                ));
            }
        }
        let line_bytes = match obj.get("line_bytes") {
            Some(v) => spec_u64(v, "line_bytes")?,
            None => MRC_LINE_BYTES,
        };
        if !line_bytes.is_power_of_two() || !(8..=4096).contains(&line_bytes) {
            return Err(invalid("line_bytes", "must be a power of two in 8..=4096"));
        }
        let policy = match obj.get("policy") {
            Some(v) => spec_policy(v, "policy")?,
            None => HierarchyPolicy::Inclusive,
        };
        let write_allocate = match obj.get("write_allocate") {
            Some(Json::Bool(b)) => *b,
            Some(_) => return Err(invalid("write_allocate", "expected true or false")),
            None => true,
        };
        let raw_levels = obj
            .get("levels")
            .ok_or_else(|| invalid("levels", "required (an array of level objects)"))?
            .as_arr()
            .ok_or_else(|| invalid("levels", "expected an array of level objects"))?;
        if raw_levels.is_empty() || raw_levels.len() > MAX_LEVELS {
            return Err(invalid("levels", format!("need 1..={MAX_LEVELS} levels")));
        }
        let mut levels = Vec::with_capacity(raw_levels.len());
        for (i, lv) in raw_levels.iter().enumerate() {
            levels.push(parse_level(lv, i, line_bytes, policy)?);
        }
        for (i, l) in levels.iter().enumerate() {
            if levels[..i].iter().any(|p| p.name == l.name) {
                return Err(invalid(
                    format!("levels[{i}].name"),
                    format!("duplicate level name '{}'", l.name),
                ));
            }
        }
        Ok(HierarchyConfig { levels, line_bytes, policy, write_allocate })
    }
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        Self::host(HierarchyPolicy::default())
    }
}

/// Hierarchy specs deeper than this get rejected (sanity bound, not a
/// hardware claim).
pub const MAX_LEVELS: usize = 8;

const TOP_KEYS: [&str; 4] = ["levels", "line_bytes", "policy", "write_allocate"];
const LEVEL_KEYS: [&str; 6] =
    ["name", "capacity_bytes", "capacity_kb", "ways", "policy", "replacement"];

/// Why a `--hierarchy-spec` / `--sweep` payload was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// Not JSON at all.
    Parse(String),
    /// Parsed, but a field is missing, unknown, or out of range.
    Invalid { field: String, why: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Parse(why) => write!(f, "hierarchy spec: parse error: {why}"),
            SpecError::Invalid { field, why } => {
                write!(f, "hierarchy spec: invalid '{field}': {why}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

fn invalid(field: impl Into<String>, why: impl Into<String>) -> SpecError {
    SpecError::Invalid { field: field.into(), why: why.into() }
}

fn spec_u64(v: &Json, field: &str) -> std::result::Result<u64, SpecError> {
    let f = v.as_f64().ok_or_else(|| invalid(field, "expected a number"))?;
    if f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f <= (1u64 << 53) as f64 {
        Ok(f as u64)
    } else {
        Err(invalid(field, "expected a non-negative integer"))
    }
}

fn spec_policy(v: &Json, field: &str) -> std::result::Result<HierarchyPolicy, SpecError> {
    let s = v.as_str().ok_or_else(|| invalid(field, "expected a string"))?;
    HierarchyPolicy::from_name(s).map_err(|e| invalid(field, e.to_string()))
}

fn parse_level(
    lv: &Json,
    i: usize,
    line_bytes: u64,
    default_policy: HierarchyPolicy,
) -> std::result::Result<LevelConfig, SpecError> {
    let ctx = |key: &str| format!("levels[{i}].{key}");
    let obj = lv
        .as_obj()
        .ok_or_else(|| invalid(format!("levels[{i}]"), "expected a level object"))?;
    for key in obj.keys() {
        if !LEVEL_KEYS.contains(&key.as_str()) {
            return Err(invalid(
                ctx(key),
                "unknown key (name|capacity_bytes|capacity_kb|ways|policy|replacement)",
            ));
        }
    }
    let capacity_bytes = match (obj.get("capacity_bytes"), obj.get("capacity_kb")) {
        (Some(v), None) => spec_u64(v, &ctx("capacity_bytes"))?,
        (None, Some(v)) => spec_u64(v, &ctx("capacity_kb"))?.saturating_mul(1024),
        (Some(_), Some(_)) => {
            return Err(invalid(
                ctx("capacity_bytes"),
                "give capacity_bytes or capacity_kb, not both",
            ))
        }
        (None, None) => return Err(invalid(ctx("capacity_bytes"), "required (or capacity_kb)")),
    };
    if capacity_bytes < line_bytes || capacity_bytes > (1 << 40) {
        return Err(invalid(
            ctx("capacity_bytes"),
            format!("must be in {line_bytes}..=2^40 bytes"),
        ));
    }
    let ways = spec_u64(
        obj.get("ways").ok_or_else(|| invalid(ctx("ways"), "required"))?,
        &ctx("ways"),
    )?;
    if !(1..=64).contains(&ways) {
        return Err(invalid(ctx("ways"), "must be in 1..=64"));
    }
    let name = match obj.get("name") {
        Some(v) => {
            let s = v.as_str().ok_or_else(|| invalid(ctx("name"), "expected a string"))?;
            if !valid_level_name(s) {
                return Err(invalid(
                    ctx("name"),
                    "1..=12 chars of [a-z0-9_] (used as a report column)",
                ));
            }
            intern_level_name(s)
        }
        None => DEFAULT_LEVEL_NAMES[i],
    };
    let policy = match obj.get("policy") {
        Some(v) => spec_policy(v, &ctx("policy"))?,
        None => default_policy,
    };
    let replacement = match obj.get("replacement") {
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| invalid(ctx("replacement"), "expected a string"))?;
            ReplacementKind::from_name(s).ok_or_else(|| {
                invalid(ctx("replacement"), format!("unknown replacement '{s}' (lru|rrip|drrip)"))
            })?
        }
        None => ReplacementKind::Lru,
    };
    Ok(LevelConfig { name, capacity_bytes, ways: ways as u32, policy, replacement })
}

const DEFAULT_LEVEL_NAMES: [&str; MAX_LEVELS] =
    ["l1", "l2", "l3", "l4", "l5", "l6", "l7", "l8"];

fn valid_level_name(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 12
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// `LevelConfig.name` is `&'static str` (configs are `Copy`-friendly and
/// cheaply cloned); spec-supplied names outside the well-known set are
/// leaked once. Bounded: one short string per distinct custom level name
/// per process, and specs are parsed at CLI/grid load, not per event.
fn intern_level_name(s: &str) -> &'static str {
    const KNOWN: [&str; 9] = ["l1", "l2", "l3", "l4", "l5", "l6", "l7", "l8", "llc"];
    for k in KNOWN {
        if k == s {
            return k;
        }
    }
    Box::leak(s.to_string().into_boxed_str())
}

/// Finalized counts for one level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelStats {
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
    /// Accesses that reached this level and hit.
    pub hits: u64,
    /// Accesses that reached this level and missed (at the last level:
    /// exactly the DRAM fills — under write-allocate).
    pub misses: u64,
    /// Dirty lines evicted from this level (written to the level below,
    /// or to DRAM from the last level).
    pub writebacks: u64,
}

impl LevelStats {
    /// Miss ratio over the accesses this level actually saw.
    pub fn miss_ratio(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct LevelCounts {
    hits: u64,
    misses: u64,
    writebacks: u64,
}

/// One grid point's finalized counters in `--sweep` mode: the config it
/// replayed plus exactly what a standalone [`HierarchyReplay`] at that
/// config would report (the differential oracle in `prop_hierarchy.rs`
/// pins that bit-identity).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepCounters {
    pub config: HierarchyConfig,
    pub levels: Vec<LevelStats>,
    pub dram_fills: u64,
    pub dram_writebacks: u64,
}

impl SweepCounters {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("config", self.config.to_json());
        let levels: Vec<Json> = self
            .levels
            .iter()
            .map(|s| {
                let mut lj = Json::obj();
                lj.set("name", s.name);
                lj.set("hits", s.hits);
                lj.set("misses", s.misses);
                lj.set("writebacks", s.writebacks);
                lj.set("miss_ratio", s.miss_ratio());
                lj
            })
            .collect();
        j.set("levels", levels);
        j.set("dram_fills", self.dram_fills);
        j.set("dram_writebacks", self.dram_writebacks);
        j
    }
}

/// Which access algorithm a config needs. Uniform chains take the
/// original single-policy paths (bit-identical to the fixed-shape
/// implementation); anything with per-level policy overrides takes the
/// unified mixed path, which reduces to either pure policy when the
/// levels happen to agree (pinned by the tests below).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ChainKind {
    UniformInclusive,
    UniformExclusive,
    Mixed,
}

impl ChainKind {
    fn of(cfg: &HierarchyConfig) -> ChainKind {
        if cfg.levels.iter().all(|l| l.policy == HierarchyPolicy::Inclusive) {
            ChainKind::UniformInclusive
        } else if cfg.levels.iter().all(|l| l.policy == HierarchyPolicy::Exclusive) {
            ChainKind::UniformExclusive
        } else {
            ChainKind::Mixed
        }
    }
}

/// The streaming hierarchy simulator.
#[derive(Debug, Clone)]
pub struct HierarchyReplay {
    cfg: HierarchyConfig,
    chain: ChainKind,
    line_shift: u32,
    caches: Vec<Cache>,
    counts: Vec<LevelCounts>,
    dram_fills: u64,
    dram_writebacks: u64,
}

impl Default for HierarchyReplay {
    fn default() -> Self {
        Self::new(HierarchyConfig::default())
    }
}

impl HierarchyReplay {
    pub fn new(cfg: HierarchyConfig) -> HierarchyReplay {
        assert!(!cfg.levels.is_empty(), "hierarchy needs at least one level");
        assert!(cfg.line_bytes.is_power_of_two());
        let line = cfg.line_bytes as usize;
        let caches = cfg
            .levels
            .iter()
            .map(|l| {
                Cache::with_policy(l.capacity_bytes as usize, l.ways as usize, line, l.replacement)
            })
            .collect();
        let counts = vec![LevelCounts::default(); cfg.levels.len()];
        HierarchyReplay {
            chain: ChainKind::of(&cfg),
            line_shift: cfg.line_bytes.trailing_zeros(),
            caches,
            counts,
            cfg,
            dram_fills: 0,
            dram_writebacks: 0,
        }
    }

    pub fn policy(&self) -> HierarchyPolicy {
        self.cfg.policy
    }

    pub fn config(&self) -> &HierarchyConfig {
        &self.cfg
    }

    /// Send one byte-addressed access through the chain. Returns the level
    /// index that serviced it (`levels.len()` = it went to DRAM).
    #[inline]
    pub fn access(&mut self, addr: u64, is_store: bool) -> usize {
        let line = addr >> self.line_shift;
        if is_store && !self.cfg.write_allocate {
            return self.store_no_alloc(line);
        }
        match self.chain {
            ChainKind::UniformInclusive => self.access_inclusive(line, is_store),
            ChainKind::UniformExclusive => self.access_exclusive(line, is_store),
            ChainKind::Mixed => self.access_mixed(line, is_store),
        }
    }

    /// Replay a dense chunk-lane slice in trace order (the hot path). The
    /// chain is stateful across levels, so unlike the old independent bank
    /// there is no cache-major sweep: order is the per-event order.
    #[inline]
    pub fn sweep(&mut self, addrs: &[u64], lanes: &crate::interp::ChunkLanes) {
        for (i, &addr) in addrs.iter().enumerate() {
            self.access(addr, lanes.is_store(i));
        }
    }

    /// No-write-allocate store: dirty the highest resident copy in place
    /// (even at an exclusive level — the line is *not* moved), or count
    /// one DRAM writeback when it misses everywhere. Loads never take
    /// this path.
    fn store_no_alloc(&mut self, line: u64) -> usize {
        let n = self.caches.len();
        for i in 0..n {
            if self.caches[i].touch_line(line, true) {
                self.counts[i].hits += 1;
                return i;
            }
            self.counts[i].misses += 1;
        }
        self.dram_writebacks += 1;
        n
    }

    fn access_inclusive(&mut self, line: u64, is_store: bool) -> usize {
        let n = self.caches.len();
        // probe top-down; the store's dirty bit lands in the L1 copy only
        let mut hit = n;
        for i in 0..n {
            if self.caches[i].touch_line(line, is_store && i == 0) {
                self.counts[i].hits += 1;
                hit = i;
                break;
            }
            self.counts[i].misses += 1;
        }
        if hit == n {
            self.dram_fills += 1;
        }
        // fill every missed level, deepest first, so inclusion holds at
        // each step (each level's fill happens after the level below it
        // already holds the line); these levels just missed their probe,
        // so the fill skips the redundant set scan
        for lvl in (0..hit).rev() {
            if let Some(v) = self.caches[lvl].fill_line_after_miss(line, is_store && lvl == 0) {
                self.evict_inclusive(lvl, v);
            }
        }
        hit
    }

    /// Level `lvl` evicted `v`: back-invalidate the copies above (merging
    /// their dirty bits — the freshest dirt lives highest), then write the
    /// merged line back downward if dirty.
    fn evict_inclusive(&mut self, lvl: usize, v: Evicted) {
        let mut dirty = v.dirty;
        for upper in (0..lvl).rev() {
            if let Some(d) = self.caches[upper].take_line(v.line) {
                dirty |= d;
            }
        }
        if dirty {
            self.counts[lvl].writebacks += 1;
            if lvl + 1 < self.caches.len() {
                let held = self.caches[lvl + 1].mark_dirty_line(v.line);
                debug_assert!(held, "inclusion violated: victim absent below level {lvl}");
            } else {
                self.dram_writebacks += 1;
            }
        }
    }

    fn access_exclusive(&mut self, line: u64, is_store: bool) -> usize {
        let n = self.caches.len();
        if self.caches[0].touch_line(line, is_store) {
            self.counts[0].hits += 1;
            return 0;
        }
        self.counts[0].misses += 1;
        for i in 1..n {
            // a lower-level hit *moves* the line up (exclusivity)
            if let Some(dirty) = self.caches[i].take_line(line) {
                self.counts[i].hits += 1;
                self.promote_exclusive(line, dirty || is_store);
                return i;
            }
            self.counts[i].misses += 1;
        }
        self.dram_fills += 1;
        self.promote_exclusive(line, is_store);
        n
    }

    /// Fill `line` into L1 and cascade each level's victim one level down;
    /// the last level's victim leaves the hierarchy. Exclusivity
    /// guarantees neither the promoted line nor any demoted victim is
    /// resident where it lands, so every fill skips the probe.
    fn promote_exclusive(&mut self, line: u64, dirty: bool) {
        let mut incoming = Some(Evicted { line, dirty });
        for lvl in 0..self.caches.len() {
            let Some(inc) = incoming else { return };
            incoming = self.caches[lvl].fill_line_after_miss(inc.line, inc.dirty);
            if incoming.is_some_and(|v| v.dirty) {
                self.counts[lvl].writebacks += 1;
            }
        }
        if incoming.is_some_and(|v| v.dirty) {
            self.dram_writebacks += 1;
        }
    }

    /// The unified per-level-policy path. Probe top-down — inclusive
    /// levels (and L1) are touched in place, exclusive levels give the
    /// line up — then fill L1 plus every missed *inclusive* level above
    /// the hit, deepest first. The store's (or taken line's) dirt lands
    /// in the L1 copy only. Reduces exactly to `access_inclusive` /
    /// `access_exclusive` when the levels agree.
    fn access_mixed(&mut self, line: u64, is_store: bool) -> usize {
        let n = self.caches.len();
        let mut hit = n;
        let mut carry = is_store;
        for i in 0..n {
            let hit_here = if i == 0 || self.cfg.levels[i].policy == HierarchyPolicy::Inclusive {
                self.caches[i].touch_line(line, is_store && i == 0)
            } else if let Some(dirty) = self.caches[i].take_line(line) {
                carry = dirty || is_store;
                true
            } else {
                false
            };
            if hit_here {
                self.counts[i].hits += 1;
                hit = i;
                break;
            }
            self.counts[i].misses += 1;
        }
        if hit == 0 {
            return 0;
        }
        if hit == n {
            self.dram_fills += 1;
        }
        for lvl in (0..hit).rev() {
            if lvl != 0 && self.cfg.levels[lvl].policy != HierarchyPolicy::Inclusive {
                continue;
            }
            if let Some(v) = self.caches[lvl].fill_line_after_miss(line, lvl == 0 && carry) {
                self.route_victim_mixed(lvl, v);
            }
        }
        hit
    }

    /// Route a victim evicted from level `lvl` in a mixed chain:
    /// back-invalidate any copies above (merging dirt), then let the
    /// *next* level's policy decide — exclusive levels accept demotions
    /// unconditionally (clean or dirty, cascading their own victims),
    /// inclusive levels just absorb the dirty bit (they hold the line by
    /// inclusion), and past the last level dirt goes to DRAM.
    fn route_victim_mixed(&mut self, lvl: usize, v: Evicted) {
        let mut dirty = v.dirty;
        for upper in (0..lvl).rev() {
            if let Some(d) = self.caches[upper].take_line(v.line) {
                dirty |= d;
            }
        }
        let next = lvl + 1;
        if next >= self.caches.len() {
            if dirty {
                self.counts[lvl].writebacks += 1;
                self.dram_writebacks += 1;
            }
            return;
        }
        if self.cfg.levels[next].policy == HierarchyPolicy::Exclusive {
            if dirty {
                self.counts[lvl].writebacks += 1;
            }
            if let Some(w) = self.caches[next].fill_line_after_miss(v.line, dirty) {
                self.route_victim_mixed(next, w);
            }
        } else if dirty {
            self.counts[lvl].writebacks += 1;
            if !self.caches[next].mark_dirty_line(v.line) {
                // a mixed chain can't always guarantee strict inclusion
                // below (an exclusive level in between may have taken the
                // line away); re-materialize the dirty line instead of
                // losing the writeback
                if let Some(w) = self.caches[next].fill_line_after_miss(v.line, true) {
                    self.route_victim_mixed(next, w);
                }
            }
        }
    }

    /// Is `addr`'s line resident at level `i`? (invariant checks)
    pub fn level_contains(&self, i: usize, addr: u64) -> bool {
        self.caches[i].contains_line(addr >> self.line_shift)
    }

    /// Resident line ids at level `i`, sorted (invariant checks).
    pub fn level_lines(&self, i: usize) -> Vec<u64> {
        self.caches[i].resident_lines()
    }

    pub fn dram_fills(&self) -> u64 {
        self.dram_fills
    }

    pub fn dram_writebacks(&self) -> u64 {
        self.dram_writebacks
    }

    /// Per-level stats in chain order.
    pub fn finalize(&self) -> Vec<LevelStats> {
        self.cfg
            .levels
            .iter()
            .zip(&self.counts)
            .map(|(cfg, c)| LevelStats {
                name: cfg.name,
                capacity_bytes: cfg.capacity_bytes,
                ways: cfg.ways,
                hits: c.hits,
                misses: c.misses,
                writebacks: c.writebacks,
            })
            .collect()
    }

    /// Everything a `--sweep` grid point reports.
    pub fn sweep_counters(&self) -> SweepCounters {
        SweepCounters {
            config: self.cfg.clone(),
            levels: self.finalize(),
            dram_fills: self.dram_fills,
            dram_writebacks: self.dram_writebacks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny 2-level chain: 2-line L1, 4-line L2, fully associative.
    fn tiny(policy: HierarchyPolicy) -> HierarchyReplay {
        HierarchyReplay::new(HierarchyConfig::uniform(
            vec![LevelConfig::new("l1", 2 * 64, 2), LevelConfig::new("l2", 4 * 64, 4)],
            64,
            policy,
        ))
    }

    fn addr(line: u64) -> u64 {
        line * 64
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            assert_eq!(HierarchyPolicy::from_name(p.name()).unwrap(), p);
        }
        assert!(HierarchyPolicy::from_name("bogus").is_err());
        assert_eq!(HierarchyPolicy::default(), HierarchyPolicy::Inclusive);
    }

    #[test]
    fn inclusive_filtering_and_fill_levels() {
        let mut h = tiny(HierarchyPolicy::Inclusive);
        assert_eq!(h.access(addr(1), false), 2, "cold goes to DRAM");
        assert_eq!(h.access(addr(1), false), 0, "then hits L1");
        // push line 1 out of the 2-line L1 but not out of L2
        h.access(addr(2), false);
        h.access(addr(3), false);
        assert_eq!(h.access(addr(1), false), 1, "L1 victim still in L2");
        let s = h.finalize();
        // L2 saw only the four L1 misses (3 cold + 1 refetch), not the hit
        assert_eq!(s[0].hits + s[0].misses, 5);
        assert_eq!(s[1].hits + s[1].misses, 4);
        assert_eq!(s[1].hits, 1);
        assert_eq!(h.dram_fills(), 3);
    }

    #[test]
    fn inclusive_upper_copies_are_subsets() {
        let mut h = tiny(HierarchyPolicy::Inclusive);
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..2000 {
            h.access(addr(rng.below(12)), rng.below(3) == 0);
            let l1 = h.level_lines(0);
            let l2 = h.level_lines(1);
            for line in &l1 {
                assert!(l2.binary_search(line).is_ok(), "L1 line {line} absent from L2");
            }
        }
    }

    #[test]
    fn inclusive_dirty_lines_cascade_to_dram() {
        // store a line, then stream enough clean lines to flush it out of
        // both levels: exactly one DRAM writeback
        let mut h = tiny(HierarchyPolicy::Inclusive);
        h.access(addr(0), true);
        for l in 1..16 {
            h.access(addr(l), false);
        }
        assert_eq!(h.dram_writebacks(), 1);
        let s = h.finalize();
        assert_eq!(s[1].writebacks, 1, "the dirt crossed the last level once");
        assert_eq!(h.dram_fills(), 16);
    }

    #[test]
    fn exclusive_lines_live_in_one_level() {
        let mut h = tiny(HierarchyPolicy::Exclusive);
        for l in 0..5 {
            h.access(addr(l), false);
        }
        for l in 0..5 {
            let in_l1 = h.level_contains(0, addr(l));
            let in_l2 = h.level_contains(1, addr(l));
            assert!(!(in_l1 && in_l2), "line {l} duplicated across levels");
        }
        // aggregate 6 lines: nothing dropped yet, so a re-walk of all 5
        // hits somewhere (L2 hits move lines back up)
        let fills_after_cold = h.dram_fills();
        for l in 0..5 {
            assert!(h.access(addr(l), false) < 2, "line {l} left the hierarchy");
        }
        assert_eq!(h.dram_fills(), fills_after_cold);
    }

    #[test]
    fn exclusive_dirty_victim_writes_back_once() {
        let mut h = tiny(HierarchyPolicy::Exclusive);
        h.access(addr(0), true);
        // 6 more clean lines overflow the 2+4 aggregate: line 0's dirt
        // must leave for DRAM exactly once
        for l in 1..=6 {
            h.access(addr(l), false);
        }
        assert_eq!(h.dram_writebacks(), 1);
        assert!(!h.level_contains(0, addr(0)) && !h.level_contains(1, addr(0)));
    }

    #[test]
    fn read_only_stream_never_writes_back() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let mut h = HierarchyReplay::new(HierarchyConfig::host(policy));
            for i in 0..100_000u64 {
                h.access(i * 64, false);
            }
            assert_eq!(h.dram_writebacks(), 0, "{}", policy.name());
            for s in h.finalize() {
                assert_eq!(s.writebacks, 0, "{}", s.name);
                assert!(s.miss_ratio() > 0.9, "{}: cold stream must miss", s.name);
            }
            assert_eq!(h.dram_fills(), 100_000);
        }
    }

    #[test]
    fn dram_fills_equal_last_level_misses() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let mut h = HierarchyReplay::new(HierarchyConfig::host(policy));
            let mut rng = crate::util::Rng::new(5);
            for _ in 0..20_000 {
                h.access(0x10_000 + rng.below(4096) * 64, rng.below(4) == 0);
            }
            let s = h.finalize();
            assert_eq!(s.last().unwrap().misses, h.dram_fills(), "{}", policy.name());
            assert_eq!(s.last().unwrap().writebacks, h.dram_writebacks(), "{}", policy.name());
            // filtering: each level sees exactly the level above's misses
            for w in s.windows(2) {
                assert_eq!(w[0].misses, w[1].hits + w[1].misses, "{}", policy.name());
            }
        }
    }

    // --- configurable-hierarchy (DSE advisor) tests ---------------------

    #[test]
    fn spec_parses_the_host_shape() {
        let spec = r#"{
            "line_bytes": 64,
            "policy": "inclusive",
            "levels": [
                {"name": "l1", "capacity_kb": 32, "ways": 8},
                {"name": "l2", "capacity_kb": 256, "ways": 8},
                {"name": "llc", "capacity_kb": 2048, "ways": 16}
            ]
        }"#;
        let cfg = HierarchyConfig::from_spec_json(spec).unwrap();
        assert_eq!(cfg, HierarchyConfig::host(HierarchyPolicy::Inclusive));
        assert_eq!(cfg, HierarchyConfig::default());
    }

    #[test]
    fn spec_defaults_and_provenance_round_trip() {
        // minimal spec: names, policy, replacement, line size all default
        let cfg = HierarchyConfig::from_spec_json(
            r#"{"levels": [{"capacity_bytes": 4096, "ways": 4}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.levels.len(), 1);
        assert_eq!(cfg.levels[0].name, "l1");
        assert_eq!(cfg.levels[0].policy, HierarchyPolicy::Inclusive);
        assert_eq!(cfg.levels[0].replacement, ReplacementKind::Lru);
        assert_eq!(cfg.line_bytes, 64);
        assert!(cfg.write_allocate);

        // a gnarly config round-trips through its own provenance JSON
        let gnarly = HierarchyConfig::from_spec_json(
            r#"{
                "line_bytes": 128,
                "policy": "exclusive",
                "write_allocate": false,
                "levels": [
                    {"name": "scratch", "capacity_kb": 4, "ways": 2,
                     "policy": "inclusive", "replacement": "rrip"},
                    {"capacity_kb": 64, "ways": 8, "replacement": "drrip"}
                ]
            }"#,
        )
        .unwrap();
        assert_eq!(gnarly.levels[0].name, "scratch");
        assert_eq!(gnarly.levels[0].policy, HierarchyPolicy::Inclusive);
        assert_eq!(gnarly.levels[1].name, "l2");
        assert_eq!(gnarly.levels[1].policy, HierarchyPolicy::Exclusive);
        assert_eq!(gnarly.levels[1].replacement, ReplacementKind::Drrip);
        assert!(!gnarly.write_allocate);
        let reparsed =
            HierarchyConfig::from_spec_json(&gnarly.to_json().to_string_compact()).unwrap();
        assert_eq!(reparsed, gnarly);
        // host configs round-trip too
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let host = HierarchyConfig::host(policy);
            let back =
                HierarchyConfig::from_spec_json(&host.to_json().to_string_compact()).unwrap();
            assert_eq!(back, host);
        }
    }

    #[test]
    fn spec_rejections_are_typed() {
        assert!(matches!(
            HierarchyConfig::from_spec_json("not json at all"),
            Err(SpecError::Parse(_))
        ));
        let bad = [
            r#"[1, 2]"#,                                                  // not an object
            r#"{}"#,                                                      // no levels
            r#"{"levels": []}"#,                                          // empty levels
            r#"{"levels": [{"capacity_kb": 4, "ways": 2}], "bogus": 1}"#, // unknown top key
            r#"{"levels": [{"capacity_kb": 4, "ways": 2, "assoc": 2}]}"#, // unknown level key
            r#"{"levels": [{"ways": 2}]}"#,                               // no capacity
            r#"{"levels": [{"capacity_kb": 4, "capacity_bytes": 4096, "ways": 2}]}"#,
            r#"{"levels": [{"capacity_bytes": 16, "ways": 2}]}"#,         // below line size
            r#"{"levels": [{"capacity_kb": 4, "ways": 0}]}"#,             // zero ways
            r#"{"levels": [{"capacity_kb": 4, "ways": 2.5}]}"#,           // fractional ways
            r#"{"levels": [{"capacity_kb": 4, "ways": 2, "policy": "nine"}]}"#,
            r#"{"levels": [{"capacity_kb": 4, "ways": 2, "replacement": "plru"}]}"#,
            r#"{"levels": [{"capacity_kb": 4, "ways": 2, "name": "BAD NAME"}]}"#,
            r#"{"levels": [{"capacity_kb": 4, "ways": 2, "name": "a"},
                           {"capacity_kb": 8, "ways": 2, "name": "a"}]}"#,
            r#"{"line_bytes": 48, "levels": [{"capacity_kb": 4, "ways": 2}]}"#,
            r#"{"write_allocate": "yes", "levels": [{"capacity_kb": 4, "ways": 2}]}"#,
        ];
        for spec in bad {
            match HierarchyConfig::from_spec_json(spec) {
                Err(SpecError::Invalid { field, why }) => {
                    assert!(!field.is_empty() && !why.is_empty(), "{spec}");
                }
                other => panic!("spec {spec:?} gave {other:?}"),
            }
        }
        // nine levels is one too many
        let levels: Vec<String> = (0..9)
            .map(|i| format!(r#"{{"name": "x{i}", "capacity_kb": 4, "ways": 2}}"#))
            .collect();
        let spec = format!(r#"{{"levels": [{}]}}"#, levels.join(","));
        assert!(matches!(
            HierarchyConfig::from_spec_json(&spec),
            Err(SpecError::Invalid { .. })
        ));
        // errors display with the greppable prefix the CI gate checks for
        let e = HierarchyConfig::from_spec_json("{").unwrap_err();
        assert!(e.to_string().starts_with("hierarchy spec:"), "{e}");
    }

    #[test]
    fn aggregate_capacity_by_policy() {
        let incl = HierarchyConfig::host(HierarchyPolicy::Inclusive);
        assert_eq!(incl.aggregate_capacity_bytes(), 2 << 20);
        let excl = HierarchyConfig::host(HierarchyPolicy::Exclusive);
        assert_eq!(excl.aggregate_capacity_bytes(), (32 << 10) + (256 << 10) + (2 << 20));
    }

    /// Flip only L1's policy flag: the chain is classified mixed but is
    /// semantically identical (L1's own flag never steers the unified
    /// path), so the mixed algorithm must be bit-identical to each pure
    /// path.
    #[test]
    fn mixed_path_reduces_to_both_pure_policies() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let flipped = match policy {
                HierarchyPolicy::Inclusive => HierarchyPolicy::Exclusive,
                HierarchyPolicy::Exclusive => HierarchyPolicy::Inclusive,
            };
            let mut pure = tiny(policy);
            let mut forced = {
                let mut cfg = pure.config().clone();
                cfg.levels[0].policy = flipped;
                HierarchyReplay::new(cfg)
            };
            let mut rng = crate::util::Rng::new(23);
            for _ in 0..4000 {
                let a = addr(rng.below(10));
                let st = rng.below(4) == 0;
                assert_eq!(pure.access(a, st), forced.access(a, st), "{}", policy.name());
            }
            for i in 0..2 {
                assert_eq!(pure.level_lines(i), forced.level_lines(i), "{}", policy.name());
            }
            let (ps, fs) = (pure.finalize(), forced.finalize());
            for (p, f) in ps.iter().zip(&fs) {
                assert_eq!((p.hits, p.misses, p.writebacks), (f.hits, f.misses, f.writebacks));
            }
            assert_eq!(pure.dram_fills(), forced.dram_fills());
            assert_eq!(pure.dram_writebacks(), forced.dram_writebacks());
        }
    }

    /// Hand-computed genuinely-mixed chain: 1-line inclusive L1, 1-line
    /// exclusive L2 (a victim cache), 4-line inclusive L3.
    #[test]
    fn mixed_victim_cache_scenario() {
        let cfg = HierarchyConfig::from_spec_json(
            r#"{"levels": [
                {"name": "l1", "capacity_bytes": 64, "ways": 1},
                {"name": "vc", "capacity_bytes": 64, "ways": 1, "policy": "exclusive"},
                {"name": "l3", "capacity_bytes": 256, "ways": 4}
            ]}"#,
        )
        .unwrap();
        let mut h = HierarchyReplay::new(cfg);
        assert_eq!(h.access(addr(0), false), 3); // A: cold
        assert_eq!(h.access(addr(1), false), 3); // B evicts A from L1 → demoted to vc
        assert_eq!(h.access(addr(0), false), 1, "victim-cache hit moves A back up");
        assert_eq!(h.access(addr(0), true), 0); // dirty A in L1
        assert_eq!(h.access(addr(2), false), 3); // C evicts dirty A → vc (B clean-dropped)
        assert_eq!(h.access(addr(0), false), 1, "dirty A promoted from vc");
        assert_eq!(h.access(addr(3), false), 3); // D evicts dirty A → vc again
        let s = h.finalize();
        assert_eq!((s[0].hits, s[0].misses, s[0].writebacks), (1, 6, 2));
        assert_eq!((s[1].hits, s[1].misses, s[1].writebacks), (2, 4, 0));
        assert_eq!((s[2].hits, s[2].misses), (0, 4));
        assert_eq!(h.dram_fills(), 4);
        assert_eq!(h.dram_writebacks(), 0, "the dirt is still in the victim cache");
        assert!(h.level_contains(1, addr(0)) && h.level_contains(0, addr(3)));
        for l in 0..4 {
            assert!(h.level_contains(2, addr(l)), "inclusive L3 holds line {l}");
        }
        // flush L3: its LRU victim is A, whose dirty vc copy must be
        // back-invalidated and written to DRAM exactly once
        assert_eq!(h.access(addr(4), false), 3);
        assert_eq!(h.dram_writebacks(), 1);
        assert_eq!(h.finalize()[2].writebacks, 1);
        assert!(!h.level_contains(1, addr(0)), "vc copy back-invalidated");
    }

    #[test]
    fn no_write_allocate_stores_never_fill() {
        let mut cfg = HierarchyConfig::uniform(
            vec![LevelConfig::new("l1", 2 * 64, 2), LevelConfig::new("l2", 4 * 64, 4)],
            64,
            HierarchyPolicy::Inclusive,
        );
        cfg.write_allocate = false;
        let mut h = HierarchyReplay::new(cfg);
        assert_eq!(h.access(addr(0), true), 2, "store miss goes straight past");
        assert_eq!(h.dram_writebacks(), 1, "missed store is one DRAM write");
        assert_eq!(h.dram_fills(), 0, "…and allocates nothing");
        assert!(!h.level_contains(0, addr(0)) && !h.level_contains(1, addr(0)));
        assert_eq!(h.access(addr(0), false), 2, "loads still allocate");
        assert_eq!(h.access(addr(0), true), 0, "store hit dirties in place");
        // flush the dirty line out of both levels: the in-place dirt
        // still cascades to DRAM like any write-allocate store would
        for l in 1..16 {
            h.access(addr(l), false);
        }
        assert_eq!(h.dram_writebacks(), 2);
        // the write-allocate identity intentionally breaks: the last
        // level's misses include the allocating load stream *plus* the
        // no-alloc store probe, while fills only count the loads
        assert_eq!(h.dram_fills(), 16);
        assert_eq!(h.finalize().last().unwrap().misses, 17);
    }

    #[test]
    fn sweep_counters_match_finalize() {
        let mut h = tiny(HierarchyPolicy::Exclusive);
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..500 {
            h.access(addr(rng.below(9)), rng.below(5) == 0);
        }
        let sc = h.sweep_counters();
        assert_eq!(sc.levels, h.finalize());
        assert_eq!(sc.dram_fills, h.dram_fills());
        assert_eq!(sc.dram_writebacks, h.dram_writebacks());
        assert_eq!(&sc.config, h.config());
        let j = sc.to_json();
        assert!(j.get("config").is_some() && j.get("levels").is_some());
        assert_eq!(j.get("dram_fills").and_then(|v| v.as_f64()), Some(sc.dram_fills as f64));
    }
}
