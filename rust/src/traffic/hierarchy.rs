//! Streaming multi-level cache-hierarchy replay (L1 → L2 → LLC → DRAM).
//!
//! Unlike the independent shadow bank this subsystem used to carry (three
//! caches each seeing every access — kept as a test-only oracle in
//! [`crate::testkit`]), the [`HierarchyReplay`] is a real hierarchy: each
//! level only sees its upper level's **misses**, dirty lines write back
//! *downward* level by level, and DRAM fill/writeback traffic is computed
//! from what actually crosses the last level — so upper-level hits are
//! subtracted from the DRAM byte accounting instead of double-counted.
//! That post-hierarchy DRAM traffic is the signal NMPO-style offload
//! models rank candidates by.
//!
//! Two content-management policies, selected by [`HierarchyPolicy`]
//! (CLI: `--hierarchy inclusive|exclusive`):
//!
//! * **Inclusive** — every upper level's contents are a subset of the
//!   levels below (strict inclusion, maintained by back-invalidation).
//!   A miss at level *i* fills the line into *every* level above the hit
//!   level, deepest first. Evicting a line from level *i* back-invalidates
//!   it from the levels above (merging their dirty bits); if the merged
//!   line is dirty it is written back to level *i+1* — which holds the
//!   line by inclusion — or to DRAM from the last level. Writebacks mark
//!   the lower copy dirty **without** refreshing its LRU stamp.
//! * **Exclusive** — a line lives in exactly one level at a time (victim
//!   hierarchy). A hit at L2/LLC *moves* the line up to L1; every L1 fill
//!   demotes the L1 victim to L2, whose victim demotes to LLC, whose
//!   victim leaves the hierarchy (to DRAM if dirty, dropped if clean).
//!   The aggregate capacity therefore approaches the *sum* of the levels,
//!   which `rust/tests/prop_hierarchy.rs` pins as a property.
//!
//! Per-level counters follow one convention in both policies:
//! `hits`/`misses` count the accesses that *reached* the level (so
//! `misses` at the last level are exactly the DRAM fills), and
//! `writebacks` counts dirty lines evicted from the level (inclusive:
//! merged-dirty victims written downward; exclusive: dirty demotions).
//!
//! The replay is streaming — one [`access`](HierarchyReplay::access) per
//! memory event, folded inside the `TrafficAnalyzer`'s single chunk-lane
//! pass — and is proven equivalent to a naive event-at-a-time multi-level
//! replay for both policies in `rust/tests/prop_hierarchy.rs`.

use anyhow::{bail, Result};

use crate::sim::cache::{Cache, Evicted};

use super::mrc::MRC_LINE_BYTES;

/// Content-management policy of the replayed hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HierarchyPolicy {
    /// Upper levels are subsets of lower levels (back-invalidation).
    #[default]
    Inclusive,
    /// A line lives in exactly one level (victim hierarchy).
    Exclusive,
}

impl HierarchyPolicy {
    pub fn name(self) -> &'static str {
        match self {
            HierarchyPolicy::Inclusive => "inclusive",
            HierarchyPolicy::Exclusive => "exclusive",
        }
    }

    /// Parse the CLI `--hierarchy` value.
    pub fn from_name(s: &str) -> Result<Self> {
        match s.trim() {
            "inclusive" => Ok(HierarchyPolicy::Inclusive),
            "exclusive" => Ok(HierarchyPolicy::Exclusive),
            other => bail!("unknown hierarchy policy '{other}' (inclusive|exclusive)"),
        }
    }
}

/// Shape of one hierarchy level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelConfig {
    /// Short label used in reports/JSON ("l1", "l2", "llc").
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
}

/// The default host-class chain at 64 B lines (Table 1's cache-per-core
/// column shapes — the same shapes the old independent bank used, so the
/// before/after DRAM comparison in `prop_hierarchy.rs` is level-for-level).
pub const HIERARCHY_LEVELS: [LevelConfig; 3] = [
    LevelConfig { name: "l1", capacity_bytes: 32 << 10, ways: 8 },
    LevelConfig { name: "l2", capacity_bytes: 256 << 10, ways: 8 },
    LevelConfig { name: "llc", capacity_bytes: 2 << 20, ways: 16 },
];

/// Full hierarchy shape: ordered levels (upper first), line size, policy.
/// Plays the `sim::config` role for the traffic subsystem: one struct the
/// CLI/coordinator hand down, defaults matching the host model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierarchyConfig {
    pub levels: Vec<LevelConfig>,
    pub line_bytes: u64,
    pub policy: HierarchyPolicy,
}

impl HierarchyConfig {
    /// The host-shaped L1→L2→LLC chain under `policy`.
    pub fn host(policy: HierarchyPolicy) -> Self {
        HierarchyConfig {
            levels: HIERARCHY_LEVELS.to_vec(),
            line_bytes: MRC_LINE_BYTES,
            policy,
        }
    }
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        Self::host(HierarchyPolicy::default())
    }
}

/// Finalized counts for one level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelStats {
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
    /// Accesses that reached this level and hit.
    pub hits: u64,
    /// Accesses that reached this level and missed (at the last level:
    /// exactly the DRAM fills).
    pub misses: u64,
    /// Dirty lines evicted from this level (written to the level below,
    /// or to DRAM from the last level).
    pub writebacks: u64,
}

impl LevelStats {
    /// Miss ratio over the accesses this level actually saw.
    pub fn miss_ratio(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct LevelCounts {
    hits: u64,
    misses: u64,
    writebacks: u64,
}

/// The streaming hierarchy simulator.
#[derive(Debug, Clone)]
pub struct HierarchyReplay {
    cfg: HierarchyConfig,
    line_shift: u32,
    caches: Vec<Cache>,
    counts: Vec<LevelCounts>,
    dram_fills: u64,
    dram_writebacks: u64,
}

impl Default for HierarchyReplay {
    fn default() -> Self {
        Self::new(HierarchyConfig::default())
    }
}

impl HierarchyReplay {
    pub fn new(cfg: HierarchyConfig) -> HierarchyReplay {
        assert!(!cfg.levels.is_empty(), "hierarchy needs at least one level");
        assert!(cfg.line_bytes.is_power_of_two());
        let line = cfg.line_bytes as usize;
        let caches = cfg
            .levels
            .iter()
            .map(|l| Cache::new(l.capacity_bytes as usize, l.ways as usize, line))
            .collect();
        let counts = vec![LevelCounts::default(); cfg.levels.len()];
        HierarchyReplay {
            line_shift: cfg.line_bytes.trailing_zeros(),
            caches,
            counts,
            cfg,
            dram_fills: 0,
            dram_writebacks: 0,
        }
    }

    pub fn policy(&self) -> HierarchyPolicy {
        self.cfg.policy
    }

    /// Send one byte-addressed access through the chain. Returns the level
    /// index that serviced it (`levels.len()` = it went to DRAM).
    #[inline]
    pub fn access(&mut self, addr: u64, is_store: bool) -> usize {
        let line = addr >> self.line_shift;
        match self.cfg.policy {
            HierarchyPolicy::Inclusive => self.access_inclusive(line, is_store),
            HierarchyPolicy::Exclusive => self.access_exclusive(line, is_store),
        }
    }

    /// Replay a dense chunk-lane slice in trace order (the hot path). The
    /// chain is stateful across levels, so unlike the old independent bank
    /// there is no cache-major sweep: order is the per-event order.
    #[inline]
    pub fn sweep(&mut self, addrs: &[u64], lanes: &crate::interp::ChunkLanes) {
        for (i, &addr) in addrs.iter().enumerate() {
            self.access(addr, lanes.is_store(i));
        }
    }

    fn access_inclusive(&mut self, line: u64, is_store: bool) -> usize {
        let n = self.caches.len();
        // probe top-down; the store's dirty bit lands in the L1 copy only
        let mut hit = n;
        for i in 0..n {
            if self.caches[i].touch_line(line, is_store && i == 0) {
                self.counts[i].hits += 1;
                hit = i;
                break;
            }
            self.counts[i].misses += 1;
        }
        if hit == n {
            self.dram_fills += 1;
        }
        // fill every missed level, deepest first, so inclusion holds at
        // each step (each level's fill happens after the level below it
        // already holds the line); these levels just missed their probe,
        // so the fill skips the redundant set scan
        for lvl in (0..hit).rev() {
            if let Some(v) = self.caches[lvl].fill_line_after_miss(line, is_store && lvl == 0) {
                self.evict_inclusive(lvl, v);
            }
        }
        hit
    }

    /// Level `lvl` evicted `v`: back-invalidate the copies above (merging
    /// their dirty bits — the freshest dirt lives highest), then write the
    /// merged line back downward if dirty.
    fn evict_inclusive(&mut self, lvl: usize, v: Evicted) {
        let mut dirty = v.dirty;
        for upper in (0..lvl).rev() {
            if let Some(d) = self.caches[upper].take_line(v.line) {
                dirty |= d;
            }
        }
        if dirty {
            self.counts[lvl].writebacks += 1;
            if lvl + 1 < self.caches.len() {
                let held = self.caches[lvl + 1].mark_dirty_line(v.line);
                debug_assert!(held, "inclusion violated: victim absent below level {lvl}");
            } else {
                self.dram_writebacks += 1;
            }
        }
    }

    fn access_exclusive(&mut self, line: u64, is_store: bool) -> usize {
        let n = self.caches.len();
        if self.caches[0].touch_line(line, is_store) {
            self.counts[0].hits += 1;
            return 0;
        }
        self.counts[0].misses += 1;
        for i in 1..n {
            // a lower-level hit *moves* the line up (exclusivity)
            if let Some(dirty) = self.caches[i].take_line(line) {
                self.counts[i].hits += 1;
                self.promote_exclusive(line, dirty || is_store);
                return i;
            }
            self.counts[i].misses += 1;
        }
        self.dram_fills += 1;
        self.promote_exclusive(line, is_store);
        n
    }

    /// Fill `line` into L1 and cascade each level's victim one level down;
    /// the last level's victim leaves the hierarchy. Exclusivity
    /// guarantees neither the promoted line nor any demoted victim is
    /// resident where it lands, so every fill skips the probe.
    fn promote_exclusive(&mut self, line: u64, dirty: bool) {
        let mut incoming = Some(Evicted { line, dirty });
        for lvl in 0..self.caches.len() {
            let Some(inc) = incoming else { return };
            incoming = self.caches[lvl].fill_line_after_miss(inc.line, inc.dirty);
            if incoming.is_some_and(|v| v.dirty) {
                self.counts[lvl].writebacks += 1;
            }
        }
        if incoming.is_some_and(|v| v.dirty) {
            self.dram_writebacks += 1;
        }
    }

    /// Is `addr`'s line resident at level `i`? (invariant checks)
    pub fn level_contains(&self, i: usize, addr: u64) -> bool {
        self.caches[i].contains_line(addr >> self.line_shift)
    }

    /// Resident line ids at level `i`, sorted (invariant checks).
    pub fn level_lines(&self, i: usize) -> Vec<u64> {
        self.caches[i].resident_lines()
    }

    pub fn dram_fills(&self) -> u64 {
        self.dram_fills
    }

    pub fn dram_writebacks(&self) -> u64 {
        self.dram_writebacks
    }

    /// Per-level stats in chain order.
    pub fn finalize(&self) -> Vec<LevelStats> {
        self.cfg
            .levels
            .iter()
            .zip(&self.counts)
            .map(|(cfg, c)| LevelStats {
                name: cfg.name,
                capacity_bytes: cfg.capacity_bytes,
                ways: cfg.ways,
                hits: c.hits,
                misses: c.misses,
                writebacks: c.writebacks,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny 2-level chain: 2-line L1, 4-line L2, fully associative.
    fn tiny(policy: HierarchyPolicy) -> HierarchyReplay {
        HierarchyReplay::new(HierarchyConfig {
            levels: vec![
                LevelConfig { name: "l1", capacity_bytes: 2 * 64, ways: 2 },
                LevelConfig { name: "l2", capacity_bytes: 4 * 64, ways: 4 },
            ],
            line_bytes: 64,
            policy,
        })
    }

    fn addr(line: u64) -> u64 {
        line * 64
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            assert_eq!(HierarchyPolicy::from_name(p.name()).unwrap(), p);
        }
        assert!(HierarchyPolicy::from_name("bogus").is_err());
        assert_eq!(HierarchyPolicy::default(), HierarchyPolicy::Inclusive);
    }

    #[test]
    fn inclusive_filtering_and_fill_levels() {
        let mut h = tiny(HierarchyPolicy::Inclusive);
        assert_eq!(h.access(addr(1), false), 2, "cold goes to DRAM");
        assert_eq!(h.access(addr(1), false), 0, "then hits L1");
        // push line 1 out of the 2-line L1 but not out of L2
        h.access(addr(2), false);
        h.access(addr(3), false);
        assert_eq!(h.access(addr(1), false), 1, "L1 victim still in L2");
        let s = h.finalize();
        // L2 saw only the four L1 misses (3 cold + 1 refetch), not the hit
        assert_eq!(s[0].hits + s[0].misses, 5);
        assert_eq!(s[1].hits + s[1].misses, 4);
        assert_eq!(s[1].hits, 1);
        assert_eq!(h.dram_fills(), 3);
    }

    #[test]
    fn inclusive_upper_copies_are_subsets() {
        let mut h = tiny(HierarchyPolicy::Inclusive);
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..2000 {
            h.access(addr(rng.below(12)), rng.below(3) == 0);
            let l1 = h.level_lines(0);
            let l2 = h.level_lines(1);
            for line in &l1 {
                assert!(l2.binary_search(line).is_ok(), "L1 line {line} absent from L2");
            }
        }
    }

    #[test]
    fn inclusive_dirty_lines_cascade_to_dram() {
        // store a line, then stream enough clean lines to flush it out of
        // both levels: exactly one DRAM writeback
        let mut h = tiny(HierarchyPolicy::Inclusive);
        h.access(addr(0), true);
        for l in 1..16 {
            h.access(addr(l), false);
        }
        assert_eq!(h.dram_writebacks(), 1);
        let s = h.finalize();
        assert_eq!(s[1].writebacks, 1, "the dirt crossed the last level once");
        assert_eq!(h.dram_fills(), 16);
    }

    #[test]
    fn exclusive_lines_live_in_one_level() {
        let mut h = tiny(HierarchyPolicy::Exclusive);
        for l in 0..5 {
            h.access(addr(l), false);
        }
        for l in 0..5 {
            let in_l1 = h.level_contains(0, addr(l));
            let in_l2 = h.level_contains(1, addr(l));
            assert!(!(in_l1 && in_l2), "line {l} duplicated across levels");
        }
        // aggregate 6 lines: nothing dropped yet, so a re-walk of all 5
        // hits somewhere (L2 hits move lines back up)
        let fills_after_cold = h.dram_fills();
        for l in 0..5 {
            assert!(h.access(addr(l), false) < 2, "line {l} left the hierarchy");
        }
        assert_eq!(h.dram_fills(), fills_after_cold);
    }

    #[test]
    fn exclusive_dirty_victim_writes_back_once() {
        let mut h = tiny(HierarchyPolicy::Exclusive);
        h.access(addr(0), true);
        // 6 more clean lines overflow the 2+4 aggregate: line 0's dirt
        // must leave for DRAM exactly once
        for l in 1..=6 {
            h.access(addr(l), false);
        }
        assert_eq!(h.dram_writebacks(), 1);
        assert!(!h.level_contains(0, addr(0)) && !h.level_contains(1, addr(0)));
    }

    #[test]
    fn read_only_stream_never_writes_back() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let mut h = HierarchyReplay::new(HierarchyConfig::host(policy));
            for i in 0..100_000u64 {
                h.access(i * 64, false);
            }
            assert_eq!(h.dram_writebacks(), 0, "{}", policy.name());
            for s in h.finalize() {
                assert_eq!(s.writebacks, 0, "{}", s.name);
                assert!(s.miss_ratio() > 0.9, "{}: cold stream must miss", s.name);
            }
            assert_eq!(h.dram_fills(), 100_000);
        }
    }

    #[test]
    fn dram_fills_equal_last_level_misses() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let mut h = HierarchyReplay::new(HierarchyConfig::host(policy));
            let mut rng = crate::util::Rng::new(5);
            for _ in 0..20_000 {
                h.access(0x10_000 + rng.below(4096) * 64, rng.below(4) == 0);
            }
            let s = h.finalize();
            assert_eq!(s.last().unwrap().misses, h.dram_fills(), "{}", policy.name());
            assert_eq!(s.last().unwrap().writebacks, h.dram_writebacks(), "{}", policy.name());
            // filtering: each level sees exactly the level above's misses
            for w in s.windows(2) {
                assert_eq!(w[0].misses, w[1].hits + w[1].misses, "{}", policy.name());
            }
        }
    }
}
