//! SHARDS-style sampled stack distances: O(sampled) miss-ratio curves.
//!
//! The exact Olken/Fenwick kernel ([`MrcBuilder`]) is the asymptotic
//! bottleneck of the whole pipeline: it carries one map entry and one
//! Fenwick slot per *distinct line ever touched*, and pays O(log n) per
//! access. SHARDS (Waldspurger et al., FAST'15) replaces it with spatial
//! hash sampling: a line is tracked **iff** `hash(line) < T`, which
//! selects a uniform, *consistent* subset of lines — every access to a
//! sampled line is seen, every access to an unsampled line is invisible.
//! With sampling rate `R = T / 2^64`:
//!
//! - a stack distance `d_s` measured in the sampled substream estimates a
//!   true distance of `d_s / R` (the rescaling rule: unsampled lines are
//!   missing from the distance count in proportion `R`), and
//! - each sampled access stands for `1 / R` accesses of the full stream,
//!   so cold-miss and hit counts are rescaled by the same factor.
//!
//! The miss-ratio estimate is *self-normalizing*: ratios are computed
//! against the rescaled sampled-access mass, not the raw access count, so
//! hash-density luck (sampling slightly more or fewer lines than `R`
//! predicts) cancels in the quotient. At `rate = 1.0` every line is
//! sampled, all weights are exactly `1.0`, and the estimator reproduces
//! the exact kernel bit for bit (integer-valued f64 arithmetic) — the
//! plumbing oracle `prop_mrc_sampled.rs` pins.
//!
//! Two variants:
//! - **fixed-rate** ([`SampledMrc::new`]): `T` is set once from the rate;
//!   memory is O(R · footprint).
//! - **fixed-size** ([`SampledMrc::fixed_size`]): at most `S_max` lines
//!   are resident; on overflow the line with the *largest* hash is
//!   evicted and `T` drops to that hash, so the rate adapts downward as
//!   the footprint grows and memory stays constant regardless of trace
//!   length. Later accesses are weighted by the rate in force when they
//!   happen (no retroactive histogram rescale — the basic SHARDS
//!   estimator, whose bias the self-normalizing ratio largely absorbs).
//!
//! When is the knee trustworthy? The knee is a *shape* feature: it needs
//! the curve's big drop to exceed sampling noise (~`1/sqrt(sampled
//! lines)` per point). With ≥ a few hundred sampled lines the knee is
//! solid; at `rate * footprint ≲ 50` lines treat the knee — and the
//! curve's absolute level — as indicative only. `sampled_accesses` is
//! recorded in the traffic JSON precisely so consumers can judge this.

use std::collections::BinaryHeap;
use std::hash::Hasher;

use anyhow::{bail, Result};

use crate::analysis::reuse::LineDist;
use crate::util::fxhash::FxHasher;
use crate::util::{FastMap, Fenwick};

use super::mrc::{MRC_CAPACITIES_BYTES, MRC_LINE_BYTES, MRC_LINE_SHIFT, N_MRC_POINTS};

/// Default sampling rate for `--mrc sampled` with no explicit rate.
pub const DEFAULT_SAMPLE_RATE: f64 = 0.01;

/// Default resident-line bound for the fixed-size variant.
pub const DEFAULT_SAMPLE_S_MAX: usize = 8192;

/// 2^64 as f64 — the denominator of the hash-threshold → rate mapping.
const TWO_POW_64: f64 = 18_446_744_073_709_551_616.0;

/// Which stack-distance kernel the traffic family runs.
///
/// `Exact` is the Olken/Fenwick kernel — bit-identical to the historical
/// output and the right choice for correctness baselines. `Sampled` is
/// fixed-rate SHARDS: ~`1/rate` less stack-distance work and memory, with
/// miss ratios that carry sampling noise of roughly
/// `1/sqrt(rate * footprint_lines)` per capacity point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MrcMode {
    /// Exact Olken/Fenwick stack distances (the default).
    Exact,
    /// Fixed-rate SHARDS sampling at the given rate in `(0, 1]`.
    Sampled { rate: f64 },
}

impl Default for MrcMode {
    fn default() -> Self {
        MrcMode::Exact
    }
}

impl MrcMode {
    /// Short mode label for JSON: `"exact"` or `"sampled"`.
    pub fn name(self) -> &'static str {
        match self {
            MrcMode::Exact => "exact",
            MrcMode::Sampled { .. } => "sampled",
        }
    }

    /// Human form including the rate, e.g. `"sampled:0.01"`.
    pub fn describe(self) -> String {
        match self {
            MrcMode::Exact => "exact".to_string(),
            MrcMode::Sampled { rate } => format!("sampled:{rate}"),
        }
    }

    /// The sampling rate: `1.0` for exact mode.
    pub fn rate(self) -> f64 {
        match self {
            MrcMode::Exact => 1.0,
            MrcMode::Sampled { rate } => rate,
        }
    }

    pub fn is_sampled(self) -> bool {
        matches!(self, MrcMode::Sampled { .. })
    }

    /// Parse `exact`, `sampled` (default rate), or `sampled:<rate>`.
    pub fn from_name(name: &str) -> Result<MrcMode> {
        let name = name.trim();
        if name.eq_ignore_ascii_case("exact") {
            return Ok(MrcMode::Exact);
        }
        if name.eq_ignore_ascii_case("sampled") {
            return Ok(MrcMode::Sampled { rate: DEFAULT_SAMPLE_RATE });
        }
        if let Some(rest) = name.strip_prefix("sampled:") {
            let rate: f64 = match rest.trim().parse() {
                Ok(r) => r,
                Err(_) => bail!("bad sample rate {rest:?} (want a number in (0, 1])"),
            };
            if !(rate > 0.0 && rate <= 1.0) {
                bail!("sample rate {rate} out of range (0, 1]");
            }
            return Ok(MrcMode::Sampled { rate });
        }
        bail!("unknown MRC mode {name:?} (try: exact, sampled, sampled:<rate>)")
    }
}

/// The spatial-sampling hash: must be deterministic across instances and
/// runs so every delivery path (per-event / chunked / offload / sharded)
/// samples the *same* lines and stays bit-identical.
#[inline]
fn line_hash(line: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write_u64(line);
    h.finish()
}

/// Outcome of one access against the sampled kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SampledAccess {
    /// The line's hash is above the threshold: invisible to the sample.
    NotSampled,
    /// A sampled access standing for `weight = 1/rate` full-stream
    /// accesses, with its distance class in the *sampled substream*.
    Sampled { weight: f64, dist: LineDist },
}

/// SHARDS stack distances over the sampled substream.
///
/// Same Olken structure as [`StackDistance`](crate::analysis::reuse::StackDistance)
/// — last-access map + Fenwick over timestamps — but both only ever hold
/// the sampled lines, so the map has O(rate · footprint) entries and the
/// Fenwick indexes sampled time, not full time.
#[derive(Debug, Clone, Default)]
pub struct SampledStackDistance {
    /// Sample iff `(hash as u128) < threshold`; `rate = threshold / 2^64`.
    /// u128 so that rate 1.0 is exactly `2^64` and admits every hash.
    threshold: u128,
    /// Resident-line bound; `None` = pure fixed-rate.
    s_max: Option<usize>,
    /// line → sampled-stream timestamp of its last access.
    last: FastMap<u64, u64>,
    /// Max-heap of `(hash, line)`, maintained only in fixed-size mode.
    /// A line enters the heap exactly once: once evicted, the threshold
    /// drops to its hash and it can never be re-admitted.
    heap: BinaryHeap<(u64, u64)>,
    fen: Fenwick,
    time: u64,
    /// Immediate-repeat fast path over the sampled substream.
    last_line: Option<u64>,
}

impl SampledStackDistance {
    fn threshold_for(rate: f64) -> u128 {
        debug_assert!(rate > 0.0 && rate <= 1.0, "rate {rate} out of (0, 1]");
        (rate * TWO_POW_64) as u128
    }

    /// Fixed-rate sampler.
    pub fn new(rate: f64) -> SampledStackDistance {
        SampledStackDistance {
            threshold: Self::threshold_for(rate),
            ..Default::default()
        }
    }

    /// Fixed-size sampler: starts at `rate`, lowers the threshold
    /// whenever more than `s_max` lines are resident.
    pub fn with_max_entries(rate: f64, s_max: usize) -> SampledStackDistance {
        SampledStackDistance {
            threshold: Self::threshold_for(rate),
            s_max: Some(s_max.max(1)),
            ..Default::default()
        }
    }

    /// The rate currently in force (monotone non-increasing over a run).
    pub fn current_rate(&self) -> f64 {
        self.threshold as f64 / TWO_POW_64
    }

    /// Number of resident sampled lines.
    pub fn resident(&self) -> usize {
        self.last.len()
    }

    /// Process one line access. Distances in the returned `LineDist` are
    /// counted over the sampled substream — scale by `1/current_rate()`
    /// (already folded into `weight`) to estimate full-stream distances.
    pub fn access_line(&mut self, line: u64) -> SampledAccess {
        let h = line_hash(line);
        if (h as u128) >= self.threshold {
            return SampledAccess::NotSampled;
        }
        let weight = 1.0 / self.current_rate();
        // Repeat fast path: previous *sampled* access was this same line.
        // Unsampled accesses in between don't exist in the substream, so
        // they must not break the run — at rate 1.0 this degenerates to
        // the exact kernel's fast path.
        if self.last_line == Some(line) {
            return SampledAccess::Sampled { weight, dist: LineDist::Repeat };
        }
        self.last_line = Some(line);
        let t = self.time;
        let dist = match self.last.insert(line, t) {
            Some(prev) => {
                let d = self.fen.range_sum(prev as usize + 1, t as usize);
                self.fen.add(prev as usize, -1);
                LineDist::Reuse(d)
            }
            None => {
                if self.s_max.is_some() {
                    self.heap.push((h, line));
                }
                LineDist::Cold(self.last.len() as u64 - 1)
            }
        };
        self.fen.add(t as usize, 1);
        self.time += 1;
        if let Some(s_max) = self.s_max {
            while self.last.len() > s_max {
                self.evict_max();
            }
        }
        SampledAccess::Sampled { weight, dist }
    }

    /// Fixed-size overflow: drop the resident line with the largest hash
    /// and lower the threshold to that hash so it (and anything denser)
    /// is never sampled again. Ties are evicted together — `hash <
    /// threshold` must remain an exact membership predicate, and leaving
    /// a second line at the same hash resident would strand its Fenwick
    /// mass.
    fn evict_max(&mut self) {
        let Some(&(h_max, _)) = self.heap.peek() else {
            return;
        };
        while let Some(&(h, line)) = self.heap.peek() {
            if h != h_max {
                break;
            }
            self.heap.pop();
            if let Some(t) = self.last.remove(&line) {
                self.fen.add(t as usize, -1);
            }
            if self.last_line == Some(line) {
                self.last_line = None;
            }
        }
        self.threshold = h_max as u128;
    }
}

/// Sampled miss-ratio curve over the same geometric capacity family as
/// [`MrcBuilder`](super::MrcBuilder), built on [`SampledStackDistance`].
///
/// First-hit mass is accumulated in *weights* (`1/rate` per sampled
/// access); miss ratios are quotients against the total sampled weight,
/// and absolute miss counts are those ratios re-applied to the raw access
/// count — the self-normalizing SHARDS estimator.
#[derive(Debug, Clone, Default)]
pub struct SampledMrc {
    sd: SampledStackDistance,
    /// Rescaled first-hit histogram: `first_hit_w[i]` is the estimated
    /// number of full-stream accesses whose first hit is capacity `i`.
    first_hit_w: [f64; N_MRC_POINTS],
    /// Rescaled cold (compulsory) mass — also the footprint estimate:
    /// in the exact kernel every distinct line cold-misses exactly once,
    /// so the same `Σ 1/R` estimates both.
    cold_w: f64,
    /// Total rescaled sampled mass (the estimator's denominator).
    sampled_w: f64,
    accesses: u64,
    sampled_accesses: u64,
}

impl SampledMrc {
    /// Fixed-rate SHARDS at `rate` in `(0, 1]`.
    pub fn new(rate: f64) -> SampledMrc {
        SampledMrc { sd: SampledStackDistance::new(rate), ..Default::default() }
    }

    /// Fixed-size SHARDS: starts at rate 1.0 and adapts the rate down to
    /// keep at most `s_max` lines resident — constant memory at any
    /// footprint.
    pub fn fixed_size(s_max: usize) -> SampledMrc {
        Self::with_smax(1.0, s_max)
    }

    /// Fixed-size SHARDS seeded at `rate`: at most `s_max` lines
    /// resident, starting from the given rate instead of 1.0 (the CLI
    /// `--mrc sampled[:rate] --mrc-smax N` combination).
    pub fn with_smax(rate: f64, s_max: usize) -> SampledMrc {
        SampledMrc {
            sd: SampledStackDistance::with_max_entries(rate, s_max),
            ..Default::default()
        }
    }

    /// Record one access of `size`-agnostic address `addr` (line mapping
    /// identical to the exact builder).
    #[inline]
    pub fn access(&mut self, addr: u64) {
        self.accesses += 1;
        match self.sd.access_line(addr >> MRC_LINE_SHIFT) {
            SampledAccess::NotSampled => {}
            SampledAccess::Sampled { weight, dist } => {
                self.sampled_w += weight;
                self.sampled_accesses += 1;
                match dist {
                    LineDist::Repeat => self.first_hit_w[0] += weight,
                    LineDist::Reuse(d_s) => {
                        // rescaling rule: sampled distance ÷ rate ≈ true
                        // distance (weight IS 1/rate at access time)
                        let d = d_s as f64 * weight;
                        if let Some(i) = Self::first_hit_index_scaled(d) {
                            self.first_hit_w[i] += weight;
                        }
                    }
                    LineDist::Cold(_) => self.cold_w += weight,
                }
            }
        }
    }

    /// f64 analogue of the exact builder's first-hit index: at rate 1.0
    /// the scaled distance is an exact integer-valued f64, so the
    /// comparison agrees bit-for-bit with the integer version.
    fn first_hit_index_scaled(d_lines: f64) -> Option<usize> {
        MRC_CAPACITIES_BYTES
            .iter()
            .position(|&cap| d_lines < (cap / MRC_LINE_BYTES) as f64)
    }

    /// Raw (full-stream) access count.
    pub fn accesses(&self) -> u64 {
        self.accesses
    }

    /// How many of those accesses were sampled — the error yardstick:
    /// per-point noise is roughly `1/sqrt(rate * footprint_lines)`.
    pub fn sampled_accesses(&self) -> u64 {
        self.sampled_accesses
    }

    /// The sampling rate currently in force (fixed-size mode lowers it).
    pub fn current_rate(&self) -> f64 {
        self.sd.current_rate()
    }

    /// Resident sampled lines (bounded by `S_max` in fixed-size mode).
    pub fn resident(&self) -> usize {
        self.sd.resident()
    }

    /// Estimated compulsory misses (`Σ 1/R` over sampled cold accesses).
    pub fn cold_estimate(&self) -> u64 {
        self.cold_w.round() as u64
    }

    /// Estimated distinct-line footprint — same estimator as the cold
    /// count (each distinct line is cold exactly once).
    pub fn footprint_estimate(&self) -> u64 {
        self.cold_w.round() as u64
    }

    /// Estimated miss ratio per capacity point. All-zero when nothing
    /// was sampled (the curve is unknown; `sampled_accesses` tells the
    /// consumer so).
    pub fn miss_ratios(&self) -> [f64; N_MRC_POINTS] {
        let mut ratios = [0.0; N_MRC_POINTS];
        if self.sampled_w <= 0.0 {
            return ratios;
        }
        let mut hit_w = 0.0;
        for (i, r) in ratios.iter_mut().enumerate() {
            hit_w += self.first_hit_w[i];
            *r = (self.sampled_w - hit_w).max(0.0) / self.sampled_w;
        }
        ratios
    }

    /// Estimated absolute miss counts: the miss *ratios* re-applied to
    /// the raw access count. At rate 1.0 this round-trips the exact
    /// integer counts.
    pub fn estimated_miss_counts(&self) -> [u64; N_MRC_POINTS] {
        let ratios = self.miss_ratios();
        let mut misses = [0u64; N_MRC_POINTS];
        for i in 0..N_MRC_POINTS {
            misses[i] = (ratios[i] * self.accesses as f64).round() as u64;
        }
        misses
    }
}

#[cfg(test)]
mod tests {
    use super::super::MrcBuilder;
    use super::*;
    use crate::testkit::{address_trace, naive_lru_misses};
    use crate::util::Rng;

    #[test]
    fn mode_parsing_and_labels() {
        assert_eq!(MrcMode::from_name("exact").unwrap(), MrcMode::Exact);
        assert_eq!(
            MrcMode::from_name("sampled").unwrap(),
            MrcMode::Sampled { rate: DEFAULT_SAMPLE_RATE }
        );
        assert_eq!(
            MrcMode::from_name("sampled:0.1").unwrap(),
            MrcMode::Sampled { rate: 0.1 }
        );
        assert!(MrcMode::from_name("sampled:0").is_err());
        assert!(MrcMode::from_name("sampled:1.5").is_err());
        assert!(MrcMode::from_name("sampled:x").is_err());
        assert!(MrcMode::from_name("approx").is_err());
        assert_eq!(MrcMode::Exact.describe(), "exact");
        assert_eq!(MrcMode::Sampled { rate: 0.05 }.describe(), "sampled:0.05");
        assert_eq!(MrcMode::Sampled { rate: 0.05 }.name(), "sampled");
        assert_eq!(MrcMode::Exact.rate(), 1.0);
        assert_eq!(MrcMode::default(), MrcMode::Exact);
    }

    #[test]
    fn rate_one_admits_every_line_and_matches_exact_bitwise() {
        // at rate 1.0 the sampled substream IS the full stream and every
        // weight is exactly 1.0 — the estimator must reproduce the exact
        // kernel bit for bit
        let mut rng = Rng::new(0xCAFE);
        let addrs = address_trace(&mut rng, 40_000, 4096);
        let mut exact = MrcBuilder::new();
        let mut sampled = SampledMrc::new(1.0);
        for &a in &addrs {
            exact.access(a);
            sampled.access(a);
        }
        assert_eq!(sampled.sampled_accesses(), sampled.accesses());
        assert_eq!(sampled.current_rate(), 1.0);
        assert_eq!(sampled.cold_estimate(), exact.cold());
        assert_eq!(sampled.footprint_estimate(), exact.footprint_lines());
        assert_eq!(sampled.estimated_miss_counts(), exact.miss_counts());
        let exact_ratios: Vec<f64> = exact
            .miss_counts()
            .iter()
            .map(|&m| m as f64 / exact.accesses() as f64)
            .collect();
        for (s, e) in sampled.miss_ratios().iter().zip(&exact_ratios) {
            assert_eq!(s.to_bits(), e.to_bits(), "ratio bits diverge");
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut rng = Rng::new(7);
        let addrs = address_trace(&mut rng, 20_000, 8192);
        let run = || {
            let mut s = SampledMrc::new(0.1);
            for &a in &addrs {
                s.access(a);
            }
            (s.miss_ratios(), s.sampled_accesses(), s.cold_estimate())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sampled_curve_finds_the_knee_of_a_looping_working_set() {
        // 192 lines (12 KiB) looped 100×: true stack distance 191 —
        // comfortably inside 16 KiB (256 lines) and past 4 KiB (64
        // lines), with ≥4σ margin against hash-density luck at rate 0.5
        let mut s = SampledMrc::new(0.5);
        for _ in 0..100 {
            for line in 0..192u64 {
                s.access(line * MRC_LINE_BYTES);
            }
        }
        let r = s.miss_ratios();
        assert!(r[0] > 0.9, "4 KiB should miss, got {}", r[0]);
        assert!(r[1] < 0.1, "16 KiB should hit, got {}", r[1]);
        for w in r.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "curve must be monotone: {r:?}");
        }
        assert_eq!(super::super::slope_knee(&r), Some(1));
    }

    #[test]
    fn sampled_tracks_naive_lru_within_noise() {
        // randomized cross-check against a naive LRU at one mid-curve
        // capacity: ~4k-line footprint at rate 0.25 → ~1000 sampled
        // lines, noise ≈ 3% — assert a loose 10% band
        let mut rng = Rng::new(0xBEEF);
        let addrs = address_trace(&mut rng, 30_000, 32_768);
        let lines: Vec<u64> = addrs.iter().map(|a| a >> MRC_LINE_SHIFT).collect();
        let mut s = SampledMrc::new(0.25);
        for &a in &addrs {
            s.access(a);
        }
        let cap_lines = (MRC_CAPACITIES_BYTES[3] / MRC_LINE_BYTES) as usize;
        let naive = naive_lru_misses(lines.iter().copied(), cap_lines) as f64 / lines.len() as f64;
        let got = s.miss_ratios()[3];
        assert!(
            (got - naive).abs() < 0.10,
            "sampled {got:.4} vs naive {naive:.4}"
        );
    }

    #[test]
    fn fixed_size_bounds_residency_and_lowers_the_rate() {
        let mut rng = Rng::new(99);
        let addrs = address_trace(&mut rng, 50_000, 65_536);
        let mut s = SampledMrc::fixed_size(256);
        for (i, &a) in addrs.iter().enumerate() {
            s.access(a);
            if i % 64 == 0 {
                assert!(s.resident() <= 256, "resident {} > S_max", s.resident());
            }
        }
        assert!(s.resident() <= 256);
        // ~8k-line footprint vs 256 slots: the threshold must have moved
        assert!(s.current_rate() < 1.0);
        let r = s.miss_ratios();
        for w in r.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "curve must stay monotone: {r:?}");
        }
        assert!(r.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn evicted_lines_are_never_readmitted() {
        // drive far more distinct lines than S_max, then revisit them
        // all: a line evicted by the threshold drop must stay invisible
        // (hash >= threshold), never re-entering as a bogus cold miss
        let mut sd = SampledStackDistance::with_max_entries(1.0, 8);
        for line in 0..64u64 {
            sd.access_line(line);
        }
        assert!(sd.resident() <= 8);
        let rate = sd.current_rate();
        for line in 0..64u64 {
            match sd.access_line(line) {
                SampledAccess::NotSampled => {}
                SampledAccess::Sampled { dist, .. } => {
                    assert!(
                        !matches!(dist, LineDist::Cold(_)),
                        "resident line {line} reported cold on revisit"
                    );
                }
            }
        }
        // revisits admit nothing new and never raise the rate
        assert!(sd.current_rate() <= rate);
        assert!(sd.resident() <= 8);
    }

    #[test]
    fn empty_sampler_reports_a_flat_zero_curve() {
        let s = SampledMrc::new(0.01);
        assert_eq!(s.accesses(), 0);
        assert_eq!(s.sampled_accesses(), 0);
        assert_eq!(s.miss_ratios(), [0.0; N_MRC_POINTS]);
        assert_eq!(s.estimated_miss_counts(), [0u64; N_MRC_POINTS]);
        assert_eq!(s.cold_estimate(), 0);
    }
}
