//! A small bank of shadow set-associative caches.
//!
//! The MRC ([`super::mrc`]) is fully associative by construction; real
//! hierarchies are not, and dirty lines cost a writeback on eviction. This
//! bank replays the same access stream through three independent
//! set-associative write-allocate LRU caches — reusing the simulator's
//! [`sim::cache::Cache`](crate::sim::cache::Cache) model verbatim, so the
//! streaming counts can be cross-validated against a direct `sim` replay
//! (see `rust/tests/prop_traffic.rs`) — capturing associativity effects
//! and the dirty-writeback byte traffic the MRC cannot express.
//!
//! The caches are *independent* (each sees every access), not a hierarchy:
//! each level answers "what would a cache of this shape see", which is the
//! platform-independent question the paper's metrics ask.

use crate::sim::cache::Cache;

use super::mrc::MRC_LINE_BYTES;

/// Shape of one shadow cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShadowConfig {
    /// Short label used in reports/JSON ("l1", "l2", "llc").
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
}

/// The bank: L1-, L2- and LLC-shaped shadows at 64 B lines (host-class
/// shapes per Table 1's cache-per-core column).
pub const SHADOW_CONFIGS: [ShadowConfig; 3] = [
    ShadowConfig { name: "l1", capacity_bytes: 32 << 10, ways: 8 },
    ShadowConfig { name: "l2", capacity_bytes: 256 << 10, ways: 8 },
    ShadowConfig { name: "llc", capacity_bytes: 2 << 20, ways: 16 },
];

/// Finalized counts for one shadow cache.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShadowCacheStats {
    pub name: &'static str,
    pub capacity_bytes: u64,
    pub ways: u32,
    pub hits: u64,
    pub misses: u64,
    /// Dirty lines evicted (each is one line of writeback traffic).
    pub writebacks: u64,
}

impl ShadowCacheStats {
    pub fn miss_ratio(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }
}

/// The streaming bank of shadow caches.
#[derive(Debug, Clone)]
pub struct ShadowBank {
    caches: Vec<Cache>,
}

impl Default for ShadowBank {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowBank {
    pub fn new() -> ShadowBank {
        let line = MRC_LINE_BYTES as usize;
        ShadowBank {
            caches: SHADOW_CONFIGS
                .iter()
                .map(|c| Cache::new(c.capacity_bytes as usize, c.ways as usize, line))
                .collect(),
        }
    }

    /// Send one access through every shadow cache.
    #[inline]
    pub fn access(&mut self, addr: u64, is_store: bool) {
        for c in &mut self.caches {
            c.access(addr, is_store);
        }
    }

    /// Cache-major sweep over a dense access slice (the chunk-lane hot
    /// path): one cache's sets stay hot for the whole slice instead of
    /// being evicted three ways per access.
    #[inline]
    pub fn sweep(&mut self, addrs: &[u64], lanes: &crate::interp::ChunkLanes) {
        for c in &mut self.caches {
            for (i, &addr) in addrs.iter().enumerate() {
                c.access(addr, lanes.is_store(i));
            }
        }
    }

    pub fn finalize(&self) -> Vec<ShadowCacheStats> {
        SHADOW_CONFIGS
            .iter()
            .zip(&self.caches)
            .map(|(cfg, c)| ShadowCacheStats {
                name: cfg.name,
                capacity_bytes: cfg.capacity_bytes,
                ways: cfg.ways,
                hits: c.hits,
                misses: c.misses,
                writebacks: c.writebacks,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_matches_direct_cache_replay() {
        let mut rng = crate::util::Rng::new(9);
        let accs: Vec<(u64, bool)> = (0..4000)
            .map(|_| (0x20_000 + rng.below(2048) * 64, rng.below(4) == 0))
            .collect();
        let mut bank = ShadowBank::new();
        for &(a, s) in &accs {
            bank.access(a, s);
        }
        for (cfg, stats) in SHADOW_CONFIGS.iter().zip(bank.finalize()) {
            let mut direct = Cache::new(
                cfg.capacity_bytes as usize,
                cfg.ways as usize,
                MRC_LINE_BYTES as usize,
            );
            for &(a, s) in &accs {
                direct.access(a, s);
            }
            assert_eq!(stats.hits, direct.hits, "{}", cfg.name);
            assert_eq!(stats.misses, direct.misses, "{}", cfg.name);
            assert_eq!(stats.writebacks, direct.writebacks, "{}", cfg.name);
            assert_eq!(stats.hits + stats.misses, accs.len() as u64);
        }
    }

    #[test]
    fn read_only_stream_never_writes_back() {
        let mut bank = ShadowBank::new();
        for i in 0..100_000u64 {
            bank.access(i * 64, false);
        }
        for s in bank.finalize() {
            assert_eq!(s.writebacks, 0, "{}", s.name);
            assert!(s.miss_ratio() > 0.9, "streaming misses everywhere");
        }
    }
}
