//! One-pass exact miss-ratio curves (MRC) over a geometric capacity family.
//!
//! Built on the Mattson inclusion property: for a fully-associative LRU
//! cache, an access hits at capacity `C` lines **iff** its stack distance
//! (distinct lines touched since the previous access to the same line) is
//! `< C`. The exact distances come from the same Olken/Fenwick kernel the
//! `reuse` analyzer uses ([`StackDistance`]) — so the whole capacity
//! family is computed from **one** streaming pass over the address lane,
//! never re-scanning the trace per capacity.
//!
//! Cold (first-touch) accesses are compulsory misses at *every* capacity:
//! where `reuse` folds first touches into its distance histogram at the
//! current footprint (see its documented convention), the MRC keeps them
//! as a separate compulsory count — the curve's floor as capacity grows.

use crate::analysis::reuse::{LineDist, StackDistance};

/// Cache-line size the curve (and the hierarchy replay) are computed at.
pub const MRC_LINE_BYTES: u64 = 64;
/// `log2(MRC_LINE_BYTES)`.
pub const MRC_LINE_SHIFT: u32 = 6;

/// The geometric capacity family (bytes), 4 KiB → 64 MiB in ×4 steps:
/// spans L1 through beyond-LLC sizes at 64 B lines.
pub const MRC_CAPACITIES_BYTES: [u64; 8] = [
    4 << 10,
    16 << 10,
    64 << 10,
    256 << 10,
    1 << 20,
    4 << 20,
    16 << 20,
    64 << 20,
];

/// Number of points on the curve.
pub const N_MRC_POINTS: usize = MRC_CAPACITIES_BYTES.len();

/// Minimum miss-ratio drop between adjacent capacities for a curve to
/// have a knee at all: below this every step is noise-flat and the knee
/// is `None` (the footprint sentinel logic takes over for ranking).
pub const MIN_KNEE_DROP: f64 = 0.05;

/// Slope-based knee: the index of the capacity realizing the *steepest
/// drop* of the miss-ratio curve. The capacity family is geometric, so
/// adjacent differences are exactly the curve's slope in log-capacity
/// space; the knee is where the working set falls into the cache. Flat
/// curves (steepest drop `< MIN_KNEE_DROP`) have no knee; ties go to the
/// smallest capacity. This replaces the earlier curve-relative rule
/// (first point under 50% of the ceiling), which ranked flat-ish curves
/// on their noise rather than their shape.
pub fn slope_knee(miss_ratio: &[f64]) -> Option<usize> {
    let mut best_i = 0usize;
    let mut best_drop = 0.0f64;
    for i in 1..miss_ratio.len() {
        let drop = miss_ratio[i - 1] - miss_ratio[i];
        // A curve from a zero-access app is all 0/0 = NaN; NaN comparisons
        // are false so such drops could never win, but be explicit: a knee
        // must come from a finite slope.
        if drop.is_finite() && drop > best_drop {
            best_i = i;
            best_drop = drop;
        }
    }
    (best_drop >= MIN_KNEE_DROP).then_some(best_i)
}

/// Smallest capacity index at which an access with stack distance `d`
/// (in 64 B lines) hits, or `None` if it misses even the largest capacity.
#[inline]
fn first_hit_index(d: u64) -> Option<usize> {
    MRC_CAPACITIES_BYTES
        .iter()
        .position(|&cap| d < cap / MRC_LINE_BYTES)
}

/// Streaming MRC accumulator: one [`StackDistance`] at 64 B lines plus a
/// tiny per-capacity first-hit histogram.
#[derive(Debug, Clone)]
pub struct MrcBuilder {
    sd: StackDistance,
    /// `first_hit[i]` = accesses whose smallest hitting capacity is `i`
    /// (they hit at every capacity `>= i`, miss below).
    first_hit: [u64; N_MRC_POINTS],
    cold: u64,
    accesses: u64,
}

impl Default for MrcBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl MrcBuilder {
    pub fn new() -> MrcBuilder {
        MrcBuilder {
            sd: StackDistance::new(),
            first_hit: [0; N_MRC_POINTS],
            cold: 0,
            accesses: 0,
        }
    }

    /// Record one byte-address access.
    #[inline]
    pub fn access(&mut self, addr: u64) {
        self.accesses += 1;
        match self.sd.access_line(addr >> MRC_LINE_SHIFT) {
            // distance 0: hits at every capacity in the family
            LineDist::Repeat => self.first_hit[0] += 1,
            LineDist::Reuse(d) => {
                if let Some(i) = first_hit_index(d) {
                    self.first_hit[i] += 1;
                }
                // else: capacity miss even at the largest point
            }
            LineDist::Cold(_) => self.cold += 1,
        }
    }

    pub fn accesses(&self) -> u64 {
        self.accesses
    }

    /// Compulsory (first-touch) misses — missed at every capacity.
    pub fn cold(&self) -> u64 {
        self.cold
    }

    /// Distinct 64 B lines touched (the working-set footprint).
    pub fn footprint_lines(&self) -> u64 {
        self.sd.footprint()
    }

    /// Exact miss counts per capacity, smallest → largest.
    pub fn miss_counts(&self) -> [u64; N_MRC_POINTS] {
        let mut misses = [0u64; N_MRC_POINTS];
        let mut hits_cum = 0u64;
        for (i, &fh) in self.first_hit.iter().enumerate() {
            hits_cum += fh;
            misses[i] = self.accesses - hits_cum;
        }
        misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shared fully-associative LRU oracle over this trace's lines.
    fn naive_lru_misses(addrs: &[u64], cap_lines: usize) -> u64 {
        crate::testkit::naive_lru_misses(addrs.iter().map(|&a| a >> MRC_LINE_SHIFT), cap_lines)
    }

    #[test]
    fn capacity_family_is_sane() {
        assert!(N_MRC_POINTS >= 6);
        for w in MRC_CAPACITIES_BYTES.windows(2) {
            assert!(w[1] > w[0]);
        }
        // every capacity is a whole number of lines
        for &c in &MRC_CAPACITIES_BYTES {
            assert_eq!(c % MRC_LINE_BYTES, 0);
        }
    }

    #[test]
    fn small_working_set_only_cold_misses() {
        // 32 lines, re-walked 10 times: fits the smallest capacity (64
        // lines), so every miss is compulsory
        let mut b = MrcBuilder::new();
        for _ in 0..10 {
            for i in 0..32u64 {
                b.access(0x10_000 + i * 64);
            }
        }
        assert_eq!(b.cold(), 32);
        assert_eq!(b.footprint_lines(), 32);
        let m = b.miss_counts();
        assert!(m.iter().all(|&x| x == 32), "{m:?}");
    }

    #[test]
    fn matches_naive_lru_randomized() {
        let mut rng = crate::util::Rng::new(41);
        // footprint ~512 lines with a hot subset: straddles the 64-line
        // and 256-line capacities
        let addrs: Vec<u64> = (0..6000)
            .map(|_| {
                if rng.below(2) == 0 {
                    0x10_000 + rng.below(48) * 64
                } else {
                    0x10_000 + rng.below(512) * 64
                }
            })
            .collect();
        let mut b = MrcBuilder::new();
        for &a in &addrs {
            b.access(a);
        }
        let m = b.miss_counts();
        for (i, &cap) in MRC_CAPACITIES_BYTES.iter().enumerate().take(3) {
            let want = naive_lru_misses(&addrs, (cap / MRC_LINE_BYTES) as usize);
            assert_eq!(m[i], want, "capacity {cap}");
        }
        // monotone non-increasing in capacity (Mattson inclusion)
        for w in m.windows(2) {
            assert!(w[1] <= w[0]);
        }
        // floor is the compulsory count once capacity exceeds the footprint
        assert_eq!(*m.last().unwrap(), b.cold());
    }

    #[test]
    fn slope_knee_lands_on_the_steepest_drop() {
        // classic working-set curve: flat-high, cliff, flat-low
        assert_eq!(slope_knee(&[0.9, 0.88, 0.2, 0.18, 0.17]), Some(2));
        // two drops: the steeper one wins regardless of order
        assert_eq!(slope_knee(&[0.9, 0.6, 0.55, 0.1, 0.1]), Some(3));
        assert_eq!(slope_knee(&[0.9, 0.3, 0.25, 0.1, 0.1]), Some(1));
        // tie: smallest capacity wins (deterministic)
        assert_eq!(slope_knee(&[0.8, 0.5, 0.2]), Some(1));
    }

    #[test]
    fn flat_curves_have_no_slope_knee() {
        assert_eq!(slope_knee(&[0.0; 8]), None);
        assert_eq!(slope_knee(&[1.0; 8]), None);
        // gentle drift below MIN_KNEE_DROP per step is still flat
        assert_eq!(slope_knee(&[0.50, 0.48, 0.46, 0.44]), None);
        assert_eq!(slope_knee(&[]), None);
        assert_eq!(slope_knee(&[0.7]), None);
    }

    #[test]
    fn nan_curves_have_no_slope_knee() {
        // an empty-traffic app divides 0 misses by 0 accesses everywhere
        assert_eq!(slope_knee(&[f64::NAN; 8]), None);
        // a NaN next to real points must neither win nor poison the scan:
        // the NaN-adjacent drops are skipped, the real cliff still counts
        assert_eq!(slope_knee(&[0.9, f64::NAN, 0.88, 0.2, 0.18]), Some(3));
        // NaN drops alone (real points but flat) stay flat
        assert_eq!(slope_knee(&[0.5, f64::NAN, 0.5, 0.5]), None);
        assert_eq!(slope_knee(&[f64::INFINITY, 0.5, 0.5]), None);
    }

    #[test]
    fn sub_line_accesses_share_a_line() {
        let mut b = MrcBuilder::new();
        // 8 consecutive f64s in one 64 B line: 1 cold miss, 7 repeats
        for i in 0..8u64 {
            b.access(0x40_000 + i * 8);
        }
        assert_eq!(b.cold(), 1);
        assert_eq!(b.miss_counts()[0], 1);
    }
}
