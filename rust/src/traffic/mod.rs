//! Streaming memory-traffic and cache-locality subsystem (the data-movement
//! signal NMPO-style offload models rank by: bytes moved per instruction
//! and, above all, the DRAM traffic left over *after* the cache hierarchy).
//!
//! [`TrafficAnalyzer`] runs as one more [`Instrument`] inside the
//! `AnalyzerStack` and folds the trace **exactly once**, sweeping the dense
//! [`ChunkLanes`] SoA view — addresses, sizes *and* the store bitset, the
//! first production consumer of all four lanes — with no `TraceEvent`
//! matching on the hot path. Per run it produces:
//!
//! * **Miss-ratio curves** ([`mrc`]): exact miss ratios for the geometric
//!   capacity family [`MRC_CAPACITIES_BYTES`] (4 KiB → 64 MiB, 64 B lines)
//!   from a single pass, via the same Olken/Fenwick stack-distance kernel
//!   `reuse` uses (Mattson: an access hits a fully-associative LRU cache of
//!   `C` lines iff its stack distance is `< C`). **Cold-miss convention**:
//!   first touches are compulsory misses at *every* capacity — the curve's
//!   floor. The **MRC knee** is slope-based ([`mrc::slope_knee`]): the
//!   capacity realizing the curve's steepest drop in log-capacity space;
//!   flat curves have no knee.
//! * **Hierarchy replay** ([`hierarchy`]): a real L1→L2→LLC chain —
//!   inclusive or exclusive ([`HierarchyPolicy`], CLI `--hierarchy`) —
//!   where each level only sees its upper level's misses, dirty lines
//!   write back downward, and DRAM fill/writeback traffic is exactly what
//!   crosses the last level. This replaces the three *independent* shadow
//!   caches earlier revisions carried (each seeing every access), whose
//!   DRAM figure could not subtract upper-level hits; the old bank
//!   survives as a test-only oracle in `testkit`, and
//!   `rust/tests/prop_hierarchy.rs` proves the streaming chain equivalent
//!   to a naive event-at-a-time multi-level replay under both policies.
//! * **Byte-traffic accounting**: read/write bytes per instruction from
//!   the sizes lane + store bitset, and post-hierarchy DRAM line traffic
//!   (last-level fills + writebacks × 64 B).
//!
//! **Exact vs sampled MRC** ([`MrcMode`], CLI `--mrc`): the default
//! `exact` mode runs the full Olken/Fenwick kernel — O(footprint) state,
//! O(log n) per access, bit-identical output. `sampled:<rate>` swaps in
//! fixed-rate SHARDS spatial sampling ([`sample`]): only lines whose hash
//! falls under the rate threshold are tracked, sampled distances and cold
//! misses are rescaled by `1/rate`, and state shrinks to O(rate ·
//! footprint). Miss ratios then carry noise ≈ `1/sqrt(rate ×
//! footprint_lines)` per point — at 1% on a million-line footprint that
//! is well under the `MIN_KNEE_DROP` knee threshold, while tiny-footprint
//! runs should stay exact (or check `mrc.sampled_accesses` in the JSON
//! before trusting the knee).
//!
//! **Separable halves** ([`TrafficParts`]): the MRC + byte accounting and
//! the hierarchy replay are independent folds over the same address lane,
//! so the sharded pipeline can place them on *different* workers
//! (`analysis/shard.rs` gives each its own lane group); the merge stitches
//! the halves back into one [`TrafficMetrics`] via
//! [`TrafficMetrics::adopt_parts`].
//!
//! Every counter is a pure fold over the memory-access subsequence — and
//! the sampling hash is deterministic — so [`TrafficMetrics`] (per-level
//! counters included) is bit-identical across the per-event,
//! inline-chunked, offload and sharded pipeline modes in *both* MRC modes
//! (enforced in `rust/tests/prop_chunked.rs` and
//! `rust/tests/prop_mrc_sampled.rs`).

pub mod hierarchy;
pub mod mrc;
pub mod sample;

pub use hierarchy::{
    HierarchyConfig, HierarchyPolicy, HierarchyReplay, LevelConfig, LevelStats, SpecError,
    SweepCounters, HIERARCHY_LEVELS, MAX_LEVELS,
};
pub use mrc::{
    slope_knee, MrcBuilder, MIN_KNEE_DROP, MRC_CAPACITIES_BYTES, MRC_LINE_BYTES, N_MRC_POINTS,
};
pub use sample::{
    MrcMode, SampledAccess, SampledMrc, SampledStackDistance, DEFAULT_SAMPLE_RATE,
    DEFAULT_SAMPLE_S_MAX,
};

use crate::interp::{ChunkLanes, Instrument, LaneMask, TraceEvent};
use crate::util::Json;

/// Configuration knobs of the traffic family, threaded together from the
/// CLI (`--hierarchy`, `--mrc`) down to the per-shard analyzer stacks.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TrafficOpts {
    /// Content-management policy of the hierarchy replay.
    pub hierarchy: HierarchyPolicy,
    /// Stack-distance kernel the MRC runs on.
    pub mrc: MrcMode,
    /// CLI `--mrc-smax`: cap the SHARDS sampler at this many resident
    /// lines (fixed-size mode). `None` keeps the mode's own kernel
    /// choice; only meaningful with a sampled [`MrcMode`].
    pub mrc_smax: Option<usize>,
    /// CLI `--hierarchy-spec`: a fully custom hierarchy shape for the
    /// main replay, overriding the host shape (and `hierarchy` above).
    /// `'static` so the opts stay `Copy` all the way down the per-shard
    /// fan-out: the CLI/coordinator leaks the one parsed config per run.
    pub spec: Option<&'static HierarchyConfig>,
    /// CLI `--sweep`: the DSE grid. Each config gets its own small
    /// [`HierarchyReplay`] folding the same lanes as the main replay, in
    /// the same single pass. Same leak-once `'static` pattern as `spec`.
    pub sweep: Option<&'static [HierarchyConfig]>,
}

impl TrafficOpts {
    /// Default MRC mode under the given hierarchy policy (the shape every
    /// pre-`--mrc` call site wants).
    pub fn with_hierarchy(hierarchy: HierarchyPolicy) -> Self {
        TrafficOpts { hierarchy, ..Default::default() }
    }

    pub fn with_mrc(mut self, mrc: MrcMode) -> Self {
        self.mrc = mrc;
        self
    }

    pub fn with_mrc_smax(mut self, smax: Option<usize>) -> Self {
        self.mrc_smax = smax;
        self
    }

    pub fn with_spec(mut self, spec: Option<&'static HierarchyConfig>) -> Self {
        self.spec = spec;
        self
    }

    pub fn with_sweep(mut self, sweep: Option<&'static [HierarchyConfig]>) -> Self {
        self.sweep = sweep;
        self
    }

    /// The shape the main replay runs under: the `--hierarchy-spec`
    /// config when given, else the host chain under `hierarchy`.
    pub fn main_config(&self) -> HierarchyConfig {
        match self.spec {
            Some(cfg) => cfg.clone(),
            None => HierarchyConfig::host(self.hierarchy),
        }
    }
}

/// The separable halves of the traffic family. `MRC` owns the miss-ratio
/// curve *and* the byte accounting (both fold the sizes/stores lanes);
/// `HIERARCHY` owns the L1→L2→LLC replay and the DRAM counters. A shard
/// plan hands each worker the parts it should fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficParts(u8);

impl TrafficParts {
    pub const NONE: TrafficParts = TrafficParts(0);
    pub const MRC: TrafficParts = TrafficParts(1);
    pub const HIERARCHY: TrafficParts = TrafficParts(2);
    pub const ALL: TrafficParts = TrafficParts(3);

    #[inline]
    pub fn has_mrc(self) -> bool {
        self.0 & Self::MRC.0 != 0
    }

    #[inline]
    pub fn has_hierarchy(self) -> bool {
        self.0 & Self::HIERARCHY.0 != 0
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    #[inline]
    pub fn is_all(self) -> bool {
        self == Self::ALL
    }

    #[inline]
    pub fn union(self, other: TrafficParts) -> TrafficParts {
        TrafficParts(self.0 | other.0)
    }

    #[inline]
    pub fn intersect(self, other: TrafficParts) -> TrafficParts {
        TrafficParts(self.0 & other.0)
    }
}

impl Default for TrafficParts {
    fn default() -> Self {
        TrafficParts::ALL
    }
}

/// The MRC engine behind the traffic family: the exact Olken/Fenwick
/// kernel or the SHARDS sampler, selected by [`MrcMode`].
#[derive(Debug, Clone)]
enum MrcEngine {
    Exact(MrcBuilder),
    Sampled(SampledMrc),
}

impl MrcEngine {
    /// Engine for `opts`: exact kernel, fixed-rate SHARDS, or (with
    /// `mrc_smax` set) fixed-size SHARDS seeded at the mode's rate.
    fn for_opts(opts: TrafficOpts) -> MrcEngine {
        match (opts.mrc, opts.mrc_smax) {
            (MrcMode::Exact, _) => MrcEngine::Exact(MrcBuilder::new()),
            (MrcMode::Sampled { rate }, None) => MrcEngine::Sampled(SampledMrc::new(rate)),
            (MrcMode::Sampled { rate }, Some(s)) => {
                MrcEngine::Sampled(SampledMrc::with_smax(rate, s))
            }
        }
    }
}

/// The streaming analyzer: MRC accumulator + byte counters and/or the
/// hierarchy replay, each present only when its [`TrafficParts`] half is
/// enabled (an unsplit analyzer carries both), all fed from the same pass.
#[derive(Debug, Clone)]
pub struct TrafficAnalyzer {
    mrc: Option<MrcEngine>,
    mrc_mode: MrcMode,
    hierarchy: Option<HierarchyReplay>,
    /// The DSE grid (`--sweep`): one small replay per grid config, all
    /// folding the same accesses as the main replay in the same pass.
    /// Rides the hierarchy half of the family in the shard plan.
    sweeps: Vec<HierarchyReplay>,
    reads: u64,
    writes: u64,
    read_bytes: u64,
    write_bytes: u64,
}

impl Default for TrafficAnalyzer {
    fn default() -> Self {
        Self::with_opts(TrafficOpts::default())
    }
}

impl TrafficAnalyzer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Host-shaped chain under `policy` (exact MRC, both halves).
    pub fn with_policy(policy: HierarchyPolicy) -> Self {
        Self::with_config(HierarchyConfig::host(policy))
    }

    /// Both halves, exact MRC, custom hierarchy shape.
    pub fn with_config(cfg: HierarchyConfig) -> Self {
        TrafficAnalyzer {
            mrc: Some(MrcEngine::Exact(MrcBuilder::new())),
            mrc_mode: MrcMode::Exact,
            hierarchy: Some(HierarchyReplay::new(cfg)),
            sweeps: Vec::new(),
            reads: 0,
            writes: 0,
            read_bytes: 0,
            write_bytes: 0,
        }
    }

    /// Both halves under `opts` (the CLI `--hierarchy`/`--mrc` flags land
    /// here through the `AnalyzerStack`).
    pub fn with_opts(opts: TrafficOpts) -> Self {
        Self::with_opts_parts(opts, TrafficParts::ALL)
    }

    /// Only the selected halves — the sharded pipeline's entry point:
    /// a worker folding just the hierarchy replay allocates no MRC state
    /// and requests no sizes lane, and vice versa.
    pub fn with_opts_parts(opts: TrafficOpts, parts: TrafficParts) -> Self {
        let sweeps = if parts.has_hierarchy() {
            opts.sweep
                .map(|grid| grid.iter().map(|c| HierarchyReplay::new(c.clone())).collect())
                .unwrap_or_default()
        } else {
            Vec::new()
        };
        TrafficAnalyzer {
            mrc: parts.has_mrc().then(|| MrcEngine::for_opts(opts)),
            mrc_mode: opts.mrc,
            hierarchy: parts.has_hierarchy().then(|| HierarchyReplay::new(opts.main_config())),
            sweeps,
            reads: 0,
            writes: 0,
            read_bytes: 0,
            write_bytes: 0,
        }
    }

    /// Record one memory access (the per-event reference path).
    #[inline]
    pub fn record(&mut self, addr: u64, size: u8, is_store: bool) {
        if self.mrc.is_some() {
            if is_store {
                self.writes += 1;
                self.write_bytes += size as u64;
            } else {
                self.reads += 1;
                self.read_bytes += size as u64;
            }
        }
        match &mut self.mrc {
            Some(MrcEngine::Exact(b)) => b.access(addr),
            Some(MrcEngine::Sampled(s)) => s.access(addr),
            None => {}
        }
        if let Some(h) = &mut self.hierarchy {
            h.access(addr, is_store);
        }
        for s in &mut self.sweeps {
            s.access(addr, is_store);
        }
    }

    /// Finalize into [`TrafficMetrics`]. `dyn_instrs` is the run's dynamic
    /// instruction count (for the per-instruction rates). Halves this
    /// analyzer does not carry keep their empty default shape — the
    /// sharded merge fills them from the worker that owns them.
    pub fn finalize(&self, dyn_instrs: u64) -> TrafficMetrics {
        let mut m = TrafficMetrics {
            dyn_instrs,
            mrc_mode: self.mrc_mode,
            ..TrafficMetrics::default()
        };
        match &self.mrc {
            Some(MrcEngine::Exact(b)) => {
                let accesses = b.accesses();
                let misses = b.miss_counts();
                let ratio: Vec<f64> = misses
                    .iter()
                    .map(|&mm| if accesses == 0 { 0.0 } else { mm as f64 / accesses as f64 })
                    .collect();
                m.mrc_knee_bytes = if accesses == 0 {
                    None
                } else {
                    slope_knee(&ratio).map(|i| MRC_CAPACITIES_BYTES[i])
                };
                m.accesses = accesses;
                m.cold_misses = b.cold();
                m.footprint_lines = b.footprint_lines();
                m.mrc_misses = misses.to_vec();
                m.mrc_miss_ratio = ratio;
                // exact mode: every access is "sampled"
                m.mrc_sampled_accesses = accesses;
            }
            Some(MrcEngine::Sampled(s)) => {
                let ratio = s.miss_ratios().to_vec();
                m.mrc_knee_bytes = if s.sampled_accesses() == 0 {
                    None
                } else {
                    slope_knee(&ratio).map(|i| MRC_CAPACITIES_BYTES[i])
                };
                m.accesses = s.accesses();
                m.cold_misses = s.cold_estimate();
                m.footprint_lines = s.footprint_estimate();
                m.mrc_misses = s.estimated_miss_counts().to_vec();
                m.mrc_miss_ratio = ratio;
                m.mrc_sampled_accesses = s.sampled_accesses();
            }
            None => {}
        }
        if self.mrc.is_some() {
            m.reads = self.reads;
            m.writes = self.writes;
            m.read_bytes = self.read_bytes;
            m.write_bytes = self.write_bytes;
        }
        if let Some(h) = &self.hierarchy {
            m.hierarchy_policy = h.policy();
            m.levels = h.finalize();
            m.dram_fills = h.dram_fills();
            m.dram_writebacks = h.dram_writebacks();
        }
        m.sweep = self.sweeps.iter().map(|s| s.sweep_counters()).collect();
        m
    }
}

impl Instrument for TrafficAnalyzer {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Instr(i) = ev {
            if let Some(m) = i.mem {
                self.record(m.addr, m.size, m.is_store);
            }
        }
    }

    /// Lane path (the hot path): structure-major sweeps over the dense
    /// lanes — byte tallies from sizes + store bits, then the MRC stack,
    /// then the hierarchy replay, each walking the packed slice while its
    /// own state stays hot. Per-structure access order matches the
    /// per-event path exactly, so the fold is bit-identical.
    fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
        let addrs = lanes.addrs();
        if addrs.is_empty() {
            return;
        }
        if self.mrc.is_some() {
            let sizes = lanes.sizes();
            let (mut reads, mut writes) = (0u64, 0u64);
            let (mut rb, mut wb) = (0u64, 0u64);
            for (i, &size) in sizes.iter().enumerate() {
                if lanes.is_store(i) {
                    writes += 1;
                    wb += size as u64;
                } else {
                    reads += 1;
                    rb += size as u64;
                }
            }
            self.reads += reads;
            self.writes += writes;
            self.read_bytes += rb;
            self.write_bytes += wb;
        }
        match &mut self.mrc {
            Some(MrcEngine::Exact(b)) => {
                for &addr in addrs {
                    b.access(addr);
                }
            }
            Some(MrcEngine::Sampled(s)) => {
                for &addr in addrs {
                    s.access(addr);
                }
            }
            None => {}
        }
        if let Some(h) = &mut self.hierarchy {
            h.sweep(addrs, lanes);
        }
        for s in &mut self.sweeps {
            s.sweep(addrs, lanes);
        }
    }

    fn wants_lanes(&self) -> bool {
        true
    }

    /// Exactly the lanes the carried halves fold: the hierarchy replay
    /// never reads sizes, so a hierarchy-only shard skips packing the
    /// sizes lane entirely.
    fn lane_needs(&self) -> LaneMask {
        let mut needs = LaneMask::NONE;
        if self.mrc.is_some() {
            needs |= LaneMask::ADDRS | LaneMask::SIZES | LaneMask::STORES;
        }
        if self.hierarchy.is_some() || !self.sweeps.is_empty() {
            needs |= LaneMask::ADDRS | LaneMask::STORES;
        }
        needs
    }
}

/// Finalized traffic metrics — the `traffic` member of
/// [`AppMetrics`](crate::analysis::AppMetrics). Shape-stable when the
/// family is deselected: the full capacity family with zero counts.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMetrics {
    pub accesses: u64,
    pub reads: u64,
    pub writes: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    /// Dynamic instructions of the profiled run (rate denominator).
    pub dyn_instrs: u64,
    /// Compulsory (first-touch) misses at 64 B lines.
    pub cold_misses: u64,
    /// Distinct 64 B lines touched.
    pub footprint_lines: u64,
    /// Capacity family (bytes), smallest → largest.
    pub mrc_capacities: Vec<u64>,
    /// Exact miss counts per capacity (fully-associative LRU, 64 B lines).
    pub mrc_misses: Vec<u64>,
    /// `mrc_misses[i] / accesses` (0 when the run had no accesses).
    pub mrc_miss_ratio: Vec<f64>,
    /// Capacity realizing the curve's steepest drop ([`slope_knee`]);
    /// `None` for flat (or empty) curves.
    pub mrc_knee_bytes: Option<u64>,
    /// Stack-distance kernel the curve came from. Under `Sampled`,
    /// `cold_misses`, `footprint_lines`, `mrc_misses` and
    /// `mrc_miss_ratio` are SHARDS estimates, not exact counts.
    pub mrc_mode: MrcMode,
    /// Accesses the MRC kernel actually folded: equals `accesses` in
    /// exact mode, the sampled subset under SHARDS — the error yardstick
    /// (per-point noise ≈ `1/sqrt(rate × footprint_lines)`).
    pub mrc_sampled_accesses: u64,
    /// Content-management policy the hierarchy was replayed under.
    pub hierarchy_policy: HierarchyPolicy,
    /// Per-level hit/miss/writeback counts, L1 → LLC. Each level only saw
    /// its upper level's misses (see [`hierarchy`]).
    pub levels: Vec<LevelStats>,
    /// Line fills from DRAM (== last level's misses).
    pub dram_fills: u64,
    /// Dirty lines written back to DRAM (== last level's writebacks).
    pub dram_writebacks: u64,
    /// One [`SweepCounters`] per `--sweep` grid config, in grid order
    /// (empty for non-sweep runs). Each entry's counters are bit-identical
    /// to a standalone replay of the whole trace at that config.
    pub sweep: Vec<SweepCounters>,
}

impl Default for TrafficMetrics {
    /// The empty (family-deselected) shape: full capacity family and
    /// hierarchy chain, all counts zero — reports and figures never change
    /// layout, and no analyzer state is allocated just to emit zeros.
    fn default() -> Self {
        TrafficMetrics {
            accesses: 0,
            reads: 0,
            writes: 0,
            read_bytes: 0,
            write_bytes: 0,
            dyn_instrs: 0,
            cold_misses: 0,
            footprint_lines: 0,
            mrc_capacities: MRC_CAPACITIES_BYTES.to_vec(),
            mrc_misses: vec![0; N_MRC_POINTS],
            mrc_miss_ratio: vec![0.0; N_MRC_POINTS],
            mrc_knee_bytes: None,
            mrc_mode: MrcMode::Exact,
            mrc_sampled_accesses: 0,
            hierarchy_policy: HierarchyPolicy::default(),
            levels: HIERARCHY_LEVELS
                .iter()
                .map(|c| LevelStats {
                    name: c.name,
                    capacity_bytes: c.capacity_bytes,
                    ways: c.ways,
                    hits: 0,
                    misses: 0,
                    writebacks: 0,
                })
                .collect(),
            dram_fills: 0,
            dram_writebacks: 0,
            sweep: Vec::new(),
        }
    }
}

impl TrafficMetrics {
    /// Merge the halves `src` owns into `self` — the sharded pipeline's
    /// stitch when the MRC and hierarchy replay ran on different workers.
    /// Each half moves as a block: MRC brings the byte accounting,
    /// access/cold/footprint counts, curve, knee, mode and the rate
    /// denominator; hierarchy brings the per-level counters and DRAM
    /// traffic.
    pub fn adopt_parts(&mut self, src: TrafficMetrics, parts: TrafficParts) {
        if parts.is_all() {
            *self = src;
            return;
        }
        if parts.has_mrc() {
            self.accesses = src.accesses;
            self.reads = src.reads;
            self.writes = src.writes;
            self.read_bytes = src.read_bytes;
            self.write_bytes = src.write_bytes;
            self.dyn_instrs = src.dyn_instrs;
            self.cold_misses = src.cold_misses;
            self.footprint_lines = src.footprint_lines;
            self.mrc_capacities = src.mrc_capacities;
            self.mrc_misses = src.mrc_misses;
            self.mrc_miss_ratio = src.mrc_miss_ratio;
            self.mrc_knee_bytes = src.mrc_knee_bytes;
            self.mrc_mode = src.mrc_mode;
            self.mrc_sampled_accesses = src.mrc_sampled_accesses;
        }
        if parts.has_hierarchy() {
            self.hierarchy_policy = src.hierarchy_policy;
            self.levels = src.levels;
            self.dram_fills = src.dram_fills;
            self.dram_writebacks = src.dram_writebacks;
            self.sweep = src.sweep;
        }
    }

    /// Total (read + write) bytes per dynamic instruction — the paper-line
    /// "data movement per instruction" signal.
    pub fn bytes_per_instr(&self) -> f64 {
        if self.dyn_instrs == 0 {
            0.0
        } else {
            (self.read_bytes + self.write_bytes) as f64 / self.dyn_instrs as f64
        }
    }

    pub fn read_bytes_per_instr(&self) -> f64 {
        if self.dyn_instrs == 0 {
            0.0
        } else {
            self.read_bytes as f64 / self.dyn_instrs as f64
        }
    }

    pub fn write_bytes_per_instr(&self) -> f64 {
        if self.dyn_instrs == 0 {
            0.0
        } else {
            self.write_bytes as f64 / self.dyn_instrs as f64
        }
    }

    /// The last (DRAM-side) level of the chain.
    pub fn llc(&self) -> Option<&LevelStats> {
        self.levels.last()
    }

    /// Line-fill traffic from DRAM: post-hierarchy misses × 64 B. Upper
    /// -level hits never reach DRAM, so they are subtracted by
    /// construction (the old independent bank could not do this).
    pub fn dram_fill_bytes(&self) -> u64 {
        self.dram_fills * MRC_LINE_BYTES
    }

    /// Writeback traffic to DRAM: dirty last-level evictions × 64 B.
    pub fn dram_writeback_bytes(&self) -> u64 {
        self.dram_writebacks * MRC_LINE_BYTES
    }

    /// Total DRAM-side line traffic per instruction (fills + writebacks) —
    /// the post-hierarchy signal the offload advisor ranks by.
    pub fn dram_bytes_per_instr(&self) -> f64 {
        if self.dyn_instrs == 0 {
            0.0
        } else {
            (self.dram_fill_bytes() + self.dram_writeback_bytes()) as f64 / self.dyn_instrs as f64
        }
    }

    /// The knee as a comparable scalar for rank correlation (the advisor's
    /// Spearman). A curve with a knee ranks at the knee capacity. A flat
    /// curve has no knee for one of two *opposite* reasons, disambiguated
    /// by the footprint: the whole working set fits the smallest capacity
    /// (cache-friendly — ranks below the family at half the smallest
    /// capacity) or no capacity in the family tames the misses
    /// (cache-hostile — ranks past the family at twice the largest).
    pub fn knee_or_sentinel(&self) -> f64 {
        if let Some(b) = self.mrc_knee_bytes {
            return b as f64;
        }
        let smallest = self.mrc_capacities.first().copied().unwrap_or(0);
        let largest = self.mrc_capacities.last().copied().unwrap_or(0);
        if self.footprint_lines * MRC_LINE_BYTES <= smallest {
            (smallest / 2) as f64
        } else {
            (largest * 2) as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("accesses", self.accesses);
        j.set("reads", self.reads);
        j.set("writes", self.writes);
        j.set("read_bytes", self.read_bytes);
        j.set("write_bytes", self.write_bytes);
        j.set("bytes_per_instr", self.bytes_per_instr());
        j.set("read_bytes_per_instr", self.read_bytes_per_instr());
        j.set("write_bytes_per_instr", self.write_bytes_per_instr());
        j.set("cold_misses", self.cold_misses);
        j.set("footprint_lines", self.footprint_lines);
        let caps_f: Vec<f64> = self.mrc_capacities.iter().map(|&c| c as f64).collect();
        let misses_f: Vec<f64> = self.mrc_misses.iter().map(|&m| m as f64).collect();
        let mut mrc = Json::obj();
        mrc.set("line_bytes", MRC_LINE_BYTES);
        mrc.set("mode", self.mrc_mode.name());
        mrc.set("sample_rate", self.mrc_mode.rate());
        mrc.set("sampled_accesses", self.mrc_sampled_accesses);
        mrc.set("capacities_bytes", caps_f);
        mrc.set("misses", misses_f);
        mrc.set("miss_ratio", self.mrc_miss_ratio.clone());
        j.set("mrc", mrc);
        match self.mrc_knee_bytes {
            Some(b) => j.set("mrc_knee_bytes", b),
            None => j.set("mrc_knee_bytes", Json::Null),
        };
        let mut hier = Json::obj();
        hier.set("policy", self.hierarchy_policy.name());
        let levels: Vec<Json> = self
            .levels
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("name", s.name);
                o.set("capacity_bytes", s.capacity_bytes);
                o.set("ways", s.ways as u64);
                o.set("hits", s.hits);
                o.set("misses", s.misses);
                o.set("writebacks", s.writebacks);
                o.set("miss_ratio", s.miss_ratio());
                o
            })
            .collect();
        hier.set("levels", levels);
        j.set("hierarchy", hier);
        let mut dram = Json::obj();
        dram.set("fills", self.dram_fills);
        dram.set("writebacks", self.dram_writebacks);
        dram.set("fill_bytes", self.dram_fill_bytes());
        dram.set("writeback_bytes", self.dram_writeback_bytes());
        dram.set("bytes_per_instr", self.dram_bytes_per_instr());
        j.set("dram", dram);
        if !self.sweep.is_empty() {
            let grid: Vec<Json> = self.sweep.iter().map(|s| s.to_json()).collect();
            j.set("sweep", grid);
        }
        j
    }
}

/// Human-readable capacity label for report columns ("4K", "1M", ...).
pub fn capacity_label(bytes: u64) -> String {
    if bytes >= 1 << 20 {
        format!("{}M", bytes >> 20)
    } else {
        format!("{}K", bytes >> 10)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::{InstrEvent, MemAccess};
    use crate::ir::Op;

    fn mem_ev(addr: u64, size: u8, is_store: bool) -> TraceEvent {
        TraceEvent::Instr(InstrEvent {
            op: if is_store { Op::Store } else { Op::Load },
            dst: if is_store { None } else { Some(1) },
            srcs: [0; 3],
            n_srcs: if is_store { 2 } else { 1 },
            mem: Some(MemAccess { addr, size, is_store }),
            block: 0,
        })
    }

    #[test]
    fn byte_accounting_splits_reads_and_writes() {
        let mut t = TrafficAnalyzer::new();
        t.record(0x100, 8, false);
        t.record(0x108, 8, false);
        t.record(0x200, 4, true);
        let m = t.finalize(10);
        assert_eq!((m.reads, m.writes), (2, 1));
        assert_eq!((m.read_bytes, m.write_bytes), (16, 4));
        assert!((m.bytes_per_instr() - 2.0).abs() < 1e-12);
        assert!((m.read_bytes_per_instr() - 1.6).abs() < 1e-12);
        assert_eq!(m.accesses, 3);
    }

    #[test]
    fn lane_sweep_matches_per_event_records() {
        for policy in [HierarchyPolicy::Inclusive, HierarchyPolicy::Exclusive] {
            let mut rng = crate::util::Rng::new(23);
            let events: Vec<TraceEvent> = (0..3000)
                .map(|_| {
                    mem_ev(
                        0x10_000 + rng.below(1 << 12) * 8,
                        if rng.below(2) == 0 { 8 } else { 4 },
                        rng.below(3) == 0,
                    )
                })
                .collect();
            let mut per_event = TrafficAnalyzer::with_policy(policy);
            for ev in &events {
                per_event.on_event(ev);
            }
            let mut lane = TrafficAnalyzer::with_policy(policy);
            let mut lanes = ChunkLanes::default();
            for chunk in events.chunks(700) {
                lanes.rebuild_masked(chunk, lane.lane_needs());
                lane.on_chunk_lanes(chunk, &lanes);
            }
            let (a, b) = (per_event.finalize(3000), lane.finalize(3000));
            assert_eq!(a, b, "{}", policy.name());
        }
    }

    #[test]
    fn sampled_mode_lane_sweep_matches_per_event() {
        // the sampling hash is deterministic, so the SHARDS estimator is
        // just as delivery-independent as the exact kernel
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.25 });
        let mut rng = crate::util::Rng::new(31);
        let events: Vec<TraceEvent> = (0..3000)
            .map(|_| {
                mem_ev(
                    0x10_000 + rng.below(1 << 12) * 8,
                    if rng.below(2) == 0 { 8 } else { 4 },
                    rng.below(3) == 0,
                )
            })
            .collect();
        let mut per_event = TrafficAnalyzer::with_opts(opts);
        for ev in &events {
            per_event.on_event(ev);
        }
        let mut lane = TrafficAnalyzer::with_opts(opts);
        let mut lanes = ChunkLanes::default();
        for chunk in events.chunks(700) {
            lanes.rebuild_masked(chunk, lane.lane_needs());
            lane.on_chunk_lanes(chunk, &lanes);
        }
        let (a, b) = (per_event.finalize(3000), lane.finalize(3000));
        assert_eq!(a.mrc_mode, MrcMode::Sampled { rate: 0.25 });
        assert!(a.mrc_sampled_accesses < a.accesses);
        assert_eq!(a, b);
    }

    #[test]
    fn mrc_smax_caps_the_sampler() {
        // 4096 distinct lines at rate 1.0: uncapped, every access is
        // sampled; with --mrc-smax 16 the fixed-size sampler must shed
        // lines and lower its rate, so it samples strictly fewer
        let feed = |mut t: TrafficAnalyzer| {
            for i in 0..4096u64 {
                t.record(0x40_0000 + i * 64, 8, false);
            }
            t.finalize(4096)
        };
        let opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 1.0 });
        let full = feed(TrafficAnalyzer::with_opts(opts));
        assert_eq!(full.mrc_sampled_accesses, full.accesses);
        let capped = feed(TrafficAnalyzer::with_opts(opts.with_mrc_smax(Some(16))));
        assert_eq!(capped.accesses, 4096);
        assert!(capped.mrc_sampled_accesses > 0);
        assert!(
            capped.mrc_sampled_accesses < full.mrc_sampled_accesses,
            "cap must shed resident lines"
        );
        // smax is inert under the exact kernel
        let exact = feed(TrafficAnalyzer::with_opts(
            TrafficOpts::default().with_mrc_smax(Some(16)),
        ));
        assert_eq!(exact.mrc_mode, MrcMode::Exact);
        assert_eq!(exact.accesses, 4096);
    }

    #[test]
    fn split_halves_reassemble_into_the_full_metrics() {
        // MRC half on one analyzer, hierarchy half on another: the merge
        // must reproduce the unsplit analyzer bit for bit
        let opts = TrafficOpts::with_hierarchy(HierarchyPolicy::Exclusive);
        let mut rng = crate::util::Rng::new(47);
        let events: Vec<TraceEvent> = (0..4000)
            .map(|_| {
                mem_ev(
                    0x20_000 + rng.below(1 << 13) * 8,
                    if rng.below(2) == 0 { 8 } else { 4 },
                    rng.below(4) == 0,
                )
            })
            .collect();
        let mut full = TrafficAnalyzer::with_opts(opts);
        let mut mrc_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::MRC);
        let mut hier_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::HIERARCHY);
        for ev in &events {
            full.on_event(ev);
            mrc_half.on_event(ev);
            hier_half.on_event(ev);
        }
        let mut merged = mrc_half.finalize(4000);
        merged.adopt_parts(hier_half.finalize(4000), TrafficParts::HIERARCHY);
        assert_eq!(merged, full.finalize(4000));
    }

    #[test]
    fn split_halves_request_only_their_lanes() {
        let opts = TrafficOpts::default();
        let full = TrafficAnalyzer::with_opts(opts);
        assert!(full.lane_needs().contains(LaneMask::ADDRS | LaneMask::SIZES | LaneMask::STORES));
        let mrc_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::MRC);
        assert!(mrc_half.lane_needs().contains(LaneMask::SIZES));
        let hier_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::HIERARCHY);
        assert!(hier_half.lane_needs().contains(LaneMask::ADDRS | LaneMask::STORES));
        assert!(!hier_half.lane_needs().contains(LaneMask::SIZES));
    }

    #[test]
    fn mrc_knee_found_on_looping_working_set() {
        // a 256-line (16 KiB) working set walked 100 times: every re-walk
        // access has stack distance 255, so it misses the 4 KiB point and
        // hits from 16 KiB up — the steepest drop (and so the knee) lands
        // exactly at 16 KiB
        let mut t = TrafficAnalyzer::new();
        for _ in 0..100u64 {
            for i in 0..256u64 {
                t.record(0x1_0000 + i * 64, 8, false);
            }
        }
        let m = t.finalize(100_000);
        assert_eq!(m.accesses, 25_600);
        assert_eq!(m.cold_misses, 256);
        assert!(m.mrc_miss_ratio[0] > 0.9, "{:?}", m.mrc_miss_ratio);
        assert!(m.mrc_miss_ratio[1] < 0.05, "{:?}", m.mrc_miss_ratio);
        assert_eq!(m.mrc_knee_bytes, Some(16 << 10));
        // curve is monotone non-increasing (Mattson inclusion)
        for w in m.mrc_miss_ratio.windows(2) {
            assert!(w[1] <= w[0] + 1e-15);
        }
        assert_eq!(m.mrc_capacities.len(), N_MRC_POINTS);
        assert!(N_MRC_POINTS >= 6);
    }

    #[test]
    fn flat_curves_rank_by_footprint_not_one_sentinel() {
        // cache-FRIENDLY flat curve: a single hot line — no knee, and the
        // footprint disambiguation ranks it below the whole family
        let mut friendly = TrafficAnalyzer::new();
        for _ in 0..100 {
            friendly.record(0x40, 8, false);
        }
        let fm = friendly.finalize(100);
        assert_eq!(fm.mrc_knee_bytes, None);
        assert!(fm.knee_or_sentinel() < MRC_CAPACITIES_BYTES[0] as f64);

        // cache-HOSTILE flat curve: a pure cold stream (every access a
        // compulsory miss, flat at 1.0, footprint past the smallest
        // capacity) — no knee, ranks past the family
        let mut hostile = TrafficAnalyzer::new();
        for i in 0..200u64 {
            hostile.record(i * 64, 8, false);
        }
        let hm = hostile.finalize(200);
        assert_eq!(hm.cold_misses, hm.accesses);
        assert_eq!(hm.mrc_knee_bytes, None);
        assert!(hm.knee_or_sentinel() > *MRC_CAPACITIES_BYTES.last().unwrap() as f64);
    }

    #[test]
    fn hierarchy_filters_dram_traffic() {
        // a 128-line hot set walked repeatedly: after the cold pass every
        // access hits L1, so DRAM fills stay at the cold count instead of
        // tracking the access count
        let mut t = TrafficAnalyzer::new();
        for _ in 0..50u64 {
            for i in 0..128u64 {
                t.record(0x2_0000 + i * 64, 8, i % 8 == 0);
            }
        }
        let m = t.finalize(10_000);
        assert_eq!(m.hierarchy_policy, HierarchyPolicy::Inclusive);
        assert_eq!(m.dram_fills, 128, "only compulsory misses cross the LLC");
        assert_eq!(m.dram_writebacks, 0, "resident dirt never reaches DRAM");
        assert_eq!(m.levels[0].hits, 50 * 128 - 128);
        assert_eq!(m.llc().unwrap().misses, m.dram_fills);
        assert!(m.dram_fill_bytes() < m.read_bytes + m.write_bytes);
    }

    #[test]
    fn empty_metrics_are_shape_stable() {
        let m = TrafficMetrics::default();
        // the hand-rolled empty shape must match a never-fed analyzer
        assert_eq!(m, TrafficAnalyzer::new().finalize(0));
        assert_eq!(m.accesses, 0);
        assert_eq!(m.mrc_capacities.len(), N_MRC_POINTS);
        assert_eq!(m.mrc_miss_ratio.len(), N_MRC_POINTS);
        assert!(m.mrc_miss_ratio.iter().all(|&r| r == 0.0));
        assert_eq!(m.mrc_knee_bytes, None);
        assert_eq!(m.levels.len(), HIERARCHY_LEVELS.len());
        assert_eq!(m.hierarchy_policy, HierarchyPolicy::Inclusive);
        assert_eq!((m.dram_fills, m.dram_writebacks), (0, 0));
        assert_eq!(m.bytes_per_instr(), 0.0);
        assert_eq!(m.dram_bytes_per_instr(), 0.0);
    }

    #[test]
    fn json_has_all_sections() {
        let mut t = TrafficAnalyzer::with_policy(HierarchyPolicy::Exclusive);
        for i in 0..500u64 {
            t.record(i * 8, 8, i % 4 == 0);
        }
        let s = t.finalize(1000).to_json().to_string_pretty();
        for key in [
            "bytes_per_instr",
            "miss_ratio",
            "capacities_bytes",
            "mrc_knee_bytes",
            "hierarchy",
            "\"policy\": \"exclusive\"",
            "levels",
            "writebacks",
            "fill_bytes",
            "\"mode\": \"exact\"",
            "sampled_accesses",
        ] {
            assert!(s.contains(key), "missing {key}");
        }

        let mut t = TrafficAnalyzer::with_opts(
            TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.05 }),
        );
        t.record(0x100, 8, false);
        let s = t.finalize(10).to_json().to_string_pretty();
        assert!(s.contains("\"mode\": \"sampled\""), "{s}");
        assert!(s.contains("\"sample_rate\": 0.05"), "{s}");
    }

    #[test]
    fn sweep_grid_matches_standalone_replays() {
        // one-pass DSE: every grid config folded alongside the main
        // replay must be bit-identical to a standalone HierarchyReplay
        // fed the same trace — across per-event and lane delivery, and
        // across the split-halves merge
        use crate::sim::cache::ReplacementKind;
        let mut no_alloc = HierarchyConfig::host(HierarchyPolicy::Inclusive);
        no_alloc.write_allocate = false;
        let mut rrip_l1 = LevelConfig::new("l1", 4 * 64, 2);
        rrip_l1.replacement = ReplacementKind::Rrip;
        let grid: &'static [HierarchyConfig] = Box::leak(
            vec![
                HierarchyConfig::uniform(
                    vec![rrip_l1, LevelConfig::new("l2", 16 * 64, 4)],
                    64,
                    HierarchyPolicy::Inclusive,
                ),
                HierarchyConfig::uniform(
                    vec![LevelConfig::new("l1", 8 * 64, 4)],
                    64,
                    HierarchyPolicy::Exclusive,
                ),
                no_alloc,
            ]
            .into_boxed_slice(),
        );
        let opts = TrafficOpts::default().with_sweep(Some(grid));
        let mut rng = crate::util::Rng::new(59);
        let events: Vec<TraceEvent> = (0..4000)
            .map(|_| {
                mem_ev(
                    0x30_000 + rng.below(1 << 10) * 8,
                    if rng.below(2) == 0 { 8 } else { 4 },
                    rng.below(3) == 0,
                )
            })
            .collect();

        let mut per_event = TrafficAnalyzer::with_opts(opts);
        let mut standalones: Vec<HierarchyReplay> =
            grid.iter().map(|c| HierarchyReplay::new(c.clone())).collect();
        for ev in &events {
            per_event.on_event(ev);
            if let TraceEvent::Instr(i) = ev {
                let m = i.mem.unwrap();
                for s in &mut standalones {
                    s.access(m.addr, m.is_store);
                }
            }
        }
        let mut lane = TrafficAnalyzer::with_opts(opts);
        let mut lanes = ChunkLanes::default();
        for chunk in events.chunks(700) {
            lanes.rebuild_masked(chunk, lane.lane_needs());
            lane.on_chunk_lanes(chunk, &lanes);
        }
        let (a, b) = (per_event.finalize(4000), lane.finalize(4000));
        assert_eq!(a, b, "sweep must be delivery-independent");
        assert_eq!(a.sweep.len(), grid.len());
        for (i, s) in standalones.iter().enumerate() {
            assert_eq!(a.sweep[i], s.sweep_counters(), "grid point {i}");
            assert_eq!(a.sweep[i].config, grid[i]);
        }
        // grid points genuinely differ from each other
        assert!(a.sweep[0].dram_fills != a.sweep[1].dram_fills);

        // the sweep rides the hierarchy half through the sharded merge
        let mut mrc_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::MRC);
        let mut hier_half = TrafficAnalyzer::with_opts_parts(opts, TrafficParts::HIERARCHY);
        for ev in &events {
            mrc_half.on_event(ev);
            hier_half.on_event(ev);
        }
        assert!(mrc_half.finalize(4000).sweep.is_empty());
        let mut merged = mrc_half.finalize(4000);
        merged.adopt_parts(hier_half.finalize(4000), TrafficParts::HIERARCHY);
        assert_eq!(merged, a);

        // JSON gains a "sweep" section only when a grid ran
        let s = a.to_json().to_string_pretty();
        assert!(s.contains("\"sweep\""), "{s}");
        assert!(s.contains("write_allocate"), "{s}");
        assert!(!TrafficMetrics::default().to_json().to_string_pretty().contains("\"sweep\""));
    }

    #[test]
    fn spec_config_replaces_the_host_shape() {
        let spec: &'static HierarchyConfig = Box::leak(Box::new(HierarchyConfig::uniform(
            vec![LevelConfig::new("l1", 2 * 64, 2)],
            64,
            HierarchyPolicy::Exclusive,
        )));
        let opts = TrafficOpts::default().with_spec(Some(spec));
        assert_eq!(opts.main_config(), *spec);
        let mut t = TrafficAnalyzer::with_opts(opts);
        for i in 0..64u64 {
            t.record(0x1000 + i * 64, 8, false);
        }
        let m = t.finalize(64);
        assert_eq!(m.levels.len(), 1);
        assert_eq!(m.levels[0].capacity_bytes, 2 * 64);
        assert_eq!(m.hierarchy_policy, HierarchyPolicy::Exclusive);
        // no spec → exactly the host chain, bit for bit
        assert_eq!(
            TrafficOpts::default().main_config(),
            HierarchyConfig::host(HierarchyPolicy::default())
        );
    }

    #[test]
    fn capacity_labels() {
        assert_eq!(capacity_label(4 << 10), "4K");
        assert_eq!(capacity_label(256 << 10), "256K");
        assert_eq!(capacity_label(1 << 20), "1M");
        assert_eq!(capacity_label(64 << 20), "64M");
    }
}
