//! `TraceReader`: decodes `.pallas-trace` files back into pooled
//! [`EventChunk`]s as a [`TraceSource`], with full validation — bad magic,
//! version mismatch, truncated stream, structural damage and per-lane
//! checksum failures each surface as a typed
//! [`TraceError`](super::TraceError), never a panic. Decoding is streaming:
//! one frame per [`TraceSource::next_chunk`] call, footer verified when the
//! sentinel is reached, so every complete frame of a truncated file is
//! delivered before the error.

use std::fs::File;
use std::io::{self, BufReader, Read};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::format::{
    fnv1a, get_varint, unzigzag, TraceError, TraceHeader, TraceLanes, TraceMeta, TraceProvenance,
    END_MAGIC, FNV_OFFSET, FOOTER_SENTINEL, FORMAT_VERSION, MAGIC, MAX_NAME_LEN,
};
use super::{ChunkStatus, TraceSource};
use crate::interp::{
    EventChunk, ExecStats, InstrEvent, MemAccess, TraceEvent, TAG_BLOCK, TAG_BR_NOT, TAG_BR_TAKEN,
};
use crate::ir::{Op, Reg};

/// Upper bound on encoded bytes per event (tag + block varint + operand
/// structure + address varint + size + store bit, with slack) — used to
/// reject implausible frame lengths before allocating.
const MAX_EVENT_BYTES: usize = 40;

/// Read exactly `buf.len()` bytes, mapping a clean-at-`what` EOF to the
/// typed [`TraceError::Truncated`].
fn read_exact_or(r: &mut impl Read, buf: &mut [u8], what: &'static str) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            anyhow::Error::new(TraceError::Truncated { what })
        } else {
            anyhow::Error::new(e).context("reading trace file")
        }
    })
}

fn read_u16(r: &mut impl Read, what: &'static str) -> Result<u16> {
    let mut b = [0u8; 2];
    read_exact_or(r, &mut b, what)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read, what: &'static str) -> Result<u32> {
    let mut b = [0u8; 4];
    read_exact_or(r, &mut b, what)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read, what: &'static str) -> Result<u64> {
    let mut b = [0u8; 8];
    read_exact_or(r, &mut b, what)?;
    Ok(u64::from_le_bytes(b))
}

fn malformed(what: &'static str) -> anyhow::Error {
    anyhow::Error::new(TraceError::Malformed { what })
}

/// Decoded operand structure for one instruction event.
#[derive(Clone, Copy)]
struct DepRec {
    dst: Option<Reg>,
    srcs: [Reg; 3],
    n_srcs: u8,
}

/// Streaming `.pallas-trace` decoder; see the [`crate::trace`] module doc
/// for the wire layout it validates against.
pub struct TraceReader {
    input: BufReader<File>,
    path: PathBuf,
    header: TraceHeader,
    /// Block open at the next frame's start (carried across frames the
    /// writer cut mid-block).
    cur_block: u32,
    chunks: u64,
    events: u64,
    stats: ExecStats,
    sums: [u64; TraceLanes::COUNT],
    done: bool,
    // frame scratch, reused so steady-state decoding allocates nothing
    body: Vec<u8>,
    blocks_v: Vec<u32>,
    deps_v: Vec<DepRec>,
    addrs_v: Vec<u64>,
}

impl TraceReader {
    /// Open `path` and validate the file header (magic, version, lane mask,
    /// metadata). Frame data is only touched by subsequent
    /// [`TraceSource::next_chunk`] calls.
    pub fn open(path: &Path) -> Result<TraceReader> {
        let file = File::open(path)
            .with_context(|| format!("opening trace file {}", path.display()))?;
        let mut input = BufReader::new(file);
        let mut magic = [0u8; 8];
        read_exact_or(&mut input, &mut magic, "file header")?;
        if magic != MAGIC {
            return Err(anyhow::Error::new(TraceError::BadMagic));
        }
        let version = read_u16(&mut input, "file header")?;
        if version != FORMAT_VERSION {
            return Err(anyhow::Error::new(TraceError::VersionMismatch {
                found: version,
                supported: FORMAT_VERSION,
            }));
        }
        let lanes = TraceLanes::from_bits(read_u16(&mut input, "file header")?);
        if !lanes.contains(TraceLanes::TAGS) {
            return Err(malformed("header lane mask lacks the mandatory tags lane"));
        }
        let chunk_capacity = read_u32(&mut input, "file header")?;
        if chunk_capacity == 0 || chunk_capacity > 1 << 24 {
            return Err(malformed("header chunk capacity out of range"));
        }
        let n = read_u64(&mut input, "file header")?;
        let seed = read_u64(&mut input, "file header")?;
        let name_len = read_u32(&mut input, "file header")?;
        if name_len > MAX_NAME_LEN {
            return Err(malformed("header app name length out of range"));
        }
        let mut name = vec![0u8; name_len as usize];
        read_exact_or(&mut input, &mut name, "file header")?;
        let app = String::from_utf8(name).map_err(|_| malformed("app name is not UTF-8"))?;
        Ok(TraceReader {
            input,
            path: path.to_path_buf(),
            header: TraceHeader {
                version,
                lanes,
                chunk_capacity,
                meta: TraceMeta { app, n, seed },
            },
            cur_block: 0,
            chunks: 0,
            events: 0,
            stats: ExecStats::default(),
            sums: [FNV_OFFSET; TraceLanes::COUNT],
            done: false,
            body: Vec::new(),
            blocks_v: Vec::new(),
            deps_v: Vec::new(),
            addrs_v: Vec::new(),
        })
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Provenance for the report's `"trace"` section — chunk/event counts
    /// reflect what has been decoded so far, so take it after the replay.
    pub fn provenance(&self) -> TraceProvenance {
        TraceProvenance {
            path: self.path.display().to_string(),
            version: self.header.version,
            lanes: self.header.lanes,
            chunk_capacity: self.header.chunk_capacity,
            app: self.header.meta.app.clone(),
            n: self.header.meta.n,
            seed: self.header.meta.seed,
            chunks: self.chunks,
            events: self.events,
        }
    }

    /// Verify the footer (counts, per-lane checksums, end magic) once the
    /// sentinel frame length has been consumed.
    fn read_footer(&mut self) -> Result<()> {
        let chunks = read_u64(&mut self.input, "footer")?;
        let events = read_u64(&mut self.input, "footer")?;
        let mut sums = [0u64; TraceLanes::COUNT];
        for sum in &mut sums {
            *sum = read_u64(&mut self.input, "footer")?;
        }
        let mut end = [0u8; 8];
        read_exact_or(&mut self.input, &mut end, "footer")?;
        if end != END_MAGIC {
            return Err(malformed("footer end marker"));
        }
        if chunks != self.chunks {
            return Err(malformed("footer chunk count disagrees with frames"));
        }
        if events != self.events {
            return Err(malformed("footer event count disagrees with frames"));
        }
        for (i, (&stored, &computed)) in sums.iter().zip(self.sums.iter()).enumerate() {
            if stored != computed {
                return Err(anyhow::Error::new(TraceError::ChecksumMismatch {
                    lane: TraceLanes::NAMES[i],
                    stored,
                    computed,
                }));
            }
        }
        Ok(())
    }

    /// Decode one frame body into `chunk` (cleared first). Reconstructs the
    /// full [`TraceEvent`] stream; sections for absent lanes yield the
    /// neutral defaults (block 0, no operands, address 0), which is safe
    /// because replay planning rejects metric families whose lanes the
    /// trace does not carry.
    fn decode_frame(&mut self, body: &[u8], chunk: &mut EventChunk) -> Result<()> {
        let lanes = self.header.lanes;
        let want_blocks = lanes.contains(TraceLanes::BLOCKS);
        let want_deps = lanes.contains(TraceLanes::DEPS);
        let want_addrs = lanes.contains(TraceLanes::ADDRS);
        let want_sizes = lanes.contains(TraceLanes::SIZES);
        let want_stores = lanes.contains(TraceLanes::STORES);

        if body.len() < 4 {
            return Err(malformed("frame body shorter than its event count"));
        }
        let n = u32::from_le_bytes([body[0], body[1], body[2], body[3]]) as usize;
        if n > chunk.capacity() || n > self.header.chunk_capacity as usize {
            return Err(malformed("frame event count exceeds declared chunk capacity"));
        }
        let mut p = 4usize;

        // tags section: one byte per event; derive the other sections' counts
        let tags = body
            .get(p..p + n)
            .ok_or_else(|| malformed("tags lane overruns frame"))?;
        p += n;
        self.sums[0] = fnv1a(self.sums[0], tags);
        let mut n_blocks = 0usize;
        let mut n_instrs = 0usize;
        let mut n_mem = 0usize;
        for &t in tags {
            match t {
                TAG_BLOCK => n_blocks += 1,
                TAG_BR_TAKEN | TAG_BR_NOT => {}
                _ => {
                    if Op::from_index(t as usize).is_none() {
                        return Err(malformed("unknown op tag"));
                    }
                    n_instrs += 1;
                    if t as usize == Op::Load.index() || t as usize == Op::Store.index() {
                        n_mem += 1;
                    }
                }
            }
        }

        // blocks section: the frame's open block, then one id per block entry
        self.blocks_v.clear();
        let mut frame_open = self.cur_block;
        if want_blocks {
            let start = p;
            let open = get_varint(body, &mut p)
                .ok_or_else(|| malformed("blocks lane overruns frame"))?;
            frame_open =
                u32::try_from(open).map_err(|_| malformed("block id out of range"))?;
            for _ in 0..n_blocks {
                let id = get_varint(body, &mut p)
                    .ok_or_else(|| malformed("blocks lane overruns frame"))?;
                self.blocks_v
                    .push(u32::try_from(id).map_err(|_| malformed("block id out of range"))?);
            }
            self.sums[5] = fnv1a(self.sums[5], &body[start..p]);
        }

        // deps section: (dst+1 | 0), n_srcs, then the source registers
        self.deps_v.clear();
        if want_deps {
            let start = p;
            for _ in 0..n_instrs {
                let dst = get_varint(body, &mut p)
                    .ok_or_else(|| malformed("deps lane overruns frame"))?;
                let dst = match dst {
                    0 => None,
                    d => Some(
                        Reg::try_from(d - 1).map_err(|_| malformed("register out of range"))?,
                    ),
                };
                let n_srcs = *body
                    .get(p)
                    .ok_or_else(|| malformed("deps lane overruns frame"))?;
                p += 1;
                if n_srcs > 3 {
                    return Err(malformed("operand count out of range"));
                }
                let mut srcs: [Reg; 3] = [0; 3];
                for s in srcs.iter_mut().take(n_srcs as usize) {
                    let r = get_varint(body, &mut p)
                        .ok_or_else(|| malformed("deps lane overruns frame"))?;
                    *s = Reg::try_from(r).map_err(|_| malformed("register out of range"))?;
                }
                self.deps_v.push(DepRec { dst, srcs, n_srcs });
            }
            self.sums[4] = fnv1a(self.sums[4], &body[start..p]);
        }

        // addrs section: zigzag deltas chained from 0 at frame start
        self.addrs_v.clear();
        if want_addrs {
            let start = p;
            let mut prev: u64 = 0;
            for _ in 0..n_mem {
                let z = get_varint(body, &mut p)
                    .ok_or_else(|| malformed("addrs lane overruns frame"))?;
                prev = prev.wrapping_add(unzigzag(z) as u64);
                self.addrs_v.push(prev);
            }
            self.sums[1] = fnv1a(self.sums[1], &body[start..p]);
        }

        // sizes section: one byte per memory access
        let sizes = if want_sizes {
            let s = body
                .get(p..p + n_mem)
                .ok_or_else(|| malformed("sizes lane overruns frame"))?;
            p += n_mem;
            self.sums[2] = fnv1a(self.sums[2], s);
            s
        } else {
            &[]
        };

        // store bitset: bit i (LSB-first per byte) set ⇔ access i is a store
        let stores = if want_stores {
            let len = (n_mem + 7) / 8;
            let s = body
                .get(p..p + len)
                .ok_or_else(|| malformed("store bitset overruns frame"))?;
            p += len;
            self.sums[3] = fnv1a(self.sums[3], s);
            s
        } else {
            &[]
        };

        if p != body.len() {
            return Err(malformed("frame has trailing bytes"));
        }

        // reconstruct the event stream
        chunk.clear();
        let mut cur = frame_open;
        let mut bi = 0usize;
        let mut ii = 0usize;
        let mut mi = 0usize;
        for &t in tags {
            match t {
                TAG_BLOCK => {
                    if want_blocks {
                        cur = self.blocks_v[bi];
                    }
                    bi += 1;
                    self.stats.dyn_blocks += 1;
                    chunk.push(TraceEvent::BlockEnter { block: cur });
                }
                TAG_BR_TAKEN | TAG_BR_NOT => {
                    self.stats.dyn_branches += 1;
                    chunk.push(TraceEvent::Branch { block: cur, taken: t == TAG_BR_TAKEN });
                }
                _ => {
                    let op = Op::from_index(t as usize).expect("tag validated above");
                    let dep = if want_deps {
                        self.deps_v[ii]
                    } else {
                        DepRec { dst: None, srcs: [0; 3], n_srcs: 0 }
                    };
                    ii += 1;
                    let mem = if matches!(op, Op::Load | Op::Store) {
                        let addr = if want_addrs { self.addrs_v[mi] } else { 0 };
                        let size = if want_sizes { sizes[mi] } else { 0 };
                        let is_store = if want_stores {
                            (stores[mi / 8] >> (mi % 8)) & 1 == 1
                        } else {
                            op == Op::Store
                        };
                        mi += 1;
                        if is_store {
                            self.stats.mem_writes += 1;
                        } else {
                            self.stats.mem_reads += 1;
                        }
                        Some(MemAccess { addr, size, is_store })
                    } else {
                        None
                    };
                    self.stats.dyn_instrs += 1;
                    chunk.push(TraceEvent::Instr(InstrEvent {
                        op,
                        dst: dep.dst,
                        srcs: dep.srcs,
                        n_srcs: dep.n_srcs,
                        mem,
                        block: cur,
                    }));
                }
            }
        }
        self.cur_block = cur;
        self.chunks += 1;
        self.events += tags.len() as u64;
        Ok(())
    }
}

impl TraceSource for TraceReader {
    fn next_chunk(&mut self, chunk: &mut EventChunk) -> Result<ChunkStatus> {
        if self.done {
            return Ok(ChunkStatus::Done);
        }
        let frame_len = read_u32(&mut self.input, "missing footer")?;
        if frame_len == FOOTER_SENTINEL {
            self.read_footer()?;
            self.done = true;
            return Ok(ChunkStatus::Done);
        }
        let cap = self.header.chunk_capacity as usize;
        if frame_len as usize > 16 + cap * MAX_EVENT_BYTES {
            return Err(malformed("frame length implausible for declared chunk capacity"));
        }
        self.body.resize(frame_len as usize, 0);
        let mut body = std::mem::take(&mut self.body);
        let res = read_exact_or(&mut self.input, &mut body, "frame body")
            .and_then(|_| self.decode_frame(&body, chunk));
        self.body = body;
        res?;
        Ok(ChunkStatus::Delivered)
    }

    fn chunk_capacity(&self) -> usize {
        self.header.chunk_capacity as usize
    }

    fn lanes(&self) -> TraceLanes {
        self.header.lanes
    }

    fn stats(&self) -> ExecStats {
        self.stats.clone()
    }
}
