//! Wire-format primitives for `.pallas-trace` files: lane bits, varint /
//! zigzag coding, FNV-1a checksums, header metadata and the typed
//! [`TraceError`] taxonomy. The byte-for-byte layout is specified in the
//! [`crate::trace`] module doc; [`crate::trace::TraceWriter`] and
//! [`crate::trace::TraceReader`] are the only encoder/decoder pair.

use std::fmt;

use crate::analysis::{Metric, MetricSet};
use crate::util::Json;

/// File magic, offset 0: identifies a `.pallas-trace` stream.
pub const MAGIC: [u8; 8] = *b"PLSTRACE";
/// Trailing magic closing the footer — its absence means the recording
/// process died before [`crate::trace::TraceWriter::finish`].
pub const END_MAGIC: [u8; 8] = *b"PLSTEOF\0";
/// The one format version this build reads and writes. Readers reject any
/// other version with [`TraceError::VersionMismatch`]; additive evolution
/// (new lanes) reuses the version by allocating spare [`TraceLanes`] bits.
pub const FORMAT_VERSION: u16 = 1;
/// Footer sentinel in the frame-length slot: no more frames follow.
pub const FOOTER_SENTINEL: u32 = u32::MAX;
/// Hard cap on the header's app-name length — a corrupt length field must
/// not trigger a giant allocation.
pub const MAX_NAME_LEN: u32 = 4096;

/// Which event lanes a trace carries, one bit per frame section. The low
/// four bits mirror [`crate::interp::LaneMask`] (tags / addrs / sizes /
/// store bitset — the SoA `ChunkLanes` layout); `DEPS` and `BLOCKS` extend
/// it with the operand and block-id structure the dependency and
/// block-parallelism analyzers fold from the full event slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceLanes(u16);

impl TraceLanes {
    pub const NONE: TraceLanes = TraceLanes(0);
    /// Op-tag lane — mandatory: it carries the event structure every other
    /// lane is parsed against, so writers always include it.
    pub const TAGS: TraceLanes = TraceLanes(1 << 0);
    /// Memory-access addresses (delta + zigzag varint coded).
    pub const ADDRS: TraceLanes = TraceLanes(1 << 1);
    /// Memory-access sizes in bytes.
    pub const SIZES: TraceLanes = TraceLanes(1 << 2);
    /// Store bitset over the packed accesses.
    pub const STORES: TraceLanes = TraceLanes(1 << 3);
    /// Operand structure per instruction (dst, n_srcs, srcs).
    pub const DEPS: TraceLanes = TraceLanes(1 << 4);
    /// Basic-block ids (frame open block + one id per block entry).
    pub const BLOCKS: TraceLanes = TraceLanes(1 << 5);
    pub const ALL: TraceLanes = TraceLanes(0b11_1111);

    /// Number of lane slots the footer reserves a checksum for.
    pub const COUNT: usize = 6;
    /// Lane names in bit order (checksum slot order).
    pub const NAMES: [&'static str; TraceLanes::COUNT] =
        ["tags", "addrs", "sizes", "stores", "deps", "blocks"];

    #[inline]
    pub fn contains(self, other: TraceLanes) -> bool {
        self.0 & other.0 == other.0
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Lanes in `self` but not in `have` (what a replay is missing).
    #[inline]
    pub fn minus(self, have: TraceLanes) -> TraceLanes {
        TraceLanes(self.0 & !have.0)
    }

    /// Raw bits as stored in the file header.
    #[inline]
    pub fn bits(self) -> u16 {
        self.0
    }

    /// Decode header bits, dropping any bit this build does not know (a
    /// newer writer within the same format version may add lanes; unknown
    /// lanes cannot be parsed, so the version must change for that).
    #[inline]
    pub fn from_bits(bits: u16) -> TraceLanes {
        TraceLanes(bits & TraceLanes::ALL.0)
    }

    /// Names of the lanes present, in bit order.
    pub fn names(self) -> Vec<&'static str> {
        (0..TraceLanes::COUNT)
            .filter(|i| self.0 >> i & 1 == 1)
            .map(|i| TraceLanes::NAMES[i])
            .collect()
    }
}

impl std::ops::BitOr for TraceLanes {
    type Output = TraceLanes;

    #[inline]
    fn bitor(self, rhs: TraceLanes) -> TraceLanes {
        TraceLanes(self.0 | rhs.0)
    }
}

impl std::ops::BitOrAssign for TraceLanes {
    #[inline]
    fn bitor_assign(&mut self, rhs: TraceLanes) {
        self.0 |= rhs.0;
    }
}

impl fmt::Display for TraceLanes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.names().join("+"))
    }
}

/// Trace lanes one metric family needs to reconstruct the events it folds.
/// `TAGS` is implied everywhere (it carries the event structure itself).
pub fn lanes_for(metric: Metric) -> TraceLanes {
    match metric {
        Metric::Mix => TraceLanes::TAGS,
        Metric::Branch => TraceLanes::TAGS | TraceLanes::BLOCKS,
        Metric::MemEntropy | Metric::Reuse => TraceLanes::TAGS | TraceLanes::ADDRS,
        Metric::Ilp => TraceLanes::TAGS | TraceLanes::DEPS,
        Metric::Dlp => TraceLanes::TAGS | TraceLanes::DEPS | TraceLanes::BLOCKS,
        Metric::Bblp | Metric::Pbblp => TraceLanes::TAGS | TraceLanes::BLOCKS,
        Metric::Traffic => {
            TraceLanes::TAGS | TraceLanes::ADDRS | TraceLanes::SIZES | TraceLanes::STORES
        }
    }
}

/// Union of [`lanes_for`] over every family in `metrics` — what `record`
/// writes for a `--metrics` selection, and what replay must find present.
pub fn required_lanes(metrics: MetricSet) -> TraceLanes {
    Metric::ALL
        .iter()
        .filter(|m| metrics.contains(**m))
        .fold(TraceLanes::TAGS, |acc, m| acc | lanes_for(*m))
}

/// Plan-time lane check for replay: every selected family's lanes must be
/// present in the trace, else the analyzers would silently fold zeroed
/// lanes. Fails with [`TraceError::MissingLanes`] naming the families.
pub fn check_lanes(have: TraceLanes, metrics: MetricSet) -> Result<(), TraceError> {
    let families: Vec<String> = Metric::ALL
        .iter()
        .filter(|m| metrics.contains(**m) && !have.contains(lanes_for(**m)))
        .map(|m| m.name().to_string())
        .collect();
    if families.is_empty() {
        Ok(())
    } else {
        Err(TraceError::MissingLanes { families, missing: required_lanes(metrics).minus(have) })
    }
}

/// App/workload identity recorded in the file header, enough for replay to
/// rebuild the analyzer stack's program context (`registry` kernel name +
/// build parameters).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceMeta {
    pub app: String,
    pub n: u64,
    pub seed: u64,
}

/// Decoded file header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceHeader {
    pub version: u16,
    pub lanes: TraceLanes,
    pub chunk_capacity: u32,
    pub meta: TraceMeta,
}

/// Everything the report's `"trace"` provenance section records about a
/// replayed (or freshly recorded) trace file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceProvenance {
    pub path: String,
    pub version: u16,
    pub lanes: TraceLanes,
    pub chunk_capacity: u32,
    pub app: String,
    pub n: u64,
    pub seed: u64,
    /// Chunk frames decoded (or written).
    pub chunks: u64,
    /// Events decoded (or written).
    pub events: u64,
}

impl TraceProvenance {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("path", self.path.as_str());
        j.set("format_version", self.version as u64);
        let lanes: Vec<Json> = self.lanes.names().into_iter().map(Json::from).collect();
        j.set("lanes", lanes);
        j.set("chunk_capacity", self.chunk_capacity as u64);
        j.set("app", self.app.as_str());
        j.set("n", self.n);
        j.set("seed", self.seed);
        j.set("chunks", self.chunks);
        j.set("events", self.events);
        j
    }
}

/// Typed decode/validation failures, in the PR-7 taxonomy style: carried
/// inside `anyhow::Error` and recovered with `downcast_ref` where callers
/// need to branch on the kind. Every corruption mode maps to exactly one
/// variant — a corrupt trace must never panic the replayer or silently
/// zero an analyzer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceError {
    /// The first 8 bytes are not [`MAGIC`]: not a `.pallas-trace` file.
    BadMagic,
    /// Header carries a format version this build does not read.
    VersionMismatch { found: u16, supported: u16 },
    /// The stream ended early: mid-header, mid-frame, or before the footer
    /// (the signature a recording run left when it died before `finish` —
    /// every complete frame before the cut remains decodable).
    Truncated { what: &'static str },
    /// A lane's footer checksum does not match the bytes decoded.
    ChecksumMismatch { lane: &'static str, stored: u64, computed: u64 },
    /// Structurally invalid contents (impossible lengths, counts that
    /// disagree, trailing bytes) under a well-formed framing.
    Malformed { what: &'static str },
    /// Plan-time replay check: the selected metric families need lanes the
    /// trace does not carry.
    MissingLanes { families: Vec<String>, missing: TraceLanes },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::BadMagic => write!(f, "not a .pallas-trace file (bad magic)"),
            TraceError::VersionMismatch { found, supported } => write!(
                f,
                "unsupported trace format version {found} (this build reads version {supported})"
            ),
            TraceError::Truncated { what } => write!(f, "truncated trace: {what}"),
            TraceError::ChecksumMismatch { lane, stored, computed } => write!(
                f,
                "trace {lane} lane checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            ),
            TraceError::Malformed { what } => write!(f, "malformed trace: {what}"),
            TraceError::MissingLanes { families, missing } => write!(
                f,
                "trace lacks the {missing} lane(s) required by metric families: {} \
                 (re-record with a wider --metrics selection)",
                families.join(", ")
            ),
        }
    }
}

impl std::error::Error for TraceError {}

/// FNV-1a 64-bit offset basis — the initial accumulator for every lane
/// checksum (absent lanes keep it, so all six footer slots verify).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into an FNV-1a 64 accumulator.
#[inline]
pub fn fnv1a(mut acc: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        acc ^= b as u64;
        acc = acc.wrapping_mul(FNV_PRIME);
    }
    acc
}

/// Append an LEB128 varint.
#[inline]
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Read an LEB128 varint from `buf[*pos..]`, advancing `pos`. `None` on
/// overrun or a >10-byte encoding.
#[inline]
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos)?;
        *pos += 1;
        if shift >= 64 {
            return None;
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

/// Zigzag-encode a signed delta for varint coding.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrips_edge_values() {
        let mut buf = Vec::new();
        let cases = [0u64, 1, 0x7f, 0x80, 0x3fff, 0x4000, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            buf.clear();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos), Some(v), "value {v:#x}");
            assert_eq!(pos, buf.len());
        }
        // overrun: an empty buffer yields None, not a panic
        let mut pos = 0;
        assert_eq!(get_varint(&[], &mut pos), None);
        // unterminated continuation bytes yield None
        let mut pos = 0;
        assert_eq!(get_varint(&[0x80; 11], &mut pos), None);
    }

    #[test]
    fn zigzag_roundtrips_and_orders_small_magnitudes_first() {
        for v in [0i64, 1, -1, 2, -2, i64::MAX, i64::MIN, 12345, -98765] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert!(zigzag(-1) < zigzag(64));
        assert_eq!(zigzag(0), 0);
    }

    #[test]
    fn lane_algebra_and_names() {
        let t = TraceLanes::TAGS | TraceLanes::ADDRS;
        assert!(t.contains(TraceLanes::TAGS));
        assert!(!t.contains(TraceLanes::DEPS));
        assert_eq!(t.names(), vec!["tags", "addrs"]);
        assert_eq!(TraceLanes::ALL.names().len(), TraceLanes::COUNT);
        assert_eq!(TraceLanes::from_bits(t.bits()), t);
        // unknown high bits are dropped on decode
        assert_eq!(TraceLanes::from_bits(0xffff), TraceLanes::ALL);
        assert_eq!(TraceLanes::ALL.minus(t), {
            TraceLanes::SIZES | TraceLanes::STORES | TraceLanes::DEPS | TraceLanes::BLOCKS
        });
        assert!(TraceLanes::NONE.is_empty());
    }

    #[test]
    fn required_lanes_cover_selected_families() {
        assert_eq!(required_lanes(MetricSet::from_names("mix").unwrap()), TraceLanes::TAGS);
        let traffic = required_lanes(MetricSet::from_names("traffic").unwrap());
        assert!(traffic.contains(TraceLanes::ADDRS | TraceLanes::SIZES | TraceLanes::STORES));
        assert_eq!(required_lanes(MetricSet::all()), TraceLanes::ALL);
    }

    #[test]
    fn check_lanes_names_the_starved_families() {
        // a tags-only trace satisfies mix but not the rest
        assert!(check_lanes(TraceLanes::TAGS, MetricSet::from_names("mix").unwrap()).is_ok());
        let err = check_lanes(TraceLanes::TAGS, MetricSet::all()).unwrap_err();
        let TraceError::MissingLanes { families, missing } = &err else {
            panic!("expected MissingLanes, got {err}");
        };
        assert!(families.contains(&"traffic".to_string()));
        assert!(families.contains(&"ilp".to_string()));
        assert!(!families.contains(&"mix".to_string()));
        assert!(missing.contains(TraceLanes::ADDRS));
        assert!(!missing.contains(TraceLanes::TAGS));
        // the error formats the family list for the CLI surface
        assert!(err.to_string().contains("traffic"));
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x85944171f73967e8);
    }
}
