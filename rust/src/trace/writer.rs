//! `TraceWriter`: an [`Instrument`] sink that streams the event stream to a
//! `.pallas-trace` file during any pipeline run (see the [`crate::trace`]
//! module doc for the wire layout). Chunk frames map 1:1 onto the delivery
//! chunks on the chunked paths; per-event delivery is buffered back into
//! capacity-sized frames so the file is identical either way.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::format::{
    fnv1a, put_varint, zigzag, TraceLanes, TraceMeta, TraceProvenance, END_MAGIC, FNV_OFFSET,
    FOOTER_SENTINEL, FORMAT_VERSION, MAGIC,
};
use crate::interp::{Instrument, TraceEvent, TAG_BLOCK, TAG_BR_NOT, TAG_BR_TAKEN};
use crate::ir::Op;

/// Per-frame section scratch, reused across frames so steady-state encoding
/// allocates nothing.
#[derive(Default)]
struct FrameBufs {
    tags: Vec<u8>,
    blocks: Vec<u8>,
    deps: Vec<u8>,
    addrs: Vec<u8>,
    sizes: Vec<u8>,
    stores: Vec<u8>,
    body: Vec<u8>,
}

/// Streaming `.pallas-trace` encoder.
///
/// Plug it into any run as an [`Instrument`] (alone, or fanned out next to
/// an analyzer stack); call [`TraceWriter::finish`] after the run to write
/// the footer. A writer dropped without `finish` — the crashed-recording
/// case, exercised by the fault-injection tests — flushes every complete
/// frame but no footer, so a reader later salvages the prefix and reports
/// `Truncated` instead of trusting a half-written file.
///
/// I/O errors are sticky: the first one is remembered, further writes are
/// skipped, and `finish` surfaces it. `on_event`/`on_chunk` stay infallible
/// as the `Instrument` contract requires.
pub struct TraceWriter {
    out: BufWriter<File>,
    meta: TraceMeta,
    lanes: TraceLanes,
    chunk_capacity: usize,
    /// Per-event delivery buffer, cut into capacity-sized frames.
    pending: Vec<TraceEvent>,
    /// Block open at the next frame's start (for frames cut mid-block).
    cur_block: u32,
    chunks: u64,
    events: u64,
    sums: [u64; TraceLanes::COUNT],
    bufs: FrameBufs,
    io_error: Option<io::Error>,
    finished: bool,
}

impl TraceWriter {
    /// Create `path` and write the file header. `chunk_capacity` bounds the
    /// events per frame (use the run's delivery chunk capacity —
    /// [`crate::interp::Machine::chunk_capacity`] — so frames mirror the
    /// delivery chunks); `lanes` selects the sections recorded per frame
    /// (the tags lane is always included — it carries the event structure
    /// every other lane is parsed against).
    pub fn create(
        path: &Path,
        meta: TraceMeta,
        chunk_capacity: usize,
        lanes: TraceLanes,
    ) -> Result<TraceWriter> {
        let lanes = lanes | TraceLanes::TAGS;
        let chunk_capacity = chunk_capacity.max(1);
        let cap32 = u32::try_from(chunk_capacity).context("chunk capacity exceeds u32")?;
        let name_len = u32::try_from(meta.app.len()).context("app name exceeds u32")?;
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut out = BufWriter::new(file);
        let mut header = Vec::with_capacity(36 + meta.app.len());
        header.extend_from_slice(&MAGIC);
        header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        header.extend_from_slice(&lanes.bits().to_le_bytes());
        header.extend_from_slice(&cap32.to_le_bytes());
        header.extend_from_slice(&meta.n.to_le_bytes());
        header.extend_from_slice(&meta.seed.to_le_bytes());
        header.extend_from_slice(&name_len.to_le_bytes());
        header.extend_from_slice(meta.app.as_bytes());
        out.write_all(&header)
            .with_context(|| format!("writing trace header to {}", path.display()))?;
        Ok(TraceWriter {
            out,
            meta,
            lanes,
            chunk_capacity,
            pending: Vec::new(),
            cur_block: 0,
            chunks: 0,
            events: 0,
            sums: [FNV_OFFSET; TraceLanes::COUNT],
            bufs: FrameBufs::default(),
            io_error: None,
            finished: false,
        })
    }

    /// Lanes actually recorded (requested lanes plus the mandatory tags).
    pub fn lanes(&self) -> TraceLanes {
        self.lanes
    }

    /// Chunk frames written so far.
    pub fn chunks(&self) -> u64 {
        self.chunks
    }

    /// Events written so far (buffered per-event deliveries excluded until
    /// their frame is cut).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Provenance of the file being written, for the record-side report.
    pub fn provenance(&self, path: &Path) -> TraceProvenance {
        TraceProvenance {
            path: path.display().to_string(),
            version: FORMAT_VERSION,
            lanes: self.lanes,
            chunk_capacity: self.chunk_capacity as u32,
            app: self.meta.app.clone(),
            n: self.meta.n,
            seed: self.meta.seed,
            chunks: self.chunks,
            events: self.events,
        }
    }

    /// Flush buffered per-event deliveries, write the footer (chunk/event
    /// counts, per-lane FNV-1a checksums, end magic) and sync the stream.
    /// Surfaces any I/O error swallowed during the run.
    pub fn finish(&mut self) -> Result<()> {
        self.flush_pending();
        if self.io_error.is_none() {
            let mut footer = Vec::with_capacity(4 + 8 * (2 + TraceLanes::COUNT) + 8);
            footer.extend_from_slice(&FOOTER_SENTINEL.to_le_bytes());
            footer.extend_from_slice(&self.chunks.to_le_bytes());
            footer.extend_from_slice(&self.events.to_le_bytes());
            for sum in &self.sums {
                footer.extend_from_slice(&sum.to_le_bytes());
            }
            footer.extend_from_slice(&END_MAGIC);
            if let Err(e) = self.out.write_all(&footer).and_then(|_| self.out.flush()) {
                self.io_error = Some(e);
            }
        }
        self.finished = true;
        match self.io_error.take() {
            None => Ok(()),
            Some(e) => Err(anyhow::Error::new(e).context("writing trace file")),
        }
    }

    fn flush_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.pending);
        self.write_frames(&pending);
        self.pending = pending;
        self.pending.clear();
    }

    /// Encode `events` as one frame per `chunk_capacity` slice (delivery
    /// chunks are already within capacity, so they map to exactly one).
    fn write_frames(&mut self, events: &[TraceEvent]) {
        for part in events.chunks(self.chunk_capacity) {
            self.write_frame(part);
        }
    }

    fn write_frame(&mut self, events: &[TraceEvent]) {
        if events.is_empty() || self.io_error.is_some() {
            return;
        }
        let want_blocks = self.lanes.contains(TraceLanes::BLOCKS);
        let want_deps = self.lanes.contains(TraceLanes::DEPS);
        let want_addrs = self.lanes.contains(TraceLanes::ADDRS);
        let want_sizes = self.lanes.contains(TraceLanes::SIZES);
        let want_stores = self.lanes.contains(TraceLanes::STORES);
        let b = &mut self.bufs;
        b.tags.clear();
        b.blocks.clear();
        b.deps.clear();
        b.addrs.clear();
        b.sizes.clear();
        b.stores.clear();
        if want_blocks {
            // the block open at frame start, for frames cut mid-block
            put_varint(&mut b.blocks, self.cur_block as u64);
        }
        let mut prev_addr: u64 = 0;
        let mut n_mem: usize = 0;
        for ev in events {
            match *ev {
                TraceEvent::BlockEnter { block } => {
                    b.tags.push(TAG_BLOCK);
                    if want_blocks {
                        put_varint(&mut b.blocks, block as u64);
                    }
                    self.cur_block = block;
                }
                TraceEvent::Branch { taken, .. } => {
                    b.tags.push(if taken { TAG_BR_TAKEN } else { TAG_BR_NOT });
                }
                TraceEvent::Instr(i) => {
                    b.tags.push(i.op.index() as u8);
                    if want_deps {
                        put_varint(&mut b.deps, i.dst.map_or(0, |r| r as u64 + 1));
                        b.deps.push(i.n_srcs);
                        for &s in i.sources() {
                            put_varint(&mut b.deps, s as u64);
                        }
                    }
                    // mem-bearing events are exactly load/store tags — the
                    // decoder relies on this to parse the access sections
                    debug_assert_eq!(i.mem.is_some(), matches!(i.op, Op::Load | Op::Store));
                    if let Some(m) = i.mem {
                        if want_addrs {
                            let delta = (m.addr as i64).wrapping_sub(prev_addr as i64);
                            put_varint(&mut b.addrs, zigzag(delta));
                            prev_addr = m.addr;
                        }
                        if want_sizes {
                            b.sizes.push(m.size);
                        }
                        if want_stores {
                            if n_mem % 8 == 0 {
                                b.stores.push(0);
                            }
                            if m.is_store {
                                let last = b.stores.len() - 1;
                                b.stores[last] |= 1u8 << (n_mem % 8);
                            }
                        }
                        n_mem += 1;
                    }
                }
            }
        }
        b.body.clear();
        b.body.extend_from_slice(&(events.len() as u32).to_le_bytes());
        // fixed section order; the checksum slot index is the lane's bit
        let sections: [(&[u8], bool, usize); 6] = [
            (b.tags.as_slice(), true, 0),
            (b.blocks.as_slice(), want_blocks, 5),
            (b.deps.as_slice(), want_deps, 4),
            (b.addrs.as_slice(), want_addrs, 1),
            (b.sizes.as_slice(), want_sizes, 2),
            (b.stores.as_slice(), want_stores, 3),
        ];
        for (sec, present, slot) in sections {
            if present {
                self.sums[slot] = fnv1a(self.sums[slot], sec);
                b.body.extend_from_slice(sec);
            }
        }
        self.chunks += 1;
        self.events += events.len() as u64;
        let frame_len = (b.body.len() as u32).to_le_bytes();
        let mut res = self.out.write_all(&frame_len);
        if res.is_ok() {
            res = self.out.write_all(&b.body);
        }
        if let Err(e) = res {
            self.io_error = Some(e);
        }
    }
}

impl Instrument for TraceWriter {
    fn on_event(&mut self, ev: &TraceEvent) {
        self.pending.push(*ev);
        if self.pending.len() >= self.chunk_capacity {
            self.flush_pending();
        }
    }

    fn on_chunk(&mut self, events: &[TraceEvent]) {
        // mixed delivery keeps event order: anything buffered goes first
        self.flush_pending();
        self.write_frames(events);
    }
    // no on_chunk_lanes / wants_lanes override: the writer reads the raw
    // event slice, so it never forces a lane build on the delivery path
}

impl Drop for TraceWriter {
    /// Best-effort flush of complete frames when the run died before
    /// [`TraceWriter::finish`] — deliberately no footer, so readers see the
    /// truncation instead of a file that lies about being complete.
    fn drop(&mut self) {
        if !self.finished {
            let _ = self.out.flush();
        }
    }
}
