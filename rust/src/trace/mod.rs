//! Trace ingestion subsystem: pull-based event sources and the versioned
//! `.pallas-trace` binary chunk format with a record/replay pair.
//!
//! The analyzer stack used to be fed push-style by `Machine::run` alone.
//! This module inverts that: a [`TraceSource`] produces [`EventChunk`]s on
//! demand, the interpreter is just one source behind the [`InterpSource`]
//! adapter, and a recorded file is another ([`TraceReader`]). Everything
//! downstream — all four delivery modes, both hierarchy policies, exact and
//! sampled MRC — runs unchanged on either, and the round-trip
//! interpret → serialize → decode → analyze is bit-identical to direct
//! analysis (pinned by `rust/tests/prop_trace.rs`).
//!
//! # `.pallas-trace` wire format, version 1
//!
//! All integers are little-endian. Varints are LEB128 over `u64` (7 data
//! bits per byte, high bit = continuation, at most 10 bytes); signed deltas
//! are zigzag-folded first (`(d << 1) ^ (d >> 63)`), so small magnitudes of
//! either sign encode in one byte. The file is header, then length-prefixed
//! chunk frames, then a footer:
//!
//! ## File header
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 8    | magic `"PLSTRACE"` |
//! | 8      | 2    | format version (`u16`, this build: 1) |
//! | 10     | 2    | lane mask (`u16`, [`TraceLanes`] bits) |
//! | 12     | 4    | chunk capacity (`u32`, max events per frame) |
//! | 16     | 8    | workload scale `n` (`u64`) |
//! | 24     | 8    | workload seed (`u64`) |
//! | 32     | 4    | app-name length (`u32`) |
//! | 36     | var  | app name (UTF-8) |
//!
//! ## Chunk frames
//!
//! Each frame is a `u32` body length followed by the body — the SoA
//! [`ChunkLanes`](crate::interp::ChunkLanes) layout serialized directly,
//! one section per recorded lane, in this fixed order (absent lanes are
//! simply omitted):
//!
//! | section | contents |
//! |---------|----------|
//! | count   | `u32` event count `n` (≤ header chunk capacity) |
//! | tags    | `n` bytes: `Op::index()`, or `0xFD` block entry / `0xFE` branch taken / `0xFF` branch not taken |
//! | blocks  | varint *open block* (block current at frame start), then one varint block id per `0xFD` tag |
//! | deps    | per instruction tag: varint `dst+1` (0 = none), `u8` source count (≤ 3), then that many varint register ids |
//! | addrs   | per memory access (load/store tags): zigzag varint delta from the previous access address (previous resets to 0 at each frame start) |
//! | sizes   | per memory access: `u8` size in bytes |
//! | stores  | bitset, `ceil(n_mem/8)` bytes, LSB-first: bit *i* set ⇔ access *i* is a store |
//!
//! Load and store tags are exactly the mem-bearing events: their count
//! determines the addrs/sizes/stores section lengths. Branch and
//! instruction events belong to the block opened by the most recent `0xFD`
//! tag (or the frame's open block before the first one).
//!
//! ## Footer
//!
//! | size | field |
//! |-----:|-------|
//! | 4    | sentinel `0xFFFF_FFFF` in the frame-length slot |
//! | 8    | total chunk frames (`u64`) |
//! | 8    | total events (`u64`) |
//! | 48   | six `u64` FNV-1a 64 checksums, one per lane in bit order (tags, addrs, sizes, stores, deps, blocks), each accumulated over that lane's section bytes across all frames; absent lanes keep the FNV offset basis |
//! | 8    | end magic `"PLSTEOF\0"` |
//!
//! A missing footer is the signature of a recording that died mid-run:
//! [`TraceWriter`]'s drop path flushes complete frames but never the
//! footer, and [`TraceReader`] streams those frames before reporting
//! [`TraceError::Truncated`].
//!
//! ## Versioning policy
//!
//! The version field covers the whole layout: readers reject any version
//! they were not built for ([`TraceError::VersionMismatch`]) rather than
//! guess. Adding a *lane* is additive within a version — writers mark the
//! bit, old payloads stay parseable — but any change to an existing
//! section's encoding, the header, or the footer bumps the version.
//! Spare lane-mask bits are reserved and must be zero; readers drop bits
//! they do not know.

mod format;
mod reader;
mod writer;

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, Result};

pub use format::{
    check_lanes, fnv1a, lanes_for, required_lanes, TraceError, TraceHeader, TraceLanes, TraceMeta,
    TraceProvenance, END_MAGIC, FNV_OFFSET, FOOTER_SENTINEL, FORMAT_VERSION, MAGIC,
};
pub use reader::TraceReader;
pub use writer::TraceWriter;

use crate::interp::{EventChunk, ExecStats, Instrument, LaneMask, Machine, TraceEvent};
use crate::interp::machine::StepState;
use crate::ir::Program;

/// What a [`TraceSource::next_chunk`] call produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkStatus {
    /// The chunk holds the next batch of events (possibly a short tail).
    Delivered,
    /// The stream is exhausted; the chunk was left untouched.
    Done,
}

/// A pull-based producer of event chunks — the ingestion side of the
/// pipeline. The IR interpreter implements it behind [`InterpSource`]; a
/// recorded `.pallas-trace` file implements it as [`TraceReader`]. The
/// analysis layer consumes a source through the `profile_source_*` entry
/// points, so every analyzer runs unchanged on either origin.
pub trait TraceSource {
    /// Fill `chunk` (cleared by the callee) with the next events, in trace
    /// order. Returns [`ChunkStatus::Done`] when the stream is exhausted;
    /// errors are terminal (interpreter fault, decode failure).
    fn next_chunk(&mut self, chunk: &mut EventChunk) -> Result<ChunkStatus>;

    /// Natural chunk capacity of this source; drivers size their pooled
    /// chunks with it.
    fn chunk_capacity(&self) -> usize;

    /// Which event lanes this source actually populates. Live
    /// interpretation carries everything; a recorded trace only what was
    /// written — replay planning checks this against the selected metric
    /// families ([`check_lanes`]).
    fn lanes(&self) -> TraceLanes;

    /// Execution statistics accumulated so far (wall time not included —
    /// the driver owns the clock).
    fn stats(&self) -> ExecStats;
}

/// The IR interpreter as a [`TraceSource`]: a [`Machine`] driven one basic
/// block at a time, filling the caller's chunk at the same block-boundary
/// flush policy as push-mode delivery. A block bigger than the remaining
/// headroom spills into a side queue drained by the next call, so no
/// program shape can overflow a chunk.
pub struct InterpSource<'p> {
    machine: Machine<'p>,
    st: StepState,
    spill: VecDeque<TraceEvent>,
}

impl<'p> InterpSource<'p> {
    pub fn new(prog: &'p Program) -> Result<Self> {
        let machine = Machine::new(prog)?;
        let st = machine.start();
        Ok(InterpSource { machine, st, spill: VecDeque::new() })
    }

    /// The machine, for post-run memory inspection.
    pub fn machine(&self) -> &Machine<'p> {
        &self.machine
    }
}

/// Event sink for one `step_block` call: fill the chunk, overflow to the
/// spill queue.
struct SpillSink<'a> {
    chunk: &'a mut EventChunk,
    spill: &'a mut VecDeque<TraceEvent>,
}

impl crate::interp::machine::EventSink for SpillSink<'_> {
    #[inline]
    fn event(&mut self, ev: TraceEvent) {
        if self.chunk.is_full() {
            self.spill.push_back(ev);
        } else {
            self.chunk.push(ev);
        }
    }

    #[inline]
    fn block_boundary(&mut self, _upcoming: usize) {}

    fn finish(&mut self) {}
}

impl TraceSource for InterpSource<'_> {
    fn next_chunk(&mut self, chunk: &mut EventChunk) -> Result<ChunkStatus> {
        chunk.clear();
        while let Some(ev) = self.spill.pop_front() {
            chunk.push(ev);
            if chunk.is_full() {
                return Ok(ChunkStatus::Delivered);
            }
        }
        loop {
            if self.st.done {
                return Ok(if chunk.is_empty() {
                    ChunkStatus::Done
                } else {
                    ChunkStatus::Delivered
                });
            }
            let upcoming = self.machine.upcoming(&self.st)?;
            if !chunk.is_empty() && chunk.needs_flush_for_block(upcoming) {
                return Ok(ChunkStatus::Delivered);
            }
            let mut sink = SpillSink { chunk: &mut *chunk, spill: &mut self.spill };
            self.machine.step_block(&mut self.st, &mut sink)?;
            if chunk.is_full() {
                return Ok(ChunkStatus::Delivered);
            }
        }
    }

    fn chunk_capacity(&self) -> usize {
        self.machine.chunk_capacity()
    }

    fn lanes(&self) -> TraceLanes {
        TraceLanes::ALL
    }

    fn stats(&self) -> ExecStats {
        self.st.stats.clone()
    }
}

/// Drive a source to completion on the caller thread, flushing each chunk
/// into `sink` (the inline delivery shape).
pub fn replay_chunked(source: &mut dyn TraceSource, sink: &mut dyn Instrument) -> Result<()> {
    let mut chunk = EventChunk::with_capacity(source.chunk_capacity());
    loop {
        match source.next_chunk(&mut chunk)? {
            ChunkStatus::Done => return Ok(()),
            ChunkStatus::Delivered => chunk.flush_into(sink),
        }
    }
}

/// Drive a source with one `on_event` virtual call per event — the
/// un-batched reference path for the bit-identity tests.
pub fn replay_per_event(source: &mut dyn TraceSource, sink: &mut dyn Instrument) -> Result<()> {
    let mut chunk = EventChunk::with_capacity(source.chunk_capacity());
    loop {
        match source.next_chunk(&mut chunk)? {
            ChunkStatus::Done => return Ok(()),
            ChunkStatus::Delivered => {
                for ev in chunk.events() {
                    sink.on_event(ev);
                }
                chunk.clear();
            }
        }
    }
}

/// Drive a source with the whole sink stack on a dedicated analysis thread
/// behind a bounded recycled-chunk channel (the offload delivery shape).
/// The producer stays on the caller thread, so the source needs no `Send`
/// bound. Strict semantics: a dead or panicked analysis thread is an
/// error, not a degraded run.
pub fn replay_offload(
    source: &mut dyn TraceSource,
    sink: &mut (dyn Instrument + Send),
) -> Result<()> {
    let cap = source.chunk_capacity();
    std::thread::scope(|scope| {
        let (full_tx, full_rx) = mpsc::sync_channel::<EventChunk>(2);
        let (free_tx, free_rx) = mpsc::channel::<EventChunk>();
        for _ in 0..4 {
            let _ = free_tx.send(EventChunk::with_capacity(cap));
        }
        let worker = scope.spawn(move || {
            while let Ok(mut chunk) = full_rx.recv() {
                chunk.flush_into(&mut *sink);
                if free_tx.send(chunk).is_err() {
                    break;
                }
            }
        });
        let mut result: Result<()> = Ok(());
        loop {
            let mut chunk = match free_rx.recv() {
                Ok(c) => c,
                Err(_) => break, // worker gone; join below reports why
            };
            match source.next_chunk(&mut chunk) {
                Err(e) => {
                    result = Err(e);
                    break;
                }
                Ok(ChunkStatus::Done) => break,
                Ok(ChunkStatus::Delivered) => {
                    if full_tx.send(chunk).is_err() {
                        break;
                    }
                }
            }
        }
        drop(full_tx);
        if worker.join().is_err() {
            bail!("replay analysis thread panicked");
        }
        result
    })
}

/// Drive a source broadcasting every chunk to per-family analyzer shards on
/// their own threads (the sharded delivery shape): chunks are shared as
/// `Arc<EventChunk>` with lanes pre-built from the union of shard needs,
/// and recycled through a countdown-return channel once every shard has
/// dropped its handle. Strict semantics: a dead shard fails the replay.
pub fn replay_sharded(
    source: &mut dyn TraceSource,
    shards: &mut [&mut (dyn Instrument + Send)],
) -> Result<()> {
    if shards.is_empty() {
        bail!("sharded replay needs at least one analyzer shard");
    }
    let union_needs = shards.iter().fold(LaneMask::NONE, |acc, s| acc | s.lane_needs());
    let cap = source.chunk_capacity();
    let n_shards = shards.len();
    std::thread::scope(|scope| {
        let (ret_tx, ret_rx) = mpsc::channel::<Arc<EventChunk>>();
        let mut senders = Vec::with_capacity(n_shards);
        let mut handles = Vec::with_capacity(n_shards);
        for shard in shards.iter_mut() {
            let (tx, rx) = mpsc::sync_channel::<Arc<EventChunk>>(2);
            senders.push(tx);
            let ret_tx = ret_tx.clone();
            handles.push(scope.spawn(move || {
                while let Ok(chunk) = rx.recv() {
                    shard.on_chunk_lanes(chunk.events(), chunk.lanes());
                    let _ = ret_tx.send(chunk);
                }
            }));
        }
        drop(ret_tx);
        let mut spares: Vec<EventChunk> =
            (0..2 * n_shards + 2).map(|_| EventChunk::with_capacity(cap)).collect();
        let mut result: Result<()> = Ok(());
        'produce: loop {
            // reclaim a buffer: a spare, or a returned chunk once the last
            // shard's handle comes back (Arc strong count down to ours)
            let mut chunk = loop {
                if let Some(c) = spares.pop() {
                    break c;
                }
                match ret_rx.recv() {
                    Ok(arc) => {
                        if let Ok(mut c) = Arc::try_unwrap(arc) {
                            c.clear();
                            break c;
                        }
                    }
                    Err(_) => break 'produce, // every shard gone; join reports
                }
            };
            match source.next_chunk(&mut chunk) {
                Err(e) => {
                    result = Err(e);
                    break;
                }
                Ok(ChunkStatus::Done) => break,
                Ok(ChunkStatus::Delivered) => {
                    if !union_needs.is_empty() {
                        chunk.build_lanes(union_needs);
                    }
                    let arc = Arc::new(chunk);
                    for tx in &senders {
                        if tx.send(arc.clone()).is_err() {
                            result = Err(anyhow!("replay analyzer shard died"));
                            break 'produce;
                        }
                    }
                }
            }
        }
        drop(senders);
        for h in handles {
            if h.join().is_err() {
                result = Err(anyhow!("replay analyzer shard panicked"));
            }
        }
        result
    })
}
